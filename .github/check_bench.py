#!/usr/bin/env python3
"""Bench-record equivalence gate.

Scans every BENCH_*.json the bench smoke produced and fails if any
boolean field whose name marks an equivalence assertion (contains
"identical" or "equiv", or ends with "_ok") is false. The benches assert
these themselves, but the gate also catches a record flushed before an
abort and future benches that record without asserting.
"""

import glob
import json
import sys

files = sorted(set(glob.glob("BENCH_*.json") + glob.glob("rust/BENCH_*.json")))
if not files:
    sys.exit("bench gate: no BENCH_*.json records found")


def is_equiv_key(key: str) -> bool:
    k = key.lower()
    return "identical" in k or "equiv" in k or k.endswith("_ok")


failures = []
checked = 0


def walk(path: str, node, record: str):
    global checked
    if isinstance(node, dict):
        for key, val in node.items():
            walk(f"{path}.{key}" if path else key, val, record)
    elif isinstance(node, list):
        for i, val in enumerate(node):
            walk(f"{path}[{i}]", val, record)
    elif isinstance(node, bool):
        leaf = path.rsplit(".", 1)[-1]
        if is_equiv_key(leaf):
            checked += 1
            if node is False:
                failures.append(f"{record}: {path} = false")


for f in files:
    with open(f) as fh:
        try:
            data = json.load(fh)
        except json.JSONDecodeError as e:
            failures.append(f"{f}: unparseable record ({e})")
            continue
    walk("", data, f)

print(f"bench gate: {len(files)} record(s), {checked} equivalence flag(s) checked")
if failures:
    print("bench gate FAILURES:")
    for line in failures:
        print(f"  {line}")
    sys.exit(1)
