#!/usr/bin/env python3
"""Bench-record equivalence gate.

Scans every BENCH_*.json the bench smoke produced and fails if any
boolean field whose name marks an equivalence assertion (contains
"identical" or "equiv", or ends with "_ok") is false. The benches assert
these themselves, but the gate also catches a record flushed before an
abort and future benches that record without asserting.

Record schema the gate relies on
--------------------------------
Every record is a single JSON object written by
``rust/src/util/bench.rs``'s ``write_json``. The gate reads three kinds
of field, all at the top level of the object unless noted:

* **generic equivalence booleans** — any boolean anywhere in the record
  (nested objects and arrays included) whose key contains ``identical``
  or ``equiv`` or ends with ``_ok`` must be ``true``. Name a flag this
  way to opt it into the gate with no python changes.
* **required flags** (``REQUIRED_FLAGS``) — per-record booleans that
  must be present at the top level and literally ``true``; a rename or
  a dropped write fails the gate even if the run aborted early:

  - ``BENCH_shard.json``: ``tcp_bit_identical`` (TCP transport ≡
    in-process), ``wedge_recovered`` (heartbeat wedge recovery fired).
  - ``BENCH_serve.json``: ``kernel_bit_identical`` (block decode ≡
    scalar reference).
  - ``BENCH_serve_live.json``: ``batched_bit_identical`` (every batched
    reply ≡ the serial oracle).
  - ``BENCH_budget.json``: ``allocation_bit_identical`` (sharded budget
    plan ≡ in-process plan), ``allocated_beats_uniform`` (allocated
    plan's PPL is no worse than the best uniform (bits, rank) baseline
    at every equal-byte budget point).
  - ``BENCH_spill.json``: ``spill_bit_identical`` (out-of-core sweep
    under a small blob cap ≡ the in-memory engine — outcomes, lock-step
    groups, fleet PPL), ``resume_bit_identical`` (a run killed at a
    chunk boundary and resumed from the spill dir ≡ in-memory).

* **required numbers** (``REQUIRED_NUMBERS``) — per-record numeric
  fields that must be present and finite (NaN/inf/bool stand-ins fail):

  - ``BENCH_serve.json``: ``decode_bytes``, ``flops``, ``achieved_gbps``
    (roofline accounting).
  - ``BENCH_serve_live.json``: ``sustained_rps``, ``p99_latency_ms``
    (the daemon actually served load).
"""

import glob
import json
import os
import sys

files = sorted(set(glob.glob("BENCH_*.json") + glob.glob("rust/BENCH_*.json")))
if not files:
    sys.exit("bench gate: no BENCH_*.json records found")

# Records and flags that MUST be present (and true), so a bench
# refactor cannot silently drop an equivalence assertion by renaming a
# record or skipping its write: the shard record has to exist and has
# to prove the TCP transport, not just the pipes, and to prove the
# heartbeat wedge-recovery path actually fired; the serve record has to
# prove the block decode kernels stayed bit-identical to the scalar
# reference. (CI always runs `--exp shard` and `--exp serve`, so a
# missing record is itself a failure.)
REQUIRED_FLAGS = {
    "BENCH_shard.json": ["tcp_bit_identical", "wedge_recovered"],
    "BENCH_serve.json": ["kernel_bit_identical"],
    # the live-daemon record has to prove every batched request matched
    # the serial one-at-a-time oracle bit for bit
    "BENCH_serve_live.json": ["batched_bit_identical"],
    # the budget record has to prove the allocator beat (or tied) the
    # best uniform baseline at equal bytes AND that the sharded plan is
    # byte-for-byte the in-process plan
    "BENCH_budget.json": ["allocation_bit_identical", "allocated_beats_uniform"],
    # the spill record has to prove the out-of-core sweep and its
    # killed-and-resumed variant both reproduced the in-memory engine
    # bit for bit
    "BENCH_spill.json": ["spill_bit_identical", "resume_bit_identical"],
}

# Numeric fields that MUST be present (finite numbers): the serve
# roofline accounting, so a kernel regression can't hide by dropping
# the bytes/FLOPs bookkeeping from the record; the live-daemon load
# metrics, so the serve_live leg can't pass with zero completed
# requests.
REQUIRED_NUMBERS = {
    "BENCH_serve.json": ["decode_bytes", "flops", "achieved_gbps"],
    "BENCH_serve_live.json": ["sustained_rps", "p99_latency_ms"],
}

present = {os.path.basename(f) for f in files}
required_names = set(REQUIRED_FLAGS) | set(REQUIRED_NUMBERS)
missing_records = [name for name in required_names if name not in present]


def is_equiv_key(key: str) -> bool:
    k = key.lower()
    return "identical" in k or "equiv" in k or k.endswith("_ok")


failures = [
    f"{name}: required bench record missing (were --exp shard/serve/serve_live/budget/spill run?)"
    for name in missing_records
]
checked = 0


def walk(path: str, node, record: str):
    global checked
    if isinstance(node, dict):
        for key, val in node.items():
            walk(f"{path}.{key}" if path else key, val, record)
    elif isinstance(node, list):
        for i, val in enumerate(node):
            walk(f"{path}[{i}]", val, record)
    elif isinstance(node, bool):
        leaf = path.rsplit(".", 1)[-1]
        if is_equiv_key(leaf):
            checked += 1
            if node is False:
                failures.append(f"{record}: {path} = false")


for f in files:
    with open(f) as fh:
        try:
            data = json.load(fh)
        except json.JSONDecodeError as e:
            failures.append(f"{f}: unparseable record ({e})")
            continue
    walk("", data, f)
    for flag in REQUIRED_FLAGS.get(os.path.basename(f), []):
        # the flag must be the literal boolean true — a string/int/null
        # stand-in would dodge the walk's bool-only validation
        if not isinstance(data, dict) or data.get(flag) is not True:
            failures.append(
                f"{f}: required equivalence flag '{flag}' missing or not true"
            )
    for field in REQUIRED_NUMBERS.get(os.path.basename(f), []):
        # bool is an int subclass in python — exclude it explicitly
        val = data.get(field) if isinstance(data, dict) else None
        ok = (
            isinstance(val, (int, float))
            and not isinstance(val, bool)
            and val == val  # NaN guard
            and abs(val) != float("inf")
        )
        if not ok:
            failures.append(
                f"{f}: required roofline field '{field}' missing or not a finite number"
            )

print(f"bench gate: {len(files)} record(s), {checked} equivalence flag(s) checked")
if failures:
    print("bench gate FAILURES:")
    for line in failures:
        print(f"  {line}")
    sys.exit(1)
