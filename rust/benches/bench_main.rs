//! `cargo bench` entry point: regenerates every paper table and figure.
//!
//! Custom harness (criterion is unavailable offline); experiment ids and
//! their paper mapping live in `srr::exp::registry` / DESIGN.md §5.
//!
//!   cargo bench                   # full suite (records EXPERIMENTS.md)
//!   cargo bench -- --exp table1   # one experiment
//!   cargo bench -- --quick        # smoke sizes
//!
//! Without `artifacts/` (or without the `pjrt` feature) the PJRT-bound
//! experiments are skipped with a note and the `offline_ok` ones (e.g.
//! `sweep`) still run against the embedded model configs — so a plain
//! checkout smoke-runs in CI and exits 0.

use srr::exp::{offline_ok, registry, run, ExpCtx};

/// Run experiments; returns the number of failures so callers can exit
/// nonzero — a failed experiment (e.g. sweep_bench's byte-identity
/// assertion) must fail the CI smoke, not just print.
fn run_ids(ctx: &mut ExpCtx, ids: &[String]) -> usize {
    let suite_start = std::time::Instant::now();
    let mut failures = 0usize;
    for id in ids {
        let t0 = std::time::Instant::now();
        match run(id, ctx) {
            Ok(tables) => {
                for t in tables {
                    t.print();
                }
                println!("[{id} done in {:.1}s]\n", t0.elapsed().as_secs_f64());
            }
            Err(e) => {
                eprintln!("[{id} FAILED: {e:#}]");
                failures += 1;
            }
        }
    }
    println!("[suite done in {:.1}s]", suite_start.elapsed().as_secs_f64());
    failures
}

fn main() {
    // the shard bench spawns `srr shard-worker` processes; cargo hands
    // bench targets the bin's absolute path at compile time
    std::env::set_var("SRR_SHARD_BIN", env!("CARGO_BIN_EXE_srr"));
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let quick = raw.iter().any(|a| a == "--quick");
    let exps: Vec<String> = {
        let mut out = vec![];
        let mut it = raw.iter();
        while let Some(a) = it.next() {
            if a == "--exp" {
                if let Some(v) = it.next() {
                    out.push(v.clone());
                }
            }
        }
        out
    };
    // `cargo bench` passes --bench and test-harness flags; ignore unknowns.
    let ids: Vec<String> = if exps.is_empty() {
        registry().iter().map(|e| e.id.to_string()).collect()
    } else {
        exps
    };
    // fail fast on typo'd ids — the offline fallback below must never
    // reclassify an unknown id as merely "PJRT-bound" and exit 0
    let known: Vec<&'static str> = registry().iter().map(|e| e.id).collect();
    if let Some(bad) = ids.iter().find(|id| !known.contains(&id.as_str())) {
        eprintln!("unknown experiment '{bad}' (see `srr bench --list`)");
        std::process::exit(2);
    }

    let failures = match ExpCtx::new(quick) {
        Ok(mut ctx) => run_ids(&mut ctx, &ids),
        Err(e) => {
            // no artifacts / no PJRT: run the offline-capable subset,
            // skip the rest cleanly (exit 0 — this is the expected state
            // of a fresh clone and of CI)
            let (offline_ids, skipped): (Vec<String>, Vec<String>) =
                ids.into_iter().partition(|id| offline_ok(id));
            if !skipped.is_empty() {
                eprintln!(
                    "[skipping {} PJRT-bound experiment(s) ({}): {e:#}; run `make artifacts` \
                     and build with --features pjrt for the full suite]",
                    skipped.len(),
                    skipped.join(", ")
                );
            }
            if offline_ids.is_empty() {
                println!("[no offline-capable experiments requested — nothing to run]");
                return;
            }
            match ExpCtx::offline(quick) {
                Ok(mut ctx) => run_ids(&mut ctx, &offline_ids),
                Err(e2) => {
                    eprintln!("offline bench context failed: {e2:#}");
                    std::process::exit(1);
                }
            }
        }
    };
    if failures > 0 {
        eprintln!("[{failures} experiment(s) FAILED]");
        std::process::exit(1);
    }
}
