//! `cargo bench` entry point: regenerates every paper table and figure.
//!
//! Custom harness (criterion is unavailable offline); experiment ids and
//! their paper mapping live in `srr::exp::registry` / DESIGN.md §5.
//!
//!   cargo bench                   # full suite (records EXPERIMENTS.md)
//!   cargo bench -- --exp table1   # one experiment
//!   cargo bench -- --quick        # smoke sizes

use srr::exp::{registry, run, ExpCtx};

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let quick = raw.iter().any(|a| a == "--quick");
    let exps: Vec<String> = {
        let mut out = vec![];
        let mut it = raw.iter();
        while let Some(a) = it.next() {
            if a == "--exp" {
                if let Some(v) = it.next() {
                    out.push(v.clone());
                }
            }
        }
        out
    };
    // `cargo bench` passes --bench and test-harness flags; ignore unknowns.
    let ids: Vec<&str> = if exps.is_empty() {
        registry().iter().map(|(id, _, _)| *id).collect()
    } else {
        exps.iter().map(|s| s.as_str()).collect()
    };

    let mut ctx = match ExpCtx::new(quick) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("bench setup failed: {e:#} (run `make artifacts` first)");
            std::process::exit(1);
        }
    };

    let suite_start = std::time::Instant::now();
    for id in ids {
        let t0 = std::time::Instant::now();
        match run(id, &mut ctx) {
            Ok(tables) => {
                for t in tables {
                    t.print();
                }
                println!("[{id} done in {:.1}s]\n", t0.elapsed().as_secs_f64());
            }
            Err(e) => {
                eprintln!("[{id} FAILED: {e:#}]");
            }
        }
    }
    println!("[suite done in {:.1}s]", suite_start.elapsed().as_secs_f64());
}
