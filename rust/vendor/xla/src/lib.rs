//! Offline stub of the `xla-rs` PJRT bindings.
//!
//! Mirrors exactly the API surface `srr::runtime::engine` consumes so the
//! optional `pjrt` feature resolves (and type-checks) without an XLA
//! toolchain. Every entry point that would touch PJRT returns an error at
//! runtime — `PjRtClient::cpu()` fails first, so the rest is unreachable
//! in practice. A deployment with a real XLA install swaps this path
//! dependency for the actual xla-rs crate; no `srr` code changes.

use std::fmt;

/// Error type standing in for xla-rs's `Error`.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>() -> Result<T> {
    Err(XlaError(
        "built against the vendored stub crate; link the real xla-rs to enable PJRT".into(),
    ))
}

/// Element types the engine marshals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Marker for host element types `Literal` can carry.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// Host literal stand-in (never holds device data in the stub).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        unavailable()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable()
    }
}

#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_surfaces_errors_not_panics() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_ok());
        assert!(lit.to_vec::<f32>().is_err());
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("stub"));
    }
}
