//! Tiny argument parser (clap stand-in): `prog <subcommand> --key value --flag`.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        if let Some(first) = iter.peek() {
            if !first.starts_with('-') {
                out.subcommand = iter.next();
            }
        }
        while let Some(a) = iter.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.options.insert(key.to_string(), iter.next().unwrap());
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("ptq --model small --rank 64 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("ptq"));
        assert_eq!(a.get("model"), Some("small"));
        assert_eq!(a.get_usize("rank", 0), 64);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn equals_syntax_and_defaults() {
        let a = parse("run --lr=0.001");
        assert_eq!(a.get_f64("lr", 0.0), 0.001);
        assert_eq!(a.get_or("missing", "x"), "x");
        assert_eq!(a.get_usize("absent", 7), 7);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("x --a --b value --c");
        assert!(a.has_flag("a"));
        assert_eq!(a.get("b"), Some("value"));
        assert!(a.has_flag("c"));
    }

    #[test]
    fn positional_args() {
        let a = parse("bench table1 fig5");
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.positional, vec!["table1", "fig5"]);
    }

    #[test]
    fn negative_number_values() {
        let a = parse("t --x -3");
        // "-3" does not start with "--" so it is consumed as the value
        assert_eq!(a.get_f64("x", 0.0), -3.0);
    }
}
