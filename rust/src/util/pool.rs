//! Scoped data-parallel helpers (in lieu of rayon).
//!
//! `par_chunks_mut` splits a mutable buffer into contiguous row-panels and
//! runs the closure on each panel from a scoped thread. Small inputs run
//! inline to avoid spawn overhead — the threshold is tuned in the §Perf
//! pass (EXPERIMENTS.md).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Number of worker threads (cached).
pub fn n_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("SRR_THREADS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
            })
    })
}

/// Minimum elements per panel before threading is worth it.
const PAR_MIN_ELEMS: usize = 16 * 1024;

/// Split `buf` (logically rows of width `row_len`) into panels and call
/// `f(first_row_index, panel)` for each, in parallel.
pub fn par_chunks_mut<F>(buf: &mut [f32], row_len: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert!(row_len > 0 && buf.len() % row_len == 0);
    let rows = buf.len() / row_len;
    let nt = n_threads();
    if buf.len() < PAR_MIN_ELEMS || nt <= 1 || rows == 1 {
        f(0, buf);
        return;
    }
    let panels = nt.min(rows);
    let per = rows.div_ceil(panels);
    std::thread::scope(|s| {
        let mut rest = buf;
        let mut start_row = 0;
        for _ in 0..panels {
            let take = per.min(rest.len() / row_len);
            if take == 0 {
                break;
            }
            let (head, tail) = rest.split_at_mut(take * row_len);
            rest = tail;
            let fr = &f;
            let sr = start_row;
            s.spawn(move || fr(sr, head));
            start_row += take;
        }
    });
}

/// Parallel for over `0..n`, invoking `f(i)` with work-stealing via an
/// atomic counter. Used where iterations are coarse (per-layer jobs).
pub fn par_for<F>(n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let nt = n_threads().min(n.max(1));
    if nt <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..nt {
            let next = &next;
            let f = &f;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Parallel map over `0..n` collecting results in order.
pub fn par_map<T: Send, F>(n: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Sync,
{
    let nt = n_threads().min(n.max(1));
    if nt <= 1 || n <= 1 {
        return (0..n).map(|i| f(i)).collect();
    }
    let next = AtomicUsize::new(0);
    let results: Vec<std::sync::Mutex<Option<T>>> =
        (0..n).map(|_| std::sync::Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..nt {
            let next = &next;
            let f = &f;
            let results = &results;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                *results[i].lock().unwrap() = Some(f(i));
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("par_map slot unfilled"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_chunks_covers_every_row_once() {
        let rows = 103;
        let width = 257;
        let mut buf = vec![0.0f32; rows * width];
        par_chunks_mut(&mut buf, width, |start, panel| {
            for (di, row) in panel.chunks_mut(width).enumerate() {
                for v in row.iter_mut() {
                    *v += (start + di) as f32 + 1.0;
                }
            }
        });
        for i in 0..rows {
            assert!(buf[i * width..(i + 1) * width].iter().all(|&v| v == (i + 1) as f32));
        }
    }

    #[test]
    fn par_for_executes_each_index_once() {
        let n = 1000;
        let sum = AtomicU64::new(0);
        par_for(n, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn par_map_preserves_order() {
        let out = par_map(500, |i| i * i);
        assert!(out.iter().enumerate().all(|(i, &v)| v == i * i));
    }

    #[test]
    fn small_input_runs_inline() {
        let mut buf = vec![0.0f32; 8];
        par_chunks_mut(&mut buf, 4, |start, panel| {
            assert_eq!(start, 0);
            assert_eq!(panel.len(), 8);
        });
    }
}
