//! Substrate utilities built from scratch (offline environment: no
//! crates.io beyond `xla`/`anyhow`). Each replaces a crate the wider
//! ecosystem would normally pull in:
//!
//! * [`prng`]   — xoshiro256++ PRNG (replaces `rand`)
//! * [`json`]   — JSON parser/writer (replaces `serde_json`)
//! * [`cli`]    — argument parser (replaces `clap`)
//! * [`stats`]  — descriptive stats + correlation metrics
//! * [`bench`]  — timing harness (replaces `criterion`)
//! * [`pool`]   — scoped data-parallel helpers (replaces `rayon`)
//! * [`prop`]   — mini property-testing driver (replaces `proptest`)

pub mod prng;
pub mod json;
pub mod cli;
pub mod stats;
pub mod bench;
pub mod pool;
pub mod prop;

pub use prng::Rng;
