//! Descriptive statistics + the correlation metrics the GLUE-sim harness
//! reports (accuracy, Matthews correlation, Pearson/Spearman) and the
//! coefficient-of-variation / MRE used to validate the paper's
//! Assumptions 4.1–4.2 (Tables 20–21).

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Coefficient of variation σ/μ (Assumption 4.1 validation).
pub fn coeff_of_variation(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m.abs() < 1e-300 {
        return f64::INFINITY;
    }
    std_dev(xs) / m
}

/// Mean relative error E[|a−b| / |a|] (Assumption 4.2 validation).
pub fn mean_relative_error(actual: &[f64], proxy: &[f64]) -> f64 {
    assert_eq!(actual.len(), proxy.len());
    let terms: Vec<f64> = actual
        .iter()
        .zip(proxy)
        .filter(|(a, _)| a.abs() > 1e-12)
        .map(|(a, p)| (a - p).abs() / a.abs())
        .collect();
    mean(&terms)
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Linear-interpolated percentile, q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q / 100.0 * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Five-number summary (min, q1, median, q3, max) — Fig. 5 box stats.
pub fn box_stats(xs: &[f64]) -> (f64, f64, f64, f64, f64) {
    (
        percentile(xs, 0.0),
        percentile(xs, 25.0),
        percentile(xs, 50.0),
        percentile(xs, 75.0),
        percentile(xs, 100.0),
    )
}

pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let (mx, my) = (mean(x), mean(y));
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (a, b) in x.iter().zip(y) {
        num += (a - mx) * (b - my);
        dx += (a - mx) * (a - mx);
        dy += (b - my) * (b - my);
    }
    if dx <= 0.0 || dy <= 0.0 {
        return 0.0;
    }
    num / (dx.sqrt() * dy.sqrt())
}

/// Average ranks with ties.
fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut r = vec![0.0; xs.len()];
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for &k in &idx[i..=j] {
            r[k] = avg;
        }
        i = j + 1;
    }
    r
}

pub fn spearman(x: &[f64], y: &[f64]) -> f64 {
    pearson(&ranks(x), &ranks(y))
}

/// Matthews correlation coefficient for binary labels (CoLA's metric).
pub fn matthews(pred: &[usize], truth: &[usize]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let (mut tp, mut tn, mut fp, mut fnn) = (0f64, 0f64, 0f64, 0f64);
    for (&p, &t) in pred.iter().zip(truth) {
        match (p, t) {
            (1, 1) => tp += 1.0,
            (0, 0) => tn += 1.0,
            (1, 0) => fp += 1.0,
            (0, 1) => fnn += 1.0,
            _ => panic!("matthews expects binary labels"),
        }
    }
    let denom = ((tp + fp) * (tp + fnn) * (tn + fp) * (tn + fnn)).sqrt();
    if denom == 0.0 {
        0.0
    } else {
        (tp * tn - fp * fnn) / denom
    }
}

pub fn accuracy(pred: &[usize], truth: &[usize]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter().zip(truth).filter(|(p, t)| p == t).count() as f64 / pred.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - 1.2909944).abs() < 1e-6);
        assert!((coeff_of_variation(&xs) - 1.2909944 / 2.5).abs() < 1e-6);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert_eq!(median(&xs), 25.0);
        let (mn, q1, md, q3, mx) = box_stats(&xs);
        assert_eq!((mn, mx), (10.0, 40.0));
        assert!(q1 < md && md < q3);
    }

    #[test]
    fn pearson_perfect_and_anticorrelated() {
        let x = [1.0, 2.0, 3.0];
        assert!((pearson(&x, &[2.0, 4.0, 6.0]) - 1.0).abs() < 1e-12);
        assert!((pearson(&x, &[3.0, 2.0, 1.0]) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_is_rank_based() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [1.0, 10.0, 100.0, 1000.0]; // monotone, nonlinear
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn matthews_known_cases() {
        assert!((matthews(&[1, 1, 0, 0], &[1, 1, 0, 0]) - 1.0).abs() < 1e-12);
        assert!((matthews(&[0, 0, 1, 1], &[1, 1, 0, 0]) + 1.0).abs() < 1e-12);
        assert_eq!(matthews(&[1, 1, 1, 1], &[1, 1, 0, 0]), 0.0);
    }

    #[test]
    fn mre_matches_manual() {
        let a = [1.0, 2.0];
        let p = [1.1, 1.8];
        let want = ((0.1f64 / 1.0) + (0.2 / 2.0)) / 2.0;
        assert!((mean_relative_error(&a, &p) - want).abs() < 1e-12);
    }
}
