//! Mini property-testing driver (proptest stand-in).
//!
//! `check(seed, cases, |g| ...)` runs a closure against `cases` freshly
//! seeded generators; failures report the per-case seed so they replay
//! deterministically with `replay(seed_reported, |g| ...)`.

use super::prng::Rng;

/// Generator handed to property closures: a seeded [`Rng`] plus sizing helpers.
pub struct Gen {
    pub rng: Rng,
    pub case_seed: u64,
}

impl Gen {
    /// Dimension in [1, max].
    pub fn dim(&mut self, max: usize) -> usize {
        1 + self.rng.below(max)
    }

    /// Pick one of the provided choices.
    pub fn choice<T: Copy>(&mut self, xs: &[T]) -> T {
        xs[self.rng.below(xs.len())]
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_in(lo as f64, hi as f64) as f32
    }
}

/// Run `cases` random cases. Panics with the failing case seed on error.
pub fn check(seed: u64, cases: usize, prop: impl Fn(&mut Gen)) {
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let case_seed = root.next_u64();
        let mut g = Gen { rng: Rng::new(case_seed), case_seed };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed on case {case}/{cases} (replay seed: {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Re-run a single failing case by its reported seed.
pub fn replay(case_seed: u64, prop: impl Fn(&mut Gen)) {
    let mut g = Gen { rng: Rng::new(case_seed), case_seed };
    prop(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let count = std::cell::Cell::new(0usize);
        check(1, 25, |g| {
            let n = g.dim(10);
            assert!((1..=10).contains(&n));
            count.set(count.get() + 1);
        });
        assert_eq!(count.get(), 25);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check(2, 50, |g| {
            let n = g.dim(100);
            assert!(n < 95, "violation n={n}");
        });
    }

    #[test]
    fn replay_reproduces_case() {
        // find a failing seed by scanning, then replay it
        let mut root = Rng::new(3);
        let mut failing = None;
        for _ in 0..200 {
            let s = root.next_u64();
            let mut g = Gen { rng: Rng::new(s), case_seed: s };
            if g.dim(100) >= 95 {
                failing = Some(s);
                break;
            }
        }
        let s = failing.expect("should find a case");
        let mut g1 = Gen { rng: Rng::new(s), case_seed: s };
        let mut g2 = Gen { rng: Rng::new(s), case_seed: s };
        assert_eq!(g1.dim(100), g2.dim(100));
    }
}
