//! Timing harness (criterion stand-in): warmup + timed iterations with
//! mean / p50 / p95 reporting, and a table printer that renders the
//! paper-style rows the experiment benches emit.

use std::time::Instant;

use super::json::Json;
use super::stats;

#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl Timing {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    /// JSON record for BENCH_*.json result files.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("iters", Json::num(self.iters as f64)),
            ("mean_ms", Json::num(self.mean_ms())),
            ("p50_ms", Json::num(self.p50_ns / 1e6)),
            ("p95_ms", Json::num(self.p95_ns / 1e6)),
        ])
    }
}

/// Persist a benchmark record (e.g. `BENCH_sweep.json`). Relative paths
/// resolve against the bench binary's working directory — under
/// `cargo bench` that is the *package* root (`rust/`), not the workspace
/// root. Failures are surfaced, not swallowed.
pub fn write_json(path: &str, json: &Json) -> std::io::Result<()> {
    std::fs::write(path, json.to_string())
}

/// Time `f` with `warmup` throwaway runs and `iters` measured runs.
pub fn time_fn<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Timing {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    Timing {
        name: name.to_string(),
        iters: samples.len(),
        mean_ns: stats::mean(&samples),
        p50_ns: stats::percentile(&samples, 50.0),
        p95_ns: stats::percentile(&samples, 95.0),
    }
}

/// Measured streaming-read ceiling of this machine in GB/s: the best of
/// `iters` sequential sum passes over a buffer far past last-level
/// cache. `exp::perf::serve_bench` records it as the roofline
/// denominator next to the decode kernels' achieved GB/s — the decode
/// path is memory-bound by design, so "achieved / ceiling" is the
/// fraction of the hardware the kernels actually reach.
pub fn stream_read_gbps(iters: usize) -> f64 {
    const WORDS: usize = 8 << 20; // 64 MiB of u64
    let buf: Vec<u64> = (0..WORDS as u64).collect();
    let mut best_ns = f64::INFINITY;
    let mut sink = 0u64;
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        let mut acc = 0u64;
        for &w in &buf {
            acc = acc.wrapping_add(w);
        }
        best_ns = best_ns.min(t0.elapsed().as_nanos() as f64);
        sink ^= acc;
    }
    std::hint::black_box(sink);
    // bytes per nanosecond == GB/s (decimal)
    (WORDS * 8) as f64 / best_ns
}

/// Simple fixed-width table printer for bench output.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::new();
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!("{:<width$}  ", c, width = w));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with fixed decimals (helper for bench rows).
pub fn f(x: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, x)
}

/// Format mean±std (paper-style cells).
pub fn pm(mean: f64, std: f64, decimals: usize) -> String {
    format!("{:.*}±{:.*}", decimals, mean, decimals, std)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_runs_and_reports() {
        let t = time_fn("noop", 2, 10, || 1 + 1);
        assert_eq!(t.iters, 10);
        assert!(t.mean_ns >= 0.0);
        assert!(t.p95_ns >= t.p50_ns * 0.5);
    }

    #[test]
    fn table_renders_all_rows() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("333"));
        assert_eq!(s.lines().filter(|l| !l.is_empty()).count(), 5);
    }

    #[test]
    fn stream_read_ceiling_is_positive() {
        assert!(stream_read_gbps(1) > 0.0);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(f(3.14159, 2), "3.14");
        assert_eq!(pm(10.25, 0.05, 2), "10.25±0.05");
    }

    #[test]
    fn timing_serializes_and_persists() {
        let t = time_fn("noop", 0, 3, || 1 + 1);
        let j = t.to_json();
        assert_eq!(j.get("name").and_then(|x| x.as_str()), Some("noop"));
        assert_eq!(j.get("iters").and_then(|x| x.as_usize()), Some(3));
        let path = std::env::temp_dir().join("srr_bench_test.json");
        let path = path.to_str().unwrap();
        write_json(path, &j).unwrap();
        let back = crate::util::json::Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        assert_eq!(back.get("name").and_then(|x| x.as_str()), Some("noop"));
    }
}
