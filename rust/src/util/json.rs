//! Minimal JSON: recursive-descent parser + writer (serde_json stand-in).
//!
//! Parses the artifact manifest and run configs; writes experiment result
//! files. Supports the full JSON grammar we emit (objects, arrays,
//! strings with escapes, numbers, bool, null); numbers surface as f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // -- accessors ---------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- builders ----------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi\nthere""#).unwrap(), Json::Str("hi\nthere".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a":[1,2,{"b":"c"}],"d":{"e":null}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1], Json::Num(2.0));
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d").unwrap().get("e"), Some(&Json::Null));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"srr","nums":[1,2.5,-3],"nested":{"ok":true,"none":null},"s":"a\"b\\c"}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn parses_manifest_shape() {
        let src = r#"{"artifacts":[{"name":"lm","args":[{"name":"w","shape":[2,3],"dtype":"f32"}]}]}"#;
        let v = Json::parse(src).unwrap();
        let a = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        let arg = &a.get("args").unwrap().as_arr().unwrap()[0];
        let shape: Vec<usize> = arg
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![2, 3]);
    }
}
