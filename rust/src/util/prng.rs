//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64.
//!
//! Every randomized component in the crate (synthetic weights, calibration
//! streams, the SRR random probe, data generators, property tests) draws
//! from this generator so runs are exactly reproducible from a `u64` seed
//! — the paper reports mean±std over three seeds, and so do we.

/// xoshiro256++ with Box–Muller normal sampling.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (e.g. per layer / per worker).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift bounded sampling (Lemire); bias negligible for our n
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal (Box–Muller, cached spare).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    pub fn fill_normal(&mut self, buf: &mut [f32], std: f32) {
        for v in buf.iter_mut() {
            *v = self.normal_f32() * std;
        }
    }

    pub fn fill_uniform(&mut self, buf: &mut [f32], lo: f32, hi: f32) {
        for v in buf.iter_mut() {
            *v = self.uniform_in(lo as f64, hi as f64) as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample from unnormalized weights (used by the Zipf corpus generator).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(5);
        let mut seen = [false; 7];
        for _ in 0..500 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy_entries() {
        let mut r = Rng::new(13);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..2_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }
}
