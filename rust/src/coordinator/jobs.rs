//! Bounded MPMC job queue with backpressure, plus an in-memory byte
//! pipe with the same close semantics as an OS pipe.
//!
//! Built on Mutex + Condvar (no crossbeam available offline). Producers
//! block when the queue is at capacity — the backpressure that keeps the
//! streaming calibration path from ballooning memory — and consumers
//! block until an item or shutdown arrives. [`byte_pipe`] layers a
//! `Read`/`Write` byte stream over the same primitives: dropping the
//! writer is EOF for the reader, dropping the reader is `BrokenPipe`
//! for the writer — the duplex the shard plane's loopback transports
//! ([`FaultTransport`](super::transport::FaultTransport)) are built on.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Outcome of a non-blocking or bounded-wait pop ([`BoundedQueue::try_pop`]
/// / [`BoundedQueue::pop_timeout`]). Distinguishes "nothing *yet*" from
/// "nothing *ever again*" — the shard host's event loop waits with a
/// timeout so it can probe worker liveness instead of blocking forever
/// on a peer that died without closing the pipe.
#[derive(Debug, PartialEq, Eq)]
pub enum PopResult<T> {
    /// an item was dequeued
    Item(T),
    /// the queue was empty for the whole wait (still open — retry later)
    Empty,
    /// closed and fully drained (no item will ever arrive)
    Closed,
}

/// A bounded multi-producer multi-consumer queue; `push` blocks at
/// capacity (backpressure), `pop` blocks until an item or close.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> BoundedQueue<T> {
    /// An empty open queue holding at most `capacity` items.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        BoundedQueue {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    /// Blocking push; returns false if the queue was closed. The result
    /// must be handled: a `false` on a shutdown race means the item was
    /// *not* enqueued, and a caller that drops it silently loses a
    /// job/result (the shard plane either propagates the failure or
    /// counts the drop — see `ShardStats`).
    #[must_use = "returns false when the queue is closed — the item was dropped"]
    pub fn push(&self, item: T) -> bool {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return false;
            }
            if g.items.len() < self.capacity {
                g.items.push_back(item);
                self.not_empty.notify_one();
                return true;
            }
            g = self.not_full.wait(g).unwrap();
        }
    }

    /// Blocking pop; None once closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Non-blocking pop: an item if one is queued right now,
    /// [`PopResult::Empty`] if not, [`PopResult::Closed`] once closed
    /// and drained.
    pub fn try_pop(&self) -> PopResult<T> {
        let mut g = self.inner.lock().unwrap();
        if let Some(item) = g.items.pop_front() {
            self.not_full.notify_one();
            return PopResult::Item(item);
        }
        if g.closed {
            PopResult::Closed
        } else {
            PopResult::Empty
        }
    }

    /// Pop, waiting at most `timeout` for an item. Returns
    /// [`PopResult::Empty`] when the deadline expires on a still-open
    /// queue — the caller can check liveness out-of-band and retry —
    /// and [`PopResult::Closed`] once closed and drained.
    pub fn pop_timeout(&self, timeout: Duration) -> PopResult<T> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                self.not_full.notify_one();
                return PopResult::Item(item);
            }
            if g.closed {
                return PopResult::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return PopResult::Empty;
            }
            let (guard, _) = self.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }

    /// Close the queue: pending pushes fail, pops drain then end.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    /// Whether the queue is currently empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---------------------------------------------------------------------------
// in-memory byte pipe
// ---------------------------------------------------------------------------

struct PipeState {
    buf: VecDeque<u8>,
    write_closed: bool,
    read_closed: bool,
}

struct PipeShared {
    state: Mutex<PipeState>,
    readable: Condvar,
    writable: Condvar,
    capacity: usize,
}

/// Write half of an in-memory [`byte_pipe`]. Dropping it (or all clones
/// of it — there are none; it is not `Clone`) signals EOF to the reader.
pub struct PipeWriter(Arc<PipeShared>);

/// Read half of an in-memory [`byte_pipe`]. Dropping it makes further
/// writes fail with `BrokenPipe`, mirroring an OS pipe whose consumer
/// died.
pub struct PipeReader(Arc<PipeShared>);

/// An in-memory unidirectional byte stream with OS-pipe close
/// semantics and `capacity` bytes of buffering (writers block at
/// capacity — the same backpressure a full kernel pipe applies).
pub fn byte_pipe(capacity: usize) -> (PipeWriter, PipeReader) {
    assert!(capacity > 0);
    let shared = Arc::new(PipeShared {
        state: Mutex::new(PipeState {
            buf: VecDeque::new(),
            write_closed: false,
            read_closed: false,
        }),
        readable: Condvar::new(),
        writable: Condvar::new(),
        capacity,
    });
    (PipeWriter(shared.clone()), PipeReader(shared))
}

impl std::io::Write for PipeWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if buf.is_empty() {
            return Ok(0);
        }
        let mut g = self.0.state.lock().unwrap();
        loop {
            if g.read_closed {
                return Err(std::io::ErrorKind::BrokenPipe.into());
            }
            let space = self.0.capacity - g.buf.len().min(self.0.capacity);
            if space > 0 {
                let n = space.min(buf.len());
                g.buf.extend(&buf[..n]);
                self.0.readable.notify_one();
                return Ok(n);
            }
            g = self.0.writable.wait(g).unwrap();
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

impl Drop for PipeWriter {
    fn drop(&mut self) {
        let mut g = self.0.state.lock().unwrap();
        g.write_closed = true;
        self.0.readable.notify_all();
    }
}

impl std::io::Read for PipeReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if buf.is_empty() {
            return Ok(0);
        }
        let mut g = self.0.state.lock().unwrap();
        loop {
            if !g.buf.is_empty() {
                let n = g.buf.len().min(buf.len());
                // slice copies instead of per-byte pops: blob traffic in
                // the fault-injection suite moves megabytes through here
                let (a, b) = g.buf.as_slices();
                let na = a.len().min(n);
                buf[..na].copy_from_slice(&a[..na]);
                if na < n {
                    buf[na..n].copy_from_slice(&b[..n - na]);
                }
                g.buf.drain(..n);
                self.0.writable.notify_one();
                return Ok(n);
            }
            if g.write_closed {
                return Ok(0); // EOF
            }
            g = self.0.readable.wait(g).unwrap();
        }
    }
}

impl Drop for PipeReader {
    fn drop(&mut self) {
        let mut g = self.0.state.lock().unwrap();
        g.read_closed = true;
        self.0.writable.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn fifo_single_thread() {
        let q = BoundedQueue::new(4);
        assert!(q.push(1));
        assert!(q.push(2));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        q.close();
        assert_eq!(q.pop(), None);
        assert!(!q.push(3), "push after close must fail");
    }

    #[test]
    fn every_item_consumed_exactly_once() {
        let q = Arc::new(BoundedQueue::new(8));
        let n_items = 1000usize;
        let consumed = Arc::new(AtomicUsize::new(0));
        let sum = Arc::new(AtomicUsize::new(0));
        let mut handles = vec![];
        for _ in 0..4 {
            let q = q.clone();
            let consumed = consumed.clone();
            let sum = sum.clone();
            handles.push(std::thread::spawn(move || {
                while let Some(x) = q.pop() {
                    consumed.fetch_add(1, Ordering::Relaxed);
                    sum.fetch_add(x, Ordering::Relaxed);
                }
            }));
        }
        for i in 0..n_items {
            assert!(q.push(i));
        }
        q.close();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(consumed.load(Ordering::Relaxed), n_items);
        assert_eq!(sum.load(Ordering::Relaxed), n_items * (n_items - 1) / 2);
    }

    #[test]
    fn backpressure_blocks_producer_until_pop() {
        let q = Arc::new(BoundedQueue::new(2));
        assert!(q.push(0));
        assert!(q.push(1));
        assert_eq!(q.len(), 2);
        let q2 = q.clone();
        let t = std::thread::spawn(move || {
            // this push must block until the consumer below pops
            let t0 = std::time::Instant::now();
            assert!(q2.push(2));
            t0.elapsed()
        });
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(q.pop(), Some(0));
        let blocked_for = t.join().unwrap();
        assert!(
            blocked_for >= std::time::Duration::from_millis(20),
            "producer should have been blocked, was {blocked_for:?}"
        );
        // queue never exceeded capacity
        assert!(q.len() <= 2);
    }

    #[test]
    fn close_wakes_blocked_consumers() {
        let q = Arc::new(BoundedQueue::<usize>::new(2));
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.pop());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert_eq!(t.join().unwrap(), None);
    }

    /// Satellite: closing while a producer is blocked at capacity must
    /// wake it with `false` — the shard host relies on this to unwedge a
    /// feeder pointed at a dead worker.
    #[test]
    fn close_wakes_blocked_producer_with_failure() {
        let q = Arc::new(BoundedQueue::new(1));
        assert!(q.push(0));
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.push(1));
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(q.len(), 1, "producer still blocked at capacity");
        q.close();
        assert!(!t.join().unwrap(), "blocked push must fail once closed");
        // the queued item still drains, then the close is visible
        assert_eq!(q.pop(), Some(0));
        assert_eq!(q.pop(), None);
    }

    /// Satellite: `pop_timeout` expires with `Empty` on an open queue,
    /// returns items when they exist, and reports `Closed` after drain.
    #[test]
    fn pop_timeout_expiry_and_close() {
        let q: BoundedQueue<usize> = BoundedQueue::new(2);
        let t0 = std::time::Instant::now();
        assert_eq!(q.pop_timeout(std::time::Duration::from_millis(40)), PopResult::Empty);
        assert!(
            t0.elapsed() >= std::time::Duration::from_millis(35),
            "expiry returned early after {:?}",
            t0.elapsed()
        );
        assert!(q.push(7));
        assert_eq!(q.pop_timeout(std::time::Duration::from_millis(40)), PopResult::Item(7));
        q.close();
        assert_eq!(q.pop_timeout(std::time::Duration::from_millis(40)), PopResult::Closed);
    }

    #[test]
    fn pop_timeout_wakes_on_push_before_deadline() {
        let q = Arc::new(BoundedQueue::new(2));
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.pop_timeout(std::time::Duration::from_secs(5)));
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(q.push(3usize));
        assert_eq!(t.join().unwrap(), PopResult::Item(3));
    }

    #[test]
    fn try_pop_reports_state_without_blocking() {
        let q: BoundedQueue<usize> = BoundedQueue::new(2);
        assert_eq!(q.try_pop(), PopResult::Empty);
        assert!(q.push(1));
        assert_eq!(q.try_pop(), PopResult::Item(1));
        q.close();
        assert_eq!(q.try_pop(), PopResult::Closed);
    }

    #[test]
    fn byte_pipe_round_trips_and_signals_eof() {
        use std::io::{Read, Write};
        let (mut w, mut r) = byte_pipe(8);
        // writes larger than capacity complete across reads (write_all
        // loops on the partial writes the bounded buffer hands back)
        let payload: Vec<u8> = (0..64u8).collect();
        let t = std::thread::spawn(move || {
            w.write_all(&payload).unwrap();
            // dropping w here is the EOF
        });
        let mut got = Vec::new();
        r.read_to_end(&mut got).unwrap();
        t.join().unwrap();
        assert_eq!(got, (0..64u8).collect::<Vec<_>>());
        // reading at EOF stays EOF
        let mut buf = [0u8; 4];
        assert_eq!(r.read(&mut buf).unwrap(), 0);
    }

    #[test]
    fn byte_pipe_write_fails_broken_pipe_after_reader_drop() {
        use std::io::Write;
        let (mut w, r) = byte_pipe(4);
        drop(r);
        let err = w.write(&[1, 2, 3]).expect_err("reader is gone");
        assert_eq!(err.kind(), std::io::ErrorKind::BrokenPipe);
    }

    #[test]
    fn byte_pipe_reader_drop_wakes_blocked_writer() {
        use std::io::Write;
        let (mut w, r) = byte_pipe(2);
        assert_eq!(w.write(&[0, 1]).unwrap(), 2); // buffer now full
        let t = std::thread::spawn(move || w.write(&[2]));
        std::thread::sleep(std::time::Duration::from_millis(30));
        drop(r); // must wake the blocked writer with BrokenPipe
        let res = t.join().unwrap();
        assert_eq!(res.expect_err("no reader").kind(), std::io::ErrorKind::BrokenPipe);
    }

    #[test]
    fn prop_queue_conserves_items() {
        crate::util::prop::check(0xD4, 10, |g| {
            let cap = g.dim(6);
            let n = g.dim(200);
            let q = Arc::new(BoundedQueue::new(cap));
            let total = Arc::new(AtomicUsize::new(0));
            let q2 = q.clone();
            let t2 = total.clone();
            let consumer = std::thread::spawn(move || {
                while let Some(x) = q2.pop() {
                    t2.fetch_add(x, Ordering::Relaxed);
                }
            });
            let mut want = 0usize;
            for i in 0..n {
                assert!(q.push(i));
                want += i;
            }
            q.close();
            consumer.join().unwrap();
            assert_eq!(total.load(Ordering::Relaxed), want);
        });
    }
}
