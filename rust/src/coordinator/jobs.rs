//! Bounded MPMC job queue with backpressure.
//!
//! Built on Mutex + Condvar (no crossbeam available offline). Producers
//! block when the queue is at capacity — the backpressure that keeps the
//! streaming calibration path from ballooning memory — and consumers
//! block until an item or shutdown arrives.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Outcome of a non-blocking or bounded-wait pop ([`BoundedQueue::try_pop`]
/// / [`BoundedQueue::pop_timeout`]). Distinguishes "nothing *yet*" from
/// "nothing *ever again*" — the shard host's event loop waits with a
/// timeout so it can probe worker liveness instead of blocking forever
/// on a peer that died without closing the pipe.
#[derive(Debug, PartialEq, Eq)]
pub enum PopResult<T> {
    /// an item was dequeued
    Item(T),
    /// the queue was empty for the whole wait (still open — retry later)
    Empty,
    /// closed and fully drained (no item will ever arrive)
    Closed,
}

/// A bounded multi-producer multi-consumer queue; `push` blocks at
/// capacity (backpressure), `pop` blocks until an item or close.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> BoundedQueue<T> {
    /// An empty open queue holding at most `capacity` items.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        BoundedQueue {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    /// Blocking push; returns false if the queue was closed.
    pub fn push(&self, item: T) -> bool {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return false;
            }
            if g.items.len() < self.capacity {
                g.items.push_back(item);
                self.not_empty.notify_one();
                return true;
            }
            g = self.not_full.wait(g).unwrap();
        }
    }

    /// Blocking pop; None once closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Non-blocking pop: an item if one is queued right now,
    /// [`PopResult::Empty`] if not, [`PopResult::Closed`] once closed
    /// and drained.
    pub fn try_pop(&self) -> PopResult<T> {
        let mut g = self.inner.lock().unwrap();
        if let Some(item) = g.items.pop_front() {
            self.not_full.notify_one();
            return PopResult::Item(item);
        }
        if g.closed {
            PopResult::Closed
        } else {
            PopResult::Empty
        }
    }

    /// Pop, waiting at most `timeout` for an item. Returns
    /// [`PopResult::Empty`] when the deadline expires on a still-open
    /// queue — the caller can check liveness out-of-band and retry —
    /// and [`PopResult::Closed`] once closed and drained.
    pub fn pop_timeout(&self, timeout: Duration) -> PopResult<T> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                self.not_full.notify_one();
                return PopResult::Item(item);
            }
            if g.closed {
                return PopResult::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return PopResult::Empty;
            }
            let (guard, _) = self.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }

    /// Close the queue: pending pushes fail, pops drain then end.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    /// Whether the queue is currently empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn fifo_single_thread() {
        let q = BoundedQueue::new(4);
        assert!(q.push(1));
        assert!(q.push(2));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        q.close();
        assert_eq!(q.pop(), None);
        assert!(!q.push(3), "push after close must fail");
    }

    #[test]
    fn every_item_consumed_exactly_once() {
        let q = Arc::new(BoundedQueue::new(8));
        let n_items = 1000usize;
        let consumed = Arc::new(AtomicUsize::new(0));
        let sum = Arc::new(AtomicUsize::new(0));
        let mut handles = vec![];
        for _ in 0..4 {
            let q = q.clone();
            let consumed = consumed.clone();
            let sum = sum.clone();
            handles.push(std::thread::spawn(move || {
                while let Some(x) = q.pop() {
                    consumed.fetch_add(1, Ordering::Relaxed);
                    sum.fetch_add(x, Ordering::Relaxed);
                }
            }));
        }
        for i in 0..n_items {
            assert!(q.push(i));
        }
        q.close();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(consumed.load(Ordering::Relaxed), n_items);
        assert_eq!(sum.load(Ordering::Relaxed), n_items * (n_items - 1) / 2);
    }

    #[test]
    fn backpressure_blocks_producer_until_pop() {
        let q = Arc::new(BoundedQueue::new(2));
        assert!(q.push(0));
        assert!(q.push(1));
        assert_eq!(q.len(), 2);
        let q2 = q.clone();
        let t = std::thread::spawn(move || {
            // this push must block until the consumer below pops
            let t0 = std::time::Instant::now();
            assert!(q2.push(2));
            t0.elapsed()
        });
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(q.pop(), Some(0));
        let blocked_for = t.join().unwrap();
        assert!(
            blocked_for >= std::time::Duration::from_millis(20),
            "producer should have been blocked, was {blocked_for:?}"
        );
        // queue never exceeded capacity
        assert!(q.len() <= 2);
    }

    #[test]
    fn close_wakes_blocked_consumers() {
        let q = Arc::new(BoundedQueue::<usize>::new(2));
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.pop());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert_eq!(t.join().unwrap(), None);
    }

    /// Satellite: closing while a producer is blocked at capacity must
    /// wake it with `false` — the shard host relies on this to unwedge a
    /// feeder pointed at a dead worker.
    #[test]
    fn close_wakes_blocked_producer_with_failure() {
        let q = Arc::new(BoundedQueue::new(1));
        assert!(q.push(0));
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.push(1));
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(q.len(), 1, "producer still blocked at capacity");
        q.close();
        assert!(!t.join().unwrap(), "blocked push must fail once closed");
        // the queued item still drains, then the close is visible
        assert_eq!(q.pop(), Some(0));
        assert_eq!(q.pop(), None);
    }

    /// Satellite: `pop_timeout` expires with `Empty` on an open queue,
    /// returns items when they exist, and reports `Closed` after drain.
    #[test]
    fn pop_timeout_expiry_and_close() {
        let q: BoundedQueue<usize> = BoundedQueue::new(2);
        let t0 = std::time::Instant::now();
        assert_eq!(q.pop_timeout(std::time::Duration::from_millis(40)), PopResult::Empty);
        assert!(
            t0.elapsed() >= std::time::Duration::from_millis(35),
            "expiry returned early after {:?}",
            t0.elapsed()
        );
        assert!(q.push(7));
        assert_eq!(q.pop_timeout(std::time::Duration::from_millis(40)), PopResult::Item(7));
        q.close();
        assert_eq!(q.pop_timeout(std::time::Duration::from_millis(40)), PopResult::Closed);
    }

    #[test]
    fn pop_timeout_wakes_on_push_before_deadline() {
        let q = Arc::new(BoundedQueue::new(2));
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.pop_timeout(std::time::Duration::from_secs(5)));
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(q.push(3usize));
        assert_eq!(t.join().unwrap(), PopResult::Item(3));
    }

    #[test]
    fn try_pop_reports_state_without_blocking() {
        let q: BoundedQueue<usize> = BoundedQueue::new(2);
        assert_eq!(q.try_pop(), PopResult::Empty);
        assert!(q.push(1));
        assert_eq!(q.try_pop(), PopResult::Item(1));
        q.close();
        assert_eq!(q.try_pop(), PopResult::Closed);
    }

    #[test]
    fn prop_queue_conserves_items() {
        crate::util::prop::check(0xD4, 10, |g| {
            let cap = g.dim(6);
            let n = g.dim(200);
            let q = Arc::new(BoundedQueue::new(cap));
            let total = Arc::new(AtomicUsize::new(0));
            let q2 = q.clone();
            let t2 = total.clone();
            let consumer = std::thread::spawn(move || {
                while let Some(x) = q2.pop() {
                    t2.fetch_add(x, Ordering::Relaxed);
                }
            });
            let mut want = 0usize;
            for i in 0..n {
                q.push(i);
                want += i;
            }
            q.close();
            consumer.join().unwrap();
            assert_eq!(total.load(Ordering::Relaxed), want);
        });
    }
}
