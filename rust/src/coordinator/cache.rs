//! The sweep's keyed per-layer cache: everything a grid of PTQ configs
//! shares for one linear layer, computed once.
//!
//! A `(method, quantizer, rank, scaling, seed)` grid over one model
//! repeats four expensive per-layer artifacts:
//!
//! * the activation **scaling** S per `ScalingKind` (O(d³) eigh for
//!   QERA-exact),
//! * the GPTQ **Hessian** H = XᵀX/n,
//! * the k=0 **dequantized weight** per (quantizer, seed) — shared by
//!   w-only and every plain-QER config,
//! * the **spectra** of (S·W, S·E) per (scaling, seed) at the grid's
//!   maximum rank — consumed by every SRR-family config, any budget
//!   r ≤ prep rank served by prefix truncation,
//!
//! plus, one level up, the plain-QER **residual SVD** per (quantizer,
//! scaling, seed), which serves every rank of that baseline. All five
//! live here as [`PreparedLayer`] / [`LayerCache`]; `coordinator::sweep`
//! populates them in deterministic parallel phases and fans per-config
//! reconstruction out over the worker pool.

use std::collections::HashMap;
use std::sync::Arc;

use crate::linalg::Svd;
use crate::qer::PreparedSpectra;
use crate::quant::{PackedMat, QuantCtx};
use crate::scaling::{Scaling, ScalingKind};
use crate::tensor::Mat;

/// Shared per-layer artifacts, keyed by what distinguishes them across a
/// sweep grid. Seeds in keys are *sweep-level* seeds; the stored values
/// were derived with the layer-salted seed the per-config path uses.
pub struct PreparedLayer {
    /// the linear's parameter name (e.g. `l0.wq`)
    pub name: String,
    /// the original weight (owned so jobs need no `Params` access)
    pub w: Mat,
    /// activation scalings S per kind the grid touches
    pub scalings: HashMap<ScalingKind, Arc<Scaling>>,
    /// GPTQ Hessian, present iff some config's quantizer needs it
    pub hessian: Option<Arc<Mat>>,
    /// k=0 dequantized weight per (quantizer label, sweep seed)
    pub qdeq0: HashMap<(String, u64), Arc<Mat>>,
    /// bit-packed encoding of `qdeq0`, present when the quantizer packs
    /// (the factored outcomes of w-only / plain-QER configs reuse it)
    pub qdeq0_packed: HashMap<(String, u64), Arc<PackedMat>>,
    /// prepared (S·W, S·E) spectra per (scaling kind, sweep seed)
    pub spectra: HashMap<(ScalingKind, u64), Arc<PreparedSpectra>>,
    /// wall-clock spent preparing this layer (amortized into reports)
    pub prep_secs: f64,
}

impl PreparedLayer {
    /// The cached scaling for `kind` (must have been in the grid).
    pub fn scaling(&self, kind: ScalingKind) -> &Scaling {
        self.scalings
            .get(&kind)
            .unwrap_or_else(|| panic!("{}: scaling {kind:?} not prepared", self.name))
            .as_ref()
    }

    /// A `QuantCtx` equivalent to `CalibrationSet::quant_ctx` for this
    /// layer, served from the cached Hessian.
    pub fn quant_ctx(&self, with_hessian: bool, seed: u64) -> QuantCtx {
        let hessian = if with_hessian {
            self.hessian.as_ref().map(|h| (**h).clone())
        } else {
            None
        };
        QuantCtx { hessian, seed }
    }

    /// The cached k=0 dequantized weight for a (quantizer, sweep seed).
    pub fn qdeq0(&self, quantizer_label: &str, seed: u64) -> Option<&Arc<Mat>> {
        self.qdeq0.get(&(quantizer_label.to_string(), seed))
    }

    /// The bit-packed encoding of [`PreparedLayer::qdeq0`]. Handed to
    /// outcomes as the `Arc` itself, so every w-only / plain-QER config
    /// of the cell serves one buffer — the sharing
    /// `eval::fleet::group_by_shared_bases` groups on.
    pub fn qdeq0_packed(&self, quantizer_label: &str, seed: u64) -> Option<&Arc<PackedMat>> {
        self.qdeq0_packed.get(&(quantizer_label.to_string(), seed))
    }

    /// The prepared (S·W, S·E) spectra for a (scaling kind, sweep seed).
    pub fn spectra(&self, kind: ScalingKind, seed: u64) -> Option<&Arc<PreparedSpectra>> {
        self.spectra.get(&(kind, seed))
    }
}

/// All layers of a sweep plus the cross-layer shared residual SVDs.
/// Immutable once built — phase B2's per-config fan-out only reads.
pub struct LayerCache {
    /// the prepared layers, in `Params::linear_names` order
    pub layers: Vec<PreparedLayer>,
    /// plain-QER residual SVDs: (layer index, quantizer label, scaling
    /// kind, sweep seed) → SVD of S(W − Q) at the grid's prep rank
    resid: HashMap<(usize, String, ScalingKind, u64), Arc<Svd>>,
}

impl LayerCache {
    /// A cache over prepared layers with no residual SVDs yet.
    pub fn new(layers: Vec<PreparedLayer>) -> Self {
        LayerCache { layers, resid: HashMap::new() }
    }

    /// Store a shared plain-QER residual SVD (phase B1).
    pub fn insert_resid(
        &mut self,
        layer: usize,
        quantizer_label: String,
        kind: ScalingKind,
        seed: u64,
        svd: Svd,
    ) {
        self.resid.insert((layer, quantizer_label, kind, seed), Arc::new(svd));
    }

    /// Look up a shared residual SVD stored by [`LayerCache::insert_resid`].
    pub fn resid(
        &self,
        layer: usize,
        quantizer_label: &str,
        kind: ScalingKind,
        seed: u64,
    ) -> Option<&Arc<Svd>> {
        self.resid.get(&(layer, quantizer_label.to_string(), kind, seed))
    }

    /// Total count of cached shared artifacts (metrics / tests).
    pub fn entry_count(&self) -> usize {
        self.resid.len()
            + self
                .layers
                .iter()
                .map(|l| {
                    l.scalings.len()
                        + l.qdeq0.len()
                        + l.spectra.len()
                        + usize::from(l.hessian.is_some())
                })
                .sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn layer(name: &str) -> PreparedLayer {
        let mut rng = Rng::new(1);
        let w = Mat::randn(8, 8, 1.0, &mut rng);
        let mut scalings = HashMap::new();
        scalings.insert(ScalingKind::Identity, Arc::new(Scaling::Identity));
        PreparedLayer {
            name: name.into(),
            w,
            scalings,
            hessian: Some(Arc::new(Mat::eye(8))),
            qdeq0: HashMap::new(),
            qdeq0_packed: HashMap::new(),
            spectra: HashMap::new(),
            prep_secs: 0.0,
        }
    }

    #[test]
    fn quant_ctx_serves_cached_hessian() {
        let l = layer("l0.wq");
        let with = l.quant_ctx(true, 7);
        assert_eq!(with.seed, 7);
        assert_eq!(with.hessian.unwrap(), Mat::eye(8));
        let without = l.quant_ctx(false, 7);
        assert!(without.hessian.is_none());
    }

    #[test]
    fn scaling_lookup_and_entry_count() {
        let l = layer("l0.wq");
        assert!(matches!(l.scaling(ScalingKind::Identity), Scaling::Identity));
        let mut cache = LayerCache::new(vec![l]);
        assert_eq!(cache.entry_count(), 2); // scaling + hessian
        let svd = crate::linalg::jacobi_svd(&Mat::eye(4));
        cache.insert_resid(0, "mxint3b32".into(), ScalingKind::Identity, 0, svd);
        assert_eq!(cache.entry_count(), 3);
        assert!(cache.resid(0, "mxint3b32", ScalingKind::Identity, 0).is_some());
        assert!(cache.resid(0, "mxint3b32", ScalingKind::Identity, 1).is_none());
    }

    #[test]
    #[should_panic(expected = "not prepared")]
    fn missing_scaling_panics_with_layer_name() {
        layer("l0.wq").scaling(ScalingKind::Exact);
    }
}
