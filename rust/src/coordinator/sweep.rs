//! The shared-work PTQ sweep engine.
//!
//! The paper's experiment grid (Tables 1/2/5/16) evaluates many
//! `(method, quantizer, rank, scaling, seed)` configs over the *same*
//! model and calibration set. Running `run_ptq` per config recomputes
//! identical per-layer work every time; [`SweepRunner`] executes the
//! whole grid in one pass instead:
//!
//! * **phase A (prepare)** — per layer, compute every activation scaling,
//!   GPTQ Hessian, k=0 dequantized weight and (S·W, S·E) spectra the
//!   grid will touch, once each, at the grid's maximum rank, into a
//!   [`LayerCache`] of [`PreparedLayer`]s;
//! * **phase B1 (shared residuals)** — one residual SVD per
//!   (layer, quantizer, scaling, seed) serves every rank of the plain-QER
//!   baseline;
//! * **phase B2 (fan-out)** — per-(layer, config) reconstruction jobs
//!   over the worker pool, consuming only cached artifacts.
//!
//! Results are **bit-identical** to the per-config `run_ptq` path run
//! with the same `prep_rank`: both truncate the same prep-rank
//! factorizations and draw from the same salted RNG streams (regression-
//! tested below; speedup recorded by `exp::perf::sweep_bench` into
//! `BENCH_sweep.json`). Stage timings land in `metrics` under `sweep.*`
//! for the Table 11 overhead accounting — `*_cpu_secs` keys are summed
//! across worker threads (CPU time), `prep_secs` / `shared_resid_secs` /
//! `reconstruct_secs` are wall-clock around each phase.
//!
//! Memory note: phase B2 emits [`FactoredOutcome`]s — packed codes +
//! adapter factors, roughly `effective_bits/32` of a dense model each —
//! so a whole grid's outcomes now fit where a handful of densified
//! copies used to. On top of that, every w-only / plain-QER config of a
//! `(quantizer, seed)` cell receives the *same* `Arc<PackedMat>` from
//! the [`LayerCache`] (not a copy), deduping the grid's base memory
//! M-fold across rank/scaling variants — and marking the outcomes as
//! lock-step-evaluable for `eval::fleet::fleet_perplexity`, which
//! decodes each shared base once per group per eval batch. The dense
//! [`PtqOutcome`]s (grid-size × model-size) only materialize when a
//! caller asks via [`SweepRunner::run`] / `to_dense` (the PJRT eval
//! engines still need them).

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::linalg::{randomized_svd, Svd};
use crate::model::{CalibrationSet, Params};
use crate::qer::methods::RESID_SALT;
use crate::qer::{
    correction_from_svd, reconstruct_prepared, Method, PreparedSpectra, QerConfig, QerResult,
};
use crate::quant::{PackedMat, QuantCtx};
use crate::runtime::manifest::ModelCfg;
use crate::scaling::{Scaling, ScalingKind};
use crate::serve::{FactoredModel, LinearOp};
use crate::tensor::Mat;
use crate::util::{pool, Rng};

use super::cache::{LayerCache, PreparedLayer};
use super::metrics::Metrics;
use super::pipeline::{
    layer_salt, FactoredOutcome, LayerMeta, LayerReport, PtqOutcome, QuantizerSpec,
};

/// Randomized-SVD power iterations, matching `QerConfig::new` (§A.4: 4).
const N_ITER: usize = 4;

/// One layer's `(quantizer, rank)` assignment inside a heterogeneous
/// [`SweepConfig`] — the unit the budget allocator
/// ([`crate::coordinator::budget`]) hands out per linear.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerAssign {
    /// quantizer spec for this layer's base
    pub quantizer: QuantizerSpec,
    /// rank budget r for this layer
    pub rank: usize,
}

/// One cell of a sweep grid.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepConfig {
    /// display/report label (defaults to `quantizer/method/rank/scaling`)
    pub label: String,
    /// quantizer spec for the base
    pub quantizer: QuantizerSpec,
    /// reconstruction method
    pub method: Method,
    /// rank budget r; for heterogeneous configs this also acts as the
    /// prep-rank floor (see [`SweepConfig::max_rank`])
    pub rank: usize,
    /// activation scaling kind
    pub scaling: ScalingKind,
    /// sweep-level seed (layer-salted per linear)
    pub seed: u64,
    /// per-layer `(quantizer, rank)` overrides, aligned with
    /// `Params::linear_names` order. `None` = homogeneous (every layer
    /// gets the cell's `quantizer`/`rank`). The engine flattens each
    /// layer's view via [`SweepConfig::resolved`] before doing any work,
    /// so heterogeneous cells reuse the homogeneous machinery verbatim.
    pub per_layer: Option<Arc<Vec<LayerAssign>>>,
}

impl SweepConfig {
    /// A cell with the default label and seed 0.
    pub fn new(
        quantizer: QuantizerSpec,
        method: Method,
        rank: usize,
        scaling: ScalingKind,
    ) -> Self {
        let label = format!(
            "{}/{}/r{}/{}",
            quantizer.label(),
            method.label(),
            rank,
            scaling.label()
        );
        SweepConfig { label, quantizer, method, rank, scaling, seed: 0, per_layer: None }
    }

    /// Builder: replace the sweep-level seed.
    pub fn seeded(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Builder: replace the display label.
    pub fn labeled(mut self, label: &str) -> Self {
        self.label = label.to_string();
        self
    }

    /// Builder: make the cell heterogeneous — one `(quantizer, rank)`
    /// assignment per linear, aligned with `Params::linear_names` order.
    pub fn with_per_layer(mut self, assigns: Vec<LayerAssign>) -> Self {
        self.per_layer = Some(Arc::new(assigns));
        self
    }

    /// Layer `li`'s homogeneous view of this cell: the config the engine
    /// actually executes for that linear. For homogeneous cells this is
    /// a plain clone; for heterogeneous cells the layer's assignment
    /// replaces `quantizer`/`rank` and `per_layer` is dropped — which is
    /// also what goes over the shard wire, so the wire format never sees
    /// a heterogeneous cell.
    pub fn resolved(&self, li: usize) -> SweepConfig {
        let mut c = self.clone();
        if let Some(assigns) = &self.per_layer {
            let a = &assigns[li];
            c.quantizer = a.quantizer;
            c.rank = a.rank;
            c.per_layer = None;
        }
        c
    }

    /// The largest rank any layer of this cell uses. The top-level
    /// `rank` field participates as a floor, so a caller (the budget
    /// planner) can pin the grid's prep rank above every per-layer rank
    /// — shared spectra must be factorized at the *planning* prep rank
    /// for the planned `k` to be the realized `k*`.
    pub fn max_rank(&self) -> usize {
        match &self.per_layer {
            None => self.rank,
            Some(a) => a.iter().map(|x| x.rank).fold(self.rank, usize::max),
        }
    }

    /// The `QerConfig` the equivalent per-config `run_ptq` call would
    /// derive for a layer with salt `salt` under grid prep rank
    /// `prep_rank` (the bit-identity contract).
    pub fn qer_config(&self, prep_rank: usize, salt: u64) -> QerConfig {
        let mut cfg = QerConfig::new(self.method, self.rank, self.scaling);
        cfg.n_iter = N_ITER;
        cfg.seed = self.seed ^ salt;
        cfg.prep_rank = Some(prep_rank);
        cfg
    }
}

/// Executes a grid of PTQ configs over one model in a single shared-work
/// pass. See the module docs for the phase structure.
pub struct SweepRunner<'a> {
    params: &'a Params,
    model_cfg: &'a ModelCfg,
    calib: &'a CalibrationSet,
    metrics: &'a Metrics,
}

impl<'a> SweepRunner<'a> {
    /// A runner over one model + calibration set; `metrics` receives the
    /// `sweep.*` stage timings.
    pub fn new(
        params: &'a Params,
        model_cfg: &'a ModelCfg,
        calib: &'a CalibrationSet,
        metrics: &'a Metrics,
    ) -> Self {
        SweepRunner { params, model_cfg, calib, metrics }
    }

    /// The grid's preparation rank: every shared factorization is
    /// computed at the maximum rank (over every layer of every cell,
    /// [`SweepConfig::max_rank`]) and prefix-truncated per config.
    pub fn prep_rank(configs: &[SweepConfig]) -> usize {
        configs.iter().map(|c| c.max_rank()).max().unwrap_or(0)
    }

    /// Run the grid densified; one [`PtqOutcome`] per config, aligned.
    /// Compatibility wrapper over [`SweepRunner::run_factored`].
    pub fn run(&self, configs: &[SweepConfig]) -> Vec<PtqOutcome> {
        self.run_factored(configs).iter().map(FactoredOutcome::to_dense).collect()
    }

    /// Run the grid; returns one [`FactoredOutcome`] per config, aligned
    /// — packed bases + adapters, no dense `W_hat` materialized.
    pub fn run_factored(&self, configs: &[SweepConfig]) -> Vec<FactoredOutcome> {
        let names = Params::linear_names(self.model_cfg);
        let n_layers = names.len();
        if configs.is_empty() || n_layers == 0 {
            return empty_outcomes(self.params, configs.len());
        }

        let prep = self.prepare(configs);

        // ---- phase B2: per-(layer, config) fan-out ----------------------
        let t_rec = Instant::now();
        let n_jobs = n_layers * configs.len();
        let parts: Vec<(LinearOp, LayerMeta, LayerReport)> = pool::par_map(n_jobs, |idx| {
            let li = idx % n_layers;
            let c = configs[idx / n_layers].resolved(li);
            let layer = &prep.cache.layers[li];
            let t0 = Instant::now();
            let arts = b2_artifacts(&prep.cache, li, &c);
            let (res, mut report) = b2_job(&c, prep.prep_rank, &arts);
            self.metrics.add("sweep.reconstruct_cpu_secs", t0.elapsed().as_secs_f64());
            // prep is shared: charge each config its amortized share
            report.scale_secs = layer.prep_secs / configs.len() as f64;
            let meta = LayerMeta {
                name: layer.name.clone(),
                k_star: res.k_star,
                selection: res.selection.clone(),
            };
            (res.into_factored(), meta, report)
        });
        self.metrics.add("sweep.reconstruct_secs", t_rec.elapsed().as_secs_f64());

        let outcomes =
            assemble_outcomes(self.params, &names, configs.len(), parts, self.metrics);
        self.metrics.add("sweep.configs", configs.len() as f64);
        self.metrics.add("sweep.layers", n_layers as f64);
        self.metrics.add("sweep.cache_entries", prep.cache.entry_count() as f64);
        outcomes
    }

    /// Phases A + B1: populate the shared-work [`LayerCache`] for
    /// `configs` — every scaling / Hessian / k=0 quantization / spectra
    /// the grid touches, plus the plain-QER residual SVDs — leaving only
    /// the per-(layer, config) phase-B2 fan-out, which the in-process
    /// [`SweepRunner::run_factored`] and the multi-process
    /// [`ShardedSweepRunner`](super::shard::ShardedSweepRunner) execute
    /// from the same cache (the sharded path ships the cached artifacts
    /// over the wire instead of sharing memory).
    pub(crate) fn prepare(&self, configs: &[SweepConfig]) -> SweepPrep {
        let names = Params::linear_names(self.model_cfg);
        let n_layers = names.len();
        let keys = sweep_keys(configs, n_layers);
        let prep_rank = keys.prep_rank;
        let any_hessian = keys.any_hessian;

        // ---- phase A: per-layer shared preparation ----------------------
        let t_prep = Instant::now();
        let layers: Vec<PreparedLayer> = pool::par_map(n_layers, |i| {
            prepare_layer(
                self.params,
                self.calib,
                &names[i],
                &keys.layers[i],
                &keys.kinds,
                any_hessian,
                prep_rank,
                self.metrics,
            )
        });
        let mut cache = LayerCache::new(layers);
        self.metrics.add("sweep.prep_secs", t_prep.elapsed().as_secs_f64());

        // ---- phase B1: shared plain-QER residual SVDs -------------------
        let t_resid = Instant::now();
        let resid_jobs = keys.resid_jobs();
        let resids: Vec<(usize, usize, Svd)> = pool::par_map(resid_jobs.len(), |idx| {
            let (li, ri) = resid_jobs[idx];
            let (label, kind, seed, _spec) = &keys.layers[li].resid_keys[ri];
            let layer = &cache.layers[li];
            let salt = layer_salt(&layer.name);
            let qdeq = layer.qdeq0(label, *seed).expect("qdeq prepared");
            let scaling = layer.scaling(*kind);
            let tj = Instant::now();
            let svd = compute_resid_svd(&layer.w, qdeq, scaling, prep_rank, *seed, salt);
            self.metrics.add("sweep.resid_cpu_secs", tj.elapsed().as_secs_f64());
            (li, ri, svd)
        });
        for (li, ri, svd) in resids {
            let (label, kind, seed, _) = &keys.layers[li].resid_keys[ri];
            cache.insert_resid(li, label.clone(), *kind, *seed, svd);
        }
        self.metrics.add("sweep.shared_resid_secs", t_resid.elapsed().as_secs_f64());

        SweepPrep { cache, prep_rank }
    }
}

/// Output of [`SweepRunner::prepare`]: the populated cache plus the
/// grid's preparation rank.
pub(crate) struct SweepPrep {
    /// shared artifacts for every layer, phases A + B1 complete
    pub cache: LayerCache,
    /// rank all shared factorizations were computed at
    pub prep_rank: usize,
}

/// One layer's distinct shared-work keys, insertion-ordered and deduped.
/// For homogeneous grids every layer carries identical lists (the
/// pre-heterogeneity behaviour); a heterogeneous cell contributes only
/// the keys its [`SweepConfig::resolved`] view of that layer touches.
#[derive(Default)]
pub(crate) struct LayerKeys {
    /// (scaling, seed) pairs needing prepared (S·W, S·E) spectra
    pub spectra_keys: Vec<(ScalingKind, u64)>,
    /// (quantizer label, seed, spec) cells needing a k=0 quantization
    pub qdeq0_keys: Vec<(String, u64, QuantizerSpec)>,
    /// (label, scaling, seed, spec) cells needing a plain-QER residual SVD
    pub resid_keys: Vec<(String, ScalingKind, u64, QuantizerSpec)>,
}

/// The shared-work keys a grid touches, per layer, plus the grid's prep
/// rank and whether any quantizer wants a Hessian. One derivation shared
/// by the in-process [`SweepRunner::prepare`] and the sharded phase-A
/// prep ([`ShardedSweepRunner`](super::shard::ShardedSweepRunner)), so
/// both paths enumerate exactly the same work — the bit-identity
/// contract between them starts here.
pub(crate) struct SweepKeys {
    /// every scaling kind any config uses (scalings are cheap; computed
    /// for all layers rather than tracked per layer)
    pub kinds: Vec<ScalingKind>,
    /// per-layer key lists, aligned with `Params::linear_names`
    pub layers: Vec<LayerKeys>,
    /// rank every shared factorization is computed at
    pub prep_rank: usize,
    /// whether any resolved cell's quantizer consumes a GPTQ Hessian
    pub any_hessian: bool,
}

impl SweepKeys {
    /// Flattened `(layer, key-index)` job list for the phase-B1 residual
    /// SVDs — layer-major, key order within a layer, so the in-process
    /// and sharded paths walk residuals identically.
    pub fn resid_jobs(&self) -> Vec<(usize, usize)> {
        self.layers
            .iter()
            .enumerate()
            .flat_map(|(li, lk)| (0..lk.resid_keys.len()).map(move |ri| (li, ri)))
            .collect()
    }
}

/// Derive the deduped per-layer shared-work key lists for `configs`
/// over `n_layers` linears.
pub(crate) fn sweep_keys(configs: &[SweepConfig], n_layers: usize) -> SweepKeys {
    let prep_rank = SweepRunner::prep_rank(configs);
    let mut any_hessian = false;
    let mut kinds: Vec<ScalingKind> = Vec::new();
    let mut layers: Vec<LayerKeys> = (0..n_layers).map(|_| LayerKeys::default()).collect();
    for (li, lk) in layers.iter_mut().enumerate() {
        for cell in configs {
            let c = cell.resolved(li);
            any_hessian |= c.quantizer.needs_hessian();
            if !kinds.contains(&c.scaling) {
                kinds.push(c.scaling);
            }
            if c.method.needs_spectra() && !lk.spectra_keys.contains(&(c.scaling, c.seed)) {
                lk.spectra_keys.push((c.scaling, c.seed));
            }
            if matches!(c.method, Method::WOnly | Method::Qer) {
                let label = c.quantizer.label();
                if !lk.qdeq0_keys.iter().any(|(l, s, _)| *l == label && *s == c.seed) {
                    lk.qdeq0_keys.push((label.clone(), c.seed, c.quantizer));
                }
                if c.method == Method::Qer
                    && !lk
                        .resid_keys
                        .iter()
                        .any(|(l, k, s, _)| *l == label && *k == c.scaling && *s == c.seed)
                {
                    lk.resid_keys.push((label, c.scaling, c.seed, c.quantizer));
                }
            }
        }
    }
    SweepKeys { kinds, layers, prep_rank, any_hessian }
}

/// One layer's full phase-A preparation — every activation scaling,
/// the optional GPTQ Hessian, the k=0 quantizations (dense + packed)
/// and the prepared (S·W, S·E) spectra the grid touches for this
/// linear. Shared verbatim by [`SweepRunner::prepare`] and the
/// spill-backed runner ([`super::spill`]), so both populate
/// byte-identical [`PreparedLayer`]s regardless of where the artifacts
/// end up living.
#[allow(clippy::too_many_arguments)]
pub(crate) fn prepare_layer(
    params: &Params,
    calib: &CalibrationSet,
    name: &str,
    lk: &LayerKeys,
    kinds: &[ScalingKind],
    any_hessian: bool,
    prep_rank: usize,
    metrics: &Metrics,
) -> PreparedLayer {
    let t0 = Instant::now();
    let w = params.get_mat(name).expect("linear present");
    let salt = layer_salt(name);

    let ts = Instant::now();
    let mut scalings = HashMap::new();
    for &kind in kinds {
        scalings.insert(kind, Arc::new(calib.scaling_for(name, kind)));
    }
    metrics.add("sweep.scaling_cpu_secs", ts.elapsed().as_secs_f64());

    let th = Instant::now();
    let hessian = if any_hessian {
        calib.quant_ctx(name, true, 0).hessian.map(Arc::new)
    } else {
        None
    };
    metrics.add("sweep.hessian_cpu_secs", th.elapsed().as_secs_f64());

    let tq = Instant::now();
    let mut qdeq0 = HashMap::new();
    let mut qdeq0_packed = HashMap::new();
    for (label, seed, spec) in &lk.qdeq0_keys {
        let (qdeq, packed) = compute_qdeq0(&w, hessian.as_deref(), spec, *seed, salt);
        qdeq0.insert((label.clone(), *seed), Arc::new(qdeq));
        if let Some(p) = packed {
            qdeq0_packed.insert((label.clone(), *seed), Arc::new(p));
        }
    }
    metrics.add("sweep.qdeq_cpu_secs", tq.elapsed().as_secs_f64());

    let tsp = Instant::now();
    let mut spectra = HashMap::new();
    for (kind, seed) in &lk.spectra_keys {
        let scaling = scalings.get(kind).expect("scaling prepared above");
        let sp = compute_spectra(&w, scaling, prep_rank, *seed, salt);
        spectra.insert((*kind, *seed), Arc::new(sp));
    }
    metrics.add("sweep.spectra_cpu_secs", tsp.elapsed().as_secs_f64());

    PreparedLayer {
        name: name.to_string(),
        w,
        scalings,
        hessian,
        qdeq0,
        qdeq0_packed,
        spectra,
        prep_secs: t0.elapsed().as_secs_f64(),
    }
}

/// One phase-A k=0 quantization: the salted-seed stream every path —
/// per-config `run_ptq`, in-process sweep, shard prep job — must open
/// identically for cell (`seed`, quantizer) on the layer with `salt`.
pub(crate) fn compute_qdeq0(
    w: &Mat,
    hessian: Option<&Mat>,
    spec: &QuantizerSpec,
    seed: u64,
    salt: u64,
) -> (Mat, Option<PackedMat>) {
    let hess = if spec.needs_hessian() { hessian.cloned() } else { None };
    let ctx = QuantCtx { hessian: hess, seed: seed ^ salt };
    spec.build().quantize_coded(w, &ctx)
}

/// One phase-A prepared-spectra computation (same salting contract as
/// [`compute_qdeq0`]).
pub(crate) fn compute_spectra(
    w: &Mat,
    scaling: &Scaling,
    prep_rank: usize,
    seed: u64,
    salt: u64,
) -> PreparedSpectra {
    PreparedSpectra::compute(w, scaling, prep_rank, N_ITER, seed ^ salt)
}

/// One phase-B1 shared plain-QER residual SVD — the same stream
/// `reconstruct_prepared` would open for this cfg.
pub(crate) fn compute_resid_svd(
    w: &Mat,
    qdeq: &Mat,
    scaling: &Scaling,
    prep_rank: usize,
    seed: u64,
    salt: u64,
) -> Svd {
    let mut rng = Rng::new((seed ^ salt) ^ RESID_SALT);
    let resid = scaling.apply(&w.sub(qdeq));
    randomized_svd(&resid, prep_rank, N_ITER, &mut rng)
}

/// The shared artifacts one phase-B2 job consumes, borrowed from a
/// [`LayerCache`] in-process or rebuilt from wire blobs on a shard
/// worker. Only the fields the config's method touches are populated.
pub(crate) struct B2Artifacts<'a> {
    /// the linear's parameter name (derives the layer salt)
    pub name: &'a str,
    /// original weight
    pub w: &'a Mat,
    /// activation scaling for the config's kind
    pub scaling: &'a Scaling,
    /// GPTQ Hessian (quantizers that need one)
    pub hessian: Option<&'a Mat>,
    /// cached k=0 dequantized weight (w-only / plain-QER)
    pub qdeq0: Option<&'a Mat>,
    /// bit-packed encoding of `qdeq0` — handed to the outcome as the
    /// `Arc` itself, so every rank/scaling variant of the cell serves
    /// one buffer (the sharing the fleet evaluator groups on)
    pub qdeq0_packed: Option<&'a Arc<PackedMat>>,
    /// shared plain-QER residual SVD (QER)
    pub resid: Option<&'a Svd>,
    /// prepared (S·W, S·E) spectra (SRR family)
    pub spectra: Option<&'a PreparedSpectra>,
}

/// Borrow the artifacts job `(layer, config)` needs out of the cache.
pub(crate) fn b2_artifacts<'a>(
    cache: &'a LayerCache,
    li: usize,
    c: &SweepConfig,
) -> B2Artifacts<'a> {
    let layer = &cache.layers[li];
    let label = c.quantizer.label();
    let wants_qdeq = matches!(c.method, Method::WOnly | Method::Qer);
    B2Artifacts {
        name: &layer.name,
        w: &layer.w,
        scaling: layer.scaling(c.scaling),
        hessian: if c.quantizer.needs_hessian() { layer.hessian.as_deref() } else { None },
        qdeq0: if wants_qdeq {
            layer.qdeq0(&label, c.seed).map(|a| a.as_ref())
        } else {
            None
        },
        qdeq0_packed: if wants_qdeq { layer.qdeq0_packed(&label, c.seed) } else { None },
        resid: if c.method == Method::Qer {
            cache.resid(li, &label, c.scaling, c.seed).map(|a| a.as_ref())
        } else {
            None
        },
        spectra: if c.method.needs_spectra() {
            layer.spectra(c.scaling, c.seed).map(|a| a.as_ref())
        } else {
            None
        },
    }
}

/// One phase-B2 reconstruction job, shared verbatim by the in-process
/// fan-out and the shard workers — the bit-identity contract between the
/// two paths is that both run exactly this function on the same
/// artifacts. `scale_secs` in the returned report is 0; the caller
/// charges the amortized shared-prep cost.
pub(crate) fn b2_job(
    c: &SweepConfig,
    prep_rank: usize,
    a: &B2Artifacts,
) -> (QerResult, LayerReport) {
    let salt = layer_salt(a.name);
    let t0 = Instant::now();
    let res: QerResult = match c.method {
        Method::WOnly => {
            let qdeq = a.qdeq0.expect("qdeq prepared").clone();
            // the Arc, not a copy: every rank/scaling variant of this
            // (quantizer, seed) cell serves the same buffer, and the
            // fleet evaluator groups outcomes by it
            let packed = a.qdeq0_packed.cloned();
            QerResult {
                qdeq,
                packed,
                l: Mat::zeros(a.w.rows, 0),
                r: Mat::zeros(0, a.w.cols),
                k_star: 0,
                selection: None,
            }
        }
        Method::Qer => {
            let qdeq = a.qdeq0.expect("qdeq prepared").clone();
            let packed = a.qdeq0_packed.cloned();
            let svd = a.resid.expect("residual SVD prepared");
            let (l, r) = correction_from_svd(svd, a.scaling, c.rank);
            QerResult { qdeq, packed, l, r, k_star: 0, selection: None }
        }
        _ => {
            let ctx = QuantCtx {
                hessian: if c.quantizer.needs_hessian() { a.hessian.cloned() } else { None },
                seed: c.seed ^ salt,
            };
            let q = c.quantizer.build();
            let qcfg = c.qer_config(prep_rank, salt);
            reconstruct_prepared(a.w, q.as_ref(), a.scaling, a.spectra, &ctx, &qcfg)
        }
    };

    // W_hat is formed transiently for the error report only; the outcome
    // keeps the factored representation
    let what = res.reconstruct();
    let report = LayerReport {
        name: a.name.to_string(),
        k_star: res.k_star,
        weight_err: a.w.sub(&what).frob(),
        scaled_err: a.scaling.apply(&a.w.sub(&what)).frob(),
        scale_secs: 0.0,
        qer_secs: t0.elapsed().as_secs_f64(),
    };
    (res, report)
}

/// The per-config outcomes every sweep produces when configs or layers
/// are absent.
pub(crate) fn empty_outcomes(params: &Params, n: usize) -> Vec<FactoredOutcome> {
    (0..n)
        .map(|_| FactoredOutcome {
            model: FactoredModel { skeleton: params.clone(), ops: vec![] },
            meta: vec![],
            reports: vec![],
        })
        .collect()
}

/// Assemble one [`FactoredOutcome`] per config from completed phase-B2
/// parts in job-id order (`idx = config_idx * n_layers + layer_idx`).
/// Shared by the in-process and sharded paths so the merge — including
/// the `ptq.*` metric accounting — is identical regardless of where the
/// jobs ran.
pub(crate) fn assemble_outcomes(
    params: &Params,
    names: &[String],
    n_configs: usize,
    parts: Vec<(LinearOp, LayerMeta, LayerReport)>,
    metrics: &Metrics,
) -> Vec<FactoredOutcome> {
    let n_layers = names.len();
    assert_eq!(parts.len(), n_configs * n_layers, "phase-B2 parts incomplete");
    let mut per_cfg: Vec<Vec<Option<(LinearOp, LayerMeta, LayerReport)>>> =
        (0..n_configs).map(|_| (0..n_layers).map(|_| None).collect()).collect();
    for (idx, part) in parts.into_iter().enumerate() {
        per_cfg[idx / n_layers][idx % n_layers] = Some(part);
    }
    let mut outcomes = Vec::with_capacity(n_configs);
    for slots in per_cfg {
        let mut skeleton = params.clone();
        let mut ops = Vec::with_capacity(n_layers);
        let mut meta = Vec::with_capacity(n_layers);
        let mut reports = Vec::with_capacity(n_layers);
        for (li, slot) in slots.into_iter().enumerate() {
            let (op, m, report) = slot.expect("job completed");
            metrics.add("ptq.scale_secs", report.scale_secs);
            metrics.add("ptq.qer_secs", report.qer_secs);
            metrics.incr("ptq.layers");
            skeleton.unset(&names[li]);
            meta.push(m);
            ops.push((names[li].clone(), op));
            reports.push(report);
        }
        outcomes.push(FactoredOutcome { model: FactoredModel { skeleton, ops }, meta, reports });
    }
    outcomes
}

/// Convenience wrapper mirroring `run_ptq`'s free-function shape.
pub fn run_sweep(
    params: &Params,
    model_cfg: &ModelCfg,
    calib: &CalibrationSet,
    configs: &[SweepConfig],
    metrics: &Metrics,
) -> Vec<PtqOutcome> {
    SweepRunner::new(params, model_cfg, calib, metrics).run(configs)
}

/// Factored counterpart of [`run_sweep`]: packed serving outcomes, no
/// densified models.
pub fn run_sweep_factored(
    params: &Params,
    model_cfg: &ModelCfg,
    calib: &CalibrationSet,
    configs: &[SweepConfig],
    metrics: &Metrics,
) -> Vec<FactoredOutcome> {
    SweepRunner::new(params, model_cfg, calib, metrics).run_factored(configs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::run_ptq;
    use crate::data::Corpus;
    use crate::model::collect_calibration;
    use crate::model::synth::synth_lm_params;

    fn setup() -> (Params, ModelCfg, CalibrationSet) {
        // same regime as the pipeline tests: rank budget a few % of the
        // min dim, calibration deep enough for a full-rank exact Gram
        let cfg = ModelCfg {
            name: "t".into(),
            vocab: 64,
            d_model: 64,
            n_heads: 2,
            n_layers: 2,
            d_ff: 128,
            seq_len: 16,
        };
        let params = synth_lm_params(&cfg, 5, cfg.vocab);
        let corpus = Corpus::generate(cfg.vocab, 4000, 6);
        let batches: Vec<Vec<i32>> = (0..10).map(|i| corpus.train_batch(2, 16, i)).collect();
        let calib = collect_calibration(&params, &cfg, &batches, 2, 16, 192);
        (params, cfg, calib)
    }

    fn grid() -> Vec<SweepConfig> {
        let mx = QuantizerSpec::Mxint { bits: 3, block: 32 };
        vec![
            SweepConfig::new(mx, Method::Qer, 4, ScalingKind::DiagRms),
            SweepConfig::new(mx, Method::QerSrr, 8, ScalingKind::Exact).seeded(5),
            SweepConfig::new(
                QuantizerSpec::Gptq { bits: 3, group: 64 },
                Method::QerSrr,
                8,
                ScalingKind::DiagAbsMean,
            ),
        ]
    }

    /// Satellite regression: the shared-work sweep must be bit-identical
    /// (`qdeq`, `k_star`, `L`, `R`) to per-config `run_ptq` with the same
    /// prep rank, for a mixed 3-config grid including a Hessian path.
    #[test]
    fn equivalent_to_per_config_run_ptq() {
        let (params, cfg, calib) = setup();
        let configs = grid();
        let prep_rank = SweepRunner::prep_rank(&configs);
        let metrics = Metrics::new();
        let outcomes = run_sweep(&params, &cfg, &calib, &configs, &metrics);
        assert_eq!(outcomes.len(), configs.len());

        for (c, sweep_out) in configs.iter().zip(&outcomes) {
            let mut qcfg = QerConfig::new(c.method, c.rank, c.scaling);
            qcfg.seed = c.seed;
            qcfg.prep_rank = Some(prep_rank);
            let solo = run_ptq(&params, &cfg, &calib, c.quantizer, &qcfg, &metrics);
            assert_eq!(solo.results.len(), sweep_out.results.len());
            for ((n1, r1), (n2, r2)) in solo.results.iter().zip(&sweep_out.results) {
                assert_eq!(n1, n2);
                assert_eq!(r1.qdeq, r2.qdeq, "{}: {n1} qdeq differs", c.label);
                assert_eq!(r1.l, r2.l, "{}: {n1} L differs", c.label);
                assert_eq!(r1.r, r2.r, "{}: {n1} R differs", c.label);
                assert_eq!(r1.k_star, r2.k_star, "{}: {n1} k* differs", c.label);
            }
            // spliced models agree too
            for name in Params::linear_names(&cfg) {
                assert_eq!(
                    solo.params.get_mat(&name).unwrap(),
                    sweep_out.params.get_mat(&name).unwrap(),
                    "{}: spliced {name} differs",
                    c.label
                );
            }
        }
    }

    /// A heterogeneous cell (per-layer quantizer/rank, the budget
    /// allocator's execution form) must be bit-identical, layer by
    /// layer, to the homogeneous grid member carrying that layer's
    /// assignment — same grid, so all cells share one prep rank.
    #[test]
    fn heterogeneous_cell_matches_homogeneous_members_per_layer() {
        let (params, cfg, calib) = setup();
        let n_layers = Params::linear_names(&cfg).len();
        let mx3 = QuantizerSpec::Mxint { bits: 3, block: 32 };
        let mx4 = QuantizerSpec::Mxint { bits: 4, block: 32 };
        let assigns: Vec<LayerAssign> = (0..n_layers)
            .map(|li| {
                if li % 2 == 0 {
                    LayerAssign { quantizer: mx3, rank: 4 }
                } else {
                    LayerAssign { quantizer: mx4, rank: 8 }
                }
            })
            .collect();
        // the het cell's top-level rank is the prep floor (max_rank)
        let configs = vec![
            SweepConfig::new(mx3, Method::QerSrr, 8, ScalingKind::DiagRms)
                .with_per_layer(assigns),
            SweepConfig::new(mx3, Method::QerSrr, 4, ScalingKind::DiagRms),
            SweepConfig::new(mx4, Method::QerSrr, 8, ScalingKind::DiagRms),
        ];
        assert_eq!(SweepRunner::prep_rank(&configs), 8);
        let metrics = Metrics::new();
        let outs = run_sweep(&params, &cfg, &calib, &configs, &metrics);
        for li in 0..n_layers {
            let want = if li % 2 == 0 { &outs[1] } else { &outs[2] };
            let (n1, got) = &outs[0].results[li];
            let (n2, exp) = &want.results[li];
            assert_eq!(n1, n2);
            assert_eq!(got.qdeq, exp.qdeq, "{n1}: qdeq differs");
            assert_eq!(got.l, exp.l, "{n1}: L differs");
            assert_eq!(got.r, exp.r, "{n1}: R differs");
            assert_eq!(got.k_star, exp.k_star, "{n1}: k* differs");
        }
    }

    /// Satellite regression: two sweep runs are deterministic.
    #[test]
    fn deterministic_across_runs() {
        let (params, cfg, calib) = setup();
        let configs = grid();
        let metrics = Metrics::new();
        let a = run_sweep(&params, &cfg, &calib, &configs, &metrics);
        let b = run_sweep(&params, &cfg, &calib, &configs, &metrics);
        for (oa, ob) in a.iter().zip(&b) {
            for ((n1, r1), (n2, r2)) in oa.results.iter().zip(&ob.results) {
                assert_eq!(n1, n2);
                assert_eq!(r1.qdeq, r2.qdeq, "{n1} qdeq differs across runs");
                assert_eq!(r1.l, r2.l);
                assert_eq!(r1.r, r2.r);
                assert_eq!(r1.k_star, r2.k_star);
            }
        }
    }

    #[test]
    fn wonly_and_qer_share_quantization() {
        let (params, cfg, calib) = setup();
        let mx = QuantizerSpec::Mxint { bits: 3, block: 32 };
        let configs = vec![
            SweepConfig::new(mx, Method::WOnly, 0, ScalingKind::Identity),
            SweepConfig::new(mx, Method::Qer, 4, ScalingKind::DiagRms),
            SweepConfig::new(mx, Method::Qer, 8, ScalingKind::DiagRms),
        ];
        let metrics = Metrics::new();
        let outs = run_sweep(&params, &cfg, &calib, &configs, &metrics);
        // all three share the k=0 quantization of W
        for li in 0..outs[0].results.len() {
            assert_eq!(outs[0].results[li].1.qdeq, outs[1].results[li].1.qdeq);
            assert_eq!(outs[1].results[li].1.qdeq, outs[2].results[li].1.qdeq);
            assert_eq!(outs[1].results[li].1.l.cols, 4);
            assert_eq!(outs[2].results[li].1.l.cols, 8);
            // the rank-4 correction is the prefix of the rank-8 one
            // (both truncate the same shared residual SVD)
            let l8 = &outs[2].results[li].1.l;
            assert_eq!(outs[1].results[li].1.l, l8.cols_slice(0, 4));
        }
        // cache actually held shared entries and metrics were recorded
        assert!(metrics.get("sweep.cache_entries") > 0.0);
        assert_eq!(metrics.get("sweep.configs"), 3.0);
        assert!(metrics.get("sweep.prep_secs") > 0.0);
        assert!(metrics.get("sweep.reconstruct_secs") > 0.0);
    }

    #[test]
    fn reports_and_outcome_shape_match_run_ptq_contract() {
        let (params, cfg, calib) = setup();
        let configs = vec![SweepConfig::new(
            QuantizerSpec::Mxint { bits: 3, block: 32 },
            Method::QerSrr,
            8,
            ScalingKind::DiagRms,
        )];
        let metrics = Metrics::new();
        let outs = run_sweep(&params, &cfg, &calib, &configs, &metrics);
        let out = &outs[0];
        assert_eq!(out.reports.len(), 14);
        assert_eq!(out.results.len(), 14);
        for (name, _) in &out.results {
            let orig = params.get_mat(name).unwrap();
            let new = out.params.get_mat(name).unwrap();
            assert_ne!(orig, new, "{name} unchanged");
        }
        // non-linear params untouched
        assert_eq!(params.get_mat("embed").unwrap(), out.params.get_mat("embed").unwrap());
        // timing fields populated
        assert!(out.reports.iter().all(|r| r.qer_secs >= 0.0 && r.scale_secs >= 0.0));
    }

    #[test]
    fn empty_grid_is_a_noop() {
        let (params, cfg, calib) = setup();
        let metrics = Metrics::new();
        let outs = run_sweep(&params, &cfg, &calib, &[], &metrics);
        assert!(outs.is_empty());
    }

    /// Phase B2's primary output is factored: packed bases + adapters,
    /// much smaller than the densified models, and densifying reproduces
    /// the dense path exactly (including the w-only / plain-QER configs
    /// that reuse the cached k=0 quantization and its packed codes).
    #[test]
    fn factored_outcomes_densify_to_run_output_and_stay_small() {
        let (params, cfg, calib) = setup();
        let mx = QuantizerSpec::Mxint { bits: 3, block: 32 };
        let configs = vec![
            SweepConfig::new(mx, Method::WOnly, 0, ScalingKind::Identity),
            SweepConfig::new(mx, Method::Qer, 4, ScalingKind::DiagRms),
            SweepConfig::new(mx, Method::QerSrr, 8, ScalingKind::Exact),
        ];
        let metrics = Metrics::new();
        let runner = SweepRunner::new(&params, &cfg, &calib, &metrics);
        let factored = runner.run_factored(&configs);
        let dense = runner.run(&configs);
        for (c, (fo, po)) in configs.iter().zip(factored.iter().zip(&dense)) {
            assert!(
                fo.model.linear_bytes() * 2 < fo.model.dense_linear_bytes(),
                "{}: factored {} vs dense {}",
                c.label,
                fo.model.linear_bytes(),
                fo.model.dense_linear_bytes()
            );
            let densified = fo.model.densified_params();
            for name in Params::linear_names(&cfg) {
                assert_eq!(
                    densified.get_mat(&name).unwrap(),
                    po.params.get_mat(&name).unwrap(),
                    "{}: {name} diverges",
                    c.label
                );
            }
            // mxint packs, so every base rides as codes, never dense f32
            for (_, r) in &po.results {
                assert!(r.packed.is_some(), "{}: base not packed", c.label);
            }
        }
    }
}
