//! The PTQ pipeline: per-layer reconstruction jobs over a worker pool.
//!
//! For every quantizable linear:  build S from calibration → (SRR only:
//! select k*) → preserve → quantize → reconstruct → pack into the
//! factored serving form (packed codes + L·R, see `serve`). The primary
//! outcome is a [`FactoredOutcome`]; the legacy dense [`PtqOutcome`]
//! (W_hat spliced into a model copy for the PJRT eval engines) stays
//! available behind the [`FactoredOutcome::to_dense`] compatibility
//! constructor. Stage timings feed the Table 11 overhead accounting.

use std::sync::Mutex;
use std::time::Instant;

use crate::model::{CalibrationSet, Params};
use crate::qer::{reconstruct, QerConfig, QerResult, RankSelection};
use crate::quant::{
    GptqQuantizer, MxintQuantizer, QuantCtx, Quantizer, QuipSharpQuantizer, UniformQuantizer,
};
use crate::runtime::manifest::ModelCfg;
use crate::scaling::Scaling;
use crate::serve::{FactoredModel, LinearOp, QuantBase};
use crate::tensor::Mat;
use crate::util::pool;

use super::metrics::Metrics;

/// Constructible quantizer description (trait objects aren't clonable
/// across worker threads; each job builds its own from the spec).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantizerSpec {
    Mxint { bits: u32, block: usize },
    Uniform { bits: u32, group: usize, symmetric: bool },
    Gptq { bits: u32, group: usize },
    QuipSharp { bits: u32 },
}

impl QuantizerSpec {
    /// Instantiate the described quantizer.
    pub fn build(&self) -> Box<dyn Quantizer> {
        match *self {
            QuantizerSpec::Mxint { bits, block } => Box::new(MxintQuantizer::new(bits, block)),
            QuantizerSpec::Uniform { bits, group, symmetric } => {
                Box::new(UniformQuantizer::new(bits, group, symmetric))
            }
            QuantizerSpec::Gptq { bits, group } => Box::new(GptqQuantizer::new(bits, group)),
            QuantizerSpec::QuipSharp { bits } => Box::new(QuipSharpQuantizer::new(bits)),
        }
    }

    /// Whether this quantizer consumes a calibration Hessian (GPTQ).
    pub fn needs_hessian(&self) -> bool {
        matches!(self, QuantizerSpec::Gptq { .. })
    }

    /// Stable label, e.g. `mxint3b32` (cache/report key).
    pub fn label(&self) -> String {
        self.build().name()
    }

    /// Effective bits per weight including side data.
    pub fn effective_bits(&self) -> f64 {
        self.build().effective_bits()
    }
}

/// Per-layer outcome report.
#[derive(Clone, Debug)]
pub struct LayerReport {
    /// the linear's parameter name
    pub name: String,
    /// preserved rank chosen by SRR (0 for non-SRR methods)
    pub k_star: usize,
    /// ‖W − Ŵ‖_F
    pub weight_err: f64,
    /// ‖S(W − Ŵ)‖_F under the config's scaling
    pub scaled_err: f64,
    /// seconds building scaling/calibration context (amortized in sweeps)
    pub scale_secs: f64,
    /// seconds in quantize + reconstruct
    pub qer_secs: f64,
}

/// Whole-model PTQ outcome, densified (the legacy shape the PJRT eval
/// engines and the regression tests consume). Built from a
/// [`FactoredOutcome`] via [`FactoredOutcome::to_dense`].
pub struct PtqOutcome {
    /// model copy with every linear replaced by W_hat = Qdeq + L·R
    pub params: Params,
    /// raw per-layer decompositions (QPEFT init consumes these)
    pub results: Vec<(String, QerResult)>,
    /// per-layer error/timing reports
    pub reports: Vec<LayerReport>,
}

impl PtqOutcome {
    /// √Σ‖W − Ŵ‖²_F over layers.
    pub fn total_weight_err(&self) -> f64 {
        self.reports.iter().map(|r| r.weight_err * r.weight_err).sum::<f64>().sqrt()
    }

    /// Mean preserved rank k* across layers.
    pub fn mean_k_star(&self) -> f64 {
        if self.reports.is_empty() {
            return 0.0;
        }
        self.reports.iter().map(|r| r.k_star as f64).sum::<f64>() / self.reports.len() as f64
    }
}

/// Per-layer metadata a [`QerResult`] carries beyond its factors,
/// aligned with `FactoredModel::ops`.
#[derive(Clone, Debug)]
pub struct LayerMeta {
    /// the linear's parameter name
    pub name: String,
    /// preserved rank chosen by SRR (0 for non-SRR methods)
    pub k_star: usize,
    /// the full k-selection trace (SRR only)
    pub selection: Option<RankSelection>,
}

/// Whole-model PTQ outcome in the factored serving form: packed bases +
/// adapter factors, no dense `W_hat` anywhere. Sweep outcomes that reuse
/// a cached k=0 quantization share their [`crate::serve::QuantBase`]
/// buffers through `Arc` — M rank variants hold one packed base, and
/// [`crate::eval::fleet`] evaluates them in one lock-step pass.
#[derive(Debug)]
pub struct FactoredOutcome {
    /// the factored serving model (consumed by `perplexity_native` /
    /// the fleet evaluator)
    pub model: FactoredModel,
    /// aligned with `model.ops`
    pub meta: Vec<LayerMeta>,
    /// per-layer error/timing reports, aligned with `model.ops`
    pub reports: Vec<LayerReport>,
}

impl FactoredOutcome {
    /// √Σ‖W − Ŵ‖²_F over layers (parity with
    /// [`PtqOutcome::total_weight_err`]).
    pub fn total_weight_err(&self) -> f64 {
        self.reports.iter().map(|r| r.weight_err * r.weight_err).sum::<f64>().sqrt()
    }

    /// Mean preserved rank k* across layers.
    pub fn mean_k_star(&self) -> f64 {
        if self.reports.is_empty() {
            return 0.0;
        }
        self.reports.iter().map(|r| r.k_star as f64).sum::<f64>() / self.reports.len() as f64
    }

    /// Densify into the legacy [`PtqOutcome`] — the compatibility
    /// constructor. Bit-identical to the historical dense pipeline:
    /// packed bases dequantize to exactly the quantizer's output (each
    /// base is decoded once; the spliced W_hat reuses the result's qdeq).
    pub fn to_dense(&self) -> PtqOutcome {
        let mut params = self.model.skeleton.clone();
        let mut results = Vec::with_capacity(self.model.ops.len());
        for ((name, op), meta) in self.model.ops.iter().zip(&self.meta) {
            debug_assert_eq!(name, &meta.name, "ops/meta misaligned");
            let res = qer_result_from_op(op, meta);
            params.set_mat(name, &res.reconstruct());
            results.push((name.clone(), res));
        }
        PtqOutcome { params, results, reports: self.reports.clone() }
    }
}

fn qer_result_from_op(op: &LinearOp, meta: &LayerMeta) -> QerResult {
    match op {
        LinearOp::FactoredQlr { base, l, r } => QerResult {
            qdeq: base.densify(),
            packed: match base {
                QuantBase::Packed(p) => Some(p.clone()),
                QuantBase::Dense(_) => None,
            },
            l: l.clone(),
            r: r.clone(),
            k_star: meta.k_star,
            selection: meta.selection.clone(),
        },
        LinearOp::Dense(w) => QerResult {
            qdeq: w.clone(),
            packed: None,
            l: Mat::zeros(w.rows, 0),
            r: Mat::zeros(0, w.cols),
            k_star: meta.k_star,
            selection: meta.selection.clone(),
        },
    }
}

/// Run the PTQ pipeline over every linear of `params`, producing the
/// factored serving outcome: per layer a packed quantized base plus the
/// (L, R) correction — `W_hat` is only formed transiently for the error
/// reports, never stored.
///
/// Jobs run on the shared worker pool (`SRR_THREADS` to override); the
/// per-stage timings are accumulated into `metrics` under
/// `ptq.scale_secs` / `ptq.qer_secs` (Table 11's stage split).
pub fn run_ptq_factored(
    params: &Params,
    model_cfg: &ModelCfg,
    calib: &CalibrationSet,
    quantizer: QuantizerSpec,
    qer_cfg: &QerConfig,
    metrics: &Metrics,
) -> FactoredOutcome {
    let names = Params::linear_names(model_cfg);
    let outputs: Mutex<Vec<Option<(QerResult, LayerReport)>>> =
        Mutex::new((0..names.len()).map(|_| None).collect());

    pool::par_for(names.len(), |i| {
        let name = &names[i];
        let w = params.get_mat(name).expect("linear present");

        let t0 = Instant::now();
        let scaling: Scaling = calib.scaling_for(name, qer_cfg.scaling_kind);
        let ctx: QuantCtx =
            calib.quant_ctx(name, quantizer.needs_hessian(), qer_cfg.seed ^ layer_salt(name));
        let scale_secs = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let q = quantizer.build();
        let mut cfg = qer_cfg.clone();
        cfg.seed = qer_cfg.seed ^ layer_salt(name);
        let res = reconstruct(&w, q.as_ref(), &scaling, &ctx, &cfg);
        let qer_secs = t1.elapsed().as_secs_f64();

        let what = res.reconstruct();
        let report = LayerReport {
            name: name.clone(),
            k_star: res.k_star,
            weight_err: w.sub(&what).frob(),
            scaled_err: scaling.apply(&w.sub(&what)).frob(),
            scale_secs,
            qer_secs,
        };
        outputs.lock().unwrap()[i] = Some((res, report));
    });

    let mut skeleton = params.clone();
    let mut ops = Vec::with_capacity(names.len());
    let mut meta = Vec::with_capacity(names.len());
    let mut reports = Vec::with_capacity(names.len());
    for (i, slot) in outputs.into_inner().unwrap().into_iter().enumerate() {
        let (res, report) = slot.expect("job completed");
        metrics.add("ptq.scale_secs", report.scale_secs);
        metrics.add("ptq.qer_secs", report.qer_secs);
        metrics.incr("ptq.layers");
        skeleton.unset(&names[i]);
        meta.push(LayerMeta {
            name: names[i].clone(),
            k_star: res.k_star,
            selection: res.selection.clone(),
        });
        ops.push((names[i].clone(), res.into_factored()));
        reports.push(report);
    }

    FactoredOutcome { model: FactoredModel { skeleton, ops }, meta, reports }
}

/// Dense compatibility wrapper around [`run_ptq_factored`].
pub fn run_ptq(
    params: &Params,
    model_cfg: &ModelCfg,
    calib: &CalibrationSet,
    quantizer: QuantizerSpec,
    qer_cfg: &QerConfig,
    metrics: &Metrics,
) -> PtqOutcome {
    run_ptq_factored(params, model_cfg, calib, quantizer, qer_cfg, metrics).to_dense()
}

/// FNV-1a mix of the layer name into the run seed, so each layer draws
/// an independent probe/SVD stream. Shared with the sweep engine — the
/// bit-identity contract between `run_ptq` and `SweepRunner` depends on
/// both deriving per-layer seeds identically.
pub(crate) fn layer_salt(s: &str) -> u64 {
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Corpus;
    use crate::model::{collect_calibration, synth::synth_lm_params};
    use crate::qer::Method;
    use crate::scaling::ScalingKind;

    fn setup() -> (Params, ModelCfg, CalibrationSet) {
        // stay in the paper's regime: rank budget a few % of min dim
        // (r=4..8 on d=64; the paper uses 32..64 on 4096)
        let cfg = ModelCfg {
            name: "t".into(),
            vocab: 64,
            d_model: 64,
            n_heads: 2,
            n_layers: 2,
            d_ff: 128,
            seq_len: 16,
        };
        let params = synth_lm_params(&cfg, 5, cfg.vocab);
        let corpus = Corpus::generate(cfg.vocab, 4000, 6);
        // enough calibration rows to keep the exact-scaling Gram full rank
        let batches: Vec<Vec<i32>> = (0..10).map(|i| corpus.train_batch(2, 16, i)).collect();
        let calib = collect_calibration(&params, &cfg, &batches, 2, 16, 192);
        (params, cfg, calib)
    }

    #[test]
    fn reconstructs_every_linear_and_reports() {
        let (params, cfg, calib) = setup();
        let metrics = Metrics::new();
        let out = run_ptq(
            &params,
            &cfg,
            &calib,
            QuantizerSpec::Mxint { bits: 3, block: 32 },
            &QerConfig::new(Method::QerSrr, 8, ScalingKind::DiagRms),
            &metrics,
        );
        assert_eq!(out.reports.len(), 14);
        assert_eq!(out.results.len(), 14);
        assert_eq!(metrics.get("ptq.layers"), 14.0);
        assert!(metrics.get("ptq.qer_secs") > 0.0);
        // every linear was actually replaced
        for (name, _) in &out.results {
            let orig = params.get_mat(name).unwrap();
            let new = out.params.get_mat(name).unwrap();
            assert_ne!(orig, new, "{name} unchanged");
        }
        // non-linear params untouched
        assert_eq!(
            params.get_mat("embed").unwrap(),
            out.params.get_mat("embed").unwrap()
        );
    }

    #[test]
    fn factored_outcome_matches_dense_and_is_smaller() {
        let (params, cfg, calib) = setup();
        let metrics = Metrics::new();
        let spec = QuantizerSpec::Mxint { bits: 3, block: 32 };
        let qcfg = QerConfig::new(Method::QerSrr, 8, ScalingKind::DiagRms);
        let fo = run_ptq_factored(&params, &cfg, &calib, spec, &qcfg, &metrics);
        assert_eq!(fo.model.ops.len(), 14);
        assert_eq!(fo.meta.len(), 14);
        // packed bases + adapters are a real memory win over dense W_hat
        assert!(
            fo.model.linear_bytes() * 2 < fo.model.dense_linear_bytes(),
            "factored {} vs dense {}",
            fo.model.linear_bytes(),
            fo.model.dense_linear_bytes()
        );
        // the skeleton dropped the dense linears but kept everything else
        assert!(fo.model.skeleton.get("l0.wq").is_err());
        assert!(fo.model.skeleton.get("embed").is_ok());
        // densify reproduces the dense compatibility path bit-for-bit
        let dense = run_ptq(&params, &cfg, &calib, spec, &qcfg, &metrics);
        let densified = fo.model.densified_params();
        for name in Params::linear_names(&cfg) {
            assert_eq!(
                densified.get_mat(&name).unwrap(),
                dense.params.get_mat(&name).unwrap(),
                "{name} diverges"
            );
        }
        // to_dense round-trips results with their packed bases attached
        let via = fo.to_dense();
        for ((n1, r1), (n2, r2)) in via.results.iter().zip(&dense.results) {
            assert_eq!(n1, n2);
            assert_eq!(r1.qdeq, r2.qdeq);
            assert_eq!(r1.l, r2.l);
            assert_eq!(r1.k_star, r2.k_star);
            assert!(r1.packed.is_some(), "{n1}: mxint base should stay packed");
        }
    }

    #[test]
    fn srr_beats_or_matches_qer_in_scaled_error() {
        let (params, cfg, calib) = setup();
        let metrics = Metrics::new();
        let spec = QuantizerSpec::Mxint { bits: 2, block: 32 };
        let qer = run_ptq(
            &params, &cfg, &calib, spec,
            &QerConfig::new(Method::Qer, 4, ScalingKind::Exact), &metrics,
        );
        let srr = run_ptq(
            &params, &cfg, &calib, spec,
            &QerConfig::new(Method::QerSrr, 4, ScalingKind::Exact), &metrics,
        );
        let sum = |o: &PtqOutcome| o.reports.iter().map(|r| r.scaled_err.powi(2)).sum::<f64>();
        assert!(
            sum(&srr) <= sum(&qer) * 1.02,
            "srr {} vs qer {}",
            sum(&srr),
            sum(&qer)
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let (params, cfg, calib) = setup();
        let metrics = Metrics::new();
        let spec = QuantizerSpec::Mxint { bits: 3, block: 32 };
        let cfgq = QerConfig::new(Method::QerSrr, 8, ScalingKind::DiagRms);
        let a = run_ptq(&params, &cfg, &calib, spec, &cfgq, &metrics);
        let b = run_ptq(&params, &cfg, &calib, spec, &cfgq, &metrics);
        for ((n1, r1), (n2, r2)) in a.results.iter().zip(&b.results) {
            assert_eq!(n1, n2);
            assert_eq!(r1.qdeq, r2.qdeq, "{n1} qdeq differs across runs");
            assert_eq!(r1.k_star, r2.k_star);
        }
    }

    #[test]
    fn quantizer_specs_build_and_label() {
        for spec in [
            QuantizerSpec::Mxint { bits: 3, block: 32 },
            QuantizerSpec::Uniform { bits: 4, group: 64, symmetric: true },
            QuantizerSpec::Gptq { bits: 3, group: 128 },
            QuantizerSpec::QuipSharp { bits: 2 },
        ] {
            assert!(!spec.label().is_empty());
            assert!(spec.effective_bits() > 1.0);
        }
        assert!(QuantizerSpec::Gptq { bits: 3, group: 128 }.needs_hessian());
        assert!(!QuantizerSpec::Mxint { bits: 3, block: 32 }.needs_hessian());
    }
}
