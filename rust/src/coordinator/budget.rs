//! Model-wide rank/bit budget allocation: "best PPL at N gigabytes".
//!
//! The paper selects the preserved rank k per layer at a *fixed*
//! (bits, rank) setting (Eq. 5). This module turns the same phase-A
//! sensitivity signals — the prepared (S·W, S·E) spectra
//! ([`PreparedSpectra`](crate::qer::PreparedSpectra)) and the
//! quantization-exposed energy η_Q
//! ([`eta_q_from`](crate::qer::eta_q_from)) — into a *cross-layer*
//! allocator: given a total byte budget, assign each linear its own
//! `(bits, rank, k)` so the predicted reconstruction error is minimized
//! subject to the model fitting the budget.
//!
//! **Predicted-error model.** For layer ℓ with scaled weight energy
//! ‖S·W_ℓ‖²_F, quantizing at `b` bits with rank budget `r` and the
//! Eq.-5 split k = k*(r):
//!
//! ```text
//!   err²(ℓ, b, r) ≈ η_b(ℓ)² · ‖S·W_ℓ‖²_F · min_k ρ_k(SW_ℓ)·ρ_{r−k}(SE_ℓ)
//! ```
//!
//! i.e. the surrogate objective the paper minimizes over k, rescaled to
//! absolute units by the layer's exposed energy at `b` bits. η_b is
//! measured on the cached k=0 quantization (Assumption 4.1: η is
//! approximately invariant to the preserve split, so measuring it on W
//! stands in for measuring it on every candidate residual). Bytes are
//! modeled as packed base + f32 adapters:
//!
//! ```text
//!   bytes(ℓ, b, r) = ⌈m·n·effective_bits(b)/8⌉ + 4·r·(m+n)
//! ```
//!
//! **Allocation.** Three passes over the per-layer candidate tables,
//! all deterministic (pure f64 arithmetic, fixed iteration counts, no
//! RNG):
//!
//! 1. *greedy marginal-utility descent* — start every layer at its
//!    cheapest candidate and repeatedly apply the single-layer upgrade
//!    with the best Δerr²/Δbytes that still fits;
//! 2. *Lagrangian water-filling refinement* — bisect the price λ and
//!    assign each layer argmin err² + λ·bytes, keeping the smallest
//!    feasible λ;
//! 3. *uniform-floor upgrades* — start from the best uniform cell
//!    fitting the budget ([`uniform_plan`]'s choice) and apply only
//!    dominating upgrades (never fewer bits, never less rank, strictly
//!    lower predicted err²), so this candidate is layer-wise no worse
//!    than the uniform baseline.
//!
//! The best feasible plan (by predicted err², pass 3 → 1 → 2 on ties)
//! wins — in particular the allocator's predicted error never exceeds
//! the best uniform baseline's. Degenerate budgets — smaller than the
//! cheapest feasible model, or at least fp32 dense size — are errors,
//! not panics.
//!
//! **Bit-identity.** A [`BudgetPlan`] is a pure deterministic function
//! of the phase-A [`LayerCache`], and the sharded prep
//! ([`ShardedSweepRunner`]) rebuilds that cache bit-identically to the
//! in-process [`SweepRunner::prepare`] — so in-process and sharded
//! planning produce byte-equal plans (property-tested under seeded
//! fault schedules; `exp::perf::budget_bench` gates it in CI via
//! `BENCH_budget.json`'s `allocation_bit_identical`). The plan travels
//! as a wire-codec frame
//! ([`encode_budget_plan`](super::wire::encode_budget_plan)).
//!
//! **Execution.** [`BudgetPlan::sweep_config`] lowers the plan onto the
//! sweep engine as one heterogeneous cell
//! ([`SweepConfig::with_per_layer`]); the plan's `prep_rank` pins the
//! grid's shared-spectra rank to the planner's, which is what makes the
//! planned per-layer `k` equal the realized `k*` (same factorization,
//! same argmin).

use anyhow::{ensure, Result};

use crate::qer::{eta_q_from, Method};
use crate::scaling::ScalingKind;

use super::cache::LayerCache;
use super::pipeline::QuantizerSpec;
use super::shard::{ShardSession, ShardedSweepRunner};
use super::sweep::{LayerAssign, SweepConfig, SweepRunner};

/// Fixed bisection depth of the water-filling pass (deterministic; 64
/// halvings take the λ bracket below any f64-meaningful width).
const WATERFILL_ITERS: usize = 64;

/// What to allocate: the budget and the per-layer candidate space.
#[derive(Clone, Debug, PartialEq)]
pub struct BudgetSpec {
    /// total model-byte budget for all quantized linears (packed bases
    /// + f32 adapters, per the module's byte model)
    pub budget_bytes: u64,
    /// MXINT bit-width choices, e.g. `[2, 3, 4]`
    pub bits_choices: Vec<u32>,
    /// MXINT block size shared by every candidate
    pub block: usize,
    /// rank choices, e.g. `[0, 4, 8, 16, 32]`; the maximum is the
    /// planning prep rank every shared spectrum is factorized at
    pub rank_choices: Vec<usize>,
    /// activation scaling kind (one, shared by every candidate)
    pub scaling: ScalingKind,
    /// sweep-level seed (layer-salted per linear, as everywhere)
    pub seed: u64,
}

impl BudgetSpec {
    /// A spec with the default candidate space: bits {2, 3, 4} ×
    /// ranks {0, 4, 8, 16, 32}, MXINT block 32, diag-rms scaling.
    pub fn new(budget_bytes: u64) -> Self {
        BudgetSpec {
            budget_bytes,
            bits_choices: vec![2, 3, 4],
            block: 32,
            rank_choices: vec![0, 4, 8, 16, 32],
            scaling: ScalingKind::DiagRms,
            seed: 0,
        }
    }

    /// [`BudgetSpec::new`] from a gigabyte figure (decimal GB).
    pub fn gigabytes(g: f64) -> Self {
        Self::new((g * 1e9) as u64)
    }

    /// The planning prep rank: the largest rank any candidate uses.
    pub fn prep_rank(&self) -> usize {
        self.rank_choices.iter().copied().max().unwrap_or(0)
    }

    /// The candidate quantizer at `bits`.
    pub fn quantizer(&self, bits: u32) -> QuantizerSpec {
        QuantizerSpec::Mxint { bits, block: self.block }
    }

    /// The probe grid whose phase-A prep computes every sensitivity the
    /// planner reads: one w-only cell per bit-width (caches the k=0
    /// quantization η_b is measured on) plus one SRR cell at the max
    /// candidate rank (caches the (S·W, S·E) spectra at the planning
    /// prep rank).
    pub fn probe_configs(&self) -> Vec<SweepConfig> {
        let mut probes: Vec<SweepConfig> = self
            .bits_choices
            .iter()
            .map(|&b| {
                SweepConfig::new(self.quantizer(b), Method::WOnly, 0, self.scaling)
                    .seeded(self.seed)
            })
            .collect();
        let bits = self.bits_choices.last().copied().unwrap_or(4);
        probes.push(
            SweepConfig::new(self.quantizer(bits), Method::QerSrr, self.prep_rank(), self.scaling)
                .seeded(self.seed),
        );
        probes
    }

    fn validate(&self) -> Result<()> {
        ensure!(!self.bits_choices.is_empty(), "budget spec has no bit-width choices");
        ensure!(!self.rank_choices.is_empty(), "budget spec has no rank choices");
        ensure!(self.block > 0, "budget spec block size must be positive");
        Ok(())
    }
}

/// One layer's sensitivity profile: everything the predicted-error
/// model needs, extracted from the phase-A cache. Pure data — the
/// allocator below never touches matrices, so allocation over profiles
/// is trivially deterministic and unit-testable without a model.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerProfile {
    /// the linear's parameter name
    pub name: String,
    /// weight rows m
    pub rows: usize,
    /// weight cols n
    pub cols: usize,
    /// ‖S·W‖²_F — the energy scale of the error model
    pub sw_frob2: f64,
    /// per rank choice (aligned with `BudgetSpec::rank_choices`): the
    /// Eq.-5 split k*(r) and its surrogate value
    /// min_k ρ_k(SW)·ρ_{r−k}(SE), read off the prepared spectra
    pub selections: Vec<(usize, f64)>,
    /// per bit-width choice (aligned with `BudgetSpec::bits_choices`):
    /// η_b measured on the cached k=0 quantization
    pub eta: Vec<f64>,
}

impl LayerProfile {
    /// Predicted squared scaled error at candidate `(bits index, rank
    /// index)` — the module-level error model.
    pub fn err2(&self, bi: usize, ri: usize) -> f64 {
        self.eta[bi] * self.eta[bi] * self.sw_frob2 * self.selections[ri].1
    }

    /// Modeled serving bytes at candidate `(bits index, rank index)`
    /// under `spec`: packed base + f32 adapters.
    pub fn bytes(&self, spec: &BudgetSpec, bi: usize, ri: usize) -> u64 {
        let eff = spec.quantizer(spec.bits_choices[bi]).effective_bits();
        let base = ((self.rows * self.cols) as f64 * eff / 8.0).ceil() as u64;
        let adapters = 4 * spec.rank_choices[ri] as u64 * (self.rows + self.cols) as u64;
        base + adapters
    }
}

/// One layer's allocated cell.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerAlloc {
    /// the linear's parameter name
    pub name: String,
    /// allocated MXINT bit-width
    pub bits: u32,
    /// allocated rank budget r
    pub rank: usize,
    /// the Eq.-5 split the planner predicts (and, because the planned
    /// run shares the planner's spectra, the run realizes)
    pub k: usize,
    /// modeled bytes of this layer at the allocated cell
    pub bytes: u64,
    /// predicted squared scaled error at the allocated cell
    pub predicted_err2: f64,
}

/// A model-wide allocation: the artifact `srr budget` emits, the wire
/// codec frames, and [`BudgetPlan::sweep_config`] executes.
#[derive(Clone, Debug, PartialEq)]
pub struct BudgetPlan {
    /// per-layer assignments, in `Params::linear_names` order
    pub layers: Vec<LayerAlloc>,
    /// the budget that was asked for
    pub budget_bytes: u64,
    /// modeled bytes of the plan (≤ `budget_bytes` always)
    pub plan_bytes: u64,
    /// Σ per-layer predicted err² (the allocator's objective)
    pub predicted_err2: f64,
    /// rank the planning spectra were factorized at; pins the executed
    /// grid's prep rank so planned k == realized k*
    pub prep_rank: usize,
    /// MXINT block size of every allocated quantizer
    pub block: usize,
    /// activation scaling kind of every layer
    pub scaling: ScalingKind,
    /// sweep-level seed of the planned run
    pub seed: u64,
}

impl BudgetPlan {
    /// Lower the plan onto the sweep engine: one heterogeneous SRR cell
    /// whose top-level rank pins the grid prep rank at the planner's
    /// ([`SweepConfig::max_rank`] treats it as a floor), so the executed
    /// per-layer k* is exactly the planned `k`.
    pub fn sweep_config(&self) -> SweepConfig {
        let assigns: Vec<LayerAssign> = self
            .layers
            .iter()
            .map(|l| LayerAssign {
                quantizer: QuantizerSpec::Mxint { bits: l.bits, block: self.block },
                rank: l.rank,
            })
            .collect();
        let bits = self.layers.first().map(|l| l.bits).unwrap_or(4);
        SweepConfig::new(
            QuantizerSpec::Mxint { bits, block: self.block },
            Method::QerSrr,
            self.prep_rank,
            self.scaling,
        )
        .seeded(self.seed)
        .labeled(&format!("budget/{}B", self.budget_bytes))
        .with_per_layer(assigns)
    }
}

/// Extract every layer's sensitivity profile from a phase-A cache
/// prepared over [`BudgetSpec::probe_configs`]. Shared by the
/// in-process and sharded planners — the cache is bit-identical between
/// them, and this is a pure read, so the plans are too.
pub(crate) fn profiles_from_cache(cache: &LayerCache, spec: &BudgetSpec) -> Vec<LayerProfile> {
    cache
        .layers
        .iter()
        .map(|layer| {
            let scaling = layer.scaling(spec.scaling);
            let sp = layer
                .spectra(spec.scaling, spec.seed)
                .expect("spectra prepared by the probe grid");
            let selections = spec
                .rank_choices
                .iter()
                .map(|&r| {
                    let sel = sp.select(r);
                    (sel.k_star, sel.objective[sel.k_star])
                })
                .collect();
            let eta = spec
                .bits_choices
                .iter()
                .map(|&b| {
                    let label = spec.quantizer(b).label();
                    let qdeq = layer
                        .qdeq0(&label, spec.seed)
                        .expect("qdeq0 prepared by the probe grid");
                    eta_q_from(&layer.w, qdeq, scaling)
                })
                .collect();
            LayerProfile {
                name: layer.name.clone(),
                rows: layer.w.rows,
                cols: layer.w.cols,
                sw_frob2: sp.sw_frob2,
                selections,
                eta,
            }
        })
        .collect()
}

/// Internal candidate tables: `bytes[li][ci]` / `err2[li][ci]` with
/// `ci = bits index · |ranks| + rank index`.
struct Tables {
    n_cand: usize,
    bytes: Vec<Vec<u64>>,
    err2: Vec<Vec<f64>>,
}

impl Tables {
    fn build(profiles: &[LayerProfile], spec: &BudgetSpec) -> Tables {
        let n_ranks = spec.rank_choices.len();
        let n_cand = spec.bits_choices.len() * n_ranks;
        let mut bytes = Vec::with_capacity(profiles.len());
        let mut err2 = Vec::with_capacity(profiles.len());
        for p in profiles {
            let mut b = Vec::with_capacity(n_cand);
            let mut e = Vec::with_capacity(n_cand);
            for ci in 0..n_cand {
                b.push(p.bytes(spec, ci / n_ranks, ci % n_ranks));
                e.push(p.err2(ci / n_ranks, ci % n_ranks));
            }
            bytes.push(b);
            err2.push(e);
        }
        Tables { n_cand, bytes, err2 }
    }

    fn total_bytes(&self, chosen: &[usize]) -> u64 {
        chosen.iter().enumerate().map(|(li, &ci)| self.bytes[li][ci]).sum()
    }

    fn total_err2(&self, chosen: &[usize]) -> f64 {
        chosen.iter().enumerate().map(|(li, &ci)| self.err2[li][ci]).sum()
    }

    /// Per-layer argmin of err² + λ·bytes (first candidate wins ties —
    /// the water-filling assignment at price λ).
    fn assign_at(&self, lambda: f64) -> Vec<usize> {
        self.err2
            .iter()
            .zip(&self.bytes)
            .map(|(e, b)| {
                let mut best = (f64::INFINITY, 0usize);
                for ci in 0..self.n_cand {
                    let cost = e[ci] + lambda * b[ci] as f64;
                    if cost < best.0 {
                        best = (cost, ci);
                    }
                }
                best.1
            })
            .collect()
    }
}

/// The lowest-total-err² candidate column every layer can share within
/// the budget, if any (ties: first candidate).
fn best_uniform_ci(t: &Tables, n_layers: usize, budget_bytes: u64) -> Option<usize> {
    let mut best: Option<(f64, usize)> = None;
    for ci in 0..t.n_cand {
        let bytes: u64 = (0..n_layers).map(|li| t.bytes[li][ci]).sum();
        if bytes > budget_bytes {
            continue;
        }
        let err: f64 = (0..n_layers).map(|li| t.err2[li][ci]).sum();
        let better = match best {
            None => true,
            Some((e, _)) => err < e,
        };
        if better {
            best = Some((err, ci));
        }
    }
    best.map(|(_, ci)| ci)
}

/// Materialise a candidate assignment (one column index per layer) as a
/// [`BudgetPlan`].
fn build_plan(
    profiles: &[LayerProfile],
    spec: &BudgetSpec,
    t: &Tables,
    chosen: &[usize],
) -> BudgetPlan {
    let n_ranks = spec.rank_choices.len();
    let layers: Vec<LayerAlloc> = chosen
        .iter()
        .zip(profiles)
        .map(|(&ci, p)| LayerAlloc {
            name: p.name.clone(),
            bits: spec.bits_choices[ci / n_ranks],
            rank: spec.rank_choices[ci % n_ranks],
            k: p.selections[ci % n_ranks].0,
            bytes: p.bytes(spec, ci / n_ranks, ci % n_ranks),
            predicted_err2: p.err2(ci / n_ranks, ci % n_ranks),
        })
        .collect();
    BudgetPlan {
        plan_bytes: t.total_bytes(chosen),
        predicted_err2: t.total_err2(chosen),
        layers,
        budget_bytes: spec.budget_bytes,
        prep_rank: spec.prep_rank(),
        block: spec.block,
        scaling: spec.scaling,
        seed: spec.seed,
    }
}

/// Allocate `spec.budget_bytes` across `profiles` (see module docs for
/// the error model and the three allocation passes). Errors on
/// degenerate budgets: too small for any assignment, or no smaller than
/// fp32 dense.
pub fn allocate(profiles: &[LayerProfile], spec: &BudgetSpec) -> Result<BudgetPlan> {
    spec.validate()?;
    ensure!(!profiles.is_empty(), "no quantizable layers to allocate");
    let t = Tables::build(profiles, spec);
    let n_ranks = spec.rank_choices.len();

    let dense_bytes: u64 = profiles.iter().map(|p| 4 * (p.rows * p.cols) as u64).sum();
    ensure!(
        spec.budget_bytes < dense_bytes,
        "budget of {} bytes is no smaller than the fp32 dense model ({} bytes) — \
         nothing to allocate",
        spec.budget_bytes,
        dense_bytes
    );

    // start at each layer's cheapest candidate (ties: lower err², then
    // candidate order)
    let cheapest: Vec<usize> = (0..profiles.len())
        .map(|li| {
            let mut best = 0usize;
            for ci in 1..t.n_cand {
                let better = t.bytes[li][ci] < t.bytes[li][best]
                    || (t.bytes[li][ci] == t.bytes[li][best]
                        && t.err2[li][ci] < t.err2[li][best]);
                if better {
                    best = ci;
                }
            }
            best
        })
        .collect();
    let min_bytes = t.total_bytes(&cheapest);
    ensure!(
        min_bytes <= spec.budget_bytes,
        "budget of {} bytes is too small: the cheapest feasible plan needs {} bytes",
        spec.budget_bytes,
        min_bytes
    );

    // ---- pass 1: greedy marginal-utility descent ----------------------
    let mut greedy = cheapest.clone();
    let mut spent = min_bytes;
    loop {
        let mut best: Option<(f64, usize, usize)> = None; // (Δerr²/Δbytes, li, ci)
        for li in 0..profiles.len() {
            let (cur_b, cur_e) = (t.bytes[li][greedy[li]], t.err2[li][greedy[li]]);
            for ci in 0..t.n_cand {
                if t.bytes[li][ci] <= cur_b || t.err2[li][ci] >= cur_e {
                    continue;
                }
                let extra = t.bytes[li][ci] - cur_b;
                if spent + extra > spec.budget_bytes {
                    continue;
                }
                let utility = (cur_e - t.err2[li][ci]) / extra as f64;
                let better = match best {
                    None => true,
                    Some((u, _, _)) => utility > u,
                };
                if better {
                    best = Some((utility, li, ci));
                }
            }
        }
        let Some((_, li, ci)) = best else { break };
        spent += t.bytes[li][ci] - t.bytes[li][greedy[li]];
        greedy[li] = ci;
    }

    // ---- pass 2: Lagrangian water-filling refinement ------------------
    // smallest price λ whose assignment fits: λ=0 is the unconstrained
    // minimum-error plan; if even that fits, we're done. Otherwise
    // double λ until feasible, then bisect.
    let refined = {
        let zero = t.assign_at(0.0);
        if t.total_bytes(&zero) <= spec.budget_bytes {
            zero
        } else {
            let mut hi = 1.0f64;
            let mut doublings = 0;
            while t.total_bytes(&t.assign_at(hi)) > spec.budget_bytes && doublings < 200 {
                hi *= 2.0;
                doublings += 1;
            }
            let mut lo = 0.0f64;
            if t.total_bytes(&t.assign_at(hi)) > spec.budget_bytes {
                // pathological scales: fall back to the known-feasible floor
                greedy.clone()
            } else {
                for _ in 0..WATERFILL_ITERS {
                    let mid = 0.5 * (lo + hi);
                    if t.total_bytes(&t.assign_at(mid)) <= spec.budget_bytes {
                        hi = mid;
                    } else {
                        lo = mid;
                    }
                }
                t.assign_at(hi)
            }
        }
    };

    // ---- pass 3: uniform-floor upgrades -------------------------------
    // grow from the best uniform cell fitting the budget with
    // *dominating* moves only — never fewer bits, never less rank,
    // strictly lower predicted err² — so this candidate is layer-wise
    // no worse than the uniform baseline it started from.
    let floor = best_uniform_ci(&t, profiles.len(), spec.budget_bytes).map(|ci| {
        let mut plan = vec![ci; profiles.len()];
        let mut spent = t.total_bytes(&plan);
        loop {
            let mut best: Option<(f64, usize, usize)> = None;
            for li in 0..profiles.len() {
                let cur = plan[li];
                let (cur_bits, cur_rank) =
                    (spec.bits_choices[cur / n_ranks], spec.rank_choices[cur % n_ranks]);
                for cj in 0..t.n_cand {
                    let dominates = spec.bits_choices[cj / n_ranks] >= cur_bits
                        && spec.rank_choices[cj % n_ranks] >= cur_rank
                        && t.err2[li][cj] < t.err2[li][cur]
                        && t.bytes[li][cj] > t.bytes[li][cur];
                    if !dominates {
                        continue;
                    }
                    let extra = t.bytes[li][cj] - t.bytes[li][cur];
                    if spent + extra > spec.budget_bytes {
                        continue;
                    }
                    let utility = (t.err2[li][cur] - t.err2[li][cj]) / extra as f64;
                    let better = match best {
                        None => true,
                        Some((u, _, _)) => utility > u,
                    };
                    if better {
                        best = Some((utility, li, cj));
                    }
                }
            }
            let Some((_, li, cj)) = best else { break };
            spent += t.bytes[li][cj] - t.bytes[li][plan[li]];
            plan[li] = cj;
        }
        plan
    });

    // best feasible candidate by predicted err² (ties: floor → greedy →
    // refined, so the layer-wise-dominating plan wins when equal)
    let mut chosen = match floor {
        Some(f) => f,
        None => greedy.clone(),
    };
    for cand in [&greedy, &refined] {
        if t.total_err2(cand) < t.total_err2(&chosen) {
            chosen = cand.clone();
        }
    }

    Ok(build_plan(profiles, spec, &t, &chosen))
}

/// The best *uniform* `(bits, rank)` baseline fitting the budget: the
/// lowest-predicted-error cell every layer can share — what the
/// headline bench compares the allocator against at equal bytes.
pub fn uniform_plan(profiles: &[LayerProfile], spec: &BudgetSpec) -> Result<BudgetPlan> {
    spec.validate()?;
    ensure!(!profiles.is_empty(), "no quantizable layers to allocate");
    let t = Tables::build(profiles, spec);
    let Some(ci) = best_uniform_ci(&t, profiles.len(), spec.budget_bytes) else {
        anyhow::bail!(
            "budget of {} bytes fits no uniform (bits, rank) cell",
            spec.budget_bytes
        )
    };
    let chosen = vec![ci; profiles.len()];
    Ok(build_plan(profiles, spec, &t, &chosen))
}

impl<'a> SweepRunner<'a> {
    /// Phase-A probe prep + profile extraction: every sensitivity the
    /// allocator reads, in one shared-work pass.
    pub fn budget_profiles(&self, spec: &BudgetSpec) -> Result<Vec<LayerProfile>> {
        spec.validate()?;
        let prep = self.prepare(&spec.probe_configs());
        Ok(profiles_from_cache(&prep.cache, spec))
    }

    /// Plan a model-wide budget in-process: probe prep → profiles →
    /// [`allocate`].
    pub fn plan_budget(&self, spec: &BudgetSpec) -> Result<BudgetPlan> {
        allocate(&self.budget_profiles(spec)?, spec)
    }
}

impl<'a> ShardedSweepRunner<'a> {
    /// [`SweepRunner::budget_profiles`] with the probe prep sharded
    /// across `session`'s workers. The rebuilt cache is bit-identical
    /// to the in-process one, so the profiles are too.
    pub fn budget_profiles(
        &self,
        session: &mut ShardSession,
        spec: &BudgetSpec,
    ) -> Result<Vec<LayerProfile>> {
        spec.validate()?;
        let prep = self.prepare(session, &spec.probe_configs())?;
        Ok(profiles_from_cache(&prep.cache, spec))
    }

    /// [`SweepRunner::plan_budget`] with the probe prep sharded across
    /// `session`'s workers — bit-identical plans (module docs).
    pub fn plan_budget(
        &self,
        session: &mut ShardSession,
        spec: &BudgetSpec,
    ) -> Result<BudgetPlan> {
        allocate(&self.budget_profiles(session, spec)?, spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::Metrics;
    use crate::coordinator::wire;
    use crate::data::Corpus;
    use crate::model::synth::synth_lm_params;
    use crate::model::{collect_calibration, CalibrationSet, Params};
    use crate::runtime::manifest::ModelCfg;

    /// Synthetic profiles with a strictly convex err-vs-bytes frontier
    /// per layer: err² halves with every extra bit and drops
    /// power-law with rank — smooth enough that both allocator passes
    /// agree with the convexified optimum.
    fn synth_profiles(n: usize, distinct: bool) -> (Vec<LayerProfile>, BudgetSpec) {
        let spec = BudgetSpec {
            budget_bytes: 0, // callers set per test
            bits_choices: vec![2, 3, 4],
            block: 32,
            rank_choices: vec![0, 4, 8, 16],
            scaling: ScalingKind::Identity,
            seed: 0,
        };
        let profiles = (0..n)
            .map(|i| {
                // layer sensitivity varies only when `distinct`
                let boost = if distinct { 1.0 + i as f64 } else { 1.0 };
                let selections = spec
                    .rank_choices
                    .iter()
                    .map(|&r| (r / 2, 1.0 / (1.0 + r as f64).powf(1.5)))
                    .collect();
                let eta = spec
                    .bits_choices
                    .iter()
                    .map(|&b| boost * 0.8 / f64::powi(2.0, b as i32))
                    .collect();
                LayerProfile {
                    name: format!("l{i}.w"),
                    rows: 64,
                    cols: 64,
                    sw_frob2: 100.0,
                    selections,
                    eta,
                }
            })
            .collect();
        (profiles, spec)
    }

    #[test]
    fn identical_sensitivities_get_uniform_allocation() {
        let (profiles, mut spec) = synth_profiles(4, false);
        // A budget exactly accommodating the best uniform cell at that
        // level, with zero slack left over. (At a budget with slack the
        // allocator rightly spends the remainder on partial upgrades —
        // identical sensitivities make the uniform plan optimal only
        // when no single upgrade fits.)
        let per_layer = profiles[0].bytes(&spec, 2, 1); // 4 bits, rank 4
        spec.budget_bytes = 4 * per_layer;
        let plan = allocate(&profiles, &spec).unwrap();
        for l in &plan.layers {
            assert_eq!((l.bits, l.rank), (plan.layers[0].bits, plan.layers[0].rank));
        }
        assert!(plan.plan_bytes <= spec.budget_bytes);
        // and it uses the budget fully: the uniform cell at that level
        assert_eq!(plan.plan_bytes, spec.budget_bytes);
    }

    #[test]
    fn distinct_sensitivities_get_nonuniform_allocation() {
        let (profiles, mut spec) = synth_profiles(4, true);
        let per_layer = profiles[0].bytes(&spec, 1, 1);
        spec.budget_bytes = 4 * per_layer;
        let plan = allocate(&profiles, &spec).unwrap();
        let first = (plan.layers[0].bits, plan.layers[0].rank);
        assert!(
            plan.layers.iter().any(|l| (l.bits, l.rank) != first),
            "layers with 4× different η should not share a cell: {:?}",
            plan.layers
        );
        // the most sensitive layer (largest η boost) gets at least as
        // many bits as the least sensitive one
        assert!(plan.layers[3].bits >= plan.layers[0].bits);
    }

    #[test]
    fn larger_budget_never_predicts_worse_and_always_fits() {
        let (profiles, mut spec) = synth_profiles(5, true);
        let lo = {
            spec.budget_bytes = u64::MAX;
            let cheapest: u64 = profiles.iter().map(|p| p.bytes(&spec, 0, 0)).sum();
            cheapest
        };
        let hi: u64 = profiles.iter().map(|p| p.bytes(&spec, 2, 3)).sum();
        let mut last_err = f64::INFINITY;
        let steps = 12u64;
        for s in 0..=steps {
            spec.budget_bytes = lo + (hi - lo) * s / steps;
            let plan = allocate(&profiles, &spec).unwrap();
            assert!(
                plan.plan_bytes <= spec.budget_bytes,
                "plan {} bytes over budget {}",
                plan.plan_bytes,
                spec.budget_bytes
            );
            assert!(
                plan.predicted_err2 <= last_err * (1.0 + 1e-12),
                "err² rose from {last_err} to {} at budget {}",
                plan.predicted_err2,
                spec.budget_bytes
            );
            last_err = plan.predicted_err2;
        }
    }

    #[test]
    fn degenerate_budgets_error_instead_of_panicking() {
        let (profiles, mut spec) = synth_profiles(3, true);
        // too small for even the cheapest assignment
        spec.budget_bytes = 1;
        let err = allocate(&profiles, &spec).unwrap_err().to_string();
        assert!(err.contains("too small"), "{err}");
        // no smaller than fp32 dense
        spec.budget_bytes = profiles.iter().map(|p| 4 * (p.rows * p.cols) as u64).sum();
        let err = allocate(&profiles, &spec).unwrap_err().to_string();
        assert!(err.contains("fp32"), "{err}");
        // empty candidate space
        spec.budget_bytes = 40_000;
        let mut empty = spec.clone();
        empty.bits_choices.clear();
        assert!(allocate(&profiles, &empty).is_err());
        assert!(allocate(&[], &spec).is_err());
    }

    #[test]
    fn allocation_beats_uniform_between_levels() {
        let (profiles, mut spec) = synth_profiles(4, true);
        // budget strictly between two uniform levels: uniform must
        // round down, the allocator spends the slack
        let level = |bi: usize, ri: usize| -> u64 {
            profiles.iter().map(|p| p.bytes(&spec, bi, ri)).sum()
        };
        let midpoint = (level(1, 1) + level(1, 2)) / 2;
        spec.budget_bytes = midpoint;
        let allocated = allocate(&profiles, &spec).unwrap();
        let uniform = uniform_plan(&profiles, &spec).unwrap();
        assert!(uniform.plan_bytes <= spec.budget_bytes);
        assert!(
            allocated.predicted_err2 < uniform.predicted_err2,
            "allocated {} !< uniform {}",
            allocated.predicted_err2,
            uniform.predicted_err2
        );
    }

    // ---- integration against a real (synthetic) model ------------------

    fn setup() -> (Params, ModelCfg, CalibrationSet) {
        let cfg = ModelCfg {
            name: "t".into(),
            vocab: 64,
            d_model: 64,
            n_heads: 2,
            n_layers: 2,
            d_ff: 128,
            seq_len: 16,
        };
        let params = synth_lm_params(&cfg, 5, cfg.vocab);
        let corpus = Corpus::generate(cfg.vocab, 4000, 6);
        let batches: Vec<Vec<i32>> = (0..10).map(|i| corpus.train_batch(2, 16, i)).collect();
        let calib = collect_calibration(&params, &cfg, &batches, 2, 16, 192);
        (params, cfg, calib)
    }

    fn small_spec(budget_bytes: u64) -> BudgetSpec {
        BudgetSpec {
            budget_bytes,
            bits_choices: vec![2, 3, 4],
            block: 32,
            rank_choices: vec![0, 4, 8],
            scaling: ScalingKind::DiagRms,
            seed: 3,
        }
    }

    #[test]
    fn planned_run_realizes_the_planned_k_and_fits() {
        let (params, cfg, calib) = setup();
        let metrics = Metrics::new();
        let runner = SweepRunner::new(&params, &cfg, &calib, &metrics);
        let profiles = runner.budget_profiles(&small_spec(0)).unwrap();
        let mid: u64 = profiles.iter().map(|p| p.bytes(&small_spec(0), 1, 1)).sum();
        let spec = small_spec(mid + mid / 10);
        let plan = runner.plan_budget(&spec).unwrap();
        assert!(plan.plan_bytes <= spec.budget_bytes);
        assert_eq!(plan.layers.len(), Params::linear_names(&cfg).len());

        let outcomes = runner.run_factored(&[plan.sweep_config()]);
        assert_eq!(outcomes.len(), 1);
        for (alloc, meta) in plan.layers.iter().zip(&outcomes[0].meta) {
            assert_eq!(alloc.name, meta.name);
            assert_eq!(
                alloc.k, meta.k_star,
                "{}: planned k {} != realized k* {}",
                alloc.name, alloc.k, meta.k_star
            );
        }
    }

    #[test]
    fn plan_is_deterministic_and_roundtrips_the_wire() {
        let (params, cfg, calib) = setup();
        let metrics = Metrics::new();
        let runner = SweepRunner::new(&params, &cfg, &calib, &metrics);
        let profiles = runner.budget_profiles(&small_spec(0)).unwrap();
        let mid: u64 = profiles.iter().map(|p| p.bytes(&small_spec(0), 1, 1)).sum();
        let spec = small_spec(mid);
        let a = runner.plan_budget(&spec).unwrap();
        let b = runner.plan_budget(&spec).unwrap();
        assert_eq!(a, b, "planning must be deterministic");

        let frame = wire::encode_budget_plan(&a);
        assert_eq!(frame.kind, wire::kind::BUDGET_PLAN);
        let back = wire::decode_budget_plan(&frame.payload).unwrap();
        assert_eq!(a, back, "wire roundtrip must be lossless");
    }
}
