//! Multi-process execution plane: shard sweep phase B2 and fleet PPL
//! evaluation across `srr shard-worker` processes.
//!
//! The in-process engines already saturate one machine's cores; this
//! module is the seam that takes them to N processes (and, with a future
//! TCP/ssh transport, N hosts). The division of labor:
//!
//! * the **host** ships per-layer phase-A/B1 **preparation jobs**
//!   (k=0 quantizations, SRR spectra, residual SVDs — the same work
//!   [`SweepRunner::prepare`] does in-process), then per-`(layer,
//!   config)` phase-B2 jobs — and fleet `(group × batch)` PPL jobs —
//!   to worker processes over the [`wire`](super::wire) codec, merging
//!   results
//!   deterministically by job id. The byte stream underneath is a
//!   [`Transport`](super::transport::Transport): child-process pipes
//!   ([`ShardSession::spawn`]), TCP to local or remote workers
//!   ([`ShardSession::spawn_tcp`], [`ShardSession::listen`],
//!   [`ShardSession::dial`]), or the fault-injection double the tests
//!   drive;
//! * each **worker** ([`worker_main`], the `srr shard-worker` CLI mode)
//!   pulls frames through a reader thread into a bounded job queue
//!   (backpressure end-to-end: a full queue stops the read loop, which
//!   stops the host's pipe), computes with the *same*
//!   [`b2_job`](super::sweep) / fleet-job functions the in-process
//!   engines run, and pushes result frames through a writer thread.
//!
//! **Bit-identity contract:** [`ShardedSweepRunner::run_factored`]
//! produces outcomes — and [`fleet_perplexity_sharded`] PPLs —
//! bit-identical to [`SweepRunner::run_factored`] +
//! [`fleet_perplexity`](crate::eval::fleet_perplexity) for any worker
//! count, including after worker-death requeue (regression- and
//! property-tested; `cargo bench -- --exp shard` records the scaling
//! efficiency into `BENCH_shard.json`). The contract holds because both
//! paths run the same job functions on the same artifacts and merge in
//! the same order; the wire layer's content-addressed blob dedup
//! rebuilds the `Arc` sharing (grid dedup, lock-step groups) on each
//! side of the pipe.
//!
//! **Failure model:** a worker that exits (cleanly or by crash), drops
//! its connection, or writes garbage frames is marked dead; its
//! in-flight jobs requeue onto surviving workers, and
//! late frames from a dead worker are discarded (the survivor's
//! recomputation is authoritative). The host's event loop waits with
//! [`BoundedQueue::pop_timeout`](super::jobs::BoundedQueue::pop_timeout)
//! and probes [`Transport::poll_dead`](super::transport::Transport) on
//! every timeout, so even a worker that dies without closing its stream
//! is noticed when the transport owns a side channel (child exit
//! status). A worker that hangs *without* exiting or disconnecting is
//! caught by the **per-job heartbeat**: workers emit a
//! [`kind::HEARTBEAT`] frame per in-flight job at a fixed cadence
//! ([`DEFAULT_HEARTBEAT`]), and a job that goes
//! [`ShardOptions::heartbeat_timeout`] without one marks its worker
//! *wedged* — the same requeue as a death, plus a transport kill so a
//! peer that later wakes up cannot publish stale frames into the
//! session. Only when every worker has died does the run error out.
//!
//! **Elasticity:** a session built by [`ShardSession::listen`] keeps
//! its accept loop running *while jobs run*, so `srr shard-worker
//! --connect` dial-ins join mid-run: an admitted joiner gets its own
//! credit window and starts pulling from the shared pending queue
//! immediately. A departing worker — clean exit, crash, or wedge —
//! requeues exactly as above, so the fleet grows and shrinks mid-run
//! without affecting results.

use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::eval::fleet::{
    fleet_job_list, reduce_fleet_results, FleetGroup, FleetJob, FleetJobResult,
};
use crate::eval::{group_by_shared_bases, perplexity_native_masked};
use crate::linalg::Svd;
use crate::model::forward::lm_nll_fleet;
use crate::model::{CalibrationSet, Params};
use crate::qer::{Method, PreparedSpectra};
use crate::quant::PackedMat;
use crate::runtime::manifest::ModelCfg;
use crate::scaling::{Scaling, ScalingKind};
use crate::serve::{FactoredModel, LinearOp, QuantBase};
use crate::tensor::Mat;
use crate::util::cli::Args;
use crate::util::pool;

use super::cache::{LayerCache, PreparedLayer};
use super::jobs::{BoundedQueue, PopResult};
use super::metrics::Metrics;
use super::pipeline::{layer_salt, FactoredOutcome, LayerMeta, LayerReport};
use super::spill::{self, SpillBase, SpillStore};
use super::sweep::{
    assemble_outcomes, b2_artifacts, b2_job, compute_qdeq0, compute_resid_svd,
    compute_spectra, empty_outcomes, sweep_keys, B2Artifacts, SweepConfig, SweepKeys,
    SweepPrep, SweepRunner,
};
use super::transport::{
    worker_accept, worker_connect, ChildPipeTransport, ShardHost, TcpTransport, Transport,
};
use super::wire::{
    self, decode_fleet_job, decode_fleet_result, decode_sweep_job, decode_sweep_result,
    encode_fleet_job, encode_fleet_result, encode_sweep_job, encode_sweep_result, kind,
    shutdown_frame, BlobRx, BlobTx, FleetJobMsg, FleetOut, FleetResultMsg, Frame, SweepJobMsg,
    SweepResultMsg, WireBase, WireLinearOp, WireModel, WireScaling, WireSpectra, WireSvd,
};

/// Jobs a worker may hold in flight before the host waits for results —
/// one computing, one queued behind it.
const WINDOW: usize = 2;

/// Worker-side queue depth for decoded jobs / encoded results. Small on
/// purpose: the queue, not the OS pipe, is the unit of backpressure.
const WORKER_QUEUE_CAP: usize = 4;

/// How long the host event loop waits before probing child liveness.
const EVENT_POLL: Duration = Duration::from_millis(500);

/// Default cadence at which a worker emits a [`kind::HEARTBEAT`] frame
/// per in-flight job (`srr shard-worker --heartbeat-secs` overrides).
pub const DEFAULT_HEARTBEAT: Duration = Duration::from_secs(1);

/// Default host-side deadline: a dispatched job that goes this long
/// without a result *or* a heartbeat marks its worker wedged.
pub const DEFAULT_HEARTBEAT_TIMEOUT: Duration = Duration::from_secs(10);

/// Configuration for a shard session.
#[derive(Clone, Debug)]
pub struct ShardOptions {
    /// worker processes to spawn (≥ 1)
    pub workers: usize,
    /// `SRR_THREADS` for each worker (0 = inherit the environment); the
    /// default of 1 makes N workers ≈ N single-threaded executors, the
    /// configuration the scaling bench measures
    pub worker_threads: usize,
    /// fault injection for tests/benches: the *first* worker exits after
    /// completing this many jobs, exercising the requeue path
    pub exit_after_first: Option<usize>,
    /// explicit path to the `srr` binary (otherwise `SRR_SHARD_BIN`,
    /// then a search near the current executable)
    pub binary: Option<PathBuf>,
    /// how long a dispatched job may go without a heartbeat before its
    /// worker is marked wedged and the job requeues
    pub heartbeat_timeout: Duration,
}

impl Default for ShardOptions {
    fn default() -> Self {
        ShardOptions {
            workers: 2,
            worker_threads: 1,
            exit_after_first: None,
            binary: None,
            heartbeat_timeout: DEFAULT_HEARTBEAT_TIMEOUT,
        }
    }
}

impl ShardOptions {
    /// `n` workers with the default single-threaded worker config.
    pub fn with_workers(n: usize) -> Self {
        ShardOptions { workers: n, ..Default::default() }
    }
}

/// Locate the `srr` binary to spawn workers from: an explicit override,
/// the `SRR_SHARD_BIN` env var (integration tests and benches set it
/// from `CARGO_BIN_EXE_srr`), the current executable when it *is* `srr`,
/// or a sibling/parent search from the current executable (covers test
/// and example binaries under `target/<profile>/deps`).
fn worker_binary(opts: &ShardOptions) -> Result<PathBuf> {
    if let Some(p) = &opts.binary {
        return Ok(p.clone());
    }
    if let Ok(p) = std::env::var("SRR_SHARD_BIN") {
        return Ok(PathBuf::from(p));
    }
    let exe = std::env::current_exe().context("resolving current executable")?;
    if exe.file_stem().map(|s| s == "srr").unwrap_or(false) {
        return Ok(exe);
    }
    let mut dir = exe.parent();
    for _ in 0..3 {
        let Some(d) = dir else { break };
        let cand = d.join(format!("srr{}", std::env::consts::EXE_SUFFIX));
        if cand.is_file() {
            return Ok(cand);
        }
        dir = d.parent();
    }
    anyhow::bail!(
        "cannot locate the `srr` worker binary near {}; set SRR_SHARD_BIN or ShardOptions.binary",
        exe.display()
    )
}

/// Shard-plane transfer/fault counters (shared with reader threads).
#[derive(Default)]
struct ShardStats {
    jobs_sent: AtomicU64,
    tx_bytes: AtomicU64,
    rx_bytes: AtomicU64,
    requeued: AtomicU64,
    deaths: AtomicU64,
    /// workers whose in-flight job outlived its heartbeat deadline
    wedged: AtomicU64,
    /// result frames refused by the dispatch-window check: duplicates,
    /// frames from dead/wedged workers, ids from a previous batch
    rejected: AtomicU64,
    /// workers admitted after the session was built (mid-run joins)
    joined: AtomicU64,
    /// events lost because the queue closed mid-push (teardown races)
    events_dropped: AtomicU64,
}

/// Host→worker result/failure notifications.
enum Event {
    /// a decoded result frame from `worker`
    Result { worker: usize, msg: ResultMsg },
    /// `worker` reports `job` still making progress
    Heartbeat { worker: usize, job: u64 },
    /// `worker`'s pipe ended or produced garbage
    Dead { worker: usize },
    /// a freshly handshaken transport wants to join the fleet
    Join(Box<dyn Transport>),
}

/// A decoded worker result.
#[derive(Debug)]
pub(crate) enum ResultMsg {
    /// phase-B2 sweep job result
    Sweep(Box<SweepResultMsg>),
    /// fleet PPL job result
    Fleet(FleetResultMsg),
    /// phase-A/B1 preparation job result
    Prep(Box<wire::PrepResultMsg>),
}

impl ResultMsg {
    fn job_id(&self) -> u64 {
        match self {
            ResultMsg::Sweep(m) => m.job_id,
            ResultMsg::Fleet(m) => m.job_id,
            ResultMsg::Prep(m) => m.job_id,
        }
    }
}

/// A source of encodable jobs; the dispatch loop is generic over sweep
/// and fleet batches.
pub(crate) trait JobSource {
    /// Total job count; job ids are `0..n_jobs`.
    fn n_jobs(&self) -> usize;
    /// Encode job `job` for one worker connection: any blob frames the
    /// worker is missing, then the job frame.
    fn encode(&self, job: usize, tx: &mut BlobTx) -> Vec<Frame>;
}

struct WorkerConn {
    /// the framed byte stream to this worker (pipes, TCP, or a test
    /// double); the write half closes when the worker dies or shuts down
    transport: Box<dyn Transport>,
    /// per-connection blob dedup state
    tx: BlobTx,
    /// job ids in flight on this worker, each with its heartbeat
    /// deadline — set at dispatch, refreshed on every heartbeat frame
    outstanding: Vec<(usize, Instant)>,
    alive: bool,
    reader: Option<JoinHandle<()>>,
}

/// A pool of worker connections — spawned `srr shard-worker` processes
/// over pipes or TCP, remote dial-ins, or any custom [`Transport`]. One
/// session serves any number of job batches
/// ([`ShardedSweepRunner::run_factored`], [`fleet_perplexity_sharded`])
/// — blob caches persist across batches, so a fleet evaluation right
/// after a sweep reuses the bases the sweep already shipped.
pub struct ShardSession {
    workers: Vec<WorkerConn>,
    events: Arc<BoundedQueue<Event>>,
    /// host-side blob cache, shared by all worker readers; seeded with
    /// outbound artifacts so results resolve to the very same `Arc`s
    rx: Arc<Mutex<BlobRx>>,
    stats: Arc<ShardStats>,
    /// per-job silence budget before a worker is marked wedged
    heartbeat_timeout: Duration,
    /// stops the mid-run accept thread ([`ShardSession::listen`])
    accept_stop: Option<Arc<AtomicBool>>,
    accept_thread: Option<JoinHandle<()>>,
}

/// Clonable handle that offers a connected transport to a session as a
/// mid-run joiner, from any thread (what [`ShardSession::listen`]'s
/// accept loop does internally; tests drive it directly).
#[derive(Clone)]
pub(crate) struct JoinSender {
    events: Arc<BoundedQueue<Event>>,
    stats: Arc<ShardStats>,
}

impl JoinSender {
    /// Queue `transport` for admission. Returns `false` if the session
    /// is tearing down (the joiner is dropped, not admitted).
    pub(crate) fn admit(&self, transport: Box<dyn Transport>) -> bool {
        if self.events.push(Event::Join(transport)) {
            true
        } else {
            self.stats.events_dropped.fetch_add(1, Ordering::Relaxed);
            false
        }
    }
}

fn spawn_reader(
    wi: usize,
    input: Box<dyn Read + Send>,
    events: Arc<BoundedQueue<Event>>,
    rx: Arc<Mutex<BlobRx>>,
    stats: Arc<ShardStats>,
) -> JoinHandle<()> {
    std::thread::spawn(move || {
        let mut out = BufReader::new(input);
        loop {
            match wire::read_frame(&mut out) {
                Ok(Some(f)) => {
                    stats.rx_bytes.fetch_add(f.payload.len() as u64 + 24, Ordering::Relaxed);
                    let ev = match f.kind {
                        kind::BLOB_MAT | kind::BLOB_PACKED | kind::BLOB_PARAMS => {
                            match rx.lock().unwrap().insert(f.kind, &f.payload) {
                                Ok(_) => continue,
                                Err(_) => Event::Dead { worker: wi },
                            }
                        }
                        kind::SWEEP_RESULT => match decode_sweep_result(&f.payload) {
                            Ok(m) => {
                                let msg = ResultMsg::Sweep(Box::new(m));
                                Event::Result { worker: wi, msg }
                            }
                            Err(_) => Event::Dead { worker: wi },
                        },
                        kind::FLEET_RESULT => match decode_fleet_result(&f.payload) {
                            Ok(m) => Event::Result { worker: wi, msg: ResultMsg::Fleet(m) },
                            Err(_) => Event::Dead { worker: wi },
                        },
                        kind::PREP_RESULT => match wire::decode_prep_result(&f.payload) {
                            Ok(m) => {
                                let msg = ResultMsg::Prep(Box::new(m));
                                Event::Result { worker: wi, msg }
                            }
                            Err(_) => Event::Dead { worker: wi },
                        },
                        kind::HEARTBEAT => match wire::decode_heartbeat(&f.payload) {
                            Ok(job) => Event::Heartbeat { worker: wi, job },
                            Err(_) => Event::Dead { worker: wi },
                        },
                        _ => Event::Dead { worker: wi },
                    };
                    let dead = matches!(ev, Event::Dead { .. });
                    if !events.push(ev) {
                        // queue closed mid-teardown: nobody is listening
                        stats.events_dropped.fetch_add(1, Ordering::Relaxed);
                        return;
                    }
                    if dead {
                        return;
                    }
                }
                Ok(None) | Err(_) => {
                    if !events.push(Event::Dead { worker: wi }) {
                        stats.events_dropped.fetch_add(1, Ordering::Relaxed);
                    }
                    return;
                }
            }
        }
    })
}

/// Kill and reap a set of spawned worker children (the error-path
/// cleanup shared by [`ShardSession::spawn_tcp`]).
fn reap_children(children: HashMap<u64, Child>) {
    for mut c in children.into_values() {
        let _ = c.kill();
        let _ = c.wait();
    }
}

/// Base `srr shard-worker` invocation shared by the pipe and TCP spawn
/// paths (threads env, first-worker fault injection).
fn worker_command(bin: &Path, opts: &ShardOptions, wi: usize) -> Command {
    let mut cmd = Command::new(bin);
    cmd.arg("shard-worker");
    if opts.worker_threads > 0 {
        cmd.env("SRR_THREADS", opts.worker_threads.to_string());
    }
    // keep the cadence a comfortable multiple of the wedge deadline, so
    // a short test timeout never false-positives on a healthy child
    let cadence = (opts.heartbeat_timeout / 4).min(DEFAULT_HEARTBEAT);
    cmd.arg("--heartbeat-secs").arg(format!("{}", cadence.as_secs_f64()));
    if wi == 0 {
        if let Some(k) = opts.exit_after_first {
            cmd.arg("--exit-after").arg(k.to_string());
        }
    }
    cmd
}

/// How long [`ShardSession::spawn_tcp`] waits for its own loopback
/// children to dial back in.
const SPAWN_TCP_ACCEPT: Duration = Duration::from_secs(30);

impl ShardSession {
    /// Wrap already-connected transports into a session (the seam every
    /// other constructor goes through; also the entry point for custom
    /// transports — ssh tunnels, test doubles).
    pub fn from_transports(transports: Vec<Box<dyn Transport>>) -> Result<ShardSession> {
        anyhow::ensure!(!transports.is_empty(), "shard session needs at least one worker");
        let events = Arc::new(BoundedQueue::new(transports.len() * (WINDOW + 2) + 4));
        let rx = Arc::new(Mutex::new(BlobRx::new()));
        let stats = Arc::new(ShardStats::default());
        let mut workers: Vec<WorkerConn> = Vec::with_capacity(transports.len());
        for (wi, mut transport) in transports.into_iter().enumerate() {
            let input = transport.take_reader().ok_or_else(|| {
                anyhow::anyhow!("transport {} has no read half left", transport.describe())
            })?;
            let reader = spawn_reader(wi, input, events.clone(), rx.clone(), stats.clone());
            workers.push(WorkerConn {
                transport,
                tx: BlobTx::new(),
                outstanding: Vec::new(),
                alive: true,
                reader: Some(reader),
            });
        }
        Ok(ShardSession {
            workers,
            events,
            rx,
            stats,
            heartbeat_timeout: DEFAULT_HEARTBEAT_TIMEOUT,
            accept_stop: None,
            accept_thread: None,
        })
    }

    /// Spawn `opts.workers` worker processes with piped stdin/stdout
    /// (stderr inherited so worker panics stay visible).
    pub fn spawn(opts: &ShardOptions) -> Result<ShardSession> {
        anyhow::ensure!(opts.workers >= 1, "shard session needs at least one worker");
        let bin = worker_binary(opts)?;
        let mut transports: Vec<Box<dyn Transport>> = Vec::with_capacity(opts.workers);
        for wi in 0..opts.workers {
            let mut cmd = worker_command(&bin, opts, wi);
            cmd.stdin(Stdio::piped()).stdout(Stdio::piped()).stderr(Stdio::inherit());
            let child = cmd
                .spawn()
                .with_context(|| format!("spawning {}", bin.display()))?;
            // earlier transports kill their children on drop if a later
            // spawn fails
            transports.push(Box::new(ChildPipeTransport::new(child)));
        }
        let mut session = Self::from_transports(transports)?;
        session.heartbeat_timeout = opts.heartbeat_timeout;
        Ok(session)
    }

    /// Spawn `opts.workers` worker processes that dial back over TCP
    /// loopback: the host binds an ephemeral `127.0.0.1` port, each
    /// child runs `srr shard-worker --connect 127.0.0.1:<port>` with a
    /// per-worker token, and the session maps dial-ins back to the
    /// child processes (so the liveness probe still sees exits). Same
    /// dispatcher, same bit-identity contract — only the bytes travel
    /// through the loopback stack instead of pipes, which is what
    /// `cargo bench -- --exp shard` measures TCP framing overhead with.
    pub fn spawn_tcp(opts: &ShardOptions) -> Result<ShardSession> {
        anyhow::ensure!(opts.workers >= 1, "shard session needs at least one worker");
        let bin = worker_binary(opts)?;
        let host = ShardHost::bind("127.0.0.1:0")?;
        let addr = host.local_addr()?.to_string();
        let mut children: HashMap<u64, Child> = HashMap::new();
        for wi in 0..opts.workers {
            let token = wi as u64 + 1;
            let mut cmd = worker_command(&bin, opts, wi);
            cmd.arg("--connect")
                .arg(&addr)
                .arg("--token")
                .arg(token.to_string())
                .stdin(Stdio::null())
                .stdout(Stdio::null())
                .stderr(Stdio::inherit());
            match cmd.spawn().with_context(|| format!("spawning {}", bin.display())) {
                Ok(child) => {
                    children.insert(token, child);
                }
                Err(e) => {
                    reap_children(children);
                    return Err(e);
                }
            }
        }
        let accepted = host.accept_workers(opts.workers, SPAWN_TCP_ACCEPT);
        let mut accepted = match accepted {
            Ok(a) => a,
            Err(e) => {
                reap_children(children);
                return Err(e);
            }
        };
        // every admitted dial-in must present a token this session issued
        // to one of its own children — a foreign process that happened to
        // dial the ephemeral port (and would skew SRR_THREADS pinning /
        // --exit-after fault injection) is an error, not a fleet member
        let mut err: Option<anyhow::Error> = None;
        for t in &mut accepted {
            match children.remove(&t.token()) {
                Some(child) => t.attach_child(child),
                None if err.is_none() => {
                    err = Some(anyhow::anyhow!(
                        "shard host: unexpected dial-in {} — not one of this session's workers",
                        t.describe()
                    ));
                }
                None => {}
            }
        }
        if err.is_none() && !children.is_empty() {
            err = Some(anyhow::anyhow!(
                "shard host: {} spawned worker(s) never completed the handshake",
                children.len()
            ));
        }
        if let Some(e) = err {
            // reap the children whose slots were taken; accepted
            // transports drop below (killing any attached children)
            reap_children(children);
            return Err(e);
        }
        let mut session =
            Self::from_transports(accepted.into_iter().map(|t| Box::new(t) as _).collect())?;
        session.heartbeat_timeout = opts.heartbeat_timeout;
        Ok(session)
    }

    /// Listen on `addr` and wait (up to `deadline`) for `workers`
    /// remote `srr shard-worker --connect` dial-ins. No authentication
    /// beyond the wire handshake — bind loopback and tunnel over ssh,
    /// or stay on a trusted LAN (see the README's remote-worker
    /// workflow).
    ///
    /// The listener stays open after the initial fleet assembles: the
    /// accept loop keeps running on its own thread, and any later
    /// dial-in is queued as a join event that the job dispatcher (or an
    /// explicit [`ShardSession::admit_pending_joins`]) admits into the
    /// fleet — mid-run elasticity.
    pub fn listen(addr: &str, workers: usize, deadline: Duration) -> Result<ShardSession> {
        anyhow::ensure!(workers >= 1, "shard session needs at least one worker");
        let host = ShardHost::bind(addr)?;
        let accepted = host.accept_workers(workers, deadline)?;
        let mut session =
            Self::from_transports(accepted.into_iter().map(|t| Box::new(t) as _).collect())?;
        session.keep_accepting(host);
        Ok(session)
    }

    /// Dial workers that are already listening (`srr shard-worker
    /// --listen host:port`), one session worker per address.
    pub fn dial(addrs: &[String]) -> Result<ShardSession> {
        anyhow::ensure!(!addrs.is_empty(), "shard session needs at least one worker");
        let mut transports: Vec<Box<dyn Transport>> = Vec::with_capacity(addrs.len());
        for addr in addrs {
            transports.push(Box::new(TcpTransport::dial(addr)?));
        }
        Self::from_transports(transports)
    }

    /// Workers still accepting jobs.
    pub fn n_alive(&self) -> usize {
        self.workers.iter().filter(|w| w.alive).count()
    }

    /// Override the wedge deadline (tests drive this down to
    /// milliseconds; the CLI maps `--heartbeat-timeout` here for the
    /// listen/dial constructors, which have no [`ShardOptions`]).
    pub fn set_heartbeat_timeout(&mut self, timeout: Duration) {
        self.heartbeat_timeout = timeout;
    }

    /// Keep `host`'s accept loop running on a background thread; each
    /// accepted dial-in is queued as a join event for the dispatcher.
    /// [`ShardSession::listen`] calls this for you; sessions assembled
    /// by hand ([`ShardSession::from_transports`] over a
    /// [`ShardHost`](super::transport::ShardHost) the caller bound, e.g.
    /// to learn an ephemeral port) call it to opt into mid-run joins.
    pub fn keep_accepting(&mut self, host: ShardHost) {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let events = self.events.clone();
        let stats = self.stats.clone();
        let thread = std::thread::spawn(move || {
            host.accept_loop(&stop2, |t| {
                if !events.push(Event::Join(Box::new(t))) {
                    stats.events_dropped.fetch_add(1, Ordering::Relaxed);
                }
            });
        });
        self.accept_stop = Some(stop);
        self.accept_thread = Some(thread);
    }

    /// A handle that injects a joiner into the session's event queue
    /// from another thread — the test seam for mid-run joins (the
    /// production path is [`ShardSession::listen`]'s accept loop).
    pub(crate) fn join_sender(&self) -> JoinSender {
        JoinSender { events: self.events.clone(), stats: self.stats.clone() }
    }

    /// Wire a freshly-connected transport into the fleet as a new
    /// worker: spawn its reader, give it an empty credit window.
    fn admit_worker(&mut self, mut transport: Box<dyn Transport>) -> Option<usize> {
        let wi = self.workers.len();
        let Some(input) = transport.take_reader() else {
            eprintln!(
                "shard host: joiner {} has no read half — rejected",
                transport.describe()
            );
            return None;
        };
        let reader =
            spawn_reader(wi, input, self.events.clone(), self.rx.clone(), self.stats.clone());
        self.workers.push(WorkerConn {
            transport,
            tx: BlobTx::new(),
            outstanding: Vec::new(),
            alive: true,
            reader: Some(reader),
        });
        self.stats.joined.fetch_add(1, Ordering::Relaxed);
        Some(wi)
    }

    /// Drain whatever is sitting in the event queue *between* job
    /// batches: deaths noticed since the last run, joiners waiting for
    /// admission, stale result frames from previous batches.
    fn absorb_idle_events(&mut self, pending: &mut VecDeque<usize>) {
        loop {
            match self.events.try_pop() {
                PopResult::Item(Event::Dead { worker }) => self.mark_dead(worker, pending),
                PopResult::Item(Event::Join(t)) => {
                    self.admit_worker(t);
                }
                PopResult::Item(Event::Result { .. }) => {
                    // stale frame from a previous batch
                    self.stats.rejected.fetch_add(1, Ordering::Relaxed);
                }
                PopResult::Item(Event::Heartbeat { .. }) => {}
                PopResult::Empty | PopResult::Closed => return,
            }
        }
    }

    /// Admit any joiners (and absorb any deaths) queued while no job
    /// batch was running — lets callers poll fleet growth between runs.
    pub fn admit_pending_joins(&mut self) {
        // no batch is running, so requeued orphans (possible only after
        // a failed run) have nowhere to go — drop them with the batch
        let mut orphans = VecDeque::new();
        self.absorb_idle_events(&mut orphans);
    }

    /// Expire heartbeat deadlines: any live worker holding a job past
    /// its deadline is wedged (requeued + killed). Returns whether
    /// anything expired, so the caller can refill windows.
    fn requeue_expired(&mut self, pending: &mut VecDeque<usize>) -> bool {
        let now = Instant::now();
        let mut any = false;
        for wi in 0..self.workers.len() {
            let expired = {
                let w = &self.workers[wi];
                w.alive && w.outstanding.iter().any(|&(_, deadline)| deadline <= now)
            };
            if expired {
                self.mark_wedged(wi, pending);
                any = true;
            }
        }
        any
    }

    /// A wedged worker is a dead worker that hasn't had the grace to
    /// disconnect: requeue its jobs like a death, then kill the
    /// transport so a late wake-up can't write stale frames.
    fn mark_wedged(&mut self, wi: usize, pending: &mut VecDeque<usize>) {
        if !self.workers[wi].alive {
            return;
        }
        self.stats.wedged.fetch_add(1, Ordering::Relaxed);
        eprintln!(
            "shard host: worker {wi} ({}) missed its heartbeat deadline — requeueing",
            self.workers[wi].transport.describe()
        );
        self.mark_dead(wi, pending);
        self.workers[wi].transport.kill();
    }

    /// The shared host-side blob cache (the sweep runner seeds it with
    /// the `Arc`s it ships, so results resolve back to the same
    /// buffers).
    pub(crate) fn rx(&self) -> &Mutex<BlobRx> {
        &self.rx
    }

    fn mark_dead(&mut self, wi: usize, pending: &mut VecDeque<usize>) {
        let w = &mut self.workers[wi];
        if !w.alive {
            return;
        }
        w.alive = false;
        w.transport.close_writer(); // peer sees EOF
        self.stats.deaths.fetch_add(1, Ordering::Relaxed);
        let orphans = std::mem::take(&mut w.outstanding);
        self.stats.requeued.fetch_add(orphans.len() as u64, Ordering::Relaxed);
        // requeue in front so interrupted work retires first
        for (j, _) in orphans.into_iter().rev() {
            pending.push_front(j);
        }
    }

    fn feed_worker<S: JobSource>(
        &mut self,
        wi: usize,
        src: &S,
        pending: &mut VecDeque<usize>,
    ) {
        loop {
            if !self.workers[wi].alive || self.workers[wi].outstanding.len() >= WINDOW {
                return;
            }
            let Some(job) = pending.pop_front() else { return };
            let frames = src.encode(job, &mut self.workers[wi].tx);
            let sent = match self.workers[wi].transport.writer() {
                Some(mut out) => {
                    frames.iter().all(|f| f.write_to(&mut out).is_ok()) && out.flush().is_ok()
                }
                None => false,
            };
            if sent {
                let bytes: u64 = frames.iter().map(|f| f.payload.len() as u64 + 24).sum();
                self.stats.tx_bytes.fetch_add(bytes, Ordering::Relaxed);
                self.stats.jobs_sent.fetch_add(1, Ordering::Relaxed);
                let deadline = Instant::now() + self.heartbeat_timeout;
                self.workers[wi].outstanding.push((job, deadline));
            } else {
                // unreachable worker: give the job back, let the reader's
                // Dead event (or this mark) finish the cleanup
                pending.push_front(job);
                self.mark_dead(wi, pending);
                return;
            }
        }
    }

    fn fill_windows<S: JobSource>(&mut self, src: &S, pending: &mut VecDeque<usize>) {
        for wi in 0..self.workers.len() {
            self.feed_worker(wi, src, pending);
        }
    }

    /// Run every job in `src` across the workers; returns results
    /// indexed by job id (merge order is therefore deterministic
    /// regardless of which worker finished what, when).
    pub(crate) fn run_jobs<S: JobSource>(
        &mut self,
        src: &S,
        metrics: &Metrics,
    ) -> Result<Vec<ResultMsg>> {
        let n = src.n_jobs();
        let mut results: Vec<Option<ResultMsg>> = (0..n).map(|_| None).collect();
        let mut pending: VecDeque<usize> = (0..n).collect();
        let mut n_done = 0usize;

        // absorb deaths, joins, and stale frames noticed since the
        // previous batch
        self.absorb_idle_events(&mut pending);

        self.fill_windows(src, &mut pending);
        while n_done < n {
            anyhow::ensure!(
                self.workers.iter().any(|w| w.alive),
                "all shard workers died with {} of {n} jobs unfinished",
                n - n_done
            );
            if self.requeue_expired(&mut pending) {
                self.fill_windows(src, &mut pending);
                continue;
            }
            match self.events.pop_timeout(EVENT_POLL) {
                PopResult::Item(Event::Result { worker, msg }) => {
                    // results from a worker already marked dead are stale:
                    // its jobs were requeued the moment it was marked, and
                    // a late frame may even belong to a previous batch —
                    // the survivor's recomputation is the one that counts.
                    // From a *live* worker, only a job actually sitting in
                    // its credit window counts: anything else is a replay
                    // or a leftover from before a requeue and would
                    // double-count against a fresh dispatch.
                    let job = msg.job_id() as usize;
                    let pos = if self.workers[worker].alive {
                        self.workers[worker]
                            .outstanding
                            .iter()
                            .position(|&(j, _)| j == job)
                    } else {
                        None
                    };
                    let Some(pos) = pos else {
                        self.stats.rejected.fetch_add(1, Ordering::Relaxed);
                        continue;
                    };
                    self.workers[worker].outstanding.remove(pos);
                    if results[job].is_none() {
                        results[job] = Some(msg);
                        n_done += 1;
                    }
                    self.feed_worker(worker, src, &mut pending);
                }
                PopResult::Item(Event::Heartbeat { worker, job }) => {
                    // a beat renews the wedge deadline of that one job
                    if self.workers[worker].alive {
                        let deadline = Instant::now() + self.heartbeat_timeout;
                        for slot in &mut self.workers[worker].outstanding {
                            if slot.0 == job as usize {
                                slot.1 = deadline;
                            }
                        }
                    }
                }
                PopResult::Item(Event::Join(t)) => {
                    if let Some(wi) = self.admit_worker(t) {
                        self.feed_worker(wi, src, &mut pending);
                    }
                }
                PopResult::Item(Event::Dead { worker }) => {
                    self.mark_dead(worker, &mut pending);
                    self.fill_windows(src, &mut pending);
                }
                PopResult::Empty => {
                    // no events: probe each transport's out-of-band death
                    // signal (a child that exited without its reader
                    // noticing), then keep waiting
                    for wi in 0..self.workers.len() {
                        if self.workers[wi].alive && self.workers[wi].transport.poll_dead() {
                            self.mark_dead(wi, &mut pending);
                        }
                    }
                    self.fill_windows(src, &mut pending);
                }
                PopResult::Closed => anyhow::bail!("shard event queue closed"),
            }
        }

        metrics.put("shard.workers", self.workers.len() as f64);
        metrics.put("shard.workers_alive", self.n_alive() as f64);
        metrics.put("shard.jobs_sent", self.stats.jobs_sent.load(Ordering::Relaxed) as f64);
        metrics.put("shard.tx_bytes", self.stats.tx_bytes.load(Ordering::Relaxed) as f64);
        metrics.put("shard.rx_bytes", self.stats.rx_bytes.load(Ordering::Relaxed) as f64);
        metrics.put("shard.requeued", self.stats.requeued.load(Ordering::Relaxed) as f64);
        metrics.put("shard.worker_deaths", self.stats.deaths.load(Ordering::Relaxed) as f64);
        metrics.put("shard.wedged", self.stats.wedged.load(Ordering::Relaxed) as f64);
        metrics.put(
            "shard.rejected_frames",
            self.stats.rejected.load(Ordering::Relaxed) as f64,
        );
        metrics.put("shard.joined", self.stats.joined.load(Ordering::Relaxed) as f64);
        metrics.put(
            "shard.events_dropped",
            self.stats.events_dropped.load(Ordering::Relaxed) as f64,
        );
        Ok(results.into_iter().map(|r| r.expect("job completed")).collect())
    }

    /// Graceful teardown: drain, send shutdown frames, reap children.
    pub fn shutdown(mut self) {
        self.teardown(true);
    }

    fn teardown(&mut self, graceful: bool) {
        if let Some(stop) = self.accept_stop.take() {
            stop.store(true, Ordering::Release);
        }
        for w in &mut self.workers {
            if graceful {
                if let Some(mut out) = w.transport.writer() {
                    let _ = shutdown_frame().write_to(&mut out);
                    let _ = out.flush();
                }
            }
            w.transport.close_writer(); // EOF either way
        }
        self.events.close();
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        for w in &mut self.workers {
            if graceful {
                w.transport.wait();
            } else {
                w.transport.kill();
            }
            if let Some(r) = w.reader.take() {
                let _ = r.join();
            }
        }
        self.workers.clear();
    }
}

impl Drop for ShardSession {
    fn drop(&mut self) {
        self.teardown(false);
    }
}

// ---------------------------------------------------------------------------
// sweep sharding
// ---------------------------------------------------------------------------

/// Per-batch memo of encoded blob bodies. Job encoding runs once per
/// job per worker on the host's dispatch thread; without the memo every
/// job re-serializes (and re-hashes) its layer's full artifacts just to
/// discover the worker already holds them. Keys are the source buffer's
/// address plus dimensions — sound because the memo lives inside a
/// `JobSource` that borrows the cache/models for the whole batch, so
/// the addresses are pinned (dimensions disambiguate zero-length
/// buffers, whose dangling pointers all compare equal).
#[derive(Default)]
struct EncodeMemo {
    entries: RefCell<HashMap<(u8, usize, usize, usize), (wire::BlobRef, Vec<u8>)>>,
}

impl EncodeMemo {
    fn blob(
        &self,
        k: u8,
        key: (usize, usize, usize),
        tx: &mut BlobTx,
        frames: &mut Vec<Frame>,
        encode: impl FnOnce() -> (wire::BlobRef, Vec<u8>),
    ) -> wire::BlobRef {
        let mut entries = self.entries.borrow_mut();
        let (hash, body) = entries.entry((k, key.0, key.1, key.2)).or_insert_with(encode);
        tx.prehashed_ref(k, *hash, body, frames)
    }

    fn mat(&self, m: &Mat, tx: &mut BlobTx, frames: &mut Vec<Frame>) -> wire::BlobRef {
        let key = (m.data.as_ptr() as usize, m.rows, m.cols);
        self.blob(kind::BLOB_MAT, key, tx, frames, || wire::encode_mat_blob(m))
    }

    fn packed(&self, p: &PackedMat, tx: &mut BlobTx, frames: &mut Vec<Frame>) -> wire::BlobRef {
        let key = (p as *const PackedMat as usize, 0, 0);
        self.blob(kind::BLOB_PACKED, key, tx, frames, || wire::encode_packed_blob(p))
    }

    fn params(&self, p: &Params, tx: &mut BlobTx, frames: &mut Vec<Frame>) -> wire::BlobRef {
        let key = (p as *const Params as usize, 0, 0);
        self.blob(kind::BLOB_PARAMS, key, tx, frames, || wire::encode_params_blob(p))
    }
}

fn wire_svd(
    svd: &Svd,
    memo: &EncodeMemo,
    tx: &mut BlobTx,
    frames: &mut Vec<Frame>,
) -> WireSvd {
    WireSvd {
        u: memo.mat(&svd.u, tx, frames),
        s: svd.s.clone(),
        v: memo.mat(&svd.v, tx, frames),
    }
}

struct SweepJobSource<'a> {
    configs: &'a [SweepConfig],
    cache: &'a LayerCache,
    prep_rank: usize,
    n_layers: usize,
    memo: EncodeMemo,
    /// `Some`: dispatch only these `(config, layer)` cells, with job id
    /// = subset index (the spill-resume path, which skips completed
    /// cells). `None`: the full dense grid, job id = `ci * n_layers +
    /// li`. The worker computes a pure function of the cell spec, so
    /// which subset a cell rides in never changes its result.
    cells: Option<&'a [(usize, usize)]>,
}

impl SweepJobSource<'_> {
    fn cell(&self, job: usize) -> (usize, usize) {
        match self.cells {
            Some(cells) => cells[job],
            None => (job / self.n_layers, job % self.n_layers),
        }
    }
}

impl JobSource for SweepJobSource<'_> {
    fn n_jobs(&self) -> usize {
        self.cells.map_or(self.n_layers * self.configs.len(), <[_]>::len)
    }

    fn encode(&self, job: usize, tx: &mut BlobTx) -> Vec<Frame> {
        let (ci, li) = self.cell(job);
        // ship the layer's resolved view, so heterogeneous cells never
        // reach the wire format (workers only ever see homogeneous
        // configs, exactly what the in-process fan-out executes)
        let c = self.configs[ci].resolved(li);
        let layer = &self.cache.layers[li];
        let arts = b2_artifacts(self.cache, li, &c);
        let memo = &self.memo;
        let mut frames = Vec::new();
        let w_ref = memo.mat(arts.w, tx, &mut frames);
        let scaling = match arts.scaling {
            Scaling::Identity => WireScaling::Identity,
            Scaling::Diagonal { d, d_inv } => {
                WireScaling::Diagonal { d: d.clone(), d_inv: d_inv.clone() }
            }
            Scaling::Full { s, s_inv } => WireScaling::Full {
                s: memo.mat(s, tx, &mut frames),
                s_inv: memo.mat(s_inv, tx, &mut frames),
            },
        };
        let msg = SweepJobMsg {
            job_id: job as u64,
            prep_rank: self.prep_rank,
            config: c.clone(),
            layer_name: layer.name.clone(),
            w: w_ref,
            scaling,
            hessian: arts.hessian.map(|h| memo.mat(h, tx, &mut frames)),
            qdeq0: arts.qdeq0.map(|m| memo.mat(m, tx, &mut frames)),
            qdeq0_packed: arts.qdeq0_packed.map(|p| memo.packed(p, tx, &mut frames)),
            resid: arts.resid.map(|svd| wire_svd(svd, memo, tx, &mut frames)),
            spectra: arts.spectra.map(|sp| WireSpectra {
                sw: wire_svd(&sp.sw_svd, memo, tx, &mut frames),
                sw_frob2: sp.sw_frob2,
                se: wire_svd(&sp.se_svd, memo, tx, &mut frames),
                se_frob2: sp.se_frob2,
                rank: sp.rank,
                seed: sp.seed,
            }),
        };
        frames.push(encode_sweep_job(&msg));
        frames
    }
}

/// Rebuild phase-B2 assembly parts from worker results (job-id order),
/// reproducing the in-process engine's `Arc` layout exactly:
///
/// * **w-only / plain-QER** results share the packed base through the
///   blob cache — which the runner seeded with the host's own
///   `LayerCache` `Arc`s — so every rank/scaling variant of a cell
///   aliases the very same buffer the in-process sweep would hand out
///   (grid dedup + lock-step groups);
/// * **every other** result gets a *fresh* `Arc` per result, because
///   the in-process path quantizes per config and never shares those —
///   even two byte-identical bases stay distinct, so pointer-based
///   fleet grouping cannot coarsen across the wire. Dense bases are
///   fresh per result for the same reason.
fn sweep_parts(
    msgs: Vec<ResultMsg>,
    rx: &BlobRx,
    configs: &[SweepConfig],
    names: &[String],
    n_layers: usize,
    prep: &SweepPrep,
) -> Result<Vec<(LinearOp, LayerMeta, LayerReport)>> {
    let n_configs = configs.len();
    let mut parts = Vec::with_capacity(msgs.len());
    for (idx, msg) in msgs.into_iter().enumerate() {
        let ResultMsg::Sweep(m) = msg else {
            anyhow::bail!("unexpected non-sweep result in a sweep batch")
        };
        debug_assert_eq!(m.job_id as usize, idx);
        let li = idx % n_layers;
        let shares_cell_base =
            matches!(configs[idx / n_layers].method, Method::WOnly | Method::Qer);
        let base = match m.base {
            WireBase::Packed(h) if shares_cell_base => QuantBase::Packed(rx.packed(h)?),
            WireBase::Packed(h) => QuantBase::Packed(Arc::new((*rx.packed(h)?).clone())),
            WireBase::Dense(h) => QuantBase::Dense(Arc::new((*rx.mat(h)?).clone())),
        };
        let op = LinearOp::FactoredQlr { base, l: m.l, r: m.r };
        let meta = LayerMeta { name: names[li].clone(), k_star: m.k_star, selection: m.selection };
        let report = LayerReport {
            name: names[li].clone(),
            k_star: m.k_star,
            weight_err: m.weight_err,
            scaled_err: m.scaled_err,
            // same amortization the in-process fan-out applies
            scale_secs: prep.cache.layers[li].prep_secs / n_configs as f64,
            qer_secs: m.qer_secs,
        };
        parts.push((op, meta, report));
    }
    Ok(parts)
}

/// [`SweepRunner`]'s multi-process counterpart: phase-A/B1 preparation
/// fans out as one job per layer, then phase B2 fans out per `(layer,
/// config)` cell — all over a [`ShardSession`]'s workers. Outcomes are
/// bit-identical to the in-process engine (module docs).
pub struct ShardedSweepRunner<'a> {
    params: &'a Params,
    model_cfg: &'a ModelCfg,
    calib: &'a CalibrationSet,
    metrics: &'a Metrics,
}

impl<'a> ShardedSweepRunner<'a> {
    /// A runner over one model + calibration set; `metrics` receives the
    /// `sweep.*` prep timings and `shard.*` transfer counters.
    pub fn new(
        params: &'a Params,
        model_cfg: &'a ModelCfg,
        calib: &'a CalibrationSet,
        metrics: &'a Metrics,
    ) -> Self {
        ShardedSweepRunner { params, model_cfg, calib, metrics }
    }

    /// Run the grid with phase-A/B1 prep *and* phase B2 sharded across
    /// `session`'s workers; one [`FactoredOutcome`] per config, aligned,
    /// bit-identical to [`SweepRunner::run_factored`].
    pub fn run_factored(
        &self,
        session: &mut ShardSession,
        configs: &[SweepConfig],
    ) -> Result<Vec<FactoredOutcome>> {
        let names = Params::linear_names(self.model_cfg);
        let n_layers = names.len();
        if configs.is_empty() || n_layers == 0 {
            return Ok(empty_outcomes(self.params, configs.len()));
        }
        let prep = self.sharded_prepare(session, configs, &names)?;

        // seed the host cache with the Arc'd artifacts being shipped, so
        // results that reference them come back as these very buffers
        {
            let mut rx = session.rx().lock().unwrap();
            for layer in &prep.cache.layers {
                for arc in layer.qdeq0.values() {
                    rx.seed_mat(arc);
                }
                for arc in layer.qdeq0_packed.values() {
                    rx.seed_packed(arc);
                }
            }
        }

        let src = SweepJobSource {
            configs,
            cache: &prep.cache,
            prep_rank: prep.prep_rank,
            n_layers,
            memo: EncodeMemo::default(),
            cells: None,
        };
        let t0 = Instant::now();
        let msgs = session.run_jobs(&src, self.metrics)?;
        self.metrics.add("shard.sweep_secs", t0.elapsed().as_secs_f64());

        let parts = {
            let rx = session.rx().lock().unwrap();
            sweep_parts(msgs, &rx, configs, &names, n_layers, &prep)?
        };
        Ok(assemble_outcomes(self.params, &names, configs.len(), parts, self.metrics))
    }

    /// [`ShardedSweepRunner::run_factored`] through a [`SpillStore`]:
    /// phase-A/B1 prep is reloaded from the store when complete (and
    /// sharded + spilled when not), only cells without a completion
    /// record are dispatched to workers, every result is spilled as it
    /// lands, and the outcomes are assembled entirely from the store —
    /// the same assembly the in-process spilled engine uses, so
    /// in-process, sharded, and killed-and-resumed runs all produce
    /// bit-identical outcomes.
    pub fn run_factored_spilled(
        &self,
        session: &mut ShardSession,
        configs: &[SweepConfig],
        store: &SpillStore,
    ) -> Result<Vec<FactoredOutcome>> {
        let names = Params::linear_names(self.model_cfg);
        let n_layers = names.len();
        if configs.is_empty() || n_layers == 0 {
            return Ok(empty_outcomes(self.params, configs.len()));
        }
        let keys = sweep_keys(configs, n_layers);
        let prep_rank = SweepRunner::prep_rank(configs);
        let fp = spill::sweep_fingerprint(self.model_cfg, &names, configs, prep_rank);
        store.begin(fp, n_layers, configs.len(), prep_rank)?;

        let cells: Vec<(usize, usize)> = (0..configs.len() * n_layers)
            .map(|idx| (idx / n_layers, idx % n_layers))
            .filter(|&(ci, li)| !store.cell_done(ci, li))
            .collect();
        if cells.is_empty() {
            // every cell already has a completion record (a resume after
            // phase B2 finished): assembly needs only the store
            let parts = store.assemble_parts(configs, &names)?;
            return Ok(assemble_outcomes(
                self.params,
                &names,
                configs.len(),
                parts,
                self.metrics,
            ));
        }

        let resid_jobs = keys.resid_jobs();
        let prep_complete = (0..n_layers).all(|li| store.prep_done(li))
            && resid_jobs.iter().all(|&(li, ri)| store.resid_done(li, ri));
        let prep = if prep_complete {
            // phases A + B1 are already on disk: rebuild the cache from
            // the store instead of re-running prep on the fleet
            let layers = (0..n_layers)
                .map(|li| store.load_layer(li, &keys.layers[li]))
                .collect::<Result<Vec<_>>>()?;
            let mut cache = LayerCache::new(layers);
            for &(li, ri) in &resid_jobs {
                let (label, kind, seed, _) = &keys.layers[li].resid_keys[ri];
                cache.insert_resid(li, label.clone(), *kind, *seed, store.load_resid(li, ri)?);
            }
            SweepPrep { cache, prep_rank }
        } else {
            let prep = self.sharded_prepare(session, configs, &names)?;
            for li in 0..n_layers {
                if !store.prep_done(li) {
                    store.spill_prep(li, &prep.cache.layers[li], &keys.layers[li], &keys.kinds)?;
                }
            }
            for &(li, ri) in &resid_jobs {
                if !store.resid_done(li, ri) {
                    let (label, kind, seed, _) = &keys.layers[li].resid_keys[ri];
                    let svd = prep
                        .cache
                        .resid(li, label, *kind, *seed)
                        .expect("resid prepared by sharded_prepare");
                    store.spill_resid(li, ri, svd)?;
                }
            }
            prep
        };

        // seed the host blob cache exactly as the unspilled path, so
        // shared-cell results resolve to the cache's own Arcs
        {
            let mut rx = session.rx().lock().unwrap();
            for layer in &prep.cache.layers {
                for arc in layer.qdeq0.values() {
                    rx.seed_mat(arc);
                }
                for arc in layer.qdeq0_packed.values() {
                    rx.seed_packed(arc);
                }
            }
        }
        let src = SweepJobSource {
            configs,
            cache: &prep.cache,
            prep_rank: prep.prep_rank,
            n_layers,
            memo: EncodeMemo::default(),
            cells: Some(&cells),
        };
        let t0 = Instant::now();
        let msgs = session.run_jobs(&src, self.metrics)?;
        self.metrics.add("shard.sweep_secs", t0.elapsed().as_secs_f64());
        {
            let rx = session.rx().lock().unwrap();
            for (j, msg) in msgs.into_iter().enumerate() {
                let ResultMsg::Sweep(m) = msg else {
                    anyhow::bail!("unexpected non-sweep result in a sweep batch")
                };
                debug_assert_eq!(m.job_id as usize, j);
                let (ci, li) = cells[j];
                // resolve the base out of the blob cache and spill it;
                // re-encoding reproduces the content hash the worker
                // shipped, so resumed runs address the same blob
                match m.base {
                    WireBase::Packed(h) => store.spill_cell(
                        ci,
                        li,
                        SpillBase::Packed(rx.packed(h)?.as_ref()),
                        &m.l,
                        &m.r,
                        m.k_star,
                        m.selection.as_ref(),
                        m.weight_err,
                        m.scaled_err,
                        m.qer_secs,
                    )?,
                    WireBase::Dense(h) => store.spill_cell(
                        ci,
                        li,
                        SpillBase::Dense(rx.mat(h)?.as_ref()),
                        &m.l,
                        &m.r,
                        m.k_star,
                        m.selection.as_ref(),
                        m.weight_err,
                        m.scaled_err,
                        m.qer_secs,
                    )?,
                }
            }
        }

        let parts = store.assemble_parts(configs, &names)?;
        Ok(assemble_outcomes(self.params, &names, configs.len(), parts, self.metrics))
    }

    /// Phase-A/B1 prep sharded across `session`, returning the rebuilt
    /// cache without running phase B2 — the budget planner's entry
    /// point ([`crate::coordinator::budget`]). A [`BudgetPlan`] is a
    /// pure function of this cache, and the cache is bit-identical to
    /// the in-process [`SweepRunner::prepare`]'s, so in-process and
    /// sharded plans match bit-for-bit.
    ///
    /// [`BudgetPlan`]: crate::coordinator::budget::BudgetPlan
    pub(crate) fn prepare(
        &self,
        session: &mut ShardSession,
        configs: &[SweepConfig],
    ) -> Result<SweepPrep> {
        let names = Params::linear_names(self.model_cfg);
        self.sharded_prepare(session, configs, &names)
    }

    /// Phases A + B1 as one shardable job per layer: the host computes
    /// what needs the calibration set (activation scalings, GPTQ
    /// Hessians) and ships it with `W`; workers run the *same*
    /// [`compute_qdeq0`] / [`compute_spectra`] / [`compute_resid_svd`]
    /// calls [`SweepRunner::prepare`] makes in-process, over the same
    /// deduped key lists ([`sweep_keys`]) — so the rebuilt
    /// [`LayerCache`] is bit-identical to the in-process one.
    pub(crate) fn sharded_prepare(
        &self,
        session: &mut ShardSession,
        configs: &[SweepConfig],
        names: &[String],
    ) -> Result<SweepPrep> {
        let keys = sweep_keys(configs, names.len());
        let prep_rank = SweepRunner::prep_rank(configs);

        // host half of phase A: everything that needs the calibration set
        let t_host = Instant::now();
        let host: Vec<HostPrep> = pool::par_map(names.len(), |i| {
            let name = &names[i];
            let t0 = Instant::now();
            let w = self.params.get_mat(name).expect("linear present");
            let mut scalings = HashMap::new();
            for &kind in &keys.kinds {
                scalings.insert(kind, Arc::new(self.calib.scaling_for(name, kind)));
            }
            let hessian = if keys.any_hessian {
                self.calib.quant_ctx(name, true, 0).hessian.map(Arc::new)
            } else {
                None
            };
            HostPrep { w, scalings, hessian, host_secs: t0.elapsed().as_secs_f64() }
        });
        self.metrics.add("sweep.scaling_cpu_secs", t_host.elapsed().as_secs_f64());

        let t0 = Instant::now();
        let msgs = {
            let src = PrepJobSource {
                names,
                keys: &keys,
                host: &host,
                memo: EncodeMemo::default(),
            };
            session.run_jobs(&src, self.metrics)?
        };
        self.metrics.add("shard.prep_secs", t0.elapsed().as_secs_f64());

        // rebuild the LayerCache from the result blobs; resolve under one
        // rx lock so every Arc comes from the shared cache (grid dedup)
        let mut resids: Vec<(usize, usize, Svd)> = Vec::new();
        let layers: Vec<PreparedLayer> = {
            let rx = session.rx().lock().unwrap();
            host.into_iter()
                .zip(msgs)
                .enumerate()
                .map(|(li, (hp, msg))| {
                    let ResultMsg::Prep(m) = msg else {
                        anyhow::bail!("unexpected non-prep result in a prep batch")
                    };
                    let lk = &keys.layers[li];
                    anyhow::ensure!(
                        m.qdeq0.len() == lk.qdeq0_keys.len()
                            && m.spectra.len() == lk.spectra_keys.len()
                            && m.resid.len() == lk.resid_keys.len(),
                        "prep result for layer {li} does not match that layer's key lists"
                    );
                    let mut qdeq0 = HashMap::new();
                    let mut qdeq0_packed = HashMap::new();
                    for ((label, seed, _), (dense, packed)) in
                        lk.qdeq0_keys.iter().zip(&m.qdeq0)
                    {
                        qdeq0.insert((label.clone(), *seed), rx.mat(*dense)?);
                        if let Some(p) = packed {
                            qdeq0_packed.insert((label.clone(), *seed), rx.packed(*p)?);
                        }
                    }
                    let mut spectra = HashMap::new();
                    for ((kind, seed), sp) in lk.spectra_keys.iter().zip(&m.spectra) {
                        spectra.insert(
                            (*kind, *seed),
                            Arc::new(PreparedSpectra {
                                sw_svd: Svd {
                                    u: (*rx.mat(sp.sw.u)?).clone(),
                                    s: sp.sw.s.clone(),
                                    v: (*rx.mat(sp.sw.v)?).clone(),
                                },
                                sw_frob2: sp.sw_frob2,
                                se_svd: Svd {
                                    u: (*rx.mat(sp.se.u)?).clone(),
                                    s: sp.se.s.clone(),
                                    v: (*rx.mat(sp.se.v)?).clone(),
                                },
                                se_frob2: sp.se_frob2,
                                rank: sp.rank,
                                seed: sp.seed,
                            }),
                        );
                    }
                    for (ri, sv) in m.resid.iter().enumerate() {
                        resids.push((
                            li,
                            ri,
                            Svd {
                                u: (*rx.mat(sv.u)?).clone(),
                                s: sv.s.clone(),
                                v: (*rx.mat(sv.v)?).clone(),
                            },
                        ));
                    }
                    Ok(PreparedLayer {
                        name: names[li].clone(),
                        w: hp.w,
                        scalings: hp.scalings,
                        hessian: hp.hessian,
                        qdeq0,
                        qdeq0_packed,
                        spectra,
                        prep_secs: hp.host_secs + m.prep_secs,
                    })
                })
                .collect::<Result<_>>()?
        };
        let mut cache = LayerCache::new(layers);
        for (li, ri, svd) in resids {
            let (label, kind, seed, _) = &keys.layers[li].resid_keys[ri];
            cache.insert_resid(li, label.clone(), *kind, *seed, svd);
        }
        self.metrics.add("sweep.prep_secs", t0.elapsed().as_secs_f64());
        Ok(SweepPrep { cache, prep_rank })
    }
}

/// Host-computed half of one layer's phase-A prep: the artifacts that
/// need the calibration set, which never leaves the host.
struct HostPrep {
    w: Mat,
    scalings: HashMap<ScalingKind, Arc<Scaling>>,
    hessian: Option<Arc<Mat>>,
    host_secs: f64,
}

/// One phase-A/B1 prep job per layer: ship `W` + scalings (+ Hessian)
/// and the grid's deduped key lists; the worker returns every k=0 base,
/// spectra pair, and shared residual SVD for that layer.
struct PrepJobSource<'a> {
    names: &'a [String],
    keys: &'a SweepKeys,
    host: &'a [HostPrep],
    memo: EncodeMemo,
}

impl JobSource for PrepJobSource<'_> {
    fn n_jobs(&self) -> usize {
        self.names.len()
    }

    fn encode(&self, job: usize, tx: &mut BlobTx) -> Vec<Frame> {
        let hp = &self.host[job];
        let memo = &self.memo;
        let mut frames = Vec::new();
        let w = memo.mat(&hp.w, tx, &mut frames);
        let scalings = self
            .keys
            .kinds
            .iter()
            .map(|&kind| {
                let ws = match hp.scalings.get(&kind).expect("scaling prepared").as_ref() {
                    Scaling::Identity => WireScaling::Identity,
                    Scaling::Diagonal { d, d_inv } => {
                        WireScaling::Diagonal { d: d.clone(), d_inv: d_inv.clone() }
                    }
                    Scaling::Full { s, s_inv } => WireScaling::Full {
                        s: memo.mat(s, tx, &mut frames),
                        s_inv: memo.mat(s_inv, tx, &mut frames),
                    },
                };
                (kind, ws)
            })
            .collect();
        let lk = &self.keys.layers[job];
        let msg = wire::PrepJobMsg {
            job_id: job as u64,
            layer_name: self.names[job].clone(),
            prep_rank: self.keys.prep_rank,
            w,
            scalings,
            hessian: hp.hessian.as_ref().map(|h| memo.mat(h, tx, &mut frames)),
            qdeq0: lk.qdeq0_keys.clone(),
            spectra: lk.spectra_keys.clone(),
            resid: lk.resid_keys.clone(),
        };
        frames.push(wire::encode_prep_job(&msg));
        frames
    }
}

// ---------------------------------------------------------------------------
// fleet sharding
// ---------------------------------------------------------------------------

fn wire_model(
    m: &FactoredModel,
    memo: &EncodeMemo,
    tx: &mut BlobTx,
    frames: &mut Vec<Frame>,
) -> WireModel {
    let skeleton = memo.params(&m.skeleton, tx, frames);
    let ops = m
        .ops
        .iter()
        .map(|(name, op)| {
            let wop = match op {
                LinearOp::Dense(w) => WireLinearOp::Dense(memo.mat(w, tx, frames)),
                LinearOp::FactoredQlr { base, l, r } => WireLinearOp::Factored {
                    base: match base {
                        QuantBase::Packed(p) => WireBase::Packed(memo.packed(p, tx, frames)),
                        QuantBase::Dense(d) => WireBase::Dense(memo.mat(d, tx, frames)),
                    },
                    l: memo.mat(l, tx, frames),
                    r: memo.mat(r, tx, frames),
                },
            };
            (name.clone(), wop)
        })
        .collect();
    WireModel { skeleton, ops }
}

struct FleetJobSource<'a> {
    models: &'a [&'a FactoredModel],
    groups: &'a [Vec<usize>],
    jobs: &'a [FleetJob],
    cfg: &'a ModelCfg,
    batches: &'a [Vec<i32>],
    b: usize,
    t: usize,
    memo: EncodeMemo,
}

impl JobSource for FleetJobSource<'_> {
    fn n_jobs(&self) -> usize {
        self.jobs.len()
    }

    fn encode(&self, job: usize, tx: &mut BlobTx) -> Vec<Frame> {
        let mut frames = Vec::new();
        let (lockstep, member_ids, batches): (bool, Vec<usize>, Vec<Vec<i32>>) =
            match self.jobs[job] {
                FleetJob::Single(mi) => (false, vec![mi], self.batches.to_vec()),
                FleetJob::GroupBatch(gi, bj) => {
                    (true, self.groups[gi].clone(), vec![self.batches[bj].clone()])
                }
            };
        let models = member_ids
            .iter()
            .map(|&mi| wire_model(self.models[mi], &self.memo, tx, &mut frames))
            .collect();
        let msg = FleetJobMsg {
            job_id: job as u64,
            lockstep,
            cfg: self.cfg.clone(),
            b: self.b,
            t: self.t,
            models,
            batches,
        };
        frames.push(encode_fleet_job(&msg));
        frames
    }
}

/// Lock-step batched perplexity with the `(group × batch)` jobs sharded
/// across `session`'s workers instead of the in-process pool. Grouping,
/// job layout, and the f64 reduce are shared with
/// [`fleet_perplexity`](crate::eval::fleet_perplexity), so the returned
/// PPLs are bit-identical to it.
pub fn fleet_perplexity_sharded(
    session: &mut ShardSession,
    models: &[&FactoredModel],
    cfg: &ModelCfg,
    batches: &[Vec<i32>],
    b: usize,
    t: usize,
    metrics: &Metrics,
) -> Result<Vec<f64>> {
    let groups = group_by_shared_bases(models);
    let jobs = fleet_job_list(&groups, batches.len());
    if jobs.is_empty() {
        return Ok(reduce_fleet_results(models.len(), &groups, &jobs, vec![]));
    }
    let src = FleetJobSource {
        models,
        groups: &groups,
        jobs: &jobs,
        cfg,
        batches,
        b,
        t,
        memo: EncodeMemo::default(),
    };
    let t0 = Instant::now();
    let msgs = session.run_jobs(&src, metrics)?;
    metrics.add("shard.fleet_secs", t0.elapsed().as_secs_f64());
    let outs = msgs
        .into_iter()
        .map(|m| match m {
            ResultMsg::Fleet(f) => Ok(match f.out {
                FleetOut::Ppl(p) => FleetJobResult::Ppl(p),
                FleetOut::Partials(p) => FleetJobResult::Partials(p),
            }),
            ResultMsg::Sweep(_) | ResultMsg::Prep(_) => {
                Err(anyhow::anyhow!("unexpected non-fleet result in a fleet batch"))
            }
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(reduce_fleet_results(models.len(), &groups, &jobs, outs))
}

// ---------------------------------------------------------------------------
// the worker side
// ---------------------------------------------------------------------------

enum WorkMsg {
    Sweep(Box<SweepJobMsg>),
    Fleet(Box<FleetJobMsg>),
    Prep(Box<wire::PrepJobMsg>),
}

impl WorkMsg {
    fn job_id(&self) -> u64 {
        match self {
            WorkMsg::Sweep(m) => m.job_id,
            WorkMsg::Fleet(m) => m.job_id,
            WorkMsg::Prep(m) => m.job_id,
        }
    }
}

/// Execute one phase-A/B1 prep job — the same compute calls
/// [`SweepRunner::prepare`] makes in-process, over the job's key lists,
/// in the same order (bit-identity contract).
fn run_prep_job(
    msg: &wire::PrepJobMsg,
    rx: &Mutex<BlobRx>,
    tx: &Mutex<BlobTx>,
) -> Result<Vec<Frame>, wire::WireError> {
    // resolve inputs under a short rx lock (never hold rx and tx
    // together: the reader thread locks rx then tx)
    let (w, scalings, hessian) = {
        let rx = rx.lock().unwrap();
        let w = rx.mat(msg.w)?;
        let scalings = msg
            .scalings
            .iter()
            .map(|(kind, ws)| {
                let s = match ws {
                    WireScaling::Identity => Scaling::Identity,
                    WireScaling::Diagonal { d, d_inv } => {
                        Scaling::Diagonal { d: d.clone(), d_inv: d_inv.clone() }
                    }
                    WireScaling::Full { s, s_inv } => Scaling::Full {
                        s: (*rx.mat(*s)?).clone(),
                        s_inv: (*rx.mat(*s_inv)?).clone(),
                    },
                };
                Ok((*kind, s))
            })
            .collect::<Result<Vec<(ScalingKind, Scaling)>, wire::WireError>>()?;
        let hessian = msg.hessian.map(|h| rx.mat(h)).transpose()?;
        (w, scalings, hessian)
    };

    let t0 = Instant::now();
    let salt = layer_salt(&msg.layer_name);
    let scaling_of = |kind: ScalingKind| -> Result<&Scaling, wire::WireError> {
        scalings
            .iter()
            .find(|(k, _)| *k == kind)
            .map(|(_, s)| s)
            .ok_or(wire::WireError::Malformed("prep job missing a scaling kind"))
    };

    let qdeq0: Vec<(Mat, Option<PackedMat>)> = msg
        .qdeq0
        .iter()
        .map(|(_, seed, spec)| compute_qdeq0(&w, hessian.as_deref(), spec, *seed, salt))
        .collect();
    let spectra = msg
        .spectra
        .iter()
        .map(|(kind, seed)| {
            Ok(compute_spectra(&w, scaling_of(*kind)?, msg.prep_rank, *seed, salt))
        })
        .collect::<Result<Vec<PreparedSpectra>, wire::WireError>>()?;
    let resid = msg
        .resid
        .iter()
        .map(|(label, kind, seed, _)| {
            let qdeq = msg
                .qdeq0
                .iter()
                .position(|(l, s, _)| l == label && s == seed)
                .map(|i| &qdeq0[i].0)
                .ok_or(wire::WireError::Malformed("prep job resid without its qdeq0"))?;
            Ok(compute_resid_svd(&w, qdeq, scaling_of(*kind)?, msg.prep_rank, *seed, salt))
        })
        .collect::<Result<Vec<Svd>, wire::WireError>>()?;
    let prep_secs = t0.elapsed().as_secs_f64();

    let mut frames = Vec::new();
    let mut tx = tx.lock().unwrap();
    let out = wire::PrepResultMsg {
        job_id: msg.job_id,
        qdeq0: qdeq0
            .iter()
            .map(|(dense, packed)| {
                (
                    tx.mat_ref(dense, &mut frames),
                    packed.as_ref().map(|p| tx.packed_ref(p, &mut frames)),
                )
            })
            .collect(),
        spectra: spectra
            .iter()
            .map(|sp| WireSpectra {
                sw: WireSvd {
                    u: tx.mat_ref(&sp.sw_svd.u, &mut frames),
                    s: sp.sw_svd.s.clone(),
                    v: tx.mat_ref(&sp.sw_svd.v, &mut frames),
                },
                sw_frob2: sp.sw_frob2,
                se: WireSvd {
                    u: tx.mat_ref(&sp.se_svd.u, &mut frames),
                    s: sp.se_svd.s.clone(),
                    v: tx.mat_ref(&sp.se_svd.v, &mut frames),
                },
                se_frob2: sp.se_frob2,
                rank: sp.rank,
                seed: sp.seed,
            })
            .collect(),
        resid: resid
            .iter()
            .map(|sv| WireSvd {
                u: tx.mat_ref(&sv.u, &mut frames),
                s: sv.s.clone(),
                v: tx.mat_ref(&sv.v, &mut frames),
            })
            .collect(),
        prep_secs,
    };
    frames.push(wire::encode_prep_result(&out));
    Ok(frames)
}

/// Execute one sweep job from wire artifacts — the same
/// [`b2_job`](super::sweep) the in-process fan-out runs.
fn run_sweep_job(
    msg: &SweepJobMsg,
    rx: &Mutex<BlobRx>,
    tx: &Mutex<BlobTx>,
) -> Result<Vec<Frame>, wire::WireError> {
    // resolve shared artifacts (clone the Arcs out under a short lock)
    let (w, scaling, hessian, qdeq0, qdeq0_packed, resid, spectra) = {
        let rx = rx.lock().unwrap();
        let w = rx.mat(msg.w)?;
        let scaling = match &msg.scaling {
            WireScaling::Identity => Scaling::Identity,
            WireScaling::Diagonal { d, d_inv } => {
                Scaling::Diagonal { d: d.clone(), d_inv: d_inv.clone() }
            }
            WireScaling::Full { s, s_inv } => Scaling::Full {
                s: (*rx.mat(*s)?).clone(),
                s_inv: (*rx.mat(*s_inv)?).clone(),
            },
        };
        let hessian = msg.hessian.map(|h| rx.mat(h)).transpose()?;
        let qdeq0 = msg.qdeq0.map(|h| rx.mat(h)).transpose()?;
        let qdeq0_packed = msg.qdeq0_packed.map(|h| rx.packed(h)).transpose()?;
        let resid = msg
            .resid
            .as_ref()
            .map(|sv| {
                Ok::<Svd, wire::WireError>(Svd {
                    u: (*rx.mat(sv.u)?).clone(),
                    s: sv.s.clone(),
                    v: (*rx.mat(sv.v)?).clone(),
                })
            })
            .transpose()?;
        let spectra = msg
            .spectra
            .as_ref()
            .map(|sp| {
                Ok::<PreparedSpectra, wire::WireError>(PreparedSpectra {
                    sw_svd: Svd {
                        u: (*rx.mat(sp.sw.u)?).clone(),
                        s: sp.sw.s.clone(),
                        v: (*rx.mat(sp.sw.v)?).clone(),
                    },
                    sw_frob2: sp.sw_frob2,
                    se_svd: Svd {
                        u: (*rx.mat(sp.se.u)?).clone(),
                        s: sp.se.s.clone(),
                        v: (*rx.mat(sp.se.v)?).clone(),
                    },
                    se_frob2: sp.se_frob2,
                    rank: sp.rank,
                    seed: sp.seed,
                })
            })
            .transpose()?;
        (w, scaling, hessian, qdeq0, qdeq0_packed, resid, spectra)
    };

    let arts = B2Artifacts {
        name: &msg.layer_name,
        w: &w,
        scaling: &scaling,
        hessian: hessian.as_deref(),
        qdeq0: qdeq0.as_deref(),
        qdeq0_packed: qdeq0_packed.as_ref(),
        resid: resid.as_ref(),
        spectra: spectra.as_ref(),
    };
    let (res, report) = b2_job(&msg.config, msg.prep_rank, &arts);

    let mut frames = Vec::new();
    let mut tx = tx.lock().unwrap();
    let base = match &res.packed {
        Some(p) => WireBase::Packed(tx.packed_ref(p, &mut frames)),
        None => WireBase::Dense(tx.mat_ref(&res.qdeq, &mut frames)),
    };
    let out = SweepResultMsg {
        job_id: msg.job_id,
        base,
        l: res.l,
        r: res.r,
        k_star: res.k_star,
        selection: res.selection,
        weight_err: report.weight_err,
        scaled_err: report.scaled_err,
        qer_secs: report.qer_secs,
    };
    frames.push(encode_sweep_result(&out));
    Ok(frames)
}

fn build_model(wm: &WireModel, rx: &BlobRx) -> Result<FactoredModel, wire::WireError> {
    let skeleton = (*rx.params(wm.skeleton)?).clone();
    let mut ops = Vec::with_capacity(wm.ops.len());
    for (name, op) in &wm.ops {
        let lop = match op {
            WireLinearOp::Dense(h) => LinearOp::Dense((*rx.mat(*h)?).clone()),
            WireLinearOp::Factored { base, l, r } => LinearOp::FactoredQlr {
                base: match base {
                    // shared Arc from the blob cache: group members alias
                    // one buffer, so matmul_grouped's lock-step path fires
                    WireBase::Packed(h) => QuantBase::Packed(rx.packed(*h)?),
                    // fresh Arc per op, mirroring in-process dense bases
                    // (never shared between outcomes)
                    WireBase::Dense(h) => QuantBase::Dense(Arc::new((*rx.mat(*h)?).clone())),
                },
                l: (*rx.mat(*l)?).clone(),
                r: (*rx.mat(*r)?).clone(),
            },
        };
        ops.push((name.clone(), lop));
    }
    Ok(FactoredModel { skeleton, ops })
}

/// Execute one fleet job: a singleton's whole-stream PPL or one
/// lock-step `(group, batch)` slice — the same code paths
/// `eval::fleet::fleet_perplexity` runs in-process.
fn run_fleet_job(msg: &FleetJobMsg, rx: &Mutex<BlobRx>) -> Result<FleetResultMsg, wire::WireError> {
    let models: Vec<FactoredModel> = {
        let rx = rx.lock().unwrap();
        msg.models.iter().map(|wm| build_model(wm, &rx)).collect::<Result<_, _>>()?
    };
    if models.is_empty() || (msg.lockstep && msg.batches.len() != 1) {
        return Err(wire::WireError::Malformed("inconsistent fleet job"));
    }
    let mask = vec![1.0f32; msg.b * msg.t];
    let out = if msg.lockstep {
        let refs: Vec<&FactoredModel> = models.iter().collect();
        let fleet = FleetGroup::new(refs);
        // a malformed fleet fails this job's frame, not the worker
        // process (the host surfaces it like any other wire error)
        let parts = lm_nll_fleet(&fleet, &msg.cfg, &msg.batches[0], &mask, msg.b, msg.t)
            .map_err(|_| wire::WireError::Malformed("malformed fleet group"))?;
        FleetOut::Partials(parts)
    } else {
        FleetOut::Ppl(perplexity_native_masked(
            &models[0],
            &msg.cfg,
            &msg.batches,
            &mask,
            msg.b,
            msg.t,
        ))
    };
    Ok(FleetResultMsg { job_id: msg.job_id, out })
}

/// The worker loop over arbitrary transports (stdin/stdout in
/// production; in-memory buffers in the loopback tests), beating at the
/// default [`DEFAULT_HEARTBEAT`] cadence.
pub fn run_worker<R, W>(input: R, output: W, exit_after: Option<usize>) -> Result<()>
where
    R: Read + Send + 'static,
    W: Write + Send + 'static,
{
    run_worker_paced(input, output, exit_after, DEFAULT_HEARTBEAT)
}

/// [`run_worker`] with an explicit heartbeat cadence (the
/// `--heartbeat-secs` CLI flag; tests drive it down to milliseconds).
///
/// Four threads: a reader decoding frames into a bounded job queue, the
/// caller's thread computing, a writer flushing result frames, and a
/// heartbeat ticker that emits one [`kind::HEARTBEAT`] frame per
/// enqueued-or-computing job every `heartbeat` — the host renews that
/// job's wedge deadline on each beat, so only a genuinely stalled
/// worker (not a slow one) gets requeued. The bounded queues are the
/// backpressure: a slow worker stops reading, the pipe fills, and the
/// host's feeder blocks instead of ballooning memory. `exit_after` is
/// the fault-injection hook behind the `--exit-after` CLI flag: the
/// worker stops (abruptly, from the host's point of view) after
/// completing that many jobs.
pub fn run_worker_paced<R, W>(
    input: R,
    output: W,
    exit_after: Option<usize>,
    heartbeat: Duration,
) -> Result<()>
where
    R: Read + Send + 'static,
    W: Write + Send + 'static,
{
    let rx = Arc::new(Mutex::new(BlobRx::new()));
    let tx = Arc::new(Mutex::new(BlobTx::new()));
    let jobs: Arc<BoundedQueue<WorkMsg>> = Arc::new(BoundedQueue::new(WORKER_QUEUE_CAP));
    let results: Arc<BoundedQueue<Vec<Frame>>> = Arc::new(BoundedQueue::new(WORKER_QUEUE_CAP));
    // job ids accepted but not yet completed — what the ticker beats for
    let inflight: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));

    let reader = {
        let rx = rx.clone();
        let tx = tx.clone();
        let jobs = jobs.clone();
        let inflight = inflight.clone();
        std::thread::spawn(move || {
            // buffer the read half: a raw TcpStream would otherwise pay
            // three read syscalls per frame (header, payload, checksum)
            let mut input = BufReader::new(input);
            // record the id *before* the (blocking) queue push: a job
            // waiting for queue space is in flight from the host's view
            // and must beat like one
            let accept = |id: u64, m: WorkMsg| {
                inflight.lock().unwrap().push(id);
                jobs.push(m)
            };
            loop {
                match wire::read_frame(&mut input) {
                    Ok(Some(f)) => match f.kind {
                        kind::SHUTDOWN => break,
                        kind::BLOB_MAT | kind::BLOB_PACKED | kind::BLOB_PARAMS => {
                            match rx.lock().unwrap().insert(f.kind, &f.payload) {
                                // referencing a host-sent blob back needs
                                // no re-upload
                                Ok(h) => tx.lock().unwrap().mark_seen(h),
                                Err(_) => break,
                            }
                        }
                        kind::SWEEP_JOB => match decode_sweep_job(&f.payload) {
                            Ok(m) => {
                                if !accept(m.job_id, WorkMsg::Sweep(Box::new(m))) {
                                    break;
                                }
                            }
                            Err(_) => break,
                        },
                        kind::FLEET_JOB => match decode_fleet_job(&f.payload) {
                            Ok(m) => {
                                if !accept(m.job_id, WorkMsg::Fleet(Box::new(m))) {
                                    break;
                                }
                            }
                            Err(_) => break,
                        },
                        kind::PREP_JOB => match wire::decode_prep_job(&f.payload) {
                            Ok(m) => {
                                if !accept(m.job_id, WorkMsg::Prep(Box::new(m))) {
                                    break;
                                }
                            }
                            Err(_) => break,
                        },
                        _ => break,
                    },
                    Ok(None) | Err(_) => break,
                }
            }
            jobs.close();
        })
    };

    let writer = {
        let results = results.clone();
        std::thread::spawn(move || {
            let mut out = BufWriter::new(output);
            while let Some(frames) = results.pop() {
                for fr in &frames {
                    if fr.write_to(&mut out).is_err() {
                        // close the queue so the compute loop's next push
                        // fails instead of blocking forever against a
                        // writer that is gone (a remote host that
                        // disconnected mid-results must not wedge the
                        // worker process)
                        results.close();
                        return;
                    }
                }
                if out.flush().is_err() {
                    results.close();
                    return;
                }
            }
            let _ = out.flush();
        })
    };

    let hb_stop = Arc::new(AtomicBool::new(false));
    let ticker = {
        let hb_stop = hb_stop.clone();
        let inflight = inflight.clone();
        let results = results.clone();
        std::thread::spawn(move || loop {
            std::thread::sleep(heartbeat);
            if hb_stop.load(Ordering::Acquire) {
                return;
            }
            // snapshot, then push without the lock: a beat must never
            // block the compute loop's completion bookkeeping
            let ids: Vec<u64> = inflight.lock().unwrap().clone();
            for id in ids {
                if !results.push(vec![wire::encode_heartbeat(id)]) {
                    return; // teardown
                }
            }
        })
    };

    let mut done = 0usize;
    while let Some(job) = jobs.pop() {
        let id = job.job_id();
        let frames = match job {
            WorkMsg::Sweep(m) => run_sweep_job(&m, &rx, &tx)?,
            WorkMsg::Fleet(m) => vec![encode_fleet_result(&run_fleet_job(&m, &rx)?)],
            WorkMsg::Prep(m) => run_prep_job(&m, &rx, &tx)?,
        };
        let pushed = results.push(frames);
        // only stop beating for a job whose result actually queued
        inflight.lock().unwrap().retain(|&j| j != id);
        if !pushed {
            break;
        }
        done += 1;
        if exit_after == Some(done) {
            break;
        }
    }
    hb_stop.store(true, Ordering::Release);
    jobs.close();
    results.close();
    let _ = writer.join();
    // the reader and ticker may be blocked (on a live input / mid-sleep);
    // both exit on queue close, EOF, or process exit — never join them
    drop(reader);
    drop(ticker);
    Ok(())
}

/// Entry point behind `srr shard-worker`: speak the wire codec over
/// stdin/stdout (default), over a dialed-out TCP connection
/// (`--connect host:port`, optionally presenting `--token N` so a host
/// that spawned this process can map the dial-in back to it), or over a
/// single accepted connection (`--listen host:port`) until shutdown or
/// EOF. `--heartbeat-secs S` sets the per-job heartbeat cadence
/// (fractional seconds; default [`DEFAULT_HEARTBEAT`]); `--exit-after N`
/// is the fault-injection hook the requeue tests use.
pub fn worker_main(args: &Args) -> Result<()> {
    let exit_after = args.get("exit-after").and_then(|s| s.parse::<usize>().ok());
    let heartbeat = Duration::from_secs_f64(
        args.get_f64("heartbeat-secs", DEFAULT_HEARTBEAT.as_secs_f64()).max(0.05),
    );
    if let Some(addr) = args.get("connect") {
        let stream = worker_connect(addr, args.get_u64("token", 0))?;
        let input = stream.try_clone().context("cloning TCP read half")?;
        return run_worker_paced(input, stream, exit_after, heartbeat);
    }
    if let Some(addr) = args.get("listen") {
        let stream = worker_accept(addr)?;
        let input = stream.try_clone().context("cloning TCP read half")?;
        return run_worker_paced(input, stream, exit_after, heartbeat);
    }
    run_worker_paced(std::io::stdin(), std::io::stdout(), exit_after, heartbeat)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{QuantizerSpec, SweepConfig};
    use crate::data::Corpus;
    use crate::eval::fleet_perplexity;
    use crate::model::{collect_calibration, synth::synth_lm_params};
    use crate::qer::Method;
    use crate::scaling::ScalingKind;
    use std::io::Cursor;

    /// An in-memory `Write` whose contents the test can inspect after
    /// the worker's writer thread finishes.
    #[derive(Clone, Default)]
    struct SharedBuf(Arc<Mutex<Vec<u8>>>);

    impl Write for SharedBuf {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    fn setup() -> (Params, ModelCfg, CalibrationSet) {
        let cfg = ModelCfg {
            name: "t".into(),
            vocab: 64,
            d_model: 64,
            n_heads: 2,
            n_layers: 2,
            d_ff: 128,
            seq_len: 16,
        };
        let params = synth_lm_params(&cfg, 5, cfg.vocab);
        let corpus = Corpus::generate(cfg.vocab, 4000, 6);
        let batches: Vec<Vec<i32>> = (0..10).map(|i| corpus.train_batch(2, 16, i)).collect();
        let calib = collect_calibration(&params, &cfg, &batches, 2, 16, 192);
        (params, cfg, calib)
    }

    fn grid() -> Vec<SweepConfig> {
        let mx = QuantizerSpec::Mxint { bits: 3, block: 32 };
        vec![
            // w-only + two QER ranks of one cell: shared packed base
            SweepConfig::new(mx, Method::WOnly, 0, ScalingKind::Identity),
            SweepConfig::new(mx, Method::Qer, 4, ScalingKind::DiagRms),
            SweepConfig::new(mx, Method::Qer, 8, ScalingKind::DiagRms),
            // SRR family with its own quantization, plus a Hessian path
            SweepConfig::new(mx, Method::QerSrr, 8, ScalingKind::Exact).seeded(5),
            SweepConfig::new(
                QuantizerSpec::Gptq { bits: 3, group: 64 },
                Method::QerSrr,
                8,
                ScalingKind::DiagAbsMean,
            ),
        ]
    }

    fn assert_outcomes_identical(a: &[FactoredOutcome], b: &[FactoredOutcome]) {
        assert_eq!(a.len(), b.len());
        for (oa, ob) in a.iter().zip(b) {
            assert_eq!(oa.model.ops.len(), ob.model.ops.len());
            for (((na, opa), (nb, opb)), (ma, mb)) in
                oa.model.ops.iter().zip(&ob.model.ops).zip(oa.meta.iter().zip(&ob.meta))
            {
                assert_eq!(na, nb);
                assert_eq!(ma.k_star, mb.k_star, "{na}: k* differs");
                match (opa, opb) {
                    (
                        LinearOp::FactoredQlr { base: ba, l: la, r: ra },
                        LinearOp::FactoredQlr { base: bb, l: lb, r: rb },
                    ) => {
                        assert_eq!(la, lb, "{na}: L differs");
                        assert_eq!(ra, rb, "{na}: R differs");
                        assert_eq!(ba.densify(), bb.densify(), "{na}: base differs");
                        assert_eq!(
                            matches!(ba, QuantBase::Packed(_)),
                            matches!(bb, QuantBase::Packed(_)),
                            "{na}: packedness differs"
                        );
                    }
                    _ => panic!("{na}: unexpected op shape"),
                }
            }
            for (ra, rb) in oa.reports.iter().zip(&ob.reports) {
                assert_eq!(ra.weight_err.to_bits(), rb.weight_err.to_bits());
                assert_eq!(ra.scaled_err.to_bits(), rb.scaled_err.to_bits());
            }
        }
    }

    /// Tentpole (hermetic half): drive `run_worker` over in-memory pipes
    /// with real sweep + fleet jobs and check the results merge
    /// bit-identical to the in-process engines — no processes involved,
    /// so this runs even where spawning is unavailable.
    #[test]
    fn worker_loopback_matches_in_process_sweep_and_fleet() {
        let (params, cfg, calib) = setup();
        let configs = grid();
        let metrics = Metrics::new();
        let runner = SweepRunner::new(&params, &cfg, &calib, &metrics);
        let expect = runner.run_factored(&configs);
        let prep = runner.prepare(&configs);
        let names = Params::linear_names(&cfg);
        let n_layers = names.len();

        // ---- sweep jobs through the worker loop ------------------------
        let src = SweepJobSource {
            configs: &configs,
            cache: &prep.cache,
            prep_rank: prep.prep_rank,
            n_layers,
            memo: EncodeMemo::default(),
        };
        let mut tx = BlobTx::new();
        let mut input = Vec::new();
        for j in 0..src.n_jobs() {
            for f in src.encode(j, &mut tx) {
                f.write_to(&mut input).unwrap();
            }
        }
        shutdown_frame().write_to(&mut input).unwrap();
        let out = SharedBuf::default();
        run_worker(Cursor::new(input), out.clone(), None).unwrap();

        // host-side merge: seed the cache like the sharded runner does
        let mut rx = BlobRx::new();
        for layer in &prep.cache.layers {
            for a in layer.qdeq0.values() {
                rx.seed_mat(a);
            }
            for a in layer.qdeq0_packed.values() {
                rx.seed_packed(a);
            }
        }
        let bytes = out.0.lock().unwrap().clone();
        let mut msgs: Vec<Option<SweepResultMsg>> = (0..src.n_jobs()).map(|_| None).collect();
        let mut cur = Cursor::new(&bytes[..]);
        while let Some(f) = wire::read_frame(&mut cur).unwrap() {
            match f.kind {
                kind::BLOB_MAT | kind::BLOB_PACKED | kind::BLOB_PARAMS => {
                    rx.insert(f.kind, &f.payload).unwrap();
                }
                kind::SWEEP_RESULT => {
                    let m = decode_sweep_result(&f.payload).unwrap();
                    let id = m.job_id as usize;
                    assert!(msgs[id].is_none(), "duplicate result {id}");
                    msgs[id] = Some(m);
                }
                kind::HEARTBEAT => {} // slow CI: a job outlived a cadence
                other => panic!("unexpected frame kind {other}"),
            }
        }
        let msgs: Vec<ResultMsg> = msgs
            .into_iter()
            .map(|m| ResultMsg::Sweep(Box::new(m.expect("job completed"))))
            .collect();
        let parts =
            sweep_parts(msgs, &rx, &configs, &names, n_layers, &prep).unwrap();
        let got = assemble_outcomes(&params, &names, configs.len(), parts, &metrics);
        assert_outcomes_identical(&expect, &got);

        // grid dedup survives the wire: the w-only + QER rank variants
        // still alias one base per layer, and the sharded merge resolves
        // it to the host cache's own Arc
        let exp_models: Vec<&FactoredModel> = expect.iter().map(|o| &o.model).collect();
        let got_models: Vec<&FactoredModel> = got.iter().map(|o| &o.model).collect();
        let exp_groups = group_by_shared_bases(&exp_models);
        let got_groups = group_by_shared_bases(&got_models);
        assert_eq!(exp_groups, got_groups, "lock-step grouping changed across the wire");
        assert!(exp_groups.iter().any(|g| g.len() == 3), "expected a 3-member cell group");

        // ---- fleet jobs through the worker loop ------------------------
        let corpus = Corpus::generate(cfg.vocab, 4000, 7);
        let batches: Vec<Vec<i32>> =
            (0..3).map(|i| corpus.train_batch(2, cfg.seq_len, 50 + i)).collect();
        let (b, t) = (2usize, cfg.seq_len);
        let solo = fleet_perplexity(&got_models, &cfg, &batches, b, t).expect("fleet");

        let groups = group_by_shared_bases(&got_models);
        let jobs = fleet_job_list(&groups, batches.len());
        let fsrc = FleetJobSource {
            models: &got_models,
            groups: &groups,
            jobs: &jobs,
            cfg: &cfg,
            batches: &batches,
            b,
            t,
            memo: EncodeMemo::default(),
        };
        let mut ftx = BlobTx::new();
        let mut finput = Vec::new();
        for j in 0..fsrc.n_jobs() {
            for f in fsrc.encode(j, &mut ftx) {
                f.write_to(&mut finput).unwrap();
            }
        }
        shutdown_frame().write_to(&mut finput).unwrap();
        let fout = SharedBuf::default();
        run_worker(Cursor::new(finput), fout.clone(), None).unwrap();

        let fbytes = fout.0.lock().unwrap().clone();
        let mut fres: Vec<Option<FleetResultMsg>> = (0..jobs.len()).map(|_| None).collect();
        let mut cur = Cursor::new(&fbytes[..]);
        while let Some(f) = wire::read_frame(&mut cur).unwrap() {
            if f.kind == kind::FLEET_RESULT {
                let m = decode_fleet_result(&f.payload).unwrap();
                fres[m.job_id as usize] = Some(m);
            }
        }
        let outs: Vec<FleetJobResult> = fres
            .into_iter()
            .map(|m| match m.expect("job completed").out {
                FleetOut::Ppl(p) => FleetJobResult::Ppl(p),
                FleetOut::Partials(p) => FleetJobResult::Partials(p),
            })
            .collect();
        let sharded = reduce_fleet_results(got_models.len(), &groups, &jobs, outs);
        assert_eq!(solo.len(), sharded.len());
        for (i, (a, b)) in solo.iter().zip(&sharded).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "model {i}: ppl {a} vs {b}");
        }
    }

    #[test]
    fn worker_exit_after_truncates_results_cleanly() {
        let (params, cfg, calib) = setup();
        let configs = grid();
        let metrics = Metrics::new();
        let runner = SweepRunner::new(&params, &cfg, &calib, &metrics);
        let prep = runner.prepare(&configs);
        let names = Params::linear_names(&cfg);
        let src = SweepJobSource {
            configs: &configs,
            cache: &prep.cache,
            prep_rank: prep.prep_rank,
            n_layers: names.len(),
            memo: EncodeMemo::default(),
        };
        let mut tx = BlobTx::new();
        let mut input = Vec::new();
        for j in 0..src.n_jobs() {
            for f in src.encode(j, &mut tx) {
                f.write_to(&mut input).unwrap();
            }
        }
        // no shutdown frame: the worker dies by exit_after, as in a crash
        let out = SharedBuf::default();
        run_worker(Cursor::new(input), out.clone(), Some(3)).unwrap();
        let bytes = out.0.lock().unwrap().clone();
        let mut n_results = 0;
        let mut cur = Cursor::new(&bytes[..]);
        while let Some(f) = wire::read_frame(&mut cur).unwrap() {
            if f.kind == kind::SWEEP_RESULT {
                n_results += 1;
            }
        }
        assert_eq!(n_results, 3, "exactly exit_after results, all complete frames");
    }

    #[test]
    fn worker_binary_env_override_wins() {
        let opts = ShardOptions {
            binary: Some(PathBuf::from("/explicit/srr")),
            ..Default::default()
        };
        assert_eq!(worker_binary(&opts).unwrap(), PathBuf::from("/explicit/srr"));
    }

    // -----------------------------------------------------------------------
    // fault injection (satellite: FaultTransport property suite)
    // -----------------------------------------------------------------------

    use crate::coordinator::jobs::byte_pipe;
    use crate::coordinator::transport::{FaultPlan, FaultTransport, Transport};
    use crate::util::prop;

    /// A worker on a thread behind in-memory pipes, with `plan`
    /// interposed on the host side of both directions. Beats fast
    /// (100ms) so tests can run with short wedge deadlines.
    fn fault_worker(plan: FaultPlan) -> Box<dyn Transport> {
        let (host_to_worker, worker_input) = byte_pipe(1 << 16);
        let (worker_output, worker_to_host) = byte_pipe(1 << 16);
        std::thread::spawn(move || {
            // errors are the host's problem: a severed pipe here is the
            // crash being simulated
            let _ = run_worker_paced(
                worker_input,
                worker_output,
                None,
                Duration::from_millis(100),
            );
        });
        Box::new(FaultTransport::new(host_to_worker, worker_to_host, plan))
    }

    /// One seeded fault schedule. Corruption severs the stream right
    /// after the corrupted byte: a flip landing in a frame's *header
    /// length field* (not covered by the payload checksum) would
    /// otherwise leave the host parser waiting for bytes the worker
    /// will never send — an unbounded stall `poll_dead` cannot see.
    /// With the cut at `at + 1` every corrupted stream terminates, and
    /// the parser observes the damage as `Truncated`/`BadChecksum`
    /// either way (the dedicated transport unit tests cover the pure
    /// checksum path deterministically).
    fn random_plan(g: &mut prop::Gen) -> FaultPlan {
        match g.rng.below(7) {
            0 => FaultPlan::default(),
            1 => FaultPlan {
                chop: 1 + g.rng.below(7),
                flush_delay: Duration::from_millis(g.rng.below(3) as u64),
                ..Default::default()
            },
            2 => FaultPlan {
                cut_tx_after: Some(g.rng.below(200_000) as u64),
                chop: g.rng.below(9),
                ..Default::default()
            },
            3 => FaultPlan {
                cut_rx_after: Some(g.rng.below(100_000) as u64),
                ..Default::default()
            },
            4 => {
                let at = g.rng.below(100_000) as u64;
                FaultPlan {
                    corrupt_rx: Some((at, 1 << g.rng.below(8))),
                    cut_rx_after: Some(at + 1),
                    ..Default::default()
                }
            }
            // silent stall: the socket stays open but no byte (result or
            // heartbeat) arrives — only the wedge deadline can clear it
            5 => FaultPlan {
                stall_rx_after: Some(g.rng.below(150_000) as u64),
                ..Default::default()
            },
            // stall-then-resume, straddling the 1500ms wedge deadline:
            // either the stall is absorbed (just a slow worker) or the
            // peer wakes after the host wedged it, and its late frames
            // must be rejected, not merged
            _ => FaultPlan {
                stall_rx_after: Some(g.rng.below(150_000) as u64),
                stall_rx_resume: Some(Duration::from_millis(
                    500 + g.rng.below(2000) as u64,
                )),
                ..Default::default()
            },
        }
    }

    /// Records how often each job was dispatched, so the suite can
    /// prove a completed job is never handed out again: a job's
    /// dispatch count can only exceed one by way of worker-death
    /// requeue.
    struct CountingSource<S> {
        inner: S,
        counts: RefCell<Vec<usize>>,
    }

    impl<S: JobSource> JobSource for CountingSource<S> {
        fn n_jobs(&self) -> usize {
            self.inner.n_jobs()
        }
        fn encode(&self, job: usize, tx: &mut BlobTx) -> Vec<Frame> {
            self.counts.borrow_mut()[job] += 1;
            self.inner.encode(job, tx)
        }
    }

    /// Satellite: for seeded schedules of byte-chopped writes, delayed
    /// flushes, mid-frame disconnects, and bit corruption, the
    /// dispatcher never deadlocks (worker 0 stays clean, so every run
    /// must complete), never double-assigns a completed job (dispatch
    /// counts bounded by deaths), and the surviving workers' merged
    /// results stay bit-identical to the in-process `SweepRunner`.
    /// Failures report a seed replayable via `util::prop::replay`.
    #[test]
    fn prop_fault_schedules_never_deadlock_or_double_assign() {
        let (params, cfg, calib) = setup();
        let configs: Vec<SweepConfig> = grid().into_iter().take(3).collect();
        let metrics = Metrics::new();
        let runner = SweepRunner::new(&params, &cfg, &calib, &metrics);
        let expect = runner.run_factored(&configs);
        let prep = runner.prepare(&configs);
        let names = Params::linear_names(&cfg);
        let n_layers = names.len();

        prop::check(0xFA17, 6, |g| {
            let n_workers = 2 + g.rng.below(2);
            let transports: Vec<Box<dyn Transport>> = (0..n_workers)
                .map(|wi| {
                    // worker 0 is always clean: the run must finish
                    let plan = if wi == 0 { FaultPlan::default() } else { random_plan(g) };
                    fault_worker(plan)
                })
                .collect();
            let mut session = ShardSession::from_transports(transports).unwrap();
            // wedge deadline: 15× the 100ms beat cadence, so a loaded CI
            // box never false-positives on a healthy-but-slow worker
            session.set_heartbeat_timeout(Duration::from_millis(1500));
            {
                let mut rx = session.rx().lock().unwrap();
                for layer in &prep.cache.layers {
                    for arc in layer.qdeq0.values() {
                        rx.seed_mat(arc);
                    }
                    for arc in layer.qdeq0_packed.values() {
                        rx.seed_packed(arc);
                    }
                }
            }
            let src = CountingSource {
                inner: SweepJobSource {
                    configs: &configs,
                    cache: &prep.cache,
                    prep_rank: prep.prep_rank,
                    n_layers,
                    memo: EncodeMemo::default(),
                },
                counts: RefCell::new(vec![0; configs.len() * n_layers]),
            };
            let case_metrics = Metrics::new();
            let msgs = session
                .run_jobs(&src, &case_metrics)
                .expect("a clean worker survives every schedule");
            let parts = {
                let rx = session.rx().lock().unwrap();
                sweep_parts(msgs, &rx, &configs, &names, n_layers, &prep).unwrap()
            };
            let got = assemble_outcomes(&params, &names, configs.len(), parts, &case_metrics);
            assert_outcomes_identical(&expect, &got);

            let deaths = case_metrics.get("shard.worker_deaths") as usize;
            for (j, &c) in src.counts.borrow().iter().enumerate() {
                assert!(c >= 1, "job {j} was never dispatched");
                assert!(
                    c <= 1 + deaths,
                    "job {j} dispatched {c}× with only {deaths} worker death(s) — \
                     a completed job was re-assigned"
                );
            }
            session.shutdown();
        });
    }

    /// Budget planning is a pure read of the phase-A cache, and the
    /// sharded prep rebuilds that cache bit-identically — so for seeded
    /// fault schedules (chopped writes, mid-frame cuts, corruption,
    /// silent stalls) the sharded planner's [`BudgetPlan`] must equal
    /// the in-process one field-for-field, f64 error predictions
    /// included.
    ///
    /// [`BudgetPlan`]: crate::coordinator::budget::BudgetPlan
    #[test]
    fn prop_budget_plans_bit_identical_in_process_vs_sharded_under_faults() {
        use crate::coordinator::budget::BudgetSpec;

        let (params, cfg, calib) = setup();
        let metrics = Metrics::new();
        let runner = SweepRunner::new(&params, &cfg, &calib, &metrics);
        let mut spec = BudgetSpec::new(0);
        spec.rank_choices = vec![0, 4, 8];
        spec.seed = 3;
        // a budget 10% above the mid-grid uniform level, so the
        // allocator has real slack to distribute
        let profiles = runner.budget_profiles(&spec).unwrap();
        let mid: u64 = profiles.iter().map(|p| p.bytes(&spec, 1, 1)).sum();
        spec.budget_bytes = mid + mid / 10;
        let expect = runner.plan_budget(&spec).unwrap();

        let sharded = ShardedSweepRunner::new(&params, &cfg, &calib, &metrics);
        prop::check(0xB0D6E7, 4, |g| {
            let transports: Vec<Box<dyn Transport>> = (0..2)
                .map(|wi| {
                    // worker 0 is always clean: the run must finish
                    let plan = if wi == 0 { FaultPlan::default() } else { random_plan(g) };
                    fault_worker(plan)
                })
                .collect();
            let mut session = ShardSession::from_transports(transports).unwrap();
            session.set_heartbeat_timeout(Duration::from_millis(1500));
            let got = sharded
                .plan_budget(&mut session, &spec)
                .expect("a clean worker survives every schedule");
            assert_eq!(expect, got, "sharded plan diverged from in-process plan");
            session.shutdown();
        });
    }

    /// Every worker faulted to death: the dispatcher must error out —
    /// "all shard workers died" — rather than hang waiting on peers
    /// that will never answer.
    #[test]
    fn all_faulty_workers_error_instead_of_hanging() {
        let (params, cfg, calib) = setup();
        let configs: Vec<SweepConfig> = grid().into_iter().take(2).collect();
        let metrics = Metrics::new();
        let runner = SweepRunner::new(&params, &cfg, &calib, &metrics);
        let prep = runner.prepare(&configs);
        let names = Params::linear_names(&cfg);

        let transports: Vec<Box<dyn Transport>> = (0..2)
            .map(|_| {
                fault_worker(FaultPlan { cut_tx_after: Some(100), ..Default::default() })
            })
            .collect();
        let mut session = ShardSession::from_transports(transports).unwrap();
        let src = SweepJobSource {
            configs: &configs,
            cache: &prep.cache,
            prep_rank: prep.prep_rank,
            n_layers: names.len(),
            memo: EncodeMemo::default(),
        };
        let err = session.run_jobs(&src, &metrics).expect_err("no worker can finish a job");
        assert!(
            err.to_string().contains("all shard workers died"),
            "unexpected error: {err:#}"
        );
    }

    /// Tentpole regression (wedge): a worker whose result stream stalls
    /// silently — socket open, no bytes, no heartbeats — is marked
    /// wedged at the deadline and its jobs requeue onto the clean
    /// worker; the merged outcomes stay bit-identical.
    #[test]
    fn wedged_worker_requeues_via_heartbeat_expiry() {
        let (params, cfg, calib) = setup();
        let configs: Vec<SweepConfig> = grid().into_iter().take(2).collect();
        let metrics = Metrics::new();
        let runner = SweepRunner::new(&params, &cfg, &calib, &metrics);
        let expect = runner.run_factored(&configs);
        let prep = runner.prepare(&configs);
        let names = Params::linear_names(&cfg);
        let n_layers = names.len();

        let transports: Vec<Box<dyn Transport>> = vec![
            fault_worker(FaultPlan::default()),
            // stalls after its first byte: every job it holds goes silent
            fault_worker(FaultPlan { stall_rx_after: Some(1), ..Default::default() }),
        ];
        let mut session = ShardSession::from_transports(transports).unwrap();
        session.set_heartbeat_timeout(Duration::from_millis(2000));
        {
            let mut rx = session.rx().lock().unwrap();
            for layer in &prep.cache.layers {
                for arc in layer.qdeq0.values() {
                    rx.seed_mat(arc);
                }
                for arc in layer.qdeq0_packed.values() {
                    rx.seed_packed(arc);
                }
            }
        }
        let src = SweepJobSource {
            configs: &configs,
            cache: &prep.cache,
            prep_rank: prep.prep_rank,
            n_layers,
            memo: EncodeMemo::default(),
        };
        let case_metrics = Metrics::new();
        let msgs = session.run_jobs(&src, &case_metrics).expect("clean worker finishes");
        let parts = {
            let rx = session.rx().lock().unwrap();
            sweep_parts(msgs, &rx, &configs, &names, n_layers, &prep).unwrap()
        };
        let got = assemble_outcomes(&params, &names, configs.len(), parts, &case_metrics);
        assert_outcomes_identical(&expect, &got);
        assert!(
            case_metrics.get("shard.wedged") >= 1.0,
            "the stalled worker was never wedged"
        );
        assert!(
            case_metrics.get("shard.requeued") >= 1.0,
            "the wedged worker's jobs were never requeued"
        );
        session.shutdown();
    }

    /// A worker behind a pump that re-emits every sweep-result frame
    /// twice — the replayed-frame double the stale-frame satellite
    /// needs.
    fn duplicating_worker() -> Box<dyn Transport> {
        let (host_to_worker, worker_input) = byte_pipe(1 << 16);
        let (worker_output, pump_input) = byte_pipe(1 << 16);
        let (mut pump_output, host_read) = byte_pipe(1 << 16);
        std::thread::spawn(move || {
            let _ = run_worker_paced(
                worker_input,
                worker_output,
                None,
                Duration::from_millis(100),
            );
        });
        std::thread::spawn(move || {
            let mut src = BufReader::new(pump_input);
            while let Ok(Some(f)) = wire::read_frame(&mut src) {
                let dup = f.kind == kind::SWEEP_RESULT;
                if f.write_to(&mut pump_output).is_err() {
                    return;
                }
                if dup && f.write_to(&mut pump_output).is_err() {
                    return;
                }
                if pump_output.flush().is_err() {
                    return;
                }
            }
        });
        Box::new(FaultTransport::new(host_to_worker, host_read, FaultPlan::default()))
    }

    /// Satellite regression (stale-frame fix): a replayed result frame
    /// whose job is no longer in the worker's dispatch window is
    /// rejected and counted — never merged, never double-counted, and
    /// never a reason to re-dispatch a completed job.
    #[test]
    fn duplicate_result_frames_are_rejected_and_counted() {
        let (params, cfg, calib) = setup();
        let configs: Vec<SweepConfig> = grid().into_iter().take(2).collect();
        let metrics = Metrics::new();
        let runner = SweepRunner::new(&params, &cfg, &calib, &metrics);
        let expect = runner.run_factored(&configs);
        let prep = runner.prepare(&configs);
        let names = Params::linear_names(&cfg);
        let n_layers = names.len();

        let mut session =
            ShardSession::from_transports(vec![duplicating_worker()]).unwrap();
        {
            let mut rx = session.rx().lock().unwrap();
            for layer in &prep.cache.layers {
                for arc in layer.qdeq0.values() {
                    rx.seed_mat(arc);
                }
                for arc in layer.qdeq0_packed.values() {
                    rx.seed_packed(arc);
                }
            }
        }
        let src = CountingSource {
            inner: SweepJobSource {
                configs: &configs,
                cache: &prep.cache,
                prep_rank: prep.prep_rank,
                n_layers,
                memo: EncodeMemo::default(),
            },
            counts: RefCell::new(vec![0; configs.len() * n_layers]),
        };
        let case_metrics = Metrics::new();
        let msgs = session.run_jobs(&src, &case_metrics).expect("duplicates are benign");
        for (j, &c) in src.counts.borrow().iter().enumerate() {
            assert_eq!(c, 1, "job {j} dispatched {c}× with no worker death");
        }
        assert!(
            case_metrics.get("shard.rejected_frames") >= 1.0,
            "no duplicate frame was rejected"
        );
        let parts = {
            let rx = session.rx().lock().unwrap();
            sweep_parts(msgs, &rx, &configs, &names, n_layers, &prep).unwrap()
        };
        let got = assemble_outcomes(&params, &names, configs.len(), parts, &case_metrics);
        assert_outcomes_identical(&expect, &got);
        session.shutdown();
    }

    /// Tentpole regression (elasticity): workers admitted mid-run — one
    /// before the batch, one racing the dispatcher, one that joins and
    /// immediately stalls — take load without disturbing bit-identity,
    /// and the departing (wedged) joiner requeues cleanly.
    #[test]
    fn mid_run_join_takes_load_and_stays_bit_identical() {
        let (params, cfg, calib) = setup();
        let configs = grid();
        let metrics = Metrics::new();
        let runner = SweepRunner::new(&params, &cfg, &calib, &metrics);
        let expect = runner.run_factored(&configs);
        let prep = runner.prepare(&configs);
        let names = Params::linear_names(&cfg);
        let n_layers = names.len();

        let mut session =
            ShardSession::from_transports(vec![fault_worker(FaultPlan::default())]).unwrap();
        session.set_heartbeat_timeout(Duration::from_millis(2000));
        {
            let mut rx = session.rx().lock().unwrap();
            for layer in &prep.cache.layers {
                for arc in layer.qdeq0.values() {
                    rx.seed_mat(arc);
                }
                for arc in layer.qdeq0_packed.values() {
                    rx.seed_packed(arc);
                }
            }
        }

        // a join queued before the batch is admitted on demand
        let sender = session.join_sender();
        assert!(sender.admit(fault_worker(FaultPlan::default())));
        session.admit_pending_joins();
        assert_eq!(session.n_alive(), 2, "pre-batch joiner admitted");

        // a second joiner races the dispatcher mid-run — and stalls
        // right after joining, so it also exercises wedge-on-joiner
        let racer = {
            let sender = session.join_sender();
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(50));
                sender.admit(fault_worker(FaultPlan {
                    stall_rx_after: Some(1),
                    ..Default::default()
                }))
            })
        };

        let src = SweepJobSource {
            configs: &configs,
            cache: &prep.cache,
            prep_rank: prep.prep_rank,
            n_layers,
            memo: EncodeMemo::default(),
        };
        let case_metrics = Metrics::new();
        let msgs = session.run_jobs(&src, &case_metrics).expect("fleet survives the churn");
        racer.join().unwrap();
        let parts = {
            let rx = session.rx().lock().unwrap();
            sweep_parts(msgs, &rx, &configs, &names, n_layers, &prep).unwrap()
        };
        let got = assemble_outcomes(&params, &names, configs.len(), parts, &case_metrics);
        assert_outcomes_identical(&expect, &got);

        // however the race landed, both clean workers are alive once any
        // leftover join is absorbed; the stalled joiner never survives
        // holding a job past its deadline
        session.admit_pending_joins();
        assert!(session.n_alive() >= 2, "clean workers survive");
        assert!(case_metrics.get("shard.joined") >= 1.0, "no join was recorded");
        session.shutdown();
    }
}
