//! Multi-process execution plane: shard sweep phase B2 and fleet PPL
//! evaluation across `srr shard-worker` processes.
//!
//! The in-process engines already saturate one machine's cores; this
//! module is the seam that takes them to N processes (and, with a future
//! TCP/ssh transport, N hosts). The division of labor:
//!
//! * the **host** runs sweep phases A + B1 in-process
//!   ([`SweepRunner::prepare`]), then ships per-`(layer, config)`
//!   phase-B2 jobs — and fleet `(group × batch)` PPL jobs — to worker
//!   processes over the [`wire`](super::wire) codec, merging results
//!   deterministically by job id. The byte stream underneath is a
//!   [`Transport`](super::transport::Transport): child-process pipes
//!   ([`ShardSession::spawn`]), TCP to local or remote workers
//!   ([`ShardSession::spawn_tcp`], [`ShardSession::listen`],
//!   [`ShardSession::dial`]), or the fault-injection double the tests
//!   drive;
//! * each **worker** ([`worker_main`], the `srr shard-worker` CLI mode)
//!   pulls frames through a reader thread into a bounded job queue
//!   (backpressure end-to-end: a full queue stops the read loop, which
//!   stops the host's pipe), computes with the *same*
//!   [`b2_job`](super::sweep) / fleet-job functions the in-process
//!   engines run, and pushes result frames through a writer thread.
//!
//! **Bit-identity contract:** [`ShardedSweepRunner::run_factored`]
//! produces outcomes — and [`fleet_perplexity_sharded`] PPLs —
//! bit-identical to [`SweepRunner::run_factored`] +
//! [`fleet_perplexity`](crate::eval::fleet_perplexity) for any worker
//! count, including after worker-death requeue (regression- and
//! property-tested; `cargo bench -- --exp shard` records the scaling
//! efficiency into `BENCH_shard.json`). The contract holds because both
//! paths run the same job functions on the same artifacts and merge in
//! the same order; the wire layer's content-addressed blob dedup
//! rebuilds the `Arc` sharing (grid dedup, lock-step groups) on each
//! side of the pipe.
//!
//! **Failure model:** a worker that exits (cleanly or by crash), drops
//! its connection, or writes garbage frames is marked dead; its
//! in-flight jobs requeue onto surviving workers, and
//! late frames from a dead worker are discarded (the survivor's
//! recomputation is authoritative). The host's event loop waits with
//! [`BoundedQueue::pop_timeout`](super::jobs::BoundedQueue::pop_timeout)
//! and probes [`Transport::poll_dead`](super::transport::Transport) on
//! every timeout, so even a worker that dies without closing its stream
//! is noticed when the transport owns a side channel (child exit
//! status). Only when every worker has died does the run error out. A
//! worker that hangs *without* exiting or disconnecting is waited on
//! indefinitely — a per-job heartbeat remains future work.

use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::eval::fleet::{
    fleet_job_list, reduce_fleet_results, FleetGroup, FleetJob, FleetJobResult,
};
use crate::eval::{group_by_shared_bases, perplexity_native_masked};
use crate::linalg::Svd;
use crate::model::forward::lm_nll_fleet;
use crate::model::{CalibrationSet, Params};
use crate::qer::{Method, PreparedSpectra};
use crate::runtime::manifest::ModelCfg;
use crate::scaling::Scaling;
use crate::serve::{FactoredModel, LinearOp, QuantBase};
use crate::tensor::Mat;
use crate::util::cli::Args;

use super::cache::LayerCache;
use super::jobs::{BoundedQueue, PopResult};
use super::metrics::Metrics;
use super::pipeline::{FactoredOutcome, LayerMeta, LayerReport};
use super::sweep::{
    assemble_outcomes, b2_artifacts, b2_job, empty_outcomes, B2Artifacts, SweepConfig,
    SweepPrep, SweepRunner,
};
use super::transport::{
    worker_accept, worker_connect, ChildPipeTransport, ShardHost, TcpTransport, Transport,
};
use super::wire::{
    self, decode_fleet_job, decode_fleet_result, decode_sweep_job, decode_sweep_result,
    encode_fleet_job, encode_fleet_result, encode_sweep_job, encode_sweep_result, kind,
    shutdown_frame, BlobRx, BlobTx, FleetJobMsg, FleetOut, FleetResultMsg, Frame, SweepJobMsg,
    SweepResultMsg, WireBase, WireLinearOp, WireModel, WireScaling, WireSpectra, WireSvd,
};

/// Jobs a worker may hold in flight before the host waits for results —
/// one computing, one queued behind it.
const WINDOW: usize = 2;

/// Worker-side queue depth for decoded jobs / encoded results. Small on
/// purpose: the queue, not the OS pipe, is the unit of backpressure.
const WORKER_QUEUE_CAP: usize = 4;

/// How long the host event loop waits before probing child liveness.
const EVENT_POLL: Duration = Duration::from_millis(500);

/// Configuration for a shard session.
#[derive(Clone, Debug)]
pub struct ShardOptions {
    /// worker processes to spawn (≥ 1)
    pub workers: usize,
    /// `SRR_THREADS` for each worker (0 = inherit the environment); the
    /// default of 1 makes N workers ≈ N single-threaded executors, the
    /// configuration the scaling bench measures
    pub worker_threads: usize,
    /// fault injection for tests/benches: the *first* worker exits after
    /// completing this many jobs, exercising the requeue path
    pub exit_after_first: Option<usize>,
    /// explicit path to the `srr` binary (otherwise `SRR_SHARD_BIN`,
    /// then a search near the current executable)
    pub binary: Option<PathBuf>,
}

impl Default for ShardOptions {
    fn default() -> Self {
        ShardOptions { workers: 2, worker_threads: 1, exit_after_first: None, binary: None }
    }
}

impl ShardOptions {
    /// `n` workers with the default single-threaded worker config.
    pub fn with_workers(n: usize) -> Self {
        ShardOptions { workers: n, ..Default::default() }
    }
}

/// Locate the `srr` binary to spawn workers from: an explicit override,
/// the `SRR_SHARD_BIN` env var (integration tests and benches set it
/// from `CARGO_BIN_EXE_srr`), the current executable when it *is* `srr`,
/// or a sibling/parent search from the current executable (covers test
/// and example binaries under `target/<profile>/deps`).
fn worker_binary(opts: &ShardOptions) -> Result<PathBuf> {
    if let Some(p) = &opts.binary {
        return Ok(p.clone());
    }
    if let Ok(p) = std::env::var("SRR_SHARD_BIN") {
        return Ok(PathBuf::from(p));
    }
    let exe = std::env::current_exe().context("resolving current executable")?;
    if exe.file_stem().map(|s| s == "srr").unwrap_or(false) {
        return Ok(exe);
    }
    let mut dir = exe.parent();
    for _ in 0..3 {
        let Some(d) = dir else { break };
        let cand = d.join(format!("srr{}", std::env::consts::EXE_SUFFIX));
        if cand.is_file() {
            return Ok(cand);
        }
        dir = d.parent();
    }
    anyhow::bail!(
        "cannot locate the `srr` worker binary near {}; set SRR_SHARD_BIN or ShardOptions.binary",
        exe.display()
    )
}

/// Shard-plane transfer/fault counters (shared with reader threads).
#[derive(Default)]
struct ShardStats {
    jobs_sent: AtomicU64,
    tx_bytes: AtomicU64,
    rx_bytes: AtomicU64,
    requeued: AtomicU64,
    deaths: AtomicU64,
}

/// Host→worker result/failure notifications.
enum Event {
    /// a decoded result frame from `worker`
    Result { worker: usize, msg: ResultMsg },
    /// `worker`'s pipe ended or produced garbage
    Dead { worker: usize },
}

/// A decoded worker result.
#[derive(Debug)]
pub(crate) enum ResultMsg {
    /// phase-B2 sweep job result
    Sweep(Box<SweepResultMsg>),
    /// fleet PPL job result
    Fleet(FleetResultMsg),
}

impl ResultMsg {
    fn job_id(&self) -> u64 {
        match self {
            ResultMsg::Sweep(m) => m.job_id,
            ResultMsg::Fleet(m) => m.job_id,
        }
    }
}

/// A source of encodable jobs; the dispatch loop is generic over sweep
/// and fleet batches.
pub(crate) trait JobSource {
    /// Total job count; job ids are `0..n_jobs`.
    fn n_jobs(&self) -> usize;
    /// Encode job `job` for one worker connection: any blob frames the
    /// worker is missing, then the job frame.
    fn encode(&self, job: usize, tx: &mut BlobTx) -> Vec<Frame>;
}

struct WorkerConn {
    /// the framed byte stream to this worker (pipes, TCP, or a test
    /// double); the write half closes when the worker dies or shuts down
    transport: Box<dyn Transport>,
    /// per-connection blob dedup state
    tx: BlobTx,
    /// job ids in flight on this worker
    outstanding: Vec<usize>,
    alive: bool,
    reader: Option<JoinHandle<()>>,
}

/// A pool of worker connections — spawned `srr shard-worker` processes
/// over pipes or TCP, remote dial-ins, or any custom [`Transport`]. One
/// session serves any number of job batches
/// ([`ShardedSweepRunner::run_factored`], [`fleet_perplexity_sharded`])
/// — blob caches persist across batches, so a fleet evaluation right
/// after a sweep reuses the bases the sweep already shipped.
pub struct ShardSession {
    workers: Vec<WorkerConn>,
    events: Arc<BoundedQueue<Event>>,
    /// host-side blob cache, shared by all worker readers; seeded with
    /// outbound artifacts so results resolve to the very same `Arc`s
    rx: Arc<Mutex<BlobRx>>,
    stats: Arc<ShardStats>,
}

fn spawn_reader(
    wi: usize,
    input: Box<dyn Read + Send>,
    events: Arc<BoundedQueue<Event>>,
    rx: Arc<Mutex<BlobRx>>,
    stats: Arc<ShardStats>,
) -> JoinHandle<()> {
    std::thread::spawn(move || {
        let mut out = BufReader::new(input);
        loop {
            match wire::read_frame(&mut out) {
                Ok(Some(f)) => {
                    stats.rx_bytes.fetch_add(f.payload.len() as u64 + 24, Ordering::Relaxed);
                    let ev = match f.kind {
                        kind::BLOB_MAT | kind::BLOB_PACKED | kind::BLOB_PARAMS => {
                            match rx.lock().unwrap().insert(f.kind, &f.payload) {
                                Ok(_) => continue,
                                Err(_) => Event::Dead { worker: wi },
                            }
                        }
                        kind::SWEEP_RESULT => match decode_sweep_result(&f.payload) {
                            Ok(m) => {
                                let msg = ResultMsg::Sweep(Box::new(m));
                                Event::Result { worker: wi, msg }
                            }
                            Err(_) => Event::Dead { worker: wi },
                        },
                        kind::FLEET_RESULT => match decode_fleet_result(&f.payload) {
                            Ok(m) => Event::Result { worker: wi, msg: ResultMsg::Fleet(m) },
                            Err(_) => Event::Dead { worker: wi },
                        },
                        _ => Event::Dead { worker: wi },
                    };
                    let dead = matches!(ev, Event::Dead { .. });
                    events.push(ev);
                    if dead {
                        return;
                    }
                }
                Ok(None) | Err(_) => {
                    events.push(Event::Dead { worker: wi });
                    return;
                }
            }
        }
    })
}

/// Kill and reap a set of spawned worker children (the error-path
/// cleanup shared by [`ShardSession::spawn_tcp`]).
fn reap_children(children: HashMap<u64, Child>) {
    for mut c in children.into_values() {
        let _ = c.kill();
        let _ = c.wait();
    }
}

/// Base `srr shard-worker` invocation shared by the pipe and TCP spawn
/// paths (threads env, first-worker fault injection).
fn worker_command(bin: &Path, opts: &ShardOptions, wi: usize) -> Command {
    let mut cmd = Command::new(bin);
    cmd.arg("shard-worker");
    if opts.worker_threads > 0 {
        cmd.env("SRR_THREADS", opts.worker_threads.to_string());
    }
    if wi == 0 {
        if let Some(k) = opts.exit_after_first {
            cmd.arg("--exit-after").arg(k.to_string());
        }
    }
    cmd
}

/// How long [`ShardSession::spawn_tcp`] waits for its own loopback
/// children to dial back in.
const SPAWN_TCP_ACCEPT: Duration = Duration::from_secs(30);

impl ShardSession {
    /// Wrap already-connected transports into a session (the seam every
    /// other constructor goes through; also the entry point for custom
    /// transports — ssh tunnels, test doubles).
    pub fn from_transports(transports: Vec<Box<dyn Transport>>) -> Result<ShardSession> {
        anyhow::ensure!(!transports.is_empty(), "shard session needs at least one worker");
        let events = Arc::new(BoundedQueue::new(transports.len() * (WINDOW + 2) + 4));
        let rx = Arc::new(Mutex::new(BlobRx::new()));
        let stats = Arc::new(ShardStats::default());
        let mut workers: Vec<WorkerConn> = Vec::with_capacity(transports.len());
        for (wi, mut transport) in transports.into_iter().enumerate() {
            let input = transport.take_reader().ok_or_else(|| {
                anyhow::anyhow!("transport {} has no read half left", transport.describe())
            })?;
            let reader = spawn_reader(wi, input, events.clone(), rx.clone(), stats.clone());
            workers.push(WorkerConn {
                transport,
                tx: BlobTx::new(),
                outstanding: Vec::new(),
                alive: true,
                reader: Some(reader),
            });
        }
        Ok(ShardSession { workers, events, rx, stats })
    }

    /// Spawn `opts.workers` worker processes with piped stdin/stdout
    /// (stderr inherited so worker panics stay visible).
    pub fn spawn(opts: &ShardOptions) -> Result<ShardSession> {
        anyhow::ensure!(opts.workers >= 1, "shard session needs at least one worker");
        let bin = worker_binary(opts)?;
        let mut transports: Vec<Box<dyn Transport>> = Vec::with_capacity(opts.workers);
        for wi in 0..opts.workers {
            let mut cmd = worker_command(&bin, opts, wi);
            cmd.stdin(Stdio::piped()).stdout(Stdio::piped()).stderr(Stdio::inherit());
            let child = cmd
                .spawn()
                .with_context(|| format!("spawning {}", bin.display()))?;
            // earlier transports kill their children on drop if a later
            // spawn fails
            transports.push(Box::new(ChildPipeTransport::new(child)));
        }
        Self::from_transports(transports)
    }

    /// Spawn `opts.workers` worker processes that dial back over TCP
    /// loopback: the host binds an ephemeral `127.0.0.1` port, each
    /// child runs `srr shard-worker --connect 127.0.0.1:<port>` with a
    /// per-worker token, and the session maps dial-ins back to the
    /// child processes (so the liveness probe still sees exits). Same
    /// dispatcher, same bit-identity contract — only the bytes travel
    /// through the loopback stack instead of pipes, which is what
    /// `cargo bench -- --exp shard` measures TCP framing overhead with.
    pub fn spawn_tcp(opts: &ShardOptions) -> Result<ShardSession> {
        anyhow::ensure!(opts.workers >= 1, "shard session needs at least one worker");
        let bin = worker_binary(opts)?;
        let host = ShardHost::bind("127.0.0.1:0")?;
        let addr = host.local_addr()?.to_string();
        let mut children: HashMap<u64, Child> = HashMap::new();
        for wi in 0..opts.workers {
            let token = wi as u64 + 1;
            let mut cmd = worker_command(&bin, opts, wi);
            cmd.arg("--connect")
                .arg(&addr)
                .arg("--token")
                .arg(token.to_string())
                .stdin(Stdio::null())
                .stdout(Stdio::null())
                .stderr(Stdio::inherit());
            match cmd.spawn().with_context(|| format!("spawning {}", bin.display())) {
                Ok(child) => {
                    children.insert(token, child);
                }
                Err(e) => {
                    reap_children(children);
                    return Err(e);
                }
            }
        }
        let accepted = host.accept_workers(opts.workers, SPAWN_TCP_ACCEPT);
        let mut accepted = match accepted {
            Ok(a) => a,
            Err(e) => {
                reap_children(children);
                return Err(e);
            }
        };
        // every admitted dial-in must present a token this session issued
        // to one of its own children — a foreign process that happened to
        // dial the ephemeral port (and would skew SRR_THREADS pinning /
        // --exit-after fault injection) is an error, not a fleet member
        let mut err: Option<anyhow::Error> = None;
        for t in &mut accepted {
            match children.remove(&t.token()) {
                Some(child) => t.attach_child(child),
                None if err.is_none() => {
                    err = Some(anyhow::anyhow!(
                        "shard host: unexpected dial-in {} — not one of this session's workers",
                        t.describe()
                    ));
                }
                None => {}
            }
        }
        if err.is_none() && !children.is_empty() {
            err = Some(anyhow::anyhow!(
                "shard host: {} spawned worker(s) never completed the handshake",
                children.len()
            ));
        }
        if let Some(e) = err {
            // reap the children whose slots were taken; accepted
            // transports drop below (killing any attached children)
            reap_children(children);
            return Err(e);
        }
        Self::from_transports(accepted.into_iter().map(|t| Box::new(t) as _).collect())
    }

    /// Listen on `addr` and wait (up to `deadline`) for `workers`
    /// remote `srr shard-worker --connect` dial-ins. No authentication
    /// beyond the wire handshake — bind loopback and tunnel over ssh,
    /// or stay on a trusted LAN (see the README's remote-worker
    /// workflow).
    pub fn listen(addr: &str, workers: usize, deadline: Duration) -> Result<ShardSession> {
        anyhow::ensure!(workers >= 1, "shard session needs at least one worker");
        let host = ShardHost::bind(addr)?;
        let accepted = host.accept_workers(workers, deadline)?;
        Self::from_transports(accepted.into_iter().map(|t| Box::new(t) as _).collect())
    }

    /// Dial workers that are already listening (`srr shard-worker
    /// --listen host:port`), one session worker per address.
    pub fn dial(addrs: &[String]) -> Result<ShardSession> {
        anyhow::ensure!(!addrs.is_empty(), "shard session needs at least one worker");
        let mut transports: Vec<Box<dyn Transport>> = Vec::with_capacity(addrs.len());
        for addr in addrs {
            transports.push(Box::new(TcpTransport::dial(addr)?));
        }
        Self::from_transports(transports)
    }

    /// Workers still accepting jobs.
    pub fn n_alive(&self) -> usize {
        self.workers.iter().filter(|w| w.alive).count()
    }

    /// The shared host-side blob cache (the sweep runner seeds it with
    /// the `Arc`s it ships, so results resolve back to the same
    /// buffers).
    pub(crate) fn rx(&self) -> &Mutex<BlobRx> {
        &self.rx
    }

    fn mark_dead(&mut self, wi: usize, pending: &mut VecDeque<usize>) {
        let w = &mut self.workers[wi];
        if !w.alive {
            return;
        }
        w.alive = false;
        w.transport.close_writer(); // peer sees EOF
        self.stats.deaths.fetch_add(1, Ordering::Relaxed);
        let orphans = std::mem::take(&mut w.outstanding);
        self.stats.requeued.fetch_add(orphans.len() as u64, Ordering::Relaxed);
        // requeue in front so interrupted work retires first
        for j in orphans.into_iter().rev() {
            pending.push_front(j);
        }
    }

    fn feed_worker<S: JobSource>(
        &mut self,
        wi: usize,
        src: &S,
        pending: &mut VecDeque<usize>,
    ) {
        loop {
            if !self.workers[wi].alive || self.workers[wi].outstanding.len() >= WINDOW {
                return;
            }
            let Some(job) = pending.pop_front() else { return };
            let frames = src.encode(job, &mut self.workers[wi].tx);
            let sent = match self.workers[wi].transport.writer() {
                Some(mut out) => {
                    frames.iter().all(|f| f.write_to(&mut out).is_ok()) && out.flush().is_ok()
                }
                None => false,
            };
            if sent {
                let bytes: u64 = frames.iter().map(|f| f.payload.len() as u64 + 24).sum();
                self.stats.tx_bytes.fetch_add(bytes, Ordering::Relaxed);
                self.stats.jobs_sent.fetch_add(1, Ordering::Relaxed);
                self.workers[wi].outstanding.push(job);
            } else {
                // unreachable worker: give the job back, let the reader's
                // Dead event (or this mark) finish the cleanup
                pending.push_front(job);
                self.mark_dead(wi, pending);
                return;
            }
        }
    }

    fn fill_windows<S: JobSource>(&mut self, src: &S, pending: &mut VecDeque<usize>) {
        for wi in 0..self.workers.len() {
            self.feed_worker(wi, src, pending);
        }
    }

    /// Run every job in `src` across the workers; returns results
    /// indexed by job id (merge order is therefore deterministic
    /// regardless of which worker finished what, when).
    pub(crate) fn run_jobs<S: JobSource>(
        &mut self,
        src: &S,
        metrics: &Metrics,
    ) -> Result<Vec<ResultMsg>> {
        let n = src.n_jobs();
        let mut results: Vec<Option<ResultMsg>> = (0..n).map(|_| None).collect();
        let mut pending: VecDeque<usize> = (0..n).collect();
        let mut n_done = 0usize;

        // absorb deaths noticed since the previous batch
        loop {
            match self.events.try_pop() {
                PopResult::Item(Event::Dead { worker }) => {
                    self.mark_dead(worker, &mut pending)
                }
                PopResult::Item(Event::Result { .. }) => {} // stale duplicate
                PopResult::Empty | PopResult::Closed => break,
            }
        }

        self.fill_windows(src, &mut pending);
        while n_done < n {
            anyhow::ensure!(
                self.workers.iter().any(|w| w.alive),
                "all shard workers died with {} of {n} jobs unfinished",
                n - n_done
            );
            match self.events.pop_timeout(EVENT_POLL) {
                PopResult::Item(Event::Result { worker, msg }) => {
                    // results from a worker already marked dead are stale:
                    // its jobs were requeued the moment it was marked, and
                    // a late frame may even belong to a previous batch —
                    // the survivor's recomputation is the one that counts
                    if !self.workers[worker].alive {
                        continue;
                    }
                    let job = msg.job_id() as usize;
                    anyhow::ensure!(job < n, "worker returned unknown job id {job}");
                    self.workers[worker].outstanding.retain(|&j| j != job);
                    if results[job].is_none() {
                        results[job] = Some(msg);
                        n_done += 1;
                    }
                    self.feed_worker(worker, src, &mut pending);
                }
                PopResult::Item(Event::Dead { worker }) => {
                    self.mark_dead(worker, &mut pending);
                    self.fill_windows(src, &mut pending);
                }
                PopResult::Empty => {
                    // no events: probe each transport's out-of-band death
                    // signal (a child that exited without its reader
                    // noticing), then keep waiting
                    for wi in 0..self.workers.len() {
                        if self.workers[wi].alive && self.workers[wi].transport.poll_dead() {
                            self.mark_dead(wi, &mut pending);
                        }
                    }
                    self.fill_windows(src, &mut pending);
                }
                PopResult::Closed => anyhow::bail!("shard event queue closed"),
            }
        }

        metrics.put("shard.workers", self.workers.len() as f64);
        metrics.put("shard.workers_alive", self.n_alive() as f64);
        metrics.put("shard.jobs_sent", self.stats.jobs_sent.load(Ordering::Relaxed) as f64);
        metrics.put("shard.tx_bytes", self.stats.tx_bytes.load(Ordering::Relaxed) as f64);
        metrics.put("shard.rx_bytes", self.stats.rx_bytes.load(Ordering::Relaxed) as f64);
        metrics.put("shard.requeued", self.stats.requeued.load(Ordering::Relaxed) as f64);
        metrics.put("shard.worker_deaths", self.stats.deaths.load(Ordering::Relaxed) as f64);
        Ok(results.into_iter().map(|r| r.expect("job completed")).collect())
    }

    /// Graceful teardown: drain, send shutdown frames, reap children.
    pub fn shutdown(mut self) {
        self.teardown(true);
    }

    fn teardown(&mut self, graceful: bool) {
        for w in &mut self.workers {
            if graceful {
                if let Some(mut out) = w.transport.writer() {
                    let _ = shutdown_frame().write_to(&mut out);
                    let _ = out.flush();
                }
            }
            w.transport.close_writer(); // EOF either way
        }
        self.events.close();
        for w in &mut self.workers {
            if graceful {
                w.transport.wait();
            } else {
                w.transport.kill();
            }
            if let Some(r) = w.reader.take() {
                let _ = r.join();
            }
        }
        self.workers.clear();
    }
}

impl Drop for ShardSession {
    fn drop(&mut self) {
        self.teardown(false);
    }
}

// ---------------------------------------------------------------------------
// sweep sharding
// ---------------------------------------------------------------------------

/// Per-batch memo of encoded blob bodies. Job encoding runs once per
/// job per worker on the host's dispatch thread; without the memo every
/// job re-serializes (and re-hashes) its layer's full artifacts just to
/// discover the worker already holds them. Keys are the source buffer's
/// address plus dimensions — sound because the memo lives inside a
/// `JobSource` that borrows the cache/models for the whole batch, so
/// the addresses are pinned (dimensions disambiguate zero-length
/// buffers, whose dangling pointers all compare equal).
#[derive(Default)]
struct EncodeMemo {
    entries: RefCell<HashMap<(u8, usize, usize, usize), (wire::BlobRef, Vec<u8>)>>,
}

impl EncodeMemo {
    fn blob(
        &self,
        k: u8,
        key: (usize, usize, usize),
        tx: &mut BlobTx,
        frames: &mut Vec<Frame>,
        encode: impl FnOnce() -> (wire::BlobRef, Vec<u8>),
    ) -> wire::BlobRef {
        let mut entries = self.entries.borrow_mut();
        let (hash, body) = entries.entry((k, key.0, key.1, key.2)).or_insert_with(encode);
        tx.prehashed_ref(k, *hash, body, frames)
    }

    fn mat(&self, m: &Mat, tx: &mut BlobTx, frames: &mut Vec<Frame>) -> wire::BlobRef {
        let key = (m.data.as_ptr() as usize, m.rows, m.cols);
        self.blob(kind::BLOB_MAT, key, tx, frames, || wire::encode_mat_blob(m))
    }

    fn packed(&self, p: &PackedMat, tx: &mut BlobTx, frames: &mut Vec<Frame>) -> wire::BlobRef {
        let key = (p as *const PackedMat as usize, 0, 0);
        self.blob(kind::BLOB_PACKED, key, tx, frames, || wire::encode_packed_blob(p))
    }

    fn params(&self, p: &Params, tx: &mut BlobTx, frames: &mut Vec<Frame>) -> wire::BlobRef {
        let key = (p as *const Params as usize, 0, 0);
        self.blob(kind::BLOB_PARAMS, key, tx, frames, || wire::encode_params_blob(p))
    }
}

fn wire_svd(
    svd: &Svd,
    memo: &EncodeMemo,
    tx: &mut BlobTx,
    frames: &mut Vec<Frame>,
) -> WireSvd {
    WireSvd {
        u: memo.mat(&svd.u, tx, frames),
        s: svd.s.clone(),
        v: memo.mat(&svd.v, tx, frames),
    }
}

struct SweepJobSource<'a> {
    configs: &'a [SweepConfig],
    cache: &'a LayerCache,
    prep_rank: usize,
    n_layers: usize,
    memo: EncodeMemo,
}

impl JobSource for SweepJobSource<'_> {
    fn n_jobs(&self) -> usize {
        self.n_layers * self.configs.len()
    }

    fn encode(&self, job: usize, tx: &mut BlobTx) -> Vec<Frame> {
        let li = job % self.n_layers;
        let c = &self.configs[job / self.n_layers];
        let layer = &self.cache.layers[li];
        let arts = b2_artifacts(self.cache, li, c);
        let memo = &self.memo;
        let mut frames = Vec::new();
        let w_ref = memo.mat(arts.w, tx, &mut frames);
        let scaling = match arts.scaling {
            Scaling::Identity => WireScaling::Identity,
            Scaling::Diagonal { d, d_inv } => {
                WireScaling::Diagonal { d: d.clone(), d_inv: d_inv.clone() }
            }
            Scaling::Full { s, s_inv } => WireScaling::Full {
                s: memo.mat(s, tx, &mut frames),
                s_inv: memo.mat(s_inv, tx, &mut frames),
            },
        };
        let msg = SweepJobMsg {
            job_id: job as u64,
            prep_rank: self.prep_rank,
            config: c.clone(),
            layer_name: layer.name.clone(),
            w: w_ref,
            scaling,
            hessian: arts.hessian.map(|h| memo.mat(h, tx, &mut frames)),
            qdeq0: arts.qdeq0.map(|m| memo.mat(m, tx, &mut frames)),
            qdeq0_packed: arts.qdeq0_packed.map(|p| memo.packed(p, tx, &mut frames)),
            resid: arts.resid.map(|svd| wire_svd(svd, memo, tx, &mut frames)),
            spectra: arts.spectra.map(|sp| WireSpectra {
                sw: wire_svd(&sp.sw_svd, memo, tx, &mut frames),
                sw_frob2: sp.sw_frob2,
                se: wire_svd(&sp.se_svd, memo, tx, &mut frames),
                se_frob2: sp.se_frob2,
                rank: sp.rank,
                seed: sp.seed,
            }),
        };
        frames.push(encode_sweep_job(&msg));
        frames
    }
}

/// Rebuild phase-B2 assembly parts from worker results (job-id order),
/// reproducing the in-process engine's `Arc` layout exactly:
///
/// * **w-only / plain-QER** results share the packed base through the
///   blob cache — which the runner seeded with the host's own
///   `LayerCache` `Arc`s — so every rank/scaling variant of a cell
///   aliases the very same buffer the in-process sweep would hand out
///   (grid dedup + lock-step groups);
/// * **every other** result gets a *fresh* `Arc` per result, because
///   the in-process path quantizes per config and never shares those —
///   even two byte-identical bases stay distinct, so pointer-based
///   fleet grouping cannot coarsen across the wire. Dense bases are
///   fresh per result for the same reason.
fn sweep_parts(
    msgs: Vec<ResultMsg>,
    rx: &BlobRx,
    configs: &[SweepConfig],
    names: &[String],
    n_layers: usize,
    prep: &SweepPrep,
) -> Result<Vec<(LinearOp, LayerMeta, LayerReport)>> {
    let n_configs = configs.len();
    let mut parts = Vec::with_capacity(msgs.len());
    for (idx, msg) in msgs.into_iter().enumerate() {
        let ResultMsg::Sweep(m) = msg else {
            anyhow::bail!("unexpected fleet result in a sweep batch")
        };
        debug_assert_eq!(m.job_id as usize, idx);
        let li = idx % n_layers;
        let shares_cell_base =
            matches!(configs[idx / n_layers].method, Method::WOnly | Method::Qer);
        let base = match m.base {
            WireBase::Packed(h) if shares_cell_base => QuantBase::Packed(rx.packed(h)?),
            WireBase::Packed(h) => QuantBase::Packed(Arc::new((*rx.packed(h)?).clone())),
            WireBase::Dense(h) => QuantBase::Dense(Arc::new((*rx.mat(h)?).clone())),
        };
        let op = LinearOp::FactoredQlr { base, l: m.l, r: m.r };
        let meta = LayerMeta { name: names[li].clone(), k_star: m.k_star, selection: m.selection };
        let report = LayerReport {
            name: names[li].clone(),
            k_star: m.k_star,
            weight_err: m.weight_err,
            scaled_err: m.scaled_err,
            // same amortization the in-process fan-out applies
            scale_secs: prep.cache.layers[li].prep_secs / n_configs as f64,
            qer_secs: m.qer_secs,
        };
        parts.push((op, meta, report));
    }
    Ok(parts)
}

/// [`SweepRunner`]'s multi-process counterpart: phases A + B1 run
/// in-process, phase B2 fans out over a [`ShardSession`]'s workers.
/// Outcomes are bit-identical to the in-process engine (module docs).
pub struct ShardedSweepRunner<'a> {
    params: &'a Params,
    model_cfg: &'a ModelCfg,
    calib: &'a CalibrationSet,
    metrics: &'a Metrics,
}

impl<'a> ShardedSweepRunner<'a> {
    /// A runner over one model + calibration set; `metrics` receives the
    /// `sweep.*` prep timings and `shard.*` transfer counters.
    pub fn new(
        params: &'a Params,
        model_cfg: &'a ModelCfg,
        calib: &'a CalibrationSet,
        metrics: &'a Metrics,
    ) -> Self {
        ShardedSweepRunner { params, model_cfg, calib, metrics }
    }

    /// Run the grid with phase B2 sharded across `session`'s workers;
    /// one [`FactoredOutcome`] per config, aligned, bit-identical to
    /// [`SweepRunner::run_factored`].
    pub fn run_factored(
        &self,
        session: &mut ShardSession,
        configs: &[SweepConfig],
    ) -> Result<Vec<FactoredOutcome>> {
        let names = Params::linear_names(self.model_cfg);
        let n_layers = names.len();
        if configs.is_empty() || n_layers == 0 {
            return Ok(empty_outcomes(self.params, configs.len()));
        }
        let runner = SweepRunner::new(self.params, self.model_cfg, self.calib, self.metrics);
        let prep = runner.prepare(configs);

        // seed the host cache with the Arc'd artifacts being shipped, so
        // results that reference them come back as these very buffers
        {
            let mut rx = session.rx().lock().unwrap();
            for layer in &prep.cache.layers {
                for arc in layer.qdeq0.values() {
                    rx.seed_mat(arc);
                }
                for arc in layer.qdeq0_packed.values() {
                    rx.seed_packed(arc);
                }
            }
        }

        let src = SweepJobSource {
            configs,
            cache: &prep.cache,
            prep_rank: prep.prep_rank,
            n_layers,
            memo: EncodeMemo::default(),
        };
        let t0 = Instant::now();
        let msgs = session.run_jobs(&src, self.metrics)?;
        self.metrics.add("shard.sweep_secs", t0.elapsed().as_secs_f64());

        let parts = {
            let rx = session.rx().lock().unwrap();
            sweep_parts(msgs, &rx, configs, &names, n_layers, &prep)?
        };
        Ok(assemble_outcomes(self.params, &names, configs.len(), parts, self.metrics))
    }
}

// ---------------------------------------------------------------------------
// fleet sharding
// ---------------------------------------------------------------------------

fn wire_model(
    m: &FactoredModel,
    memo: &EncodeMemo,
    tx: &mut BlobTx,
    frames: &mut Vec<Frame>,
) -> WireModel {
    let skeleton = memo.params(&m.skeleton, tx, frames);
    let ops = m
        .ops
        .iter()
        .map(|(name, op)| {
            let wop = match op {
                LinearOp::Dense(w) => WireLinearOp::Dense(memo.mat(w, tx, frames)),
                LinearOp::FactoredQlr { base, l, r } => WireLinearOp::Factored {
                    base: match base {
                        QuantBase::Packed(p) => WireBase::Packed(memo.packed(p, tx, frames)),
                        QuantBase::Dense(d) => WireBase::Dense(memo.mat(d, tx, frames)),
                    },
                    l: memo.mat(l, tx, frames),
                    r: memo.mat(r, tx, frames),
                },
            };
            (name.clone(), wop)
        })
        .collect();
    WireModel { skeleton, ops }
}

struct FleetJobSource<'a> {
    models: &'a [&'a FactoredModel],
    groups: &'a [Vec<usize>],
    jobs: &'a [FleetJob],
    cfg: &'a ModelCfg,
    batches: &'a [Vec<i32>],
    b: usize,
    t: usize,
    memo: EncodeMemo,
}

impl JobSource for FleetJobSource<'_> {
    fn n_jobs(&self) -> usize {
        self.jobs.len()
    }

    fn encode(&self, job: usize, tx: &mut BlobTx) -> Vec<Frame> {
        let mut frames = Vec::new();
        let (lockstep, member_ids, batches): (bool, Vec<usize>, Vec<Vec<i32>>) =
            match self.jobs[job] {
                FleetJob::Single(mi) => (false, vec![mi], self.batches.to_vec()),
                FleetJob::GroupBatch(gi, bj) => {
                    (true, self.groups[gi].clone(), vec![self.batches[bj].clone()])
                }
            };
        let models = member_ids
            .iter()
            .map(|&mi| wire_model(self.models[mi], &self.memo, tx, &mut frames))
            .collect();
        let msg = FleetJobMsg {
            job_id: job as u64,
            lockstep,
            cfg: self.cfg.clone(),
            b: self.b,
            t: self.t,
            models,
            batches,
        };
        frames.push(encode_fleet_job(&msg));
        frames
    }
}

/// Lock-step batched perplexity with the `(group × batch)` jobs sharded
/// across `session`'s workers instead of the in-process pool. Grouping,
/// job layout, and the f64 reduce are shared with
/// [`fleet_perplexity`](crate::eval::fleet_perplexity), so the returned
/// PPLs are bit-identical to it.
pub fn fleet_perplexity_sharded(
    session: &mut ShardSession,
    models: &[&FactoredModel],
    cfg: &ModelCfg,
    batches: &[Vec<i32>],
    b: usize,
    t: usize,
    metrics: &Metrics,
) -> Result<Vec<f64>> {
    let groups = group_by_shared_bases(models);
    let jobs = fleet_job_list(&groups, batches.len());
    if jobs.is_empty() {
        return Ok(reduce_fleet_results(models.len(), &groups, &jobs, vec![]));
    }
    let src = FleetJobSource {
        models,
        groups: &groups,
        jobs: &jobs,
        cfg,
        batches,
        b,
        t,
        memo: EncodeMemo::default(),
    };
    let t0 = Instant::now();
    let msgs = session.run_jobs(&src, metrics)?;
    metrics.add("shard.fleet_secs", t0.elapsed().as_secs_f64());
    let outs = msgs
        .into_iter()
        .map(|m| match m {
            ResultMsg::Fleet(f) => Ok(match f.out {
                FleetOut::Ppl(p) => FleetJobResult::Ppl(p),
                FleetOut::Partials(p) => FleetJobResult::Partials(p),
            }),
            ResultMsg::Sweep(_) => {
                Err(anyhow::anyhow!("unexpected sweep result in a fleet batch"))
            }
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(reduce_fleet_results(models.len(), &groups, &jobs, outs))
}

// ---------------------------------------------------------------------------
// the worker side
// ---------------------------------------------------------------------------

enum WorkMsg {
    Sweep(Box<SweepJobMsg>),
    Fleet(Box<FleetJobMsg>),
}

/// Execute one sweep job from wire artifacts — the same
/// [`b2_job`](super::sweep) the in-process fan-out runs.
fn run_sweep_job(
    msg: &SweepJobMsg,
    rx: &Mutex<BlobRx>,
    tx: &Mutex<BlobTx>,
) -> Result<Vec<Frame>, wire::WireError> {
    // resolve shared artifacts (clone the Arcs out under a short lock)
    let (w, scaling, hessian, qdeq0, qdeq0_packed, resid, spectra) = {
        let rx = rx.lock().unwrap();
        let w = rx.mat(msg.w)?;
        let scaling = match &msg.scaling {
            WireScaling::Identity => Scaling::Identity,
            WireScaling::Diagonal { d, d_inv } => {
                Scaling::Diagonal { d: d.clone(), d_inv: d_inv.clone() }
            }
            WireScaling::Full { s, s_inv } => Scaling::Full {
                s: (*rx.mat(*s)?).clone(),
                s_inv: (*rx.mat(*s_inv)?).clone(),
            },
        };
        let hessian = msg.hessian.map(|h| rx.mat(h)).transpose()?;
        let qdeq0 = msg.qdeq0.map(|h| rx.mat(h)).transpose()?;
        let qdeq0_packed = msg.qdeq0_packed.map(|h| rx.packed(h)).transpose()?;
        let resid = msg
            .resid
            .as_ref()
            .map(|sv| {
                Ok::<Svd, wire::WireError>(Svd {
                    u: (*rx.mat(sv.u)?).clone(),
                    s: sv.s.clone(),
                    v: (*rx.mat(sv.v)?).clone(),
                })
            })
            .transpose()?;
        let spectra = msg
            .spectra
            .as_ref()
            .map(|sp| {
                Ok::<PreparedSpectra, wire::WireError>(PreparedSpectra {
                    sw_svd: Svd {
                        u: (*rx.mat(sp.sw.u)?).clone(),
                        s: sp.sw.s.clone(),
                        v: (*rx.mat(sp.sw.v)?).clone(),
                    },
                    sw_frob2: sp.sw_frob2,
                    se_svd: Svd {
                        u: (*rx.mat(sp.se.u)?).clone(),
                        s: sp.se.s.clone(),
                        v: (*rx.mat(sp.se.v)?).clone(),
                    },
                    se_frob2: sp.se_frob2,
                    rank: sp.rank,
                    seed: sp.seed,
                })
            })
            .transpose()?;
        (w, scaling, hessian, qdeq0, qdeq0_packed, resid, spectra)
    };

    let arts = B2Artifacts {
        name: &msg.layer_name,
        w: &w,
        scaling: &scaling,
        hessian: hessian.as_deref(),
        qdeq0: qdeq0.as_deref(),
        qdeq0_packed: qdeq0_packed.as_ref(),
        resid: resid.as_ref(),
        spectra: spectra.as_ref(),
    };
    let (res, report) = b2_job(&msg.config, msg.prep_rank, &arts);

    let mut frames = Vec::new();
    let mut tx = tx.lock().unwrap();
    let base = match &res.packed {
        Some(p) => WireBase::Packed(tx.packed_ref(p, &mut frames)),
        None => WireBase::Dense(tx.mat_ref(&res.qdeq, &mut frames)),
    };
    let out = SweepResultMsg {
        job_id: msg.job_id,
        base,
        l: res.l,
        r: res.r,
        k_star: res.k_star,
        selection: res.selection,
        weight_err: report.weight_err,
        scaled_err: report.scaled_err,
        qer_secs: report.qer_secs,
    };
    frames.push(encode_sweep_result(&out));
    Ok(frames)
}

fn build_model(wm: &WireModel, rx: &BlobRx) -> Result<FactoredModel, wire::WireError> {
    let skeleton = (*rx.params(wm.skeleton)?).clone();
    let mut ops = Vec::with_capacity(wm.ops.len());
    for (name, op) in &wm.ops {
        let lop = match op {
            WireLinearOp::Dense(h) => LinearOp::Dense((*rx.mat(*h)?).clone()),
            WireLinearOp::Factored { base, l, r } => LinearOp::FactoredQlr {
                base: match base {
                    // shared Arc from the blob cache: group members alias
                    // one buffer, so matmul_grouped's lock-step path fires
                    WireBase::Packed(h) => QuantBase::Packed(rx.packed(*h)?),
                    // fresh Arc per op, mirroring in-process dense bases
                    // (never shared between outcomes)
                    WireBase::Dense(h) => QuantBase::Dense(Arc::new((*rx.mat(*h)?).clone())),
                },
                l: (*rx.mat(*l)?).clone(),
                r: (*rx.mat(*r)?).clone(),
            },
        };
        ops.push((name.clone(), lop));
    }
    Ok(FactoredModel { skeleton, ops })
}

/// Execute one fleet job: a singleton's whole-stream PPL or one
/// lock-step `(group, batch)` slice — the same code paths
/// `eval::fleet::fleet_perplexity` runs in-process.
fn run_fleet_job(msg: &FleetJobMsg, rx: &Mutex<BlobRx>) -> Result<FleetResultMsg, wire::WireError> {
    let models: Vec<FactoredModel> = {
        let rx = rx.lock().unwrap();
        msg.models.iter().map(|wm| build_model(wm, &rx)).collect::<Result<_, _>>()?
    };
    if models.is_empty() || (msg.lockstep && msg.batches.len() != 1) {
        return Err(wire::WireError::Malformed("inconsistent fleet job"));
    }
    let mask = vec![1.0f32; msg.b * msg.t];
    let out = if msg.lockstep {
        let refs: Vec<&FactoredModel> = models.iter().collect();
        let fleet = FleetGroup::new(refs);
        FleetOut::Partials(lm_nll_fleet(&fleet, &msg.cfg, &msg.batches[0], &mask, msg.b, msg.t))
    } else {
        FleetOut::Ppl(perplexity_native_masked(
            &models[0],
            &msg.cfg,
            &msg.batches,
            &mask,
            msg.b,
            msg.t,
        ))
    };
    Ok(FleetResultMsg { job_id: msg.job_id, out })
}

/// The worker loop over arbitrary transports (stdin/stdout in
/// production; in-memory buffers in the loopback tests).
///
/// Three threads: a reader decoding frames into a bounded job queue, the
/// caller's thread computing, and a writer flushing result frames. The
/// bounded queues are the backpressure: a slow worker stops reading, the
/// pipe fills, and the host's feeder blocks instead of ballooning
/// memory. `exit_after` is the fault-injection hook behind the
/// `--exit-after` CLI flag: the worker stops (abruptly, from the host's
/// point of view) after completing that many jobs.
pub fn run_worker<R, W>(input: R, output: W, exit_after: Option<usize>) -> Result<()>
where
    R: Read + Send + 'static,
    W: Write + Send + 'static,
{
    let rx = Arc::new(Mutex::new(BlobRx::new()));
    let tx = Arc::new(Mutex::new(BlobTx::new()));
    let jobs: Arc<BoundedQueue<WorkMsg>> = Arc::new(BoundedQueue::new(WORKER_QUEUE_CAP));
    let results: Arc<BoundedQueue<Vec<Frame>>> = Arc::new(BoundedQueue::new(WORKER_QUEUE_CAP));

    let reader = {
        let rx = rx.clone();
        let tx = tx.clone();
        let jobs = jobs.clone();
        std::thread::spawn(move || {
            // buffer the read half: a raw TcpStream would otherwise pay
            // three read syscalls per frame (header, payload, checksum)
            let mut input = BufReader::new(input);
            loop {
                match wire::read_frame(&mut input) {
                    Ok(Some(f)) => match f.kind {
                        kind::SHUTDOWN => break,
                        kind::BLOB_MAT | kind::BLOB_PACKED | kind::BLOB_PARAMS => {
                            match rx.lock().unwrap().insert(f.kind, &f.payload) {
                                // referencing a host-sent blob back needs
                                // no re-upload
                                Ok(h) => tx.lock().unwrap().mark_seen(h),
                                Err(_) => break,
                            }
                        }
                        kind::SWEEP_JOB => match decode_sweep_job(&f.payload) {
                            Ok(m) => {
                                if !jobs.push(WorkMsg::Sweep(Box::new(m))) {
                                    break;
                                }
                            }
                            Err(_) => break,
                        },
                        kind::FLEET_JOB => match decode_fleet_job(&f.payload) {
                            Ok(m) => {
                                if !jobs.push(WorkMsg::Fleet(Box::new(m))) {
                                    break;
                                }
                            }
                            Err(_) => break,
                        },
                        _ => break,
                    },
                    Ok(None) | Err(_) => break,
                }
            }
            jobs.close();
        })
    };

    let writer = {
        let results = results.clone();
        std::thread::spawn(move || {
            let mut out = BufWriter::new(output);
            while let Some(frames) = results.pop() {
                for fr in &frames {
                    if fr.write_to(&mut out).is_err() {
                        // close the queue so the compute loop's next push
                        // fails instead of blocking forever against a
                        // writer that is gone (a remote host that
                        // disconnected mid-results must not wedge the
                        // worker process)
                        results.close();
                        return;
                    }
                }
                if out.flush().is_err() {
                    results.close();
                    return;
                }
            }
            let _ = out.flush();
        })
    };

    let mut done = 0usize;
    while let Some(job) = jobs.pop() {
        let frames = match job {
            WorkMsg::Sweep(m) => run_sweep_job(&m, &rx, &tx)?,
            WorkMsg::Fleet(m) => vec![encode_fleet_result(&run_fleet_job(&m, &rx)?)],
        };
        if !results.push(frames) {
            break;
        }
        done += 1;
        if exit_after == Some(done) {
            break;
        }
    }
    jobs.close();
    results.close();
    let _ = writer.join();
    // the reader may be blocked on a live input; it exits on queue close,
    // EOF, or process exit — never join it here
    drop(reader);
    Ok(())
}

/// Entry point behind `srr shard-worker`: speak the wire codec over
/// stdin/stdout (default), over a dialed-out TCP connection
/// (`--connect host:port`, optionally presenting `--token N` so a host
/// that spawned this process can map the dial-in back to it), or over a
/// single accepted connection (`--listen host:port`) until shutdown or
/// EOF. `--exit-after N` is the fault-injection hook the requeue tests
/// use.
pub fn worker_main(args: &Args) -> Result<()> {
    let exit_after = args.get("exit-after").and_then(|s| s.parse::<usize>().ok());
    if let Some(addr) = args.get("connect") {
        let stream = worker_connect(addr, args.get_u64("token", 0))?;
        let input = stream.try_clone().context("cloning TCP read half")?;
        return run_worker(input, stream, exit_after);
    }
    if let Some(addr) = args.get("listen") {
        let stream = worker_accept(addr)?;
        let input = stream.try_clone().context("cloning TCP read half")?;
        return run_worker(input, stream, exit_after);
    }
    run_worker(std::io::stdin(), std::io::stdout(), exit_after)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{QuantizerSpec, SweepConfig};
    use crate::data::Corpus;
    use crate::eval::fleet_perplexity;
    use crate::model::{collect_calibration, synth::synth_lm_params};
    use crate::qer::Method;
    use crate::scaling::ScalingKind;
    use std::io::Cursor;

    /// An in-memory `Write` whose contents the test can inspect after
    /// the worker's writer thread finishes.
    #[derive(Clone, Default)]
    struct SharedBuf(Arc<Mutex<Vec<u8>>>);

    impl Write for SharedBuf {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    fn setup() -> (Params, ModelCfg, CalibrationSet) {
        let cfg = ModelCfg {
            name: "t".into(),
            vocab: 64,
            d_model: 64,
            n_heads: 2,
            n_layers: 2,
            d_ff: 128,
            seq_len: 16,
        };
        let params = synth_lm_params(&cfg, 5, cfg.vocab);
        let corpus = Corpus::generate(cfg.vocab, 4000, 6);
        let batches: Vec<Vec<i32>> = (0..10).map(|i| corpus.train_batch(2, 16, i)).collect();
        let calib = collect_calibration(&params, &cfg, &batches, 2, 16, 192);
        (params, cfg, calib)
    }

    fn grid() -> Vec<SweepConfig> {
        let mx = QuantizerSpec::Mxint { bits: 3, block: 32 };
        vec![
            // w-only + two QER ranks of one cell: shared packed base
            SweepConfig::new(mx, Method::WOnly, 0, ScalingKind::Identity),
            SweepConfig::new(mx, Method::Qer, 4, ScalingKind::DiagRms),
            SweepConfig::new(mx, Method::Qer, 8, ScalingKind::DiagRms),
            // SRR family with its own quantization, plus a Hessian path
            SweepConfig::new(mx, Method::QerSrr, 8, ScalingKind::Exact).seeded(5),
            SweepConfig::new(
                QuantizerSpec::Gptq { bits: 3, group: 64 },
                Method::QerSrr,
                8,
                ScalingKind::DiagAbsMean,
            ),
        ]
    }

    fn assert_outcomes_identical(a: &[FactoredOutcome], b: &[FactoredOutcome]) {
        assert_eq!(a.len(), b.len());
        for (oa, ob) in a.iter().zip(b) {
            assert_eq!(oa.model.ops.len(), ob.model.ops.len());
            for (((na, opa), (nb, opb)), (ma, mb)) in
                oa.model.ops.iter().zip(&ob.model.ops).zip(oa.meta.iter().zip(&ob.meta))
            {
                assert_eq!(na, nb);
                assert_eq!(ma.k_star, mb.k_star, "{na}: k* differs");
                match (opa, opb) {
                    (
                        LinearOp::FactoredQlr { base: ba, l: la, r: ra },
                        LinearOp::FactoredQlr { base: bb, l: lb, r: rb },
                    ) => {
                        assert_eq!(la, lb, "{na}: L differs");
                        assert_eq!(ra, rb, "{na}: R differs");
                        assert_eq!(ba.densify(), bb.densify(), "{na}: base differs");
                        assert_eq!(
                            matches!(ba, QuantBase::Packed(_)),
                            matches!(bb, QuantBase::Packed(_)),
                            "{na}: packedness differs"
                        );
                    }
                    _ => panic!("{na}: unexpected op shape"),
                }
            }
            for (ra, rb) in oa.reports.iter().zip(&ob.reports) {
                assert_eq!(ra.weight_err.to_bits(), rb.weight_err.to_bits());
                assert_eq!(ra.scaled_err.to_bits(), rb.scaled_err.to_bits());
            }
        }
    }

    /// Tentpole (hermetic half): drive `run_worker` over in-memory pipes
    /// with real sweep + fleet jobs and check the results merge
    /// bit-identical to the in-process engines — no processes involved,
    /// so this runs even where spawning is unavailable.
    #[test]
    fn worker_loopback_matches_in_process_sweep_and_fleet() {
        let (params, cfg, calib) = setup();
        let configs = grid();
        let metrics = Metrics::new();
        let runner = SweepRunner::new(&params, &cfg, &calib, &metrics);
        let expect = runner.run_factored(&configs);
        let prep = runner.prepare(&configs);
        let names = Params::linear_names(&cfg);
        let n_layers = names.len();

        // ---- sweep jobs through the worker loop ------------------------
        let src = SweepJobSource {
            configs: &configs,
            cache: &prep.cache,
            prep_rank: prep.prep_rank,
            n_layers,
            memo: EncodeMemo::default(),
        };
        let mut tx = BlobTx::new();
        let mut input = Vec::new();
        for j in 0..src.n_jobs() {
            for f in src.encode(j, &mut tx) {
                f.write_to(&mut input).unwrap();
            }
        }
        shutdown_frame().write_to(&mut input).unwrap();
        let out = SharedBuf::default();
        run_worker(Cursor::new(input), out.clone(), None).unwrap();

        // host-side merge: seed the cache like the sharded runner does
        let mut rx = BlobRx::new();
        for layer in &prep.cache.layers {
            for a in layer.qdeq0.values() {
                rx.seed_mat(a);
            }
            for a in layer.qdeq0_packed.values() {
                rx.seed_packed(a);
            }
        }
        let bytes = out.0.lock().unwrap().clone();
        let mut msgs: Vec<Option<SweepResultMsg>> = (0..src.n_jobs()).map(|_| None).collect();
        let mut cur = Cursor::new(&bytes[..]);
        while let Some(f) = wire::read_frame(&mut cur).unwrap() {
            match f.kind {
                kind::BLOB_MAT | kind::BLOB_PACKED | kind::BLOB_PARAMS => {
                    rx.insert(f.kind, &f.payload).unwrap();
                }
                kind::SWEEP_RESULT => {
                    let m = decode_sweep_result(&f.payload).unwrap();
                    let id = m.job_id as usize;
                    assert!(msgs[id].is_none(), "duplicate result {id}");
                    msgs[id] = Some(m);
                }
                other => panic!("unexpected frame kind {other}"),
            }
        }
        let msgs: Vec<ResultMsg> = msgs
            .into_iter()
            .map(|m| ResultMsg::Sweep(Box::new(m.expect("job completed"))))
            .collect();
        let parts =
            sweep_parts(msgs, &rx, &configs, &names, n_layers, &prep).unwrap();
        let got = assemble_outcomes(&params, &names, configs.len(), parts, &metrics);
        assert_outcomes_identical(&expect, &got);

        // grid dedup survives the wire: the w-only + QER rank variants
        // still alias one base per layer, and the sharded merge resolves
        // it to the host cache's own Arc
        let exp_models: Vec<&FactoredModel> = expect.iter().map(|o| &o.model).collect();
        let got_models: Vec<&FactoredModel> = got.iter().map(|o| &o.model).collect();
        let exp_groups = group_by_shared_bases(&exp_models);
        let got_groups = group_by_shared_bases(&got_models);
        assert_eq!(exp_groups, got_groups, "lock-step grouping changed across the wire");
        assert!(exp_groups.iter().any(|g| g.len() == 3), "expected a 3-member cell group");

        // ---- fleet jobs through the worker loop ------------------------
        let corpus = Corpus::generate(cfg.vocab, 4000, 7);
        let batches: Vec<Vec<i32>> =
            (0..3).map(|i| corpus.train_batch(2, cfg.seq_len, 50 + i)).collect();
        let (b, t) = (2usize, cfg.seq_len);
        let solo = fleet_perplexity(&got_models, &cfg, &batches, b, t);

        let groups = group_by_shared_bases(&got_models);
        let jobs = fleet_job_list(&groups, batches.len());
        let fsrc = FleetJobSource {
            models: &got_models,
            groups: &groups,
            jobs: &jobs,
            cfg: &cfg,
            batches: &batches,
            b,
            t,
            memo: EncodeMemo::default(),
        };
        let mut ftx = BlobTx::new();
        let mut finput = Vec::new();
        for j in 0..fsrc.n_jobs() {
            for f in fsrc.encode(j, &mut ftx) {
                f.write_to(&mut finput).unwrap();
            }
        }
        shutdown_frame().write_to(&mut finput).unwrap();
        let fout = SharedBuf::default();
        run_worker(Cursor::new(finput), fout.clone(), None).unwrap();

        let fbytes = fout.0.lock().unwrap().clone();
        let mut fres: Vec<Option<FleetResultMsg>> = (0..jobs.len()).map(|_| None).collect();
        let mut cur = Cursor::new(&fbytes[..]);
        while let Some(f) = wire::read_frame(&mut cur).unwrap() {
            if f.kind == kind::FLEET_RESULT {
                let m = decode_fleet_result(&f.payload).unwrap();
                fres[m.job_id as usize] = Some(m);
            }
        }
        let outs: Vec<FleetJobResult> = fres
            .into_iter()
            .map(|m| match m.expect("job completed").out {
                FleetOut::Ppl(p) => FleetJobResult::Ppl(p),
                FleetOut::Partials(p) => FleetJobResult::Partials(p),
            })
            .collect();
        let sharded = reduce_fleet_results(got_models.len(), &groups, &jobs, outs);
        assert_eq!(solo.len(), sharded.len());
        for (i, (a, b)) in solo.iter().zip(&sharded).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "model {i}: ppl {a} vs {b}");
        }
    }

    #[test]
    fn worker_exit_after_truncates_results_cleanly() {
        let (params, cfg, calib) = setup();
        let configs = grid();
        let metrics = Metrics::new();
        let runner = SweepRunner::new(&params, &cfg, &calib, &metrics);
        let prep = runner.prepare(&configs);
        let names = Params::linear_names(&cfg);
        let src = SweepJobSource {
            configs: &configs,
            cache: &prep.cache,
            prep_rank: prep.prep_rank,
            n_layers: names.len(),
            memo: EncodeMemo::default(),
        };
        let mut tx = BlobTx::new();
        let mut input = Vec::new();
        for j in 0..src.n_jobs() {
            for f in src.encode(j, &mut tx) {
                f.write_to(&mut input).unwrap();
            }
        }
        // no shutdown frame: the worker dies by exit_after, as in a crash
        let out = SharedBuf::default();
        run_worker(Cursor::new(input), out.clone(), Some(3)).unwrap();
        let bytes = out.0.lock().unwrap().clone();
        let mut n_results = 0;
        let mut cur = Cursor::new(&bytes[..]);
        while let Some(f) = wire::read_frame(&mut cur).unwrap() {
            if f.kind == kind::SWEEP_RESULT {
                n_results += 1;
            }
        }
        assert_eq!(n_results, 3, "exactly exit_after results, all complete frames");
    }

    #[test]
    fn worker_binary_env_override_wins() {
        let opts = ShardOptions {
            binary: Some(PathBuf::from("/explicit/srr")),
            ..Default::default()
        };
        assert_eq!(worker_binary(&opts).unwrap(), PathBuf::from("/explicit/srr"));
    }

    // -----------------------------------------------------------------------
    // fault injection (satellite: FaultTransport property suite)
    // -----------------------------------------------------------------------

    use crate::coordinator::jobs::byte_pipe;
    use crate::coordinator::transport::{FaultPlan, FaultTransport, Transport};
    use crate::util::prop;

    /// A worker on a thread behind in-memory pipes, with `plan`
    /// interposed on the host side of both directions.
    fn fault_worker(plan: FaultPlan) -> Box<dyn Transport> {
        let (host_to_worker, worker_input) = byte_pipe(1 << 16);
        let (worker_output, worker_to_host) = byte_pipe(1 << 16);
        std::thread::spawn(move || {
            // errors are the host's problem: a severed pipe here is the
            // crash being simulated
            let _ = run_worker(worker_input, worker_output, None);
        });
        Box::new(FaultTransport::new(host_to_worker, worker_to_host, plan))
    }

    /// One seeded fault schedule. Corruption severs the stream right
    /// after the corrupted byte: a flip landing in a frame's *header
    /// length field* (not covered by the payload checksum) would
    /// otherwise leave the host parser waiting for bytes the worker
    /// will never send — an unbounded stall `poll_dead` cannot see.
    /// With the cut at `at + 1` every corrupted stream terminates, and
    /// the parser observes the damage as `Truncated`/`BadChecksum`
    /// either way (the dedicated transport unit tests cover the pure
    /// checksum path deterministically).
    fn random_plan(g: &mut prop::Gen) -> FaultPlan {
        match g.rng.below(5) {
            0 => FaultPlan::default(),
            1 => FaultPlan {
                chop: 1 + g.rng.below(7),
                flush_delay: Duration::from_millis(g.rng.below(3) as u64),
                ..Default::default()
            },
            2 => FaultPlan {
                cut_tx_after: Some(g.rng.below(200_000) as u64),
                chop: g.rng.below(9),
                ..Default::default()
            },
            3 => FaultPlan {
                cut_rx_after: Some(g.rng.below(100_000) as u64),
                ..Default::default()
            },
            _ => {
                let at = g.rng.below(100_000) as u64;
                FaultPlan {
                    corrupt_rx: Some((at, 1 << g.rng.below(8))),
                    cut_rx_after: Some(at + 1),
                    ..Default::default()
                }
            }
        }
    }

    /// Records how often each job was dispatched, so the suite can
    /// prove a completed job is never handed out again: a job's
    /// dispatch count can only exceed one by way of worker-death
    /// requeue.
    struct CountingSource<S> {
        inner: S,
        counts: RefCell<Vec<usize>>,
    }

    impl<S: JobSource> JobSource for CountingSource<S> {
        fn n_jobs(&self) -> usize {
            self.inner.n_jobs()
        }
        fn encode(&self, job: usize, tx: &mut BlobTx) -> Vec<Frame> {
            self.counts.borrow_mut()[job] += 1;
            self.inner.encode(job, tx)
        }
    }

    /// Satellite: for seeded schedules of byte-chopped writes, delayed
    /// flushes, mid-frame disconnects, and bit corruption, the
    /// dispatcher never deadlocks (worker 0 stays clean, so every run
    /// must complete), never double-assigns a completed job (dispatch
    /// counts bounded by deaths), and the surviving workers' merged
    /// results stay bit-identical to the in-process `SweepRunner`.
    /// Failures report a seed replayable via `util::prop::replay`.
    #[test]
    fn prop_fault_schedules_never_deadlock_or_double_assign() {
        let (params, cfg, calib) = setup();
        let configs: Vec<SweepConfig> = grid().into_iter().take(3).collect();
        let metrics = Metrics::new();
        let runner = SweepRunner::new(&params, &cfg, &calib, &metrics);
        let expect = runner.run_factored(&configs);
        let prep = runner.prepare(&configs);
        let names = Params::linear_names(&cfg);
        let n_layers = names.len();

        prop::check(0xFA17, 6, |g| {
            let n_workers = 2 + g.rng.below(2);
            let transports: Vec<Box<dyn Transport>> = (0..n_workers)
                .map(|wi| {
                    // worker 0 is always clean: the run must finish
                    let plan = if wi == 0 { FaultPlan::default() } else { random_plan(g) };
                    fault_worker(plan)
                })
                .collect();
            let mut session = ShardSession::from_transports(transports).unwrap();
            {
                let mut rx = session.rx().lock().unwrap();
                for layer in &prep.cache.layers {
                    for arc in layer.qdeq0.values() {
                        rx.seed_mat(arc);
                    }
                    for arc in layer.qdeq0_packed.values() {
                        rx.seed_packed(arc);
                    }
                }
            }
            let src = CountingSource {
                inner: SweepJobSource {
                    configs: &configs,
                    cache: &prep.cache,
                    prep_rank: prep.prep_rank,
                    n_layers,
                    memo: EncodeMemo::default(),
                },
                counts: RefCell::new(vec![0; configs.len() * n_layers]),
            };
            let case_metrics = Metrics::new();
            let msgs = session
                .run_jobs(&src, &case_metrics)
                .expect("a clean worker survives every schedule");
            let parts = {
                let rx = session.rx().lock().unwrap();
                sweep_parts(msgs, &rx, &configs, &names, n_layers, &prep).unwrap()
            };
            let got = assemble_outcomes(&params, &names, configs.len(), parts, &case_metrics);
            assert_outcomes_identical(&expect, &got);

            let deaths = case_metrics.get("shard.worker_deaths") as usize;
            for (j, &c) in src.counts.borrow().iter().enumerate() {
                assert!(c >= 1, "job {j} was never dispatched");
                assert!(
                    c <= 1 + deaths,
                    "job {j} dispatched {c}× with only {deaths} worker death(s) — \
                     a completed job was re-assigned"
                );
            }
            session.shutdown();
        });
    }

    /// Every worker faulted to death: the dispatcher must error out —
    /// "all shard workers died" — rather than hang waiting on peers
    /// that will never answer.
    #[test]
    fn all_faulty_workers_error_instead_of_hanging() {
        let (params, cfg, calib) = setup();
        let configs: Vec<SweepConfig> = grid().into_iter().take(2).collect();
        let metrics = Metrics::new();
        let runner = SweepRunner::new(&params, &cfg, &calib, &metrics);
        let prep = runner.prepare(&configs);
        let names = Params::linear_names(&cfg);

        let transports: Vec<Box<dyn Transport>> = (0..2)
            .map(|_| {
                fault_worker(FaultPlan { cut_tx_after: Some(100), ..Default::default() })
            })
            .collect();
        let mut session = ShardSession::from_transports(transports).unwrap();
        let src = SweepJobSource {
            configs: &configs,
            cache: &prep.cache,
            prep_rank: prep.prep_rank,
            n_layers: names.len(),
            memo: EncodeMemo::default(),
        };
        let err = session.run_jobs(&src, &metrics).expect_err("no worker can finish a job");
        assert!(
            err.to_string().contains("all shard workers died"),
            "unexpected error: {err:#}"
        );
    }
}
