//! Disk-backed artifact store for out-of-core sweeps (`srr ptq --spill`).
//!
//! A sweep grid's shared artifacts — phase-A scalings / Hessians / k=0
//! quantizations / spectra, phase-B1 residual SVDs, and the per-(layer,
//! config) phase-B2 cell results — can dwarf host memory on wide grids.
//! [`SpillStore`] keeps them on disk instead, and
//! [`run_sweep_spilled`] streams the sweep through a bounded in-memory
//! working set:
//!
//! * **phase A** — each layer is prepared (same [`prepare_layer`] the
//!   in-memory engine runs), spilled, and dropped; at most one layer per
//!   worker thread is ever resident;
//! * **phase B1** — residual SVDs reload only the `W`/`Qdeq` blobs they
//!   consume, through the bounded blob cache;
//! * **phase B2** — layer-major: one layer's artifacts are reloaded into
//!   a single-layer [`LayerCache`], its missing cells fan out over the
//!   pool ([`b2_artifacts`] / [`b2_job`], the same bit-identity seam the
//!   shard plane uses), each [`QerResult`] is spilled as its cell
//!   completes, and the layer is dropped before the next loads.
//!
//! # Disk layout
//!
//! ```text
//! DIR/
//!   blobs/<hash:032x>.blob   one wire frame each (BLOB_MAT/BLOB_PACKED),
//!                            content-addressed, written tmp+rename+fsync
//!   manifest.srrm            append-only log of wire frames:
//!                            HEADER(32) PREP(33) RESID(34) CELL(35)
//! ```
//!
//! Both files reuse `coordinator::wire`'s framing, so every read gets
//! magic/version/checksum validation for free — a torn or bit-flipped
//! blob or record surfaces as a [`wire::WireError`], never as silent
//! corruption. Blobs are fsynced *before* the manifest record that
//! references them is appended and fsynced, so a record present in the
//! manifest implies its blobs are durable.
//!
//! # Crash resume
//!
//! The manifest is a chunk-completion log: one record per finished unit
//! of work. Reopening a spill dir replays it; [`run_sweep_spilled`]
//! recomputes only units without a record, so a sweep killed mid-run
//! resumes from the last completed chunk. A torn final append (the only
//! kind the write protocol can produce) fails the frame checksum or
//! truncates mid-frame; the loader treats any unreadable tail as "chunk
//! incomplete", truncates it away, and resumes — it never fails the
//! whole store over a torn last record. A [`sweep_fingerprint`] in the
//! HEADER record pins the store to one (model, grid) pair; resuming with
//! a different sweep is an error, not a silent mix.
//!
//! # Bit-identity invariants
//!
//! Spilled sweeps must be indistinguishable from in-memory ones:
//!
//! * every artifact round-trips bit-exactly (f32/f64 little-endian wire
//!   encoding is lossless, packed words are integers);
//! * all RNG streams are salted off (seed, layer) exactly as in-memory
//!   ([`compute_resid_svd`], [`b2_job`]) — *where* an artifact lives
//!   never shifts a draw;
//! * assembly reproduces the in-memory `Arc` topology: shared cells
//!   (w-only / plain QER) hand every rank/scaling variant *one*
//!   `Arc<PackedMat>` per content hash (grid dedup — what
//!   `eval::fleet` groups into lock-step batches), while every other
//!   base gets a fresh `Arc` per cell so pointer-based fleet grouping
//!   cannot coarsen — the same rule the shard plane's result assembly
//!   applies.

use std::collections::HashMap;
use std::fs::{self, File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::linalg::Svd;
use crate::model::{CalibrationSet, Params};
use crate::qer::{Method, PreparedSpectra, QerResult, RankSelection};
use crate::quant::PackedMat;
use crate::runtime::manifest::ModelCfg;
use crate::scaling::{Scaling, ScalingKind};
use crate::serve::{LinearOp, QuantBase};
use crate::tensor::Mat;
use crate::util::pool;

use super::cache::{LayerCache, PreparedLayer};
use super::metrics::Metrics;
use super::pipeline::{layer_salt, FactoredOutcome, LayerMeta, LayerReport};
use super::sweep::{
    assemble_outcomes, b2_artifacts, b2_job, compute_resid_svd, empty_outcomes, prepare_layer,
    sweep_keys, LayerKeys, SweepConfig, SweepKeys,
};
use super::wire::{
    self, content_hash128, get_mat, get_opt, get_packed, get_scaling_kind, get_selection,
    get_wire_base, get_wire_scaling, get_wire_spectra, get_wire_svd, kind, put_mat,
    put_model_cfg, put_opt, put_packed, put_scaling_kind, put_selection, put_sweep_config,
    put_wire_base, put_wire_scaling, put_wire_spectra, put_wire_svd, read_frame, Frame,
    WireBase, WireReader, WireScaling, WireSpectra, WireSvd, WireWriter,
};

/// Manifest record kinds (disjoint from the shard plane's 1–16 so a
/// manifest accidentally fed to a shard decoder is rejected, not
/// misparsed).
const REC_HEADER: u8 = 32;
const REC_PREP: u8 = 33;
const REC_RESID: u8 = 34;
const REC_CELL: u8 = 35;

/// Exit code of the env-triggered kill hooks, distinct from the CLI's
/// generic failure exit(1) so the kill-and-resume integration test can
/// tell "killed as planned" from "crashed".
pub const KILL_EXIT_CODE: i32 = 17;

/// Tuning + fault-injection knobs for a [`SpillStore`].
#[derive(Clone, Debug)]
pub struct SpillOptions {
    /// strong blob-cache budget in bytes (the bounded working set);
    /// blobs beyond it are dropped LRU-first and reloaded on demand
    pub cap_bytes: usize,
    /// test hook: after this many successful record appends (each
    /// fsynced), the next append returns an error — an in-process
    /// simulation of a kill at a chunk boundary
    pub abort_after_records: Option<usize>,
    /// test hook: the N-th record append writes only half its frame
    /// bytes, syncs, and errors — an in-process simulation of a torn
    /// final write
    pub torn_after_records: Option<usize>,
}

impl Default for SpillOptions {
    fn default() -> Self {
        SpillOptions {
            cap_bytes: 256 << 20,
            abort_after_records: None,
            torn_after_records: None,
        }
    }
}

/// Counters for the bench legs (`BENCH_spill.json`) and the CLI report.
#[derive(Clone, Copy, Debug, Default)]
pub struct SpillStats {
    /// bytes durably written (blobs + manifest records)
    pub bytes_spilled: u64,
    /// bytes read back from disk (blob reloads)
    pub bytes_reloaded: u64,
    /// high-water mark of strong blob-cache residency — the store's
    /// peak-RSS proxy for the bounded working set
    pub peak_resident_bytes: u64,
    /// manifest records currently known (header included)
    pub records: usize,
}

/// The spill manifest header: pins the store to one (model, grid) pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Header {
    fingerprint: u128,
    n_layers: usize,
    n_configs: usize,
    prep_rank: usize,
}

/// One layer's phase-A completion record: blob refs for every artifact,
/// aligned with the layer's [`LayerKeys`] lists.
#[derive(Clone, Debug)]
pub(crate) struct PrepRecord {
    pub name: String,
    pub w: wire::BlobRef,
    pub scalings: Vec<(ScalingKind, WireScaling)>,
    pub hessian: Option<wire::BlobRef>,
    /// (dense ref, packed ref) per entry of `LayerKeys::qdeq0_keys`
    pub qdeq0: Vec<(wire::BlobRef, Option<wire::BlobRef>)>,
    /// per entry of `LayerKeys::spectra_keys`
    pub spectra: Vec<WireSpectra>,
    pub prep_secs: f64,
}

/// One completed phase-B2 cell.
#[derive(Clone, Debug)]
pub(crate) struct CellRecord {
    pub base: WireBase,
    pub l: Mat,
    pub r: Mat,
    pub k_star: usize,
    pub selection: Option<RankSelection>,
    pub weight_err: f64,
    pub scaled_err: f64,
    pub qer_secs: f64,
}

/// The base a completed cell spills: borrowed from a [`QerResult`]
/// in-process or resolved out of a shard session's blob cache.
pub(crate) enum SpillBase<'a> {
    Packed(&'a PackedMat),
    Dense(&'a Mat),
}

struct Manifest {
    file: File,
    header: Option<Header>,
    preps: HashMap<usize, Arc<PrepRecord>>,
    resids: HashMap<(usize, usize), WireSvd>,
    cells: HashMap<(usize, usize), Arc<CellRecord>>,
    /// records appended by this process (drives the kill hooks)
    appended: usize,
}

/// Strong-LRU + weak-identity blob cache: the strong side is the bounded
/// working set; the weak side guarantees that as long as *any* consumer
/// holds a blob's `Arc`, reloading the same hash returns that very `Arc`
/// — eviction can never split one logical buffer into two, so the
/// outcome `Arc` topology (grid dedup, lock-step groups) survives any
/// cap setting.
struct BlobCache {
    cap: usize,
    clock: u64,
    resident: usize,
    peak: usize,
    mats: HashMap<u128, (u64, Arc<Mat>)>,
    packed: HashMap<u128, (u64, Arc<PackedMat>)>,
    weak_mats: HashMap<u128, Weak<Mat>>,
    weak_packed: HashMap<u128, Weak<PackedMat>>,
}

fn mat_bytes(m: &Mat) -> usize {
    m.data.len() * 4
}

impl BlobCache {
    fn new(cap: usize) -> Self {
        BlobCache {
            cap,
            clock: 0,
            resident: 0,
            peak: 0,
            mats: HashMap::new(),
            packed: HashMap::new(),
            weak_mats: HashMap::new(),
            weak_packed: HashMap::new(),
        }
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    fn get_mat(&mut self, h: u128) -> Option<Arc<Mat>> {
        let t = self.tick();
        if let Some((stamp, a)) = self.mats.get_mut(&h) {
            *stamp = t;
            return Some(a.clone());
        }
        if let Some(a) = self.weak_mats.get(&h).and_then(Weak::upgrade) {
            self.resident += mat_bytes(&a);
            self.mats.insert(h, (t, a.clone()));
            self.evict();
            return Some(a);
        }
        None
    }

    fn get_packed(&mut self, h: u128) -> Option<Arc<PackedMat>> {
        let t = self.tick();
        if let Some((stamp, a)) = self.packed.get_mut(&h) {
            *stamp = t;
            return Some(a.clone());
        }
        if let Some(a) = self.weak_packed.get(&h).and_then(Weak::upgrade) {
            self.resident += a.bytes();
            self.packed.insert(h, (t, a.clone()));
            self.evict();
            return Some(a);
        }
        None
    }

    fn insert_mat(&mut self, h: u128, m: Mat) -> Arc<Mat> {
        let t = self.tick();
        let a = Arc::new(m);
        self.weak_mats.insert(h, Arc::downgrade(&a));
        self.resident += mat_bytes(&a);
        self.mats.insert(h, (t, a.clone()));
        self.evict();
        a
    }

    fn insert_packed(&mut self, h: u128, p: PackedMat) -> Arc<PackedMat> {
        let t = self.tick();
        let a = Arc::new(p);
        self.weak_packed.insert(h, Arc::downgrade(&a));
        self.resident += a.bytes();
        self.packed.insert(h, (t, a.clone()));
        self.evict();
        a
    }

    /// Drop LRU entries until resident ≤ cap. Only strong refs are
    /// dropped; live `Arc`s elsewhere stay reachable via the weak maps.
    fn evict(&mut self) {
        self.peak = self.peak.max(self.resident);
        while self.resident > self.cap {
            let oldest_mat = self.mats.iter().map(|(h, (s, _))| (*s, *h)).min();
            let oldest_packed = self.packed.iter().map(|(h, (s, _))| (*s, *h)).min();
            match (oldest_mat, oldest_packed) {
                (Some((sm, hm)), Some((sp, hp))) => {
                    if sm <= sp {
                        self.drop_mat(hm);
                    } else {
                        self.drop_packed(hp);
                    }
                }
                (Some((_, hm)), None) => self.drop_mat(hm),
                (None, Some((_, hp))) => self.drop_packed(hp),
                (None, None) => break,
            }
        }
    }

    fn drop_mat(&mut self, h: u128) {
        if let Some((_, a)) = self.mats.remove(&h) {
            self.resident -= mat_bytes(&a);
        }
    }

    fn drop_packed(&mut self, h: u128) {
        if let Some((_, a)) = self.packed.remove(&h) {
            self.resident -= a.bytes();
        }
    }
}

/// A disk-backed sweep-artifact store rooted at one directory. Safe to
/// share across the worker pool (`&self` methods, internal locking).
pub struct SpillStore {
    blobs: PathBuf,
    opts: SpillOptions,
    /// env-triggered kill hooks (`SRR_SPILL_KILL_AFTER=N`,
    /// `SRR_SPILL_KILL_TORN=N`): process::exit after / torn-write at the
    /// N-th append — the process-level kill-and-resume test harness
    kill_after: Option<usize>,
    kill_torn: Option<usize>,
    manifest: Mutex<Manifest>,
    cache: Mutex<BlobCache>,
    tmp_counter: AtomicU64,
    bytes_spilled: AtomicU64,
    bytes_reloaded: AtomicU64,
}

impl SpillStore {
    /// Open (creating or resuming) the spill store at `dir`. A torn
    /// trailing manifest record is truncated away; every complete record
    /// is replayed into the completion maps.
    pub fn open(dir: impl AsRef<Path>, opts: SpillOptions) -> Result<SpillStore> {
        let dir = dir.as_ref();
        let blobs = dir.join("blobs");
        fs::create_dir_all(&blobs)
            .with_context(|| format!("creating spill dir {}", blobs.display()))?;
        let manifest_path = dir.join("manifest.srrm");

        let (frames, truncated, good_len) = scan_manifest(&manifest_path)?;
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&manifest_path)
            .with_context(|| format!("opening spill manifest {}", manifest_path.display()))?;
        if truncated {
            // torn final write: drop the unreadable tail so this run's
            // appends extend from the last complete record
            file.set_len(good_len)?;
            file.sync_all()?;
        }

        let mut header = None;
        let mut preps = HashMap::new();
        let mut resids = HashMap::new();
        let mut cells = HashMap::new();
        for f in frames {
            match f.kind {
                REC_HEADER => {
                    ensure!(header.is_none(), "duplicate spill manifest header");
                    header = Some(decode_header(&f.payload)?);
                }
                REC_PREP => {
                    ensure!(header.is_some(), "spill PREP record before header");
                    let (li, rec) = decode_prep(&f.payload)?;
                    preps.insert(li, Arc::new(rec));
                }
                REC_RESID => {
                    ensure!(header.is_some(), "spill RESID record before header");
                    let (li, ri, svd) = decode_resid(&f.payload)?;
                    resids.insert((li, ri), svd);
                }
                REC_CELL => {
                    ensure!(header.is_some(), "spill CELL record before header");
                    let (ci, li, rec) = decode_cell(&f.payload)?;
                    cells.insert((ci, li), Arc::new(rec));
                }
                k => bail!("unknown spill manifest record kind {k}"),
            }
        }

        let kill_after = std::env::var("SRR_SPILL_KILL_AFTER")
            .ok()
            .and_then(|v| v.parse().ok());
        let kill_torn = std::env::var("SRR_SPILL_KILL_TORN")
            .ok()
            .and_then(|v| v.parse().ok());
        let cap = opts.cap_bytes;
        Ok(SpillStore {
            blobs,
            opts,
            kill_after,
            kill_torn,
            manifest: Mutex::new(Manifest {
                file,
                header,
                preps,
                resids,
                cells,
                appended: 0,
            }),
            cache: Mutex::new(BlobCache::new(cap)),
            tmp_counter: AtomicU64::new(0),
            bytes_spilled: AtomicU64::new(0),
            bytes_reloaded: AtomicU64::new(0),
        })
    }

    /// Snapshot the store's counters.
    pub fn stats(&self) -> SpillStats {
        let man = self.manifest.lock().unwrap();
        let cache = self.cache.lock().unwrap();
        SpillStats {
            bytes_spilled: self.bytes_spilled.load(Ordering::Relaxed),
            bytes_reloaded: self.bytes_reloaded.load(Ordering::Relaxed),
            peak_resident_bytes: cache.peak.max(cache.resident) as u64,
            records: man.header.is_some() as usize
                + man.preps.len()
                + man.resids.len()
                + man.cells.len(),
        }
    }

    /// Bind the store to one sweep. Fresh store: writes the HEADER
    /// record. Resumed store: verifies the fingerprint and dimensions
    /// match — a spill dir holding a *different* sweep is an error, not
    /// a silent mix. Returns whether completed work was found.
    pub(crate) fn begin(
        &self,
        fingerprint: u128,
        n_layers: usize,
        n_configs: usize,
        prep_rank: usize,
    ) -> Result<bool> {
        let want = Header { fingerprint, n_layers, n_configs, prep_rank };
        let mut man = self.manifest.lock().unwrap();
        match man.header {
            Some(have) => {
                ensure!(
                    have == want,
                    "spill dir holds a different sweep (manifest fingerprint \
                     {:032x}, this sweep {:032x}) — use a fresh --spill dir",
                    have.fingerprint,
                    fingerprint
                );
                for li in man.preps.keys() {
                    ensure!(*li < n_layers, "spill PREP record for layer {li} out of range");
                }
                for (ci, li) in man.cells.keys() {
                    ensure!(
                        *ci < n_configs && *li < n_layers,
                        "spill CELL record ({ci}, {li}) out of range"
                    );
                }
                Ok(!man.preps.is_empty() || !man.cells.is_empty() || !man.resids.is_empty())
            }
            None => {
                let mut w = WireWriter::new();
                w.put_u128(want.fingerprint);
                w.put_usize(want.n_layers);
                w.put_usize(want.n_configs);
                w.put_usize(want.prep_rank);
                self.append(&mut man, REC_HEADER, w.into_bytes())?;
                man.header = Some(want);
                Ok(false)
            }
        }
    }

    pub(crate) fn prep_done(&self, li: usize) -> bool {
        self.manifest.lock().unwrap().preps.contains_key(&li)
    }

    pub(crate) fn resid_done(&self, li: usize, ri: usize) -> bool {
        self.manifest.lock().unwrap().resids.contains_key(&(li, ri))
    }

    pub(crate) fn cell_done(&self, ci: usize, li: usize) -> bool {
        self.manifest.lock().unwrap().cells.contains_key(&(ci, li))
    }

    pub(crate) fn prep_record(&self, li: usize) -> Result<Arc<PrepRecord>> {
        self.manifest
            .lock()
            .unwrap()
            .preps
            .get(&li)
            .cloned()
            .ok_or_else(|| anyhow!("spill manifest has no PREP record for layer {li}"))
    }

    // ---- durable writes ---------------------------------------------------

    /// Append one record frame: full bytes, then fsync. The torn/abort
    /// fault hooks live here — they are the *only* way this store
    /// produces a partial record, mirroring the only way a real crash
    /// can (the kernel persisting a prefix of an in-flight append).
    fn append(&self, man: &mut Manifest, k: u8, payload: Vec<u8>) -> Result<()> {
        man.appended += 1;
        let n = man.appended;
        let mut buf = Vec::new();
        Frame { kind: k, payload }.write_to(&mut buf).expect("vec write cannot fail");
        if self.opts.torn_after_records == Some(n) || self.kill_torn == Some(n) {
            let half = buf.len() / 2;
            man.file.write_all(&buf[..half])?;
            man.file.sync_all()?;
            if self.kill_torn == Some(n) {
                std::process::exit(KILL_EXIT_CODE);
            }
            bail!("spill: simulated torn write at record {n}");
        }
        man.file.write_all(&buf)?;
        man.file.sync_all()?;
        self.bytes_spilled.fetch_add(buf.len() as u64, Ordering::Relaxed);
        if self.kill_after == Some(n) {
            std::process::exit(KILL_EXIT_CODE);
        }
        if self.opts.abort_after_records == Some(n) {
            bail!("spill: simulated crash after record {n}");
        }
        Ok(())
    }

    fn blob_path(&self, h: u128) -> PathBuf {
        self.blobs.join(format!("{h:032x}.blob"))
    }

    /// Write one content-addressed blob durably (tmp + fsync + rename).
    /// Idempotent: an existing blob of the same hash is kept as-is, so
    /// concurrent writers and resumed runs converge on one file.
    fn write_blob(&self, k: u8, h: u128, body: Vec<u8>) -> Result<()> {
        let path = self.blob_path(h);
        if path.exists() {
            return Ok(());
        }
        let tmp = self.blobs.join(format!(
            "{h:032x}.tmp.{}.{}",
            std::process::id(),
            self.tmp_counter.fetch_add(1, Ordering::Relaxed)
        ));
        let bytes = body.len() as u64 + 24;
        let mut f = File::create(&tmp)
            .with_context(|| format!("creating spill blob {}", tmp.display()))?;
        Frame { kind: k, payload: body }.write_to(&mut f)?;
        f.sync_all()?;
        drop(f);
        fs::rename(&tmp, &path)?;
        // durability of the rename itself; best-effort where directory
        // fsync is unsupported
        let _ = File::open(&self.blobs).and_then(|d| d.sync_all());
        self.bytes_spilled.fetch_add(bytes, Ordering::Relaxed);
        Ok(())
    }

    fn put_mat_blob(&self, m: &Mat) -> Result<wire::BlobRef> {
        let (h, body) = wire::encode_mat_blob(m);
        self.write_blob(kind::BLOB_MAT, h, body)?;
        Ok(h)
    }

    fn put_packed_blob(&self, p: &PackedMat) -> Result<wire::BlobRef> {
        let (h, body) = wire::encode_packed_blob(p);
        self.write_blob(kind::BLOB_PACKED, h, body)?;
        Ok(h)
    }

    fn put_svd(&self, svd: &Svd) -> Result<WireSvd> {
        Ok(WireSvd {
            u: self.put_mat_blob(&svd.u)?,
            s: svd.s.clone(),
            v: self.put_mat_blob(&svd.v)?,
        })
    }

    fn put_scaling(&self, s: &Scaling) -> Result<WireScaling> {
        Ok(match s {
            Scaling::Identity => WireScaling::Identity,
            Scaling::Diagonal { d, d_inv } => {
                WireScaling::Diagonal { d: d.clone(), d_inv: d_inv.clone() }
            }
            Scaling::Full { s, s_inv } => WireScaling::Full {
                s: self.put_mat_blob(s)?,
                s_inv: self.put_mat_blob(s_inv)?,
            },
        })
    }

    /// Spill one prepared layer: every blob first, then the PREP record
    /// that makes the layer's completion durable.
    pub(crate) fn spill_prep(
        &self,
        li: usize,
        layer: &PreparedLayer,
        lk: &LayerKeys,
        kinds: &[ScalingKind],
    ) -> Result<()> {
        let w_ref = self.put_mat_blob(&layer.w)?;
        let mut scalings = Vec::with_capacity(kinds.len());
        for &k in kinds {
            let s = layer
                .scalings
                .get(&k)
                .ok_or_else(|| anyhow!("layer {li} missing prepared scaling"))?;
            scalings.push((k, self.put_scaling(s)?));
        }
        let hessian = match &layer.hessian {
            Some(h) => Some(self.put_mat_blob(h)?),
            None => None,
        };
        let mut qdeq0 = Vec::with_capacity(lk.qdeq0_keys.len());
        for (label, seed, _) in &lk.qdeq0_keys {
            let d = layer
                .qdeq0
                .get(&(label.clone(), *seed))
                .ok_or_else(|| anyhow!("layer {li} missing prepared qdeq0 {label}/{seed}"))?;
            let dh = self.put_mat_blob(d)?;
            let ph = match layer.qdeq0_packed.get(&(label.clone(), *seed)) {
                Some(p) => Some(self.put_packed_blob(p)?),
                None => None,
            };
            qdeq0.push((dh, ph));
        }
        let mut spectra = Vec::with_capacity(lk.spectra_keys.len());
        for (k, seed) in &lk.spectra_keys {
            let sp = layer
                .spectra
                .get(&(*k, *seed))
                .ok_or_else(|| anyhow!("layer {li} missing prepared spectra"))?;
            spectra.push(WireSpectra {
                sw: self.put_svd(&sp.sw_svd)?,
                sw_frob2: sp.sw_frob2,
                se: self.put_svd(&sp.se_svd)?,
                se_frob2: sp.se_frob2,
                rank: sp.rank,
                seed: sp.seed,
            });
        }
        let rec = PrepRecord {
            name: layer.name.clone(),
            w: w_ref,
            scalings,
            hessian,
            qdeq0,
            spectra,
            prep_secs: layer.prep_secs,
        };
        let payload = encode_prep(li, &rec);
        let mut man = self.manifest.lock().unwrap();
        self.append(&mut man, REC_PREP, payload)?;
        man.preps.insert(li, Arc::new(rec));
        Ok(())
    }

    /// Spill one phase-B1 residual SVD.
    pub(crate) fn spill_resid(&self, li: usize, ri: usize, svd: &Svd) -> Result<()> {
        let ws = self.put_svd(svd)?;
        let mut w = WireWriter::new();
        w.put_usize(li);
        w.put_usize(ri);
        put_wire_svd(&mut w, &ws);
        let mut man = self.manifest.lock().unwrap();
        self.append(&mut man, REC_RESID, w.into_bytes())?;
        man.resids.insert((li, ri), ws);
        Ok(())
    }

    /// Spill one completed phase-B2 cell.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn spill_cell(
        &self,
        ci: usize,
        li: usize,
        base: SpillBase<'_>,
        l: &Mat,
        r: &Mat,
        k_star: usize,
        selection: Option<&RankSelection>,
        weight_err: f64,
        scaled_err: f64,
        qer_secs: f64,
    ) -> Result<()> {
        let wb = match base {
            SpillBase::Packed(p) => WireBase::Packed(self.put_packed_blob(p)?),
            SpillBase::Dense(m) => WireBase::Dense(self.put_mat_blob(m)?),
        };
        let rec = CellRecord {
            base: wb,
            l: l.clone(),
            r: r.clone(),
            k_star,
            selection: selection.cloned(),
            weight_err,
            scaled_err,
            qer_secs,
        };
        let payload = encode_cell(ci, li, &rec);
        let mut man = self.manifest.lock().unwrap();
        self.append(&mut man, REC_CELL, payload)?;
        man.cells.insert((ci, li), Arc::new(rec));
        Ok(())
    }

    // ---- reloads ----------------------------------------------------------

    fn read_blob(&self, expect_kind: u8, h: u128) -> Result<Vec<u8>> {
        let path = self.blob_path(h);
        let mut f = File::open(&path)
            .with_context(|| format!("spill blob {h:032x} missing from {}", path.display()))?;
        let frame = read_frame(&mut f)
            .with_context(|| format!("spill blob {h:032x} unreadable"))?
            .ok_or_else(|| anyhow!("spill blob {h:032x} is empty"))?;
        ensure!(frame.kind == expect_kind, "spill blob {h:032x} has the wrong kind");
        ensure!(
            content_hash128(&frame.payload) == h,
            "spill blob {h:032x} content does not match its address"
        );
        self.bytes_reloaded
            .fetch_add(frame.payload.len() as u64 + 24, Ordering::Relaxed);
        Ok(frame.payload)
    }

    /// Load a matrix blob through the bounded cache. Identity contract:
    /// while any `Arc` for `h` is alive, every load returns that `Arc`.
    pub(crate) fn load_mat(&self, h: wire::BlobRef) -> Result<Arc<Mat>> {
        let mut cache = self.cache.lock().unwrap();
        if let Some(a) = cache.get_mat(h) {
            return Ok(a);
        }
        let payload = self.read_blob(kind::BLOB_MAT, h)?;
        let mut r = WireReader::new(&payload);
        let m = get_mat(&mut r)?;
        ensure!(r.is_done(), "spill mat blob {h:032x} has trailing bytes");
        Ok(cache.insert_mat(h, m))
    }

    /// [`SpillStore::load_mat`] for packed bases.
    pub(crate) fn load_packed(&self, h: wire::BlobRef) -> Result<Arc<PackedMat>> {
        let mut cache = self.cache.lock().unwrap();
        if let Some(a) = cache.get_packed(h) {
            return Ok(a);
        }
        let payload = self.read_blob(kind::BLOB_PACKED, h)?;
        let mut r = WireReader::new(&payload);
        let p = get_packed(&mut r)?;
        ensure!(r.is_done(), "spill packed blob {h:032x} has trailing bytes");
        Ok(cache.insert_packed(h, p))
    }

    fn load_scaling(&self, ws: &WireScaling) -> Result<Scaling> {
        Ok(match ws {
            WireScaling::Identity => Scaling::Identity,
            WireScaling::Diagonal { d, d_inv } => {
                Scaling::Diagonal { d: d.clone(), d_inv: d_inv.clone() }
            }
            WireScaling::Full { s, s_inv } => Scaling::Full {
                s: (*self.load_mat(*s)?).clone(),
                s_inv: (*self.load_mat(*s_inv)?).clone(),
            },
        })
    }

    fn load_svd(&self, ws: &WireSvd) -> Result<Svd> {
        Ok(Svd {
            u: (*self.load_mat(ws.u)?).clone(),
            s: ws.s.clone(),
            v: (*self.load_mat(ws.v)?).clone(),
        })
    }

    /// Rebuild one layer's [`PreparedLayer`] from its PREP record — the
    /// same reconstruction the shard host applies to prep results, so
    /// the rebuilt artifacts are bit-identical to the in-memory ones.
    pub(crate) fn load_layer(&self, li: usize, lk: &LayerKeys) -> Result<PreparedLayer> {
        let rec = self.prep_record(li)?;
        ensure!(
            rec.qdeq0.len() == lk.qdeq0_keys.len()
                && rec.spectra.len() == lk.spectra_keys.len(),
            "spill PREP record for layer {li} does not match this grid's key lists"
        );
        let w = (*self.load_mat(rec.w)?).clone();
        let mut scalings = HashMap::new();
        for (k, ws) in &rec.scalings {
            scalings.insert(*k, Arc::new(self.load_scaling(ws)?));
        }
        let hessian = match rec.hessian {
            Some(h) => Some(self.load_mat(h)?),
            None => None,
        };
        let mut qdeq0 = HashMap::new();
        let mut qdeq0_packed = HashMap::new();
        for ((label, seed, _), (dh, ph)) in lk.qdeq0_keys.iter().zip(&rec.qdeq0) {
            qdeq0.insert((label.clone(), *seed), self.load_mat(*dh)?);
            if let Some(p) = ph {
                qdeq0_packed.insert((label.clone(), *seed), self.load_packed(*p)?);
            }
        }
        let mut spectra = HashMap::new();
        for ((k, seed), sp) in lk.spectra_keys.iter().zip(&rec.spectra) {
            spectra.insert(
                (*k, *seed),
                Arc::new(PreparedSpectra {
                    sw_svd: self.load_svd(&sp.sw)?,
                    sw_frob2: sp.sw_frob2,
                    se_svd: self.load_svd(&sp.se)?,
                    se_frob2: sp.se_frob2,
                    rank: sp.rank,
                    seed: sp.seed,
                }),
            );
        }
        Ok(PreparedLayer {
            name: rec.name.clone(),
            w,
            scalings,
            hessian,
            qdeq0,
            qdeq0_packed,
            spectra,
            prep_secs: rec.prep_secs,
        })
    }

    /// Reload one spilled phase-B1 residual SVD.
    pub(crate) fn load_resid(&self, li: usize, ri: usize) -> Result<Svd> {
        let ws = self
            .manifest
            .lock()
            .unwrap()
            .resids
            .get(&(li, ri))
            .cloned()
            .ok_or_else(|| anyhow!("spill manifest missing RESID record ({li}, {ri})"))?;
        self.load_svd(&ws)
    }

    /// Rebuild a single-layer [`LayerCache`] (layer `li` at index 0)
    /// with its phase-B1 residuals — the bounded working set one
    /// phase-B2 layer pass runs against.
    pub(crate) fn load_layer_cache(&self, li: usize, lk: &LayerKeys) -> Result<LayerCache> {
        let layer = self.load_layer(li, lk)?;
        let mut cache = LayerCache::new(vec![layer]);
        for (ri, (label, kind, seed, _)) in lk.resid_keys.iter().enumerate() {
            cache.insert_resid(0, label.clone(), *kind, *seed, self.load_resid(li, ri)?);
        }
        Ok(cache)
    }

    /// Assemble the phase-B2 parts for every `(config, layer)` cell in
    /// job-id order from the spilled CELL records, reproducing the
    /// in-memory engine's `Arc` layout exactly (module docs; the same
    /// rule as the shard plane's `sweep_parts`).
    pub(crate) fn assemble_parts(
        &self,
        configs: &[SweepConfig],
        names: &[String],
    ) -> Result<Vec<(LinearOp, LayerMeta, LayerReport)>> {
        let n_layers = names.len();
        let n_configs = configs.len();
        let (cells, prep_secs) = {
            let man = self.manifest.lock().unwrap();
            let cells = (0..n_configs * n_layers)
                .map(|idx| {
                    man.cells.get(&(idx / n_layers, idx % n_layers)).cloned().ok_or_else(
                        || {
                            anyhow!(
                                "spill manifest missing CELL record ({}, {})",
                                idx / n_layers,
                                idx % n_layers
                            )
                        },
                    )
                })
                .collect::<Result<Vec<_>>>()?;
            let prep_secs = (0..n_layers)
                .map(|li| {
                    man.preps
                        .get(&li)
                        .map(|p| p.prep_secs)
                        .ok_or_else(|| anyhow!("spill manifest missing PREP record {li}"))
                })
                .collect::<Result<Vec<_>>>()?;
            (cells, prep_secs)
        };
        let mut parts = Vec::with_capacity(cells.len());
        for (idx, rec) in cells.iter().enumerate() {
            let li = idx % n_layers;
            let shares_cell_base =
                matches!(configs[idx / n_layers].method, Method::WOnly | Method::Qer);
            let base = match rec.base {
                // shared cells alias one Arc per content hash (grid
                // dedup + lock-step groups); everything else gets a
                // fresh Arc per cell so pointer-based fleet grouping
                // cannot coarsen across the disk round-trip
                WireBase::Packed(h) if shares_cell_base => {
                    QuantBase::Packed(self.load_packed(h)?)
                }
                WireBase::Packed(h) => {
                    QuantBase::Packed(Arc::new((*self.load_packed(h)?).clone()))
                }
                WireBase::Dense(h) => QuantBase::Dense(Arc::new((*self.load_mat(h)?).clone())),
            };
            let op = LinearOp::FactoredQlr { base, l: rec.l.clone(), r: rec.r.clone() };
            let meta = LayerMeta {
                name: names[li].clone(),
                k_star: rec.k_star,
                selection: rec.selection.clone(),
            };
            let report = LayerReport {
                name: names[li].clone(),
                k_star: rec.k_star,
                weight_err: rec.weight_err,
                scaled_err: rec.scaled_err,
                scale_secs: prep_secs[li] / n_configs as f64,
                qer_secs: rec.qer_secs,
            };
            parts.push((op, meta, report));
        }
        Ok(parts)
    }
}

// ---------------------------------------------------------------------------
// record payloads
// ---------------------------------------------------------------------------

fn decode_header(payload: &[u8]) -> Result<Header> {
    let mut r = WireReader::new(payload);
    let h = Header {
        fingerprint: r.get_u128()?,
        n_layers: r.get_usize()?,
        n_configs: r.get_usize()?,
        prep_rank: r.get_usize()?,
    };
    ensure!(r.is_done(), "spill header has trailing bytes");
    Ok(h)
}

fn encode_prep(li: usize, rec: &PrepRecord) -> Vec<u8> {
    let mut w = WireWriter::new();
    w.put_usize(li);
    w.put_str(&rec.name);
    w.put_u128(rec.w);
    w.put_usize(rec.scalings.len());
    for (k, ws) in &rec.scalings {
        put_scaling_kind(&mut w, *k);
        put_wire_scaling(&mut w, ws);
    }
    put_opt(&mut w, &rec.hessian, |w, h| w.put_u128(*h));
    w.put_usize(rec.qdeq0.len());
    for (d, p) in &rec.qdeq0 {
        w.put_u128(*d);
        put_opt(&mut w, p, |w, h| w.put_u128(*h));
    }
    w.put_usize(rec.spectra.len());
    for sp in &rec.spectra {
        put_wire_spectra(&mut w, sp);
    }
    w.put_f64(rec.prep_secs);
    w.into_bytes()
}

fn decode_prep(payload: &[u8]) -> Result<(usize, PrepRecord)> {
    let mut r = WireReader::new(payload);
    let li = r.get_usize()?;
    let name = r.get_str()?;
    let w_ref = r.get_u128()?;
    let n = r.get_usize()?;
    let mut scalings = Vec::with_capacity(n);
    for _ in 0..n {
        let k = get_scaling_kind(&mut r)?;
        scalings.push((k, get_wire_scaling(&mut r)?));
    }
    let hessian = get_opt(&mut r, |r| r.get_u128())?;
    let n = r.get_usize()?;
    let mut qdeq0 = Vec::with_capacity(n);
    for _ in 0..n {
        let d = r.get_u128()?;
        let p = get_opt(&mut r, |r| r.get_u128())?;
        qdeq0.push((d, p));
    }
    let n = r.get_usize()?;
    let mut spectra = Vec::with_capacity(n);
    for _ in 0..n {
        spectra.push(get_wire_spectra(&mut r)?);
    }
    let prep_secs = r.get_f64()?;
    ensure!(r.is_done(), "spill PREP record has trailing bytes");
    Ok((li, PrepRecord { name, w: w_ref, scalings, hessian, qdeq0, spectra, prep_secs }))
}

fn decode_resid(payload: &[u8]) -> Result<(usize, usize, WireSvd)> {
    let mut r = WireReader::new(payload);
    let li = r.get_usize()?;
    let ri = r.get_usize()?;
    let svd = get_wire_svd(&mut r)?;
    ensure!(r.is_done(), "spill RESID record has trailing bytes");
    Ok((li, ri, svd))
}

fn encode_cell(ci: usize, li: usize, rec: &CellRecord) -> Vec<u8> {
    let mut w = WireWriter::new();
    w.put_usize(ci);
    w.put_usize(li);
    put_wire_base(&mut w, &rec.base);
    put_mat(&mut w, &rec.l);
    put_mat(&mut w, &rec.r);
    w.put_usize(rec.k_star);
    put_opt(&mut w, &rec.selection, put_selection);
    w.put_f64(rec.weight_err);
    w.put_f64(rec.scaled_err);
    w.put_f64(rec.qer_secs);
    w.into_bytes()
}

fn decode_cell(payload: &[u8]) -> Result<(usize, usize, CellRecord)> {
    let mut r = WireReader::new(payload);
    let ci = r.get_usize()?;
    let li = r.get_usize()?;
    let rec = CellRecord {
        base: get_wire_base(&mut r)?,
        l: get_mat(&mut r)?,
        r: get_mat(&mut r)?,
        k_star: r.get_usize()?,
        selection: get_opt(&mut r, get_selection)?,
        weight_err: r.get_f64()?,
        scaled_err: r.get_f64()?,
        qer_secs: r.get_f64()?,
    };
    ensure!(r.is_done(), "spill CELL record has trailing bytes");
    Ok((ci, li, rec))
}

/// Scan the manifest file: every complete frame, whether the tail was
/// unreadable (torn final write — [`wire::WireError::Truncated`] or a failed
/// frame checksum), and the byte offset of the last complete record.
/// A missing or zero-length file is an empty, untruncated manifest.
fn scan_manifest(path: &Path) -> Result<(Vec<Frame>, bool, u64)> {
    let bytes = match fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
        Err(e) => {
            return Err(e).with_context(|| format!("reading spill manifest {}", path.display()))
        }
    };
    let mut cur = std::io::Cursor::new(&bytes[..]);
    let mut frames = Vec::new();
    let mut good = 0u64;
    let truncated = loop {
        match read_frame(&mut cur) {
            Ok(None) => break false,
            Ok(Some(f)) => {
                frames.push(f);
                good = cur.position();
            }
            // a torn or corrupted tail means "the last chunk did not
            // complete", not "the store is lost": resume from the last
            // record that round-tripped its checksum
            Err(_) => break true,
        }
    };
    Ok((frames, truncated, good))
}

// ---------------------------------------------------------------------------
// the spilled sweep engine
// ---------------------------------------------------------------------------

/// Content fingerprint of one (model, grid) pair: model shape, linear
/// names, every config's per-layer resolved view, and the grid prep
/// rank. Two sweeps share a spill dir iff these all match.
pub fn sweep_fingerprint(
    model_cfg: &ModelCfg,
    names: &[String],
    configs: &[SweepConfig],
    prep_rank: usize,
) -> u128 {
    let mut w = WireWriter::new();
    put_model_cfg(&mut w, model_cfg);
    w.put_usize(names.len());
    for n in names {
        w.put_str(n);
    }
    w.put_usize(configs.len());
    for c in configs {
        // encode the resolved per-layer views so heterogeneous cells
        // fingerprint by what actually executes (and the wire codec
        // never sees `per_layer`)
        for li in 0..names.len() {
            put_sweep_config(&mut w, &c.resolved(li));
        }
    }
    w.put_usize(prep_rank);
    content_hash128(&w.into_bytes())
}

/// Content hash of an outcome's factored model + rank selections —
/// printed by `srr ptq --spill` so the process-level kill-and-resume
/// harness can compare runs bit-exactly across process boundaries.
pub fn outcome_content_hash(out: &FactoredOutcome) -> u128 {
    let mut w = WireWriter::new();
    for (name, op) in &out.model.ops {
        w.put_str(name);
        match op {
            LinearOp::Dense(m) => {
                w.put_u8(0);
                put_mat(&mut w, m);
            }
            LinearOp::FactoredQlr { base, l, r } => {
                match base {
                    QuantBase::Packed(p) => {
                        w.put_u8(1);
                        put_packed(&mut w, p);
                    }
                    QuantBase::Dense(m) => {
                        w.put_u8(2);
                        put_mat(&mut w, m);
                    }
                }
                put_mat(&mut w, l);
                put_mat(&mut w, r);
            }
        }
    }
    for m in &out.meta {
        w.put_usize(m.k_star);
        put_opt(&mut w, &m.selection, put_selection);
    }
    content_hash128(&w.into_bytes())
}

/// Run a sweep grid through `store` with a bounded in-memory working
/// set, resuming any chunks the store already holds. Bit-identical to
/// [`SweepRunner::run_factored`](super::sweep::SweepRunner) — outcomes,
/// `Arc` sharing topology, and fleet PPL all match the in-memory engine
/// (property-tested below and gated by `BENCH_spill.json`).
pub fn run_sweep_spilled(
    params: &Params,
    model_cfg: &ModelCfg,
    calib: &CalibrationSet,
    configs: &[SweepConfig],
    metrics: &Metrics,
    store: &SpillStore,
) -> Result<Vec<FactoredOutcome>> {
    let names = Params::linear_names(model_cfg);
    let n_layers = names.len();
    if configs.is_empty() || n_layers == 0 {
        return Ok(empty_outcomes(params, configs.len()));
    }
    let keys = sweep_keys(configs, n_layers);
    let prep_rank = keys.prep_rank;
    let fp = sweep_fingerprint(model_cfg, &names, configs, prep_rank);
    let resumed = store.begin(fp, n_layers, configs.len(), prep_rank)?;
    if resumed {
        metrics.incr("spill.resumed");
    }

    // ---- phase A: prepare, spill, drop — one layer per worker ------------
    let missing: Vec<usize> = (0..n_layers).filter(|li| !store.prep_done(*li)).collect();
    let t_prep = Instant::now();
    let spilled: Vec<Result<()>> = pool::par_map(missing.len(), |i| {
        let li = missing[i];
        let layer = prepare_layer(
            params,
            calib,
            &names[li],
            &keys.layers[li],
            &keys.kinds,
            keys.any_hessian,
            prep_rank,
            metrics,
        );
        store.spill_prep(li, &layer, &keys.layers[li], &keys.kinds)
    });
    for r in spilled {
        r?;
    }
    metrics.add("sweep.prep_secs", t_prep.elapsed().as_secs_f64());

    // ---- phase B1: shared plain-QER residual SVDs, from spilled blobs ----
    let t_resid = Instant::now();
    let resid_missing: Vec<(usize, usize)> = keys
        .resid_jobs()
        .into_iter()
        .filter(|(li, ri)| !store.resid_done(*li, *ri))
        .collect();
    let done: Vec<Result<()>> = pool::par_map(resid_missing.len(), |i| {
        let (li, ri) = resid_missing[i];
        let svd = resid_job_inputs(store, &keys, li, ri)?;
        store.spill_resid(li, ri, &svd)
    });
    for r in done {
        r?;
    }
    metrics.add("sweep.shared_resid_secs", t_resid.elapsed().as_secs_f64());

    // ---- phase B2: layer-major fan-out over a one-layer working set ------
    let t_rec = Instant::now();
    for li in 0..n_layers {
        let todo: Vec<usize> =
            (0..configs.len()).filter(|ci| !store.cell_done(*ci, li)).collect();
        if todo.is_empty() {
            continue;
        }
        let cache1 = store.load_layer_cache(li, &keys.layers[li])?;
        let done: Vec<Result<()>> = pool::par_map(todo.len(), |j| {
            let ci = todo[j];
            let c = configs[ci].resolved(li);
            let t0 = Instant::now();
            let arts = b2_artifacts(&cache1, 0, &c);
            let (res, report) = b2_job(&c, prep_rank, &arts);
            metrics.add("sweep.reconstruct_cpu_secs", t0.elapsed().as_secs_f64());
            spill_qer_result(store, ci, li, &res, &report)
        });
        for r in done {
            r?;
        }
        // cache1 drops here: the next layer starts from a clean slate
    }
    metrics.add("sweep.reconstruct_secs", t_rec.elapsed().as_secs_f64());

    // ---- assembly, entirely from the manifest ----------------------------
    let parts = store.assemble_parts(configs, &names)?;
    let outcomes = assemble_outcomes(params, &names, configs.len(), parts, metrics);
    metrics.add("sweep.configs", configs.len() as f64);
    metrics.add("sweep.layers", n_layers as f64);
    let stats = store.stats();
    metrics.put("spill.bytes_spilled", stats.bytes_spilled as f64);
    metrics.put("spill.bytes_reloaded", stats.bytes_reloaded as f64);
    metrics.put("spill.peak_resident_bytes", stats.peak_resident_bytes as f64);
    Ok(outcomes)
}

/// Spill one in-process [`QerResult`] as its cell's completion record.
pub(crate) fn spill_qer_result(
    store: &SpillStore,
    ci: usize,
    li: usize,
    res: &QerResult,
    report: &LayerReport,
) -> Result<()> {
    let base = match &res.packed {
        Some(p) => SpillBase::Packed(p.as_ref()),
        None => SpillBase::Dense(&res.qdeq),
    };
    store.spill_cell(
        ci,
        li,
        base,
        &res.l,
        &res.r,
        res.k_star,
        res.selection.as_ref(),
        report.weight_err,
        report.scaled_err,
        report.qer_secs,
    )
}

/// Compute one phase-B1 residual SVD from spilled phase-A blobs — the
/// same [`compute_resid_svd`] call, same salted stream, as the
/// in-memory engine; only the artifact source differs.
fn resid_job_inputs(
    store: &SpillStore,
    keys: &SweepKeys,
    li: usize,
    ri: usize,
) -> Result<Svd> {
    let lk = &keys.layers[li];
    let (label, kind, seed, _) = &lk.resid_keys[ri];
    let rec = store.prep_record(li)?;
    let qi = lk
        .qdeq0_keys
        .iter()
        .position(|(l, s, _)| l == label && s == seed)
        .ok_or_else(|| anyhow!("resid key without a matching qdeq0 key"))?;
    ensure!(qi < rec.qdeq0.len(), "spill PREP record qdeq0 list too short");
    let w = store.load_mat(rec.w)?;
    let qdeq = store.load_mat(rec.qdeq0[qi].0)?;
    let ws = rec
        .scalings
        .iter()
        .find(|(k, _)| k == kind)
        .map(|(_, ws)| ws)
        .ok_or_else(|| anyhow!("spill PREP record missing scaling for resid key"))?;
    let scaling = store.load_scaling(ws)?;
    let salt = layer_salt(&rec.name);
    Ok(compute_resid_svd(&w, &qdeq, &scaling, keys.prep_rank, *seed, salt))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Mat;
    use crate::util::Rng;

    /// Self-cleaning unique temp dir for spill tests.
    pub(crate) struct TempDir(pub PathBuf);

    impl TempDir {
        pub(crate) fn new(tag: &str) -> TempDir {
            static NEXT: AtomicU64 = AtomicU64::new(0);
            let dir = std::env::temp_dir().join(format!(
                "srr-spill-{tag}-{}-{}",
                std::process::id(),
                NEXT.fetch_add(1, Ordering::Relaxed)
            ));
            fs::create_dir_all(&dir).expect("create temp dir");
            TempDir(dir)
        }
    }

    impl Drop for TempDir {
        fn drop(&mut self) {
            let _ = fs::remove_dir_all(&self.0);
        }
    }

    fn tiny_svd(rng: &mut Rng) -> Svd {
        Svd {
            u: Mat::randn(6, 3, 1.0, rng),
            s: vec![3.0, 2.0, 1.0],
            v: Mat::randn(5, 3, 1.0, rng),
        }
    }

    fn store_records(dir: &Path) -> usize {
        SpillStore::open(dir, SpillOptions::default()).expect("reopen").stats().records
    }

    #[test]
    fn fresh_store_round_trips_records() {
        let tmp = TempDir::new("roundtrip");
        let mut rng = Rng::new(7);
        let svd = tiny_svd(&mut rng);
        {
            let store = SpillStore::open(&tmp.0, SpillOptions::default()).expect("open");
            assert!(!store.begin(42, 2, 3, 8).expect("begin"));
            store.spill_resid(1, 0, &svd).expect("spill resid");
            assert!(store.resid_done(1, 0));
            assert!(!store.resid_done(0, 0));
        }
        let store = SpillStore::open(&tmp.0, SpillOptions::default()).expect("reopen");
        assert!(store.begin(42, 2, 3, 8).expect("begin resumed"));
        assert!(store.resid_done(1, 0));
        let ws = store.manifest.lock().unwrap().resids.get(&(1, 0)).cloned().unwrap();
        let back = store.load_svd(&ws).expect("reload svd");
        assert_eq!(back.u, svd.u);
        assert_eq!(back.s, svd.s);
        assert_eq!(back.v, svd.v);
    }

    #[test]
    fn mismatched_fingerprint_is_an_error() {
        let tmp = TempDir::new("fpmismatch");
        {
            let store = SpillStore::open(&tmp.0, SpillOptions::default()).expect("open");
            store.begin(1, 2, 3, 8).expect("begin");
        }
        let store = SpillStore::open(&tmp.0, SpillOptions::default()).expect("reopen");
        let err = store.begin(2, 2, 3, 8).expect_err("different sweep must be rejected");
        assert!(err.to_string().contains("different sweep"), "unexpected error: {err:#}");
    }

    /// Satellite: the manifest loader treats a torn trailing record —
    /// truncated at *every possible byte* — as "chunk incomplete", never
    /// as a store-fatal error, and resumes with every earlier record.
    #[test]
    fn manifest_truncated_at_every_byte_of_last_record_resumes() {
        let tmp = TempDir::new("torn");
        let mut rng = Rng::new(11);
        let manifest = tmp.0.join("manifest.srrm");
        let full = {
            let store = SpillStore::open(&tmp.0, SpillOptions::default()).expect("open");
            store.begin(7, 4, 2, 8).expect("begin");
            for ri in 0..3 {
                store.spill_resid(0, ri, &tiny_svd(&mut rng)).expect("spill");
            }
            fs::read(&manifest).expect("read manifest")
        };
        // offset where the last record starts = end of the second-to-last
        let (frames, truncated, _) = scan_manifest(&manifest).expect("scan");
        assert_eq!(frames.len(), 4, "header + 3 records");
        assert!(!truncated);
        let last_start = {
            let mut cur = std::io::Cursor::new(&full[..]);
            let mut boundary = 0u64;
            for _ in 0..3 {
                read_frame(&mut cur).expect("frame").expect("present");
                boundary = cur.position();
            }
            boundary as usize
        };
        assert!(last_start < full.len());
        for cut in last_start..full.len() {
            fs::write(&manifest, &full[..cut]).expect("write truncated");
            let store = SpillStore::open(&tmp.0, SpillOptions::default())
                .unwrap_or_else(|e| panic!("open failed at cut {cut}: {e:#}"));
            assert!(store.begin(7, 4, 2, 8).expect("begin"), "resume at cut {cut}");
            assert!(store.resid_done(0, 0) && store.resid_done(0, 1), "cut {cut}");
            assert!(!store.resid_done(0, 2), "torn record must read as incomplete, cut {cut}");
            // the torn tail is gone: appends extend a clean manifest
            assert_eq!(
                fs::metadata(&manifest).expect("meta").len(),
                last_start as u64,
                "cut {cut}"
            );
        }
        // a wholly zero-length manifest is a fresh store, not an error
        fs::write(&manifest, b"").expect("truncate to zero");
        let store = SpillStore::open(&tmp.0, SpillOptions::default()).expect("open empty");
        assert!(!store.begin(7, 4, 2, 8).expect("fresh begin"));
        assert_eq!(store_records(&tmp.0), 1, "fresh header only");
    }

    #[test]
    fn blob_cache_eviction_preserves_arc_identity() {
        let tmp = TempDir::new("evict");
        // cap far below one blob: every load evicts the previous one
        let opts = SpillOptions { cap_bytes: 64, ..Default::default() };
        let store = SpillStore::open(&tmp.0, opts).expect("open");
        let mut rng = Rng::new(3);
        let a = Mat::randn(16, 16, 1.0, &mut rng);
        let b = Mat::randn(16, 16, 1.0, &mut rng);
        let ha = store.put_mat_blob(&a).expect("spill a");
        let hb = store.put_mat_blob(&b).expect("spill b");
        let first = store.load_mat(ha).expect("load a");
        let _other = store.load_mat(hb).expect("load b evicts a");
        // `first` is still alive, so reloading must alias it — eviction
        // may drop the strong ref but can never split the identity
        let again = store.load_mat(ha).expect("reload a");
        assert!(Arc::ptr_eq(&first, &again), "eviction split a live Arc");
        assert_eq!(*again, a, "content must round-trip bit-exactly");
        let stats = store.stats();
        assert!(stats.peak_resident_bytes >= (16 * 16 * 4) as u64);
        assert!(stats.bytes_reloaded > 0);
    }

    #[test]
    fn abort_hook_fails_append_after_durable_write() {
        let tmp = TempDir::new("abort");
        let mut rng = Rng::new(5);
        let svd = tiny_svd(&mut rng);
        {
            let opts = SpillOptions { abort_after_records: Some(2), ..Default::default() };
            let store = SpillStore::open(&tmp.0, opts).expect("open");
            store.begin(9, 1, 1, 4).expect("begin (record 1)");
            let err = store.spill_resid(0, 0, &svd).expect_err("record 2 aborts");
            assert!(err.to_string().contains("simulated crash"), "{err:#}");
        }
        // the aborted append was durable: resume sees the record
        let store = SpillStore::open(&tmp.0, SpillOptions::default()).expect("reopen");
        assert!(store.begin(9, 1, 1, 4).expect("resume"));
        assert!(store.resid_done(0, 0), "abort happens after the fsynced append");
    }

    #[test]
    fn torn_hook_leaves_a_resumable_half_record() {
        let tmp = TempDir::new("tornhook");
        let mut rng = Rng::new(6);
        let svd = tiny_svd(&mut rng);
        {
            let opts = SpillOptions { torn_after_records: Some(2), ..Default::default() };
            let store = SpillStore::open(&tmp.0, opts).expect("open");
            store.begin(9, 1, 1, 4).expect("begin");
            let err = store.spill_resid(0, 0, &svd).expect_err("torn write");
            assert!(err.to_string().contains("torn"), "{err:#}");
        }
        let store = SpillStore::open(&tmp.0, SpillOptions::default()).expect("reopen");
        assert!(store.begin(9, 1, 1, 4).expect("resume"));
        assert!(!store.resid_done(0, 0), "half-written record reads as incomplete");
    }
}
