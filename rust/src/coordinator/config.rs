//! Run configuration: CLI args + optional JSON config file, merged.
//!
//! Precedence: CLI > JSON file > defaults. The same structure drives the
//! `srr` binary's subcommands and the examples.

use anyhow::{anyhow, Result};

use crate::qer::Method;
use crate::scaling::ScalingKind;
use crate::util::cli::Args;
use crate::util::json::Json;

use super::pipeline::QuantizerSpec;

/// One PTQ run's configuration, merged from CLI args and an optional
/// JSON file.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// manifest model name (`tiny` / `small` / `base`)
    pub model: String,
    /// reconstruction method (see [`parse_method`] for the CLI names)
    pub method: Method,
    /// rank budget r for the L·R correction
    pub rank: usize,
    /// activation scaling kind (see [`parse_scaling`])
    pub scaling: ScalingKind,
    /// quantizer spec (see [`parse_quantizer`])
    pub quantizer: QuantizerSpec,
    /// base RNG seed (layer-salted per linear)
    pub seed: u64,
    /// calibration rows collected per linear
    pub calib_rows: usize,
    /// output directory for reports
    pub out_dir: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "small".into(),
            method: Method::QerSrr,
            rank: 32,
            scaling: ScalingKind::Exact,
            quantizer: QuantizerSpec::Mxint { bits: 3, block: 32 },
            seed: 0,
            calib_rows: 256,
            out_dir: "results".into(),
        }
    }
}

/// Parse a CLI method name (`w-only`, `qer`, `srr`, `loftq`, …).
pub fn parse_method(s: &str) -> Result<Method> {
    Ok(match s {
        "w-only" | "wonly" => Method::WOnly,
        "qer" => Method::Qer,
        "srr" | "qer+srr" => Method::QerSrr,
        "srr-eq6" => Method::SrrSingleSvd,
        "loftq" | "iterative" => Method::IterativeLowRank { iters: 5 },
        "preserve-only" | "svdquant" => Method::PreserveOnly,
        "odlri" | "fixed-half" => Method::FixedSplitHalf,
        other => return Err(anyhow!("unknown method '{other}'")),
    })
}

/// Parse a CLI scaling name (`identity`, `rms`, `absmean`, `exact`, …).
pub fn parse_scaling(s: &str) -> Result<ScalingKind> {
    Ok(match s {
        "identity" | "zeroquant" => ScalingKind::Identity,
        "rms" | "lqer" => ScalingKind::DiagRms,
        "absmean" | "qera-approx" => ScalingKind::DiagAbsMean,
        "exact" | "qera-exact" | "qera" => ScalingKind::Exact,
        other => return Err(anyhow!("unknown scaling '{other}'")),
    })
}

/// Parse a CLI quantizer spec (`mxint3`, `mxint4:16`, `uniform4g64`,
/// `gptq3`, `quip2`).
pub fn parse_quantizer(s: &str) -> Result<QuantizerSpec> {
    // forms: mxint3, mxint4:16, uniform4g64, gptq3, quip2
    if let Some(rest) = s.strip_prefix("mxint") {
        let (bits, block) = match rest.split_once(':') {
            Some((b, blk)) => (b.parse()?, blk.parse()?),
            None => (rest.parse()?, 32),
        };
        return Ok(QuantizerSpec::Mxint { bits, block });
    }
    if let Some(rest) = s.strip_prefix("gptq") {
        return Ok(QuantizerSpec::Gptq { bits: rest.parse()?, group: 128 });
    }
    if let Some(rest) = s.strip_prefix("quip") {
        return Ok(QuantizerSpec::QuipSharp { bits: rest.parse()? });
    }
    if let Some(rest) = s.strip_prefix("uniform") {
        let (bits, group) = rest.split_once('g').ok_or_else(|| anyhow!("uniform<bits>g<group>"))?;
        return Ok(QuantizerSpec::Uniform {
            bits: bits.parse()?,
            group: group.parse()?,
            symmetric: true,
        });
    }
    Err(anyhow!("unknown quantizer '{s}'"))
}

impl RunConfig {
    /// Merge: defaults ← JSON file (`--config path`) ← CLI options.
    pub fn from_args(args: &Args) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        if let Some(path) = args.get("config") {
            let text = std::fs::read_to_string(path)?;
            let j = Json::parse(&text).map_err(|e| anyhow!("config parse: {e}"))?;
            if let Some(v) = j.get("model").and_then(|x| x.as_str()) {
                cfg.model = v.to_string();
            }
            if let Some(v) = j.get("method").and_then(|x| x.as_str()) {
                cfg.method = parse_method(v)?;
            }
            if let Some(v) = j.get("scaling").and_then(|x| x.as_str()) {
                cfg.scaling = parse_scaling(v)?;
            }
            if let Some(v) = j.get("quantizer").and_then(|x| x.as_str()) {
                cfg.quantizer = parse_quantizer(v)?;
            }
            if let Some(v) = j.get("rank").and_then(|x| x.as_usize()) {
                cfg.rank = v;
            }
            if let Some(v) = j.get("seed").and_then(|x| x.as_f64()) {
                cfg.seed = v as u64;
            }
            if let Some(v) = j.get("calib_rows").and_then(|x| x.as_usize()) {
                cfg.calib_rows = v;
            }
        }
        if let Some(v) = args.get("model") {
            cfg.model = v.to_string();
        }
        if let Some(v) = args.get("method") {
            cfg.method = parse_method(v)?;
        }
        if let Some(v) = args.get("scaling") {
            cfg.scaling = parse_scaling(v)?;
        }
        if let Some(v) = args.get("quantizer") {
            cfg.quantizer = parse_quantizer(v)?;
        }
        cfg.rank = args.get_usize("rank", cfg.rank);
        cfg.seed = args.get_u64("seed", cfg.seed);
        cfg.calib_rows = args.get_usize("calib-rows", cfg.calib_rows);
        if let Some(v) = args.get("out") {
            cfg.out_dir = v.to_string();
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn parses_all_method_aliases() {
        assert_eq!(parse_method("srr").unwrap(), Method::QerSrr);
        assert_eq!(parse_method("qer").unwrap(), Method::Qer);
        assert_eq!(parse_method("w-only").unwrap(), Method::WOnly);
        assert!(matches!(parse_method("loftq").unwrap(), Method::IterativeLowRank { iters: 5 }));
        assert!(parse_method("bogus").is_err());
    }

    #[test]
    fn parses_quantizer_grammar() {
        assert!(matches!(
            parse_quantizer("mxint3").unwrap(),
            QuantizerSpec::Mxint { bits: 3, block: 32 }
        ));
        assert!(matches!(
            parse_quantizer("mxint4:16").unwrap(),
            QuantizerSpec::Mxint { bits: 4, block: 16 }
        ));
        assert!(matches!(parse_quantizer("gptq3").unwrap(), QuantizerSpec::Gptq { bits: 3, .. }));
        assert!(matches!(parse_quantizer("quip2").unwrap(), QuantizerSpec::QuipSharp { bits: 2 }));
        assert!(matches!(
            parse_quantizer("uniform4g64").unwrap(),
            QuantizerSpec::Uniform { bits: 4, group: 64, .. }
        ));
        assert!(parse_quantizer("float8").is_err());
    }

    #[test]
    fn cli_overrides_defaults() {
        let cfg = RunConfig::from_args(&args(
            "ptq --model tiny --method qer --rank 64 --scaling lqer --quantizer mxint2 --seed 9",
        ))
        .unwrap();
        assert_eq!(cfg.model, "tiny");
        assert_eq!(cfg.method, Method::Qer);
        assert_eq!(cfg.rank, 64);
        assert_eq!(cfg.scaling, ScalingKind::DiagRms);
        assert_eq!(cfg.seed, 9);
    }

    #[test]
    fn json_file_then_cli_precedence() {
        let dir = std::env::temp_dir().join("srr_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        std::fs::write(&path, r#"{"model": "base", "rank": 16, "method": "qer"}"#).unwrap();
        let cfg = RunConfig::from_args(&args(&format!(
            "ptq --config {} --rank 64",
            path.display()
        )))
        .unwrap();
        assert_eq!(cfg.model, "base"); // from file
        assert_eq!(cfg.rank, 64); // CLI wins
        assert_eq!(cfg.method, Method::Qer); // from file
    }
}
