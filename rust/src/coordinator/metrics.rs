//! Counters and stage timers (Table 11's scale/QER/SRR accounting).

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// A thread-safe registry of named f64 counters/timers.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, f64>>,
}

impl Metrics {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Accumulate `v` into `key`.
    pub fn add(&self, key: &str, v: f64) {
        *self.counters.lock().unwrap().entry(key.to_string()).or_insert(0.0) += v;
    }

    /// Increment `key` by one.
    pub fn incr(&self, key: &str) {
        self.add(key, 1.0);
    }

    /// Overwrite `key` with `v` (gauges like `shard.workers`, where
    /// accumulation across runs would be meaningless).
    pub fn put(&self, key: &str, v: f64) {
        self.counters.lock().unwrap().insert(key.to_string(), v);
    }

    /// Current value of `key` (0.0 if never written).
    pub fn get(&self, key: &str) -> f64 {
        self.counters.lock().unwrap().get(key).copied().unwrap_or(0.0)
    }

    /// Time a closure into `key` (seconds, accumulated).
    pub fn time<T>(&self, key: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(key, t0.elapsed().as_secs_f64());
        out
    }

    /// A point-in-time copy of every counter.
    pub fn snapshot(&self) -> BTreeMap<String, f64> {
        self.counters.lock().unwrap().clone()
    }

    /// Human-readable key/value report, sorted by key.
    pub fn report(&self) -> String {
        let snap = self.snapshot();
        let mut out = String::new();
        for (k, v) in snap {
            out.push_str(&format!("{k:<32} {v:.6}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("jobs");
        m.incr("jobs");
        m.add("bytes", 10.0);
        assert_eq!(m.get("jobs"), 2.0);
        assert_eq!(m.get("bytes"), 10.0);
        assert_eq!(m.get("missing"), 0.0);
    }

    #[test]
    fn timers_accumulate_positive() {
        let m = Metrics::new();
        let v = m.time("work", || {
            std::thread::sleep(std::time::Duration::from_millis(2));
            42
        });
        assert_eq!(v, 42);
        assert!(m.get("work") > 0.0);
        m.time("work", || ());
        assert!(m.snapshot().contains_key("work"));
    }

    #[test]
    fn concurrent_updates_are_safe() {
        let m = Metrics::new();
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..100 {
                        m.incr("n");
                    }
                });
            }
        });
        assert_eq!(m.get("n"), 800.0);
    }
}
