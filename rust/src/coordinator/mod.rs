//! The Layer-3 coordinator: orchestrates the PTQ pipeline over a model's
//! layers and owns run configuration and metrics.
//!
//! The paper's contribution is algorithmic (L1/L2-adjacent), so per the
//! architecture the coordinator is the *pipeline driver*: it streams
//! calibration activations, schedules per-layer reconstruction jobs
//! (scale → select-k → preserve → quantize → reconstruct → pack) across a
//! worker pool, tracks per-stage timings (Table 11's overhead accounting)
//! and emits the factored serving model (`serve::FactoredModel` — packed
//! codes + adapters); densified copies for the PJRT eval engines are
//! derived on demand via `FactoredOutcome::to_dense`.
//!
//! * [`pipeline`] — the single-config PTQ orchestrator
//!   (`run_ptq_factored`, with `run_ptq` as the dense compatibility
//!   wrapper).
//! * [`sweep`] — the shared-work grid engine (`SweepRunner`): one pass
//!   over the model executes a whole `(method, quantizer, rank, scaling,
//!   seed)` grid, preparing scalings / Hessians / spectra once per layer
//!   into a [`cache::LayerCache`] and fanning per-config reconstruction
//!   out over the worker pool — bit-identical to per-config `run_ptq`.
//!   Outcomes share packed bases through `Arc`, which is what the fleet
//!   evaluator ([`crate::eval::fleet`]) groups on to score a whole grid
//!   in lock-step. This is the seam sharding / multi-model serving will
//!   plug into.
//! * [`cache`] — the keyed per-layer cache ([`cache::PreparedLayer`]).
//! * [`jobs`] — bounded work queue with backpressure (used by the
//!   streaming calibration path and the shard plane's reader/writer
//!   threads; invariants property-tested).
//! * [`wire`] — the dependency-free binary wire codec (versioned,
//!   length-prefixed, checksummed frames; content-addressed blob dedup)
//!   the shard plane speaks.
//! * [`transport`] — how a shard host reaches a worker's byte stream:
//!   the [`transport::Transport`] trait with child-pipe, TCP
//!   (handshaken, local or remote), and fault-injection
//!   implementations.
//! * [`shard`] — the multi-process execution plane: phase-A prep jobs
//!   (per-layer Hessians/spectra/quantizations), phase-B2 sweep jobs,
//!   and fleet PPL jobs sharded across `srr shard-worker` processes
//!   (pipes or TCP), bit-identical to the in-process engines. The fleet
//!   is elastic and stall-proof: workers heartbeat per in-flight job, a
//!   silent (wedged) worker is requeued like a death, and new workers
//!   may dial in and be admitted mid-run.
//! * [`spill`] — the disk-backed artifact store for out-of-core sweeps
//!   (`srr ptq --spill DIR`): phase-A artifacts, shared residual SVDs
//!   and completed grid cells stream through a bounded in-memory
//!   working set, the manifest doubles as a crash-resumable chunk
//!   completion log (fsynced, torn-tail tolerant), and reassembly
//!   reproduces the in-memory `Arc` topology so grid dedup and
//!   lock-step fleet groups survive the disk round-trip bit-identically.
//! * [`budget`] — the model-wide rank/bit budget allocator ("best PPL
//!   at N gigabytes"): greedy marginal-utility descent plus Lagrangian
//!   water-filling over phase-A sensitivity profiles, emitting a
//!   [`budget::BudgetPlan`] that [`sweep`] executes as one
//!   heterogeneous per-layer cell; plans are bit-identical whether the
//!   probe prep ran in-process or sharded.
//! * [`metrics`] — counters/timers registry.
//! * [`config`] — run configuration (CLI/JSON).

pub mod budget;
pub mod cache;
pub mod config;
pub mod jobs;
pub mod metrics;
pub mod pipeline;
pub mod shard;
pub mod spill;
pub mod sweep;
pub mod transport;
pub mod wire;

pub use budget::{allocate, uniform_plan, BudgetPlan, BudgetSpec, LayerAlloc, LayerProfile};
pub use cache::{LayerCache, PreparedLayer};
pub use config::RunConfig;
pub use metrics::Metrics;
pub use pipeline::{
    run_ptq, run_ptq_factored, FactoredOutcome, LayerMeta, LayerReport, PtqOutcome,
    QuantizerSpec,
};
pub use shard::{
    fleet_perplexity_sharded, worker_main, ShardOptions, ShardSession, ShardedSweepRunner,
};
pub use spill::{
    outcome_content_hash, run_sweep_spilled, sweep_fingerprint, SpillOptions, SpillStats,
    SpillStore,
};
pub use sweep::{run_sweep, run_sweep_factored, LayerAssign, SweepConfig, SweepRunner};
pub use transport::{
    ChildPipeTransport, FaultPlan, FaultTransport, ShardHost, TcpTransport, Transport,
};
