//! The Layer-3 coordinator: orchestrates the PTQ pipeline over a model's
//! layers and owns run configuration and metrics.
//!
//! The paper's contribution is algorithmic (L1/L2-adjacent), so per the
//! architecture the coordinator is the *pipeline driver*: it streams
//! calibration activations, schedules per-layer reconstruction jobs
//! (scale → select-k → preserve → quantize → reconstruct → pack) across a
//! worker pool, tracks per-stage timings (Table 11's overhead accounting)
//! and materializes the reconstructed model for the PJRT eval engines.
//!
//! * [`pipeline`] — the PTQ orchestrator.
//! * [`jobs`] — bounded work queue with backpressure (used by the
//!   streaming calibration path; invariants property-tested).
//! * [`metrics`] — counters/timers registry.
//! * [`config`] — run configuration (CLI/JSON).

pub mod pipeline;
pub mod jobs;
pub mod metrics;
pub mod config;

pub use config::RunConfig;
pub use metrics::Metrics;
pub use pipeline::{run_ptq, LayerReport, PtqOutcome, QuantizerSpec};
