//! Transports for the shard plane: how a [`ShardSession`] host reaches
//! a worker's framed byte stream.
//!
//! The wire codec ([`super::wire`]) and the dispatcher
//! ([`super::shard`]) are transport-agnostic: everything they need from
//! a connection is a duplex byte stream with *liveness close semantics*
//! (EOF/`BrokenPipe` when the peer goes away) plus an out-of-band death
//! probe for peers that can hang without closing. This module is that
//! seam, the [`Transport`] trait, with three implementations:
//!
//! * [`ChildPipeTransport`] — a spawned `srr shard-worker` child over
//!   stdin/stdout pipes (the original, single-host production path);
//! * [`TcpTransport`] — a worker on the other end of a TCP connection,
//!   opened by either side ([`ShardHost`] accepts dial-ins from
//!   `srr shard-worker --connect host:port`; [`TcpTransport::dial`]
//!   reaches a worker started with `--listen`). Connections open with a
//!   [`kind::HELLO`](super::wire::kind::HELLO) exchange carried in a
//!   regular wire frame, so the codec's magic/version/checksum checks
//!   *are* the handshake — a peer speaking another [`WIRE_VERSION`]
//!   (or not speaking the protocol at all) is refused before any job
//!   bytes flow. **No authentication beyond that**: run it on a trusted
//!   LAN or through an ssh tunnel (see the README's remote-worker
//!   workflow).
//! * [`FaultTransport`] — a deterministic fault-injection double for
//!   tests: a seeded [`FaultPlan`] chops writes into short chunks,
//!   delays flushes, severs either direction mid-frame, flips bits on
//!   the receive path, and *wedges* the worker→host direction (silent
//!   stall without EOF — the failure only per-job heartbeat expiry can
//!   see), so the dispatcher's death/wedge/requeue handling is
//!   exercised without real processes or sockets.
//!
//! [`ShardSession`]: super::shard::ShardSession
//! [`WIRE_VERSION`]: super::wire::WIRE_VERSION

use std::io::{BufWriter, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::process::Child;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::wire::{self, decode_hello, encode_hello, kind, WireError};

/// How long each side of a TCP handshake waits for the peer's HELLO.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(5);

/// Payload cap for the HELLO frame (a real hello is 9 bytes): an
/// unauthenticated peer must not be able to make the handshake
/// allocate an attacker-chosen buffer.
const HELLO_MAX_LEN: u64 = 64;

/// A duplex framed byte stream to one shard worker.
///
/// Contract with the dispatcher ([`super::shard::ShardSession`]):
///
/// * [`take_reader`](Transport::take_reader) yields the owned read half
///   exactly once (it moves into the session's reader thread); the read
///   half must return `Ok(0)` — EOF — once the peer is gone, which is
///   the in-band death signal.
/// * [`writer`](Transport::writer) is the framed write half; a write or
///   flush error means the peer is unreachable and the caller marks the
///   worker dead. [`close_writer`](Transport::close_writer) delivers
///   EOF to the peer (a worker drains and exits on it).
/// * [`poll_dead`](Transport::poll_dead) is the out-of-band probe the
///   event loop calls on `pop_timeout` expiry, for peers that can die
///   *without* closing the stream (a wedged child); transports without
///   such a side channel return `false` and rely on reader EOF.
pub trait Transport: Send {
    /// Take the owned read half for the session's reader thread.
    /// Returns `None` after the first call.
    fn take_reader(&mut self) -> Option<Box<dyn Read + Send>>;

    /// The write half, or `None` once closed/dead.
    fn writer(&mut self) -> Option<&mut dyn Write>;

    /// Close the write half so the peer sees EOF (idempotent).
    fn close_writer(&mut self);

    /// Out-of-band liveness probe: `true` once the peer is known dead.
    fn poll_dead(&mut self) -> bool;

    /// Graceful reap: block until a peer this transport owns (a spawned
    /// child process) has exited. No-op for unowned peers.
    fn wait(&mut self);

    /// Forceful teardown: kill an owned peer / sever the connection.
    fn kill(&mut self);

    /// Human-readable endpoint description for error messages.
    fn describe(&self) -> String;
}

// ---------------------------------------------------------------------------
// child-process pipes
// ---------------------------------------------------------------------------

/// A spawned worker child reached over its stdin/stdout pipes.
pub struct ChildPipeTransport {
    child: Child,
    stdin: Option<BufWriter<std::process::ChildStdin>>,
    stdout: Option<std::process::ChildStdout>,
}

impl ChildPipeTransport {
    /// Adopt a freshly spawned child whose stdin/stdout were configured
    /// as pipes (panics if they were not).
    pub fn new(mut child: Child) -> Self {
        let stdin = child.stdin.take().expect("piped stdin");
        let stdout = child.stdout.take().expect("piped stdout");
        ChildPipeTransport { child, stdin: Some(BufWriter::new(stdin)), stdout: Some(stdout) }
    }
}

impl Transport for ChildPipeTransport {
    fn take_reader(&mut self) -> Option<Box<dyn Read + Send>> {
        self.stdout.take().map(|s| Box::new(s) as Box<dyn Read + Send>)
    }

    fn writer(&mut self) -> Option<&mut dyn Write> {
        self.stdin.as_mut().map(|w| w as &mut dyn Write)
    }

    fn close_writer(&mut self) {
        self.stdin = None; // drop → pipe EOF
    }

    fn poll_dead(&mut self) -> bool {
        matches!(self.child.try_wait(), Ok(Some(_)))
    }

    fn wait(&mut self) {
        let _ = self.child.wait();
    }

    fn kill(&mut self) {
        self.stdin = None;
        if matches!(self.child.try_wait(), Ok(None)) {
            let _ = self.child.kill();
        }
        let _ = self.child.wait();
    }

    fn describe(&self) -> String {
        format!("child pid {}", self.child.id())
    }
}

impl Drop for ChildPipeTransport {
    fn drop(&mut self) {
        self.kill();
    }
}

// ---------------------------------------------------------------------------
// TCP
// ---------------------------------------------------------------------------

/// A worker reached over a handshaken TCP connection. When the host
/// spawned the worker process itself (loopback benches/tests), the
/// transport also owns the [`Child`] so the liveness probe can notice
/// an exit that never sent FIN.
pub struct TcpTransport {
    /// buffered write half (the read half is a `try_clone` of the same
    /// socket, handed to the session's reader thread)
    writer: Option<BufWriter<TcpStream>>,
    reader: Option<TcpStream>,
    /// a third clone of the socket kept for shutdown: after the session
    /// takes the reader and teardown drops the writer, this is the only
    /// handle left that can sever the connection and unblock a reader
    /// thread parked on a wedged remote peer
    ctrl: TcpStream,
    peer: String,
    /// the token the worker presented in its HELLO (0 = anonymous)
    token: u64,
    child: Option<Child>,
}

impl TcpTransport {
    /// Wrap an already-handshaken stream. `token` is the peer's HELLO
    /// token; `child` attaches a host-spawned worker process.
    fn from_stream(stream: TcpStream, token: u64, child: Option<Child>) -> Result<Self> {
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "<unknown peer>".into());
        let _ = stream.set_nodelay(true);
        let reader = stream.try_clone().context("cloning TCP read half")?;
        let ctrl = stream.try_clone().context("cloning TCP shutdown handle")?;
        Ok(TcpTransport {
            writer: Some(BufWriter::new(stream)),
            reader: Some(reader),
            ctrl,
            peer,
            token,
            child,
        })
    }

    /// Dial a worker that is listening (`srr shard-worker --listen
    /// host:port`), performing the HELLO handshake as the host side.
    pub fn dial(addr: &str) -> Result<Self> {
        let sock = resolve(addr)?;
        let mut stream = TcpStream::connect_timeout(&sock, Duration::from_secs(10))
            .with_context(|| format!("dialing shard worker at {addr}"))?;
        let token = handshake_tcp(&mut stream, false, 0)
            .map_err(|e| anyhow::anyhow!("handshake with {addr} failed: {e}"))?;
        Self::from_stream(stream, token, None)
    }

    /// The token the peer presented in its HELLO.
    pub fn token(&self) -> u64 {
        self.token
    }

    /// Attach a host-spawned child process for liveness probing.
    pub fn attach_child(&mut self, child: Child) {
        self.child = Some(child);
    }

    fn shutdown_both(&mut self) {
        let _ = self.ctrl.shutdown(Shutdown::Both);
    }
}

impl Transport for TcpTransport {
    fn take_reader(&mut self) -> Option<Box<dyn Read + Send>> {
        self.reader.take().map(|s| Box::new(s) as Box<dyn Read + Send>)
    }

    fn writer(&mut self) -> Option<&mut dyn Write> {
        self.writer.as_mut().map(|w| w as &mut dyn Write)
    }

    fn close_writer(&mut self) {
        if let Some(mut w) = self.writer.take() {
            let _ = w.flush();
            let _ = self.ctrl.shutdown(Shutdown::Write); // FIN
        }
    }

    fn poll_dead(&mut self) -> bool {
        match &mut self.child {
            Some(c) => matches!(c.try_wait(), Ok(Some(_))),
            None => false, // rely on reader EOF (FIN) for remote peers
        }
    }

    fn wait(&mut self) {
        if let Some(c) = &mut self.child {
            let _ = c.wait();
        }
        // unblock a reader thread still parked on the socket
        self.shutdown_both();
    }

    fn kill(&mut self) {
        self.shutdown_both();
        self.writer = None;
        if let Some(c) = &mut self.child {
            if matches!(c.try_wait(), Ok(None)) {
                let _ = c.kill();
            }
            let _ = c.wait();
        }
    }

    fn describe(&self) -> String {
        match self.token {
            0 => format!("tcp {}", self.peer),
            t => format!("tcp {} (token {t})", self.peer),
        }
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.kill();
    }
}

fn resolve(addr: &str) -> Result<SocketAddr> {
    addr.to_socket_addrs()
        .with_context(|| format!("resolving {addr}"))?
        .next()
        .ok_or_else(|| anyhow::anyhow!("{addr} resolved to no address"))
}

/// Exchange HELLO frames over any duplex stream. Both sides send first,
/// then read — no ordering deadlock. Refuses a peer claiming the local
/// role, so success implies the peer holds the opposite role; returns
/// the peer's token.
pub(crate) fn handshake_io<S: Read + Write>(
    s: &mut S,
    local_is_worker: bool,
    token: u64,
) -> Result<u64, WireError> {
    encode_hello(local_is_worker, token)
        .write_to(s)
        .map_err(|e| WireError::Io(e.kind()))?;
    s.flush().map_err(|e| WireError::Io(e.kind()))?;
    let frame =
        wire::read_frame_limited(s, HELLO_MAX_LEN)?.ok_or(WireError::Truncated)?;
    if frame.kind != kind::HELLO {
        return Err(WireError::Malformed("expected hello frame"));
    }
    let (peer_is_worker, peer_token) = decode_hello(&frame.payload)?;
    if peer_is_worker == local_is_worker {
        return Err(WireError::Malformed("peer claims the same role"));
    }
    Ok(peer_token)
}

/// [`handshake_io`] over TCP, with a read/write deadline so a silent
/// peer cannot wedge the accept loop. Timeouts are cleared afterwards
/// (a read timeout would surface as spurious I/O errors on the
/// session's reader thread).
pub(crate) fn handshake_tcp(
    stream: &mut TcpStream,
    local_is_worker: bool,
    token: u64,
) -> Result<u64, WireError> {
    let _ = stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT));
    let _ = stream.set_write_timeout(Some(HANDSHAKE_TIMEOUT));
    let out = handshake_io(stream, local_is_worker, token);
    let _ = stream.set_read_timeout(None);
    let _ = stream.set_write_timeout(None);
    out
}

/// A bound listener collecting handshaken worker dial-ins. Two-phase
/// (bind, then [`accept_workers`](ShardHost::accept_workers)) so
/// callers can learn the ephemeral port before starting workers that
/// dial it.
pub struct ShardHost {
    listener: TcpListener,
}

impl ShardHost {
    /// Bind `addr` (e.g. `0.0.0.0:7777`, or `127.0.0.1:0` for an
    /// ephemeral loopback port).
    pub fn bind(addr: &str) -> Result<ShardHost> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding shard host on {addr}"))?;
        listener.set_nonblocking(true).context("nonblocking listener")?;
        Ok(ShardHost { listener })
    }

    /// The bound address (the port workers must `--connect` to).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept dial-ins until `n` workers pass the HELLO handshake or
    /// `deadline` elapses. Connections that fail the handshake — wrong
    /// wire version, wrong role, not the protocol at all — are logged
    /// to stderr and dropped; they do not count and do not abort the
    /// accept loop. Handshakes run on their own threads, so a silent
    /// connection (a port scanner, a health check) burning its
    /// [`HANDSHAKE_TIMEOUT`] cannot stall the admission of legitimate
    /// workers dialing in behind it.
    pub fn accept_workers(&self, n: usize, deadline: Duration) -> Result<Vec<TcpTransport>> {
        let t_end = Instant::now() + deadline;
        let mut out = Vec::with_capacity(n);
        let (done_tx, done_rx) = std::sync::mpsc::channel::<Option<TcpTransport>>();
        let mut in_flight = 0usize;
        while out.len() < n {
            // collect finished handshakes without blocking
            while let Ok(res) = done_rx.try_recv() {
                in_flight -= 1;
                if let Some(t) = res {
                    out.push(t);
                }
            }
            if out.len() >= n {
                break;
            }
            // deadline is enforced every iteration — a steady stream of
            // refused connections must not extend the accept window
            if Instant::now() >= t_end {
                // give in-flight handshakes their bounded window before
                // declaring the accept window closed
                while in_flight > 0 && out.len() < n {
                    match done_rx.recv_timeout(HANDSHAKE_TIMEOUT) {
                        Ok(res) => {
                            in_flight -= 1;
                            if let Some(t) = res {
                                out.push(t);
                            }
                        }
                        Err(_) => break,
                    }
                }
                if out.len() >= n {
                    break;
                }
                anyhow::bail!(
                    "shard host: only {}/{n} workers connected within {:?}",
                    out.len(),
                    deadline
                );
            }
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    in_flight += 1;
                    spawn_handshake(stream, peer, done_tx.clone());
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(25));
                }
                Err(e) => return Err(e).context("accepting shard worker"),
            }
        }
        Ok(out)
    }

    /// Persistent accept loop for **mid-run joins**: every dial-in that
    /// passes the worker handshake is handed to `admit`, until `stop`
    /// goes true. Handshakes run on their own threads (like
    /// [`accept_workers`](ShardHost::accept_workers)), so a silent
    /// connection cannot stall later joiners. Runs on a dedicated
    /// thread owned by the shard session while `run_jobs` executes.
    pub fn accept_loop(
        &self,
        stop: &std::sync::atomic::AtomicBool,
        admit: impl Fn(TcpTransport),
    ) {
        let (done_tx, done_rx) = std::sync::mpsc::channel::<Option<TcpTransport>>();
        while !stop.load(std::sync::atomic::Ordering::Acquire) {
            while let Ok(res) = done_rx.try_recv() {
                if let Some(t) = res {
                    admit(t);
                }
            }
            match self.listener.accept() {
                Ok((stream, peer)) => spawn_handshake(stream, peer, done_tx.clone()),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(25));
                }
                Err(e) => {
                    eprintln!("shard host: accept loop stopping: {e}");
                    return;
                }
            }
        }
    }
}

/// Handshake one accepted dial-in on its own thread, reporting the
/// admitted transport (or `None` for a refused/broken peer) on `done`.
fn spawn_handshake(
    mut stream: TcpStream,
    peer: SocketAddr,
    done: std::sync::mpsc::Sender<Option<TcpTransport>>,
) {
    let _ = stream.set_nonblocking(false);
    std::thread::spawn(move || {
        let res = match handshake_tcp(&mut stream, false, 0) {
            Ok(token) => match TcpTransport::from_stream(stream, token, None) {
                Ok(t) => Some(t),
                Err(e) => {
                    eprintln!("shard host: dropping {peer}: {e:#}");
                    None
                }
            },
            Err(e) => {
                eprintln!("shard host: refusing {peer}: {e}");
                None
            }
        };
        let _ = done.send(res);
    });
}

/// Worker-side TCP entry: dial `addr` and handshake as a worker,
/// presenting `token`. Returns the connected stream ready for the
/// worker loop (the caller clones it for the read half).
pub fn worker_connect(addr: &str, token: u64) -> Result<TcpStream> {
    let sock = resolve(addr)?;
    let mut stream = TcpStream::connect_timeout(&sock, Duration::from_secs(10))
        .with_context(|| format!("connecting to shard host at {addr}"))?;
    let _ = stream.set_nodelay(true);
    handshake_tcp(&mut stream, true, token)
        .map_err(|e| anyhow::anyhow!("handshake with host {addr} failed: {e}"))?;
    Ok(stream)
}

/// Worker-side listen entry: bind `addr` and accept connections until
/// one passes the host handshake. Stray connections — port scanners,
/// health checks, cross-version peers — are logged and dropped instead
/// of killing the worker before the real host dials in, and each
/// handshake runs on its own thread (mirroring
/// [`ShardHost::accept_workers`]) so a slow or silent stray cannot
/// block the real host's dial-in past its handshake timeout. Used by
/// `srr shard-worker --listen`.
pub fn worker_accept(addr: &str) -> Result<TcpStream> {
    let listener =
        TcpListener::bind(addr).with_context(|| format!("worker listening on {addr}"))?;
    listener.set_nonblocking(true).context("nonblocking worker listener")?;
    let (done_tx, done_rx) = std::sync::mpsc::channel::<TcpStream>();
    loop {
        if let Ok(stream) = done_rx.try_recv() {
            return Ok(stream);
        }
        match listener.accept() {
            Ok((mut stream, peer)) => {
                let _ = stream.set_nonblocking(false);
                let _ = stream.set_nodelay(true);
                let done_tx = done_tx.clone();
                std::thread::spawn(move || match handshake_tcp(&mut stream, true, 0) {
                    Ok(_) => {
                        let _ = done_tx.send(stream);
                    }
                    Err(e) => eprintln!("shard worker: refusing {peer}: {e}"),
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(e) => return Err(e).context("accepting shard host"),
        }
    }
}

// ---------------------------------------------------------------------------
// fault injection
// ---------------------------------------------------------------------------

/// Deterministic fault schedule for one [`FaultTransport`] connection.
/// All offsets are absolute byte positions in the respective direction's
/// stream, so a schedule replays exactly (see
/// [`util::prop`](crate::util::prop) for the replay workflow).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// accept at most this many bytes per `write` call (0 = unlimited):
    /// byte-chops frames so peers must reassemble short reads/writes
    pub chop: usize,
    /// sleep this long on every flush (delayed delivery)
    pub flush_delay: Duration,
    /// sever the host→worker direction after this many bytes: the write
    /// fails with `BrokenPipe` and the worker sees EOF mid-frame
    pub cut_tx_after: Option<u64>,
    /// sever the worker→host direction after this many bytes: the host
    /// reader sees EOF mid-frame
    pub cut_rx_after: Option<u64>,
    /// XOR this mask into the worker→host byte at this offset (bit
    /// corruption the frame checksum must catch). Schedules should pair
    /// this with [`cut_rx_after`](FaultPlan::cut_rx_after) at the very
    /// next byte — mirroring a link that corrupts and then drops.
    /// A flip left on a *live* stream can land in a frame header's
    /// length field, which the payload checksum does not cover; the
    /// parser would then wait for bytes the peer never sends, a stall
    /// no liveness probe can see.
    pub corrupt_rx: Option<(u64, u8)>,
    /// **wedge**: after this many worker→host bytes, stop delivering —
    /// no further bytes, no EOF, connection still "open". Unlike the
    /// cuts, nothing in-band ever tells the host the worker is gone;
    /// only per-job heartbeat expiry can recover. The stall lifts when
    /// [`stall_rx_resume`](FaultPlan::stall_rx_resume) elapses or the
    /// host [`kill`](Transport::kill)s the transport (which surfaces as
    /// EOF to the parked reader thread).
    pub stall_rx_after: Option<u64>,
    /// lift the stall after this long (`None` = wedged forever): the
    /// stall-then-resume schedule, where late frames from the requeued
    /// window arrive after the host already re-dispatched the jobs
    pub stall_rx_resume: Option<Duration>,
}

struct FaultWriter {
    inner: Option<Box<dyn Write + Send>>,
    chop: usize,
    flush_delay: Duration,
    cut_after: Option<u64>,
    written: u64,
}

impl Write for FaultWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if let Some(cut) = self.cut_after {
            if self.written >= cut {
                self.inner = None; // sever: peer sees EOF mid-frame
                return Err(std::io::ErrorKind::BrokenPipe.into());
            }
        }
        let inner = match &mut self.inner {
            Some(w) => w,
            None => return Err(std::io::ErrorKind::BrokenPipe.into()),
        };
        let mut n = buf.len();
        if self.chop > 0 {
            n = n.min(self.chop);
        }
        if let Some(cut) = self.cut_after {
            // written < cut here (checked above), so at least one byte
            // still fits before the sever point
            n = n.min((cut - self.written) as usize);
        }
        let n = inner.write(&buf[..n])?;
        self.written += n as u64;
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        if !self.flush_delay.is_zero() {
            std::thread::sleep(self.flush_delay);
        }
        match &mut self.inner {
            Some(w) => w.flush(),
            None => Err(std::io::ErrorKind::BrokenPipe.into()),
        }
    }
}

struct FaultReader {
    inner: Box<dyn Read + Send>,
    cut_after: Option<u64>,
    corrupt: Option<(u64, u8)>,
    stall_after: Option<u64>,
    stall_resume: Option<Duration>,
    stall_started: Option<Instant>,
    /// set by [`FaultTransport::kill`]: severs a stalled (or future)
    /// read with EOF, exactly what a real socket shutdown does to a
    /// parked reader thread
    severed: std::sync::Arc<std::sync::atomic::AtomicBool>,
    read: u64,
}

impl Read for FaultReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        use std::sync::atomic::Ordering;
        let mut limit = buf.len();
        if self.severed.load(Ordering::Acquire) {
            return Ok(0);
        }
        if let Some(at) = self.stall_after {
            if self.read >= at {
                // wedged: neither bytes nor EOF. Poll for the two ways
                // out — the schedule's resume point, or the host
                // severing the transport after heartbeat expiry.
                let started = *self.stall_started.get_or_insert_with(Instant::now);
                loop {
                    if self.severed.load(Ordering::Acquire) {
                        return Ok(0);
                    }
                    match self.stall_resume {
                        Some(resume) if started.elapsed() >= resume => {
                            self.stall_after = None;
                            break;
                        }
                        _ => std::thread::sleep(Duration::from_millis(2)),
                    }
                }
            } else {
                limit = limit.min((at - self.read) as usize);
            }
        }
        if let Some(cut) = self.cut_after {
            if self.read >= cut {
                return Ok(0); // EOF mid-frame
            }
            limit = limit.min((cut - self.read) as usize);
        }
        let n = self.inner.read(&mut buf[..limit])?;
        if let Some((at, mask)) = self.corrupt {
            if at >= self.read && at < self.read + n as u64 {
                buf[(at - self.read) as usize] ^= mask;
            }
        }
        self.read += n as u64;
        Ok(n)
    }
}

/// Fault-injecting [`Transport`] over any duplex pair — in practice the
/// in-memory [`byte_pipe`](super::jobs::byte_pipe)s of a worker running
/// on a thread. With a default (empty) [`FaultPlan`] it is a clean
/// loopback transport.
pub struct FaultTransport {
    writer: Option<FaultWriter>,
    reader: Option<FaultReader>,
    /// shared with the reader (which may already live on the session's
    /// reader thread when `kill` runs): setting it delivers EOF, even to
    /// a read parked inside a stall
    severed: std::sync::Arc<std::sync::atomic::AtomicBool>,
}

impl FaultTransport {
    /// Interpose `plan` on a duplex pair: `to_peer` carries host→worker
    /// bytes, `from_peer` carries worker→host bytes.
    pub fn new(
        to_peer: impl Write + Send + 'static,
        from_peer: impl Read + Send + 'static,
        plan: FaultPlan,
    ) -> Self {
        let severed = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        FaultTransport {
            writer: Some(FaultWriter {
                inner: Some(Box::new(to_peer)),
                chop: plan.chop,
                flush_delay: plan.flush_delay,
                cut_after: plan.cut_tx_after,
                written: 0,
            }),
            reader: Some(FaultReader {
                inner: Box::new(from_peer),
                cut_after: plan.cut_rx_after,
                corrupt: plan.corrupt_rx,
                stall_after: plan.stall_rx_after,
                stall_resume: plan.stall_rx_resume,
                stall_started: None,
                severed: severed.clone(),
                read: 0,
            }),
            severed,
        }
    }
}

impl Transport for FaultTransport {
    fn take_reader(&mut self) -> Option<Box<dyn Read + Send>> {
        self.reader.take().map(|r| Box::new(r) as Box<dyn Read + Send>)
    }

    fn writer(&mut self) -> Option<&mut dyn Write> {
        match &mut self.writer {
            Some(w) if w.inner.is_some() => Some(w as &mut dyn Write),
            _ => None,
        }
    }

    fn close_writer(&mut self) {
        self.writer = None; // drops the inner half → peer EOF
    }

    fn poll_dead(&mut self) -> bool {
        false
    }

    fn wait(&mut self) {}

    fn kill(&mut self) {
        self.severed.store(true, std::sync::atomic::Ordering::Release);
        self.writer = None;
        self.reader = None;
    }

    fn describe(&self) -> String {
        "fault-injected loopback".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::jobs::byte_pipe;
    use crate::coordinator::wire::{read_frame, Frame, WIRE_VERSION};

    #[test]
    fn handshake_pairs_host_and_worker_roles() {
        let (host_w, worker_r) = byte_pipe(1 << 12);
        let (worker_w, host_r) = byte_pipe(1 << 12);
        let worker = std::thread::spawn(move || {
            let mut duplex = Duplex { r: worker_r, w: worker_w };
            handshake_io(&mut duplex, true, 42)
        });
        let mut duplex = Duplex { r: host_r, w: host_w };
        let host_view = handshake_io(&mut duplex, false, 0).expect("host handshake");
        let worker_view = worker.join().unwrap().expect("worker handshake");
        assert_eq!(host_view, 42, "host sees the worker's token");
        assert_eq!(worker_view, 0, "worker sees the host's token");
    }

    #[test]
    fn handshake_refuses_same_role_and_non_hello() {
        // two hosts
        let (host_w, peer_r) = byte_pipe(1 << 12);
        let (peer_w, host_r) = byte_pipe(1 << 12);
        let peer = std::thread::spawn(move || {
            let mut duplex = Duplex { r: peer_r, w: peer_w };
            handshake_io(&mut duplex, false, 0)
        });
        let mut duplex = Duplex { r: host_r, w: host_w };
        assert!(matches!(
            handshake_io(&mut duplex, false, 0),
            Err(WireError::Malformed("peer claims the same role"))
        ));
        let _ = peer.join().unwrap();

        // a shutdown frame where the hello belongs
        let (mut raw_w, raw_r) = byte_pipe(1 << 12);
        wire::shutdown_frame().write_to(&mut raw_w).unwrap();
        let (sink_w, _keep) = byte_pipe(1 << 12);
        let mut duplex = Duplex { r: raw_r, w: sink_w };
        assert!(matches!(
            handshake_io(&mut duplex, false, 0),
            Err(WireError::Malformed("expected hello frame"))
        ));
    }

    /// The handshake *is* the wire version gate: a peer advertising a
    /// different WIRE_VERSION is refused by the frame reader itself.
    #[test]
    fn handshake_refuses_cross_version_peer() {
        let mut bytes = Vec::new();
        wire::encode_hello(true, 0).write_to(&mut bytes).unwrap();
        bytes[4..6].copy_from_slice(&(WIRE_VERSION + 1).to_le_bytes());
        let (mut raw_w, raw_r) = byte_pipe(1 << 12);
        std::io::Write::write_all(&mut raw_w, &bytes).unwrap();
        let (sink_w, _keep) = byte_pipe(1 << 12);
        let mut duplex = Duplex { r: raw_r, w: sink_w };
        match handshake_io(&mut duplex, false, 0) {
            Err(WireError::BadVersion { got }) => assert_eq!(got, WIRE_VERSION + 1),
            other => panic!("expected BadVersion, got {other:?}"),
        }
    }

    /// An unauthenticated peer advertising a huge payload length in the
    /// hello header must be refused without the allocation.
    #[test]
    fn handshake_refuses_oversized_hello_frame() {
        let mut bytes = Vec::new();
        wire::encode_hello(true, 0).write_to(&mut bytes).unwrap();
        // lie about the payload length (4 GiB) in the frame header
        bytes[8..16].copy_from_slice(&(u32::MAX as u64).to_le_bytes());
        let (mut raw_w, raw_r) = byte_pipe(1 << 12);
        std::io::Write::write_all(&mut raw_w, &bytes).unwrap();
        let (sink_w, _keep) = byte_pipe(1 << 12);
        let mut duplex = Duplex { r: raw_r, w: sink_w };
        assert!(matches!(
            handshake_io(&mut duplex, false, 0),
            Err(WireError::Malformed("frame length out of bounds"))
        ));
    }

    #[test]
    fn chopped_writes_still_frame_correctly() {
        let (to_peer, mut peer_r) = byte_pipe(1 << 12);
        let (_keep_w, from_peer) = byte_pipe(16);
        let mut t = FaultTransport::new(
            to_peer,
            from_peer,
            FaultPlan { chop: 3, ..Default::default() },
        );
        let frame = Frame { kind: 5, payload: (0..100u8).collect() };
        let reader = std::thread::spawn(move || read_frame(&mut peer_r));
        {
            let mut w = t.writer().expect("open writer");
            frame.write_to(&mut w).unwrap();
            w.flush().unwrap();
        }
        t.close_writer();
        let got = reader.join().unwrap().unwrap().expect("one frame");
        assert_eq!(got, frame);
    }

    #[test]
    fn cut_tx_severs_mid_frame_with_broken_pipe_then_peer_eof() {
        let (to_peer, mut peer_r) = byte_pipe(1 << 12);
        let (_keep_w, from_peer) = byte_pipe(16);
        let mut t = FaultTransport::new(
            to_peer,
            from_peer,
            FaultPlan { cut_tx_after: Some(40), ..Default::default() },
        );
        let frame = Frame { kind: 4, payload: vec![7u8; 600] };
        let mut w = t.writer().expect("open writer");
        let err = frame.write_to(&mut w).expect_err("cut severs the write");
        assert_eq!(err.kind(), std::io::ErrorKind::BrokenPipe);
        assert!(t.writer().is_none(), "writer is gone after the cut");
        // the peer sees the truncation as a mid-frame EOF
        assert!(matches!(read_frame(&mut peer_r), Err(WireError::Truncated)));
    }

    #[test]
    fn rx_corruption_fails_checksum_and_rx_cut_truncates() {
        // corruption at a payload byte (header is 16 bytes)
        let (mut src_w, from_peer) = byte_pipe(1 << 12);
        let frame = Frame { kind: 6, payload: vec![9u8; 64] };
        frame.write_to(&mut src_w).unwrap();
        drop(src_w);
        let (to_peer, _keep_r) = byte_pipe(16);
        let mut t = FaultTransport::new(
            to_peer,
            from_peer,
            FaultPlan { corrupt_rx: Some((20, 0x10)), ..Default::default() },
        );
        let mut r = t.take_reader().expect("reader");
        assert!(matches!(read_frame(&mut r), Err(WireError::BadChecksum)));

        // rx cut: EOF inside the frame
        let (mut src_w, from_peer) = byte_pipe(1 << 12);
        frame.write_to(&mut src_w).unwrap();
        drop(src_w);
        let (to_peer, _keep_r) = byte_pipe(16);
        let mut t = FaultTransport::new(
            to_peer,
            from_peer,
            FaultPlan { cut_rx_after: Some(30), ..Default::default() },
        );
        let mut r = t.take_reader().expect("reader");
        assert!(matches!(read_frame(&mut r), Err(WireError::Truncated)));
    }

    /// A wedged (stalled, never closed) rx direction parks the reader
    /// without EOF; `kill` severs it, and a scheduled resume delivers
    /// the frame intact, just late.
    #[test]
    fn stalled_rx_parks_until_kill_or_resume() {
        let frame = Frame { kind: 6, payload: vec![3u8; 64] };

        // wedge forever: the read parks; kill() surfaces EOF mid-frame
        let (mut src_w, from_peer) = byte_pipe(1 << 12);
        frame.write_to(&mut src_w).unwrap();
        let (to_peer, _keep_r) = byte_pipe(16);
        let mut t = FaultTransport::new(
            to_peer,
            from_peer,
            FaultPlan { stall_rx_after: Some(20), ..Default::default() },
        );
        let mut r = t.take_reader().expect("reader");
        let parked = std::thread::spawn(move || read_frame(&mut r));
        std::thread::sleep(Duration::from_millis(50));
        assert!(!parked.is_finished(), "reader must be parked inside the stall");
        t.kill();
        assert!(matches!(parked.join().unwrap(), Err(WireError::Truncated)));

        // stall-then-resume: the frame arrives intact, just late
        let (mut src_w, from_peer) = byte_pipe(1 << 12);
        frame.write_to(&mut src_w).unwrap();
        drop(src_w);
        let (to_peer, _keep_r) = byte_pipe(16);
        let mut t = FaultTransport::new(
            to_peer,
            from_peer,
            FaultPlan {
                stall_rx_after: Some(20),
                stall_rx_resume: Some(Duration::from_millis(30)),
                ..Default::default()
            },
        );
        let mut r = t.take_reader().expect("reader");
        let t0 = Instant::now();
        let got = read_frame(&mut r).unwrap().expect("one frame");
        assert_eq!(got, frame);
        assert!(
            t0.elapsed() >= Duration::from_millis(25),
            "resume must actually have delayed delivery"
        );
    }

    /// Minimal duplex adapter for driving `handshake_io` over two
    /// unidirectional byte pipes.
    struct Duplex<R, W> {
        r: R,
        w: W,
    }

    impl<R: Read, W> Read for Duplex<R, W> {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            self.r.read(buf)
        }
    }

    impl<R, W: Write> Write for Duplex<R, W> {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.w.write(buf)
        }
        fn flush(&mut self) -> std::io::Result<()> {
            self.w.flush()
        }
    }
}
