//! Dependency-free binary wire codec for the multi-process shard plane.
//!
//! [`coordinator::shard`](super::shard) ships phase-B2 sweep jobs and
//! fleet PPL jobs to `srr shard-worker` processes over stdin/stdout.
//! Everything on that pipe is a [`Frame`]:
//!
//! ```text
//! [magic "SRRW"][version u16][kind u8][0u8][payload_len u64]
//! [payload bytes …][fnv1a64(payload) u64]
//! ```
//!
//! * **versioned** — a reader refuses frames from a different
//!   [`WIRE_VERSION`] ([`WireError::BadVersion`]), so a host never
//!   silently exchanges jobs with a stale worker binary;
//! * **length-prefixed** — readers know exactly how many payload bytes
//!   to consume, and a pipe that ends mid-frame surfaces as
//!   [`WireError::Truncated`] instead of a garbage decode;
//! * **checksummed** — the payload carries an FNV-1a trailer; corruption
//!   is [`WireError::BadChecksum`], never a silently wrong matrix.
//!
//! Large artifacts (weights, packed bases, skeleton [`Params`]) travel
//! as **blobs**, content-addressed by a 128-bit FNV hash of their
//! encoded bytes. A sender ([`BlobTx`]) emits each distinct blob once
//! per connection and thereafter refers to it by hash; a receiver
//! ([`BlobRx`]) caches decoded blobs in `Arc`s keyed by that hash. Two
//! properties of the sweep/fleet data model ride on this:
//!
//! * the **M-fold grid dedup** — every w-only / plain-QER result of one
//!   `(quantizer, seed)` cell references the same packed-base hash, so
//!   the host rebuilds them as one shared `Arc<PackedMat>` exactly like
//!   the in-process sweep engine hands out its `LayerCache` `Arc`s;
//! * the **lock-step groups** — a fleet job's group members all resolve
//!   their base to the same cached `Arc`, so
//!   [`LinearOp::matmul_grouped`](crate::serve::LinearOp::matmul_grouped)
//!   still sees pointer-identical buffers on the worker and decodes the
//!   base once per group.
//!
//! Every message and payload kind round-trips bit-exactly (f32/f64 as
//! IEEE-754 little-endian bytes) — property-tested below, including
//! rank-0 adapters and all three [`PackScheme`] families.

use std::collections::{HashMap, HashSet};
use std::io::{Read, Write};
use std::sync::Arc;

use crate::model::Params;
use crate::qer::RankSelection;
use crate::quant::packed::{PackScheme, PackedCodes, PackedMat};
use crate::runtime::manifest::ModelCfg;
use crate::runtime::TensorValue;
use crate::scaling::ScalingKind;
use crate::tensor::Mat;

use super::budget::{BudgetPlan, LayerAlloc};
use super::pipeline::QuantizerSpec;
use super::sweep::SweepConfig;
use crate::qer::Method;

/// Magic bytes opening every frame.
pub const WIRE_MAGIC: [u8; 4] = *b"SRRW";
/// Protocol version; readers refuse any other value.
pub const WIRE_VERSION: u16 = 1;
/// Upper bound on a frame payload (defense against garbage lengths).
pub const MAX_FRAME_LEN: u64 = 1 << 32;

/// Frame kinds (the `kind` byte of the header).
pub mod kind {
    /// blob: a dense matrix, content-addressed
    pub const BLOB_MAT: u8 = 1;
    /// blob: a bit-packed quantized matrix
    pub const BLOB_PACKED: u8 = 2;
    /// blob: a `Params` skeleton
    pub const BLOB_PARAMS: u8 = 3;
    /// host→worker: one phase-B2 sweep reconstruction job
    pub const SWEEP_JOB: u8 = 4;
    /// host→worker: one fleet PPL job (singleton or group×batch)
    pub const FLEET_JOB: u8 = 5;
    /// worker→host: a sweep job's factored result
    pub const SWEEP_RESULT: u8 = 6;
    /// worker→host: a fleet job's PPL / partial sums
    pub const FLEET_RESULT: u8 = 7;
    /// host→worker: drain and exit cleanly
    pub const SHUTDOWN: u8 = 8;
    /// both directions: TCP connection opener (role + session token).
    /// Carried in a regular frame, so the version/magic/checksum checks
    /// of [`read_frame`](super::read_frame) *are* the handshake — a
    /// stale binary is refused before any job bytes flow.
    pub const HELLO: u8 = 9;
    /// worker→host: "job N is still making progress" — emitted per
    /// in-flight job at a fixed cadence so the host can tell a slow
    /// worker from a wedged one and requeue on silence.
    pub const HEARTBEAT: u8 = 10;
    /// host→worker: one phase-A preparation job (a whole layer's
    /// quantized bases, spectra, and residual SVDs)
    pub const PREP_JOB: u8 = 11;
    /// worker→host: a prep job's artifacts (blobs precede this frame)
    pub const PREP_RESULT: u8 = 12;
    /// client→daemon: one serving request (generate or score) for the
    /// continuous-batching daemon (`serve::daemon`)
    pub const SERVE_REQUEST: u8 = 13;
    /// daemon→client: the reply to a serving request (tokens, score,
    /// busy, or a structured error)
    pub const SERVE_REPLY: u8 = 14;
    /// client→daemon: cancel an in-flight serving request by id; the
    /// daemon frees the request's scheduler slot and sends no reply
    pub const SERVE_CANCEL: u8 = 15;
    /// artifact: a model-wide budget allocation
    /// ([`crate::coordinator::budget::BudgetPlan`]) — what `srr budget
    /// --plan-out` writes and sharded planners could ship; not part of
    /// the host/worker job protocol
    pub const BUDGET_PLAN: u8 = 16;
}

/// Content-address of a blob: 128-bit FNV over its encoded bytes.
pub type BlobRef = u128;

/// Decode/IO failure. Any of these on a shard connection means the peer
/// is broken; the host reacts by requeueing the worker's jobs.
#[derive(Debug)]
pub enum WireError {
    /// the underlying pipe failed
    Io(std::io::ErrorKind),
    /// the stream ended inside a frame
    Truncated,
    /// the frame did not open with [`WIRE_MAGIC`]
    BadMagic,
    /// the peer speaks a different protocol version
    BadVersion {
        /// version advertised by the peer
        got: u16,
    },
    /// the payload checksum did not match
    BadChecksum,
    /// structurally invalid payload (short buffer, bad tag, bad utf-8,
    /// unknown blob reference, …)
    Malformed(&'static str),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(k) => write!(f, "wire io error: {k:?}"),
            WireError::Truncated => write!(f, "truncated frame"),
            WireError::BadMagic => write!(f, "bad frame magic"),
            WireError::BadVersion { got } => {
                write!(f, "wire version {got} != supported {WIRE_VERSION}")
            }
            WireError::BadChecksum => write!(f, "frame checksum mismatch"),
            WireError::Malformed(what) => write!(f, "malformed payload: {what}"),
        }
    }
}

impl std::error::Error for WireError {}

/// FNV-1a over `bytes` (the frame checksum).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    bytes
        .iter()
        .fold(0xcbf29ce484222325u64, |h, &b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

/// 128-bit content hash: two decorrelated FNV-1a lanes. Used only to
/// key blob caches within one shard session (dozens-to-thousands of
/// artifacts), where a 2⁻¹²⁸ collision is not a practical concern.
pub fn content_hash128(bytes: &[u8]) -> u128 {
    let lo = fnv1a64(bytes);
    // second lane: offset basis perturbed by a fixed odd constant so the
    // lanes decorrelate while staying deterministic across processes
    let hi = bytes
        .iter()
        .fold(0xcbf29ce484222325u64 ^ 0x9e3779b97f4a7c15, |h, &b| {
            (h ^ b as u64).wrapping_mul(0x100000001b3)
        });
    ((hi as u128) << 64) | lo as u128
}

/// One length-prefixed, checksummed protocol frame.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    /// frame kind (see [`kind`])
    pub kind: u8,
    /// the frame body (message or blob encoding)
    pub payload: Vec<u8>,
}

impl Frame {
    /// Serialize header + payload + checksum onto `w`.
    pub fn write_to<W: Write>(&self, w: &mut W) -> std::io::Result<()> {
        let mut head = [0u8; 16];
        head[0..4].copy_from_slice(&WIRE_MAGIC);
        head[4..6].copy_from_slice(&WIRE_VERSION.to_le_bytes());
        head[6] = self.kind;
        head[8..16].copy_from_slice(&(self.payload.len() as u64).to_le_bytes());
        w.write_all(&head)?;
        w.write_all(&self.payload)?;
        w.write_all(&fnv1a64(&self.payload).to_le_bytes())
    }
}

fn read_fully<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<usize, WireError> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => return Ok(filled),
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e.kind())),
        }
    }
    Ok(filled)
}

/// Read one frame. `Ok(None)` is a clean end-of-stream exactly at a
/// frame boundary; a stream ending anywhere inside a frame is
/// [`WireError::Truncated`].
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Frame>, WireError> {
    read_frame_limited(r, MAX_FRAME_LEN)
}

/// [`read_frame`] with a caller-imposed payload cap. Pre-handshake
/// reads (the TCP HELLO exchange) cap to hello size, so an
/// unauthenticated peer advertising a multi-GiB length in the header
/// cannot make the handshake thread allocate it.
pub fn read_frame_limited<R: Read>(
    r: &mut R,
    max_len: u64,
) -> Result<Option<Frame>, WireError> {
    let mut head = [0u8; 16];
    match read_fully(r, &mut head)? {
        0 => return Ok(None),
        16 => {}
        _ => return Err(WireError::Truncated),
    }
    if head[0..4] != WIRE_MAGIC {
        return Err(WireError::BadMagic);
    }
    let version = u16::from_le_bytes([head[4], head[5]]);
    if version != WIRE_VERSION {
        return Err(WireError::BadVersion { got: version });
    }
    let kind = head[6];
    let len = u64::from_le_bytes(head[8..16].try_into().unwrap());
    if len > max_len.min(MAX_FRAME_LEN) {
        return Err(WireError::Malformed("frame length out of bounds"));
    }
    // grow the payload buffer in bounded chunks as bytes actually
    // arrive: a lying length field (bit-corrupted header, hostile TCP
    // peer) must surface as Truncated, not as a multi-GiB upfront
    // allocation
    const ALLOC_CHUNK: usize = 1 << 20;
    let mut payload = Vec::with_capacity((len as usize).min(ALLOC_CHUNK));
    let mut remaining = len as usize;
    while remaining > 0 {
        let take = remaining.min(ALLOC_CHUNK);
        let start = payload.len();
        payload.resize(start + take, 0);
        if read_fully(r, &mut payload[start..])? != take {
            return Err(WireError::Truncated);
        }
        remaining -= take;
    }
    let mut trailer = [0u8; 8];
    if read_fully(r, &mut trailer)? != 8 {
        return Err(WireError::Truncated);
    }
    if u64::from_le_bytes(trailer) != fnv1a64(&payload) {
        return Err(WireError::BadChecksum);
    }
    Ok(Some(Frame { kind, payload }))
}

// ---------------------------------------------------------------------------
// primitive payload encoding
// ---------------------------------------------------------------------------

/// Append-only payload builder (little-endian throughout).
#[derive(Default)]
pub struct WireWriter {
    buf: Vec<u8>,
}

impl WireWriter {
    /// An empty builder.
    pub fn new() -> Self {
        WireWriter { buf: Vec::new() }
    }

    /// Finish, yielding the payload bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Append one byte.
    pub fn put_u8(&mut self, x: u8) {
        self.buf.push(x);
    }

    /// Append a bool as one byte (0/1).
    pub fn put_bool(&mut self, x: bool) {
        self.buf.push(u8::from(x));
    }

    /// Append a `u32` (little-endian).
    pub fn put_u32(&mut self, x: u32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    /// Append a `u64` (little-endian).
    pub fn put_u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    /// Append a `usize` as a `u64` (little-endian).
    pub fn put_usize(&mut self, x: usize) {
        self.put_u64(x as u64);
    }

    /// Append a `u128` (little-endian).
    pub fn put_u128(&mut self, x: u128) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    /// Append an `f64` as its IEEE-754 little-endian bytes.
    pub fn put_f64(&mut self, x: f64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    /// Append a length-prefixed UTF-8 string.
    pub fn put_str(&mut self, s: &str) {
        self.put_usize(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Append a length-prefixed `f32` slice (IEEE-754 LE).
    pub fn put_f32s(&mut self, xs: &[f32]) {
        self.put_usize(xs.len());
        self.buf.reserve(4 * xs.len());
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Append a length-prefixed `f64` slice (IEEE-754 LE).
    pub fn put_f64s(&mut self, xs: &[f64]) {
        self.put_usize(xs.len());
        self.buf.reserve(8 * xs.len());
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Append a length-prefixed `i32` slice (little-endian).
    pub fn put_i32s(&mut self, xs: &[i32]) {
        self.put_usize(xs.len());
        self.buf.reserve(4 * xs.len());
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Append a length-prefixed `u64` slice (little-endian).
    pub fn put_u64s(&mut self, xs: &[u64]) {
        self.put_usize(xs.len());
        self.buf.reserve(8 * xs.len());
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

/// Bounds-checked payload cursor.
pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    /// A cursor over `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        WireReader { buf, pos: 0 }
    }

    /// Whether the whole payload was consumed.
    pub fn is_done(&self) -> bool {
        self.pos == self.buf.len()
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.buf.len() - self.pos < n {
            return Err(WireError::Malformed("short payload"));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    /// Read one byte.
    pub fn get_u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    /// Read a bool byte; any value other than 0/1 is `Malformed`.
    pub fn get_bool(&mut self) -> Result<bool, WireError> {
        match self.get_u8()? {
            0 => Ok(false),
            1 => Ok(true),
            _ => Err(WireError::Malformed("bad bool")),
        }
    }

    /// Read a `u32` (little-endian).
    pub fn get_u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Read a `u64` (little-endian).
    pub fn get_u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read a `u64` and convert to `usize` (overflow is `Malformed`).
    pub fn get_usize(&mut self) -> Result<usize, WireError> {
        let x = self.get_u64()?;
        usize::try_from(x).map_err(|_| WireError::Malformed("usize overflow"))
    }

    /// Read a `u128` (little-endian).
    pub fn get_u128(&mut self) -> Result<u128, WireError> {
        Ok(u128::from_le_bytes(self.take(16)?.try_into().unwrap()))
    }

    /// Read an `f64` from IEEE-754 little-endian bytes.
    pub fn get_f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read a length-prefixed UTF-8 string.
    pub fn get_str(&mut self) -> Result<String, WireError> {
        let n = self.get_usize()?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| WireError::Malformed("bad utf-8"))
    }

    /// Read a length-prefixed `f32` slice.
    pub fn get_f32s(&mut self) -> Result<Vec<f32>, WireError> {
        let n = self.get_usize()?;
        let bytes = self.take(n.checked_mul(4).ok_or(WireError::Malformed("len overflow"))?)?;
        Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// Read a length-prefixed `f64` slice.
    pub fn get_f64s(&mut self) -> Result<Vec<f64>, WireError> {
        let n = self.get_usize()?;
        let bytes = self.take(n.checked_mul(8).ok_or(WireError::Malformed("len overflow"))?)?;
        Ok(bytes.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// Read a length-prefixed `i32` slice.
    pub fn get_i32s(&mut self) -> Result<Vec<i32>, WireError> {
        let n = self.get_usize()?;
        let bytes = self.take(n.checked_mul(4).ok_or(WireError::Malformed("len overflow"))?)?;
        Ok(bytes.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// Read a length-prefixed `u64` slice.
    pub fn get_u64s(&mut self) -> Result<Vec<u64>, WireError> {
        let n = self.get_usize()?;
        let bytes = self.take(n.checked_mul(8).ok_or(WireError::Malformed("len overflow"))?)?;
        Ok(bytes.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

// ---------------------------------------------------------------------------
// domain-type codecs
// ---------------------------------------------------------------------------

pub(crate) fn put_mat(w: &mut WireWriter, m: &Mat) {
    w.put_usize(m.rows);
    w.put_usize(m.cols);
    w.put_f32s(&m.data);
}

pub(crate) fn get_mat(r: &mut WireReader) -> Result<Mat, WireError> {
    let rows = r.get_usize()?;
    let cols = r.get_usize()?;
    let data = r.get_f32s()?;
    if rows.checked_mul(cols) != Some(data.len()) {
        return Err(WireError::Malformed("mat shape/data mismatch"));
    }
    Ok(Mat { rows, cols, data })
}

pub(crate) fn put_packed(w: &mut WireWriter, p: &PackedMat) {
    w.put_usize(p.rows);
    w.put_usize(p.cols);
    match p.scheme {
        PackScheme::MxintBlock { bits, block } => {
            w.put_u8(0);
            w.put_u32(bits);
            w.put_usize(block);
        }
        PackScheme::UniformGroup { bits, group, symmetric } => {
            w.put_u8(1);
            w.put_u32(bits);
            w.put_usize(group);
            w.put_bool(symmetric);
        }
        PackScheme::GptqGrouped { bits, group } => {
            w.put_u8(2);
            w.put_u32(bits);
            w.put_usize(group);
        }
    }
    w.put_u32(p.codes.bits);
    w.put_usize(p.codes.len);
    w.put_u64s(p.codes.words());
    w.put_f32s(&p.scales);
    w.put_f32s(&p.los);
}

pub(crate) fn get_packed(r: &mut WireReader) -> Result<PackedMat, WireError> {
    let rows = r.get_usize()?;
    let cols = r.get_usize()?;
    let scheme = match r.get_u8()? {
        0 => PackScheme::MxintBlock { bits: r.get_u32()?, block: r.get_usize()? },
        1 => PackScheme::UniformGroup {
            bits: r.get_u32()?,
            group: r.get_usize()?,
            symmetric: r.get_bool()?,
        },
        2 => PackScheme::GptqGrouped { bits: r.get_u32()?, group: r.get_usize()? },
        _ => return Err(WireError::Malformed("bad pack scheme tag")),
    };
    if scheme.group_len() == 0 {
        return Err(WireError::Malformed("zero pack group"));
    }
    let bits = r.get_u32()?;
    let len = r.get_usize()?;
    let words = r.get_u64s()?;
    // every arithmetic step is checked: a hostile/corrupt payload must
    // surface as Malformed, never as an overflow panic
    let n_elems = rows.checked_mul(cols).ok_or(WireError::Malformed("len overflow"))?;
    let total_bits =
        len.checked_mul(bits as usize).ok_or(WireError::Malformed("bit count overflow"))?;
    if !(2..=32).contains(&bits) || len != n_elems || words.len() != total_bits.div_ceil(64) {
        return Err(WireError::Malformed("packed code layout"));
    }
    // trailing padding bits above the last code must be zero — the pack
    // path never writes them, so a nonzero tail is corruption (and would
    // silently poison word-level content hashes of spilled blobs)
    if total_bits % 64 != 0 {
        let last = *words.last().ok_or(WireError::Malformed("packed code layout"))?;
        if last >> (total_bits % 64) != 0 {
            return Err(WireError::Malformed("nonzero packed padding bits"));
        }
    }
    let codes = PackedCodes::from_raw(bits, len, words);
    let scales = r.get_f32s()?;
    let los = r.get_f32s()?;
    let gpr = cols.div_ceil(scheme.group_len());
    let n_groups = rows.checked_mul(gpr).ok_or(WireError::Malformed("group count overflow"))?;
    if scales.len() != n_groups {
        return Err(WireError::Malformed("packed scale count"));
    }
    if scheme.is_symmetric() {
        if !los.is_empty() {
            return Err(WireError::Malformed("symmetric scheme with lower bounds"));
        }
    } else if los.len() != n_groups {
        return Err(WireError::Malformed("packed lower-bound count"));
    }
    Ok(PackedMat { rows, cols, scheme, codes, scales, los })
}

fn put_tensor_value(w: &mut WireWriter, v: &TensorValue) {
    match v {
        TensorValue::F32 { shape, data } => {
            w.put_u8(0);
            w.put_u64s(&shape.iter().map(|&d| d as u64).collect::<Vec<_>>());
            w.put_f32s(data);
        }
        TensorValue::I32 { shape, data } => {
            w.put_u8(1);
            w.put_u64s(&shape.iter().map(|&d| d as u64).collect::<Vec<_>>());
            w.put_i32s(data);
        }
    }
}

fn get_tensor_value(r: &mut WireReader) -> Result<TensorValue, WireError> {
    let tag = r.get_u8()?;
    let shape: Vec<usize> = r.get_u64s()?.into_iter().map(|d| d as usize).collect();
    let n = shape
        .iter()
        .try_fold(1usize, |a, &d| a.checked_mul(d))
        .ok_or(WireError::Malformed("shape overflow"))?;
    match tag {
        0 => {
            let data = r.get_f32s()?;
            if data.len() != n {
                return Err(WireError::Malformed("tensor shape/data mismatch"));
            }
            Ok(TensorValue::F32 { shape, data })
        }
        1 => {
            let data = r.get_i32s()?;
            if data.len() != n {
                return Err(WireError::Malformed("tensor shape/data mismatch"));
            }
            Ok(TensorValue::I32 { shape, data })
        }
        _ => Err(WireError::Malformed("bad tensor tag")),
    }
}

fn put_params(w: &mut WireWriter, p: &Params) {
    w.put_usize(p.order.len());
    for n in &p.order {
        w.put_str(n);
    }
    w.put_usize(p.by_name.len());
    for (n, v) in &p.by_name {
        w.put_str(n);
        put_tensor_value(w, v);
    }
}

fn get_params(r: &mut WireReader) -> Result<Params, WireError> {
    let n_order = r.get_usize()?;
    let mut order = Vec::with_capacity(n_order.min(1 << 16));
    for _ in 0..n_order {
        order.push(r.get_str()?);
    }
    let mut params = Params::new(order);
    let n_set = r.get_usize()?;
    for _ in 0..n_set {
        let name = r.get_str()?;
        let value = get_tensor_value(r)?;
        if !params.order.iter().any(|n| *n == name) {
            return Err(WireError::Malformed("param outside order"));
        }
        params.set(&name, value);
    }
    Ok(params)
}

fn put_method(w: &mut WireWriter, m: &Method) {
    match m {
        Method::WOnly => w.put_u8(0),
        Method::Qer => w.put_u8(1),
        Method::QerSrr => w.put_u8(2),
        Method::IterativeLowRank { iters } => {
            w.put_u8(3);
            w.put_usize(*iters);
        }
        Method::PreserveOnly => w.put_u8(4),
        Method::FixedSplitHalf => w.put_u8(5),
        Method::SrrSingleSvd => w.put_u8(6),
    }
}

fn get_method(r: &mut WireReader) -> Result<Method, WireError> {
    Ok(match r.get_u8()? {
        0 => Method::WOnly,
        1 => Method::Qer,
        2 => Method::QerSrr,
        3 => Method::IterativeLowRank { iters: r.get_usize()? },
        4 => Method::PreserveOnly,
        5 => Method::FixedSplitHalf,
        6 => Method::SrrSingleSvd,
        _ => return Err(WireError::Malformed("bad method tag")),
    })
}

pub(crate) fn put_scaling_kind(w: &mut WireWriter, k: ScalingKind) {
    w.put_u8(match k {
        ScalingKind::Identity => 0,
        ScalingKind::DiagRms => 1,
        ScalingKind::DiagAbsMean => 2,
        ScalingKind::Exact => 3,
    });
}

pub(crate) fn get_scaling_kind(r: &mut WireReader) -> Result<ScalingKind, WireError> {
    Ok(match r.get_u8()? {
        0 => ScalingKind::Identity,
        1 => ScalingKind::DiagRms,
        2 => ScalingKind::DiagAbsMean,
        3 => ScalingKind::Exact,
        _ => return Err(WireError::Malformed("bad scaling kind")),
    })
}

pub(crate) fn put_quantizer(w: &mut WireWriter, q: &QuantizerSpec) {
    match *q {
        QuantizerSpec::Mxint { bits, block } => {
            w.put_u8(0);
            w.put_u32(bits);
            w.put_usize(block);
        }
        QuantizerSpec::Uniform { bits, group, symmetric } => {
            w.put_u8(1);
            w.put_u32(bits);
            w.put_usize(group);
            w.put_bool(symmetric);
        }
        QuantizerSpec::Gptq { bits, group } => {
            w.put_u8(2);
            w.put_u32(bits);
            w.put_usize(group);
        }
        QuantizerSpec::QuipSharp { bits } => {
            w.put_u8(3);
            w.put_u32(bits);
        }
    }
}

pub(crate) fn get_quantizer(r: &mut WireReader) -> Result<QuantizerSpec, WireError> {
    Ok(match r.get_u8()? {
        0 => QuantizerSpec::Mxint { bits: r.get_u32()?, block: r.get_usize()? },
        1 => QuantizerSpec::Uniform {
            bits: r.get_u32()?,
            group: r.get_usize()?,
            symmetric: r.get_bool()?,
        },
        2 => QuantizerSpec::Gptq { bits: r.get_u32()?, group: r.get_usize()? },
        3 => QuantizerSpec::QuipSharp { bits: r.get_u32()? },
        _ => return Err(WireError::Malformed("bad quantizer tag")),
    })
}

pub(crate) fn put_sweep_config(w: &mut WireWriter, c: &SweepConfig) {
    // heterogeneous cells are resolved to a layer's homogeneous view
    // before encoding (SweepJobSource), so per_layer never rides the wire
    debug_assert!(c.per_layer.is_none(), "encode a resolved SweepConfig");
    w.put_str(&c.label);
    put_quantizer(w, &c.quantizer);
    put_method(w, &c.method);
    w.put_usize(c.rank);
    put_scaling_kind(w, c.scaling);
    w.put_u64(c.seed);
}

pub(crate) fn get_sweep_config(r: &mut WireReader) -> Result<SweepConfig, WireError> {
    Ok(SweepConfig {
        label: r.get_str()?,
        quantizer: get_quantizer(r)?,
        method: get_method(r)?,
        rank: r.get_usize()?,
        scaling: get_scaling_kind(r)?,
        seed: r.get_u64()?,
        per_layer: None,
    })
}

pub(crate) fn put_selection(w: &mut WireWriter, s: &RankSelection) {
    w.put_usize(s.k_star);
    w.put_f64s(&s.objective);
    w.put_f64s(&s.rho_sw);
    w.put_f64s(&s.rho_se);
    w.put_f32s(&s.sw_spectrum);
}

pub(crate) fn get_selection(r: &mut WireReader) -> Result<RankSelection, WireError> {
    Ok(RankSelection {
        k_star: r.get_usize()?,
        objective: r.get_f64s()?,
        rho_sw: r.get_f64s()?,
        rho_se: r.get_f64s()?,
        sw_spectrum: r.get_f32s()?,
    })
}

pub(crate) fn put_model_cfg(w: &mut WireWriter, c: &ModelCfg) {
    w.put_str(&c.name);
    w.put_usize(c.vocab);
    w.put_usize(c.d_model);
    w.put_usize(c.n_heads);
    w.put_usize(c.n_layers);
    w.put_usize(c.d_ff);
    w.put_usize(c.seq_len);
}

fn get_model_cfg(r: &mut WireReader) -> Result<ModelCfg, WireError> {
    Ok(ModelCfg {
        name: r.get_str()?,
        vocab: r.get_usize()?,
        d_model: r.get_usize()?,
        n_heads: r.get_usize()?,
        n_layers: r.get_usize()?,
        d_ff: r.get_usize()?,
        seq_len: r.get_usize()?,
    })
}

// ---------------------------------------------------------------------------
// blob dedup
// ---------------------------------------------------------------------------

/// Encode `m` as a blob body plus its content hash. Callers that
/// reference the same artifact many times (the shard host's job
/// encoding) cache the pair instead of re-serializing per reference.
pub fn encode_mat_blob(m: &Mat) -> (BlobRef, Vec<u8>) {
    let mut w = WireWriter::new();
    put_mat(&mut w, m);
    let bytes = w.into_bytes();
    (content_hash128(&bytes), bytes)
}

/// [`encode_mat_blob`] for packed bases.
pub fn encode_packed_blob(p: &PackedMat) -> (BlobRef, Vec<u8>) {
    let mut w = WireWriter::new();
    put_packed(&mut w, p);
    let bytes = w.into_bytes();
    (content_hash128(&bytes), bytes)
}

/// [`encode_mat_blob`] for `Params` skeletons.
pub fn encode_params_blob(p: &Params) -> (BlobRef, Vec<u8>) {
    let mut w = WireWriter::new();
    put_params(&mut w, p);
    let bytes = w.into_bytes();
    (content_hash128(&bytes), bytes)
}

/// Per-connection sender state: remembers which blob hashes the peer
/// already holds, so each distinct artifact crosses the pipe once.
#[derive(Default)]
pub struct BlobTx {
    sent: HashSet<BlobRef>,
}

impl BlobTx {
    /// Fresh sender state (nothing sent yet).
    pub fn new() -> Self {
        Self::default()
    }

    /// Mark `h` as already held by the peer (used by a worker for blobs
    /// it *received* from the host — referencing them back needs no
    /// re-upload).
    pub fn mark_seen(&mut self, h: BlobRef) {
        self.sent.insert(h);
    }

    fn owned_ref(
        &mut self,
        k: u8,
        hash: BlobRef,
        body: Vec<u8>,
        frames: &mut Vec<Frame>,
    ) -> BlobRef {
        if self.sent.insert(hash) {
            frames.push(Frame { kind: k, payload: body });
        }
        hash
    }

    /// Reference a pre-encoded blob by its precomputed hash, queueing a
    /// frame (copying `body`) only on first use for this connection.
    pub fn prehashed_ref(
        &mut self,
        k: u8,
        hash: BlobRef,
        body: &[u8],
        frames: &mut Vec<Frame>,
    ) -> BlobRef {
        if self.sent.insert(hash) {
            frames.push(Frame { kind: k, payload: body.to_vec() });
        }
        hash
    }

    /// Reference `m`, queueing a [`kind::BLOB_MAT`] frame on first use.
    pub fn mat_ref(&mut self, m: &Mat, frames: &mut Vec<Frame>) -> BlobRef {
        let (h, body) = encode_mat_blob(m);
        self.owned_ref(kind::BLOB_MAT, h, body, frames)
    }

    /// Reference `p`, queueing a [`kind::BLOB_PACKED`] frame on first use.
    pub fn packed_ref(&mut self, p: &PackedMat, frames: &mut Vec<Frame>) -> BlobRef {
        let (h, body) = encode_packed_blob(p);
        self.owned_ref(kind::BLOB_PACKED, h, body, frames)
    }

    /// Reference `p`, queueing a [`kind::BLOB_PARAMS`] frame on first use.
    pub fn params_ref(&mut self, p: &Params, frames: &mut Vec<Frame>) -> BlobRef {
        let (h, body) = encode_params_blob(p);
        self.owned_ref(kind::BLOB_PARAMS, h, body, frames)
    }
}

/// Receiver-side blob cache: hash → decoded `Arc`. First insert wins, so
/// every later reference to the same content aliases one buffer — this
/// is what reconstructs the sweep grid's `Arc` dedup (and the fleet
/// evaluator's lock-step groups) on the far side of the pipe.
#[derive(Default)]
pub struct BlobRx {
    mats: HashMap<BlobRef, Arc<Mat>>,
    packed: HashMap<BlobRef, Arc<PackedMat>>,
    params: HashMap<BlobRef, Arc<Params>>,
}

impl BlobRx {
    /// An empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Decode and cache a blob frame; returns its content hash. Keeps
    /// the existing `Arc` if the hash is already present.
    pub fn insert(&mut self, k: u8, payload: &[u8]) -> Result<BlobRef, WireError> {
        let h = content_hash128(payload);
        let mut r = WireReader::new(payload);
        match k {
            kind::BLOB_MAT => {
                let m = get_mat(&mut r)?;
                self.mats.entry(h).or_insert_with(|| Arc::new(m));
            }
            kind::BLOB_PACKED => {
                let p = get_packed(&mut r)?;
                self.packed.entry(h).or_insert_with(|| Arc::new(p));
            }
            kind::BLOB_PARAMS => {
                let p = get_params(&mut r)?;
                self.params.entry(h).or_insert_with(|| Arc::new(p));
            }
            _ => return Err(WireError::Malformed("not a blob kind")),
        }
        Ok(h)
    }

    /// Pre-register an outgoing matrix under its wire hash, so incoming
    /// references resolve to this very `Arc` (the host seeds its cache
    /// with the `LayerCache` artifacts it ships out — results that
    /// reference them come back sharing the *same* buffers the
    /// in-process sweep would have handed out).
    pub fn seed_mat(&mut self, m: &Arc<Mat>) -> BlobRef {
        let (h, _) = encode_mat_blob(m);
        self.mats.entry(h).or_insert_with(|| m.clone());
        h
    }

    /// [`BlobRx::seed_mat`] for packed bases.
    pub fn seed_packed(&mut self, p: &Arc<PackedMat>) -> BlobRef {
        let (h, _) = encode_packed_blob(p);
        self.packed.entry(h).or_insert_with(|| p.clone());
        h
    }

    /// Resolve a matrix reference.
    pub fn mat(&self, h: BlobRef) -> Result<Arc<Mat>, WireError> {
        self.mats.get(&h).cloned().ok_or(WireError::Malformed("unknown mat blob"))
    }

    /// Resolve a packed-base reference.
    pub fn packed(&self, h: BlobRef) -> Result<Arc<PackedMat>, WireError> {
        self.packed.get(&h).cloned().ok_or(WireError::Malformed("unknown packed blob"))
    }

    /// Resolve a `Params` skeleton reference.
    pub fn params(&self, h: BlobRef) -> Result<Arc<Params>, WireError> {
        self.params.get(&h).cloned().ok_or(WireError::Malformed("unknown params blob"))
    }
}

// ---------------------------------------------------------------------------
// messages
// ---------------------------------------------------------------------------

/// An SVD shipped by reference: `u`/`v` as matrix blobs, spectrum inline.
#[derive(Clone, Debug, PartialEq)]
pub struct WireSvd {
    /// left factor blob
    pub u: BlobRef,
    /// singular values (descending)
    pub s: Vec<f32>,
    /// right factor blob
    pub v: BlobRef,
}

/// [`PreparedSpectra`](crate::qer::PreparedSpectra) on the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct WireSpectra {
    /// randomized SVD of S·W
    pub sw: WireSvd,
    /// ‖S·W‖²_F
    pub sw_frob2: f64,
    /// randomized SVD of the scaled probe S·E
    pub se: WireSvd,
    /// ‖S·E‖²_F
    pub se_frob2: f64,
    /// rank the SVDs were computed at
    pub rank: usize,
    /// sweep-level seed the spectra derive from
    pub seed: u64,
}

/// [`Scaling`](crate::scaling::Scaling) on the wire (full matrices by
/// reference, diagonals inline).
#[derive(Clone, Debug, PartialEq)]
pub enum WireScaling {
    /// S = I
    Identity,
    /// diagonal S with its inverse
    Diagonal {
        /// diag(S)
        d: Vec<f32>,
        /// diag(S⁻¹)
        d_inv: Vec<f32>,
    },
    /// full S (QERA-exact) with its inverse, as matrix blobs
    Full {
        /// S blob
        s: BlobRef,
        /// S⁻¹ blob
        s_inv: BlobRef,
    },
}

/// A quantized base by reference: packed codes or a dense fallback.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WireBase {
    /// bit-packed base ([`kind::BLOB_PACKED`] reference)
    Packed(BlobRef),
    /// dense dequantized base ([`kind::BLOB_MAT`] reference)
    Dense(BlobRef),
}

/// One phase-B2 reconstruction job: a [`SweepConfig`]-keyed spec plus
/// references to every shared artifact the job consumes.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepJobMsg {
    /// dense job index (`config_idx * n_layers + layer_idx`)
    pub job_id: u64,
    /// the grid's preparation rank (bit-identity contract)
    pub prep_rank: usize,
    /// the grid cell being reconstructed
    pub config: SweepConfig,
    /// the linear's parameter name (seeds the layer salt)
    pub layer_name: String,
    /// original weight blob
    pub w: BlobRef,
    /// activation scaling for the config's kind
    pub scaling: WireScaling,
    /// GPTQ Hessian blob (quantizers that need one)
    pub hessian: Option<BlobRef>,
    /// cached k=0 dequantized weight (w-only / plain-QER configs)
    pub qdeq0: Option<BlobRef>,
    /// bit-packed encoding of `qdeq0`
    pub qdeq0_packed: Option<BlobRef>,
    /// shared plain-QER residual SVD (QER configs)
    pub resid: Option<WireSvd>,
    /// prepared (S·W, S·E) spectra (SRR-family configs)
    pub spectra: Option<WireSpectra>,
}

/// A completed phase-B2 job: the factored decomposition plus the error
/// report fields the host folds into its [`LayerReport`]s.
///
/// [`LayerReport`]: super::pipeline::LayerReport
#[derive(Clone, Debug, PartialEq)]
pub struct SweepResultMsg {
    /// echoes [`SweepJobMsg::job_id`]
    pub job_id: u64,
    /// the quantized base (packed when the quantizer packs)
    pub base: WireBase,
    /// left adapter factor (rank 0 ⇒ zero columns)
    pub l: Mat,
    /// right adapter factor
    pub r: Mat,
    /// preserved rank chosen by SRR (0 otherwise)
    pub k_star: usize,
    /// the full k-selection trace (SRR only)
    pub selection: Option<RankSelection>,
    /// ‖W − Ŵ‖_F
    pub weight_err: f64,
    /// ‖S(W − Ŵ)‖_F
    pub scaled_err: f64,
    /// worker seconds in quantize + reconstruct
    pub qer_secs: f64,
}

/// One linear of a fleet-job model.
#[derive(Clone, Debug, PartialEq)]
pub enum WireLinearOp {
    /// unquantized dense weight blob
    Dense(BlobRef),
    /// factored `Qdeq + L·R`
    Factored {
        /// the shared quantized base
        base: WireBase,
        /// left adapter blob
        l: BlobRef,
        /// right adapter blob
        r: BlobRef,
    },
}

/// A [`FactoredModel`](crate::serve::FactoredModel) on the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct WireModel {
    /// skeleton `Params` blob (shared by every member of a sweep)
    pub skeleton: BlobRef,
    /// (linear name, op) in `Params::linear_names` order
    pub ops: Vec<(String, WireLinearOp)>,
}

/// One fleet PPL job: a singleton model over all batches, or one
/// lock-step group over one batch.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetJobMsg {
    /// dense job index into the host's fleet job list
    pub job_id: u64,
    /// true ⇒ lock-step group × single batch; false ⇒ singleton × all
    /// batches (the exact split `eval::fleet::fleet_perplexity` uses)
    pub lockstep: bool,
    /// model architecture
    pub cfg: ModelCfg,
    /// sequences per batch
    pub b: usize,
    /// tokens per sequence
    pub t: usize,
    /// the models to score (singleton: exactly one)
    pub models: Vec<WireModel>,
    /// token batches (lock-step: exactly one)
    pub batches: Vec<Vec<i32>>,
}

/// A completed fleet job.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetResultMsg {
    /// echoes [`FleetJobMsg::job_id`]
    pub job_id: u64,
    /// singleton PPL or per-member partial sums
    pub out: FleetOut,
}

/// Fleet job output payload.
#[derive(Clone, Debug, PartialEq)]
pub enum FleetOut {
    /// a singleton's full perplexity
    Ppl(f64),
    /// per-member (Σ nll, Σ tokens) for one lock-step batch
    Partials(Vec<(f64, f64)>),
}

/// One phase-A preparation job: every shared artifact of one layer —
/// k=0 quantized bases, SRR spectra, plain-QER residual SVDs — computed
/// on a worker instead of serializing on the host. The key vectors
/// mirror the dedup loop of the in-process
/// [`SweepRunner::prepare`](super::sweep::SweepRunner); the worker runs
/// the same salted-seed functions on the same f32 inputs, so the
/// artifacts are bit-identical to the host computing them itself.
#[derive(Clone, Debug, PartialEq)]
pub struct PrepJobMsg {
    /// layer index into the sweep's linear list (doubles as job id)
    pub job_id: u64,
    /// the linear's parameter name (seeds the layer salt)
    pub layer_name: String,
    /// the grid's preparation rank (bit-identity contract)
    pub prep_rank: usize,
    /// original weight blob
    pub w: BlobRef,
    /// activation scalings, one per distinct kind in the grid (computed
    /// on the host — they need the calibration set)
    pub scalings: Vec<(ScalingKind, WireScaling)>,
    /// GPTQ Hessian blob (when any quantizer in the grid needs one)
    pub hessian: Option<BlobRef>,
    /// distinct (quantizer label, seed, spec) cells needing a k=0 base
    pub qdeq0: Vec<(String, u64, QuantizerSpec)>,
    /// distinct (scaling kind, seed) cells needing SRR spectra
    pub spectra: Vec<(ScalingKind, u64)>,
    /// distinct (label, scaling kind, seed, spec) cells needing a shared
    /// plain-QER residual SVD
    pub resid: Vec<(String, ScalingKind, u64, QuantizerSpec)>,
}

/// A completed prep job: one entry per key of the corresponding
/// [`PrepJobMsg`], in the same order. Blob frames for the referenced
/// artifacts precede this frame on the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct PrepResultMsg {
    /// echoes [`PrepJobMsg::job_id`]
    pub job_id: u64,
    /// per [`PrepJobMsg::qdeq0`] key: dense base blob + packed encoding
    pub qdeq0: Vec<(BlobRef, Option<BlobRef>)>,
    /// per [`PrepJobMsg::spectra`] key
    pub spectra: Vec<WireSpectra>,
    /// per [`PrepJobMsg::resid`] key
    pub resid: Vec<WireSvd>,
    /// worker seconds spent preparing the layer
    pub prep_secs: f64,
}

pub(crate) fn put_wire_svd(w: &mut WireWriter, s: &WireSvd) {
    w.put_u128(s.u);
    w.put_f32s(&s.s);
    w.put_u128(s.v);
}

pub(crate) fn get_wire_svd(r: &mut WireReader) -> Result<WireSvd, WireError> {
    Ok(WireSvd { u: r.get_u128()?, s: r.get_f32s()?, v: r.get_u128()? })
}

pub(crate) fn put_opt<T>(w: &mut WireWriter, v: &Option<T>, f: impl FnOnce(&mut WireWriter, &T)) {
    match v {
        Some(x) => {
            w.put_u8(1);
            f(w, x);
        }
        None => w.put_u8(0),
    }
}

pub(crate) fn get_opt<T>(
    r: &mut WireReader,
    f: impl FnOnce(&mut WireReader) -> Result<T, WireError>,
) -> Result<Option<T>, WireError> {
    match r.get_u8()? {
        0 => Ok(None),
        1 => Ok(Some(f(r)?)),
        _ => Err(WireError::Malformed("bad option tag")),
    }
}

pub(crate) fn put_wire_base(w: &mut WireWriter, b: &WireBase) {
    match b {
        WireBase::Packed(h) => {
            w.put_u8(0);
            w.put_u128(*h);
        }
        WireBase::Dense(h) => {
            w.put_u8(1);
            w.put_u128(*h);
        }
    }
}

pub(crate) fn get_wire_base(r: &mut WireReader) -> Result<WireBase, WireError> {
    Ok(match r.get_u8()? {
        0 => WireBase::Packed(r.get_u128()?),
        1 => WireBase::Dense(r.get_u128()?),
        _ => return Err(WireError::Malformed("bad base tag")),
    })
}

pub(crate) fn put_wire_scaling(w: &mut WireWriter, s: &WireScaling) {
    match s {
        WireScaling::Identity => w.put_u8(0),
        WireScaling::Diagonal { d, d_inv } => {
            w.put_u8(1);
            w.put_f32s(d);
            w.put_f32s(d_inv);
        }
        WireScaling::Full { s, s_inv } => {
            w.put_u8(2);
            w.put_u128(*s);
            w.put_u128(*s_inv);
        }
    }
}

pub(crate) fn get_wire_scaling(r: &mut WireReader) -> Result<WireScaling, WireError> {
    Ok(match r.get_u8()? {
        0 => WireScaling::Identity,
        1 => WireScaling::Diagonal { d: r.get_f32s()?, d_inv: r.get_f32s()? },
        2 => WireScaling::Full { s: r.get_u128()?, s_inv: r.get_u128()? },
        _ => return Err(WireError::Malformed("bad scaling tag")),
    })
}

pub(crate) fn put_wire_spectra(w: &mut WireWriter, sp: &WireSpectra) {
    put_wire_svd(w, &sp.sw);
    w.put_f64(sp.sw_frob2);
    put_wire_svd(w, &sp.se);
    w.put_f64(sp.se_frob2);
    w.put_usize(sp.rank);
    w.put_u64(sp.seed);
}

pub(crate) fn get_wire_spectra(r: &mut WireReader) -> Result<WireSpectra, WireError> {
    Ok(WireSpectra {
        sw: get_wire_svd(r)?,
        sw_frob2: r.get_f64()?,
        se: get_wire_svd(r)?,
        se_frob2: r.get_f64()?,
        rank: r.get_usize()?,
        seed: r.get_u64()?,
    })
}

/// Encode a sweep job into its frame.
pub fn encode_sweep_job(m: &SweepJobMsg) -> Frame {
    let mut w = WireWriter::new();
    w.put_u64(m.job_id);
    w.put_usize(m.prep_rank);
    put_sweep_config(&mut w, &m.config);
    w.put_str(&m.layer_name);
    w.put_u128(m.w);
    put_wire_scaling(&mut w, &m.scaling);
    put_opt(&mut w, &m.hessian, |w, h| w.put_u128(*h));
    put_opt(&mut w, &m.qdeq0, |w, h| w.put_u128(*h));
    put_opt(&mut w, &m.qdeq0_packed, |w, h| w.put_u128(*h));
    put_opt(&mut w, &m.resid, put_wire_svd);
    put_opt(&mut w, &m.spectra, put_wire_spectra);
    Frame { kind: kind::SWEEP_JOB, payload: w.into_bytes() }
}

/// Decode a [`kind::SWEEP_JOB`] payload.
pub fn decode_sweep_job(payload: &[u8]) -> Result<SweepJobMsg, WireError> {
    let mut r = WireReader::new(payload);
    Ok(SweepJobMsg {
        job_id: r.get_u64()?,
        prep_rank: r.get_usize()?,
        config: get_sweep_config(&mut r)?,
        layer_name: r.get_str()?,
        w: r.get_u128()?,
        scaling: get_wire_scaling(&mut r)?,
        hessian: get_opt(&mut r, |r| r.get_u128())?,
        qdeq0: get_opt(&mut r, |r| r.get_u128())?,
        qdeq0_packed: get_opt(&mut r, |r| r.get_u128())?,
        resid: get_opt(&mut r, get_wire_svd)?,
        spectra: get_opt(&mut r, get_wire_spectra)?,
    })
}

/// Encode a sweep result into its frame.
pub fn encode_sweep_result(m: &SweepResultMsg) -> Frame {
    let mut w = WireWriter::new();
    w.put_u64(m.job_id);
    put_wire_base(&mut w, &m.base);
    put_mat(&mut w, &m.l);
    put_mat(&mut w, &m.r);
    w.put_usize(m.k_star);
    put_opt(&mut w, &m.selection, put_selection);
    w.put_f64(m.weight_err);
    w.put_f64(m.scaled_err);
    w.put_f64(m.qer_secs);
    Frame { kind: kind::SWEEP_RESULT, payload: w.into_bytes() }
}

/// Decode a [`kind::SWEEP_RESULT`] payload.
pub fn decode_sweep_result(payload: &[u8]) -> Result<SweepResultMsg, WireError> {
    let mut r = WireReader::new(payload);
    Ok(SweepResultMsg {
        job_id: r.get_u64()?,
        base: get_wire_base(&mut r)?,
        l: get_mat(&mut r)?,
        r: get_mat(&mut r)?,
        k_star: r.get_usize()?,
        selection: get_opt(&mut r, get_selection)?,
        weight_err: r.get_f64()?,
        scaled_err: r.get_f64()?,
        qer_secs: r.get_f64()?,
    })
}

/// Encode a fleet job into its frame.
pub fn encode_fleet_job(m: &FleetJobMsg) -> Frame {
    let mut w = WireWriter::new();
    w.put_u64(m.job_id);
    w.put_bool(m.lockstep);
    put_model_cfg(&mut w, &m.cfg);
    w.put_usize(m.b);
    w.put_usize(m.t);
    w.put_usize(m.models.len());
    for model in &m.models {
        w.put_u128(model.skeleton);
        w.put_usize(model.ops.len());
        for (name, op) in &model.ops {
            w.put_str(name);
            match op {
                WireLinearOp::Dense(h) => {
                    w.put_u8(0);
                    w.put_u128(*h);
                }
                WireLinearOp::Factored { base, l, r } => {
                    w.put_u8(1);
                    put_wire_base(&mut w, base);
                    w.put_u128(*l);
                    w.put_u128(*r);
                }
            }
        }
    }
    w.put_usize(m.batches.len());
    for batch in &m.batches {
        w.put_i32s(batch);
    }
    Frame { kind: kind::FLEET_JOB, payload: w.into_bytes() }
}

/// Decode a [`kind::FLEET_JOB`] payload.
pub fn decode_fleet_job(payload: &[u8]) -> Result<FleetJobMsg, WireError> {
    let mut r = WireReader::new(payload);
    let job_id = r.get_u64()?;
    let lockstep = r.get_bool()?;
    let cfg = get_model_cfg(&mut r)?;
    let b = r.get_usize()?;
    let t = r.get_usize()?;
    let n_models = r.get_usize()?;
    let mut models = Vec::with_capacity(n_models.min(1 << 16));
    for _ in 0..n_models {
        let skeleton = r.get_u128()?;
        let n_ops = r.get_usize()?;
        let mut ops = Vec::with_capacity(n_ops.min(1 << 16));
        for _ in 0..n_ops {
            let name = r.get_str()?;
            let op = match r.get_u8()? {
                0 => WireLinearOp::Dense(r.get_u128()?),
                1 => WireLinearOp::Factored {
                    base: get_wire_base(&mut r)?,
                    l: r.get_u128()?,
                    r: r.get_u128()?,
                },
                _ => return Err(WireError::Malformed("bad op tag")),
            };
            ops.push((name, op));
        }
        models.push(WireModel { skeleton, ops });
    }
    let n_batches = r.get_usize()?;
    let mut batches = Vec::with_capacity(n_batches.min(1 << 16));
    for _ in 0..n_batches {
        batches.push(r.get_i32s()?);
    }
    Ok(FleetJobMsg { job_id, lockstep, cfg, b, t, models, batches })
}

/// Encode a fleet result into its frame.
pub fn encode_fleet_result(m: &FleetResultMsg) -> Frame {
    let mut w = WireWriter::new();
    w.put_u64(m.job_id);
    match &m.out {
        FleetOut::Ppl(p) => {
            w.put_u8(0);
            w.put_f64(*p);
        }
        FleetOut::Partials(parts) => {
            w.put_u8(1);
            w.put_usize(parts.len());
            for &(nll, tok) in parts {
                w.put_f64(nll);
                w.put_f64(tok);
            }
        }
    }
    Frame { kind: kind::FLEET_RESULT, payload: w.into_bytes() }
}

/// Decode a [`kind::FLEET_RESULT`] payload.
pub fn decode_fleet_result(payload: &[u8]) -> Result<FleetResultMsg, WireError> {
    let mut r = WireReader::new(payload);
    let job_id = r.get_u64()?;
    let out = match r.get_u8()? {
        0 => FleetOut::Ppl(r.get_f64()?),
        1 => {
            let n = r.get_usize()?;
            let mut parts = Vec::with_capacity(n.min(1 << 16));
            for _ in 0..n {
                parts.push((r.get_f64()?, r.get_f64()?));
            }
            FleetOut::Partials(parts)
        }
        _ => return Err(WireError::Malformed("bad fleet out tag")),
    };
    Ok(FleetResultMsg { job_id, out })
}

/// Encode a prep job into its frame.
pub fn encode_prep_job(m: &PrepJobMsg) -> Frame {
    let mut w = WireWriter::new();
    w.put_u64(m.job_id);
    w.put_str(&m.layer_name);
    w.put_usize(m.prep_rank);
    w.put_u128(m.w);
    w.put_usize(m.scalings.len());
    for (k, s) in &m.scalings {
        put_scaling_kind(&mut w, *k);
        put_wire_scaling(&mut w, s);
    }
    put_opt(&mut w, &m.hessian, |w, h| w.put_u128(*h));
    w.put_usize(m.qdeq0.len());
    for (label, seed, spec) in &m.qdeq0 {
        w.put_str(label);
        w.put_u64(*seed);
        put_quantizer(&mut w, spec);
    }
    w.put_usize(m.spectra.len());
    for (k, seed) in &m.spectra {
        put_scaling_kind(&mut w, *k);
        w.put_u64(*seed);
    }
    w.put_usize(m.resid.len());
    for (label, k, seed, spec) in &m.resid {
        w.put_str(label);
        put_scaling_kind(&mut w, *k);
        w.put_u64(*seed);
        put_quantizer(&mut w, spec);
    }
    Frame { kind: kind::PREP_JOB, payload: w.into_bytes() }
}

/// Decode a [`kind::PREP_JOB`] payload.
pub fn decode_prep_job(payload: &[u8]) -> Result<PrepJobMsg, WireError> {
    let mut r = WireReader::new(payload);
    let job_id = r.get_u64()?;
    let layer_name = r.get_str()?;
    let prep_rank = r.get_usize()?;
    let w = r.get_u128()?;
    let n_scalings = r.get_usize()?;
    let mut scalings = Vec::with_capacity(n_scalings.min(1 << 8));
    for _ in 0..n_scalings {
        let k = get_scaling_kind(&mut r)?;
        scalings.push((k, get_wire_scaling(&mut r)?));
    }
    let hessian = get_opt(&mut r, |r| r.get_u128())?;
    let n_qdeq0 = r.get_usize()?;
    let mut qdeq0 = Vec::with_capacity(n_qdeq0.min(1 << 16));
    for _ in 0..n_qdeq0 {
        let label = r.get_str()?;
        let seed = r.get_u64()?;
        qdeq0.push((label, seed, get_quantizer(&mut r)?));
    }
    let n_spectra = r.get_usize()?;
    let mut spectra = Vec::with_capacity(n_spectra.min(1 << 16));
    for _ in 0..n_spectra {
        let k = get_scaling_kind(&mut r)?;
        spectra.push((k, r.get_u64()?));
    }
    let n_resid = r.get_usize()?;
    let mut resid = Vec::with_capacity(n_resid.min(1 << 16));
    for _ in 0..n_resid {
        let label = r.get_str()?;
        let k = get_scaling_kind(&mut r)?;
        let seed = r.get_u64()?;
        resid.push((label, k, seed, get_quantizer(&mut r)?));
    }
    Ok(PrepJobMsg { job_id, layer_name, prep_rank, w, scalings, hessian, qdeq0, spectra, resid })
}

/// Encode a prep result into its frame.
pub fn encode_prep_result(m: &PrepResultMsg) -> Frame {
    let mut w = WireWriter::new();
    w.put_u64(m.job_id);
    w.put_usize(m.qdeq0.len());
    for (dense, packed) in &m.qdeq0 {
        w.put_u128(*dense);
        put_opt(&mut w, packed, |w, h| w.put_u128(*h));
    }
    w.put_usize(m.spectra.len());
    for sp in &m.spectra {
        put_wire_spectra(&mut w, sp);
    }
    w.put_usize(m.resid.len());
    for svd in &m.resid {
        put_wire_svd(&mut w, svd);
    }
    w.put_f64(m.prep_secs);
    Frame { kind: kind::PREP_RESULT, payload: w.into_bytes() }
}

/// Decode a [`kind::PREP_RESULT`] payload.
pub fn decode_prep_result(payload: &[u8]) -> Result<PrepResultMsg, WireError> {
    let mut r = WireReader::new(payload);
    let job_id = r.get_u64()?;
    let n_qdeq0 = r.get_usize()?;
    let mut qdeq0 = Vec::with_capacity(n_qdeq0.min(1 << 16));
    for _ in 0..n_qdeq0 {
        let dense = r.get_u128()?;
        qdeq0.push((dense, get_opt(&mut r, |r| r.get_u128())?));
    }
    let n_spectra = r.get_usize()?;
    let mut spectra = Vec::with_capacity(n_spectra.min(1 << 16));
    for _ in 0..n_spectra {
        spectra.push(get_wire_spectra(&mut r)?);
    }
    let n_resid = r.get_usize()?;
    let mut resid = Vec::with_capacity(n_resid.min(1 << 16));
    for _ in 0..n_resid {
        resid.push(get_wire_svd(&mut r)?);
    }
    let prep_secs = r.get_f64()?;
    Ok(PrepResultMsg { job_id, qdeq0, spectra, resid, prep_secs })
}

/// The empty [`kind::SHUTDOWN`] frame.
pub fn shutdown_frame() -> Frame {
    Frame { kind: kind::SHUTDOWN, payload: Vec::new() }
}

/// Encode a [`kind::HEARTBEAT`] frame for an in-flight job.
pub fn encode_heartbeat(job_id: u64) -> Frame {
    let mut w = WireWriter::new();
    w.put_u64(job_id);
    Frame { kind: kind::HEARTBEAT, payload: w.into_bytes() }
}

/// Decode a [`kind::HEARTBEAT`] payload into its job id.
pub fn decode_heartbeat(payload: &[u8]) -> Result<u64, WireError> {
    let mut r = WireReader::new(payload);
    let job_id = r.get_u64()?;
    if !r.is_done() {
        return Err(WireError::Malformed("heartbeat trailing bytes"));
    }
    Ok(job_id)
}

/// Encode a [`kind::HELLO`] handshake frame. `worker` is the sender's
/// role (a host refuses a peer claiming its own role); `token` lets a
/// host that spawned its own TCP workers map dial-ins back to child
/// processes (0 = anonymous, e.g. a hand-started remote worker).
pub fn encode_hello(worker: bool, token: u64) -> Frame {
    let mut w = WireWriter::new();
    w.put_bool(worker);
    w.put_u64(token);
    Frame { kind: kind::HELLO, payload: w.into_bytes() }
}

/// Decode a [`kind::HELLO`] payload into `(is_worker, token)`.
pub fn decode_hello(payload: &[u8]) -> Result<(bool, u64), WireError> {
    let mut r = WireReader::new(payload);
    let worker = r.get_bool()?;
    let token = r.get_u64()?;
    if !r.is_done() {
        return Err(WireError::Malformed("hello trailing bytes"));
    }
    Ok((worker, token))
}

/// Encode a [`kind::BUDGET_PLAN`] frame: the allocator's full output,
/// so a plan written by `srr budget --plan-out` (or shipped between
/// processes) reconstructs bit-exactly — f64 error predictions
/// included.
pub fn encode_budget_plan(p: &BudgetPlan) -> Frame {
    let mut w = WireWriter::new();
    w.put_u64(p.budget_bytes);
    w.put_u64(p.plan_bytes);
    w.put_f64(p.predicted_err2);
    w.put_usize(p.prep_rank);
    w.put_usize(p.block);
    put_scaling_kind(&mut w, p.scaling);
    w.put_u64(p.seed);
    w.put_usize(p.layers.len());
    for l in &p.layers {
        w.put_str(&l.name);
        w.put_u32(l.bits);
        w.put_usize(l.rank);
        w.put_usize(l.k);
        w.put_u64(l.bytes);
        w.put_f64(l.predicted_err2);
    }
    Frame { kind: kind::BUDGET_PLAN, payload: w.into_bytes() }
}

/// Decode a [`kind::BUDGET_PLAN`] payload.
pub fn decode_budget_plan(payload: &[u8]) -> Result<BudgetPlan, WireError> {
    let mut r = WireReader::new(payload);
    let budget_bytes = r.get_u64()?;
    let plan_bytes = r.get_u64()?;
    let predicted_err2 = r.get_f64()?;
    let prep_rank = r.get_usize()?;
    let block = r.get_usize()?;
    let scaling = get_scaling_kind(&mut r)?;
    let seed = r.get_u64()?;
    let n = r.get_usize()?;
    let mut layers = Vec::with_capacity(n.min(1 << 20));
    for _ in 0..n {
        layers.push(LayerAlloc {
            name: r.get_str()?,
            bits: r.get_u32()?,
            rank: r.get_usize()?,
            k: r.get_usize()?,
            bytes: r.get_u64()?,
            predicted_err2: r.get_f64()?,
        });
    }
    if !r.is_done() {
        return Err(WireError::Malformed("budget plan trailing bytes"));
    }
    Ok(BudgetPlan {
        layers,
        budget_bytes,
        plan_bytes,
        predicted_err2,
        prep_rank,
        block,
        scaling,
        seed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::packed::PackAcc;
    use crate::quant::QuantCtx;
    use crate::util::{prop, Rng};
    use std::io::Cursor;

    fn roundtrip(frame: &Frame) -> Frame {
        let mut bytes = Vec::new();
        frame.write_to(&mut bytes).unwrap();
        let got = read_frame(&mut Cursor::new(&bytes)).unwrap().expect("one frame");
        assert!(read_frame(&mut Cursor::new(&bytes[bytes.len()..])).unwrap().is_none());
        got
    }

    fn sample_packed(g: &mut prop::Gen) -> PackedMat {
        // cover every PackScheme family, via the real quantizers and via
        // hand-packed affine grids
        let spec = g.choice(&[
            QuantizerSpec::Mxint { bits: 3, block: 32 },
            QuantizerSpec::Uniform { bits: 4, group: 32, symmetric: true },
            QuantizerSpec::Uniform { bits: 3, group: 32, symmetric: false },
            QuantizerSpec::Gptq { bits: 3, group: 32 },
        ]);
        let m = 32 * g.dim(2);
        let n = 32 * g.dim(2);
        let w = Mat::randn(m, n, 1.0, &mut g.rng);
        let (_, packed) = spec.build().quantize_coded(&w, &QuantCtx::default());
        packed.expect("packable family")
    }

    fn assert_packed_eq(a: &PackedMat, b: &PackedMat) {
        assert_eq!((a.rows, a.cols, a.scheme), (b.rows, b.cols, b.scheme));
        assert_eq!(a.scales, b.scales);
        assert_eq!(a.los, b.los);
        assert_eq!(a.codes.words(), b.codes.words());
        assert_eq!(a.dequantize(), b.dequantize());
    }

    /// Satellite: every payload kind round-trips bit-exactly through a
    /// frame — matrices (including zero-column rank-0 adapters), packed
    /// bases of every scheme, params, and both job/result messages with
    /// every optional field populated and absent.
    #[test]
    fn prop_frames_round_trip_all_payload_kinds() {
        prop::check(0x51BE17, 12, |g| {
            // --- packed blob ---------------------------------------------
            let p = sample_packed(g);
            let mut tx = BlobTx::new();
            let mut rx = BlobRx::new();
            let mut frames = Vec::new();
            let hp = tx.packed_ref(&p, &mut frames);
            assert_eq!(frames.len(), 1);
            let fr = roundtrip(&frames[0]);
            assert_eq!(fr.kind, kind::BLOB_PACKED);
            assert_eq!(rx.insert(fr.kind, &fr.payload).unwrap(), hp);
            assert_packed_eq(&rx.packed(hp).unwrap(), &p);

            // --- mat blobs, including a rank-0 (zero-column) adapter -----
            let rank = g.choice(&[0usize, 4, 8]);
            let l = Mat::randn(p.rows, rank, 0.1, &mut g.rng);
            let r = Mat::randn(rank, p.cols, 0.1, &mut g.rng);
            let hl = tx.mat_ref(&l, &mut frames);
            let f_l = roundtrip(frames.last().unwrap());
            assert_eq!(rx.insert(f_l.kind, &f_l.payload).unwrap(), hl);
            assert_eq!(*rx.mat(hl).unwrap(), l);

            // --- sweep job with/without optionals ------------------------
            let svd = WireSvd { u: hl, s: vec![3.0, 2.0, 1.0], v: hl };
            let job = SweepJobMsg {
                job_id: g.rng.next_u64(),
                prep_rank: g.dim(32),
                config: SweepConfig::new(
                    g.choice(&[
                        QuantizerSpec::Mxint { bits: 2, block: 32 },
                        QuantizerSpec::QuipSharp { bits: 2 },
                        QuantizerSpec::Gptq { bits: 3, group: 64 },
                    ]),
                    g.choice(&[
                        Method::WOnly,
                        Method::Qer,
                        Method::QerSrr,
                        Method::IterativeLowRank { iters: 3 },
                        Method::PreserveOnly,
                        Method::FixedSplitHalf,
                        Method::SrrSingleSvd,
                    ]),
                    g.dim(16),
                    g.choice(&[
                        ScalingKind::Identity,
                        ScalingKind::DiagRms,
                        ScalingKind::DiagAbsMean,
                        ScalingKind::Exact,
                    ]),
                )
                .seeded(g.rng.next_u64()),
                layer_name: "l0.wq".into(),
                w: hl,
                scaling: match g.rng.below(3) {
                    0 => WireScaling::Identity,
                    1 => WireScaling::Diagonal { d: vec![1.0, 2.0], d_inv: vec![1.0, 0.5] },
                    _ => WireScaling::Full { s: hl, s_inv: hl },
                },
                hessian: if g.rng.below(2) == 0 { None } else { Some(hl) },
                qdeq0: Some(hl),
                qdeq0_packed: if g.rng.below(2) == 0 { None } else { Some(hp) },
                resid: if g.rng.below(2) == 0 { None } else { Some(svd.clone()) },
                spectra: if g.rng.below(2) == 0 {
                    None
                } else {
                    Some(WireSpectra {
                        sw: svd.clone(),
                        sw_frob2: 1.25,
                        se: svd,
                        se_frob2: 0.5,
                        rank: 8,
                        seed: 7,
                    })
                },
            };
            let fr = roundtrip(&encode_sweep_job(&job));
            assert_eq!(fr.kind, kind::SWEEP_JOB);
            assert_eq!(decode_sweep_job(&fr.payload).unwrap(), job);

            // --- sweep result (rank-0 adapters included) -----------------
            let res = SweepResultMsg {
                job_id: job.job_id,
                base: if g.rng.below(2) == 0 { WireBase::Packed(hp) } else { WireBase::Dense(hl) },
                l,
                r,
                k_star: g.rng.below(9),
                selection: if g.rng.below(2) == 0 {
                    None
                } else {
                    Some(RankSelection {
                        k_star: 2,
                        objective: vec![0.5, 0.25, 0.75],
                        rho_sw: vec![1.0, 0.5],
                        rho_se: vec![1.0, 0.25],
                        sw_spectrum: vec![4.0, 2.0, 1.0],
                    })
                },
                weight_err: g.rng.uniform_in(0.0, 10.0),
                scaled_err: g.rng.uniform_in(0.0, 10.0),
                qer_secs: 0.125,
            };
            let fr = roundtrip(&encode_sweep_result(&res));
            assert_eq!(decode_sweep_result(&fr.payload).unwrap(), res);

            // --- fleet job / result --------------------------------------
            let fjob = FleetJobMsg {
                job_id: 3,
                lockstep: g.rng.below(2) == 1,
                cfg: ModelCfg {
                    name: "t".into(),
                    vocab: 48,
                    d_model: 64,
                    n_heads: 2,
                    n_layers: 1,
                    d_ff: 96,
                    seq_len: 8,
                },
                b: 2,
                t: 8,
                models: vec![WireModel {
                    skeleton: hl,
                    ops: vec![
                        ("l0.wq".into(), WireLinearOp::Dense(hl)),
                        (
                            "l0.wk".into(),
                            WireLinearOp::Factored {
                                base: WireBase::Packed(hp),
                                l: hl,
                                r: hl,
                            },
                        ),
                    ],
                }],
                batches: vec![(0..16).collect(), vec![]],
            };
            let fr = roundtrip(&encode_fleet_job(&fjob));
            assert_eq!(decode_fleet_job(&fr.payload).unwrap(), fjob);

            let fres = FleetResultMsg {
                job_id: 3,
                out: if fjob.lockstep {
                    FleetOut::Partials(vec![(1.5, 16.0), (2.25, 16.0)])
                } else {
                    FleetOut::Ppl(12.75)
                },
            };
            let fr = roundtrip(&encode_fleet_result(&fres));
            assert_eq!(decode_fleet_result(&fr.payload).unwrap(), fres);
        });
    }

    #[test]
    fn params_round_trip() {
        let cfg = ModelCfg {
            name: "t".into(),
            vocab: 16,
            d_model: 8,
            n_heads: 2,
            n_layers: 1,
            d_ff: 16,
            seq_len: 4,
        };
        let mut params = crate::model::synth::synth_lm_params(&cfg, 5, cfg.vocab);
        params.unset("l0.wq"); // skeletons ship with linears unset
        let mut w = WireWriter::new();
        put_params(&mut w, &params);
        let bytes = w.into_bytes();
        let got = get_params(&mut WireReader::new(&bytes)).unwrap();
        assert_eq!(got.order, params.order);
        assert_eq!(got.by_name.len(), params.by_name.len());
        assert!(got.get("l0.wq").is_err());
        assert_eq!(got.get_mat("embed").unwrap(), params.get_mat("embed").unwrap());
        // i32 tensors survive too
        let mut p2 = Params::new(vec!["embed".into()]);
        p2.set("embed", TensorValue::i32(vec![3], vec![1, -2, 3]));
        let mut w2 = WireWriter::new();
        put_params(&mut w2, &p2);
        let b2 = w2.into_bytes();
        let got2 = get_params(&mut WireReader::new(&b2)).unwrap();
        match got2.get("embed").unwrap() {
            TensorValue::I32 { data, .. } => assert_eq!(data, &vec![1, -2, 3]),
            _ => panic!("wrong tensor tag"),
        }
    }

    #[test]
    fn hand_packed_affine_scheme_round_trips() {
        // the asymmetric UniformGroup path with a ragged trailing group
        let scheme = PackScheme::UniformGroup { bits: 4, group: 3, symmetric: false };
        let (rows, cols) = (2usize, 7usize);
        let gpr = cols.div_ceil(3);
        let mut acc = PackAcc::default();
        for i in 0..rows {
            for gidx in 0..gpr {
                acc.scales.push(0.5 + i as f32);
                acc.los.push(-1.0 + gidx as f32 * 0.25);
            }
            for j in 0..cols {
                acc.codes.push(((i * cols + j) % 16) as u32);
            }
        }
        let p = acc.into_packed(rows, cols, scheme);
        let mut w = WireWriter::new();
        put_packed(&mut w, &p);
        let bytes = w.into_bytes();
        let got = get_packed(&mut WireReader::new(&bytes)).unwrap();
        assert_packed_eq(&got, &p);
    }

    #[test]
    fn blob_dedup_sends_once_and_aliases_on_receive() {
        let mut rng = Rng::new(9);
        let m = Mat::randn(8, 8, 1.0, &mut rng);
        let mut tx = BlobTx::new();
        let mut frames = Vec::new();
        let h1 = tx.mat_ref(&m, &mut frames);
        let h2 = tx.mat_ref(&m, &mut frames);
        let h3 = tx.mat_ref(&m.clone(), &mut frames); // equal content, new alloc
        assert_eq!(h1, h2);
        assert_eq!(h1, h3);
        assert_eq!(frames.len(), 1, "one upload for three references");

        let mut rx = BlobRx::new();
        rx.insert(frames[0].kind, &frames[0].payload).unwrap();
        // replay of the same blob keeps the first Arc
        rx.insert(frames[0].kind, &frames[0].payload).unwrap();
        let a = rx.mat(h1).unwrap();
        let b = rx.mat(h1).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "references alias one buffer");

        // mark_seen suppresses the upload entirely (worker referencing a
        // host-sent blob back)
        let mut tx2 = BlobTx::new();
        tx2.mark_seen(h1);
        let mut frames2 = Vec::new();
        assert_eq!(tx2.mat_ref(&m, &mut frames2), h1);
        assert!(frames2.is_empty());

        // host-side seeding resolves to the seeded Arc itself
        let arc = Arc::new(m);
        let mut rx2 = BlobRx::new();
        let hs = rx2.seed_mat(&arc);
        assert_eq!(hs, h1);
        assert!(Arc::ptr_eq(&rx2.mat(hs).unwrap(), &arc));
    }

    #[test]
    fn truncated_frames_are_rejected() {
        let frame = Frame { kind: kind::SWEEP_JOB, payload: vec![7u8; 100] };
        let mut bytes = Vec::new();
        frame.write_to(&mut bytes).unwrap();
        // chop anywhere inside the frame (header, payload, checksum)
        for cut in [1usize, 8, 15, 16, 60, bytes.len() - 1] {
            let got = read_frame(&mut Cursor::new(&bytes[..cut]));
            assert!(
                matches!(got, Err(WireError::Truncated)),
                "cut at {cut}: {got:?}"
            );
        }
        // clean EOF at a frame boundary is Ok(None)
        assert!(read_frame(&mut Cursor::new(&[] as &[u8])).unwrap().is_none());
    }

    #[test]
    fn budget_plan_roundtrips_and_rejects_truncation() {
        let plan = BudgetPlan {
            layers: vec![
                LayerAlloc {
                    name: "h.0.attn.wq".into(),
                    bits: 3,
                    rank: 16,
                    k: 5,
                    bytes: 12_345,
                    predicted_err2: 0.125,
                },
                LayerAlloc {
                    name: "h.1.mlp.w1".into(),
                    bits: 2,
                    rank: 0,
                    k: 0,
                    bytes: 6_789,
                    predicted_err2: 7.5e-3,
                },
            ],
            budget_bytes: 20_000,
            plan_bytes: 19_134,
            predicted_err2: 0.1325,
            prep_rank: 16,
            block: 32,
            scaling: ScalingKind::DiagRms,
            seed: 9,
        };
        let frame = roundtrip(&encode_budget_plan(&plan));
        assert_eq!(frame.kind, kind::BUDGET_PLAN);
        assert_eq!(decode_budget_plan(&frame.payload).unwrap(), plan);

        // any strict payload prefix is refused, as are trailing bytes
        let payload = encode_budget_plan(&plan).payload;
        for cut in [0usize, 4, 11, payload.len() / 2, payload.len() - 1] {
            assert!(decode_budget_plan(&payload[..cut]).is_err(), "cut at {cut}");
        }
        let mut extended = payload.clone();
        extended.push(0);
        assert!(matches!(
            decode_budget_plan(&extended),
            Err(WireError::Malformed(_))
        ));
    }

    #[test]
    fn corrupted_payload_fails_checksum() {
        let frame = Frame { kind: kind::FLEET_RESULT, payload: vec![1, 2, 3, 4, 5] };
        let mut bytes = Vec::new();
        frame.write_to(&mut bytes).unwrap();
        for flip in [16usize, 18, 20] {
            let mut corrupt = bytes.clone();
            corrupt[flip] ^= 0x40;
            let got = read_frame(&mut Cursor::new(&corrupt));
            assert!(
                matches!(got, Err(WireError::BadChecksum)),
                "flip at {flip}: {got:?}"
            );
        }
        // a flipped trailer byte also fails
        let last = bytes.len() - 1;
        bytes[last] ^= 1;
        assert!(matches!(read_frame(&mut Cursor::new(&bytes)), Err(WireError::BadChecksum)));
    }

    #[test]
    fn cross_version_header_is_refused() {
        let frame = shutdown_frame();
        let mut bytes = Vec::new();
        frame.write_to(&mut bytes).unwrap();
        bytes[4] = WIRE_VERSION as u8 + 1; // bump the version field
        match read_frame(&mut Cursor::new(&bytes)) {
            Err(WireError::BadVersion { got }) => assert_eq!(got, WIRE_VERSION + 1),
            other => panic!("expected BadVersion, got {other:?}"),
        }
        // and bad magic is its own refusal
        let mut bad = Vec::new();
        frame.write_to(&mut bad).unwrap();
        bad[0] = b'X';
        assert!(matches!(read_frame(&mut Cursor::new(&bad)), Err(WireError::BadMagic)));
    }

    #[test]
    fn malformed_payloads_error_not_panic() {
        // short payloads, bad tags, inconsistent shapes
        assert!(decode_sweep_job(&[]).is_err());
        assert!(decode_sweep_result(&[0u8; 4]).is_err());
        assert!(decode_fleet_job(&[9u8; 9]).is_err());
        let mut rx = BlobRx::new();
        assert!(rx.insert(kind::BLOB_MAT, &[1, 2, 3]).is_err());
        assert!(rx.insert(kind::SWEEP_JOB, &[]).is_err());
        // mat with a lying shape header
        let mut w = WireWriter::new();
        w.put_usize(4);
        w.put_usize(4);
        w.put_f32s(&[0.0; 3]);
        let bytes = w.into_bytes();
        assert!(get_mat(&mut WireReader::new(&bytes)).is_err());
        assert!(rx.mat(42).is_err());
    }

    #[test]
    fn shutdown_frame_round_trips_empty() {
        let fr = roundtrip(&shutdown_frame());
        assert_eq!(fr.kind, kind::SHUTDOWN);
        assert!(fr.payload.is_empty());
    }

    #[test]
    fn hello_round_trips_and_rejects_garbage() {
        for (worker, token) in [(false, 0u64), (true, 42), (true, u64::MAX)] {
            let fr = roundtrip(&encode_hello(worker, token));
            assert_eq!(fr.kind, kind::HELLO);
            assert_eq!(decode_hello(&fr.payload).unwrap(), (worker, token));
        }
        // bad role byte, short payload, trailing bytes
        assert!(decode_hello(&[2u8, 0, 0, 0, 0, 0, 0, 0, 0]).is_err());
        assert!(decode_hello(&[1u8, 0, 0]).is_err());
        let mut long = encode_hello(true, 7).payload;
        long.push(0);
        assert!(matches!(
            decode_hello(&long),
            Err(WireError::Malformed("hello trailing bytes"))
        ));
    }

    #[test]
    fn heartbeat_round_trips_and_rejects_garbage() {
        for job in [0u64, 17, u64::MAX] {
            let fr = roundtrip(&encode_heartbeat(job));
            assert_eq!(fr.kind, kind::HEARTBEAT);
            assert_eq!(decode_heartbeat(&fr.payload).unwrap(), job);
        }
        assert!(decode_heartbeat(&[1u8, 2]).is_err());
        let mut long = encode_heartbeat(3).payload;
        long.push(0);
        assert!(matches!(
            decode_heartbeat(&long),
            Err(WireError::Malformed("heartbeat trailing bytes"))
        ));
    }

    /// Prep job/result messages round-trip bit-exactly with every key
    /// vector populated and empty.
    #[test]
    fn prop_prep_messages_round_trip() {
        prop::check(0x93E9, 8, |g| {
            let h = g.rng.next_u64() as u128;
            let empty = g.rng.below(4) == 0;
            let job = PrepJobMsg {
                job_id: g.rng.next_u64(),
                layer_name: "l1.wo".into(),
                prep_rank: g.dim(32),
                w: h,
                scalings: if empty {
                    vec![]
                } else {
                    vec![
                        (ScalingKind::Identity, WireScaling::Identity),
                        (
                            ScalingKind::DiagRms,
                            WireScaling::Diagonal { d: vec![1.0, 2.0], d_inv: vec![1.0, 0.5] },
                        ),
                        (ScalingKind::Exact, WireScaling::Full { s: h, s_inv: h.wrapping_add(1) }),
                    ]
                },
                hessian: if g.rng.below(2) == 0 { None } else { Some(h) },
                qdeq0: if empty {
                    vec![]
                } else {
                    vec![
                        ("mx3".into(), 5, QuantizerSpec::Mxint { bits: 3, block: 32 }),
                        ("gptq".into(), 7, QuantizerSpec::Gptq { bits: 3, group: 64 }),
                    ]
                },
                spectra: if empty { vec![] } else { vec![(ScalingKind::DiagRms, 5)] },
                resid: if empty {
                    vec![]
                } else {
                    vec![(
                        "mx3".into(),
                        ScalingKind::DiagAbsMean,
                        9,
                        QuantizerSpec::Uniform { bits: 4, group: 32, symmetric: false },
                    )]
                },
            };
            let fr = roundtrip(&encode_prep_job(&job));
            assert_eq!(fr.kind, kind::PREP_JOB);
            assert_eq!(decode_prep_job(&fr.payload).unwrap(), job);

            let svd = WireSvd { u: h, s: vec![2.0, 1.0], v: h };
            let res = PrepResultMsg {
                job_id: job.job_id,
                qdeq0: if empty { vec![] } else { vec![(h, None), (h, Some(h))] },
                spectra: if empty {
                    vec![]
                } else {
                    vec![WireSpectra {
                        sw: svd.clone(),
                        sw_frob2: 4.5,
                        se: svd.clone(),
                        se_frob2: 0.25,
                        rank: 8,
                        seed: 11,
                    }]
                },
                resid: if empty { vec![] } else { vec![svd] },
                prep_secs: 0.75,
            };
            let fr = roundtrip(&encode_prep_result(&res));
            assert_eq!(fr.kind, kind::PREP_RESULT);
            assert_eq!(decode_prep_result(&fr.payload).unwrap(), res);
            assert!(decode_prep_job(&[]).is_err());
            assert!(decode_prep_result(&[0u8; 3]).is_err());
        });
    }

    /// Satellite: a packed blob whose word buffer disagrees with the
    /// declared shape/scheme must be refused at decode (`Malformed`),
    /// never handed to `PackedCodes::from_raw` where the mismatch would
    /// panic the worker.
    #[test]
    fn packed_blob_layout_disagreement_is_malformed() {
        let mut rng = Rng::new(11);
        let w = Mat::randn(32, 32, 1.0, &mut rng);
        let spec = QuantizerSpec::Mxint { bits: 3, block: 32 };
        let (_, packed) = spec.build().quantize_coded(&w, &QuantCtx::default());
        let p = packed.expect("packable family");

        // a helper that re-encodes `p` with one field surgically lied
        // about, then feeds the payload through the public insert path
        let reject = |mutate: &dyn Fn(&mut WireWriter, &PackedMat)| {
            let mut wtr = WireWriter::new();
            mutate(&mut wtr, &p);
            let payload = wtr.into_bytes();
            let mut rx = BlobRx::new();
            assert!(
                matches!(rx.insert(kind::BLOB_PACKED, &payload), Err(WireError::Malformed(_))),
                "lying packed payload must be Malformed"
            );
        };

        // word buffer shorter than shape × bits requires
        reject(&|w, p| {
            put_packed_with(w, p, |words| {
                words.pop();
            });
        });
        // word buffer longer than the declared layout
        reject(&|w, p| {
            put_packed_with(w, p, |words| words.push(0));
        });
        // declared element count disagreeing with rows × cols
        reject(&|wtr, p| {
            let mut clone = p.clone();
            clone.rows += 1; // codes/scales no longer match the shape
            put_packed(wtr, &clone);
        });
        // scale count disagreeing with the scheme's group layout
        reject(&|wtr, p| {
            let mut clone = p.clone();
            clone.scales.push(1.0);
            put_packed(wtr, &clone);
        });
    }

    /// Satellite: a packed blob whose trailing padding bits are nonzero
    /// passes the frame checksum (the corruption is *in* the payload)
    /// but must still be refused — the pack path never writes those
    /// bits, and accepting them would silently poison word-level
    /// equality and content hashes of spilled blobs.
    #[test]
    fn packed_blob_nonzero_padding_bits_are_malformed() {
        let mut rng = Rng::new(12);
        // 4×10 at 3 bits: 120 code bits in 2 words, 8 padding bits
        let w = Mat::randn(4, 10, 1.0, &mut rng);
        let spec = QuantizerSpec::Mxint { bits: 3, block: 32 };
        let (_, packed) = spec.build().quantize_coded(&w, &QuantCtx::default());
        let p = packed.expect("packable family");
        let total_bits = p.codes.len * p.codes.bits as usize;
        assert_ne!(total_bits % 64, 0, "test shape must leave padding bits");

        // the honest encoding decodes fine
        let mut ok = WireWriter::new();
        put_packed(&mut ok, &p);
        let mut rx = BlobRx::new();
        rx.insert(kind::BLOB_PACKED, &ok.into_bytes()).expect("honest packed blob decodes");

        // same blob with one bit set above the last code: Malformed
        let mut wtr = WireWriter::new();
        put_packed_with(&mut wtr, &p, |words| {
            *words.last_mut().expect("padded buffer has words") |= 1u64 << 63;
        });
        let mut rx = BlobRx::new();
        assert!(
            matches!(
                rx.insert(kind::BLOB_PACKED, &wtr.into_bytes()),
                Err(WireError::Malformed("nonzero packed padding bits"))
            ),
            "nonzero padding bits must be Malformed"
        );
    }

    /// Re-encode `p` with `words` mutated after the fact (the layout
    /// check under test compares the word count against len × bits).
    fn put_packed_with(w: &mut WireWriter, p: &PackedMat, tweak: impl FnOnce(&mut Vec<u64>)) {
        w.put_usize(p.rows);
        w.put_usize(p.cols);
        match p.scheme {
            PackScheme::MxintBlock { bits, block } => {
                w.put_u8(0);
                w.put_u32(bits);
                w.put_usize(block);
            }
            PackScheme::UniformGroup { bits, group, symmetric } => {
                w.put_u8(1);
                w.put_u32(bits);
                w.put_usize(group);
                w.put_bool(symmetric);
            }
            PackScheme::GptqGrouped { bits, group } => {
                w.put_u8(2);
                w.put_u32(bits);
                w.put_usize(group);
            }
        }
        w.put_u32(p.codes.bits);
        w.put_usize(p.codes.len);
        let mut words = p.codes.words().to_vec();
        tweak(&mut words);
        w.put_u64s(&words);
        w.put_f32s(&p.scales);
        w.put_f32s(&p.los);
    }
}
