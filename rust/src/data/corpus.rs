//! Zipf-Markov synthetic corpus.
//!
//! Token t+1 is drawn from a sparse per-token transition table whose
//! support follows a Zipf law, mixed with a global Zipf unigram floor.
//! The result has (i) skewed marginals, (ii) strong local predictability
//! — so a small trained LM reaches a PPL well below vocab size, leaving
//! visible headroom for quantization to damage and QER/SRR to recover,
//! exactly the dynamic the paper's Table 1 measures.

use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct Corpus {
    pub vocab: usize,
    pub tokens: Vec<i32>,
    pub train_frac: f64,
}

impl Corpus {
    /// Generate `len` tokens over `vocab` symbols.
    pub fn generate(vocab: usize, len: usize, seed: u64) -> Corpus {
        let mut rng = Rng::new(seed);
        // global Zipf unigram weights
        let unigram: Vec<f64> = (0..vocab).map(|i| 1.0 / (1.0 + i as f64)).collect();
        // per-token sparse successors: each token prefers `fanout` others
        let fanout = 6usize.min(vocab);
        let successors: Vec<Vec<usize>> = (0..vocab)
            .map(|_| (0..fanout).map(|_| rng.below(vocab)).collect())
            .collect();

        let mut tokens = Vec::with_capacity(len);
        let mut cur = rng.below(vocab);
        for _ in 0..len {
            tokens.push(cur as i32);
            cur = if rng.uniform() < 0.75 {
                // Markov step: Zipf over the successor list
                let succ = &successors[cur];
                let w: Vec<f64> = (0..succ.len()).map(|i| 1.0 / (1.0 + i as f64)).collect();
                succ[rng.weighted(&w)]
            } else {
                rng.weighted(&unigram)
            };
        }
        Corpus { vocab, tokens, train_frac: 0.9 }
    }

    fn split_point(&self) -> usize {
        (self.tokens.len() as f64 * self.train_frac) as usize
    }

    /// A (b, t) token batch from the training split; `step` indexes
    /// deterministically so epochs are reproducible.
    pub fn train_batch(&self, b: usize, t: usize, step: usize) -> Vec<i32> {
        let end = self.split_point();
        self.window_batch(0, end, b, t, step)
    }

    /// Deterministic eval batches covering the held-out split.
    pub fn eval_batches(&self, b: usize, t: usize) -> Vec<Vec<i32>> {
        let start = self.split_point();
        let avail = self.tokens.len() - start;
        let per_batch = b * t;
        let n_batches = avail / per_batch;
        (0..n_batches)
            .map(|i| {
                let base = start + i * per_batch;
                self.tokens[base..base + per_batch].to_vec()
            })
            .collect()
    }

    fn window_batch(&self, lo: usize, hi: usize, b: usize, t: usize, step: usize) -> Vec<i32> {
        let span = hi - lo;
        assert!(span >= t, "corpus split shorter than seq len");
        let mut out = Vec::with_capacity(b * t);
        for bi in 0..b {
            // stride through the split pseudo-randomly but deterministically
            let offset = lo + ((step * b + bi) * 7919 + bi * 104729) % (span - t);
            out.extend_from_slice(&self.tokens[offset..offset + t]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_range_and_deterministic() {
        let c = Corpus::generate(64, 5000, 42);
        assert!(c.tokens.iter().all(|&t| (0..64).contains(&(t as usize))));
        let c2 = Corpus::generate(64, 5000, 42);
        assert_eq!(c.tokens, c2.tokens);
    }

    #[test]
    fn marginals_are_skewed() {
        let c = Corpus::generate(64, 20000, 1);
        let mut counts = vec![0usize; 64];
        for &t in &c.tokens {
            counts[t as usize] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        // head token much more frequent than tail
        assert!(counts[0] > counts[40] * 3, "head {} tail {}", counts[0], counts[40]);
    }

    #[test]
    fn corpus_is_predictable_markov() {
        // bigram entropy must be well below unigram entropy
        let c = Corpus::generate(32, 30000, 2);
        let mut uni = vec![0f64; 32];
        let mut bi = std::collections::HashMap::new();
        for w in c.tokens.windows(2) {
            uni[w[0] as usize] += 1.0;
            *bi.entry((w[0], w[1])).or_insert(0f64) += 1.0;
        }
        let n: f64 = uni.iter().sum();
        let h_uni: f64 = uni.iter().filter(|&&x| x > 0.0).map(|x| -(x / n) * (x / n).ln()).sum();
        let mut h_bi = 0.0;
        for (&(a, _), &cnt) in &bi {
            let p_joint = cnt / n;
            let p_cond = cnt / uni[a as usize];
            h_bi -= p_joint * p_cond.ln();
        }
        assert!(h_bi < h_uni * 0.8, "h_bi={h_bi} h_uni={h_uni}");
    }

    #[test]
    fn batches_have_right_shape_and_split_is_disjoint() {
        let c = Corpus::generate(64, 10000, 3);
        let tb = c.train_batch(4, 16, 0);
        assert_eq!(tb.len(), 64);
        let eb = c.eval_batches(4, 16);
        assert!(!eb.is_empty());
        assert!(eb.iter().all(|b| b.len() == 64));
        // different steps give different batches
        assert_ne!(c.train_batch(4, 16, 0), c.train_batch(4, 16, 1));
    }
}
