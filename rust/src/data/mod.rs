//! Synthetic data standing in for the paper's gated assets (DESIGN.md §2):
//!
//! * [`corpus`] — Zipf-weighted Markov token corpus (WikiText2 /
//!   SlimPajama substitute) with train/eval splits and batching.
//! * [`glue_sim`] — eight GLUE-like classification/regression tasks of
//!   graded difficulty (incl. a CoLA analog scored by Matthews corr. and
//!   an STSB analog scored by Pearson/Spearman).
//! * [`gsm_sim`] — modular-arithmetic reasoning sequences (GSM8K
//!   substitute) scored by exact match on the answer digits.
//! * [`zeroshot`] — five option-ranking probe tasks (HellaSwag…BBH
//!   substitute) scored by per-option sequence log-likelihood.

pub mod corpus;
pub mod glue_sim;
pub mod gsm_sim;
pub mod zeroshot;

pub use corpus::Corpus;
pub use glue_sim::{GlueTask, GlueExample, Metric};
pub use gsm_sim::GsmSim;
pub use zeroshot::ZeroShotTask;
