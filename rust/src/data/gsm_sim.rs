//! GSM-sim: modular-arithmetic reasoning sequences (GSM8K substitute).
//!
//! Each example encodes `a ⊕ b = c (mod base)` as a token sequence with a
//! dedicated operator/equals alphabet and the answer digits at fixed tail
//! positions. Exact-match accuracy over the answer digits is the metric,
//! scored teacher-forced (argmax at answer positions) — the standard
//! cheap proxy for greedy decode on deterministic-answer tasks.

use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct GsmExample {
    pub tokens: Vec<i32>,
    /// positions (within the sequence) holding the answer digits
    pub answer_positions: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct GsmSim {
    pub vocab: usize,
    pub seq: usize,
    pub base: usize,
    pub train: Vec<GsmExample>,
    pub test: Vec<GsmExample>,
}

impl GsmSim {
    /// Token layout (seq ≥ 10):
    /// [BOS, a1, a0, OP, b1, b0, EQ, c1, c0, PAD…] with digits in [0, base).
    pub fn generate(vocab: usize, seq: usize, n_train: usize, n_test: usize, seed: u64) -> GsmSim {
        assert!(seq >= 10);
        let base = 10.min(vocab.saturating_sub(4)).max(2);
        let bos = (base) as i32;
        let op_add = (base + 1) as i32;
        let op_mul = (base + 2) as i32;
        let eq = (base + 3) as i32;
        let mut rng = Rng::new(seed);
        let gen = |n: usize, rng: &mut Rng| {
            (0..n)
                .map(|_| {
                    let a = rng.below(base * base);
                    let b = rng.below(base * base);
                    let mul = rng.uniform() < 0.5;
                    let c = if mul { (a * b) % (base * base) } else { (a + b) % (base * base) };
                    let mut tokens = vec![
                        bos,
                        (a / base) as i32,
                        (a % base) as i32,
                        if mul { op_mul } else { op_add },
                        (b / base) as i32,
                        (b % base) as i32,
                        eq,
                        (c / base) as i32,
                        (c % base) as i32,
                    ];
                    tokens.resize(seq, bos);
                    GsmExample { tokens, answer_positions: vec![7, 8] }
                })
                .collect()
        };
        GsmSim {
            vocab,
            seq,
            base,
            train: gen(n_train, &mut rng),
            test: gen(n_test, &mut rng),
        }
    }

    /// Exact match: all answer digits correct for an example.
    pub fn exact_match(example: &GsmExample, predicted: &[i32]) -> bool {
        example
            .answer_positions
            .iter()
            .all(|&p| predicted[p] == example.tokens[p])
    }

    pub fn batch(examples: &[GsmExample], i0: usize, b: usize) -> Vec<i32> {
        let mut out = Vec::new();
        for k in 0..b {
            out.extend_from_slice(&examples[(i0 + k) % examples.len()].tokens);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic_is_consistent() {
        let g = GsmSim::generate(64, 16, 100, 50, 1);
        for ex in g.train.iter().chain(&g.test) {
            let a = ex.tokens[1] as usize * g.base + ex.tokens[2] as usize;
            let b = ex.tokens[4] as usize * g.base + ex.tokens[5] as usize;
            let c = ex.tokens[7] as usize * g.base + ex.tokens[8] as usize;
            let mul = ex.tokens[3] as usize == g.base + 2;
            let want = if mul { (a * b) % (g.base * g.base) } else { (a + b) % (g.base * g.base) };
            assert_eq!(c, want);
        }
    }

    #[test]
    fn exact_match_logic() {
        let g = GsmSim::generate(64, 16, 1, 1, 2);
        let ex = &g.train[0];
        assert!(GsmSim::exact_match(ex, &ex.tokens));
        let mut wrong = ex.tokens.clone();
        wrong[7] = (wrong[7] + 1) % g.base as i32;
        assert!(!GsmSim::exact_match(ex, &wrong));
    }

    #[test]
    fn tokens_within_vocab() {
        let g = GsmSim::generate(32, 12, 50, 10, 3);
        for ex in &g.train {
            assert!(ex.tokens.iter().all(|&t| (t as usize) < g.vocab));
            assert_eq!(ex.tokens.len(), 12);
        }
    }
}
