//! GLUE-sim: eight synthetic sentence-classification tasks of graded
//! difficulty, mirroring the paper's GLUE table structure (Table 3):
//! accuracy tasks, a Matthews-scored acceptability task (CoLA analog),
//! and a regression task scored by Pearson/Spearman (STSB analog).
//!
//! Each task plants a learnable pattern in token sequences plus label
//! noise; difficulty (pattern strength, noise) varies so fine-tuning
//! quality spreads across tasks like the real benchmark.

use crate::util::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    Accuracy,
    Matthews,
    PearsonSpearman,
}

#[derive(Clone, Debug)]
pub struct GlueExample {
    pub tokens: Vec<i32>,
    pub label: usize,
    /// regression target for the STSB analog
    pub target: f32,
}

#[derive(Clone, Debug)]
pub struct GlueTask {
    pub name: &'static str,
    pub metric: Metric,
    pub n_classes: usize,
    pub train: Vec<GlueExample>,
    pub dev: Vec<GlueExample>,
}

/// The eight tasks: (name, metric, classes, pattern strength, label noise).
pub const TASK_SPECS: [(&str, Metric, usize, f32, f32); 8] = [
    ("MNLI-sim", Metric::Accuracy, 3, 0.80, 0.08),
    ("QNLI-sim", Metric::Accuracy, 2, 0.85, 0.06),
    ("RTE-sim", Metric::Accuracy, 2, 0.55, 0.18),
    ("SST-sim", Metric::Accuracy, 2, 0.90, 0.04),
    ("MRPC-sim", Metric::Accuracy, 2, 0.70, 0.10),
    ("CoLA-sim", Metric::Matthews, 2, 0.60, 0.15),
    ("QQP-sim", Metric::Accuracy, 2, 0.85, 0.05),
    ("STSB-sim", Metric::PearsonSpearman, 1, 0.85, 0.08),
];

impl GlueTask {
    /// Generate all eight tasks for a given vocab / sequence length.
    pub fn all(vocab: usize, seq: usize, n_train: usize, n_dev: usize, seed: u64) -> Vec<GlueTask> {
        TASK_SPECS
            .iter()
            .enumerate()
            .map(|(i, &(name, metric, classes, strength, noise))| {
                let mut rng = Rng::new(seed ^ (i as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15));
                let gen = |n: usize, rng: &mut Rng| {
                    (0..n)
                        .map(|_| gen_example(vocab, seq, classes, strength, noise, metric, rng))
                        .collect()
                };
                GlueTask {
                    name,
                    metric,
                    n_classes: classes,
                    train: gen(n_train, &mut rng),
                    dev: gen(n_dev, &mut rng),
                }
            })
            .collect()
    }

    /// Pack examples [i0, i1) into (tokens, int labels, float targets),
    /// cycling if the range exceeds the set.
    pub fn batch(
        examples: &[GlueExample],
        i0: usize,
        batch: usize,
        seq: usize,
    ) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
        let mut toks = Vec::with_capacity(batch * seq);
        let mut labels = Vec::with_capacity(batch);
        let mut targets = Vec::with_capacity(batch);
        for k in 0..batch {
            let ex = &examples[(i0 + k) % examples.len()];
            toks.extend_from_slice(&ex.tokens);
            labels.push(ex.label as i32);
            targets.push(ex.target);
        }
        (toks, labels, targets)
    }
}

/// Plant class-dependent token statistics:
/// * class c biases tokens toward the band [c·vocab/C, (c+1)·vocab/C)
///   with probability `strength`, else uniform;
/// * the STSB analog's target is the (noisy) fraction of in-band tokens.
fn gen_example(
    vocab: usize,
    seq: usize,
    n_classes: usize,
    strength: f32,
    noise: f32,
    metric: Metric,
    rng: &mut Rng,
) -> GlueExample {
    if metric == Metric::PearsonSpearman {
        // regression: similarity = overlap between two halves
        let half = seq / 2;
        let base: Vec<i32> = (0..half).map(|_| rng.below(vocab) as i32).collect();
        let sim = rng.uniform() as f32; // target in [0,1]
        let mut second = Vec::with_capacity(seq - half);
        for i in 0..(seq - half) {
            if (rng.uniform() as f32) < sim {
                second.push(base[i % half]);
            } else {
                second.push(rng.below(vocab) as i32);
            }
        }
        let mut tokens = base;
        tokens.extend(second);
        let target = (sim + (rng.normal() as f32) * noise).clamp(0.0, 1.0);
        return GlueExample { tokens, label: 0, target };
    }

    let label = rng.below(n_classes);
    let band = vocab / n_classes;
    let lo = label * band;
    let tokens: Vec<i32> = (0..seq)
        .map(|_| {
            if (rng.uniform() as f32) < strength {
                (lo + rng.below(band)) as i32
            } else {
                rng.below(vocab) as i32
            }
        })
        .collect();
    // label noise: flip with probability `noise`
    let observed = if (rng.uniform() as f32) < noise {
        rng.below(n_classes)
    } else {
        label
    };
    GlueExample { tokens, label: observed, target: observed as f32 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_eight_tasks_with_expected_metrics() {
        let tasks = GlueTask::all(256, 32, 64, 32, 1);
        assert_eq!(tasks.len(), 8);
        assert_eq!(tasks.iter().filter(|t| t.metric == Metric::Matthews).count(), 1);
        assert_eq!(
            tasks.iter().filter(|t| t.metric == Metric::PearsonSpearman).count(),
            1
        );
        for t in &tasks {
            assert_eq!(t.train.len(), 64);
            assert_eq!(t.dev.len(), 32);
            for ex in t.train.iter().chain(&t.dev) {
                assert_eq!(ex.tokens.len(), 32);
                assert!(ex.label < t.n_classes.max(2));
            }
        }
    }

    #[test]
    fn classification_pattern_is_learnable_by_band_statistic() {
        // a trivial band-count classifier must beat chance on a strong task
        let tasks = GlueTask::all(256, 32, 0, 400, 2);
        let sst = tasks.iter().find(|t| t.name == "SST-sim").unwrap();
        let mut correct = 0;
        for ex in &sst.dev {
            let band = 256 / 2;
            let votes0 = ex.tokens.iter().filter(|&&t| (t as usize) < band).count();
            let pred = if votes0 * 2 > ex.tokens.len() { 0 } else { 1 };
            if pred == ex.label {
                correct += 1;
            }
        }
        let acc = correct as f64 / sst.dev.len() as f64;
        assert!(acc > 0.85, "band statistic should solve SST-sim, acc={acc}");
    }

    #[test]
    fn stsb_targets_correlate_with_overlap() {
        let tasks = GlueTask::all(256, 32, 0, 300, 3);
        let stsb = tasks.iter().find(|t| t.name == "STSB-sim").unwrap();
        let mut overlaps = vec![];
        let mut targets = vec![];
        for ex in &stsb.dev {
            let half = 16;
            let shared = ex.tokens[half..]
                .iter()
                .enumerate()
                .filter(|(i, &t)| ex.tokens[i % half] == t)
                .count();
            overlaps.push(shared as f64 / half as f64);
            targets.push(ex.target as f64);
        }
        let r = crate::util::stats::pearson(&overlaps, &targets);
        assert!(r > 0.6, "overlap/target correlation too weak: {r}");
    }

    #[test]
    fn batch_cycles_and_shapes() {
        let tasks = GlueTask::all(64, 16, 10, 5, 4);
        let (t, l, tg) = GlueTask::batch(&tasks[0].train, 8, 4, 16);
        assert_eq!(t.len(), 64);
        assert_eq!(l.len(), 4);
        assert_eq!(tg.len(), 4);
    }

    #[test]
    fn difficulty_ordering_sst_easier_than_rte() {
        // noisier task ⇒ weaker band statistic
        let tasks = GlueTask::all(256, 32, 0, 400, 5);
        let acc_of = |name: &str| {
            let t = tasks.iter().find(|t| t.name == name).unwrap();
            let band = 256 / t.n_classes;
            let mut ok = 0;
            for ex in &t.dev {
                let mut counts = vec![0usize; t.n_classes];
                for &tok in &ex.tokens {
                    counts[(tok as usize / band).min(t.n_classes - 1)] += 1;
                }
                let pred = counts
                    .iter()
                    .enumerate()
                    .max_by_key(|(_, &c)| c)
                    .unwrap()
                    .0;
                if pred == ex.label {
                    ok += 1;
                }
            }
            ok as f64 / t.dev.len() as f64
        };
        assert!(acc_of("SST-sim") > acc_of("RTE-sim") + 0.05);
    }
}
