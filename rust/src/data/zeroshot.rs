//! Zero-shot probe tasks (the five downstream benchmarks' substitute).
//!
//! Protocol mirrors lm-eval: each example is a context plus N candidate
//! continuations; the model scores each continuation's token
//! log-likelihood given the context, and the argmin-NLL option is the
//! prediction. The correct continuation is drawn from the same Markov
//! corpus process that trained the model; distractors break the Markov
//! statistics with increasing subtlety per task (graded difficulty, like
//! HellaSwag → BBH).

use crate::data::corpus::Corpus;
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct ZeroShotExample {
    /// full sequences (context ++ option), one per option, padded to seq
    pub options: Vec<Vec<i32>>,
    /// mask: 1.0 on continuation positions (these are scored)
    pub masks: Vec<Vec<f32>>,
    pub correct: usize,
}

#[derive(Clone, Debug)]
pub struct ZeroShotTask {
    pub name: &'static str,
    pub examples: Vec<ZeroShotExample>,
}

pub const TASK_NAMES: [&str; 5] =
    ["hellaswag-sim", "winogrande-sim", "boolq-sim", "mmlu-sim", "bbh-sim"];

impl ZeroShotTask {
    /// Build all five probe tasks from a corpus. `seq` must match the LM
    /// artifact's sequence length; contexts take the first 3/4.
    pub fn all(corpus: &Corpus, seq: usize, n_examples: usize, seed: u64) -> Vec<ZeroShotTask> {
        TASK_NAMES
            .iter()
            .enumerate()
            .map(|(ti, name)| {
                let mut rng = Rng::new(seed ^ ((ti as u64 + 1) * 0x5851F42D4C957F2D));
                // task difficulty: how much distractors resemble the corpus
                let corruption = [0.35, 0.25, 0.18, 0.12, 0.08][ti];
                let examples = (0..n_examples)
                    .map(|_| gen_example(corpus, seq, corruption, &mut rng))
                    .collect();
                ZeroShotTask { name, examples }
            })
            .collect()
    }
}

fn gen_example(corpus: &Corpus, seq: usize, corruption: f64, rng: &mut Rng) -> ZeroShotExample {
    let ctx_len = seq * 3 / 4;
    let cont_len = seq - ctx_len;
    let n_options = 4;
    // pick a real span: context + true continuation
    let max_start = corpus.tokens.len() - seq - 1;
    let start = rng.below(max_start);
    let span = &corpus.tokens[start..start + seq];
    let correct = rng.below(n_options);

    let mut options = Vec::with_capacity(n_options);
    let mut masks = Vec::with_capacity(n_options);
    for opt in 0..n_options {
        let mut tokens = span[..ctx_len].to_vec();
        if opt == correct {
            tokens.extend_from_slice(&span[ctx_len..]);
        } else {
            // distractor: corrupt a fraction of the true continuation
            for (i, &t) in span[ctx_len..].iter().enumerate() {
                let _ = i;
                if rng.uniform() < corruption {
                    tokens.push(rng.below(corpus.vocab) as i32);
                } else {
                    tokens.push(t);
                }
            }
        }
        let mut mask = vec![0.0f32; seq];
        for m in mask.iter_mut().skip(ctx_len) {
            *m = 1.0;
        }
        options.push(tokens);
        masks.push(mask);
        let _ = cont_len;
    }
    ZeroShotExample { options, masks, correct }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_five_tasks_with_valid_examples() {
        let corpus = Corpus::generate(64, 20_000, 7);
        let tasks = ZeroShotTask::all(&corpus, 32, 10, 1);
        assert_eq!(tasks.len(), 5);
        for t in &tasks {
            assert_eq!(t.examples.len(), 10);
            for ex in &t.examples {
                assert_eq!(ex.options.len(), 4);
                assert!(ex.correct < 4);
                for (o, m) in ex.options.iter().zip(&ex.masks) {
                    assert_eq!(o.len(), 32);
                    assert_eq!(m.len(), 32);
                    // context unmasked, continuation masked
                    assert_eq!(m[..24].iter().sum::<f32>(), 0.0);
                    assert_eq!(m[24..].iter().sum::<f32>(), 8.0);
                }
            }
        }
    }

    #[test]
    fn context_is_shared_across_options() {
        let corpus = Corpus::generate(64, 20_000, 8);
        let tasks = ZeroShotTask::all(&corpus, 32, 5, 2);
        for ex in &tasks[0].examples {
            let ctx = &ex.options[0][..24];
            for o in &ex.options[1..] {
                assert_eq!(&o[..24], ctx);
            }
        }
    }

    #[test]
    fn correct_option_preserves_corpus_statistics() {
        // the true continuation equals the original span; distractors differ
        let corpus = Corpus::generate(64, 20_000, 9);
        let tasks = ZeroShotTask::all(&corpus, 32, 30, 3);
        let mut differs = 0;
        for ex in &tasks[0].examples {
            for (i, o) in ex.options.iter().enumerate() {
                if i != ex.correct && o[24..] != ex.options[ex.correct][24..] {
                    differs += 1;
                }
            }
        }
        assert!(differs > 60, "distractors should usually differ, got {differs}/90");
    }
}
