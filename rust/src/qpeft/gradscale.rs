//! Gradient scaling on the preserved top-k\* directions (paper §4.4).
//!
//! * Fixed γ (Eq. 7): attenuate the gradients of the preserved block —
//!   columns `0..k*` of ∇L and rows `0..k*` of ∇R — by γ ∈ (0, 1); the
//!   residual directions are untouched.
//! * SGP (Eq. 8–9, Saha & Roy 2023): rank-wise scaling
//!   λ_i = (α+1)σ_i / (ασ_i + σ_1), factor (1 − λ_i), with σ_i the
//!   current magnitude of preserved direction i (‖R row i‖ — L's columns
//!   stay ~orthonormal from the SVD init).

use crate::tensor::Mat;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GradScale {
    None,
    Fixed { gamma: f32 },
    Sgp { alpha: f32 },
}

impl GradScale {
    pub fn label(&self) -> String {
        match self {
            GradScale::None => "γ=1".into(),
            GradScale::Fixed { gamma } => format!("γ={gamma}"),
            GradScale::Sgp { alpha } => format!("SGP(α={alpha})"),
        }
    }

    /// Scale ∇L / ∇R in place for one adapter with preserved rank `k`.
    /// `r_current` supplies σ_i for SGP (the adapter's current R factor).
    pub fn apply(&self, k: usize, grad_l: &mut Mat, grad_r: &mut Mat, r_current: &Mat) {
        if k == 0 {
            return;
        }
        match *self {
            GradScale::None => {}
            GradScale::Fixed { gamma } => {
                scale_block(grad_l, grad_r, k, |_| gamma);
            }
            GradScale::Sgp { alpha } => {
                let sigma: Vec<f32> = (0..k)
                    .map(|i| {
                        r_current.row(i).iter().map(|&x| (x * x) as f64).sum::<f64>().sqrt() as f32
                    })
                    .collect();
                let s1 = sigma.iter().cloned().fold(0.0f32, f32::max).max(1e-12);
                scale_block(grad_l, grad_r, k, |i| {
                    let si = sigma[i];
                    let lambda = (alpha + 1.0) * si / (alpha * si + s1);
                    (1.0 - lambda).max(0.0)
                });
            }
        }
    }
}

fn scale_block(grad_l: &mut Mat, grad_r: &mut Mat, k: usize, factor: impl Fn(usize) -> f32) {
    let k = k.min(grad_l.cols).min(grad_r.rows);
    for i in 0..grad_l.rows {
        let row = grad_l.row_mut(i);
        for (j, v) in row.iter_mut().enumerate().take(k) {
            *v *= factor(j);
        }
    }
    for i in 0..k {
        let f = factor(i);
        for v in grad_r.row_mut(i) {
            *v *= f;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn grads(rng: &mut Rng) -> (Mat, Mat) {
        (Mat::randn(8, 6, 1.0, rng), Mat::randn(6, 10, 1.0, rng))
    }

    #[test]
    fn fixed_gamma_scales_only_preserved_block() {
        let mut rng = Rng::new(1);
        let (gl0, gr0) = grads(&mut rng);
        let (mut gl, mut gr) = (gl0.clone(), gr0.clone());
        let rcur = Mat::zeros(6, 10);
        GradScale::Fixed { gamma: 0.1 }.apply(2, &mut gl, &mut gr, &rcur);
        for i in 0..8 {
            for j in 0..6 {
                let want = if j < 2 { gl0.at(i, j) * 0.1 } else { gl0.at(i, j) };
                assert!((gl.at(i, j) - want).abs() < 1e-6);
            }
        }
        for i in 0..6 {
            for j in 0..10 {
                let want = if i < 2 { gr0.at(i, j) * 0.1 } else { gr0.at(i, j) };
                assert!((gr.at(i, j) - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn gamma_one_equals_none() {
        let mut rng = Rng::new(2);
        let (gl0, gr0) = grads(&mut rng);
        let (mut gl, mut gr) = (gl0.clone(), gr0.clone());
        let rcur = Mat::zeros(6, 10);
        GradScale::Fixed { gamma: 1.0 }.apply(3, &mut gl, &mut gr, &rcur);
        assert_eq!(gl, gl0);
        assert_eq!(gr, gr0);
    }

    #[test]
    fn k_zero_is_noop_for_all_modes() {
        let mut rng = Rng::new(3);
        for scale in [GradScale::Fixed { gamma: 0.0 }, GradScale::Sgp { alpha: 5.0 }] {
            let (gl0, gr0) = grads(&mut rng);
            let (mut gl, mut gr) = (gl0.clone(), gr0.clone());
            scale.apply(0, &mut gl, &mut gr, &gr0);
            assert_eq!(gl, gl0);
            assert_eq!(gr, gr0);
        }
    }

    #[test]
    fn sgp_attenuates_dominant_direction_most() {
        let mut rng = Rng::new(4);
        let (gl0, gr0) = grads(&mut rng);
        let (mut gl, mut gr) = (gl0.clone(), gr0.clone());
        // R with row 0 large (σ1), row 1 small
        let mut rcur = Mat::zeros(6, 10);
        for v in rcur.row_mut(0) {
            *v = 5.0;
        }
        for v in rcur.row_mut(1) {
            *v = 0.5;
        }
        GradScale::Sgp { alpha: 5.0 }.apply(2, &mut gl, &mut gr, &rcur);
        // σ_1 = σ_max: λ = 1 → factor 0; σ small: λ < 1 → factor > 0
        let f0 = gr.at(0, 0) / gr0.at(0, 0);
        let f1 = gr.at(1, 0) / gr0.at(1, 0);
        assert!(f0.abs() < 1e-6, "dominant direction should be fully attenuated, f0={f0}");
        assert!(f1 > 0.05 && f1 < 1.0, "weak direction partially attenuated, f1={f1}");
    }
}
