//! QPEFT parameter state: frozen backbone + trainable adapters/head, laid
//! out exactly like the `qpeft_*` artifacts' positional signature:
//!
//!   frozen:    embed, per-layer {ln1, Qdeq(wq,wk,wv,wo), ln2,
//!              Qdeq(gate,up,down)}, norm_f
//!   trainable: (L, R) per linear (linear_names order), head
//!   data:      tokens [, labels]
//!
//! The frozen linears ride as [`FrozenTensor::Packed`] bases when the
//! quantizer has a packed format — the trainer holds the factored base
//! between steps (4–8× smaller at 2–4 bits) and dequantizes only while
//! marshalling an artifact call.

use std::sync::Arc;

use crate::model::Params;
use crate::quant::PackedMat;
use crate::runtime::manifest::ModelCfg;
use crate::runtime::TensorValue;
use crate::tensor::Mat;

/// One linear's adapter pair with its preserved-rank annotation.
#[derive(Clone, Debug)]
pub struct AdapterEntry {
    pub name: String,
    pub l: Mat,
    pub r: Mat,
    /// leading columns of `l` / rows of `r` spanning the preserved
    /// subspace (0 for non-SRR inits)
    pub k_star: usize,
}

/// One frozen backbone tensor: dense, or a packed quantized linear base
/// dequantized only at artifact-marshal time. Packed bases ride behind
/// an [`Arc`], so freezing a sweep outcome shares the buffer the serving
/// layer (and the fleet evaluator) already hold — no copy at init.
#[derive(Clone, Debug)]
pub enum FrozenTensor {
    /// a dense (unquantized or densified) tensor
    Dense(TensorValue),
    /// a bit-packed quantized base, shared with its producer
    Packed(Arc<PackedMat>),
}

impl FrozenTensor {
    pub fn to_tensor(&self) -> TensorValue {
        match self {
            FrozenTensor::Dense(t) => t.clone(),
            FrozenTensor::Packed(p) => TensorValue::from_mat(&p.dequantize()),
        }
    }

    pub fn to_mat(&self) -> Mat {
        match self {
            FrozenTensor::Dense(t) => t.to_mat(),
            FrozenTensor::Packed(p) => p.dequantize(),
        }
    }

    /// Resident bytes of this entry.
    pub fn bytes(&self) -> usize {
        match self {
            FrozenTensor::Dense(t) => t.len() * 4,
            FrozenTensor::Packed(p) => p.bytes(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct QpeftState {
    /// frozen args in artifact order (embed, ln/Qdeq interleaved, norm_f)
    pub frozen: Vec<FrozenTensor>,
    pub adapters: Vec<AdapterEntry>,
    pub head: Mat,
}

impl QpeftState {
    /// Frozen arg ordering for `cfg`: all params except `head`, with the
    /// linears holding their dequantized Qdeq (all dense — the packed
    /// entries come from `init_qpeft` / `init_qpeft_factored`).
    pub fn frozen_from_params(params: &Params, cfg: &ModelCfg) -> Vec<FrozenTensor> {
        Params::param_order(cfg)
            .iter()
            .filter(|n| n.as_str() != "head")
            .map(|n| FrozenTensor::Dense(params.get(n).expect("param").clone()))
            .collect()
    }

    /// Resident bytes of the frozen backbone (the factored-base memory win).
    pub fn frozen_bytes(&self) -> usize {
        self.frozen.iter().map(|f| f.bytes()).sum()
    }

    /// Trainable tensors in artifact order: L0, R0, L1, R1, …, head.
    pub fn trainable_mats(&self) -> Vec<&Mat> {
        let mut out = Vec::with_capacity(self.adapters.len() * 2 + 1);
        for a in &self.adapters {
            out.push(&a.l);
            out.push(&a.r);
        }
        out.push(&self.head);
        out
    }

    pub fn trainable_mats_mut(&mut self) -> Vec<&mut Mat> {
        let mut out = Vec::with_capacity(self.adapters.len() * 2 + 1);
        for a in &mut self.adapters {
            out.push(&mut a.l);
            out.push(&mut a.r);
        }
        out.push(&mut self.head);
        out
    }

    /// Full positional argument list for a train/fwd artifact call.
    /// Packed frozen bases dequantize here, transiently.
    pub fn artifact_inputs(&self, data: &[TensorValue]) -> Vec<TensorValue> {
        let mut inputs: Vec<TensorValue> = self.frozen.iter().map(|f| f.to_tensor()).collect();
        for a in &self.adapters {
            inputs.push(TensorValue::from_mat(&a.l));
            inputs.push(TensorValue::from_mat(&a.r));
        }
        inputs.push(TensorValue::from_mat(&self.head));
        inputs.extend_from_slice(data);
        inputs
    }

    pub fn rank(&self) -> usize {
        self.adapters.first().map(|a| a.l.cols).unwrap_or(0)
    }

    /// Trainable parameter count (the "adapter budget" reported in logs).
    pub fn trainable_count(&self) -> usize {
        self.trainable_mats().iter().map(|m| m.data.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synth::synth_lm_params;

    fn cfg() -> ModelCfg {
        ModelCfg {
            name: "t".into(),
            vocab: 32,
            d_model: 16,
            n_heads: 2,
            n_layers: 1,
            d_ff: 24,
            seq_len: 8,
        }
    }

    fn toy_state(c: &ModelCfg, rank: usize) -> QpeftState {
        let params = synth_lm_params(c, 1, c.vocab);
        let frozen = QpeftState::frozen_from_params(&params, c);
        let adapters = Params::linear_names(c)
            .into_iter()
            .map(|name| {
                let shape = Params::param_shape(&name, c, c.vocab);
                AdapterEntry {
                    name,
                    l: Mat::zeros(shape[0], rank),
                    r: Mat::zeros(rank, shape[1]),
                    k_star: 2,
                }
            })
            .collect();
        QpeftState { frozen, adapters, head: Mat::zeros(c.d_model, 4) }
    }

    #[test]
    fn frozen_order_excludes_head() {
        let c = cfg();
        let st = toy_state(&c, 4);
        // 1 embed + 9 per layer + norm_f
        assert_eq!(st.frozen.len(), 1 + 9 + 1);
    }

    #[test]
    fn artifact_inputs_layout() {
        let c = cfg();
        let st = toy_state(&c, 4);
        let tokens = TensorValue::i32(vec![2, 8], vec![0; 16]);
        let labels = TensorValue::i32(vec![2], vec![0, 1]);
        let inputs = st.artifact_inputs(&[tokens, labels]);
        // frozen(11) + adapters(7*2) + head + tokens + labels
        assert_eq!(inputs.len(), 11 + 14 + 1 + 2);
        assert_eq!(st.rank(), 4);
        assert_eq!(st.trainable_mats().len(), 15);
    }

    #[test]
    fn trainable_count_explicit() {
        let c = cfg();
        let st = toy_state(&c, 4);
        let mut want = 0;
        for name in Params::linear_names(&c) {
            let s = Params::param_shape(&name, &c, c.vocab);
            want += s[0] * 4 + 4 * s[1];
        }
        want += 16 * 4; // head
        assert_eq!(st.trainable_count(), want);
    }
}
