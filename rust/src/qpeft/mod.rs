//! QPEFT: Quantized Parameter-Efficient Fine-Tuning (paper §4.4).
//!
//! The quantized backbone (Qdeq per linear + embeddings/norms) is frozen
//! and held *factored* — packed codes, not a densified copy (see
//! `state::FrozenTensor`; `init_qpeft_factored` feeds a PTQ serving
//! outcome straight in). The (L, R) adapters plus the task head train
//! through the AOT `qpeft_*_train_*` artifacts (jax.value_and_grad
//! lowered once), with the optimizer, gradient scaling on the preserved
//! directions (Eq. 7 / SGP Eq. 8–9) and the training loop all owned by
//! rust.
//!
//! * [`state`] — frozen + trainable tensors in artifact arg order.
//! * [`init`] — the initialization strategies under comparison:
//!   QLoRA / LoftQ / QERA / LQ-LoRA / **SRR** (Table 3's rows).
//! * [`optim`] — AdamW.
//! * [`gradscale`] — γ attenuation + SGP rank-wise scaling of the
//!   preserved top-k\* directions.
//! * [`trainer`] — the step/eval loop.

pub mod state;
pub mod init;
pub mod optim;
pub mod gradscale;
pub mod trainer;

pub use gradscale::GradScale;
pub use init::{init_qpeft, init_qpeft_factored, QpeftInit};
pub use optim::AdamW;
pub use state::{AdapterEntry, FrozenTensor, QpeftState};
pub use trainer::QpeftTrainer;
