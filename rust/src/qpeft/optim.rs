//! AdamW over a flat list of matrices (the trainable adapter tensors).

use crate::tensor::Mat;

pub struct AdamW {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    step: u64,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl AdamW {
    pub fn new(lr: f32, sizes: &[usize]) -> Self {
        AdamW {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.01,
            step: 0,
            m: sizes.iter().map(|&n| vec![0.0; n]).collect(),
            v: sizes.iter().map(|&n| vec![0.0; n]).collect(),
        }
    }

    pub fn for_mats(lr: f32, mats: &[&Mat]) -> Self {
        let sizes: Vec<usize> = mats.iter().map(|m| m.data.len()).collect();
        Self::new(lr, &sizes)
    }

    /// One decoupled-weight-decay Adam step.
    pub fn update(&mut self, params: &mut [&mut Mat], grads: &[&Mat]) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.m.len());
        self.step += 1;
        let b1t = 1.0 - self.beta1.powi(self.step as i32);
        let b2t = 1.0 - self.beta2.powi(self.step as i32);
        for ((p, g), (m, v)) in params
            .iter_mut()
            .zip(grads)
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            assert_eq!(p.data.len(), g.data.len(), "param/grad size mismatch");
            for i in 0..p.data.len() {
                let gi = g.data[i];
                m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * gi;
                v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * gi * gi;
                let mhat = m[i] / b1t;
                let vhat = v[i] / b2t;
                p.data[i] -=
                    self.lr * (mhat / (vhat.sqrt() + self.eps) + self.weight_decay * p.data[i]);
            }
        }
    }

    pub fn steps_taken(&self) -> u64 {
        self.step
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// AdamW must descend a simple quadratic: f(x) = ‖x − c‖².
    #[test]
    fn converges_on_quadratic() {
        let mut rng = Rng::new(1);
        let target = Mat::randn(4, 4, 1.0, &mut rng);
        let mut x = Mat::zeros(4, 4);
        let mut opt = AdamW::for_mats(0.05, &[&x]);
        opt.weight_decay = 0.0;
        for _ in 0..800 {
            let grad = x.sub(&target).scale(2.0);
            opt.update(&mut [&mut x], &[&grad]);
        }
        assert!(x.allclose(&target, 0.05), "did not converge");
        assert_eq!(opt.steps_taken(), 800);
    }

    #[test]
    fn weight_decay_shrinks_params_at_zero_grad() {
        let mut x = Mat::from_fn(2, 2, |_, _| 1.0);
        let zero = Mat::zeros(2, 2);
        let mut opt = AdamW::for_mats(0.1, &[&x]);
        let before = x.frob();
        for _ in 0..10 {
            opt.update(&mut [&mut x], &[&zero]);
        }
        assert!(x.frob() < before);
    }

    #[test]
    fn multiple_tensors_updated_independently() {
        let mut a = Mat::zeros(2, 2);
        let mut b = Mat::zeros(3, 3);
        let ga = Mat::from_fn(2, 2, |_, _| 1.0);
        let gb = Mat::zeros(3, 3);
        let mut opt = AdamW::for_mats(0.01, &[&a, &b]);
        opt.weight_decay = 0.0;
        opt.update(&mut [&mut a, &mut b], &[&ga, &gb]);
        assert!(a.data.iter().all(|&v| v < 0.0), "a moved against grad");
        assert!(b.data.iter().all(|&v| v == 0.0), "b should not move");
    }
}
