//! The QPEFT training loop: artifact-backed value-and-grad steps, with
//! rust-side gradient scaling and AdamW.

use anyhow::{anyhow, Result};

use crate::runtime::{Executor, TensorValue};
use crate::tensor::Mat;

use super::gradscale::GradScale;
use super::optim::AdamW;
use super::state::QpeftState;

pub struct QpeftTrainer<'a> {
    pub exec: &'a dyn Executor,
    pub train_artifact: String,
    pub state: QpeftState,
    pub opt: AdamW,
    pub scale: GradScale,
    pub losses: Vec<f32>,
}

impl<'a> QpeftTrainer<'a> {
    pub fn new(
        exec: &'a dyn Executor,
        train_artifact: &str,
        state: QpeftState,
        lr: f32,
        scale: GradScale,
    ) -> Self {
        let opt = AdamW::for_mats(lr, &state.trainable_mats());
        QpeftTrainer { exec, train_artifact: train_artifact.to_string(), state, opt, scale, losses: vec![] }
    }

    /// One optimization step. `data` = [tokens] or [tokens, labels].
    pub fn step(&mut self, data: &[TensorValue]) -> Result<f32> {
        let inputs = self.state.artifact_inputs(data);
        let outs = self.exec.run(&self.train_artifact, &inputs)?;
        let n_trainable = self.state.adapters.len() * 2 + 1;
        if outs.len() != 1 + n_trainable {
            return Err(anyhow!(
                "{}: expected loss + {n_trainable} grads, got {} outputs",
                self.train_artifact,
                outs.len()
            ));
        }
        let loss = outs[0].scalar();

        // grads arrive as (L, R) pairs then head; apply preserved-direction
        // scaling per adapter before the optimizer sees them.
        let mut grads: Vec<Mat> = outs[1..].iter().map(|t| t.to_mat()).collect();
        for (ai, a) in self.state.adapters.iter().enumerate() {
            let (gl_slice, gr_slice) = grads.split_at_mut(ai * 2 + 1);
            let gl = &mut gl_slice[ai * 2];
            let gr = &mut gr_slice[0];
            self.scale.apply(a.k_star, gl, gr, &a.r);
        }

        let grad_refs: Vec<&Mat> = grads.iter().collect();
        let mut params = self.state.trainable_mats_mut();
        self.opt.update(&mut params, &grad_refs);
        self.losses.push(loss);
        Ok(loss)
    }

    /// Forward through an eval artifact (e.g. `qpeft_cls_fwd_*`),
    /// returning its first output.
    pub fn eval(&self, fwd_artifact: &str, data: &[TensorValue]) -> Result<TensorValue> {
        let inputs = self.state.artifact_inputs(data);
        let outs = self.exec.run(fwd_artifact, &inputs)?;
        outs.into_iter().next().ok_or_else(|| anyhow!("no outputs"))
    }

    /// Smoothed final loss (mean of the last `window` steps).
    pub fn final_loss(&self, window: usize) -> f32 {
        if self.losses.is_empty() {
            return f32::NAN;
        }
        let w = window.min(self.losses.len());
        let tail = &self.losses[self.losses.len() - w..];
        tail.iter().sum::<f32>() / w as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qpeft::state::{AdapterEntry, FrozenTensor};
    use crate::runtime::MockExecutor;

    /// A synthetic "artifact": quadratic loss in the single adapter's
    /// (L, R) around a target product, with exact gradients. Verifies the
    /// full step loop (marshalling, grad pairing, scaling, optimizer).
    fn toy_state() -> QpeftState {
        QpeftState {
            frozen: vec![FrozenTensor::Dense(TensorValue::scalar_f32(0.0))],
            adapters: vec![AdapterEntry {
                name: "l0.wq".into(),
                l: Mat::from_fn(2, 1, |_, _| 0.5),
                r: Mat::from_fn(1, 2, |_, _| 0.5),
                k_star: 0,
            }],
            head: Mat::zeros(1, 1),
        }
    }

    fn toy_mock() -> MockExecutor {
        MockExecutor::empty().on("train", |ins| {
            // ins: frozen(1), L(2x1), R(1x2), head(1x1), tokens
            let l = ins[1].to_mat();
            let r = ins[2].to_mat();
            let prod = crate::tensor::matmul(&l, &r);
            let target = Mat::from_fn(2, 2, |_, _| 1.0);
            let diff = prod.sub(&target);
            let loss = (diff.frob2() as f32) * 0.5;
            // dL = diff · Rᵀ ; dR = Lᵀ · diff
            let gl = crate::tensor::matmul_nt(&diff, &r);
            let gr = crate::tensor::matmul_tn(&l, &diff);
            vec![
                TensorValue::scalar_f32(loss),
                TensorValue::from_mat(&gl),
                TensorValue::from_mat(&gr),
                TensorValue::from_mat(&Mat::zeros(1, 1)),
            ]
        })
    }

    #[test]
    fn training_reduces_loss() {
        let mock = toy_mock();
        let mut tr = QpeftTrainer::new(&mock, "train", toy_state(), 0.05, GradScale::None);
        let tokens = TensorValue::i32(vec![1], vec![0]);
        let first = tr.step(&[tokens.clone()]).unwrap();
        for _ in 0..200 {
            tr.step(&[tokens.clone()]).unwrap();
        }
        let last = tr.final_loss(10);
        assert!(last < first * 0.1, "loss {first} -> {last}");
        assert_eq!(mock.call_count("train"), 201);
    }

    #[test]
    fn gamma_zero_freezes_preserved_block() {
        let mock = toy_mock();
        let mut state = toy_state();
        state.adapters[0].k_star = 1; // whole rank-1 adapter preserved
        let l_before = state.adapters[0].l.clone();
        let mut tr =
            QpeftTrainer::new(&mock, "train", state, 0.05, GradScale::Fixed { gamma: 0.0 });
        let tokens = TensorValue::i32(vec![1], vec![0]);
        for _ in 0..10 {
            tr.step(&[tokens.clone()]).unwrap();
        }
        // AdamW weight decay still nudges, but gradient-driven motion is
        // gone: compare against an unfrozen run
        let moved_frozen = tr.state.adapters[0].l.sub(&l_before).frob();

        let mock2 = toy_mock();
        let mut tr2 = QpeftTrainer::new(&mock2, "train", toy_state(), 0.05, GradScale::None);
        for _ in 0..10 {
            tr2.step(&[tokens.clone()]).unwrap();
        }
        let moved_free = tr2.state.adapters[0].l.sub(&l_before).frob();
        assert!(
            moved_frozen < moved_free * 0.2,
            "frozen {moved_frozen} vs free {moved_free}"
        );
    }

    #[test]
    fn wrong_output_arity_is_an_error() {
        let mock = MockExecutor::empty().on("train", |_| vec![TensorValue::scalar_f32(1.0)]);
        let mut tr = QpeftTrainer::new(&mock, "train", toy_state(), 0.01, GradScale::None);
        assert!(tr.step(&[TensorValue::i32(vec![1], vec![0])]).is_err());
    }
}
