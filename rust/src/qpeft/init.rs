//! QPEFT initialization strategies (the rows of Tables 3–4).
//!
//! All strategies freeze the same backbone structure and produce the same
//! adapter shapes; they differ in how (Qdeq, L, R) are derived from W:
//!
//! * QLoRA   — Qdeq = quant(W); L ~ N(0, 0.02), R = 0 (LoRA A/B init,
//!             adapter starts at zero contribution).
//! * LoftQ   — iterative quant/SVD refinement in the *weight* space
//!             (S = I), 5 iterations.
//! * LQ-LoRA — same iterative scheme but in the activation-scaled space
//!             (the paper aligns its scaling with QERA-exact; §A.3).
//! * QERA    — one-shot residual reconstruction, exact scaling.
//! * SRR     — Algorithm 1 with k\* selection; the k\* annotation then
//!             drives gradient scaling during training.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::model::{CalibrationSet, Params};
use crate::qer::{reconstruct, Method, QerConfig, QerResult};
use crate::quant::{PackedMat, QuantCtx};
use crate::runtime::manifest::ModelCfg;
use crate::runtime::TensorValue;
use crate::scaling::ScalingKind;
use crate::serve::{LinearOp, QuantBase};
use crate::tensor::Mat;
use crate::util::Rng;

use super::state::{AdapterEntry, FrozenTensor, QpeftState};
use crate::coordinator::pipeline::{FactoredOutcome, QuantizerSpec};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QpeftInit {
    /// full-precision LoRA (no quantization; the 16-bit reference row)
    LoRA,
    QLoRA,
    LoftQ { iters: usize },
    LqLora { iters: usize },
    Qera,
    Srr,
}

impl QpeftInit {
    pub fn label(&self) -> String {
        match self {
            QpeftInit::LoRA => "LoRA".into(),
            QpeftInit::QLoRA => "QLoRA".into(),
            QpeftInit::LoftQ { .. } => "LoftQ".into(),
            QpeftInit::LqLora { .. } => "LQ-LoRA".into(),
            QpeftInit::Qera => "QERA".into(),
            QpeftInit::Srr => "SRR".into(),
        }
    }

    fn qer_config(&self, rank: usize, seed: u64) -> Option<QerConfig> {
        let (method, scaling) = match *self {
            QpeftInit::LoRA | QpeftInit::QLoRA => return None,
            QpeftInit::LoftQ { iters } => {
                (Method::IterativeLowRank { iters }, ScalingKind::Identity)
            }
            QpeftInit::LqLora { iters } => {
                (Method::IterativeLowRank { iters }, ScalingKind::Exact)
            }
            QpeftInit::Qera => (Method::Qer, ScalingKind::Exact),
            QpeftInit::Srr => (Method::QerSrr, ScalingKind::Exact),
        };
        let mut cfg = QerConfig::new(method, rank, scaling);
        cfg.seed = seed;
        Some(cfg)
    }
}

/// Build the full QPEFT state for a model.
///
/// `head_dim` is n_classes (cls), 1 (reg) or vocab (lm); the head is
/// initialized from the base model's head (fine-tuning convention).
/// Quantized backbones stay *factored*: when the quantizer packs, the
/// frozen Qdeq rides as bit-packed codes, not a densified copy.
pub fn init_qpeft(
    params: &Params,
    cfg: &ModelCfg,
    calib: &CalibrationSet,
    quantizer: QuantizerSpec,
    init: QpeftInit,
    rank: usize,
    head_init: Mat,
    seed: u64,
) -> QpeftState {
    let mut rng = Rng::new(seed ^ 0x51D3);
    let linears = Params::linear_names(cfg);
    let mut frozen_linears: BTreeMap<String, FrozenTensor> = BTreeMap::new();
    let mut adapters = Vec::with_capacity(linears.len());

    for name in &linears {
        let w = params.get_mat(name).expect("linear");
        let (frozen, l, r, k_star) = match init {
            QpeftInit::LoRA => {
                // no quantization: backbone keeps W, adapter starts at 0
                let l = Mat::randn(w.rows, rank, 0.02, &mut rng);
                let r = Mat::zeros(rank, w.cols);
                (FrozenTensor::Dense(TensorValue::from_mat(&w)), l, r, 0)
            }
            QpeftInit::QLoRA => {
                let q = quantizer.build();
                let qctx = calib.quant_ctx(name, quantizer.needs_hessian(), seed);
                let (qdeq, packed) = q.quantize_coded(&w, &qctx);
                let l = Mat::randn(w.rows, rank, 0.02, &mut rng);
                let r = Mat::zeros(rank, w.cols);
                (frozen_base(qdeq, packed.map(Arc::new)), l, r, 0)
            }
            _ => {
                let qcfg = init.qer_config(rank, seed ^ fx(name)).unwrap();
                let scaling = calib.scaling_for(name, qcfg.scaling_kind);
                let ctx: QuantCtx =
                    calib.quant_ctx(name, quantizer.needs_hessian(), seed ^ fx(name));
                let q = quantizer.build();
                let QerResult { qdeq, packed, l, r, k_star, .. } =
                    reconstruct(&w, q.as_ref(), &scaling, &ctx, &qcfg);
                let (l, r) = pad_rank(l, r, rank);
                (frozen_base(qdeq, packed), l, r, k_star)
            }
        };
        frozen_linears.insert(name.clone(), frozen);
        adapters.push(AdapterEntry { name: name.clone(), l, r, k_star });
    }

    QpeftState {
        frozen: frozen_in_order(cfg, &mut frozen_linears, |n| {
            FrozenTensor::Dense(params.get(n).expect("param").clone())
        }),
        adapters,
        head: head_init,
    }
}

/// Build QPEFT state straight from a factored PTQ outcome: the frozen
/// backbone keeps the packed bases (no densified copy anywhere) and the
/// adapters start from the outcome's (L, R) factors, zero-padded to
/// `rank`. Equivalent to the matching [`init_qpeft`] call, minus the
/// recomputation — the QPEFT-after-PTQ path reuses the serving model.
pub fn init_qpeft_factored(
    outcome: &FactoredOutcome,
    cfg: &ModelCfg,
    rank: usize,
    head_init: Mat,
) -> QpeftState {
    let mut frozen_linears: BTreeMap<String, FrozenTensor> = BTreeMap::new();
    let mut adapters = Vec::with_capacity(outcome.model.ops.len());
    for ((name, op), meta) in outcome.model.ops.iter().zip(&outcome.meta) {
        debug_assert_eq!(name, &meta.name, "ops/meta misaligned");
        let (frozen, l, r) = match op {
            LinearOp::FactoredQlr { base, l, r } => {
                let f = match base {
                    QuantBase::Packed(p) => FrozenTensor::Packed(p.clone()),
                    QuantBase::Dense(q) => FrozenTensor::Dense(TensorValue::from_mat(q)),
                };
                (f, l.clone(), r.clone())
            }
            LinearOp::Dense(w) => (
                FrozenTensor::Dense(TensorValue::from_mat(w)),
                Mat::zeros(w.rows, 0),
                Mat::zeros(0, w.cols),
            ),
        };
        let (l, r) = pad_rank(l, r, rank);
        frozen_linears.insert(name.clone(), frozen);
        adapters.push(AdapterEntry { name: name.clone(), l, r, k_star: meta.k_star });
    }
    QpeftState {
        frozen: frozen_in_order(cfg, &mut frozen_linears, |n| {
            FrozenTensor::Dense(outcome.model.skeleton.get(n).expect("param").clone())
        }),
        adapters,
        head: head_init,
    }
}

fn frozen_base(qdeq: Mat, packed: Option<Arc<PackedMat>>) -> FrozenTensor {
    match packed {
        Some(p) => FrozenTensor::Packed(p),
        None => FrozenTensor::Dense(TensorValue::from_mat(&qdeq)),
    }
}

/// Assemble the frozen vec in artifact order: linears from `linears`,
/// everything else via `other`.
fn frozen_in_order(
    cfg: &ModelCfg,
    linears: &mut BTreeMap<String, FrozenTensor>,
    other: impl Fn(&str) -> FrozenTensor,
) -> Vec<FrozenTensor> {
    Params::param_order(cfg)
        .iter()
        .filter(|n| n.as_str() != "head")
        .map(|n| match linears.remove(n.as_str()) {
            Some(f) => f,
            None => other(n),
        })
        .collect()
}

/// Zero-pad (L, R) out to the artifact's fixed rank if a method returned
/// fewer columns.
fn pad_rank(l: Mat, r: Mat, rank: usize) -> (Mat, Mat) {
    if l.cols == rank {
        return (l, r);
    }
    assert!(l.cols < rank);
    let lpad = l.hcat(&Mat::zeros(l.rows, rank - l.cols));
    let rpad = r.vcat(&Mat::zeros(rank - r.rows, r.cols));
    (lpad, rpad)
}

fn fx(s: &str) -> u64 {
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Corpus;
    use crate::model::{collect_calibration, synth::synth_lm_params};
    use crate::tensor::matmul;

    fn setup() -> (Params, ModelCfg, CalibrationSet) {
        let cfg = ModelCfg {
            name: "t".into(),
            vocab: 64,
            d_model: 32,
            n_heads: 2,
            n_layers: 1,
            d_ff: 64,
            seq_len: 16,
        };
        let params = synth_lm_params(&cfg, 5, cfg.vocab);
        let corpus = Corpus::generate(cfg.vocab, 3000, 6);
        let batches: Vec<Vec<i32>> = (0..2).map(|i| corpus.train_batch(2, 16, i)).collect();
        let calib = collect_calibration(&params, &cfg, &batches, 2, 16, 24);
        (params, cfg, calib)
    }

    #[test]
    fn all_inits_produce_consistent_shapes() {
        let (params, cfg, calib) = setup();
        let spec = QuantizerSpec::Mxint { bits: 3, block: 32 };
        let head = Mat::zeros(cfg.d_model, 4);
        for init in [
            QpeftInit::LoRA,
            QpeftInit::QLoRA,
            QpeftInit::LoftQ { iters: 2 },
            QpeftInit::LqLora { iters: 2 },
            QpeftInit::Qera,
            QpeftInit::Srr,
        ] {
            let st = init_qpeft(&params, &cfg, &calib, spec, init, 8, head.clone(), 1);
            assert_eq!(st.adapters.len(), 7, "{}", init.label());
            assert_eq!(st.rank(), 8);
            for a in &st.adapters {
                assert_eq!(a.l.cols, 8);
                assert_eq!(a.r.rows, 8);
                assert!(a.k_star <= 8);
            }
        }
    }

    #[test]
    fn qlora_adapter_contribution_starts_at_zero() {
        let (params, cfg, calib) = setup();
        let spec = QuantizerSpec::Mxint { bits: 3, block: 32 };
        let st = init_qpeft(
            &params, &cfg, &calib, spec, QpeftInit::QLoRA, 8,
            Mat::zeros(cfg.d_model, 4), 2,
        );
        for a in &st.adapters {
            assert_eq!(matmul(&a.l, &a.r), Mat::zeros(a.l.rows, a.r.cols));
        }
    }

    #[test]
    fn srr_init_approximates_w_better_than_qlora() {
        let (params, cfg, calib) = setup();
        let spec = QuantizerSpec::Mxint { bits: 2, block: 32 };
        let approx_err = |init: QpeftInit| {
            let st = init_qpeft(
                &params, &cfg, &calib, spec, init, 8, Mat::zeros(cfg.d_model, 4), 3,
            );
            let mut err = 0.0f64;
            // frozen: embed, ln1, wq..., compare reconstructed to original
            let order: Vec<String> = Params::param_order(&cfg)
                .into_iter()
                .filter(|n| n != "head")
                .collect();
            for a in &st.adapters {
                let idx = order.iter().position(|n| n == &a.name).unwrap();
                let qdeq = st.frozen[idx].to_mat();
                let w = params.get_mat(&a.name).unwrap();
                let rec = qdeq.add(&matmul(&a.l, &a.r));
                err += w.sub(&rec).frob2();
            }
            err.sqrt()
        };
        let e_srr = approx_err(QpeftInit::Srr);
        let e_qlora = approx_err(QpeftInit::QLoRA);
        assert!(e_srr < e_qlora * 0.9, "srr {e_srr} should beat qlora {e_qlora}");
    }

    #[test]
    fn factored_init_matches_direct_init_and_shrinks_frozen_memory() {
        // init_qpeft_factored reuses a PTQ outcome; with matching seeds it
        // must agree bit-for-bit with the recomputing init_qpeft path
        let (params, cfg, calib) = setup();
        let spec = QuantizerSpec::Mxint { bits: 3, block: 32 };
        let seed = 7u64;
        let mut qcfg = QerConfig::new(Method::QerSrr, 8, ScalingKind::Exact);
        qcfg.seed = seed;
        let metrics = crate::coordinator::Metrics::new();
        let outcome =
            crate::coordinator::run_ptq_factored(&params, &cfg, &calib, spec, &qcfg, &metrics);
        let head = Mat::zeros(cfg.d_model, 4);
        let via_factored = init_qpeft_factored(&outcome, &cfg, 8, head.clone());
        let direct = init_qpeft(&params, &cfg, &calib, spec, QpeftInit::Srr, 8, head, seed);

        assert_eq!(via_factored.adapters.len(), direct.adapters.len());
        for (a, b) in via_factored.adapters.iter().zip(&direct.adapters) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.l, b.l, "{} L differs", a.name);
            assert_eq!(a.r, b.r, "{} R differs", a.name);
            assert_eq!(a.k_star, b.k_star);
        }
        for (fa, fb) in via_factored.frozen.iter().zip(&direct.frozen) {
            assert_eq!(fa.to_tensor().as_f32(), fb.to_tensor().as_f32());
        }
        // the frozen backbone stays packed — a real memory win over the
        // densified frozen copy the trainer used to hold
        let dense_bytes: usize =
            QpeftState::frozen_from_params(&params, &cfg).iter().map(|f| f.bytes()).sum();
        assert!(
            via_factored.frozen_bytes() * 2 < dense_bytes,
            "factored {} vs dense {}",
            via_factored.frozen_bytes(),
            dense_bytes
        );
    }

    #[test]
    fn srr_records_positive_kstar_somewhere() {
        let (params, cfg, calib) = setup();
        let spec = QuantizerSpec::Mxint { bits: 2, block: 32 };
        let st = init_qpeft(
            &params, &cfg, &calib, spec, QpeftInit::Srr, 8, Mat::zeros(cfg.d_model, 4), 4,
        );
        assert!(
            st.adapters.iter().any(|a| a.k_star > 0),
            "SRR should preserve in at least one projection"
        );
        // non-SRR methods carry no preserved annotation
        let st2 = init_qpeft(
            &params, &cfg, &calib, spec, QpeftInit::Qera, 8, Mat::zeros(cfg.d_model, 4), 4,
        );
        assert!(st2.adapters.iter().all(|a| a.k_star == 0));
    }
}
