//! Named-tensor parameter container in manifest order.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::runtime::manifest::ModelCfg;
use crate::runtime::TensorValue;
use crate::tensor::Mat;

/// One named parameter.
#[derive(Clone, Debug)]
pub struct NamedTensor {
    pub name: String,
    pub value: TensorValue,
}

/// A model's parameters, ordered exactly like the manifest's
/// `param_order` (the positional contract with the artifacts).
#[derive(Clone, Debug)]
pub struct Params {
    pub order: Vec<String>,
    pub by_name: BTreeMap<String, TensorValue>,
}

impl Params {
    pub fn new(order: Vec<String>) -> Self {
        Params { order, by_name: BTreeMap::new() }
    }

    pub fn set(&mut self, name: &str, value: TensorValue) {
        assert!(self.order.iter().any(|n| n == name), "unknown param {name}");
        self.by_name.insert(name.to_string(), value);
    }

    pub fn get(&self, name: &str) -> Result<&TensorValue> {
        self.by_name.get(name).ok_or_else(|| anyhow!("param {name} unset"))
    }

    pub fn get_mat(&self, name: &str) -> Result<Mat> {
        Ok(self.get(name)?.to_mat())
    }

    pub fn set_mat(&mut self, name: &str, m: &Mat) {
        self.set(name, TensorValue::from_mat(m));
    }

    pub fn get_vec(&self, name: &str) -> Result<&[f32]> {
        Ok(self.get(name)?.as_f32())
    }

    /// Drop a parameter's value, keeping its order slot (`get` errors
    /// until it is set again). The factored pipeline uses this to strip
    /// dense linears out of outcome skeletons.
    pub fn unset(&mut self, name: &str) {
        self.by_name.remove(name);
    }

    /// Positional argument list for an artifact call.
    pub fn flat(&self) -> Result<Vec<TensorValue>> {
        self.order
            .iter()
            .map(|n| self.get(n).cloned())
            .collect()
    }

    /// Names of the quantizable linears (the 7 projections per block).
    pub fn linear_names(cfg: &ModelCfg) -> Vec<String> {
        let kinds = ["wq", "wk", "wv", "wo", "gate", "up", "down"];
        (0..cfg.n_layers)
            .flat_map(|i| kinds.iter().map(move |k| format!("l{i}.{k}")))
            .collect()
    }

    /// The canonical parameter order (mirrors python model.param_names).
    pub fn param_order(cfg: &ModelCfg) -> Vec<String> {
        let mut names = vec!["embed".to_string()];
        for i in 0..cfg.n_layers {
            for k in ["ln1", "wq", "wk", "wv", "wo", "ln2", "gate", "up", "down"] {
                names.push(format!("l{i}.{k}"));
            }
        }
        names.push("norm_f".into());
        names.push("head".into());
        names
    }

    /// Shape of a parameter (mirrors python model.param_shape; `head_dim`
    /// is vocab for LM, n_classes for classifiers, 1 for regression).
    pub fn param_shape(name: &str, cfg: &ModelCfg, head_dim: usize) -> Vec<usize> {
        let (d, ff, v) = (cfg.d_model, cfg.d_ff, cfg.vocab);
        if name == "embed" {
            return vec![v, d];
        }
        if name == "norm_f" || name.ends_with(".ln1") || name.ends_with(".ln2") {
            return vec![d];
        }
        if name == "head" {
            return vec![d, head_dim];
        }
        match name.rsplit('.').next().unwrap() {
            "wq" | "wk" | "wv" | "wo" => vec![d, d],
            "gate" | "up" => vec![d, ff],
            "down" => vec![ff, d],
            other => panic!("unknown param kind {other}"),
        }
    }

    /// Total parameter count.
    pub fn count(&self) -> usize {
        self.order
            .iter()
            .filter_map(|n| self.by_name.get(n))
            .map(|t| t.len())
            .sum()
    }

    /// Replace a linear weight with its reconstruction, leaving the rest.
    pub fn with_replaced(&self, replacements: &BTreeMap<String, Mat>) -> Params {
        let mut out = self.clone();
        for (name, m) in replacements {
            out.set_mat(name, m);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelCfg {
        ModelCfg {
            name: "t".into(),
            vocab: 32,
            d_model: 16,
            n_heads: 2,
            n_layers: 2,
            d_ff: 24,
            seq_len: 8,
        }
    }

    #[test]
    fn order_and_shapes_mirror_python() {
        let c = cfg();
        let order = Params::param_order(&c);
        assert_eq!(order.len(), 1 + 9 * 2 + 2);
        assert_eq!(order[0], "embed");
        assert_eq!(order[1], "l0.ln1");
        assert_eq!(order.last().unwrap(), "head");
        assert_eq!(Params::param_shape("l1.down", &c, c.vocab), vec![24, 16]);
        assert_eq!(Params::param_shape("head", &c, 4), vec![16, 4]);
        assert_eq!(Params::linear_names(&c).len(), 14);
    }

    #[test]
    fn flat_respects_order_and_detects_missing() {
        let c = cfg();
        let mut p = Params::new(vec!["embed".into(), "head".into()]);
        p.set("embed", TensorValue::zeros(vec![32, 16]));
        assert!(p.flat().is_err(), "missing head must error");
        p.set("head", TensorValue::zeros(vec![16, 32]));
        let flat = p.flat().unwrap();
        assert_eq!(flat.len(), 2);
        assert_eq!(flat[0].shape(), &[32, 16]);
        let _ = c;
    }

    #[test]
    fn replace_roundtrip() {
        let mut p = Params::new(vec!["l0.wq".into()]);
        p.set_mat("l0.wq", &Mat::eye(4));
        let mut reps = BTreeMap::new();
        reps.insert("l0.wq".to_string(), Mat::zeros(4, 4));
        let p2 = p.with_replaced(&reps);
        assert_eq!(p2.get_mat("l0.wq").unwrap(), Mat::zeros(4, 4));
        assert_eq!(p.get_mat("l0.wq").unwrap(), Mat::eye(4));
    }
}
