//! Model zoo: parameter containers, synthetic weight generation, and a
//! rust-native forward pass used for calibration capture.
//!
//! * [`params`] — named-tensor container following the manifest's
//!   canonical parameter order.
//! * [`synth`] — synthetic transformer weights with per-projection
//!   anisotropy (Q/K concentrated, V/Down flat — §B.2), standing in for
//!   the paper's gated checkpoints.
//! * [`forward`] — the transformer forward in pure rust, numerically
//!   mirroring python/compile/model.py; its linear-input hooks produce
//!   *real* calibration activations for the scaling matrices (LQER /
//!   QERA need per-layer input statistics). Cross-validated against the
//!   PJRT `lm_fwd_*` artifacts by the integration tests.
//! * [`calibration`] — runs the forward over a calibration stream and
//!   collects per-linear activation matrices.

pub mod params;
pub mod synth;
pub mod forward;
pub mod calibration;

pub use calibration::{collect_calibration, CalibrationSet};
pub use forward::ModelWeights;
pub use params::{NamedTensor, Params};
pub use synth::{spectral_matrix, spectral_matrix_spiked, synth_lm_params, ProjectionKind};
