//! Rust-native transformer forward, numerically mirroring
//! python/compile/model.py (rmsnorm → attention → swiglu blocks).
//!
//! Three jobs:
//! 1. **Calibration capture** — the activation-aware scalings (LQER,
//!    QERA) need the *inputs of every linear layer* under real data; the
//!    [`Capture`] hook records them as the forward runs. (The PJRT
//!    artifacts are sealed graphs — they cannot expose internals.)
//! 2. **Cross-validation** — the integration tests assert this forward
//!    matches the AOT `lm_fwd_*` artifact logits, pinning the rust and
//!    JAX stacks to the same semantics.
//! 3. **Factored serving** — every linear dispatches through the
//!    [`ModelWeights`] trait, so the same forward runs against dense
//!    [`Params`] or against `serve::FactoredModel`'s `LinearOp`s
//!    (`Qdeq·x + L·(R·x)` streamed from packed codes, no densified
//!    `W_hat`, no PJRT).

use std::collections::BTreeMap;

use crate::runtime::manifest::ModelCfg;
use crate::serve::ServeError;
use crate::tensor::{matmul, Mat};

use super::params::Params;

/// Weight access the forward pass needs, abstracted so dense parameters
/// and the factored QLR serving representation share one code path.
pub trait ModelWeights {
    /// y = x · W for the named quantizable linear.
    fn linear(&self, name: &str, x: &Mat) -> Mat;
    /// A 1-D parameter (rmsnorm weights).
    fn vec(&self, name: &str) -> &[f32];
    /// A dense 2-D parameter (embedding table / head).
    fn mat(&self, name: &str) -> Mat;
}

impl ModelWeights for Params {
    fn linear(&self, name: &str, x: &Mat) -> Mat {
        matmul(x, &self.get_mat(name).expect("linear param"))
    }

    fn vec(&self, name: &str) -> &[f32] {
        self.get_vec(name).expect("vec param")
    }

    fn mat(&self, name: &str) -> Mat {
        self.get_mat(name).expect("mat param")
    }
}

const EPS: f32 = 1e-5;

/// Records linear-layer inputs (rows = samples) during forward passes.
#[derive(Default, Debug)]
pub struct Capture {
    pub inputs: BTreeMap<String, Vec<Mat>>,
    /// stop capturing for a layer once this many rows were kept
    pub max_rows: usize,
}

impl Capture {
    pub fn new(max_rows: usize) -> Self {
        Capture { inputs: BTreeMap::new(), max_rows }
    }

    fn record(&mut self, name: &str, x: &Mat) {
        let kept: usize = self
            .inputs
            .get(name)
            .map(|v| v.iter().map(|m| m.rows).sum())
            .unwrap_or(0);
        if kept >= self.max_rows {
            return;
        }
        let take = (self.max_rows - kept).min(x.rows);
        self.inputs
            .entry(name.to_string())
            .or_default()
            .push(x.rows_slice(0, take));
    }

    /// Concatenate the captured rows for one linear.
    pub fn activation_matrix(&self, name: &str) -> Option<Mat> {
        let parts = self.inputs.get(name)?;
        let mut it = parts.iter();
        let first = it.next()?.clone();
        Some(it.fold(first, |acc, m| acc.vcat(m)))
    }
}

fn rmsnorm(x: &Mat, w: &[f32]) -> Mat {
    let mut out = x.clone();
    for i in 0..out.rows {
        let row = out.row_mut(i);
        let ms: f32 =
            row.iter().map(|&v| v * v).sum::<f32>() / row.len() as f32;
        let inv = 1.0 / (ms + EPS).sqrt();
        for (v, &wv) in row.iter_mut().zip(w) {
            *v *= inv * wv;
        }
    }
    out
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Multi-head attention over flattened activations (B*T, d).
fn attention(q: &Mat, k: &Mat, v: &Mat, cfg: &ModelCfg, b: usize, t: usize, causal: bool) -> Mat {
    let d = cfg.d_model;
    let dh = d / cfg.n_heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut out = Mat::zeros(b * t, d);
    for bi in 0..b {
        for h in 0..cfg.n_heads {
            let c0 = h * dh;
            // scores (t x t)
            let mut scores = vec![0.0f32; t * t];
            for i in 0..t {
                let qrow = &q.row(bi * t + i)[c0..c0 + dh];
                let jmax = if causal { i + 1 } else { t };
                for j in 0..jmax {
                    let krow = &k.row(bi * t + j)[c0..c0 + dh];
                    let mut s = 0.0f32;
                    for (a, b2) in qrow.iter().zip(krow) {
                        s += a * b2;
                    }
                    scores[i * t + j] = s * scale;
                }
            }
            // softmax rows (respecting causal mask) then P·V
            for i in 0..t {
                let jmax = if causal { i + 1 } else { t };
                let row = &mut scores[i * t..i * t + jmax];
                let m = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
                let mut z = 0.0f32;
                for s in row.iter_mut() {
                    *s = (*s - m).exp();
                    z += *s;
                }
                let orow = &mut out.row_mut(bi * t + i)[c0..c0 + dh];
                for j in 0..jmax {
                    let p = scores[i * t + j] / z;
                    let vrow = &v.row(bi * t + j)[c0..c0 + dh];
                    for (o, &vv) in orow.iter_mut().zip(vrow) {
                        *o += p * vv;
                    }
                }
            }
        }
    }
    out
}

/// Full trunk + head forward over dense [`Params`]. `tokens` is
/// row-major (b, t). Returns logits (b*t, head_dim). `capture`
/// optionally records linear inputs.
pub fn forward(
    params: &Params,
    cfg: &ModelCfg,
    tokens: &[i32],
    b: usize,
    t: usize,
    causal: bool,
    capture: Option<&mut Capture>,
) -> Mat {
    forward_with(params, cfg, tokens, b, t, causal, capture)
}

/// The forward pass over any [`ModelWeights`] — dense parameters or the
/// factored QLR serving representation.
pub fn forward_with(
    weights: &dyn ModelWeights,
    cfg: &ModelCfg,
    tokens: &[i32],
    b: usize,
    t: usize,
    causal: bool,
    mut capture: Option<&mut Capture>,
) -> Mat {
    assert_eq!(tokens.len(), b * t);
    let embed = weights.mat("embed");
    let d = cfg.d_model;
    let mut x = Mat::zeros(b * t, d);
    for (i, &tok) in tokens.iter().enumerate() {
        x.row_mut(i).copy_from_slice(embed.row(tok as usize));
    }

    for layer in 0..cfg.n_layers {
        let name = |k: &str| format!("l{layer}.{k}");
        let ln1 = weights.vec(&name("ln1"));
        let h = rmsnorm(&x, ln1);
        if let Some(c) = capture.as_deref_mut() {
            for k in ["wq", "wk", "wv"] {
                c.record(&name(k), &h);
            }
        }
        let q = weights.linear(&name("wq"), &h);
        let k = weights.linear(&name("wk"), &h);
        let v = weights.linear(&name("wv"), &h);
        let a = attention(&q, &k, &v, cfg, b, t, causal);
        if let Some(c) = capture.as_deref_mut() {
            c.record(&name("wo"), &a);
        }
        let o = weights.linear(&name("wo"), &a);
        x = x.add(&o);

        let ln2 = weights.vec(&name("ln2"));
        let h2 = rmsnorm(&x, ln2);
        if let Some(c) = capture.as_deref_mut() {
            c.record(&name("gate"), &h2);
            c.record(&name("up"), &h2);
        }
        let g = weights.linear(&name("gate"), &h2);
        let u = weights.linear(&name("up"), &h2);
        let mut m = Mat::zeros(g.rows, g.cols);
        for i in 0..g.data.len() {
            m.data[i] = silu(g.data[i]) * u.data[i];
        }
        if let Some(c) = capture.as_deref_mut() {
            c.record(&name("down"), &m);
        }
        let dn = weights.linear(&name("down"), &m);
        x = x.add(&dn);
    }

    let xf = rmsnorm(&x, weights.vec("norm_f"));
    matmul(&xf, &weights.mat("head"))
}

/// Per-sequence next-token NLL + token counts (mirrors the lm_nll
/// artifact) over dense [`Params`].
pub fn lm_nll(
    params: &Params,
    cfg: &ModelCfg,
    tokens: &[i32],
    mask: &[f32],
    b: usize,
    t: usize,
) -> (Vec<f64>, Vec<f64>) {
    lm_nll_with(params, cfg, tokens, mask, b, t)
}

/// Weight access for a lock-step *fleet* forward: `group_size()` models
/// evaluated simultaneously over vertically stacked activations.
///
/// The stacked activation matrix hands member `g` rows
/// `[g·rows, (g+1)·rows)`; [`FleetWeights::linear_stacked`] applies each
/// member's weight to its own block — the factored serving
/// implementation (`eval::fleet::FleetGroup`) dispatches the whole stack
/// through one `serve::LinearOp::matmul_grouped` call so a shared packed
/// base is decoded once per group. Non-linear parameters (`vec` / `mat`)
/// are shared by construction: a fleet group only ever contains outcomes
/// of one sweep over one model.
pub trait FleetWeights {
    /// Number of models evaluated in lock-step.
    fn group_size(&self) -> usize;
    /// y = x·W_g per member block of the stacked `x`. A malformed group
    /// (member missing the op, ragged stack) is a recoverable
    /// [`ServeError`] — it fails the job, never the process.
    fn linear_stacked(&self, name: &str, x: &Mat) -> Result<Mat, ServeError>;
    /// A 1-D parameter (rmsnorm weights), shared across members.
    fn vec(&self, name: &str) -> &[f32];
    /// A dense 2-D parameter (embedding table / head), shared across
    /// members.
    fn mat(&self, name: &str) -> Mat;
}

/// The lock-step fleet forward: one pass evaluates `group_size()` models
/// on the *same* tokens, carrying all members' activations stacked in
/// one matrix (member `g` owns sequences `[g·b, (g+1)·b)`).
///
/// Per member, bit-identical to [`forward_with`] on that member alone
/// whenever both runs take the batched base-matmul path (`b·t > 1`):
/// every stage — rmsnorm, attention, swiglu, the head — is row- or
/// sequence-local, and the grouped linear preserves per-row summation
/// order. Returns stacked logits (`group·b·t`, head_dim), or the first
/// member's [`ServeError`] if the fleet is malformed.
pub fn forward_fleet(
    weights: &dyn FleetWeights,
    cfg: &ModelCfg,
    tokens: &[i32],
    b: usize,
    t: usize,
    causal: bool,
) -> Result<Mat, ServeError> {
    assert_eq!(tokens.len(), b * t);
    let g = weights.group_size();
    let embed = weights.mat("embed");
    let d = cfg.d_model;
    // every member sees the same tokens: embed once, replicate G times
    let mut x = Mat::zeros(g * b * t, d);
    for (i, &tok) in tokens.iter().enumerate() {
        x.row_mut(i).copy_from_slice(embed.row(tok as usize));
    }
    let block = b * t * d;
    for gi in 1..g {
        x.data.copy_within(0..block, gi * block);
    }
    fleet_trunk(weights, cfg, x, g * b, t, causal)
}

/// The lock-step fleet forward over **per-member tokens**: member `g`
/// runs its own sequences `tokens[g·b·t .. (g+1)·b·t]` — the
/// continuous-batching daemon's shape, where every batch member is a
/// *different* request evaluated under its own model variant.
///
/// Identical to [`forward_fleet`] except for the embedding (each row is
/// looked up from its member's own token instead of replicated); the
/// post-embedding trunk is literally shared code, so the per-member
/// bit-identity argument of [`forward_fleet`] carries over unchanged.
/// Returns stacked logits (`group·b·t`, head_dim), or the first
/// member's [`ServeError`] if the fleet is malformed.
pub fn forward_fleet_distinct(
    weights: &dyn FleetWeights,
    cfg: &ModelCfg,
    tokens: &[i32],
    b: usize,
    t: usize,
    causal: bool,
) -> Result<Mat, ServeError> {
    let g = weights.group_size();
    assert_eq!(tokens.len(), g * b * t, "stacked token count");
    let embed = weights.mat("embed");
    let mut x = Mat::zeros(g * b * t, cfg.d_model);
    for (i, &tok) in tokens.iter().enumerate() {
        x.row_mut(i).copy_from_slice(embed.row(tok as usize));
    }
    fleet_trunk(weights, cfg, x, g * b, t, causal)
}

/// The token-agnostic post-embedding trunk shared by [`forward_fleet`]
/// and [`forward_fleet_distinct`]: the layer loop plus head over `gb`
/// stacked sequences of length `t`. Every stage is row- or
/// sequence-local, so stacking never changes a member's per-element
/// summation order.
fn fleet_trunk(
    weights: &dyn FleetWeights,
    cfg: &ModelCfg,
    mut x: Mat,
    gb: usize,
    t: usize,
    causal: bool,
) -> Result<Mat, ServeError> {
    for layer in 0..cfg.n_layers {
        let name = |k: &str| format!("l{layer}.{k}");
        let h = rmsnorm(&x, weights.vec(&name("ln1")));
        let q = weights.linear_stacked(&name("wq"), &h)?;
        let k = weights.linear_stacked(&name("wk"), &h)?;
        let v = weights.linear_stacked(&name("wv"), &h)?;
        let a = attention(&q, &k, &v, cfg, gb, t, causal);
        let o = weights.linear_stacked(&name("wo"), &a)?;
        x = x.add(&o);

        let h2 = rmsnorm(&x, weights.vec(&name("ln2")));
        let gate = weights.linear_stacked(&name("gate"), &h2)?;
        let u = weights.linear_stacked(&name("up"), &h2)?;
        let mut m = Mat::zeros(gate.rows, gate.cols);
        for i in 0..gate.data.len() {
            m.data[i] = silu(gate.data[i]) * u.data[i];
        }
        let dn = weights.linear_stacked(&name("down"), &m)?;
        x = x.add(&dn);
    }

    let xf = rmsnorm(&x, weights.vec("norm_f"));
    Ok(matmul(&xf, &weights.mat("head")))
}

/// Masked NLL of one predicted position: `-log softmax(row)[target]`
/// weighted by `mk`. Shared by the single-model and fleet NLL loops —
/// the fleet evaluator's ≤1e-6 equivalence gate depends on both paths
/// computing the identical float expression, so there is exactly one
/// copy of it. (`-(a)·b` and `x + (-y)` are IEEE-exact rewrites of the
/// historical `x - a·b` accumulation.)
#[inline]
pub(crate) fn row_nll(row: &[f32], target: usize, mk: f32) -> f64 {
    let m = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
    let z: f32 = row.iter().map(|&x| (x - m).exp()).sum();
    let logp = (row[target] - m) - z.ln();
    -(logp as f64) * mk as f64
}

/// Lock-step NLL: per-member `(Σ nll, Σ tokens)` for one token batch,
/// all members forwarded together through [`forward_fleet`].
///
/// The per-sequence math and accumulation order mirror [`lm_nll_with`] +
/// `eval::ppl::perplexity_native` exactly, so a member's sums equal the
/// single-model path's bit for bit (same batched-path caveat as
/// [`forward_fleet`]).
pub fn lm_nll_fleet(
    weights: &dyn FleetWeights,
    cfg: &ModelCfg,
    tokens: &[i32],
    mask: &[f32],
    b: usize,
    t: usize,
) -> Result<Vec<(f64, f64)>, ServeError> {
    let g = weights.group_size();
    // logits over the first t-1 positions predict tokens 1..t
    let inputs: Vec<i32> = (0..b)
        .flat_map(|bi| tokens[bi * t..bi * t + t - 1].to_vec())
        .collect();
    let logits = forward_fleet(weights, cfg, &inputs, b, t - 1, true)?;
    let mut out = vec![(0.0f64, 0.0f64); g];
    for (gi, slot) in out.iter_mut().enumerate() {
        for bi in 0..b {
            let mut nll = 0.0f64;
            let mut cnt = 0.0f64;
            for pos in 0..t - 1 {
                let mk = mask[bi * t + pos + 1];
                if mk == 0.0 {
                    continue;
                }
                let row = logits.row((gi * b + bi) * (t - 1) + pos);
                let target = tokens[bi * t + pos + 1] as usize;
                nll += row_nll(row, target, mk);
                cnt += mk as f64;
            }
            slot.0 += nll;
            slot.1 += cnt;
        }
    }
    Ok(out)
}

/// NLL over any [`ModelWeights`] — the rust-native factored PPL path.
pub fn lm_nll_with(
    weights: &dyn ModelWeights,
    cfg: &ModelCfg,
    tokens: &[i32],
    mask: &[f32],
    b: usize,
    t: usize,
) -> (Vec<f64>, Vec<f64>) {
    // logits over the first t-1 positions predict tokens 1..t
    let inputs: Vec<i32> = (0..b)
        .flat_map(|bi| tokens[bi * t..bi * t + t - 1].to_vec())
        .collect();
    let logits = forward_with(weights, cfg, &inputs, b, t - 1, true, None);
    let mut nll = vec![0.0f64; b];
    let mut cnt = vec![0.0f64; b];
    for bi in 0..b {
        for pos in 0..t - 1 {
            let mk = mask[bi * t + pos + 1];
            if mk == 0.0 {
                continue;
            }
            let row = logits.row(bi * (t - 1) + pos);
            let target = tokens[bi * t + pos + 1] as usize;
            nll[bi] += row_nll(row, target, mk);
            cnt[bi] += mk as f64;
        }
    }
    (nll, cnt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synth::synth_lm_params;
    use crate::util::Rng;

    fn cfg() -> ModelCfg {
        ModelCfg {
            name: "t".into(),
            vocab: 32,
            d_model: 16,
            n_heads: 2,
            n_layers: 2,
            d_ff: 32,
            seq_len: 8,
        }
    }

    fn toks(c: &ModelCfg, b: usize, rng: &mut Rng) -> Vec<i32> {
        (0..b * c.seq_len).map(|_| rng.below(c.vocab) as i32).collect()
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let c = cfg();
        let p = synth_lm_params(&c, 1, c.vocab);
        let mut rng = Rng::new(2);
        let tk = toks(&c, 2, &mut rng);
        let logits = forward(&p, &c, &tk, 2, c.seq_len, true, None);
        assert_eq!((logits.rows, logits.cols), (2 * 8, 32));
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causal_prefix_invariance() {
        // causal LM: logits at position i must not depend on tokens > i
        let c = cfg();
        let p = synth_lm_params(&c, 3, c.vocab);
        let mut rng = Rng::new(4);
        let mut tk = toks(&c, 1, &mut rng);
        let l1 = forward(&p, &c, &tk, 1, c.seq_len, true, None);
        tk[c.seq_len - 1] = (tk[c.seq_len - 1] + 1) % c.vocab as i32; // mutate last token
        let l2 = forward(&p, &c, &tk, 1, c.seq_len, true, None);
        for pos in 0..c.seq_len - 1 {
            for j in 0..c.vocab {
                assert!(
                    (l1.at(pos, j) - l2.at(pos, j)).abs() < 1e-5,
                    "position {pos} leaked future tokens"
                );
            }
        }
    }

    #[test]
    fn non_causal_differs_from_causal() {
        let c = cfg();
        let p = synth_lm_params(&c, 5, c.vocab);
        let mut rng = Rng::new(6);
        let tk = toks(&c, 1, &mut rng);
        let lc = forward(&p, &c, &tk, 1, c.seq_len, true, None);
        let lb = forward(&p, &c, &tk, 1, c.seq_len, false, None);
        assert!(!lc.allclose(&lb, 1e-4));
    }

    #[test]
    fn capture_collects_every_linear() {
        let c = cfg();
        let p = synth_lm_params(&c, 7, c.vocab);
        let mut rng = Rng::new(8);
        let tk = toks(&c, 2, &mut rng);
        let mut cap = Capture::new(12);
        forward(&p, &c, &tk, 2, c.seq_len, true, Some(&mut cap));
        for name in Params::linear_names(&c) {
            let x = cap.activation_matrix(&name).unwrap_or_else(|| panic!("{name} missing"));
            assert_eq!(x.rows, 12, "{name} row cap");
            let want_cols = if name.ends_with("down") { c.d_ff } else { c.d_model };
            assert_eq!(x.cols, want_cols, "{name} width");
        }
    }

    /// A fleet of G members all serving the same dense weights: every
    /// member's stacked block must equal the single-model forward bit
    /// for bit, and the fleet NLL must match `lm_nll`'s sums.
    struct DenseFleet<'a> {
        params: &'a Params,
        g: usize,
    }

    impl FleetWeights for DenseFleet<'_> {
        fn group_size(&self) -> usize {
            self.g
        }
        fn linear_stacked(&self, name: &str, x: &Mat) -> Result<Mat, ServeError> {
            // same weight for every member; matmul is row-local, so one
            // call over the stack serves all blocks
            Ok(ModelWeights::linear(self.params, name, x))
        }
        fn vec(&self, name: &str) -> &[f32] {
            ModelWeights::vec(self.params, name)
        }
        fn mat(&self, name: &str) -> Mat {
            ModelWeights::mat(self.params, name)
        }
    }

    #[test]
    fn fleet_forward_replicates_single_forward() {
        let c = cfg();
        let p = synth_lm_params(&c, 21, c.vocab);
        let mut rng = Rng::new(22);
        let tk = toks(&c, 2, &mut rng);
        let single = forward(&p, &c, &tk, 2, c.seq_len, true, None);
        let fleet = DenseFleet { params: &p, g: 3 };
        let stacked = forward_fleet(&fleet, &c, &tk, 2, c.seq_len, true).expect("dense fleet");
        assert_eq!(stacked.rows, 3 * single.rows);
        for gi in 0..3 {
            for i in 0..single.rows {
                assert_eq!(
                    stacked.row(gi * single.rows + i),
                    single.row(i),
                    "member {gi} row {i}"
                );
            }
        }

        let mask = vec![1.0f32; 2 * c.seq_len];
        let (nll, cnt) = lm_nll(&p, &c, &tk, &mask, 2, c.seq_len);
        let per_member = lm_nll_fleet(&fleet, &c, &tk, &mask, 2, c.seq_len).expect("dense fleet");
        let want = (nll.iter().sum::<f64>(), cnt.iter().sum::<f64>());
        for (gi, got) in per_member.iter().enumerate() {
            assert_eq!(got.0, want.0, "member {gi} nll");
            assert_eq!(got.1, want.1, "member {gi} count");
        }
    }

    #[test]
    fn nll_mask_zeroes_contributions() {
        let c = cfg();
        let p = synth_lm_params(&c, 9, c.vocab);
        let mut rng = Rng::new(10);
        let tk = toks(&c, 2, &mut rng);
        let full = vec![1.0f32; 2 * c.seq_len];
        let mut half = full.clone();
        for v in half.iter_mut().skip(c.seq_len + 4) {
            *v = 0.0; // mask tail of sequence 1
        }
        let (nll_f, cnt_f) = lm_nll(&p, &c, &tk, &full, 2, c.seq_len);
        let (nll_h, cnt_h) = lm_nll(&p, &c, &tk, &half, 2, c.seq_len);
        assert_eq!(cnt_f[1], (c.seq_len - 1) as f64);
        assert!(cnt_h[1] < cnt_f[1]);
        assert!(nll_h[1] < nll_f[1]);
        assert_eq!(nll_f[0], nll_h[0]); // sequence 0 untouched
    }
}
