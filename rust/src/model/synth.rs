//! Synthetic transformer weights with realistic per-projection anisotropy.
//!
//! The paper's §B.2 observes that in the activation-scaled space, Q and K
//! projections show concentrated spectra (they feed the attention inner
//! product), V flatter, Down flattest (Table 15 eRank: Key 0.43, Output
//! 0.63, Down 0.87 of dimension). SRR's behaviour depends precisely on
//! this structure, so the generator reproduces it: each projection kind
//! draws a rotation-invariant matrix with a power-law spectral profile
//! whose decay exponent is kind-specific, plus a dense noise floor.

use crate::linalg::qr_thin;
use crate::runtime::manifest::ModelCfg;
use crate::runtime::TensorValue;
use crate::tensor::{matmul, Mat};
use crate::util::Rng;

use super::params::Params;

/// The seven projection kinds (paper Fig. 5 taxonomy).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProjectionKind {
    Query,
    Key,
    Value,
    Output,
    Gate,
    Up,
    Down,
}

impl ProjectionKind {
    pub fn from_name(name: &str) -> Option<ProjectionKind> {
        match name.rsplit('.').next()? {
            "wq" => Some(Self::Query),
            "wk" => Some(Self::Key),
            "wv" => Some(Self::Value),
            "wo" => Some(Self::Output),
            "gate" => Some(Self::Gate),
            "up" => Some(Self::Up),
            "down" => Some(Self::Down),
            _ => None,
        }
    }

    /// Spectral decay exponent: higher = more concentrated spectrum.
    /// Calibrated so normalized eRank ordering matches Table 15
    /// (Key < Output < Down).
    pub fn decay(&self) -> f32 {
        match self {
            Self::Query | Self::Key => 0.85,
            Self::Output => 0.55,
            Self::Gate | Self::Up => 0.45,
            Self::Value => 0.40,
            Self::Down => 0.25,
        }
    }

    /// Outlier-direction boost: real transformer weights carry a handful
    /// of dominant directions whose singular values sit far above the
    /// power-law bulk (Yuan et al. 2023b; Wang et al. 2025 — and the
    /// premise of the paper's §3: quantizing them injects
    /// disproportionately large scaled error). Returns
    /// (n_spike_directions, multiplier).
    pub fn spikes(&self) -> (usize, f32) {
        match self {
            Self::Query | Self::Key => (4, 6.0),
            Self::Output => (3, 4.0),
            Self::Gate | Self::Up => (3, 3.0),
            Self::Value => (2, 2.5),
            Self::Down => (2, 2.0),
        }
    }

    pub fn all() -> [ProjectionKind; 7] {
        [
            Self::Query,
            Self::Key,
            Self::Value,
            Self::Output,
            Self::Gate,
            Self::Up,
            Self::Down,
        ]
    }
}

/// Rotation-invariant matrix with power-law spectrum + noise floor,
/// scaled so row-wise std ≈ `std` (keeps activations O(1) through depth).
pub fn spectral_matrix(m: usize, n: usize, decay: f32, std: f32, rng: &mut Rng) -> Mat {
    spectral_matrix_spiked(m, n, decay, 0, 1.0, std, rng)
}

/// [`spectral_matrix`] with `n_spikes` leading directions boosted by
/// `spike` — the outlier structure of real transformer weights.
pub fn spectral_matrix_spiked(
    m: usize,
    n: usize,
    decay: f32,
    n_spikes: usize,
    spike: f32,
    std: f32,
    rng: &mut Rng,
) -> Mat {
    let r = m.min(n);
    let (qu, _) = qr_thin(&Mat::randn(m, r, 1.0, rng));
    let (qv, _) = qr_thin(&Mat::randn(n, r, 1.0, rng));
    // core spectrum σ_i ∝ (1+i)^-decay, normalized to unit mean square
    let mut sv: Vec<f32> = (0..r).map(|i| (1.0 + i as f32).powf(-decay)).collect();
    for s in sv.iter_mut().take(n_spikes) {
        *s *= spike;
    }
    let ms: f32 = sv.iter().map(|s| s * s).sum::<f32>() / r as f32;
    let norm = (1.0 / ms).sqrt();
    for s in sv.iter_mut() {
        *s *= norm;
    }
    let us = Mat::from_fn(m, r, |i, j| qu.at(i, j) * sv[j]);
    let sig = matmul(&us, &qv.transpose());
    // blend signal with an i.i.d. noise floor (10% energy)
    let noise = Mat::randn(m, n, 0.32, rng);
    let blended = sig.scale(0.95).add(&noise.scale(0.312));
    // scale to target std: E[entry²] of sig ≈ r/(m·n)·E[σ²]... just normalize empirically
    let cur = (blended.frob2() / (m * n) as f64).sqrt() as f32;
    blended.scale(std / cur.max(1e-12))
}

/// Build a full LM parameter set for `cfg`.
///
/// `head_dim` selects the output head (vocab for LM). Weight stds follow
/// standard transformer init scaled for residual depth.
pub fn synth_lm_params(cfg: &ModelCfg, seed: u64, head_dim: usize) -> Params {
    let mut rng = Rng::new(seed);
    let order = Params::param_order(cfg);
    let mut p = Params::new(order.clone());
    let d = cfg.d_model;
    let resid_scale = 1.0 / (2.0 * cfg.n_layers as f32).sqrt();
    for name in &order {
        let shape = Params::param_shape(name, cfg, head_dim);
        let t = if shape.len() == 1 {
            TensorValue::f32(shape.clone(), vec![1.0; shape[0]])
        } else if name == "embed" {
            let mut m = Mat::zeros(shape[0], shape[1]);
            rng.fill_normal(&mut m.data, 0.7);
            TensorValue::from_mat(&m)
        } else if name == "head" {
            let m = Mat::randn(shape[0], shape[1], 1.0 / (d as f32).sqrt(), &mut rng);
            TensorValue::from_mat(&m)
        } else {
            let kind = ProjectionKind::from_name(name).expect("linear name");
            let std = match kind {
                ProjectionKind::Output | ProjectionKind::Down => {
                    resid_scale / (shape[0] as f32).sqrt()
                }
                _ => 1.0 / (shape[0] as f32).sqrt(),
            };
            let (n_spikes, spike) = kind.spikes();
            let mut sub = rng.fork(fxhash(name));
            TensorValue::from_mat(&spectral_matrix_spiked(
                shape[0], shape[1], kind.decay(), n_spikes, spike, std, &mut sub,
            ))
        };
        p.set(name, t);
    }
    p
}

fn fxhash(s: &str) -> u64 {
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{effective_rank, jacobi_svd};

    fn cfg() -> ModelCfg {
        ModelCfg {
            name: "t".into(),
            vocab: 64,
            d_model: 48,
            n_heads: 4,
            n_layers: 2,
            d_ff: 96,
            seq_len: 16,
        }
    }

    #[test]
    fn builds_complete_param_set() {
        let c = cfg();
        let p = synth_lm_params(&c, 1, c.vocab);
        assert!(p.flat().is_ok());
        assert!(p.count() > 0);
        let wq = p.get_mat("l0.wq").unwrap();
        assert_eq!((wq.rows, wq.cols), (48, 48));
        assert!(p.get_vec("l0.ln1").unwrap().iter().all(|&v| v == 1.0));
    }

    #[test]
    fn deterministic_by_seed() {
        let c = cfg();
        let a = synth_lm_params(&c, 7, c.vocab);
        let b = synth_lm_params(&c, 7, c.vocab);
        assert_eq!(a.get_mat("l1.gate").unwrap(), b.get_mat("l1.gate").unwrap());
        let c2 = synth_lm_params(&c, 8, c.vocab);
        assert_ne!(a.get_mat("l1.gate").unwrap(), c2.get_mat("l1.gate").unwrap());
    }

    #[test]
    fn erank_ordering_matches_paper_table15() {
        // Key < Output < Down in normalized effective rank
        let c = cfg();
        let p = synth_lm_params(&c, 3, c.vocab);
        let er = |name: &str| {
            let m = p.get_mat(name).unwrap();
            let svd = jacobi_svd(&m);
            effective_rank(&svd.s) / m.rows.min(m.cols) as f64
        };
        let key = er("l0.wk");
        let out = er("l0.wo");
        let down = er("l0.down");
        assert!(key < out, "key {key} !< output {out}");
        assert!(out < down, "output {out} !< down {down}");
        assert!(down > 0.6, "down should be near-flat, got {down}");
    }

    #[test]
    fn spectral_matrix_hits_target_std() {
        let mut rng = Rng::new(9);
        let m = spectral_matrix(64, 96, 0.8, 0.05, &mut rng);
        let std = (m.frob2() / (64.0 * 96.0)).sqrt();
        assert!((std - 0.05).abs() / 0.05 < 0.05, "std={std}");
    }
}
