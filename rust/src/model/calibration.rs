//! Calibration: run the rust-native forward over calibration batches and
//! collect per-linear activation matrices (the paper uses 256 SlimPajama
//! samples; we stream batches of a synthetic corpus — see data::corpus).

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::quant::QuantCtx;
use crate::runtime::manifest::ModelCfg;
use crate::scaling::{Scaling, ScalingKind};
use crate::tensor::{matmul_tn, Mat};

use super::forward::{forward, Capture};
use super::params::Params;

/// Activation matrices per linear layer.
pub struct CalibrationSet {
    pub activations: BTreeMap<String, Mat>,
    /// memoized scalings — the exact kind costs an O(d³) eigendecomposition
    /// and the experiment grid reuses each (layer, kind) many times
    cache: Mutex<BTreeMap<(String, u8), Scaling>>,
}

fn kind_tag(kind: ScalingKind) -> u8 {
    match kind {
        ScalingKind::Identity => 0,
        ScalingKind::DiagRms => 1,
        ScalingKind::DiagAbsMean => 2,
        ScalingKind::Exact => 3,
    }
}

impl CalibrationSet {
    pub fn new(activations: BTreeMap<String, Mat>) -> Self {
        CalibrationSet { activations, cache: Mutex::new(BTreeMap::new()) }
    }

    /// Build (or fetch the memoized) Scaling of the requested kind.
    pub fn scaling_for(&self, name: &str, kind: ScalingKind) -> Scaling {
        let key = (name.to_string(), kind_tag(kind));
        if let Some(s) = self.cache.lock().unwrap().get(&key) {
            return s.clone();
        }
        let s = match self.activations.get(name) {
            Some(x) => Scaling::from_activations(kind, x),
            None => Scaling::Identity,
        };
        self.cache.lock().unwrap().insert(key, s.clone());
        s
    }

    /// A view over the same activations with an empty scaling memo — a
    /// fresh `run_ptq` invocation's cache state (benchmarks use this to
    /// measure the cold per-config path the sweep engine amortizes).
    pub fn cold_copy(&self) -> CalibrationSet {
        CalibrationSet::new(self.activations.clone())
    }

    /// GPTQ's Hessian H = XᵀX/n for one linear.
    pub fn quant_ctx(&self, name: &str, with_hessian: bool, seed: u64) -> QuantCtx {
        let hessian = if with_hessian {
            self.activations
                .get(name)
                .map(|x| matmul_tn(x, x).scale(1.0 / x.rows as f32))
        } else {
            None
        };
        QuantCtx { hessian, seed }
    }
}

/// Run `batches` (each row-major (b, t) token blocks) through the model,
/// capturing up to `max_rows` activation rows per linear.
pub fn collect_calibration(
    params: &Params,
    cfg: &ModelCfg,
    batches: &[Vec<i32>],
    b: usize,
    t: usize,
    max_rows: usize,
) -> CalibrationSet {
    let mut cap = Capture::new(max_rows);
    for batch in batches {
        forward(params, cfg, batch, b, t, true, Some(&mut cap));
        let have = cap
            .inputs
            .values()
            .map(|v| v.iter().map(|m| m.rows).sum::<usize>())
            .min()
            .unwrap_or(0);
        if have >= max_rows {
            break;
        }
    }
    let activations = Params::linear_names(cfg)
        .into_iter()
        .filter_map(|name| cap.activation_matrix(&name).map(|m| (name, m)))
        .collect();
    CalibrationSet::new(activations)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synth::synth_lm_params;
    use crate::util::Rng;

    fn cfg() -> ModelCfg {
        ModelCfg {
            name: "t".into(),
            vocab: 32,
            d_model: 16,
            n_heads: 2,
            n_layers: 1,
            d_ff: 32,
            seq_len: 8,
        }
    }

    #[test]
    fn collects_for_all_linears_and_builds_scalings() {
        let c = cfg();
        let p = synth_lm_params(&c, 1, c.vocab);
        let mut rng = Rng::new(2);
        let batches: Vec<Vec<i32>> = (0..4)
            .map(|_| (0..2 * c.seq_len).map(|_| rng.below(c.vocab) as i32).collect())
            .collect();
        let cal = collect_calibration(&p, &c, &batches, 2, c.seq_len, 24);
        assert_eq!(cal.activations.len(), 7);
        for kind in [ScalingKind::DiagRms, ScalingKind::DiagAbsMean, ScalingKind::Exact] {
            let s = cal.scaling_for("l0.wq", kind);
            assert!(s.dim_hint().unwrap_or(16) == 16);
        }
        let ctx = cal.quant_ctx("l0.wq", true, 0);
        let h = ctx.hessian.expect("hessian");
        assert_eq!((h.rows, h.cols), (16, 16));
        // hessian is symmetric PSD-ish
        for i in 0..16 {
            assert!(h.at(i, i) >= 0.0);
            for j in 0..16 {
                assert!((h.at(i, j) - h.at(j, i)).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn cold_copy_rebuilds_identical_scalings() {
        let c = cfg();
        let p = synth_lm_params(&c, 1, c.vocab);
        let mut rng = Rng::new(3);
        let batches: Vec<Vec<i32>> = (0..4)
            .map(|_| (0..2 * c.seq_len).map(|_| rng.below(c.vocab) as i32).collect())
            .collect();
        let cal = collect_calibration(&p, &c, &batches, 2, c.seq_len, 24);
        let warm = cal.scaling_for("l0.wq", ScalingKind::Exact);
        let cold = cal.cold_copy();
        // deterministic rebuild from the same activations
        match (warm, cold.scaling_for("l0.wq", ScalingKind::Exact)) {
            (Scaling::Full { s: a, .. }, Scaling::Full { s: b, .. }) => assert_eq!(a, b),
            other => panic!("expected full scalings, got {other:?}"),
        }
    }

    #[test]
    fn missing_layer_falls_back_to_identity() {
        let cal = CalibrationSet::new(BTreeMap::new());
        match cal.scaling_for("nope", ScalingKind::Exact) {
            Scaling::Identity => {}
            other => panic!("expected identity fallback, got {other:?}"),
        }
    }
}
