//! GLUE-sim scoring from classifier logits: per-task metric selection
//! (accuracy / Matthews / Pearson+Spearman averaged, as the paper
//! reports "P/S Corr" for STSB).

use crate::data::glue_sim::{GlueExample, Metric};
use crate::util::stats;

/// Score predictions against examples for the task's metric.
/// `logits` is row-major (n_examples, n_classes); regression tasks use
/// column 0 as the prediction.
pub fn glue_score(metric: Metric, logits: &[f32], n_classes: usize, examples: &[GlueExample]) -> f64 {
    let n = examples.len();
    assert!(logits.len() >= n * n_classes.max(1));
    match metric {
        Metric::Accuracy | Metric::Matthews => {
            let pred: Vec<usize> = (0..n)
                .map(|i| {
                    let row = &logits[i * n_classes..(i + 1) * n_classes];
                    row.iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(j, _)| j)
                        .unwrap_or(0)
                })
                .collect();
            let truth: Vec<usize> = examples.iter().map(|e| e.label).collect();
            match metric {
                Metric::Accuracy => stats::accuracy(&pred, &truth) * 100.0,
                Metric::Matthews => {
                    // clamp predictions to binary for MCC
                    let predb: Vec<usize> = pred.iter().map(|&p| p.min(1)).collect();
                    let truthb: Vec<usize> = truth.iter().map(|&t| t.min(1)).collect();
                    stats::matthews(&predb, &truthb) * 100.0
                }
                _ => unreachable!(),
            }
        }
        Metric::PearsonSpearman => {
            let pred: Vec<f64> = (0..n).map(|i| logits[i * n_classes] as f64).collect();
            let truth: Vec<f64> = examples.iter().map(|e| e.target as f64).collect();
            let p = stats::pearson(&pred, &truth);
            let s = stats::spearman(&pred, &truth);
            (p + s) / 2.0 * 100.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ex(label: usize, target: f32) -> GlueExample {
        GlueExample { tokens: vec![], label, target }
    }

    #[test]
    fn accuracy_from_argmax() {
        let examples = vec![ex(0, 0.0), ex(1, 0.0), ex(1, 0.0)];
        let logits = vec![
            2.0, 1.0, // -> 0 correct
            0.0, 3.0, // -> 1 correct
            5.0, 1.0, // -> 0 wrong
        ];
        let acc = glue_score(Metric::Accuracy, &logits, 2, &examples);
        assert!((acc - 200.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn matthews_perfect_binary() {
        let examples = vec![ex(0, 0.0), ex(1, 0.0), ex(0, 0.0), ex(1, 0.0)];
        let logits = vec![1.0, 0.0, 0.0, 1.0, 1.0, 0.0, 0.0, 1.0];
        assert!((glue_score(Metric::Matthews, &logits, 2, &examples) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn pearson_spearman_average() {
        let examples = vec![ex(0, 0.1), ex(0, 0.5), ex(0, 0.9)];
        let logits = vec![0.2, 0.6, 1.0]; // n_classes = 1, perfectly monotone/linear
        let score = glue_score(Metric::PearsonSpearman, &logits, 1, &examples);
        assert!((score - 100.0).abs() < 1e-6);
    }
}
