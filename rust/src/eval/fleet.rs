//! The fleet evaluator: lock-step batched PPL over many sweep outcomes.
//!
//! A sweep grid produces one [`FactoredModel`] per config, and rank
//! variants of the same `(layer, quantizer, seed)` cell carry
//! *pointer-identical* packed bases (the sweep engine hands them the
//! same `Arc<PackedMat>` from its `LayerCache`). Scoring those outcomes
//! with one [`perplexity_native`](super::ppl::perplexity_native) loop
//! each re-pays the streaming dequantization of every shared base per
//! outcome; this module evaluates them together instead:
//!
//! * [`group_by_shared_bases`] partitions outcomes into lock-step
//!   groups — two outcomes share a group iff *every* quantized linear's
//!   base aliases the same buffer
//!   ([`QuantBase::same_buffer`](crate::serve::QuantBase::same_buffer));
//! * [`FleetGroup`] implements
//!   [`FleetWeights`](crate::model::forward::FleetWeights): the group
//!   runs layer-by-layer through one
//!   [`forward_fleet`](crate::model::forward::forward_fleet) pass with
//!   every member's activations stacked, so each base's code row-spans
//!   are decoded **once per group per batch**
//!   ([`LinearOp::matmul_grouped`]) while only the cheap per-member
//!   `L·(R·x)` corrections differ — and that one decode runs the
//!   word-at-a-time block kernels with the stacked activations reusing
//!   each L1-resident tile (`quant::packed`), so the fleet path rides
//!   the serving layer's cache-blocked matmul, not a scalar per-code
//!   loop;
//! * [`fleet_perplexity`] fans the per-(group, batch) jobs over the
//!   coordinator worker pool and reduces per-member NLL sums in batch
//!   order, so every PPL matches the per-outcome
//!   [`perplexity_native`](super::ppl::perplexity_native) value (bit-
//!   identically on the batched path; a group of one takes exactly that
//!   single-outcome path).
//!
//! Consumers: the `exp::ptq` grid experiments (Tables 1/5/16), the
//! `ptq_sweep` example, and `exp::perf::evalbatch_bench`, which records
//! per-outcome vs fleet tokens/sec and the packed-buffer dedup into
//! `BENCH_evalbatch.json`.

use std::collections::{HashMap, HashSet};

use crate::model::forward::{lm_nll_fleet, FleetWeights};
use crate::runtime::manifest::ModelCfg;
use crate::serve::{FactoredModel, LinearOp, ServeError};
use crate::tensor::{matmul, Mat};
use crate::util::pool;

use super::ppl::perplexity_native_masked;

/// A group of factored models whose quantized linears all share base
/// buffers, evaluated in lock-step. Non-linear parameters are served
/// from the first member's skeleton — a group only ever contains
/// outcomes of one sweep over one model, whose skeletons are equal by
/// construction.
pub struct FleetGroup<'a> {
    members: Vec<&'a FactoredModel>,
}

impl<'a> FleetGroup<'a> {
    /// Build a group. The members must have aligned `ops` (same linear
    /// names in the same order); [`group_by_shared_bases`] guarantees
    /// this for groups it emits.
    pub fn new(members: Vec<&'a FactoredModel>) -> Self {
        assert!(!members.is_empty(), "empty fleet group");
        debug_assert!(members
            .iter()
            .all(|m| m.ops.len() == members[0].ops.len()));
        FleetGroup { members }
    }

    /// The models in this group, in input order.
    pub fn members(&self) -> &[&'a FactoredModel] {
        &self.members
    }
}

impl FleetWeights for FleetGroup<'_> {
    fn group_size(&self) -> usize {
        self.members.len()
    }

    fn linear_stacked(&self, name: &str, x: &Mat) -> Result<Mat, ServeError> {
        if self.members[0].op(name).is_some() {
            // a hand-built (or partially spilled) group can be
            // misaligned — a member missing the op fails the job as a
            // ServeError, never the process
            let ops: Vec<&LinearOp> = self
                .members
                .iter()
                .map(|m| m.op(name).ok_or_else(|| ServeError::UnknownTensor(name.to_string())))
                .collect::<Result<_, _>>()?;
            LinearOp::matmul_grouped(&ops, x)
        } else {
            // un-quantized linear: shared skeleton weight, plain GEMM
            let w = self.members[0]
                .skeleton
                .get_mat(name)
                .ok_or_else(|| ServeError::UnknownTensor(name.to_string()))?;
            Ok(matmul(x, &w))
        }
    }

    fn vec(&self, name: &str) -> &[f32] {
        self.members[0].skeleton.get_vec(name).expect("vec param")
    }

    fn mat(&self, name: &str) -> Mat {
        self.members[0].skeleton.get_mat(name).expect("mat param")
    }
}

fn shares_all_bases(a: &FactoredModel, b: &FactoredModel) -> bool {
    !a.ops.is_empty()
        && a.ops.len() == b.ops.len()
        && a.ops.iter().zip(&b.ops).all(|((na, oa), (nb, ob))| {
            na == nb
                && match (oa, ob) {
                    (
                        LinearOp::FactoredQlr { base: ba, .. },
                        LinearOp::FactoredQlr { base: bb, .. },
                    ) => ba.same_buffer(bb),
                    _ => false,
                }
        })
}

/// Partition `models` into lock-step groups by shared base buffers.
///
/// Two models land in one group iff every quantized linear's
/// [`QuantBase`](crate::serve::QuantBase) aliases the same underlying
/// buffer — pointer identity,
/// not content equality, so only outcomes that genuinely share memory
/// (rank/scaling variants of one sweep cell) are batched; equal-looking
/// but independently quantized models stay apart. Returns index groups
/// in first-seen order; singletons stay singletons.
pub fn group_by_shared_bases(models: &[&FactoredModel]) -> Vec<Vec<usize>> {
    let mut groups: Vec<Vec<usize>> = Vec::new();
    'outer: for i in 0..models.len() {
        for group in groups.iter_mut() {
            if shares_all_bases(models[group[0]], models[i]) {
                group.push(i);
                continue 'outer;
            }
        }
        groups.push(vec![i]);
    }
    groups
}

/// Packed/dense base-buffer accounting across a fleet of outcomes.
#[derive(Clone, Copy, Debug)]
pub struct FleetFootprint {
    /// base bytes summed per model — what per-outcome serving would hold
    /// resident if every outcome owned its buffers
    pub total_base_bytes: usize,
    /// bytes of *distinct* buffers — what the `Arc`-shared outcomes
    /// actually keep resident
    pub unique_base_bytes: usize,
    /// number of lock-step groups the fleet evaluator would form
    pub groups: usize,
}

/// Measure the base-buffer dedup across `models` (see
/// [`FleetFootprint`]).
pub fn fleet_footprint(models: &[&FactoredModel]) -> FleetFootprint {
    let mut seen: HashSet<usize> = HashSet::new();
    let mut total = 0usize;
    let mut unique = 0usize;
    for m in models {
        for (_, op) in &m.ops {
            if let LinearOp::FactoredQlr { base, .. } = op {
                total += base.bytes();
                if seen.insert(base.buffer_ptr()) {
                    unique += base.bytes();
                }
            }
        }
    }
    FleetFootprint {
        total_base_bytes: total,
        unique_base_bytes: unique,
        groups: group_by_shared_bases(models).len(),
    }
}

/// One unit of fleet-eval work. The job layout — and therefore the f64
/// reduce order — is shared between the in-process [`fleet_perplexity`]
/// and the multi-process
/// [`fleet_perplexity_sharded`](crate::coordinator::shard::fleet_perplexity_sharded),
/// which is what keeps the two paths bit-identical.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum FleetJob {
    /// singleton group → the existing single-outcome path over all
    /// batches (the model index)
    Single(usize),
    /// (group index, batch index) lock-step slice
    GroupBatch(usize, usize),
}

/// The canonical job layout for `groups` over `n_batches` batches:
/// singleton groups take one whole-stream job, multi-member groups one
/// job per batch, in group order.
pub(crate) fn fleet_job_list(groups: &[Vec<usize>], n_batches: usize) -> Vec<FleetJob> {
    let mut jobs: Vec<FleetJob> = Vec::new();
    for (gi, group) in groups.iter().enumerate() {
        if group.len() == 1 {
            jobs.push(FleetJob::Single(group[0]));
        } else {
            for bj in 0..n_batches {
                jobs.push(FleetJob::GroupBatch(gi, bj));
            }
        }
    }
    jobs
}

/// A completed [`FleetJob`]'s output.
pub(crate) enum FleetJobResult {
    /// a singleton's full perplexity
    Ppl(f64),
    /// per-member (Σ nll, Σ tokens) for one lock-step batch
    Partials(Vec<(f64, f64)>),
}

/// Reduce per-job outputs (aligned with `jobs`) into per-model PPLs.
/// Jobs are consumed in list order, so a group's partials accumulate in
/// batch order and the f64 summation matches `perplexity_native`
/// regardless of where the jobs executed.
pub(crate) fn reduce_fleet_results(
    n_models: usize,
    groups: &[Vec<usize>],
    jobs: &[FleetJob],
    outs: Vec<FleetJobResult>,
) -> Vec<f64> {
    assert_eq!(jobs.len(), outs.len(), "fleet outputs incomplete");
    let mut sums: HashMap<usize, Vec<(f64, f64)>> = groups
        .iter()
        .enumerate()
        .filter(|(_, g)| g.len() > 1)
        .map(|(gi, g)| (gi, vec![(0.0f64, 0.0f64); g.len()]))
        .collect();
    let mut ppl = vec![f64::NAN; n_models];
    for (job, out) in jobs.iter().zip(outs) {
        match (job, out) {
            (FleetJob::Single(mi), FleetJobResult::Ppl(p)) => ppl[*mi] = p,
            (FleetJob::GroupBatch(gi, _), FleetJobResult::Partials(parts)) => {
                let acc = sums.get_mut(gi).expect("group registered");
                assert_eq!(acc.len(), parts.len(), "partial arity mismatch");
                for (a, p) in acc.iter_mut().zip(parts) {
                    a.0 += p.0;
                    a.1 += p.1;
                }
            }
            _ => panic!("fleet job/result shape mismatch"),
        }
    }
    for (gi, group) in groups.iter().enumerate() {
        if group.len() > 1 {
            for (slot, &mi) in sums[&gi].iter().zip(group) {
                // zero scored tokens (no batches, all-zero masks) stays
                // NaN — the documented contract shared with
                // `perplexity_native_masked` — instead of a bogus 1.0
                ppl[mi] = if slot.1 == 0.0 { f64::NAN } else { (slot.0 / slot.1).exp() };
            }
        }
    }
    ppl
}

/// Lock-step batched perplexity over many factored models; returns PPLs
/// aligned with `models`.
///
/// Models are grouped by [`group_by_shared_bases`]; each multi-member
/// group evaluates per batch through one stacked
/// [`forward_fleet`](crate::model::forward::forward_fleet) pass (one
/// base decode per group per batch), each singleton takes the existing
/// single-outcome
/// [`perplexity_native`](super::ppl::perplexity_native) path. All
/// (group × batch) jobs fan out over the shared worker pool; per-member
/// sums reduce in batch order, so results match the per-outcome loop.
/// The job layout and reduce are shared with the sharded evaluator
/// (`coordinator::shard`), which runs the same jobs in worker processes.
///
/// **Zero-token contract:** a model scored over zero tokens (no
/// batches, all-zero masks) gets `NaN`, matching
/// [`perplexity_native_masked`] — never a fabricated finite PPL.
///
/// A malformed fleet (a member missing an op, a ragged stack) surfaces
/// as the first failing job's [`ServeError`].
pub fn fleet_perplexity(
    models: &[&FactoredModel],
    cfg: &ModelCfg,
    batches: &[Vec<i32>],
    b: usize,
    t: usize,
) -> Result<Vec<f64>, ServeError> {
    let groups = group_by_shared_bases(models);
    // one mask allocation for the whole fleet (satellite: hoisted out of
    // every perplexity_native call)
    let mask = vec![1.0f32; b * t];
    let jobs = fleet_job_list(&groups, batches.len());

    let outs: Vec<FleetJobResult> = pool::par_map(jobs.len(), |j| match jobs[j] {
        FleetJob::Single(mi) => Ok(FleetJobResult::Ppl(perplexity_native_masked(
            models[mi],
            cfg,
            batches,
            &mask,
            b,
            t,
        ))),
        FleetJob::GroupBatch(gi, bj) => {
            let fleet = FleetGroup::new(groups[gi].iter().map(|&mi| models[mi]).collect());
            lm_nll_fleet(&fleet, cfg, &batches[bj], &mask, b, t).map(FleetJobResult::Partials)
        }
    })
    .into_iter()
    .collect::<Result<_, _>>()?;

    Ok(reduce_fleet_results(models.len(), &groups, &jobs, outs))
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::coordinator::QuantizerSpec;
    use crate::model::synth::synth_lm_params;
    use crate::model::Params;
    use crate::quant::QuantCtx;
    use crate::serve::QuantBase;
    use crate::util::{prop, Rng};

    use super::super::ppl::perplexity_native;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg {
            name: "t".into(),
            vocab: 48,
            d_model: 64,
            n_heads: 2,
            n_layers: 1,
            d_ff: 96,
            seq_len: 8,
        }
    }

    /// Factored outcomes over `params`: one model per rank, every rank
    /// sharing the same freshly quantized base per linear (the sweep
    /// engine's layout).
    fn rank_variants(
        params: &Params,
        cfg: &ModelCfg,
        spec: QuantizerSpec,
        ranks: &[usize],
        seed: u64,
        rng: &mut Rng,
    ) -> Vec<FactoredModel> {
        let names = Params::linear_names(cfg);
        // one shared base per linear
        let bases: Vec<(String, QuantBase)> = names
            .iter()
            .map(|n| {
                let w = params.get_mat(n).expect("linear");
                let ctx = QuantCtx { hessian: None, seed };
                let (_, packed) = spec.build().quantize_coded(&w, &ctx);
                (n.clone(), QuantBase::Packed(Arc::new(packed.expect("packable"))))
            })
            .collect();
        ranks
            .iter()
            .map(|&rank| {
                let mut skeleton = params.clone();
                let ops: Vec<(String, LinearOp)> = bases
                    .iter()
                    .map(|(n, base)| {
                        skeleton.unset(n);
                        let (m, k) = (base.rows(), base.cols());
                        let op = LinearOp::FactoredQlr {
                            base: base.clone(),
                            l: Mat::randn(m, rank, 0.05, rng),
                            r: Mat::randn(rank, k, 0.05, rng),
                        };
                        (n.clone(), op)
                    })
                    .collect();
                FactoredModel { skeleton, ops }
            })
            .collect()
    }

    /// Satellite property: fleet PPL matches the per-outcome
    /// `perplexity_native` loop to ≤ 1e-6 across all three packed
    /// families, ranks {0, 16, 64}, and mixed group sizes — including a
    /// group of one, which must take the single-outcome path.
    #[test]
    fn prop_fleet_matches_per_outcome_ppl() {
        prop::check(0xF1EE7BA7, 4, |g| {
            let cfg = tiny_cfg();
            let params = synth_lm_params(&cfg, 100 + g.rng.next_u64() % 50, cfg.vocab);
            let ranks = [0usize, 16, 64];
            let families = [
                QuantizerSpec::Mxint { bits: 3, block: 32 },
                QuantizerSpec::Uniform { bits: 4, group: 32, symmetric: false },
                QuantizerSpec::Gptq { bits: 3, group: 32 },
            ];
            let mut models: Vec<FactoredModel> = Vec::new();
            for (fi, spec) in families.iter().enumerate() {
                models.extend(rank_variants(
                    &params,
                    &cfg,
                    *spec,
                    &ranks,
                    fi as u64,
                    &mut g.rng,
                ));
            }
            // a singleton: same family as group 0 but its own buffers,
            // so pointer-grouping must keep it apart
            models.extend(rank_variants(&params, &cfg, families[0], &[16], 99, &mut g.rng));

            let refs: Vec<&FactoredModel> = models.iter().collect();
            let groups = group_by_shared_bases(&refs);
            let mut sizes: Vec<usize> = groups.iter().map(|gr| gr.len()).collect();
            sizes.sort_unstable();
            assert_eq!(sizes, vec![1, 3, 3, 3], "grouping by shared buffers");

            let b = 1 + g.dim(2); // 2..3 sequences
            let t = cfg.seq_len;
            let n_batches = g.dim(3);
            let batches: Vec<Vec<i32>> = (0..n_batches)
                .map(|_| (0..b * t).map(|_| g.rng.below(cfg.vocab) as i32).collect())
                .collect();

            let fleet = fleet_perplexity(&refs, &cfg, &batches, b, t).expect("well-formed fleet");
            for (i, m) in refs.iter().enumerate() {
                let solo = perplexity_native(*m, &cfg, &batches, b, t);
                assert!(
                    (fleet[i] - solo).abs() <= 1e-6,
                    "model {i}: fleet {} vs per-outcome {solo}",
                    fleet[i]
                );
            }

            // dedup accounting: 10 models, 4 distinct buffer sets
            let fp = fleet_footprint(&refs);
            assert_eq!(fp.groups, 4);
            assert!(fp.unique_base_bytes * 2 < fp.total_base_bytes);
        });
    }

    /// Mixed-bit fleets, as the budget allocator emits them: a w-only
    /// cell and a QER cell carrying the *same* per-layer alternating
    /// 2/4-bit assignment share every cached packed base `Arc`, so they
    /// group, and each member's lock-step fleet PPL equals its solo
    /// [`perplexity_native`].
    #[test]
    fn mixed_bit_heterogeneous_cells_group_and_match_solo_ppl() {
        use crate::coordinator::{run_sweep_factored, LayerAssign, Metrics, SweepConfig};
        use crate::data::Corpus;
        use crate::model::collect_calibration;
        use crate::qer::Method;
        use crate::scaling::ScalingKind;

        let cfg = tiny_cfg();
        let params = synth_lm_params(&cfg, 11, cfg.vocab);
        let corpus = Corpus::generate(cfg.vocab, 2000, 6);
        let batches: Vec<Vec<i32>> =
            (0..6).map(|i| corpus.train_batch(2, cfg.seq_len, i)).collect();
        let calib = collect_calibration(&params, &cfg, &batches, 2, cfg.seq_len, 128);

        let names = Params::linear_names(&cfg);
        let quant_of = |li: usize| QuantizerSpec::Mxint {
            bits: if li % 2 == 0 { 2 } else { 4 },
            block: 32,
        };
        let wonly: Vec<LayerAssign> = (0..names.len())
            .map(|li| LayerAssign { quantizer: quant_of(li), rank: 0 })
            .collect();
        let qer: Vec<LayerAssign> = (0..names.len())
            .map(|li| LayerAssign { quantizer: quant_of(li), rank: 4 })
            .collect();
        let mx = QuantizerSpec::Mxint { bits: 4, block: 32 };
        let configs = vec![
            SweepConfig::new(mx, Method::WOnly, 0, ScalingKind::DiagRms)
                .with_per_layer(wonly),
            SweepConfig::new(mx, Method::Qer, 4, ScalingKind::DiagRms).with_per_layer(qer),
        ];
        let metrics = Metrics::new();
        let outs = run_sweep_factored(&params, &cfg, &calib, &configs, &metrics);

        let refs: Vec<&FactoredModel> = outs.iter().map(|o| &o.model).collect();
        let groups = group_by_shared_bases(&refs);
        assert_eq!(
            groups.len(),
            1,
            "same per-layer bits must share packed bases into one group"
        );

        let fleet =
            fleet_perplexity(&refs, &cfg, &batches, 2, cfg.seq_len).expect("well-formed fleet");
        for (i, m) in refs.iter().enumerate() {
            let solo = perplexity_native(*m, &cfg, &batches, 2, cfg.seq_len);
            assert!(
                (fleet[i] - solo).abs() <= 1e-6,
                "model {i}: fleet {} vs per-outcome {solo}",
                fleet[i]
            );
        }
    }

    #[test]
    fn singleton_group_of_dense_ops_never_groups() {
        let cfg = tiny_cfg();
        let params = synth_lm_params(&cfg, 7, cfg.vocab);
        let w = params.get_mat("l0.wq").unwrap();
        let mk = || {
            let mut skeleton = params.clone();
            skeleton.unset("l0.wq");
            FactoredModel {
                skeleton,
                ops: vec![("l0.wq".into(), LinearOp::Dense(w.clone()))],
            }
        };
        let (a, b) = (mk(), mk());
        let refs: Vec<&FactoredModel> = vec![&a, &b];
        assert_eq!(group_by_shared_bases(&refs).len(), 2);
    }

    /// Regression (zero-token contract): zero batches must surface as
    /// NaN for every member — singleton and lock-step alike — never as
    /// a fabricated "perfect" PPL of 1.0.
    #[test]
    fn empty_batches_yield_nan_not_bogus_ppl() {
        let cfg = tiny_cfg();
        let params = synth_lm_params(&cfg, 9, cfg.vocab);
        let mut rng = Rng::new(4);
        let mut models = rank_variants(
            &params,
            &cfg,
            QuantizerSpec::Mxint { bits: 3, block: 32 },
            &[0, 16],
            1,
            &mut rng,
        );
        // a singleton with its own buffers exercises the Single path too
        models.extend(rank_variants(
            &params,
            &cfg,
            QuantizerSpec::Mxint { bits: 3, block: 32 },
            &[16],
            2,
            &mut rng,
        ));
        let refs: Vec<&FactoredModel> = models.iter().collect();
        let ppl = fleet_perplexity(&refs, &cfg, &[], 2, cfg.seq_len).expect("well-formed fleet");
        assert_eq!(ppl.len(), 3);
        assert!(ppl.iter().all(|p| p.is_nan()), "{ppl:?}");
    }

    /// Regression (satellite bugfix): a group whose member is missing an
    /// op — the shape a partially spilled or hand-built fleet can take —
    /// must fail the job with a [`ServeError`], not panic the process
    /// via the old `expect("fleet group ops aligned")`.
    #[test]
    fn misaligned_group_member_is_a_serve_error_not_a_panic() {
        let cfg = tiny_cfg();
        let params = synth_lm_params(&cfg, 13, cfg.vocab);
        let mut rng = Rng::new(5);
        let spec = QuantizerSpec::Mxint { bits: 3, block: 32 };
        let mut mk = |name: &str| {
            let w = params.get_mat(name).expect("linear");
            let ctx = QuantCtx { hessian: None, seed: 1 };
            let (_, packed) = spec.build().quantize_coded(&w, &ctx);
            let mut skeleton = params.clone();
            skeleton.unset(name);
            let base = QuantBase::Packed(Arc::new(packed.expect("packable")));
            let (m, k) = (base.rows(), base.cols());
            let op = LinearOp::FactoredQlr {
                base,
                l: Mat::randn(m, 4, 0.05, &mut rng),
                r: Mat::randn(4, k, 0.05, &mut rng),
            };
            FactoredModel { skeleton, ops: vec![(name.to_string(), op)] }
        };
        // same op *count*, different op *names*: member 1 has no
        // "l0.wq" op, so the first stacked linear must refuse
        let a = mk("l0.wq");
        let b = mk("l0.wk");
        let fleet = FleetGroup::new(vec![&a, &b]);
        let tokens: Vec<i32> = (0..2 * cfg.seq_len).map(|i| (i % cfg.vocab) as i32).collect();
        let mask = vec![1.0f32; 2 * cfg.seq_len];
        let got = lm_nll_fleet(&fleet, &cfg, &tokens, &mask, 2, cfg.seq_len);
        assert!(
            matches!(got, Err(ServeError::UnknownTensor(ref n)) if n == "l0.wq"),
            "misaligned group must surface UnknownTensor, got {got:?}"
        );
    }
}
