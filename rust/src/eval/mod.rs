//! Evaluation engines over the PJRT artifacts.
//!
//! * [`ppl`] — perplexity on a held-out corpus via the `lm_nll_*`
//!   artifact (WikiText2 / SlimPajama analog), plus the rust-native
//!   [`ppl::perplexity_native`] that evaluates any `ModelWeights` —
//!   including the factored QLR serving model — without PJRT.
//! * [`zeroshot`] — option-ranking accuracy over the five probe tasks
//!   (lm-eval protocol: argmin per-option NLL).
//! * [`glue`] — GLUE-sim metric computation from classifier logits
//!   (accuracy / Matthews / Pearson+Spearman per task).
//! * [`gsm`] — teacher-forced exact-match on the arithmetic task.

pub mod ppl;
pub mod zeroshot;
pub mod glue;
pub mod gsm;

pub use glue::glue_score;
pub use gsm::gsm_exact_match;
pub use ppl::{perplexity, perplexity_native};
pub use zeroshot::zero_shot_accuracy;
