//! Evaluation engines: perplexity (PJRT and rust-native), zero-shot,
//! GLUE-sim, GSM-sim — plus the fleet evaluator for sweep outcomes.
//!
//! * [`ppl`] — perplexity on a held-out corpus via the `lm_nll_*`
//!   artifact (WikiText2 / SlimPajama analog), plus the rust-native
//!   [`ppl::perplexity_native`] that evaluates any
//!   [`ModelWeights`](crate::model::ModelWeights) — including the
//!   factored QLR serving model
//!   ([`FactoredModel`](crate::serve::FactoredModel)) — without PJRT.
//! * [`fleet`] — lock-step batched PPL over many sweep outcomes:
//!   outcomes sharing `Arc`-shared packed bases are grouped by buffer
//!   identity and forwarded together, decoding each base once per group
//!   per batch ([`fleet::fleet_perplexity`]).
//! * [`zeroshot`] — option-ranking accuracy over the five probe tasks
//!   (lm-eval protocol: argmin per-option NLL).
//! * [`glue`] — GLUE-sim metric computation from classifier logits
//!   (accuracy / Matthews / Pearson+Spearman per task).
//! * [`gsm`] — teacher-forced exact-match on the arithmetic task.

pub mod fleet;
pub mod ppl;
pub mod zeroshot;
pub mod glue;
pub mod gsm;

pub use fleet::{
    fleet_footprint, fleet_perplexity, group_by_shared_bases, FleetFootprint, FleetGroup,
};
pub use glue::glue_score;
pub use gsm::gsm_exact_match;
pub use ppl::{perplexity, perplexity_native, perplexity_native_masked};
pub use zeroshot::zero_shot_accuracy;
