//! GSM-sim exact-match: teacher-forced argmax at the answer positions
//! through the `lm_fwd_*` artifact. The answer at position p is predicted
//! by the logits at p−1 (next-token head).

use anyhow::Result;

use crate::data::gsm_sim::{GsmExample, GsmSim};
use crate::model::Params;
use crate::runtime::{Executor, TensorValue};

/// Fraction of test examples whose answer digits are all predicted
/// correctly. Works with either full-precision or QPEFT-adapted params —
/// the caller picks the artifact + params pairing.
pub fn gsm_exact_match(
    exec: &dyn Executor,
    artifact: &str,
    params: &Params,
    gsm: &GsmSim,
    examples: &[GsmExample],
    b: usize,
) -> Result<f64> {
    let base_inputs = params.flat()?;
    let t = gsm.seq;
    let vocab = gsm.vocab;
    let mut correct = 0usize;
    for chunk in examples.chunks(b) {
        let mut tokens = Vec::with_capacity(b * t);
        for e in chunk {
            tokens.extend_from_slice(&e.tokens);
        }
        while tokens.len() < b * t {
            tokens.extend(std::iter::repeat_n(0i32, t));
        }
        let mut inputs = base_inputs.clone();
        inputs.push(TensorValue::i32(vec![b, t], tokens));
        let outs = exec.run(artifact, &inputs)?;
        let logits = outs[0].as_f32(); // (b, t, vocab)
        for (row, ex) in chunk.iter().enumerate() {
            let all_right = ex.answer_positions.iter().all(|&p| {
                assert!(p > 0);
                let base = row * t * vocab + (p - 1) * vocab;
                let slice = &logits[base..base + vocab];
                let pred = slice
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as i32)
                    .unwrap_or(-1);
                pred == ex.tokens[p]
            });
            if all_right {
                correct += 1;
            }
        }
    }
    Ok(correct as f64 / examples.len().max(1) as f64 * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::MockExecutor;

    #[test]
    fn scores_argmax_at_answer_positions() {
        let gsm = GsmSim::generate(32, 12, 0, 6, 1);
        // oracle mock: put all logit mass on the true next token
        let examples = gsm.test.clone();
        let ex_copy = examples.clone();
        let mock = MockExecutor::empty().on("fwd", move |ins| {
            let tokens = ins[ins.len() - 1].as_i32();
            let b = ins[ins.len() - 1].shape()[0];
            let t = ins[ins.len() - 1].shape()[1];
            let vocab = 32;
            let mut logits = vec![0.0f32; b * t * vocab];
            for r in 0..b {
                for p in 0..t - 1 {
                    let next = tokens[r * t + p + 1] as usize;
                    logits[r * t * vocab + p * vocab + next] = 10.0;
                }
            }
            vec![TensorValue::f32(vec![b, t, vocab], logits)]
        });
        let params = Params::new(vec![]);
        let acc = gsm_exact_match(&mock, "fwd", &params, &gsm, &ex_copy, 4).unwrap();
        assert_eq!(acc, 100.0);
    }

    #[test]
    fn wrong_model_scores_low() {
        let gsm = GsmSim::generate(32, 12, 0, 10, 2);
        // mock always predicts token 0
        let mock = MockExecutor::empty().on("fwd", |ins| {
            let b = ins[ins.len() - 1].shape()[0];
            let t = ins[ins.len() - 1].shape()[1];
            let vocab = 32;
            let mut logits = vec![0.0f32; b * t * vocab];
            for r in 0..b {
                for p in 0..t {
                    logits[r * t * vocab + p * vocab] = 10.0;
                }
            }
            vec![TensorValue::f32(vec![b, t, vocab], logits)]
        });
        let params = Params::new(vec![]);
        let acc = gsm_exact_match(&mock, "fwd", &params, &gsm, &gsm.test, 4).unwrap();
        assert!(acc < 30.0);
    }
}
