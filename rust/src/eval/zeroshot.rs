//! Zero-shot option ranking (lm-eval protocol): score each candidate
//! continuation's masked NLL through `lm_nll_*`, predict the argmin.
//! Options are packed densely into the artifact's fixed batch size.

use anyhow::Result;

use crate::data::zeroshot::ZeroShotTask;
use crate::model::Params;
use crate::runtime::{Executor, TensorValue};

/// Accuracy of `params` on one probe task.
pub fn zero_shot_accuracy(
    exec: &dyn Executor,
    artifact: &str,
    params: &Params,
    task: &ZeroShotTask,
    b: usize,
    t: usize,
) -> Result<f64> {
    let base_inputs = params.flat()?;
    // flatten all (example, option) pairs into a scoring queue
    let mut queue: Vec<(usize, usize, &Vec<i32>, &Vec<f32>)> = Vec::new();
    for (ei, ex) in task.examples.iter().enumerate() {
        for (oi, (o, m)) in ex.options.iter().zip(&ex.masks).enumerate() {
            queue.push((ei, oi, o, m));
        }
    }
    let n_options = task.examples.first().map(|e| e.options.len()).unwrap_or(0);
    let mut scores = vec![vec![f64::INFINITY; n_options]; task.examples.len()];

    for chunk in queue.chunks(b) {
        let mut tokens = Vec::with_capacity(b * t);
        let mut mask = Vec::with_capacity(b * t);
        for (_, _, o, m) in chunk {
            tokens.extend_from_slice(o);
            mask.extend_from_slice(m);
        }
        // pad the tail of the last batch
        while tokens.len() < b * t {
            tokens.extend(std::iter::repeat_n(0i32, t));
            mask.extend(std::iter::repeat_n(0.0f32, t));
        }
        let mut inputs = base_inputs.clone();
        inputs.push(TensorValue::i32(vec![b, t], tokens));
        inputs.push(TensorValue::f32(vec![b, t], mask));
        let outs = exec.run(artifact, &inputs)?;
        let nll = outs[0].as_f32();
        let cnt = outs[1].as_f32();
        for (row, &(ei, oi, _, _)) in chunk.iter().enumerate() {
            // only real rows are read (padded rows land past `chunk`),
            // so a zero token count here is a broken mask, not padding —
            // erroring beats ranking options by a fabricated score (and
            // a silent NaN would poison the argmin below)
            anyhow::ensure!(
                cnt[row] > 0.0,
                "zero scored tokens for example {ei} option {oi}"
            );
            scores[ei][oi] = nll[row] as f64 / cnt[row] as f64;
        }
    }

    let mut correct = 0usize;
    for (ei, ex) in task.examples.iter().enumerate() {
        let pred = scores[ei]
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        if pred == ex.correct {
            correct += 1;
        }
    }
    Ok(correct as f64 / task.examples.len().max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::zeroshot::ZeroShotExample;
    use crate::runtime::MockExecutor;

    fn toy_task() -> ZeroShotTask {
        // 3 examples, 2 options each; "correct" options are all-sevens,
        // which the mock scores low.
        let mk = |correct: usize| {
            let options: Vec<Vec<i32>> = (0..2)
                .map(|o| vec![if o == correct { 7 } else { 1 }; 8])
                .collect();
            let masks = vec![vec![1.0f32; 8]; 2];
            ZeroShotExample { options, masks, correct }
        };
        ZeroShotTask { name: "toy", examples: vec![mk(0), mk(1), mk(0)] }
    }

    #[test]
    fn picks_lowest_nll_option() {
        let mock = MockExecutor::empty().on("nll", |ins| {
            let tokens = ins[ins.len() - 2].as_i32();
            let b = ins[ins.len() - 2].shape()[0];
            let t = ins[ins.len() - 2].shape()[1];
            let nll: Vec<f32> = (0..b)
                .map(|r| if tokens[r * t] == 7 { 1.0 } else { 5.0 })
                .collect();
            vec![
                TensorValue::f32(vec![b], nll),
                TensorValue::f32(vec![b], vec![t as f32; b]),
            ]
        });
        let params = Params::new(vec![]);
        let acc = zero_shot_accuracy(&mock, "nll", &params, &toy_task(), 4, 8).unwrap();
        assert_eq!(acc, 1.0);
    }

    #[test]
    fn handles_batch_padding() {
        // batch 4 with 6 scoring rows -> 2 batches, last padded
        let mock = MockExecutor::empty().on("nll", |ins| {
            let b = ins[ins.len() - 2].shape()[0];
            vec![
                TensorValue::f32(vec![b], vec![1.0; b]),
                TensorValue::f32(vec![b], vec![8.0; b]),
            ]
        });
        let params = Params::new(vec![]);
        let acc = zero_shot_accuracy(&mock, "nll", &params, &toy_task(), 4, 8).unwrap();
        assert_eq!(mock.call_count("nll"), 2);
        assert!((0.0..=1.0).contains(&acc));
    }

    /// Regression (zero-token contract): a scored row with zero counted
    /// tokens is an error, never a fabricated per-token score.
    #[test]
    fn zero_token_option_is_an_error() {
        let mock = MockExecutor::empty().on("nll", |ins| {
            let b = ins[ins.len() - 2].shape()[0];
            vec![
                TensorValue::f32(vec![b], vec![1.0; b]),
                TensorValue::f32(vec![b], vec![0.0; b]),
            ]
        });
        let params = Params::new(vec![]);
        let err =
            zero_shot_accuracy(&mock, "nll", &params, &toy_task(), 4, 8).unwrap_err();
        assert!(err.to_string().contains("zero scored tokens"), "{err}");
    }
}
