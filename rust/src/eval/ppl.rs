//! Perplexity engines: exp(Σ nll / Σ tokens) over eval batches.
//!
//! * [`perplexity`] — through the `lm_nll_<model>` PJRT artifact (all
//!   masking on-device). The weight tensors are marshalled once; each
//!   batch only overwrites the token slot (no per-batch re-clone of the
//!   full flattened params), and the all-ones mask tensor is built once
//!   per run, not once per batch.
//! * [`perplexity_native`] — pure rust over any
//!   [`ModelWeights`](crate::model::ModelWeights): dense params or the
//!   factored QLR serving model
//!   ([`FactoredModel`](crate::serve::FactoredModel)), which streams its
//!   packed bases — PPL without PJRT and without densifying `W_hat`.
//!   [`perplexity_native_masked`] is the same engine with the mask
//!   hoisted by the caller; the fleet evaluator
//!   ([`crate::eval::fleet`]) shares one mask allocation across every
//!   outcome it scores.

use anyhow::Result;

use crate::model::forward::lm_nll_with;
use crate::model::{ModelWeights, Params};
use crate::runtime::manifest::ModelCfg;
use crate::runtime::{Executor, TensorValue};

/// Perplexity of `params` on `batches` (each row-major (b, t) tokens).
pub fn perplexity(
    exec: &dyn Executor,
    artifact: &str,
    params: &Params,
    batches: &[Vec<i32>],
    b: usize,
    t: usize,
) -> Result<f64> {
    let mut inputs = params.flat()?;
    let tok_slot = inputs.len();
    // marshal the weights once; reserve a token slot that each batch
    // overwrites, and build the all-ones mask once for the whole run
    inputs.push(TensorValue::i32(vec![b, t], vec![0; b * t]));
    inputs.push(TensorValue::f32(vec![b, t], vec![1.0; b * t]));
    let mut total_nll = 0.0f64;
    let mut total_tok = 0.0f64;
    for batch in batches {
        inputs[tok_slot] = TensorValue::i32(vec![b, t], batch.clone());
        let outs = exec.run(artifact, &inputs)?;
        total_nll += outs[0].as_f32().iter().map(|&x| x as f64).sum::<f64>();
        total_tok += outs[1].as_f32().iter().map(|&x| x as f64).sum::<f64>();
    }
    // zero scored tokens would make any finite PPL a fabrication —
    // surface the misconfiguration (empty batch list, all-zero mask)
    // instead of reporting exp(0/1) = 1.0 as if the model were perfect
    anyhow::ensure!(
        total_tok > 0.0,
        "perplexity over zero scored tokens ({} batches)",
        batches.len()
    );
    Ok((total_nll / total_tok).exp())
}

/// Rust-native perplexity over any [`ModelWeights`] — the factored QLR
/// serving path evaluates PPL here with no PJRT and no dense `W_hat`.
pub fn perplexity_native(
    weights: &dyn ModelWeights,
    cfg: &ModelCfg,
    batches: &[Vec<i32>],
    b: usize,
    t: usize,
) -> f64 {
    perplexity_native_masked(weights, cfg, batches, &vec![1.0f32; b * t], b, t)
}

/// [`perplexity_native`] with the (all-ones) mask allocated by the
/// caller, so loops that score many models over the same batches — the
/// fleet evaluator, the serving benches — share one allocation instead
/// of re-building it per call.
///
/// **Zero-token contract:** scoring zero tokens (empty batch list,
/// all-zero mask) returns `NaN`, never a bogus finite PPL — the same
/// contract as [`crate::eval::fleet::fleet_perplexity`]. The
/// `Result`-returning engines ([`perplexity`],
/// [`crate::eval::zeroshot::zero_shot_accuracy`]) make the same
/// condition a hard error instead.
pub fn perplexity_native_masked(
    weights: &dyn ModelWeights,
    cfg: &ModelCfg,
    batches: &[Vec<i32>],
    mask: &[f32],
    b: usize,
    t: usize,
) -> f64 {
    let mut total_nll = 0.0f64;
    let mut total_tok = 0.0f64;
    for batch in batches {
        let (nll, cnt) = lm_nll_with(weights, cfg, batch, mask, b, t);
        total_nll += nll.iter().sum::<f64>();
        total_tok += cnt.iter().sum::<f64>();
    }
    if total_tok == 0.0 {
        return f64::NAN; // documented zero-token contract
    }
    (total_nll / total_tok).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synth::synth_lm_params;
    use crate::runtime::MockExecutor;
    use crate::util::Rng;

    #[test]
    fn aggregates_across_batches() {
        // mock: per-seq nll = 2.0 per token over t-1 tokens
        let mock = MockExecutor::empty().on("nll", |ins| {
            let tokens = &ins[ins.len() - 2];
            let b = tokens.shape()[0];
            let t = tokens.shape()[1];
            vec![
                TensorValue::f32(vec![b], vec![2.0 * (t as f32 - 1.0); b]),
                TensorValue::f32(vec![b], vec![t as f32 - 1.0; b]),
            ]
        });
        let params = Params::new(vec![]);
        let batches = vec![vec![0i32; 8]; 3];
        let ppl = perplexity(&mock, "nll", &params, &batches, 2, 4).unwrap();
        assert!((ppl - (2.0f64).exp()).abs() < 1e-9);
        assert_eq!(mock.call_count("nll"), 3);
    }

    #[test]
    fn batch_tensors_do_not_accumulate_across_iterations() {
        // the no-re-clone refactor must still hand each call exactly
        // base + 2 inputs (a bug here would grow the arg list per batch)
        let mock = MockExecutor::empty().on("nll", |ins| {
            assert_eq!(ins.len(), 2, "weights(0) + tokens + mask");
            let b = ins[0].shape()[0];
            vec![
                TensorValue::f32(vec![b], vec![1.0; b]),
                TensorValue::f32(vec![b], vec![1.0; b]),
            ]
        });
        let params = Params::new(vec![]);
        let batches = vec![vec![0i32; 6]; 4];
        let ppl = perplexity(&mock, "nll", &params, &batches, 2, 3).unwrap();
        assert!(ppl.is_finite());
        assert_eq!(mock.call_count("nll"), 4);
    }

    /// Regression (zero-token contract): an empty batch list or an
    /// all-zero token count must never produce a finite "PPL 1.0" — the
    /// executor path errors, the native path returns NaN.
    #[test]
    fn zero_scored_tokens_error_or_nan_not_bogus_ppl() {
        let mock = MockExecutor::empty().on("nll", |ins| {
            let b = ins[ins.len() - 2].shape()[0];
            vec![
                TensorValue::f32(vec![b], vec![0.0; b]),
                TensorValue::f32(vec![b], vec![0.0; b]), // zero tokens counted
            ]
        });
        let params = Params::new(vec![]);
        let err = perplexity(&mock, "nll", &params, &[], 2, 4).unwrap_err();
        assert!(err.to_string().contains("zero scored tokens"), "{err}");
        let err =
            perplexity(&mock, "nll", &params, &[vec![0i32; 8]], 2, 4).unwrap_err();
        assert!(err.to_string().contains("zero scored tokens"), "{err}");

        let cfg = ModelCfg {
            name: "t".into(),
            vocab: 32,
            d_model: 16,
            n_heads: 2,
            n_layers: 1,
            d_ff: 24,
            seq_len: 8,
        };
        let native_params = synth_lm_params(&cfg, 11, cfg.vocab);
        assert!(perplexity_native(&native_params, &cfg, &[], 2, 8).is_nan());
        let zero_mask = vec![0.0f32; 16];
        assert!(perplexity_native_masked(
            &native_params,
            &cfg,
            &[vec![1i32; 16]],
            &zero_mask,
            2,
            8
        )
        .is_nan());
    }

    #[test]
    fn native_ppl_is_finite_and_matches_manual_nll() {
        let cfg = ModelCfg {
            name: "t".into(),
            vocab: 32,
            d_model: 16,
            n_heads: 2,
            n_layers: 1,
            d_ff: 24,
            seq_len: 8,
        };
        let params = synth_lm_params(&cfg, 11, cfg.vocab);
        let mut rng = Rng::new(12);
        let batches: Vec<Vec<i32>> =
            (0..2).map(|_| (0..2 * 8).map(|_| rng.below(32) as i32).collect()).collect();
        let ppl = perplexity_native(&params, &cfg, &batches, 2, 8);
        assert!(ppl.is_finite() && ppl > 1.0);

        let mask = vec![1.0f32; 16];
        let mut nll = 0.0;
        let mut tok = 0.0;
        for batch in &batches {
            let (n, c) = crate::model::forward::lm_nll(&params, &cfg, batch, &mask, 2, 8);
            nll += n.iter().sum::<f64>();
            tok += c.iter().sum::<f64>();
        }
        assert!((ppl - (nll / tok).exp()).abs() < 1e-12);
    }
}
