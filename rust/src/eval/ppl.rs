//! Perplexity engine: exp(Σ nll / Σ tokens) over eval batches, computed
//! through the `lm_nll_<model>` artifact (all masking on-device).

use anyhow::Result;

use crate::model::Params;
use crate::runtime::{Executor, TensorValue};

/// Perplexity of `params` on `batches` (each row-major (b, t) tokens).
pub fn perplexity(
    exec: &dyn Executor,
    artifact: &str,
    params: &Params,
    batches: &[Vec<i32>],
    b: usize,
    t: usize,
) -> Result<f64> {
    let base_inputs = params.flat()?;
    let mut total_nll = 0.0f64;
    let mut total_tok = 0.0f64;
    for batch in batches {
        let mut inputs = base_inputs.clone();
        inputs.push(TensorValue::i32(vec![b, t], batch.clone()));
        inputs.push(TensorValue::f32(vec![b, t], vec![1.0; b * t]));
        let outs = exec.run(artifact, &inputs)?;
        total_nll += outs[0].as_f32().iter().map(|&x| x as f64).sum::<f64>();
        total_tok += outs[1].as_f32().iter().map(|&x| x as f64).sum::<f64>();
    }
    Ok((total_nll / total_tok.max(1.0)).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::MockExecutor;

    #[test]
    fn aggregates_across_batches() {
        // mock: per-seq nll = 2.0 per token over t-1 tokens
        let mock = MockExecutor::empty().on("nll", |ins| {
            let tokens = &ins[ins.len() - 2];
            let b = tokens.shape()[0];
            let t = tokens.shape()[1];
            vec![
                TensorValue::f32(vec![b], vec![2.0 * (t as f32 - 1.0); b]),
                TensorValue::f32(vec![b], vec![t as f32 - 1.0; b]),
            ]
        });
        let params = Params::new(vec![]);
        let batches = vec![vec![0i32; 8]; 3];
        let ppl = perplexity(&mock, "nll", &params, &batches, 2, 4).unwrap();
        assert!((ppl - (2.0f64).exp()).abs() < 1e-9);
        assert_eq!(mock.call_count("nll"), 3);
    }
}
