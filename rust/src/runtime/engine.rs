//! The artifact executor.
//!
//! [`Engine`] owns the PJRT CPU client plus a compile cache: each HLO text
//! artifact is parsed (`HloModuleProto::from_text_file` — text is the
//! interchange format, see DESIGN.md §6) and compiled at most once, then
//! executed any number of times from the request path.
//!
//! PJRT is opt-in (`--features pjrt`): the default build ships a
//! manifest-only [`Engine`] whose `run` returns an error, so everything
//! that never executes an artifact — quantization, QER/SRR, sweeps, the
//! property tests — builds and runs without an XLA toolchain. Tests and
//! benches gate on `Engine::discover()` and skip cleanly when artifacts
//! are absent.
//!
//! [`Executor`] abstracts execution so the coordinator / eval / QPEFT
//! stacks are testable without PJRT ([`MockExecutor`]).

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{anyhow, Result};
#[cfg(feature = "pjrt")]
use anyhow::Context;

use super::manifest::Manifest;
use super::tensor_value::TensorValue;

/// Anything that can run a named artifact on typed host tensors.
///
/// NOT `Send`/`Sync`: the underlying PJRT client is `Rc`-based, so one
/// engine serves one thread; XLA's CPU backend parallelizes internally.
/// The coordinator's own parallelism lives in the pure-rust quantization
/// stages, not in artifact execution.
pub trait Executor {
    fn run(&self, artifact: &str, inputs: &[TensorValue]) -> Result<Vec<TensorValue>>;
    fn manifest(&self) -> &Manifest;
}

// ---------------------------------------------------------------------------
// PJRT engine (feature = "pjrt")
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: std::cell::RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
}

#[cfg(feature = "pjrt")]
impl Engine {
    pub fn new(manifest: Manifest) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, manifest, cache: std::cell::RefCell::new(HashMap::new()) })
    }

    pub fn discover() -> Result<Engine> {
        Engine::new(Manifest::discover()?)
    }

    fn executable(&self, name: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let path = self.manifest.hlo_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::rc::Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))?,
        );
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of artifacts compiled so far (metrics / tests).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }

    fn to_literal(t: &TensorValue) -> Result<xla::Literal> {
        let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
        let lit = match t {
            TensorValue::F32 { data, .. } => xla::Literal::vec1(data),
            TensorValue::I32 { data, .. } => xla::Literal::vec1(data),
        };
        Ok(lit.reshape(&dims)?)
    }

    fn from_literal(lit: &xla::Literal) -> Result<TensorValue> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(TensorValue::F32 { shape: dims, data: lit.to_vec::<f32>()? }),
            xla::ElementType::S32 => Ok(TensorValue::I32 { shape: dims, data: lit.to_vec::<i32>()? }),
            ty => Err(anyhow!("unsupported output element type {ty:?}")),
        }
    }
}

#[cfg(feature = "pjrt")]
impl Executor for Engine {
    fn run(&self, artifact: &str, inputs: &[TensorValue]) -> Result<Vec<TensorValue>> {
        validate_inputs(&self.manifest, artifact, inputs)?;
        let exe = self.executable(artifact)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(Self::to_literal).collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?;
        // single-device: result[0][0] is the tuple of outputs
        let root = result[0][0].to_literal_sync()?;
        let parts = root.to_tuple()?;
        parts.iter().map(Self::from_literal).collect()
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }
}

// ---------------------------------------------------------------------------
// Manifest-only engine (default build, no PJRT)
// ---------------------------------------------------------------------------

#[cfg(not(feature = "pjrt"))]
pub struct Engine {
    manifest: Manifest,
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    pub fn new(manifest: Manifest) -> Result<Engine> {
        Ok(Engine { manifest })
    }

    pub fn discover() -> Result<Engine> {
        Engine::new(Manifest::discover()?)
    }

    /// Number of artifacts compiled so far (always 0 without PJRT).
    pub fn compiled_count(&self) -> usize {
        0
    }
}

#[cfg(not(feature = "pjrt"))]
impl Executor for Engine {
    fn run(&self, artifact: &str, inputs: &[TensorValue]) -> Result<Vec<TensorValue>> {
        validate_inputs(&self.manifest, artifact, inputs)?;
        Err(anyhow!(
            "artifact '{artifact}': PJRT execution requires building with \
             `--features pjrt` (and `make artifacts`)"
        ))
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }
}

/// Shape/dtype check shared by both engine flavors.
fn validate_inputs(manifest: &Manifest, name: &str, inputs: &[TensorValue]) -> Result<()> {
    let spec = manifest.artifact(name)?;
    if spec.args.len() != inputs.len() {
        return Err(anyhow!(
            "{name}: expected {} args, got {}",
            spec.args.len(),
            inputs.len()
        ));
    }
    for (i, (arg, t)) in spec.args.iter().zip(inputs).enumerate() {
        if arg.shape != t.shape() || arg.dtype != t.dtype() {
            return Err(anyhow!(
                "{name} arg {i} ({}): expected {:?} {}, got {:?} {}",
                arg.name,
                arg.shape,
                arg.dtype,
                t.shape(),
                t.dtype()
            ));
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Mock executor (tests)
// ---------------------------------------------------------------------------

type MockFn = Box<dyn Fn(&[TensorValue]) -> Vec<TensorValue>>;

/// Test double: routes artifact names to closures and records call counts.
pub struct MockExecutor {
    manifest: Manifest,
    handlers: HashMap<String, MockFn>,
    pub calls: Mutex<Vec<String>>,
}

impl MockExecutor {
    pub fn new(manifest: Manifest) -> Self {
        MockExecutor { manifest, handlers: HashMap::new(), calls: Mutex::new(vec![]) }
    }

    /// Minimal empty manifest for pure-coordinator tests.
    pub fn empty() -> Self {
        let manifest = Manifest::parse(
            r#"{"models": {}, "artifacts": [], "constants": {}}"#,
            std::path::PathBuf::from("/nonexistent"),
        )
        .unwrap();
        Self::new(manifest)
    }

    pub fn on(mut self, artifact: &str, f: impl Fn(&[TensorValue]) -> Vec<TensorValue> + 'static) -> Self {
        self.handlers.insert(artifact.to_string(), Box::new(f));
        self
    }

    pub fn call_count(&self, artifact: &str) -> usize {
        self.calls.lock().unwrap().iter().filter(|c| c.as_str() == artifact).count()
    }
}

impl Executor for MockExecutor {
    fn run(&self, artifact: &str, inputs: &[TensorValue]) -> Result<Vec<TensorValue>> {
        self.calls.lock().unwrap().push(artifact.to_string());
        let h = self
            .handlers
            .get(artifact)
            .ok_or_else(|| anyhow!("mock has no handler for {artifact}"))?;
        Ok(h(inputs))
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_routes_and_counts() {
        let mock = MockExecutor::empty().on("echo", |ins| ins.to_vec());
        let input = vec![TensorValue::scalar_f32(7.0)];
        let out = mock.run("echo", &input).unwrap();
        assert_eq!(out, input);
        assert_eq!(mock.call_count("echo"), 1);
        assert!(mock.run("missing", &input).is_err());
    }

    #[test]
    fn manifest_only_engine_reports_missing_pjrt() {
        // only meaningful for the default build; with pjrt the same call
        // path is exercised by the integration tests against artifacts
        if cfg!(feature = "pjrt") {
            return;
        }
        let manifest = Manifest::parse(
            r#"{"models": {}, "constants": {},
                "artifacts": [{"name": "echo", "file": "echo.hlo.txt",
                               "args": [{"name": "x", "shape": [1], "dtype": "f32"}],
                               "outputs": [{"shape": [1], "dtype": "f32"}]}]}"#,
            std::path::PathBuf::from("/nonexistent"),
        )
        .unwrap();
        let eng = Engine::new(manifest).unwrap();
        assert_eq!(eng.compiled_count(), 0);
        let err = eng
            .run("echo", &[TensorValue::f32(vec![1], vec![0.0])])
            .unwrap_err()
            .to_string();
        assert!(err.contains("pjrt"), "unexpected error: {err}");
        // shape validation still applies before the feature gate
        let shape_err = eng
            .run("echo", &[TensorValue::f32(vec![2], vec![0.0, 0.0])])
            .unwrap_err()
            .to_string();
        assert!(shape_err.contains("arg 0"), "unexpected error: {shape_err}");
    }
}
