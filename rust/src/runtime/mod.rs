//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) produced
//! by `make artifacts` and executes them from the request path.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (arg/output specs,
//!   model configs, parameter orders); the contract with python/compile.
//! * [`tensor_value`] — host-side typed tensors (f32 / i32 + shape) that
//!   marshal to/from `xla::Literal`.
//! * [`engine`] — the executor: PJRT CPU client + per-artifact compile
//!   cache; also defines the [`Executor`] trait and a mock implementation
//!   the coordinator tests run against without PJRT.

pub mod manifest;
pub mod tensor_value;
pub mod engine;

pub use engine::{Engine, Executor, MockExecutor};
pub use manifest::{ArgSpec, ArtifactSpec, Manifest};
pub use tensor_value::TensorValue;
