//! The artifact manifest: the compile-time contract with python/compile.
//!
//! aot.py writes `artifacts/manifest.json` describing every lowered HLO
//! module (positional args with name/shape/dtype, outputs), the model
//! configs, canonical parameter orders and linear-layer names. Everything
//! shape-dependent on the rust side is driven from here — never
//! hard-coded twice.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub args: Vec<ArgSpec>,
    pub outputs: Vec<ArgSpec>,
}

/// Mirrors python/compile/configs.py::ModelCfg.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelCfg {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub seq_len: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelCfg>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub param_order: BTreeMap<String, Vec<String>>,
    pub linear_names: BTreeMap<String, Vec<String>>,
    pub lm_batch: usize,
    pub cls_batch: usize,
    pub cls_seq: usize,
    pub cls_classes: usize,
}

fn parse_specs(v: &Json) -> Result<Vec<ArgSpec>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("expected array of specs"))?
        .iter()
        .map(|a| {
            Ok(ArgSpec {
                name: a
                    .get("name")
                    .and_then(|x| x.as_str())
                    .unwrap_or_default()
                    .to_string(),
                shape: a
                    .get("shape")
                    .and_then(|x| x.as_arr())
                    .ok_or_else(|| anyhow!("spec missing shape"))?
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect(),
                dtype: a
                    .get("dtype")
                    .and_then(|x| x.as_str())
                    .unwrap_or("f32")
                    .to_string(),
            })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text, dir)
    }

    /// Locate the artifacts dir next to the current exe / cwd.
    pub fn discover() -> Result<Manifest> {
        for cand in ["artifacts", "../artifacts", "../../artifacts"] {
            if Path::new(cand).join("manifest.json").exists() {
                return Manifest::load(cand);
            }
        }
        Err(anyhow!("artifacts/manifest.json not found — run `make artifacts`"))
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest parse error: {e}"))?;

        let mut models = BTreeMap::new();
        if let Some(obj) = j.get("models").and_then(|m| m.as_obj()) {
            for (name, m) in obj {
                let g = |k: &str| m.get(k).and_then(|x| x.as_usize()).unwrap_or(0);
                models.insert(
                    name.clone(),
                    ModelCfg {
                        name: name.clone(),
                        vocab: g("vocab"),
                        d_model: g("d_model"),
                        n_heads: g("n_heads"),
                        n_layers: g("n_layers"),
                        d_ff: g("d_ff"),
                        seq_len: g("seq_len"),
                    },
                );
            }
        }

        let mut artifacts = BTreeMap::new();
        for a in j
            .get("artifacts")
            .and_then(|x| x.as_arr())
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let name = a
                .get("name")
                .and_then(|x| x.as_str())
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name,
                    file: a
                        .get("file")
                        .and_then(|x| x.as_str())
                        .unwrap_or_default()
                        .to_string(),
                    args: parse_specs(a.get("args").ok_or_else(|| anyhow!("missing args"))?)?,
                    outputs: parse_specs(
                        a.get("outputs").ok_or_else(|| anyhow!("missing outputs"))?,
                    )?,
                },
            );
        }

        let str_lists = |key: &str| -> BTreeMap<String, Vec<String>> {
            j.get(key)
                .and_then(|x| x.as_obj())
                .map(|obj| {
                    obj.iter()
                        .map(|(k, v)| {
                            let list = v
                                .as_arr()
                                .map(|a| {
                                    a.iter()
                                        .filter_map(|s| s.as_str().map(|x| x.to_string()))
                                        .collect()
                                })
                                .unwrap_or_default();
                            (k.clone(), list)
                        })
                        .collect()
                })
                .unwrap_or_default()
        };
        let param_order = str_lists("param_order");
        let linear_names = str_lists("linear_names");

        let consts = j.get("constants");
        let getc = |k: &str, d: usize| {
            consts
                .and_then(|c| c.get(k))
                .and_then(|x| x.as_usize())
                .unwrap_or(d)
        };

        Ok(Manifest {
            dir,
            models,
            artifacts,
            param_order,
            linear_names,
            lm_batch: getc("lm_batch", 8),
            cls_batch: getc("cls_batch", 16),
            cls_seq: getc("cls_seq", 32),
            cls_classes: getc("cls_classes", 4),
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelCfg> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model {name} not in manifest"))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "models": {"tiny": {"vocab": 256, "d_model": 128, "n_heads": 4,
                           "n_layers": 2, "d_ff": 512, "seq_len": 64}},
      "constants": {"lm_batch": 8, "cls_batch": 16, "cls_seq": 32,
                    "cls_classes": 4, "qpeft_ranks": [8, 64]},
      "param_order": {"tiny": ["embed", "l0.ln1", "head"]},
      "linear_names": {"tiny": ["l0.wq", "l0.down"]},
      "artifacts": [
        {"name": "lm_fwd_tiny", "file": "lm_fwd_tiny.hlo.txt",
         "args": [{"name": "embed", "shape": [256, 128], "dtype": "f32"},
                  {"name": "tokens", "shape": [8, 64], "dtype": "i32"}],
         "outputs": [{"shape": [8, 64, 256], "dtype": "f32"}]}
      ]
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        let cfg = m.model("tiny").unwrap();
        assert_eq!(cfg.d_model, 128);
        assert_eq!(cfg.n_layers, 2);
        let a = m.artifact("lm_fwd_tiny").unwrap();
        assert_eq!(a.args.len(), 2);
        assert_eq!(a.args[1].dtype, "i32");
        assert_eq!(a.outputs[0].shape, vec![8, 64, 256]);
        assert_eq!(m.param_order["tiny"].len(), 3);
        assert_eq!(m.lm_batch, 8);
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert!(m.artifact("nope").is_err());
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // integration-lite: if `make artifacts` has run, the real file parses
        if let Ok(m) = Manifest::discover() {
            assert!(m.artifacts.contains_key("lm_fwd_tiny"));
            assert!(m.models.contains_key("small"));
            assert_eq!(m.param_order["tiny"].first().map(|s| s.as_str()), Some("embed"));
        }
    }
}
