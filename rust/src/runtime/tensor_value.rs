//! Host tensors crossing the PJRT boundary.

use crate::tensor::Mat;

/// A typed host tensor: the unit of exchange with the artifacts.
#[derive(Clone, Debug, PartialEq)]
pub enum TensorValue {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl TensorValue {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "f32 shape/data mismatch");
        TensorValue::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "i32 shape/data mismatch");
        TensorValue::I32 { shape, data }
    }

    pub fn scalar_f32(x: f32) -> Self {
        TensorValue::F32 { shape: vec![], data: vec![x] }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        TensorValue::F32 { shape, data: vec![0.0; n] }
    }

    pub fn from_mat(m: &Mat) -> Self {
        TensorValue::F32 { shape: vec![m.rows, m.cols], data: m.data.clone() }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            TensorValue::F32 { shape, .. } | TensorValue::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            TensorValue::F32 { .. } => "f32",
            TensorValue::I32 { .. } => "i32",
        }
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            TensorValue::F32 { data, .. } => data,
            _ => panic!("expected f32 tensor"),
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match self {
            TensorValue::F32 { data, .. } => data,
            _ => panic!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match self {
            TensorValue::I32 { data, .. } => data,
            _ => panic!("expected i32 tensor"),
        }
    }

    /// View a 2-D f32 tensor as a Mat (copies).
    pub fn to_mat(&self) -> Mat {
        let shape = self.shape();
        assert_eq!(shape.len(), 2, "to_mat needs rank 2, got {shape:?}");
        Mat::from_vec(shape[0], shape[1], self.as_f32().to_vec())
    }

    pub fn scalar(&self) -> f32 {
        assert_eq!(self.len(), 1, "scalar() on non-scalar {:?}", self.shape());
        self.as_f32()[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_mat() {
        let m = Mat::from_fn(2, 3, |i, j| (i * 3 + j) as f32);
        let t = TensorValue::from_mat(&m);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.to_mat(), m);
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn shape_mismatch_panics() {
        TensorValue::f32(vec![2, 2], vec![1.0; 3]);
    }

    #[test]
    fn dtype_and_scalar() {
        assert_eq!(TensorValue::scalar_f32(2.5).scalar(), 2.5);
        assert_eq!(TensorValue::i32(vec![2], vec![1, 2]).dtype(), "i32");
        assert_eq!(TensorValue::zeros(vec![3, 4]).len(), 12);
    }
}
