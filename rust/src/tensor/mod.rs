//! Dense row-major f32 matrix substrate.
//!
//! The whole algorithmic stack (quantizers, SVDs, SRR) runs on [`Mat`].
//! Dot products accumulate in f64 where precision matters (norms, Gram
//! entries); the blocked multithreaded matmul accumulates in f32 per the
//! usual GEMM practice — adequate at our dimensions (<= 4096) and matching
//! XLA's own f32 GEMM behaviour.

mod matrix;
mod ops;

pub use matrix::Mat;
pub use ops::{matmul, matmul_nt, matmul_tn};
