//! The [`Mat`] type: dense, row-major, f32.

use crate::util::Rng;

/// Dense row-major matrix of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// i.i.d. N(0, std²) entries.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Self {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_normal(&mut m.data, std);
        m
    }

    /// i.i.d. U[lo, hi) entries — the SRR random probe uses U[-1, 1].
    pub fn rand_uniform(rows: usize, cols: usize, lo: f32, hi: f32, rng: &mut Rng) -> Self {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_uniform(&mut m.data, lo, hi);
        m
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    pub fn set_col(&mut self, j: usize, v: &[f32]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            *self.at_mut(i, j) = v[i];
        }
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// Frobenius norm (f64 accumulation).
    pub fn frob(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Squared Frobenius norm (f64 accumulation).
    pub fn frob2(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    pub fn scale(&self, s: f32) -> Mat {
        let data = self.data.iter().map(|a| a * s).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Scale row i by d[i] (left-multiply by diag(d)).
    pub fn scale_rows(&self, d: &[f32]) -> Mat {
        assert_eq!(d.len(), self.rows);
        let mut out = self.clone();
        for i in 0..self.rows {
            let s = d[i];
            for v in out.row_mut(i) {
                *v *= s;
            }
        }
        out
    }

    /// Columns [j0, j1) as a new matrix.
    pub fn cols_slice(&self, j0: usize, j1: usize) -> Mat {
        assert!(j0 <= j1 && j1 <= self.cols);
        let mut out = Mat::zeros(self.rows, j1 - j0);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[j0..j1]);
        }
        out
    }

    /// Rows [i0, i1) as a new matrix.
    pub fn rows_slice(&self, i0: usize, i1: usize) -> Mat {
        assert!(i0 <= i1 && i1 <= self.rows);
        Mat::from_vec(i1 - i0, self.cols, self.data[i0 * self.cols..i1 * self.cols].to_vec())
    }

    /// Horizontal concatenation [A | B].
    pub fn hcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows);
        let mut out = Mat::zeros(self.rows, self.cols + other.cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
            out.row_mut(i)[self.cols..].copy_from_slice(other.row(i));
        }
        out
    }

    /// Vertical concatenation [A; B].
    pub fn vcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Mat::from_vec(self.rows + other.rows, self.cols, data)
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn allclose(&self, other: &Mat, atol: f32) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && self.data.iter().zip(&other.data).all(|(a, b)| (a - b).abs() <= atol)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let m = Mat::from_fn(3, 4, |i, j| (i * 10 + j) as f32);
        assert_eq!(m.at(2, 3), 23.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0, 13.0]);
        assert_eq!(m.col(2), vec![2.0, 12.0, 22.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let m = Mat::randn(5, 7, 1.0, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn frob_matches_manual() {
        let m = Mat::from_vec(1, 3, vec![3.0, 4.0, 0.0]);
        assert!((m.frob() - 5.0).abs() < 1e-12);
        assert!((m.frob2() - 25.0).abs() < 1e-12);
    }

    #[test]
    fn slicing_and_concat_roundtrip() {
        let mut rng = Rng::new(2);
        let m = Mat::randn(6, 8, 1.0, &mut rng);
        let left = m.cols_slice(0, 3);
        let right = m.cols_slice(3, 8);
        assert_eq!(left.hcat(&right), m);
        let top = m.rows_slice(0, 2);
        let bot = m.rows_slice(2, 6);
        assert_eq!(top.vcat(&bot), m);
    }

    #[test]
    fn scale_rows_is_diag_mul() {
        let m = Mat::from_fn(2, 2, |i, j| (i + j) as f32 + 1.0);
        let d = [2.0, 3.0];
        let s = m.scale_rows(&d);
        assert_eq!(s.at(0, 0), 2.0);
        assert_eq!(s.at(1, 1), 9.0);
    }

    #[test]
    fn arithmetic() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::eye(2);
        assert_eq!(a.add(&b).at(0, 0), 2.0);
        assert_eq!(a.sub(&b).at(1, 1), 3.0);
        assert_eq!(a.scale(2.0).at(0, 1), 4.0);
    }
}
