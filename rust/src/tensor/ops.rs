//! Blocked, multithreaded matrix multiplication.
//!
//! The classic ikj micro-kernel with row-panel parallelism via scoped
//! threads. At our sizes (<= 4096²) this reaches a few GFLOP/s per core —
//! enough that the coordinator pipeline, not the GEMM, dominates wall
//! clock (profiled in EXPERIMENTS.md §Perf; the PJRT-side GEMMs run inside
//! XLA and don't use this path).

use super::Mat;
use crate::util::pool;

/// C = A · B.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch: {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Mat::zeros(m, n);
    let bdata = &b.data;
    let adata = &a.data;
    pool::par_chunks_mut(&mut c.data, n, |i0, rows| {
        // rows = C[i0..i0+h] flattened
        for (di, crow) in rows.chunks_mut(n).enumerate() {
            let i = i0 + di;
            let arow = &adata[i * k..(i + 1) * k];
            for (kk, &aik) in arow.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let brow = &bdata[kk * n..(kk + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += aik * bv;
                }
            }
        }
    });
    c
}

/// C = Aᵀ · B  (A is k×m, B is k×n, C is m×n).
pub fn matmul_tn(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, b.rows, "matmul_tn shape mismatch");
    let (k, m, n) = (a.rows, a.cols, b.cols);
    let mut c = Mat::zeros(m, n);
    let adata = &a.data;
    let bdata = &b.data;
    pool::par_chunks_mut(&mut c.data, n, |i0, rows| {
        for (di, crow) in rows.chunks_mut(n).enumerate() {
            let i = i0 + di; // column i of A = row i of C
            for kk in 0..k {
                let aik = adata[kk * m + i];
                if aik == 0.0 {
                    continue;
                }
                let brow = &bdata[kk * n..(kk + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += aik * bv;
                }
            }
        }
    });
    c
}

/// C = A · Bᵀ  (A is m×k, B is n×k, C is m×n).
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_nt shape mismatch");
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut c = Mat::zeros(m, n);
    let adata = &a.data;
    let bdata = &b.data;
    pool::par_chunks_mut(&mut c.data, n, |i0, rows| {
        for (di, crow) in rows.chunks_mut(n).enumerate() {
            let i = i0 + di;
            let arow = &adata[i * k..(i + 1) * k];
            for (j, cv) in crow.iter_mut().enumerate() {
                let brow = &bdata[j * k..(j + 1) * k];
                // f64 accumulation: these dot products feed Gram matrices
                let mut acc = 0.0f64;
                for (&av, &bv) in arow.iter().zip(brow) {
                    acc += av as f64 * bv as f64;
                }
                *cv = acc as f32;
            }
        }
    });
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0f64;
                for kk in 0..a.cols {
                    s += a.at(i, kk) as f64 * b.at(kk, j) as f64;
                }
                *c.at_mut(i, j) = s as f32;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(3, 4, 5), (17, 33, 9), (64, 64, 64), (1, 128, 1)] {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let c = matmul(&a, &b);
            assert!(c.allclose(&naive(&a, &b), 1e-3), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn transposed_variants_match_explicit_transpose() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(20, 12, 1.0, &mut rng);
        let b = Mat::randn(20, 15, 1.0, &mut rng);
        assert!(matmul_tn(&a, &b).allclose(&matmul(&a.transpose(), &b), 1e-3));
        let b2 = Mat::randn(9, 12, 1.0, &mut rng);
        assert!(matmul_nt(&a, &b2).allclose(&matmul(&a, &b2.transpose()), 1e-3));
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(10, 10, 1.0, &mut rng);
        assert!(matmul(&a, &Mat::eye(10)).allclose(&a, 1e-6));
        assert!(matmul(&Mat::eye(10), &a).allclose(&a, 1e-6));
    }

    #[test]
    fn associativity_with_vector() {
        let mut rng = Rng::new(4);
        let a = Mat::randn(8, 6, 1.0, &mut rng);
        let b = Mat::randn(6, 7, 1.0, &mut rng);
        let x = Mat::randn(7, 1, 1.0, &mut rng);
        let left = matmul(&matmul(&a, &b), &x);
        let right = matmul(&a, &matmul(&b, &x));
        assert!(left.allclose(&right, 1e-3));
    }
}
