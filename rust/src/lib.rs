//! # SRR — Structured Residual Reconstruction
//!
//! Production reproduction of *"Preserve-Then-Quantize: Balancing Rank
//! Budgets for Quantization Error Reconstruction in LLMs"* (ICML 2026).
//!
//! Layer-3 of the three-layer architecture: this crate owns the request
//! path — quantization pipeline coordination, the SRR algorithm and every
//! QER baseline, evaluation engines, and QPEFT training — and executes the
//! AOT-compiled JAX/Pallas compute graphs (`artifacts/*.hlo.txt`) through
//! the PJRT C API (`xla` crate, behind the opt-in `pjrt` feature; the
//! default build is pure rust). Python never runs at request time.
//!
//! Module map (see DESIGN.md for the full inventory, and
//! `docs/ARCHITECTURE.md` in the repo root for the end-to-end dataflow
//! walkthrough — phase-A prep → sweep → packed serving → shard plane →
//! fleet eval → serve daemon → budget allocator — with the bit-identity
//! invariant and gating `BENCH_*.json` record at every seam):
//!
//! * [`util`] — substrates built in-repo (PRNG, JSON, CLI, stats, thread
//!   pool, property-test helper): no crates.io access beyond `xla`/`anyhow`.
//! * [`tensor`] / [`linalg`] — dense f32 matrices and the factorization
//!   stack (QR, randomized SVD, Jacobi SVD/eigh, Cholesky, Hadamard).
//! * [`quant`] — MXINT, uniform, GPTQ, QuIP#-sim quantizers
//!   (half-step/round-trip invariants property-tested).
//! * [`scaling`] — activation-aware scaling matrices S.
//! * [`qer`] — QER baselines + SRR rank allocation (the paper's core).
//!   Entry points come in self-contained (`reconstruct`, `select_k`) and
//!   shared-work (`reconstruct_prepared` + `PreparedSpectra`) forms; the
//!   two are bit-identical for the same seed and prep rank.
//! * [`model`] / [`data`] — synthetic model zoo, calibration streams,
//!   corpora and tasks standing in for the paper's gated assets. The
//!   forward dispatches every linear through `model::ModelWeights`, so
//!   dense params and the factored serving model share one code path.
//! * [`runtime`] — PJRT client + manifest-driven artifact executor
//!   (manifest-only stub without the `pjrt` feature).
//! * [`serve`] — the factored QLR serving layer: `LinearOp` evaluates
//!   `Qdeq·x + L·(R·x)` by streaming dequant over bit-packed codes
//!   (`quant::packed`), never materializing `W_hat`; `FactoredModel`
//!   carries a whole model 4–8× smaller than dense f32 at 2–4 bits.
//!   `QuantBase` buffers are `Arc`-shared, so sweep rank variants alias
//!   one packed base and `LinearOp::matmul_grouped` decodes it once for
//!   a whole lock-step group.
//! * [`coordinator`] — the multi-threaded layer-pipeline orchestrator:
//!   single-config `run_ptq_factored` (dense `run_ptq` kept as the
//!   compatibility wrapper), plus the shared-work grid engine
//!   (`SweepRunner` over a keyed `LayerCache` of `PreparedLayer`s) that
//!   executes a whole (method, quantizer, rank, scaling, seed) grid in
//!   one pass and emits factored outcomes — plus the multi-process shard
//!   plane that seam grew into: `coordinator::wire` (versioned,
//!   length-prefixed, checksummed frames with content-addressed blob
//!   dedup) and `coordinator::shard` (`ShardedSweepRunner` /
//!   `fleet_perplexity_sharded` over `srr shard-worker` processes,
//!   bit-identical to the in-process engines, with worker-death
//!   requeue). `coordinator::budget` sits on top of the same phase-A
//!   cache: a model-wide byte budget ("best PPL at N gigabytes")
//!   becomes a per-layer `(bits, rank, k)` `BudgetPlan` by greedy
//!   marginal-utility descent with Lagrangian water-filling refinement
//!   over the measured sensitivity profiles — plannable in-process or
//!   sharded, bit-identically (`BENCH_budget.json` gates it).
//! * [`eval`] — perplexity / zero-shot / GLUE-sim metrics engines;
//!   `perplexity_native` evaluates any `ModelWeights` (including the
//!   factored model) without PJRT, and `eval::fleet` scores whole sweep
//!   grids in lock-step: outcomes grouped by shared packed bases
//!   forward together, one base decode per group per batch
//!   (`BENCH_evalbatch.json` records the speedup).
//! * [`qpeft`] — adapter fine-tuning: AdamW, γ gradient scaling, SGP;
//!   the frozen backbone stays packed (`FrozenTensor`), dequantized only
//!   at artifact-marshal time.
//! * [`exp`] — the benchmark harness regenerating every paper table/figure
//!   (grid experiments drive `run_sweep`; `sweep` and `serve` record the
//!   shared-work speedup / factored-serving wins into BENCH_sweep.json /
//!   BENCH_serve.json and run without artifacts).
//!
//! Testing: `cargo build --release && cargo test -q` from a fresh clone —
//! PJRT-bound integration tests skip with a stderr note until
//! `make artifacts` + `--features pjrt`. Property tests (`util::prop`)
//! print a per-case replay seed on failure; re-run one case with
//! `util::prop::replay(seed, |g| ...)` in a scratch test.

// Style lints the numeric-kernel idioms here trip deliberately (index
// loops over matrix storage, `add`/`sub` on Mat, constructor-only types,
// NaN-propagating `!(a > b)` guards). CI runs `clippy -- -D warnings`;
// everything outside this list stays a hard error.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::should_implement_trait,
    clippy::new_without_default,
    clippy::neg_cmp_op_on_partial_ord,
    clippy::type_complexity,
    clippy::manual_range_contains
)]

pub mod util;
pub mod tensor;
pub mod linalg;
pub mod quant;
pub mod scaling;
pub mod qer;
pub mod model;
pub mod data;
pub mod runtime;
pub mod serve;
pub mod coordinator;
pub mod eval;
pub mod qpeft;
pub mod exp;

pub use tensor::Mat;
