//! Activation-aware scaling matrices S (paper §2, Eq. 1).
//!
//! Each QER baseline is characterized by its S:
//!
//! * ZeroQuant-V2 — S = I (weight-space reconstruction)
//! * LQER         — S = diag(rms(x_i)) from calibration activations
//! * QERA-approx  — S = diag(mean |x_i|)
//! * QERA-exact   — S = (E[xxᵀ])^{1/2}, the exact minimizer of the layer
//!   output error (computed by symmetric eigendecomposition; inverse uses
//!   an eigenvalue floor for numerical safety on near-singular Grams)

use crate::linalg::eigh;
use crate::tensor::{matmul, matmul_tn, Mat};

/// Relative eigenvalue floor for the exact scaling: eigenvalues below
/// λ_max·REL_FLOOR are clamped, bounding κ(S) ≤ 10³. Without this, a
/// rank-deficient calibration Gram (fewer samples than dims, or strongly
/// correlated activations) makes S⁻¹ explode and the preserved component
/// S⁻¹·SVD_k(SW) blows up the *unscaled* residual handed to the
/// quantizer — the failure mode our pipeline test caught.
const REL_FLOOR: f64 = 1e-2;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ScalingKind {
    Identity,
    DiagRms,
    DiagAbsMean,
    Exact,
}

impl ScalingKind {
    pub fn label(&self) -> &'static str {
        match self {
            ScalingKind::Identity => "identity",
            ScalingKind::DiagRms => "diag-rms(LQER)",
            ScalingKind::DiagAbsMean => "diag-absmean(QERA-approx)",
            ScalingKind::Exact => "exact(QERA)",
        }
    }
}

/// A scaling S with its inverse, applied on the left of W (m×n), S m×m.
#[derive(Clone, Debug)]
pub enum Scaling {
    Identity,
    Diagonal { d: Vec<f32>, d_inv: Vec<f32> },
    Full { s: Mat, s_inv: Mat },
}

impl Scaling {
    /// Build from calibration activations X (n_samples × m).
    pub fn from_activations(kind: ScalingKind, x: &Mat) -> Scaling {
        match kind {
            ScalingKind::Identity => Scaling::Identity,
            ScalingKind::DiagRms => {
                let d = column_stat(x, |acc, v| acc + (v as f64) * (v as f64))
                    .into_iter()
                    .map(|s| ((s / x.rows as f64).sqrt() as f32).max(1e-6))
                    .collect();
                Scaling::diagonal(d)
            }
            ScalingKind::DiagAbsMean => {
                let d = column_stat(x, |acc, v| acc + (v as f64).abs())
                    .into_iter()
                    .map(|s| ((s / x.rows as f64) as f32).max(1e-6))
                    .collect();
                Scaling::diagonal(d)
            }
            ScalingKind::Exact => {
                // one eigendecomposition builds both S and S⁻¹
                let gram = matmul_tn(x, x).scale(1.0 / x.rows as f32);
                let (q, lam) = eigh(&gram);
                let lam_max = lam.first().copied().unwrap_or(1.0).max(1e-12) as f64;
                let floor = lam_max * REL_FLOOR;
                let n = gram.rows;
                let build = |pow: f64| {
                    let mut qf = Mat::zeros(n, n);
                    for j in 0..n {
                        let l = (lam[j] as f64).max(floor);
                        let f = l.powf(pow) as f32;
                        for i in 0..n {
                            *qf.at_mut(i, j) = q.at(i, j) * f;
                        }
                    }
                    crate::tensor::matmul_nt(&qf, &q)
                };
                Scaling::Full { s: build(0.5), s_inv: build(-0.5) }
            }
        }
    }

    pub fn diagonal(d: Vec<f32>) -> Scaling {
        let d_inv = d.iter().map(|&v| 1.0 / v).collect();
        Scaling::Diagonal { d, d_inv }
    }

    /// S·W.
    pub fn apply(&self, w: &Mat) -> Mat {
        match self {
            Scaling::Identity => w.clone(),
            Scaling::Diagonal { d, .. } => w.scale_rows(d),
            Scaling::Full { s, .. } => matmul(s, w),
        }
    }

    /// S⁻¹·W.
    pub fn unapply(&self, w: &Mat) -> Mat {
        match self {
            Scaling::Identity => w.clone(),
            Scaling::Diagonal { d_inv, .. } => w.scale_rows(d_inv),
            Scaling::Full { s_inv, .. } => matmul(s_inv, w),
        }
    }

    pub fn dim_hint(&self) -> Option<usize> {
        match self {
            Scaling::Identity => None,
            Scaling::Diagonal { d, .. } => Some(d.len()),
            Scaling::Full { s, .. } => Some(s.rows),
        }
    }
}

fn column_stat(x: &Mat, fold: impl Fn(f64, f32) -> f64) -> Vec<f64> {
    let mut acc = vec![0.0f64; x.cols];
    for i in 0..x.rows {
        for (j, &v) in x.row(i).iter().enumerate() {
            acc[j] = fold(acc[j], v);
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn activations(rng: &mut Rng) -> Mat {
        // anisotropic activations: feature j has std ~ 1/(1+j/4)
        let mut x = Mat::randn(200, 16, 1.0, rng);
        for i in 0..x.rows {
            for j in 0..x.cols {
                *x.at_mut(i, j) /= 1.0 + j as f32 / 4.0;
            }
        }
        x
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Rng::new(110);
        let w = Mat::randn(8, 8, 1.0, &mut rng);
        let s = Scaling::from_activations(ScalingKind::Identity, &Mat::zeros(4, 8));
        assert_eq!(s.apply(&w), w);
        assert_eq!(s.unapply(&w), w);
    }

    #[test]
    fn diagonal_apply_unapply_roundtrip() {
        let mut rng = Rng::new(111);
        let x = activations(&mut rng);
        let w = Mat::randn(16, 12, 1.0, &mut rng);
        for kind in [ScalingKind::DiagRms, ScalingKind::DiagAbsMean] {
            let s = Scaling::from_activations(kind, &x);
            let rt = s.unapply(&s.apply(&w));
            assert!(rt.allclose(&w, 1e-4), "{kind:?}");
        }
    }

    #[test]
    fn exact_apply_unapply_roundtrip() {
        let mut rng = Rng::new(112);
        let x = activations(&mut rng);
        let w = Mat::randn(16, 12, 1.0, &mut rng);
        let s = Scaling::from_activations(ScalingKind::Exact, &x);
        let rt = s.unapply(&s.apply(&w));
        assert!(rt.allclose(&w, 2e-3));
    }

    #[test]
    fn diag_rms_matches_manual_computation() {
        let x = Mat::from_vec(2, 2, vec![3.0, 1.0, 4.0, 1.0]);
        let s = Scaling::from_activations(ScalingKind::DiagRms, &x);
        if let Scaling::Diagonal { d, .. } = &s {
            assert!((d[0] - ((9.0f32 + 16.0) / 2.0).sqrt()).abs() < 1e-5);
            assert!((d[1] - 1.0).abs() < 1e-5);
        } else {
            panic!("expected diagonal");
        }
    }

    #[test]
    fn exact_scaling_squares_to_gram() {
        let mut rng = Rng::new(113);
        let x = activations(&mut rng);
        let gram = matmul_tn(&x, &x).scale(1.0 / x.rows as f32);
        if let Scaling::Full { s, .. } = Scaling::from_activations(ScalingKind::Exact, &x) {
            assert!(matmul(&s, &s).allclose(&gram, 1e-2));
        } else {
            panic!("expected full");
        }
    }

    #[test]
    fn exact_scaling_emphasizes_high_energy_directions() {
        // ‖S u‖ should be larger along the dominant activation direction
        let mut rng = Rng::new(114);
        let x = activations(&mut rng);
        let s = Scaling::from_activations(ScalingKind::Exact, &x);
        let e0 = Mat::from_fn(16, 1, |i, _| if i == 0 { 1.0 } else { 0.0 });
        let e15 = Mat::from_fn(16, 1, |i, _| if i == 15 { 1.0 } else { 0.0 });
        assert!(s.apply(&e0).frob() > s.apply(&e15).frob());
    }
}
