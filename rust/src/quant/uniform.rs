//! Per-group affine scalar quantizer (symmetric or asymmetric).
//!
//! The generic low-bit grid: groups of `group` elements along each row
//! share a scale (and zero point when asymmetric). Used standalone, as
//! GPTQ's inner rounding step, and as the QuIP#-sim codebook stand-in.

use super::packed::{PackAcc, PackScheme, PackedMat};
use super::{QuantCtx, Quantizer};
use crate::tensor::Mat;

#[derive(Clone, Debug)]
pub struct UniformQuantizer {
    pub bits: u32,
    pub group: usize,
    pub symmetric: bool,
}

impl UniformQuantizer {
    pub fn new(bits: u32, group: usize, symmetric: bool) -> Self {
        assert!((2..=16).contains(&bits));
        UniformQuantizer { bits, group, symmetric }
    }

    /// Quantize one group in place, reporting `(lo, scale)` and emitting
    /// each element's integer code (qmax-offset when symmetric). One
    /// rounding loop serves both the dense path (no-op `emit`) and the
    /// packed path so the two can never drift apart. Degenerate groups
    /// (all-zero symmetric, constant asymmetric) report scale 0 with
    /// codes that decode back to the untouched values.
    fn qdq_slice_inner(&self, chunk: &mut [f32], mut emit: impl FnMut(u32)) -> (f32, f32) {
        if self.symmetric {
            let qmax = (1i64 << (self.bits - 1)) as f32 - 1.0;
            let maxabs = chunk.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            if maxabs == 0.0 {
                for _ in chunk.iter() {
                    emit(qmax as u32); // q = 0
                }
                return (0.0, 0.0);
            }
            let scale = maxabs / qmax;
            for v in chunk.iter_mut() {
                let q = (*v / scale).round_ties_even().clamp(-qmax, qmax);
                emit((q + qmax) as u32);
                *v = q * scale;
            }
            (0.0, scale)
        } else {
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for &v in chunk.iter() {
                lo = lo.min(v);
                hi = hi.max(v);
            }
            if !(hi > lo) {
                // constant group: every value equals lo, decoded as lo + 0·0
                let c = if lo.is_finite() { lo } else { 0.0 };
                for _ in chunk.iter() {
                    emit(0);
                }
                return (c, 0.0);
            }
            let levels = ((1u64 << self.bits) - 1) as f32;
            let scale = (hi - lo) / levels;
            for v in chunk.iter_mut() {
                let q = ((*v - lo) / scale).round_ties_even().clamp(0.0, levels);
                emit(q as u32);
                *v = lo + q * scale;
            }
            (lo, scale)
        }
    }

    pub fn qdq_slice(&self, chunk: &mut [f32]) {
        self.qdq_slice_inner(chunk, |_| {});
    }

    /// The coded variant GPTQ's error-feedback loop packs through.
    pub(crate) fn qdq_slice_coded(&self, chunk: &mut [f32], codes: &mut Vec<u32>) -> (f32, f32) {
        self.qdq_slice_inner(chunk, |c| codes.push(c))
    }
}

impl Quantizer for UniformQuantizer {
    fn name(&self) -> String {
        format!(
            "uniform{}g{}{}",
            self.bits,
            self.group,
            if self.symmetric { "s" } else { "a" }
        )
    }

    fn effective_bits(&self) -> f64 {
        // one f16 scale (+ f16 zero point when asymmetric) per group
        let overhead = if self.symmetric { 16.0 } else { 32.0 };
        self.bits as f64 + overhead / self.group as f64
    }

    fn quantize(&self, w: &Mat, _ctx: &QuantCtx) -> Mat {
        let mut out = w.clone();
        for i in 0..out.rows {
            for chunk in out.row_mut(i).chunks_mut(self.group) {
                self.qdq_slice(chunk);
            }
        }
        out
    }

    fn quantize_coded(&self, w: &Mat, _ctx: &QuantCtx) -> (Mat, Option<PackedMat>) {
        let groups = w.rows * w.cols.div_ceil(self.group);
        let mut acc = PackAcc::with_capacity(w.rows * w.cols, groups, !self.symmetric);
        let mut out = w.clone();
        for i in 0..out.rows {
            for chunk in out.row_mut(i).chunks_mut(self.group) {
                let (lo, scale) = self.qdq_slice_inner(chunk, |c| acc.codes.push(c));
                acc.scales.push(scale);
                if !self.symmetric {
                    acc.los.push(lo);
                }
            }
        }
        let scheme = PackScheme::UniformGroup {
            bits: self.bits,
            group: self.group,
            symmetric: self.symmetric,
        };
        (out, Some(acc.into_packed(w.rows, w.cols, scheme)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Rng};

    #[test]
    fn symmetric_preserves_sign_and_bounds() {
        let mut rng = Rng::new(80);
        let w = Mat::randn(8, 128, 1.0, &mut rng);
        let q = UniformQuantizer::new(4, 64, true).quantize(&w, &QuantCtx::default());
        for (a, b) in w.data.iter().zip(&q.data) {
            assert!(a * b >= 0.0 || b.abs() < 1e-6, "sign flip {a} -> {b}");
        }
        assert!(q.max_abs() <= w.max_abs() * 1.0001);
    }

    #[test]
    fn asymmetric_handles_shifted_data() {
        let mut rng = Rng::new(81);
        let mut w = Mat::randn(4, 64, 0.1, &mut rng);
        for v in w.data.iter_mut() {
            *v += 5.0; // all positive, far from zero
        }
        let qs = UniformQuantizer::new(3, 64, true).quantize(&w, &QuantCtx::default());
        let qa = UniformQuantizer::new(3, 64, false).quantize(&w, &QuantCtx::default());
        assert!(w.sub(&qa).frob() < w.sub(&qs).frob(), "asymmetric should win on shifted data");
    }

    #[test]
    fn constant_group_roundtrips_exactly_asymmetric() {
        let w = Mat::from_fn(2, 32, |_, _| 3.7);
        let q = UniformQuantizer::new(2, 32, false).quantize(&w, &QuantCtx::default());
        // hi == lo -> group untouched
        assert!(q.allclose(&w, 0.0));
    }

    #[test]
    fn coded_path_matches_dense_and_unpacks_exactly() {
        // serving-layer contract for both grid variants, including the
        // degenerate all-zero (symmetric) / constant (asymmetric) groups
        let mut rng = Rng::new(82);
        let mut w = Mat::randn(6, 80, 1.0, &mut rng); // 80 = 2.5 groups of 32
        for v in w.row_mut(1) {
            *v = 0.0;
        }
        for v in w.row_mut(4) {
            *v = 3.7;
        }
        for symmetric in [true, false] {
            for bits in [2u32, 3, 4] {
                let q = UniformQuantizer::new(bits, 32, symmetric);
                let ctx = QuantCtx::default();
                let dense = q.quantize(&w, &ctx);
                let (coded, packed) = q.quantize_coded(&w, &ctx);
                let packed = packed.expect("uniform has a packed form");
                assert_eq!(coded, dense, "bits={bits} sym={symmetric}");
                assert_eq!(packed.dequantize(), dense, "bits={bits} sym={symmetric} unpack");
                assert!(packed.bytes() < packed.dense_bytes());
            }
        }
    }

    #[test]
    fn prop_symmetric_error_bounded_by_half_step() {
        // Satellite invariant: for every element, the dequantized output
        // is within step/2 of the input (step = maxabs/qmax per group),
        // across random dims, bit-widths and group counts.
        prop::check(0xB3, 30, |g| {
            let m = g.dim(8);
            let groups = g.dim(3);
            let bits = g.choice(&[2u32, 3, 4, 6]);
            let group = 32;
            let scale = g.choice(&[1e-2f32, 1.0, 50.0]);
            let w = Mat::randn(m, groups * group, scale, &mut g.rng);
            let q = UniformQuantizer::new(bits, group, true).quantize(&w, &QuantCtx::default());
            let qmax = (1i64 << (bits - 1)) as f32 - 1.0;
            for i in 0..m {
                for c in 0..groups {
                    let s = &w.row(i)[c * group..(c + 1) * group];
                    let maxabs = s.iter().fold(0.0f32, |mm, &x| mm.max(x.abs()));
                    if maxabs == 0.0 {
                        continue;
                    }
                    let step = maxabs / qmax;
                    for j in 0..group {
                        let err = (w.at(i, c * group + j) - q.at(i, c * group + j)).abs();
                        assert!(
                            err <= step / 2.0 + step * 1e-5,
                            "err {err} > step/2 {} (bits={bits})",
                            step / 2.0
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn prop_error_bounded_by_half_step() {
        prop::check(0xB2, 30, |g| {
            let m = g.dim(8);
            let groups = g.dim(3);
            let bits = g.choice(&[2u32, 3, 4]);
            let group = 32;
            let w = Mat::randn(m, groups * group, 1.0, &mut g.rng);
            let q = UniformQuantizer::new(bits, group, false).quantize(&w, &QuantCtx::default());
            for i in 0..m {
                for c in 0..groups {
                    let s = &w.row(i)[c * group..(c + 1) * group];
                    let (lo, hi) = s
                        .iter()
                        .fold((f32::INFINITY, f32::NEG_INFINITY), |(l, h), &x| (l.min(x), h.max(x)));
                    let step = (hi - lo) / ((1u64 << bits) - 1) as f32;
                    for j in 0..group {
                        let err = (w.at(i, c * group + j) - q.at(i, c * group + j)).abs();
                        assert!(err <= step / 2.0 + 1e-6);
                    }
                }
            }
        });
    }
}
