//! GPTQ (Frantar et al. 2023): Hessian-guided sequential quantization
//! with error feedback.
//!
//! Orientation note: our weights are stored as W (m_in × n_out) applied as
//! y = x·W, so GPTQ's per-column loop over *input* dimensions becomes a
//! loop over *rows* here. For each input dim i (in order):
//!
//!   q_i   = round(w_i)                     (per-group scalar grid)
//!   err_i = (w_i − q_i) / [H⁻¹]_{ii}
//!   w_j  ← w_j − [H⁻¹]_{ji} · err_i        for all j > i
//!
//! with H = XᵀX/n + λ·mean(diag)·I (damping λ = 0.01, matching §A.2).
//! Without a Hessian in the ctx, H = I and GPTQ degrades gracefully to
//! plain nearest rounding (the error-feedback term vanishes).

use super::packed::{PackAcc, PackScheme, PackedMat};
use super::{QuantCtx, Quantizer, UniformQuantizer};
use crate::linalg::cholesky_solve;
use crate::tensor::Mat;

#[derive(Clone, Debug)]
pub struct GptqQuantizer {
    pub bits: u32,
    pub group: usize,
    pub damp: f32,
}

impl GptqQuantizer {
    pub fn new(bits: u32, group: usize) -> Self {
        GptqQuantizer { bits, group, damp: 0.01 }
    }

    fn hinv(&self, m: usize, ctx: &QuantCtx) -> Mat {
        match &ctx.hessian {
            None => Mat::eye(m),
            Some(h) => {
                assert_eq!(h.rows, m, "hessian dim mismatch");
                let mut hd = h.clone();
                let mean_diag: f64 =
                    (0..m).map(|i| h.at(i, i) as f64).sum::<f64>() / m as f64;
                let mut damp = self.damp as f64 * mean_diag.max(1e-12);
                // auto-increment damping until PD (paper: +0.0025 steps)
                loop {
                    let mut try_h = hd.clone();
                    for i in 0..m {
                        *try_h.at_mut(i, i) = h.at(i, i) + damp as f32;
                    }
                    if let Some(inv) = cholesky_solve(&try_h, &Mat::eye(m)) {
                        return inv;
                    }
                    damp += 0.0025 * mean_diag.max(1e-12);
                    hd = h.clone();
                }
            }
        }
    }
}

impl Quantizer for GptqQuantizer {
    fn name(&self) -> String {
        format!("gptq{}g{}", self.bits, self.group)
    }

    fn effective_bits(&self) -> f64 {
        self.bits as f64 + 32.0 / self.group as f64
    }

    fn quantize(&self, w: &Mat, ctx: &QuantCtx) -> Mat {
        self.run(w, ctx, None)
    }

    fn quantize_coded(&self, w: &Mat, ctx: &QuantCtx) -> (Mat, Option<PackedMat>) {
        let g = self.group.min(w.cols);
        let mut acc = PackAcc::with_capacity(w.rows * w.cols, w.rows * w.cols.div_ceil(g), true);
        let out = self.run(w, ctx, Some(&mut acc));
        let scheme = PackScheme::GptqGrouped { bits: self.bits, group: g };
        (out, Some(acc.into_packed(w.rows, w.cols, scheme)))
    }
}

impl GptqQuantizer {
    /// The sequential error-feedback loop, optionally emitting the
    /// per-group (codes, scale, lo) of every quantized row into `acc`.
    /// One loop serves both paths — the packed codes are by construction
    /// the exact integers behind the dense output.
    fn run(&self, w: &Mat, ctx: &QuantCtx, mut acc: Option<&mut PackAcc>) -> Mat {
        let (m, n) = (w.rows, w.cols);
        let hinv = self.hinv(m, ctx);
        let inner = UniformQuantizer::new(self.bits, self.group.min(n), false);
        let mut work = w.clone();
        let mut out = Mat::zeros(m, n);

        for i in 0..m {
            // quantize row i with the scalar grid
            let mut qrow = work.row(i).to_vec();
            match acc.as_mut() {
                Some(a) => {
                    for chunk in qrow.chunks_mut(self.group.min(n)) {
                        let (lo, scale) = inner.qdq_slice_coded(chunk, &mut a.codes);
                        a.scales.push(scale);
                        a.los.push(lo);
                    }
                }
                None => {
                    for chunk in qrow.chunks_mut(self.group.min(n)) {
                        inner.qdq_slice(chunk);
                    }
                }
            }
            let dii = hinv.at(i, i).max(1e-12);
            // propagate the compensated error into the not-yet-quantized rows
            let err: Vec<f32> = work
                .row(i)
                .iter()
                .zip(&qrow)
                .map(|(wv, qv)| (wv - qv) / dii)
                .collect();
            for j in (i + 1)..m {
                let hji = hinv.at(j, i);
                if hji != 0.0 {
                    let row_j = work.row_mut(j);
                    for (rv, &ev) in row_j.iter_mut().zip(&err) {
                        *rv -= hji * ev;
                    }
                }
            }
            out.row_mut(i).copy_from_slice(&qrow);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul;
    use crate::util::Rng;

    fn calib_gram(m: usize, n_samples: usize, rng: &mut Rng) -> (Mat, Mat) {
        let x = Mat::randn(n_samples, m, 1.0, rng);
        let gram = crate::tensor::matmul_tn(&x, &x).scale(1.0 / n_samples as f32);
        (x, gram)
    }

    #[test]
    fn without_hessian_equals_plain_rounding() {
        let mut rng = Rng::new(90);
        let w = Mat::randn(16, 64, 1.0, &mut rng);
        let g = GptqQuantizer::new(3, 64);
        let got = g.quantize(&w, &QuantCtx::default());
        let want = UniformQuantizer::new(3, 64, false).quantize(&w, &QuantCtx::default());
        assert!(got.allclose(&want, 1e-6));
    }

    #[test]
    fn hessian_feedback_reduces_activation_error() {
        // GPTQ's whole point: ‖X(W − Q)‖ is smaller than nearest rounding's.
        let mut rng = Rng::new(91);
        let (x, gram) = calib_gram(32, 256, &mut rng);
        // correlated weight rows make error feedback matter
        let base = Mat::randn(32, 48, 1.0, &mut rng);
        let mix = Mat::randn(32, 32, 0.2, &mut rng).add(&Mat::eye(32));
        let w = matmul(&mix, &base);

        let ctx_h = QuantCtx { hessian: Some(gram), seed: 0 };
        let gptq = GptqQuantizer::new(2, 48).quantize(&w, &ctx_h);
        let near = UniformQuantizer::new(2, 48, false).quantize(&w, &QuantCtx::default());

        let err_gptq = matmul(&x, &w.sub(&gptq)).frob();
        let err_near = matmul(&x, &w.sub(&near)).frob();
        assert!(
            err_gptq < err_near,
            "gptq {err_gptq} should beat nearest {err_near}"
        );
    }

    #[test]
    fn coded_path_matches_dense_and_unpacks_exactly() {
        // the packed codes come out of the same error-feedback loop, so
        // the unpack must reproduce the Hessian-compensated output exactly
        let mut rng = Rng::new(93);
        let (_, gram) = calib_gram(24, 128, &mut rng);
        let w = Mat::randn(24, 80, 1.0, &mut rng); // ragged tail group
        let q = GptqQuantizer::new(3, 32);
        let ctx = QuantCtx { hessian: Some(gram), seed: 0 };
        let dense = q.quantize(&w, &ctx);
        let (coded, packed) = q.quantize_coded(&w, &ctx);
        let packed = packed.expect("gptq has a packed form");
        assert_eq!(coded, dense);
        assert_eq!(packed.dequantize(), dense);
        assert!(packed.bytes() < packed.dense_bytes());
    }

    #[test]
    fn output_is_on_the_quantization_grid_rowwise() {
        // each output row must be exactly representable by the scalar grid
        // fitted to the *adjusted* row — verify idempotence per row
        let mut rng = Rng::new(92);
        let (_, gram) = calib_gram(8, 64, &mut rng);
        let w = Mat::randn(8, 32, 1.0, &mut rng);
        let ctx = QuantCtx { hessian: Some(gram), seed: 0 };
        let q = GptqQuantizer::new(3, 32).quantize(&w, &ctx);
        let q2 = UniformQuantizer::new(3, 32, false).quantize(&q, &QuantCtx::default());
        assert!(q.allclose(&q2, 1e-5));
    }
}
