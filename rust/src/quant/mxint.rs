//! MXINT-b: block-wise shared power-of-two exponent + signed mantissa.
//!
//! Exactly mirrors python/compile/kernels/ref.py::mxint_qdq_ref (and thus
//! the Pallas kernel): E = floor(log2(max|block|)), scale = 2^(E-b+2),
//! q = clip(round(w/scale), ±(2^(b-1)−1)), round-half-to-even.

use super::packed::{PackAcc, PackScheme, PackedMat};
use super::{QuantCtx, Quantizer};
use crate::tensor::Mat;

#[derive(Clone, Debug)]
pub struct MxintQuantizer {
    pub bits: u32,
    pub block: usize,
}

impl MxintQuantizer {
    pub fn new(bits: u32, block: usize) -> Self {
        assert!((2..=16).contains(&bits));
        assert!(block > 0);
        MxintQuantizer { bits, block }
    }

    /// Quantize one block in place (row-contiguous slice), reporting the
    /// block scale and emitting each element's qmax-offset mantissa code.
    /// The single rounding loop serves both the dense path (no-op `emit`)
    /// and the packed path, so the two can never drift apart.
    fn qdq_block(&self, block: &mut [f32], mut emit: impl FnMut(u32)) -> f32 {
        let qmax = (1i64 << (self.bits - 1)) as f32 - 1.0;
        let maxabs = block.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        if maxabs == 0.0 {
            for _ in block.iter() {
                emit(qmax as u32); // q = 0
            }
            return 0.0;
        }
        let e = maxabs.log2().floor();
        let scale = (e - (self.bits as f32 - 2.0)).exp2();
        for v in block.iter_mut() {
            let q = (*v / scale).round_ties_even().clamp(-qmax, qmax);
            emit((q + qmax) as u32);
            *v = q * scale;
        }
        scale
    }

    fn assert_block_layout(&self, w: &Mat) {
        assert!(
            w.cols % self.block == 0,
            "cols {} not divisible by MX block {}",
            w.cols,
            self.block
        );
    }
}

impl Quantizer for MxintQuantizer {
    fn name(&self) -> String {
        format!("mxint{}b{}", self.bits, self.block)
    }

    fn effective_bits(&self) -> f64 {
        self.bits as f64 + 8.0 / self.block as f64
    }

    fn quantize(&self, w: &Mat, _ctx: &QuantCtx) -> Mat {
        self.assert_block_layout(w);
        let mut out = w.clone();
        for i in 0..out.rows {
            for chunk in out.row_mut(i).chunks_mut(self.block) {
                self.qdq_block(chunk, |_| {});
            }
        }
        out
    }

    fn quantize_coded(&self, w: &Mat, _ctx: &QuantCtx) -> (Mat, Option<PackedMat>) {
        self.assert_block_layout(w);
        let groups = w.rows * (w.cols / self.block);
        let mut acc = PackAcc::with_capacity(w.rows * w.cols, groups, false);
        let mut out = w.clone();
        for i in 0..out.rows {
            for chunk in out.row_mut(i).chunks_mut(self.block) {
                let scale = self.qdq_block(chunk, |c| acc.codes.push(c));
                acc.scales.push(scale);
            }
        }
        let scheme = PackScheme::MxintBlock { bits: self.bits, block: self.block };
        (out, Some(acc.into_packed(w.rows, w.cols, scheme)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Rng};

    fn ref_qdq(w: &Mat, bits: u32, block: usize) -> Mat {
        // direct transliteration of ref.py
        let mut out = w.clone();
        for i in 0..w.rows {
            let row = out.row_mut(i);
            for chunk in row.chunks_mut(block) {
                let maxabs = chunk.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                if maxabs == 0.0 {
                    for v in chunk.iter_mut() {
                        *v = 0.0;
                    }
                    continue;
                }
                let e = maxabs.log2().floor();
                let scale = (e - (bits as f32 - 2.0)).exp2();
                let qmax = (1i64 << (bits - 1)) as f32 - 1.0;
                for v in chunk.iter_mut() {
                    *v = (*v / scale).round_ties_even().clamp(-qmax, qmax) * scale;
                }
            }
        }
        out
    }

    #[test]
    fn matches_reference_impl() {
        let mut rng = Rng::new(70);
        let w = Mat::randn(16, 96, 1.0, &mut rng);
        for bits in [2u32, 3, 4, 8] {
            let q = MxintQuantizer::new(bits, 32).quantize(&w, &QuantCtx::default());
            assert_eq!(q, ref_qdq(&w, bits, 32));
        }
    }

    #[test]
    fn effective_bits_accounts_for_exponent() {
        assert!((MxintQuantizer::new(3, 32).effective_bits() - 3.25).abs() < 1e-12);
        assert!((MxintQuantizer::new(4, 32).effective_bits() - 4.25).abs() < 1e-12);
        assert!((MxintQuantizer::new(2, 32).effective_bits() - 2.25).abs() < 1e-12);
    }

    #[test]
    fn zero_blocks_stay_zero_and_idempotent() {
        let mut rng = Rng::new(71);
        let mut w = Mat::randn(4, 64, 1.0, &mut rng);
        for v in w.row_mut(2) {
            *v = 0.0;
        }
        let q = MxintQuantizer::new(3, 32);
        let ctx = QuantCtx::default();
        let once = q.quantize(&w, &ctx);
        assert!(once.row(2).iter().all(|&v| v == 0.0));
        let twice = q.quantize(&once, &ctx);
        assert_eq!(once, twice);
    }

    #[test]
    fn coded_path_matches_dense_and_unpacks_exactly() {
        // the serving-layer contract: quantize_coded's dense output equals
        // quantize bit-for-bit, and the packed form dequantizes to it
        let mut rng = Rng::new(72);
        let mut w = Mat::randn(8, 96, 1.0, &mut rng);
        for v in w.row_mut(3) {
            *v = 0.0; // degenerate (all-zero) blocks covered
        }
        for bits in [2u32, 3, 4, 8] {
            let q = MxintQuantizer::new(bits, 32);
            let ctx = QuantCtx::default();
            let dense = q.quantize(&w, &ctx);
            let (coded, packed) = q.quantize_coded(&w, &ctx);
            let packed = packed.expect("mxint has a packed form");
            assert_eq!(coded, dense, "bits={bits} dense outputs diverge");
            assert_eq!(packed.dequantize(), dense, "bits={bits} unpack diverges");
            assert!(packed.bytes() < packed.dense_bytes());
        }
    }

    #[test]
    fn prop_block_exponent_round_trip() {
        // Satellite invariant: dequantized values re-encode to themselves
        // — quantize∘quantize = quantize — and the shared block exponent
        // never grows, across random dims, bit-widths and value scales.
        prop::check(0xA2, 30, |g| {
            let m = g.dim(10);
            let nb = g.dim(4);
            let bits = g.choice(&[2u32, 3, 4, 6, 8]);
            let scale = g.choice(&[1e-4f32, 1e-1, 1.0, 1e3]);
            let w = Mat::randn(m, nb * 32, scale, &mut g.rng);
            let q = MxintQuantizer::new(bits, 32);
            let ctx = QuantCtx::default();
            let once = q.quantize(&w, &ctx);
            let twice = q.quantize(&once, &ctx);
            assert_eq!(once, twice, "MXINT{bits} qdq not idempotent");
            for i in 0..m {
                for b in 0..nb {
                    let (a, z) = (b * 32, (b + 1) * 32);
                    let max_in = w.row(i)[a..z].iter().fold(0.0f32, |mm, &x| mm.max(x.abs()));
                    let max_out =
                        once.row(i)[a..z].iter().fold(0.0f32, |mm, &x| mm.max(x.abs()));
                    if max_in == 0.0 {
                        assert_eq!(max_out, 0.0);
                        continue;
                    }
                    assert!(
                        max_out.log2().floor() <= max_in.log2().floor(),
                        "block exponent grew: {max_in} -> {max_out}"
                    );
                }
            }
        });
    }

    #[test]
    fn prop_error_bounded_by_one_step() {
        prop::check(0xA1, 30, |g| {
            let m = g.dim(12);
            let nb = g.dim(4);
            let bits = g.choice(&[2u32, 3, 4, 6]);
            let scale = g.choice(&[1e-3f32, 1.0, 100.0]);
            let w = Mat::randn(m, nb * 32, scale, &mut g.rng);
            let q = MxintQuantizer::new(bits, 32).quantize(&w, &QuantCtx::default());
            for i in 0..m {
                for chunk_idx in 0..nb {
                    let (a, b) = (chunk_idx * 32, (chunk_idx + 1) * 32);
                    let maxabs = w.row(i)[a..b].iter().fold(0.0f32, |mm, &x| mm.max(x.abs()));
                    if maxabs == 0.0 {
                        continue;
                    }
                    let step = (maxabs.log2().floor() - (bits as f32 - 2.0)).exp2();
                    for j in a..b {
                        let err = (w.at(i, j) - q.at(i, j)).abs();
                        assert!(err <= step * 1.0001, "err {err} > step {step}");
                    }
                }
            }
        });
    }
}
