//! QuIP#-sim: incoherence processing + low-bit grid (Tseng et al. 2024).
//!
//! The real QuIP# pipeline is (i) two-sided randomized-Hadamard rotation
//! to make the weight incoherent (no outliers), (ii) E8-lattice codebook
//! quantization, (iii) rotate back. We reproduce (i) and (iii) exactly and
//! substitute (ii) with a per-group symmetric scalar grid — documented in
//! DESIGN.md §2; the substitution preserves the property SRR interacts
//! with (dense, unstructured 2-bit error in a rotated basis).

use super::{QuantCtx, Quantizer};
use crate::linalg::RandomizedHadamard;
use crate::tensor::Mat;
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct QuipSharpQuantizer {
    pub bits: u32,
    pub group: usize,
}

impl QuipSharpQuantizer {
    pub fn new(bits: u32) -> Self {
        QuipSharpQuantizer { bits, group: 128 }
    }
}

/// MSE-optimal clipped symmetric grid: the scalar stand-in for QuIP#'s
/// lattice codebook. After Hadamard rotation the data is ~gaussian, where
/// max-abs scaling wastes most of a 2-bit grid on the tail; searching a
/// handful of clip ratios recovers the bulk of the lattice's gain.
fn qdq_clip_search(chunk: &mut [f32], bits: u32) {
    let maxabs = chunk.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    if maxabs == 0.0 {
        return;
    }
    let qmax = (1i64 << (bits - 1)) as f32 - 1.0;
    let mut best = (f64::INFINITY, maxabs / qmax);
    for ratio in [1.0f32, 0.8, 0.6, 0.45, 0.32, 0.22] {
        let scale = maxabs * ratio / qmax;
        let mut mse = 0.0f64;
        for &v in chunk.iter() {
            let q = (v / scale).round_ties_even().clamp(-qmax, qmax);
            let e = v - q * scale;
            mse += (e as f64) * (e as f64);
        }
        if mse < best.0 {
            best = (mse, scale);
        }
    }
    let scale = best.1;
    for v in chunk.iter_mut() {
        *v = (*v / scale).round_ties_even().clamp(-qmax, qmax) * scale;
    }
}

impl Quantizer for QuipSharpQuantizer {
    fn name(&self) -> String {
        format!("quipsharp{}", self.bits)
    }

    fn effective_bits(&self) -> f64 {
        // sign diagonals cost 1 bit per row+col, amortized to ~0; per-group
        // fp16 scale dominates, matching QuIP#'s reported overhead regime.
        self.bits as f64 + 16.0 / self.group as f64
    }

    fn quantize(&self, w: &Mat, ctx: &QuantCtx) -> Mat {
        let mut rng = Rng::new(ctx.seed ^ 0x9E37_79B9_7F4A_7C15);
        let rh = RandomizedHadamard::new(w.rows, w.cols, &mut rng);
        let mut rotated = rh.forward(w);
        let group = self.group.min(w.cols);
        for i in 0..rotated.rows {
            for chunk in rotated.row_mut(i).chunks_mut(group) {
                qdq_clip_search(chunk, self.bits);
            }
        }
        rh.inverse(&rotated)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::UniformQuantizer;

    fn spiky_weight(rng: &mut Rng) -> Mat {
        // a weight with strong outlier columns — the case QuIP# targets
        let mut w = Mat::randn(64, 128, 0.3, rng);
        for i in 0..64 {
            *w.at_mut(i, 5) += 4.0;
            *w.at_mut(i, 77) -= 4.0;
        }
        w
    }

    #[test]
    fn beats_plain_uniform_on_outlier_weights() {
        let mut rng = Rng::new(100);
        let w = spiky_weight(&mut rng);
        let ctx = QuantCtx { hessian: None, seed: 1 };
        let quip = QuipSharpQuantizer::new(2).quantize(&w, &ctx);
        let unif = UniformQuantizer::new(2, 128, true).quantize(&w, &QuantCtx::default());
        let e_quip = w.sub(&quip).frob();
        let e_unif = w.sub(&unif).frob();
        assert!(e_quip < e_unif, "quip {e_quip} !< uniform {e_unif}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(101);
        let w = Mat::randn(32, 64, 1.0, &mut rng);
        let ctx = QuantCtx { hessian: None, seed: 7 };
        let a = QuipSharpQuantizer::new(2).quantize(&w, &ctx);
        let b = QuipSharpQuantizer::new(2).quantize(&w, &ctx);
        assert_eq!(a, b);
    }

    #[test]
    fn higher_bits_reduce_error() {
        let mut rng = Rng::new(102);
        let w = Mat::randn(32, 64, 1.0, &mut rng);
        let ctx = QuantCtx { hessian: None, seed: 3 };
        let e2 = w.sub(&QuipSharpQuantizer::new(2).quantize(&w, &ctx)).frob();
        let e4 = w.sub(&QuipSharpQuantizer::new(4).quantize(&w, &ctx)).frob();
        assert!(e4 < e2);
    }

    #[test]
    fn works_on_non_pow2_dims() {
        let mut rng = Rng::new(103);
        let w = Mat::randn(96, 384, 1.0, &mut rng); // base-model shapes
        let ctx = QuantCtx { hessian: None, seed: 5 };
        let q = QuipSharpQuantizer::new(2).quantize(&w, &ctx);
        assert!(q.data.iter().all(|v| v.is_finite()));
        assert!(w.sub(&q).frob() / w.frob() < 1.0);
    }
}
