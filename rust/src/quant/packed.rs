//! Bit-packed quantized weight storage for the factored QLR serving path.
//!
//! The quantizers historically returned only the *dequantized* f32 matrix;
//! serving then paid dense-f32 memory for a tensor that is really `bits`
//! bits per weight plus per-group side data. This module defines the
//! packed form the serving layer carries instead:
//!
//! * [`PackedCodes`] — a flat bit-packed integer code buffer (codes of
//!   width 2..=32 bits, straddling word boundaries freely);
//! * [`PackScheme`] — how codes + side data map back to values, one
//!   variant per packable quantizer family: MXINT shared-exponent blocks,
//!   per-group affine grids (uniform symmetric/asymmetric), and GPTQ's
//!   grouped grid (same affine decode; the codes were produced by the
//!   error-feedback loop);
//! * [`PackedMat`] — codes + per-group scales (+ lower bounds for the
//!   affine grids) with streaming decode.
//!
//! **Exactness contract:** `PackedMat::dequantize()` reproduces the
//! quantizer's dense output *bit-exactly*. The quantizers guarantee this
//! by emitting codes from inside their own rounding loops
//! (`Quantizer::quantize_coded`) and the decode here replays the same
//! float expressions: `q · scale` for the symmetric grids (`q` is a small
//! integer, exactly representable), `lo + q · scale` for the affine ones.
//! Property tests in `serve` pin the contract for every packable family.
//! QuIP#-sim has no packed form (its codes live in a rotated basis) and
//! falls back to a dense base in the serving layer.
//!
//! **Decode kernels.** The serving hot paths ([`PackedMat::decode_span_into`],
//! [`PackedMat::axpy_span`]) run *block* decode: [`PackedCodes::unpack_span_into`]
//! pulls one `u64` word (or the straddling pair, fused through a `u128`
//! shift) from the code buffer per lane block and emits every resident
//! code with a fixed-trip, branch-free shift+mask loop LLVM can unroll
//! and autovectorize, monomorphized for the widths the quantizers
//! actually emit (2, 3, 4, 8 bits) with a generic word-pair path for
//! 5–7 and a scalar cursor for wide codes. The unpacked lanes then take
//! an affine map per group in equally fixed `[f32]` chunk loops. The
//! per-code bit-cursor paths survive as
//! [`PackedMat::decode_span_into_scalar`] / [`PackedMat::axpy_span_scalar`]:
//! they are the property-test oracle and the bench baseline the block
//! kernels must stay bit-identical to (`kernel_bit_identical` in
//! `BENCH_serve.json`), so the exactness contract above transfers to the
//! fast paths verbatim.

use crate::tensor::Mat;

/// Codes unpacked per scratch burst in the block decode paths: two
/// cache lines of `u32` lanes, enough to amortize the per-burst group
/// bookkeeping while staying comfortably on the stack.
const DECODE_CHUNK: usize = 128;

/// Word-at-a-time unpack, monomorphized per code width: each block of
/// `LANES` codes spans at most two `u64` words (`BITS * LANES <= 64`),
/// which are fused through one `u128` shift so the lane loop below is
/// branch-free with a fixed trip count — the shape LLVM autovectorizes.
/// Bit-exact with per-code [`PackedCodes::get`].
#[inline]
fn unpack_words<const BITS: usize, const LANES: usize>(
    words: &[u64],
    start: usize,
    out: &mut [u32],
) {
    debug_assert!(BITS >= 2 && BITS * LANES <= 64);
    let mask = ((1u64 << BITS) - 1) as u32;
    let n = out.len();
    let mut k = 0usize;
    while k + LANES <= n {
        let bit = (start + k) * BITS;
        let (w, off) = (bit >> 6, bit & 63);
        // the block needs words[w + 1] iff its bits spill past word w,
        // and exactly then the spill bits keep w + 1 in bounds
        let lo = words[w] as u128;
        let hi = if off + BITS * LANES > 64 { (words[w + 1] as u128) << 64 } else { 0 };
        let v = ((lo | hi) >> off) as u64;
        for (lane, slot) in out[k..k + LANES].iter_mut().enumerate() {
            *slot = ((v >> (lane * BITS)) as u32) & mask;
        }
        k += LANES;
    }
    while k < n {
        let bit = (start + k) * BITS;
        let (w, off) = (bit >> 6, bit & 63);
        let mut v = words[w] >> off;
        if off + BITS > 64 {
            v |= words[w + 1] << (64 - off);
        }
        out[k] = (v as u32) & mask;
        k += 1;
    }
}

/// The width-generic twin of [`unpack_words`] for the odd widths without
/// a monomorphized fast path (5–7 bits): same two-word `u128` fuse, lane
/// count fixed at 8 so `bits * 8 <= 64` always holds.
#[inline]
fn unpack_words_generic(words: &[u64], bits: usize, start: usize, out: &mut [u32]) {
    const LANES: usize = 8;
    debug_assert!((2..=8).contains(&bits));
    let mask = ((1u64 << bits) - 1) as u32;
    let n = out.len();
    let mut k = 0usize;
    while k + LANES <= n {
        let bit = (start + k) * bits;
        let (w, off) = (bit >> 6, bit & 63);
        let lo = words[w] as u128;
        let hi = if off + bits * LANES > 64 { (words[w + 1] as u128) << 64 } else { 0 };
        let v = ((lo | hi) >> off) as u64;
        for (lane, slot) in out[k..k + LANES].iter_mut().enumerate() {
            *slot = ((v >> (lane * bits)) as u32) & mask;
        }
        k += LANES;
    }
    while k < n {
        let bit = (start + k) * bits;
        let (w, off) = (bit >> 6, bit & 63);
        let mut v = words[w] >> off;
        if off + bits > 64 {
            v |= words[w + 1] << (64 - off);
        }
        out[k] = (v as u32) & mask;
        k += 1;
    }
}

/// Flat bit-packed unsigned integer codes.
#[derive(Clone, Debug)]
pub struct PackedCodes {
    /// code width in bits (2..=32)
    pub bits: u32,
    /// number of codes stored
    pub len: usize,
    words: Vec<u64>,
}

impl PackedCodes {
    /// An all-zero buffer ready for [`PackedCodes::set`].
    pub fn zeroed(bits: u32, len: usize) -> Self {
        assert!((2..=32).contains(&bits), "code width {bits} out of range");
        let words = (len * bits as usize).div_ceil(64);
        PackedCodes { bits, len, words: vec![0; words] }
    }

    #[inline]
    fn mask(&self) -> u32 {
        if self.bits == 32 {
            u32::MAX
        } else {
            (1u32 << self.bits) - 1
        }
    }

    /// Write code `i` (buffer must still be zero at that slot).
    #[inline]
    pub fn set(&mut self, i: usize, code: u32) {
        debug_assert!(i < self.len);
        debug_assert!(code <= self.mask(), "code {code} exceeds {} bits", self.bits);
        let bits = self.bits as usize;
        let bit = i * bits;
        let (w, off) = (bit >> 6, bit & 63);
        self.words[w] |= (code as u64) << off;
        if off + bits > 64 {
            self.words[w + 1] |= (code as u64) >> (64 - off);
        }
    }

    /// Read code `i`.
    #[inline]
    pub fn get(&self, i: usize) -> u32 {
        debug_assert!(i < self.len);
        self.get_at_bit(i * self.bits as usize)
    }

    /// Read the code starting at absolute bit offset `bit` (callers keep
    /// an incrementing cursor to skip the per-index multiply).
    #[inline]
    pub fn get_at_bit(&self, bit: usize) -> u32 {
        let bits = self.bits as usize;
        let (w, off) = (bit >> 6, bit & 63);
        let mut v = self.words[w] >> off;
        if off + bits > 64 {
            v |= self.words[w + 1] << (64 - off);
        }
        (v as u32) & self.mask()
    }

    /// Unpack `out.len()` consecutive codes starting at code index
    /// `start`, word-at-a-time (see `unpack_words` above). Bit-exact
    /// with a per-code [`PackedCodes::get`] loop at any alignment —
    /// spans may start mid-word and codes may straddle word boundaries
    /// freely.
    pub fn unpack_span_into(&self, start: usize, out: &mut [u32]) {
        debug_assert!(start + out.len() <= self.len);
        match self.bits {
            // monomorphized fast paths for the widths quantizers emit
            2 => unpack_words::<2, 32>(&self.words, start, out),
            3 => unpack_words::<3, 16>(&self.words, start, out),
            4 => unpack_words::<4, 16>(&self.words, start, out),
            8 => unpack_words::<8, 8>(&self.words, start, out),
            b @ 5..=7 => unpack_words_generic(&self.words, b as usize, start, out),
            // wide codes (no serving quantizer emits them): scalar cursor
            _ => {
                let bits = self.bits as usize;
                let mut bit = start * bits;
                for slot in out.iter_mut() {
                    *slot = self.get_at_bit(bit);
                    bit += bits;
                }
            }
        }
    }

    /// Payload bytes of the packed buffer.
    pub fn bytes(&self) -> usize {
        self.words.len() * 8
    }

    /// The backing 64-bit words, exposed for wire serialization
    /// (`coordinator::wire` ships packed bases between shard processes
    /// without decoding them).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Rebuild a buffer from its raw parts (the wire deserialization
    /// counterpart of [`PackedCodes::words`]). The caller must have
    /// validated the word count against `len`/`bits` — this asserts the
    /// same invariants [`PackedCodes::zeroed`] establishes, including
    /// zero trailing padding bits (set/get never touch them, so a
    /// nonzero tail means the buffer was corrupted or hand-forged and
    /// would silently break word-level equality and content hashing).
    pub fn from_raw(bits: u32, len: usize, words: Vec<u64>) -> Self {
        assert!((2..=32).contains(&bits), "code width {bits} out of range");
        let total_bits = len * bits as usize;
        assert_eq!(
            words.len(),
            total_bits.div_ceil(64),
            "word count mismatch for {len} codes of {bits} bits"
        );
        let tail = total_bits % 64;
        if tail != 0 {
            let last = *words.last().expect("tail bits imply a last word");
            assert_eq!(
                last >> tail,
                0,
                "nonzero padding bits above bit {tail} of the last word"
            );
        }
        PackedCodes { bits, len, words }
    }
}

/// How a [`PackedMat`]'s codes + side data decode back to values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PackScheme {
    /// MXINT block: shared power-of-two scale per `block`, codes are the
    /// signed mantissas offset by `qmax` (value = (code − qmax) · scale).
    MxintBlock { bits: u32, block: usize },
    /// Per-group scalar grid. Symmetric stores codes offset by `qmax`
    /// like MXINT; asymmetric stores unsigned codes plus a per-group
    /// lower bound (value = lo + code · scale).
    UniformGroup { bits: u32, group: usize, symmetric: bool },
    /// GPTQ's grouped asymmetric grid — affine decode; the codes came out
    /// of the Hessian error-feedback loop, not nearest rounding of W.
    GptqGrouped { bits: u32, group: usize },
}

impl PackScheme {
    /// Elements sharing one scale (and lower bound).
    pub fn group_len(&self) -> usize {
        match *self {
            PackScheme::MxintBlock { block, .. } => block,
            PackScheme::UniformGroup { group, .. } | PackScheme::GptqGrouped { group, .. } => {
                group
            }
        }
    }

    pub fn code_bits(&self) -> u32 {
        match *self {
            PackScheme::MxintBlock { bits, .. }
            | PackScheme::UniformGroup { bits, .. }
            | PackScheme::GptqGrouped { bits, .. } => bits,
        }
    }

    /// Symmetric grids center codes on `qmax` and carry no lower bound.
    pub fn is_symmetric(&self) -> bool {
        match *self {
            PackScheme::MxintBlock { .. } => true,
            PackScheme::UniformGroup { symmetric, .. } => symmetric,
            PackScheme::GptqGrouped { .. } => false,
        }
    }
}

/// A quantized matrix in packed form: bit-packed codes plus per-group
/// scales (and lower bounds for the affine schemes), row-major.
#[derive(Clone, Debug)]
pub struct PackedMat {
    pub rows: usize,
    pub cols: usize,
    pub scheme: PackScheme,
    pub codes: PackedCodes,
    /// one scale per group, `groups_per_row()` per row
    pub scales: Vec<f32>,
    /// per-group lower bound (empty for symmetric schemes)
    pub los: Vec<f32>,
}

impl PackedMat {
    pub fn groups_per_row(&self) -> usize {
        self.cols.div_ceil(self.scheme.group_len())
    }

    /// Decode columns `[j0, j1)` of row `i` into `out` (len `j1 - j0`)
    /// through the block unpacker: codes burst into a stack scratch via
    /// [`PackedCodes::unpack_span_into`], then each group segment takes
    /// its affine map in a fixed chunk loop. Bit-exact with
    /// [`PackedMat::decode_span_into_scalar`] at any span alignment.
    pub fn decode_span_into(&self, i: usize, j0: usize, j1: usize, out: &mut [f32]) {
        debug_assert!(i < self.rows && j0 <= j1 && j1 <= self.cols);
        debug_assert_eq!(out.len(), j1 - j0);
        if self.codes.bits > 16 {
            // wide codes overflow the i32 lane math; no serving
            // quantizer emits them, so they keep the reference path
            self.decode_span_into_scalar(i, j0, j1, out);
            return;
        }
        let glen = self.scheme.group_len();
        let gpr = self.groups_per_row();
        let qmax = ((1u32 << (self.codes.bits - 1)) - 1) as i32;
        let symmetric = self.scheme.is_symmetric();
        let scales = &self.scales[i * gpr..(i + 1) * gpr];
        let los: &[f32] = if symmetric { &[] } else { &self.los[i * gpr..(i + 1) * gpr] };
        let base = i * self.cols;
        let mut cbuf = [0u32; DECODE_CHUNK];
        let mut j = j0;
        while j < j1 {
            let take = DECODE_CHUNK.min(j1 - j);
            self.codes.unpack_span_into(base + j, &mut cbuf[..take]);
            let mut s = 0usize; // burst-local cursor
            while s < take {
                let g = (j + s) / glen;
                let e = (((g + 1) * glen).min(j + take)) - j;
                let dst = &mut out[j - j0 + s..j - j0 + e];
                let codes = &cbuf[s..e];
                let scale = scales[g];
                if symmetric {
                    for (slot, &c) in dst.iter_mut().zip(codes) {
                        *slot = (c as i32 - qmax) as f32 * scale;
                    }
                } else {
                    let lo = los[g];
                    for (slot, &c) in dst.iter_mut().zip(codes) {
                        *slot = lo + c as f32 * scale;
                    }
                }
                s = e;
            }
            j += take;
        }
    }

    /// The pre-kernel per-code bit-cursor decode, retained verbatim as
    /// the property-test oracle and the bench reference the block
    /// kernels are measured against (`kernel_bit_identical`).
    pub fn decode_span_into_scalar(&self, i: usize, j0: usize, j1: usize, out: &mut [f32]) {
        debug_assert!(i < self.rows && j0 <= j1 && j1 <= self.cols);
        debug_assert_eq!(out.len(), j1 - j0);
        let glen = self.scheme.group_len();
        let gpr = self.groups_per_row();
        let bits = self.codes.bits as usize;
        let qmax = (1i64 << (self.codes.bits - 1)) - 1;
        let symmetric = self.scheme.is_symmetric();
        let mut j = j0;
        let mut bit = (i * self.cols + j0) * bits;
        while j < j1 {
            let g = j / glen;
            let end = ((g + 1) * glen).min(j1);
            let scale = self.scales[i * gpr + g];
            if symmetric {
                for slot in &mut out[j - j0..end - j0] {
                    let q = self.codes.get_at_bit(bit) as i64 - qmax;
                    bit += bits;
                    *slot = q as f32 * scale;
                }
            } else {
                let lo = self.los[i * gpr + g];
                for slot in &mut out[j - j0..end - j0] {
                    let c = self.codes.get_at_bit(bit) as f32;
                    bit += bits;
                    *slot = lo + c * scale;
                }
            }
            j = end;
        }
    }

    pub fn decode_row_into(&self, i: usize, out: &mut [f32]) {
        self.decode_span_into(i, 0, self.cols, out);
    }

    /// Fused serving hot path: `acc[..] += xv · row_i[j0..j1)`, decoding
    /// on the fly with the scalar folded per group (`u = xv · scale`), so
    /// a batch-1 matvec makes a single pass over the codes with no
    /// intermediate buffer. Runs the same block unpack as
    /// [`PackedMat::decode_span_into`]; bit-exact with
    /// [`PackedMat::axpy_span_scalar`].
    pub fn axpy_span(&self, i: usize, j0: usize, j1: usize, xv: f32, acc: &mut [f32]) {
        debug_assert!(i < self.rows && j0 <= j1 && j1 <= self.cols);
        debug_assert_eq!(acc.len(), j1 - j0);
        if self.codes.bits > 16 {
            self.axpy_span_scalar(i, j0, j1, xv, acc);
            return;
        }
        let glen = self.scheme.group_len();
        let gpr = self.groups_per_row();
        let qmax = ((1u32 << (self.codes.bits - 1)) - 1) as i32;
        let symmetric = self.scheme.is_symmetric();
        let scales = &self.scales[i * gpr..(i + 1) * gpr];
        let los: &[f32] = if symmetric { &[] } else { &self.los[i * gpr..(i + 1) * gpr] };
        let base = i * self.cols;
        let mut cbuf = [0u32; DECODE_CHUNK];
        let mut j = j0;
        while j < j1 {
            let take = DECODE_CHUNK.min(j1 - j);
            self.codes.unpack_span_into(base + j, &mut cbuf[..take]);
            let mut s = 0usize;
            while s < take {
                let g = (j + s) / glen;
                let e = (((g + 1) * glen).min(j + take)) - j;
                let dst = &mut acc[j - j0 + s..j - j0 + e];
                let codes = &cbuf[s..e];
                let u = xv * scales[g];
                if symmetric {
                    for (slot, &c) in dst.iter_mut().zip(codes) {
                        *slot += (c as i32 - qmax) as f32 * u;
                    }
                } else {
                    let xlo = xv * los[g];
                    for (slot, &c) in dst.iter_mut().zip(codes) {
                        *slot += xlo + c as f32 * u;
                    }
                }
                s = e;
            }
            j += take;
        }
    }

    /// The pre-kernel per-code fused axpy, retained verbatim as the
    /// oracle/bench twin of [`PackedMat::axpy_span`].
    pub fn axpy_span_scalar(&self, i: usize, j0: usize, j1: usize, xv: f32, acc: &mut [f32]) {
        debug_assert!(i < self.rows && j0 <= j1 && j1 <= self.cols);
        debug_assert_eq!(acc.len(), j1 - j0);
        let glen = self.scheme.group_len();
        let gpr = self.groups_per_row();
        let bits = self.codes.bits as usize;
        let qmax = (1i64 << (self.codes.bits - 1)) - 1;
        let symmetric = self.scheme.is_symmetric();
        let mut j = j0;
        let mut bit = (i * self.cols + j0) * bits;
        while j < j1 {
            let g = j / glen;
            let end = ((g + 1) * glen).min(j1);
            let u = xv * self.scales[i * gpr + g];
            if symmetric {
                for slot in &mut acc[j - j0..end - j0] {
                    let q = self.codes.get_at_bit(bit) as i64 - qmax;
                    bit += bits;
                    *slot += q as f32 * u;
                }
            } else {
                let xlo = xv * self.los[i * gpr + g];
                for slot in &mut acc[j - j0..end - j0] {
                    let c = self.codes.get_at_bit(bit) as f32;
                    bit += bits;
                    *slot += xlo + c * u;
                }
            }
            j = end;
        }
    }

    /// Unpack to the dense dequantized matrix — bit-identical to the
    /// originating quantizer's output (see the module exactness contract).
    pub fn dequantize(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            self.decode_span_into(i, 0, self.cols, out.row_mut(i));
        }
        out
    }

    /// Payload bytes of the packed form (codes + scales + lower bounds).
    pub fn bytes(&self) -> usize {
        self.codes.bytes() + (self.scales.len() + self.los.len()) * 4
    }

    /// Bytes the dense f32 form of the same matrix occupies.
    pub fn dense_bytes(&self) -> usize {
        self.rows * self.cols * 4
    }

    /// Effective bits per weight of the packed form, side data included.
    pub fn effective_bits(&self) -> f64 {
        self.bytes() as f64 * 8.0 / (self.rows * self.cols) as f64
    }
}

/// Code/side-data accumulator the quantizers fill while rounding; turned
/// into a [`PackedMat`] once the full matrix has been visited.
#[derive(Default)]
pub struct PackAcc {
    pub codes: Vec<u32>,
    pub scales: Vec<f32>,
    pub los: Vec<f32>,
}

impl PackAcc {
    pub fn with_capacity(n_codes: usize, n_groups: usize, affine: bool) -> Self {
        PackAcc {
            codes: Vec::with_capacity(n_codes),
            scales: Vec::with_capacity(n_groups),
            los: Vec::with_capacity(if affine { n_groups } else { 0 }),
        }
    }

    pub fn into_packed(self, rows: usize, cols: usize, scheme: PackScheme) -> PackedMat {
        let gpr = cols.div_ceil(scheme.group_len());
        assert_eq!(self.codes.len(), rows * cols, "code count mismatch");
        assert_eq!(self.scales.len(), rows * gpr, "scale count mismatch");
        if scheme.is_symmetric() {
            assert!(self.los.is_empty(), "symmetric scheme carries no lower bounds");
        } else {
            assert_eq!(self.los.len(), rows * gpr, "lower-bound count mismatch");
        }
        let mut codes = PackedCodes::zeroed(scheme.code_bits(), rows * cols);
        for (i, &c) in self.codes.iter().enumerate() {
            codes.set(i, c);
        }
        PackedMat { rows, cols, scheme, codes, scales: self.scales, los: self.los }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn codes_round_trip_across_word_boundaries() {
        // 3-bit codes misalign against the 64-bit words every 64/gcd steps
        for bits in [2u32, 3, 5, 7, 12, 17, 32] {
            let len = 257;
            let modulus = if bits == 32 { u64::from(u32::MAX) + 1 } else { 1u64 << bits };
            let vals: Vec<u32> =
                (0..len).map(|i| ((i as u64 * 2654435761) % modulus) as u32).collect();
            let mut codes = PackedCodes::zeroed(bits, len);
            for (i, &v) in vals.iter().enumerate() {
                codes.set(i, v);
            }
            for (i, &v) in vals.iter().enumerate() {
                assert_eq!(codes.get(i), v, "bits={bits} i={i}");
            }
        }
    }

    #[test]
    fn prop_codes_round_trip() {
        // Satellite invariant: set/get round-trips arbitrary code streams
        // for every width, including straddled word boundaries.
        prop::check(0xAC0DE5, 30, |g| {
            let bits = g.choice(&[2u32, 3, 4, 6, 8, 11, 16]);
            let len = g.dim(400);
            let mask = (1u64 << bits) - 1;
            let vals: Vec<u32> = (0..len).map(|_| (g.rng.next_u64() & mask) as u32).collect();
            let mut codes = PackedCodes::zeroed(bits, len);
            for (i, &v) in vals.iter().enumerate() {
                codes.set(i, v);
            }
            for (i, &v) in vals.iter().enumerate() {
                assert_eq!(codes.get(i), v, "bits={bits} i={i}/{len}");
            }
        });
    }

    #[test]
    fn unpack_span_matches_per_code_get() {
        // every dispatch arm (2/3/4/8 monomorphized, 5..=7 generic
        // word-pair, >8 scalar cursor), at starts that land mid-word and
        // spans whose codes straddle u64 boundaries
        for bits in [2u32, 3, 4, 5, 6, 7, 8, 11, 16, 32] {
            let len = 517;
            let modulus = if bits == 32 { u64::from(u32::MAX) + 1 } else { 1u64 << bits };
            let vals: Vec<u32> =
                (0..len).map(|i| ((i as u64 * 2654435761 + 977) % modulus) as u32).collect();
            let mut codes = PackedCodes::zeroed(bits, len);
            for (i, &v) in vals.iter().enumerate() {
                codes.set(i, v);
            }
            for start in [0usize, 1, 7, 20, 21, 42, 63, 64, 65, 127, 500, len] {
                for span in [0usize, 1, 5, 13, 16, 17, 64, len - start] {
                    if start + span > len {
                        continue;
                    }
                    let mut out = vec![0u32; span];
                    codes.unpack_span_into(start, &mut out);
                    for (k, &o) in out.iter().enumerate() {
                        assert_eq!(
                            o,
                            vals[start + k],
                            "bits={bits} start={start} span={span} lane={k}"
                        );
                    }
                }
            }
        }
    }

    /// Builds a random [`PackedMat`] of the given family with `bits` in
    /// 2..=8 and group lengths that misalign against both the chunk
    /// bursts and the u64 words.
    fn random_packed(g: &mut prop::Gen) -> PackedMat {
        let bits = 2 + g.rng.below(7) as u32; // 2..=8
        let glen = g.choice(&[3usize, 7, 8, 32, 33]);
        let scheme = match g.rng.below(3) {
            0 => PackScheme::MxintBlock { bits, block: glen },
            1 => PackScheme::UniformGroup { bits, group: glen, symmetric: g.rng.below(2) == 0 },
            _ => PackScheme::GptqGrouped { bits, group: glen },
        };
        let rows = g.dim(4);
        let cols = g.dim(97);
        let gpr = cols.div_ceil(glen);
        let mask = (1u64 << bits) - 1;
        let mut acc = PackAcc::default();
        for _ in 0..rows {
            for _ in 0..gpr {
                acc.scales.push(g.f32_in(0.01, 2.0));
                if !scheme.is_symmetric() {
                    acc.los.push(g.f32_in(-3.0, 3.0));
                }
            }
            for _ in 0..cols {
                acc.codes.push((g.rng.next_u64() & mask) as u32);
            }
        }
        acc.into_packed(rows, cols, scheme)
    }

    /// Satellite invariant: block-kernel span decode and fused axpy are
    /// bit-exact with the scalar reference AND with `dequantize()` for
    /// **unaligned** spans — `j0`/`j1` landing mid-group, codes
    /// straddling u64 word boundaries — across all three `PackScheme`
    /// families × bits 2..=8. Failures print a `replay seed: 0x…`;
    /// re-run one case via `util::prop::replay(seed, |g| { same body })`.
    #[test]
    fn prop_unaligned_span_decode_is_bit_exact() {
        prop::check(0xB10CDE, 40, |g| {
            let p = random_packed(g);
            let (rows, cols) = (p.rows, p.cols);
            let full = p.dequantize();
            for _ in 0..8 {
                let i = g.rng.below(rows);
                let j0 = g.rng.below(cols);
                let j1 = j0 + g.rng.below(cols - j0 + 1);
                let w = j1 - j0;
                let mut fast = vec![0.0f32; w];
                let mut slow = vec![0.0f32; w];
                p.decode_span_into(i, j0, j1, &mut fast);
                p.decode_span_into_scalar(i, j0, j1, &mut slow);
                for (k, (a, b)) in fast.iter().zip(&slow).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "decode {:?} row {i} span {j0}..{j1} lane {k}",
                        p.scheme
                    );
                }
                for (k, (a, b)) in fast.iter().zip(&full.row(i)[j0..j1]).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "decode vs dequantize {:?} row {i} span {j0}..{j1} lane {k}",
                        p.scheme
                    );
                }

                let xv = g.f32_in(-2.0, 2.0);
                let mut acc_fast: Vec<f32> = (0..w).map(|_| g.f32_in(-1.0, 1.0)).collect();
                let mut acc_slow = acc_fast.clone();
                p.axpy_span(i, j0, j1, xv, &mut acc_fast);
                p.axpy_span_scalar(i, j0, j1, xv, &mut acc_slow);
                for (k, (a, b)) in acc_fast.iter().zip(&acc_slow).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "axpy {:?} row {i} span {j0}..{j1} lane {k}",
                        p.scheme
                    );
                }
            }
        });
    }

    #[test]
    fn wide_codes_decode_through_scalar_fallback() {
        // 17-bit codes take the bits>16 delegation; the two paths must
        // still agree bit-for-bit
        let scheme = PackScheme::UniformGroup { bits: 17, group: 5, symmetric: true };
        let (rows, cols) = (2usize, 13usize);
        let gpr = cols.div_ceil(5);
        let mut acc = PackAcc::default();
        for i in 0..rows {
            for gidx in 0..gpr {
                acc.scales.push(0.25 + (i + gidx) as f32 * 0.5);
            }
            for j in 0..cols {
                acc.codes.push(((i * cols + j) * 7919 % (1 << 17)) as u32);
            }
        }
        let p = acc.into_packed(rows, cols, scheme);
        for i in 0..rows {
            let mut fast = vec![0.0f32; cols];
            let mut slow = vec![0.0f32; cols];
            p.decode_span_into(i, 0, cols, &mut fast);
            p.decode_span_into_scalar(i, 0, cols, &mut slow);
            assert_eq!(fast, slow, "row {i}");
        }
    }

    #[test]
    fn raw_parts_round_trip() {
        // the wire-serialization accessors reproduce the buffer exactly
        let mut codes = PackedCodes::zeroed(3, 100);
        for i in 0..100 {
            codes.set(i, (i % 8) as u32);
        }
        let rebuilt = PackedCodes::from_raw(3, 100, codes.words().to_vec());
        for i in 0..100 {
            assert_eq!(rebuilt.get(i), codes.get(i), "code {i}");
        }
    }

    #[test]
    #[should_panic(expected = "word count mismatch")]
    fn raw_parts_validate_word_count() {
        let _ = PackedCodes::from_raw(3, 100, vec![0; 1]);
    }

    #[test]
    #[should_panic(expected = "word count mismatch")]
    fn raw_parts_reject_oversized_word_buffer() {
        // 100 3-bit codes need ceil(300/64) = 5 words; 6 is a lie too
        let _ = PackedCodes::from_raw(3, 100, vec![0; 6]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn raw_parts_reject_code_width_out_of_range() {
        let _ = PackedCodes::from_raw(1, 64, vec![0; 1]);
    }

    #[test]
    #[should_panic(expected = "nonzero padding bits")]
    fn raw_parts_reject_nonzero_padding_bits() {
        // 100 3-bit codes = 300 bits: bits 44..64 of word 4 are padding
        // the pack path never writes, so a set bit there is corruption
        let mut words = vec![0u64; 5];
        words[4] = 1u64 << 63;
        let _ = PackedCodes::from_raw(3, 100, words);
    }

    #[test]
    fn raw_parts_accept_full_last_word_without_padding() {
        // 32 2-bit codes fill exactly one word — all 64 bits are code
        // payload, so a saturated word is legal (no padding to check)
        let rebuilt = PackedCodes::from_raw(2, 32, vec![u64::MAX]);
        for i in 0..32 {
            assert_eq!(rebuilt.get(i), 3, "code {i}");
        }
    }

    #[test]
    fn packed_buffer_is_actually_small() {
        let codes = PackedCodes::zeroed(3, 1024);
        // 3072 bits = 48 words = 384 bytes vs 4096 dense f32 bytes
        assert_eq!(codes.bytes(), 384);
    }

    #[test]
    fn decode_span_matches_full_dequantize() {
        // hand-build a 2-row affine PackedMat and check span decode
        let scheme = PackScheme::UniformGroup { bits: 4, group: 3, symmetric: false };
        let (rows, cols) = (2usize, 7usize);
        let gpr = cols.div_ceil(3);
        let mut acc = PackAcc::default();
        for i in 0..rows {
            for g in 0..gpr {
                acc.scales.push(0.5 + i as f32);
                acc.los.push(-1.0 + g as f32 * 0.25);
            }
            for j in 0..cols {
                acc.codes.push(((i * cols + j) % 16) as u32);
            }
        }
        let p = acc.into_packed(rows, cols, scheme);
        let full = p.dequantize();
        for i in 0..rows {
            for (j0, j1) in [(0usize, 7usize), (1, 4), (2, 7), (5, 5)] {
                let mut buf = vec![0.0f32; j1 - j0];
                p.decode_span_into(i, j0, j1, &mut buf);
                assert_eq!(&full.row(i)[j0..j1], &buf[..], "row {i} span {j0}..{j1}");
            }
        }
        assert!(p.bytes() < p.dense_bytes());
        assert!(p.effective_bits() < 32.0);
    }

    #[test]
    #[should_panic(expected = "scale count mismatch")]
    fn pack_acc_validates_side_data() {
        let acc = PackAcc { codes: vec![0; 8], scales: vec![], los: vec![] };
        let _ = acc.into_packed(2, 4, PackScheme::MxintBlock { bits: 3, block: 4 });
    }
}
