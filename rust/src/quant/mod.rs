//! Weight quantizers.
//!
//! All quantizers implement [`Quantizer`] and return the *dequantized*
//! matrix (f32); the factored serving path additionally obtains the
//! bit-packed low-bit encoding through [`Quantizer::quantize_coded`]
//! (see [`packed`]) so inference never has to carry dense f32 bases.
//!
//! * [`mxint`] — MXINT-b, block-32 shared power-of-two exponent
//!   (Darvish Rouhani et al. 2023); byte-exact vs the Pallas kernel /
//!   ref.py oracle (checked by the `kernel_parity` integration test).
//! * [`uniform`] — per-group affine (symmetric/asymmetric) scalar grid.
//! * [`gptq`] — Hessian-guided sequential rounding with error feedback
//!   (Frantar et al. 2023): group 128, damping 0.01.
//! * [`quipsharp`] — QuIP#-sim: randomized two-sided Hadamard incoherence
//!   + 2-bit grid in the rotated space (lattice codebook substituted by a
//!   scalar grid; see DESIGN.md §2 substitution table).

mod mxint;
pub mod packed;
mod uniform;
mod gptq;
mod quipsharp;

pub use gptq::GptqQuantizer;
pub use mxint::MxintQuantizer;
pub use packed::{PackScheme, PackedCodes, PackedMat};
pub use quipsharp::QuipSharpQuantizer;
pub use uniform::UniformQuantizer;

use crate::tensor::Mat;

/// Side information some quantizers need.
#[derive(Default)]
pub struct QuantCtx {
    /// Gram matrix of calibration activations, H = XᵀX / n  (m×m), for GPTQ.
    pub hessian: Option<Mat>,
    /// Seed for randomized components (QuIP# sign diagonals).
    pub seed: u64,
}

pub trait Quantizer: Send + Sync {
    fn name(&self) -> String;
    /// Effective bits per weight including shared-exponent/scale overhead.
    fn effective_bits(&self) -> f64;
    /// Quantize and immediately dequantize `w`.
    fn quantize(&self, w: &Mat, ctx: &QuantCtx) -> Mat;

    /// Quantize `w`, additionally returning the bit-packed encoding the
    /// factored serving path carries. Contract: the dense output is
    /// bit-identical to [`Quantizer::quantize`] and
    /// `packed.dequantize()` reproduces it bit-exactly. The default
    /// packs nothing (QuIP#-sim's codes live in a rotated basis; its
    /// serving base stays dense).
    fn quantize_coded(&self, w: &Mat, ctx: &QuantCtx) -> (Mat, Option<PackedMat>) {
        (self.quantize(w, ctx), None)
    }
}

/// The paper's default PTQ quantizer: 3-bit MXINT, block 32 (→ 3.25 bits).
pub fn default_mxint3() -> MxintQuantizer {
    MxintQuantizer::new(3, 32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Shared sanity: quantization error energy shrinks as bits grow.
    #[test]
    fn more_bits_less_error() {
        let mut rng = Rng::new(60);
        let w = Mat::randn(32, 128, 1.0, &mut rng);
        let ctx = QuantCtx::default();
        let mut prev = f64::INFINITY;
        for bits in [2u32, 3, 4, 6, 8] {
            let q = MxintQuantizer::new(bits, 32).quantize(&w, &ctx);
            let err = w.sub(&q).frob();
            assert!(err < prev, "bits={bits}: {err} !< {prev}");
            prev = err;
        }
    }

    /// Relative error scale η_Q is roughly constant across inputs with the
    /// same quantizer — the empirical backbone of Assumption 4.1.
    #[test]
    fn eta_q_is_stable_across_matrices() {
        let mut rng = Rng::new(61);
        let ctx = QuantCtx::default();
        let q3 = MxintQuantizer::new(3, 32);
        let etas: Vec<f64> = (0..8)
            .map(|i| {
                let w = Mat::randn(64, 128, 0.5 + 0.2 * i as f32, &mut rng);
                let qd = q3.quantize(&w, &ctx);
                w.sub(&qd).frob() / w.frob()
            })
            .collect();
        let cv = crate::util::stats::coeff_of_variation(&etas);
        assert!(cv < 0.25, "cv={cv} etas={etas:?}");
    }
}
