//! The continuous-batching scheduler.
//!
//! Pure decision logic: given the set of admitted in-flight requests,
//! pick the next lock-step batch. No IO, no wall clock, no threads —
//! every method is a deterministic function of the scheduler's state
//! and its arguments, which is what lets the property harness replay
//! any schedule bit-exactly from a seed.
//!
//! Batching rule: all members of a batch must share the same current
//! sequence length (the fleet forward is a lock-step `g × b × t`
//! stack), so `take_batch` picks the **oldest** waiting request (lowest
//! admission sequence number) and fills the batch with other waiting
//! requests of the same current length, oldest-first, up to
//! `max_batch`. Unfinished members are `restore`d after the step and
//! compete again next round — a freshly admitted short request can
//! therefore join a half-decoded batch as soon as its lengths align,
//! which is exactly continuous batching.

use super::clock::Tick;
use super::protocol::ReqKind;

/// Scheduler limits (admission control).
#[derive(Clone, Copy, Debug)]
pub struct SchedConfig {
    /// max in-flight requests; admission sheds beyond this (backpressure)
    pub max_slots: usize,
    /// max requests evaluated in one lock-step forward
    pub max_batch: usize,
}

/// One admitted request occupying a scheduler slot.
#[derive(Clone, Debug)]
pub struct SlotRequest {
    /// owning connection (slots are freed when it disconnects)
    pub conn: u64,
    /// client-chosen request id (reply routing key)
    pub id: u64,
    /// index into the engine's served-variant table
    pub variant: usize,
    /// prompt token ids
    pub tokens: Vec<i32>,
    /// tokens decoded so far (generate requests only)
    pub produced: Vec<i32>,
    /// what to do with the prompt
    pub kind: ReqKind,
    /// admission order — the scheduler's total tie-break order
    pub seq: u64,
    /// tick at which the request was admitted
    pub admitted: Tick,
}

impl SlotRequest {
    /// Current sequence length: prompt plus everything decoded so far.
    pub fn cur_len(&self) -> usize {
        self.tokens.len() + self.produced.len()
    }
}

/// Admission verdict.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admit {
    /// the request holds a slot and will be scheduled
    Accepted,
    /// all slots busy — request shed with an explicit busy reply
    Busy,
}

/// Deterministic continuous-batching scheduler over a bounded slot
/// pool.
#[derive(Debug)]
pub struct Scheduler {
    cfg: SchedConfig,
    slots: Vec<SlotRequest>,
    next_seq: u64,
}

impl Scheduler {
    /// An empty scheduler with the given limits.
    pub fn new(cfg: SchedConfig) -> Self {
        Scheduler { cfg, slots: Vec::new(), next_seq: 0 }
    }

    /// Number of in-flight requests holding slots.
    pub fn active(&self) -> usize {
        self.slots.len()
    }

    /// Admit a request, or shed it when every slot is taken. The
    /// `seq`/`admitted` fields of `req` are overwritten here — callers
    /// pass zeros.
    pub fn admit(&mut self, mut req: SlotRequest, now: Tick) -> Admit {
        if self.slots.len() >= self.cfg.max_slots {
            return Admit::Busy;
        }
        req.seq = self.next_seq;
        self.next_seq += 1;
        req.admitted = now;
        self.slots.push(req);
        Admit::Accepted
    }

    /// Cancel one waiting request by `(conn, id)`; returns whether a
    /// slot was freed.
    pub fn cancel(&mut self, conn: u64, id: u64) -> bool {
        let before = self.slots.len();
        self.slots.retain(|s| !(s.conn == conn && s.id == id));
        self.slots.len() < before
    }

    /// Free every slot owned by a disconnected connection; returns how
    /// many were freed.
    pub fn drop_conn(&mut self, conn: u64) -> usize {
        let before = self.slots.len();
        self.slots.retain(|s| s.conn != conn);
        before - self.slots.len()
    }

    /// Remove and return the next lock-step batch: the oldest waiting
    /// request plus every other waiting request of the same current
    /// length, oldest-first, capped at `max_batch`. Empty when idle.
    pub fn take_batch(&mut self) -> Vec<SlotRequest> {
        let Some(oldest) = self.slots.iter().min_by_key(|s| s.seq) else {
            return Vec::new();
        };
        let t0 = oldest.cur_len();
        let mut picked: Vec<u64> = self
            .slots
            .iter()
            .filter(|s| s.cur_len() == t0)
            .map(|s| s.seq)
            .collect();
        picked.sort_unstable();
        picked.truncate(self.cfg.max_batch);
        let mut batch = Vec::with_capacity(picked.len());
        let mut kept = Vec::with_capacity(self.slots.len());
        for s in self.slots.drain(..) {
            if picked.contains(&s.seq) {
                batch.push(s);
            } else {
                kept.push(s);
            }
        }
        self.slots = kept;
        batch.sort_by_key(|s| s.seq);
        batch
    }

    /// Return an unfinished request to its slot after a step. Its
    /// `seq` is preserved, so scheduling priority is stable across
    /// steps.
    pub fn restore(&mut self, req: SlotRequest) {
        self.slots.push(req);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(conn: u64, id: u64, len: usize) -> SlotRequest {
        SlotRequest {
            conn,
            id,
            variant: 0,
            tokens: vec![1; len],
            produced: Vec::new(),
            kind: ReqKind::Score,
            seq: 0,
            admitted: 0,
        }
    }

    #[test]
    fn batches_group_by_length_oldest_first() {
        let mut s = Scheduler::new(SchedConfig { max_slots: 8, max_batch: 2 });
        assert_eq!(s.admit(req(1, 1, 4), 0), Admit::Accepted); // seq 0, len 4
        assert_eq!(s.admit(req(1, 2, 5), 0), Admit::Accepted); // seq 1, len 5
        assert_eq!(s.admit(req(2, 3, 4), 0), Admit::Accepted); // seq 2, len 4
        assert_eq!(s.admit(req(2, 4, 4), 0), Admit::Accepted); // seq 3, len 4
        // oldest is seq 0 (len 4); same-length peers seq 2, 3; cap 2.
        let b = s.take_batch();
        assert_eq!(b.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(s.active(), 2);
        // next round: oldest remaining is seq 1 (len 5), alone.
        let b = s.take_batch();
        assert_eq!(b.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2]);
        // last: seq 3.
        let b = s.take_batch();
        assert_eq!(b.iter().map(|r| r.id).collect::<Vec<_>>(), vec![4]);
        assert!(s.take_batch().is_empty());
    }

    #[test]
    fn admission_sheds_at_capacity_and_frees_on_cancel() {
        let mut s = Scheduler::new(SchedConfig { max_slots: 2, max_batch: 8 });
        assert_eq!(s.admit(req(1, 1, 3), 0), Admit::Accepted);
        assert_eq!(s.admit(req(1, 2, 3), 0), Admit::Accepted);
        assert_eq!(s.admit(req(1, 3, 3), 0), Admit::Busy);
        assert!(s.cancel(1, 2));
        assert!(!s.cancel(1, 2)); // already gone
        assert_eq!(s.admit(req(1, 3, 3), 1), Admit::Accepted);
        assert_eq!(s.active(), 2);
    }

    #[test]
    fn drop_conn_frees_every_owned_slot() {
        let mut s = Scheduler::new(SchedConfig { max_slots: 8, max_batch: 8 });
        s.admit(req(7, 1, 3), 0);
        s.admit(req(7, 2, 3), 0);
        s.admit(req(9, 3, 3), 0);
        assert_eq!(s.drop_conn(7), 2);
        assert_eq!(s.active(), 1);
        assert_eq!(s.take_batch()[0].id, 3);
    }

    #[test]
    fn restore_preserves_priority() {
        let mut s = Scheduler::new(SchedConfig { max_slots: 8, max_batch: 1 });
        s.admit(req(1, 1, 3), 0);
        s.admit(req(1, 2, 3), 0);
        let mut b = s.take_batch();
        assert_eq!(b[0].id, 1);
        // simulate one decoded token, then restore: id 1 now has len 4
        let mut r = b.pop().unwrap();
        r.produced.push(42);
        s.restore(r);
        // oldest is still id 1 (seq 0) even though id 2 arrived earlier
        // at its current length.
        assert_eq!(s.take_batch()[0].id, 1);
    }
}
