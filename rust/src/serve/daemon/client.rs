//! The serving client: dial a daemon over TCP (HELLO handshake
//! included) or attach in-process over any reader/writer pair, send
//! requests, receive replies.
//!
//! The client is deliberately thin — frames in, frames out — so the
//! load generator can split it into independent send/receive halves
//! and drive the daemon open-loop (sends never wait for replies).

use std::io::{BufWriter, Read, Write};
use std::net::TcpStream;

use anyhow::{Context, Result};

use crate::coordinator::transport::worker_connect;
use crate::coordinator::wire::{kind, read_frame_limited};

use super::protocol::{
    decode_reply, encode_cancel, encode_request, ReqKind, ServeReply, ServeRequest,
    SERVE_MAX_REQUEST_LEN,
};

/// The sending half: owns the write stream and the request-id counter.
pub struct ClientTx {
    writer: Box<dyn Write + Send>,
    next_id: u64,
    variant: String,
}

/// The receiving half: owns the read stream.
pub struct ClientRx {
    reader: Box<dyn Read + Send>,
}

/// A connected serving client (a [`ClientTx`] / [`ClientRx`] pair).
pub struct ServeClient {
    tx: ClientTx,
    rx: ClientRx,
}

impl ServeClient {
    /// Dial a daemon over TCP, passing the HELLO handshake as a
    /// worker-role peer. `variant` is the served variant this client's
    /// requests run under.
    pub fn dial(addr: &str, variant: &str) -> Result<ServeClient> {
        let stream = worker_connect(addr, 0)?;
        let read_half = stream.try_clone().context("cloning client stream")?;
        Ok(Self::over(
            Box::new(BufWriter::new(stream)),
            Box::new(read_half),
            variant,
        ))
    }

    /// Attach over an already-open reader/writer pair (in-process
    /// clients admitted via `DaemonHandle::admit`, which skips the TCP
    /// handshake).
    pub fn over(
        writer: Box<dyn Write + Send>,
        reader: Box<dyn Read + Send>,
        variant: &str,
    ) -> ServeClient {
        ServeClient {
            tx: ClientTx { writer, next_id: 1, variant: variant.to_string() },
            rx: ClientRx { reader },
        }
    }

    /// Split into independent send/receive halves (open-loop load
    /// generation: one thread sends on schedule, another drains
    /// replies).
    pub fn split(self) -> (ClientTx, ClientRx) {
        (self.tx, self.rx)
    }

    /// Send a generate request; returns its id.
    pub fn send_generate(&mut self, tokens: &[i32], max_new: usize) -> Result<u64> {
        self.tx.send_generate(tokens, max_new)
    }

    /// Send a score request; returns its id.
    pub fn send_score(&mut self, tokens: &[i32]) -> Result<u64> {
        self.tx.send_score(tokens)
    }

    /// Cancel an in-flight request by id (fire-and-forget; the daemon
    /// sends no reply for cancels).
    pub fn cancel(&mut self, id: u64) -> Result<()> {
        self.tx.cancel(id)
    }

    /// Block for the next reply.
    pub fn recv(&mut self) -> Result<ServeReply> {
        self.rx.recv()
    }

    /// Convenience: send one generate request and block for its reply.
    pub fn generate(&mut self, tokens: &[i32], max_new: usize) -> Result<ServeReply> {
        let id = self.send_generate(tokens, max_new)?;
        self.recv_for(id)
    }

    /// Convenience: send one score request and block for its reply.
    pub fn score(&mut self, tokens: &[i32]) -> Result<ServeReply> {
        let id = self.send_score(tokens)?;
        self.recv_for(id)
    }

    fn recv_for(&mut self, id: u64) -> Result<ServeReply> {
        loop {
            let reply = self.recv()?;
            if reply.id() == id {
                return Ok(reply);
            }
        }
    }
}

impl ClientTx {
    /// Send a generate request; returns its id.
    pub fn send_generate(&mut self, tokens: &[i32], max_new: usize) -> Result<u64> {
        self.send(tokens, ReqKind::Generate { max_new })
    }

    /// Send a score request; returns its id.
    pub fn send_score(&mut self, tokens: &[i32]) -> Result<u64> {
        self.send(tokens, ReqKind::Score)
    }

    /// Cancel an in-flight request by id.
    pub fn cancel(&mut self, id: u64) -> Result<()> {
        encode_cancel(id).write_to(&mut self.writer)?;
        self.writer.flush()?;
        Ok(())
    }

    fn send(&mut self, tokens: &[i32], kind: ReqKind) -> Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let req = ServeRequest {
            id,
            variant: self.variant.clone(),
            tokens: tokens.to_vec(),
            kind,
        };
        encode_request(&req).write_to(&mut self.writer)?;
        self.writer.flush()?;
        Ok(id)
    }
}

impl ClientRx {
    /// Block for the next reply frame; EOF and protocol violations are
    /// errors.
    pub fn recv(&mut self) -> Result<ServeReply> {
        let frame = read_frame_limited(&mut self.reader, SERVE_MAX_REQUEST_LEN)
            .map_err(|e| anyhow::anyhow!("reading serve reply: {e}"))?
            .context("daemon closed the connection")?;
        anyhow::ensure!(
            frame.kind == kind::SERVE_REPLY,
            "unexpected frame kind {} from daemon",
            frame.kind
        );
        decode_reply(&frame.payload).map_err(|e| anyhow::anyhow!("decoding serve reply: {e}"))
    }
}

/// Dial a daemon and return the raw handshaken stream (the load
/// generator's socket-timeout path needs the `TcpStream` itself).
pub fn dial_raw(addr: &str) -> Result<TcpStream> {
    worker_connect(addr, 0)
}
