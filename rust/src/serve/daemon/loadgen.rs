//! Open-loop synthetic load generator for the serving daemon.
//!
//! Each client thread dials the daemon over TCP, scripts its prompts
//! from a seeded [`Rng`], and sends on a fixed cadence **without
//! waiting for replies** (open loop — the arrival rate never adapts to
//! the daemon, so queueing shows up in the latency tail instead of
//! being hidden by client backoff). A paired reader thread timestamps
//! replies. The wall clock here *measures*; it never decides — request
//! content is a pure function of the spec's seed, which is what lets
//! the bench re-run every completed request against the serial oracle
//! and assert bit-identity.

use std::collections::HashMap;
use std::io::BufWriter;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::Rng;

use super::client::{dial_raw, ServeClient};
use super::protocol::{ReqKind, ServeReply};

/// What load to offer.
#[derive(Clone, Debug)]
pub struct LoadSpec {
    /// concurrent client connections
    pub clients: usize,
    /// requests each client sends
    pub per_client: usize,
    /// send cadence per client (open loop)
    pub gap: Duration,
    /// prompt length (≥ 2)
    pub prompt_len: usize,
    /// tokens each generate request asks for
    pub max_new: usize,
    /// vocab to draw prompt tokens from
    pub vocab: usize,
    /// served variant names; client `i` uses `variants[i % len]`
    pub variants: Vec<String>,
    /// every k-th request is a score instead of a generate (0 = never)
    pub score_every: usize,
    /// base seed; client `i` scripts from `seed ^ i`
    pub seed: u64,
}

/// One finished request: what was sent, what came back, how long it
/// took. Carries everything the oracle check needs to re-run the
/// request serially.
#[derive(Clone, Debug)]
pub struct LoadOutcome {
    /// served variant name
    pub variant: String,
    /// the scripted prompt
    pub tokens: Vec<i32>,
    /// generate or score
    pub kind: ReqKind,
    /// the daemon's reply
    pub reply: ServeReply,
    /// send-to-reply latency
    pub latency: Duration,
}

/// Aggregated load-generation results.
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// requests sent across all clients
    pub sent: usize,
    /// requests answered with tokens or a score
    pub completed: usize,
    /// requests shed with a busy reply
    pub busy: usize,
    /// requests answered with an error reply (or lost to disconnects)
    pub errors: usize,
    /// completed requests per wall-clock second
    pub sustained_rps: f64,
    /// median completed-request latency, milliseconds
    pub p50_ms: f64,
    /// 99th-percentile completed-request latency, milliseconds
    pub p99_ms: f64,
    /// every per-request outcome, for oracle replay
    pub outcomes: Vec<LoadOutcome>,
}

/// The prompts and kinds client `i` will send — exposed so the oracle
/// check can regenerate exactly what the load run sent.
pub fn scripted_requests(spec: &LoadSpec, client: usize) -> Vec<(Vec<i32>, ReqKind)> {
    let mut rng = Rng::new(spec.seed ^ client as u64);
    (0..spec.per_client)
        .map(|j| {
            let tokens: Vec<i32> = (0..spec.prompt_len)
                .map(|_| rng.below(spec.vocab) as i32)
                .collect();
            let kind = if spec.score_every > 0 && (j + 1) % spec.score_every == 0 {
                ReqKind::Score
            } else {
                ReqKind::Generate { max_new: spec.max_new }
            };
            (tokens, kind)
        })
        .collect()
}

/// Drive `spec` against a TCP daemon at `addr`; blocks until every
/// client finishes (reply, error, or read timeout per connection).
pub fn run_open_loop(addr: &str, spec: &LoadSpec) -> Result<LoadReport> {
    assert!(spec.clients >= 1 && !spec.variants.is_empty());
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for i in 0..spec.clients {
        let addr = addr.to_string();
        let spec = spec.clone();
        handles.push(std::thread::spawn(move || client_main(&addr, &spec, i)));
    }
    let mut outcomes = Vec::new();
    let mut sent = 0usize;
    for h in handles {
        let (n, mut outs) = h.join().expect("load client panicked")?;
        sent += n;
        outcomes.append(&mut outs);
    }
    let span = t0.elapsed().as_secs_f64().max(1e-9);

    let mut completed = 0usize;
    let mut busy = 0usize;
    let mut errors = sent - outcomes.len(); // sent but never answered
    let mut lat_ms: Vec<f64> = Vec::new();
    for o in &outcomes {
        match &o.reply {
            ServeReply::Tokens { .. } | ServeReply::Score { .. } => {
                completed += 1;
                lat_ms.push(o.latency.as_secs_f64() * 1e3);
            }
            ServeReply::Busy { .. } => busy += 1,
            ServeReply::Error { .. } => errors += 1,
        }
    }
    lat_ms.sort_by(|a, b| a.total_cmp(b));
    let pct = |p: f64| -> f64 {
        if lat_ms.is_empty() {
            return f64::NAN;
        }
        let idx = ((lat_ms.len() as f64 - 1.0) * p).round() as usize;
        lat_ms[idx]
    };
    Ok(LoadReport {
        sent,
        completed,
        busy,
        errors,
        sustained_rps: completed as f64 / span,
        p50_ms: pct(0.50),
        p99_ms: pct(0.99),
        outcomes,
    })
}

/// One client: a paced sender plus a reply-draining reader thread.
#[allow(clippy::type_complexity)]
fn client_main(
    addr: &str,
    spec: &LoadSpec,
    client: usize,
) -> Result<(usize, Vec<LoadOutcome>)> {
    let variant = &spec.variants[client % spec.variants.len()];
    let stream = dial_raw(addr).with_context(|| format!("load client {client} dialing"))?;
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .context("setting read timeout")?;
    let read_half = stream.try_clone().context("cloning load stream")?;
    let (mut tx, mut rx) =
        ServeClient::over(Box::new(BufWriter::new(stream)), Box::new(read_half), variant)
            .split();

    let script = scripted_requests(spec, client);
    let expect = script.len();
    let reader = std::thread::spawn(move || {
        let mut replies: Vec<(ServeReply, Instant)> = Vec::new();
        while replies.len() < expect {
            match rx.recv() {
                Ok(r) => replies.push((r, Instant::now())),
                Err(_) => break, // timeout / disconnect: report what we have
            }
        }
        replies
    });

    let mut sent_at: HashMap<u64, (usize, Instant)> = HashMap::new();
    for (j, (tokens, kind)) in script.iter().enumerate() {
        let id = match kind {
            ReqKind::Generate { max_new } => tx.send_generate(tokens, *max_new)?,
            ReqKind::Score => tx.send_score(tokens)?,
        };
        sent_at.insert(id, (j, Instant::now()));
        std::thread::sleep(spec.gap);
    }

    let replies = reader.join().expect("load reader panicked");
    let mut outcomes = Vec::with_capacity(replies.len());
    for (reply, at) in replies {
        let Some(&(j, t_send)) = sent_at.get(&reply.id()) else {
            continue; // daemon-initiated error frames carry id 0
        };
        let (tokens, kind) = &script[j];
        outcomes.push(LoadOutcome {
            variant: variant.clone(),
            tokens: tokens.clone(),
            kind: *kind,
            reply,
            latency: at.duration_since(t_send),
        });
    }
    Ok((expect, outcomes))
}
