//! The client↔daemon serving protocol, built on the shard plane's wire
//! codec ([`crate::coordinator::wire`]).
//!
//! Every message is a versioned, length-prefixed, checksummed
//! [`Frame`], so the daemon inherits the shard plane's refusal
//! semantics for free: a truncated stream is
//! [`WireError::Truncated`], a flipped bit is
//! [`WireError::BadChecksum`], a stale client binary is
//! [`WireError::BadVersion`] — all surfaced as values the daemon maps
//! to a dropped connection, never a panic. Requests are additionally
//! capped at [`SERVE_MAX_REQUEST_LEN`] via
//! [`read_frame_limited`](crate::coordinator::wire::read_frame_limited),
//! so a client advertising a multi-GiB payload length cannot make the
//! daemon allocate it.

use crate::coordinator::wire::{kind, Frame, WireError, WireReader, WireWriter};

/// Upper bound on one serving request's payload (1 MiB). Prompts are
/// token ids, so this is far beyond any admissible request; anything
/// larger is refused at the framing layer before allocation.
pub const SERVE_MAX_REQUEST_LEN: u64 = 1 << 20;

/// What a client wants done with its prompt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReqKind {
    /// Greedy-decode up to `max_new` tokens after the prompt.
    Generate {
        /// number of tokens to generate (≥ 1)
        max_new: usize,
    },
    /// Score the prompt: next-token NLL summed over positions 1..t.
    Score,
}

/// One client request: a prompt, the model variant to serve it under
/// (the per-request quality/latency tier), and what to do.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServeRequest {
    /// client-chosen request id, echoed in the reply
    pub id: u64,
    /// which served variant evaluates this request
    pub variant: String,
    /// prompt token ids
    pub tokens: Vec<i32>,
    /// generate or score
    pub kind: ReqKind,
}

/// The daemon's reply to one request (matched by `id`).
#[derive(Clone, Debug, PartialEq)]
pub enum ServeReply {
    /// a generate request's decoded continuation
    Tokens {
        /// the request this answers
        id: u64,
        /// greedily decoded token ids (length = requested `max_new`)
        tokens: Vec<i32>,
    },
    /// a score request's summed NLL and scored-token count
    Score {
        /// the request this answers
        id: u64,
        /// Σ next-token negative log-likelihood over the prompt
        nll: f64,
        /// number of scored positions (t − 1)
        count: f64,
    },
    /// admission control shed this request — all scheduler slots busy
    Busy {
        /// the request this answers
        id: u64,
    },
    /// the request was refused (unknown variant, bad prompt, …)
    Error {
        /// the request this answers (0 when no request id was decodable)
        id: u64,
        /// what was wrong
        message: String,
    },
}

impl ServeReply {
    /// The request id this reply answers.
    pub fn id(&self) -> u64 {
        match self {
            ServeReply::Tokens { id, .. }
            | ServeReply::Score { id, .. }
            | ServeReply::Busy { id }
            | ServeReply::Error { id, .. } => *id,
        }
    }
}

/// Encode a request into a [`kind::SERVE_REQUEST`] frame.
pub fn encode_request(r: &ServeRequest) -> Frame {
    let mut w = WireWriter::new();
    w.put_u64(r.id);
    w.put_str(&r.variant);
    w.put_i32s(&r.tokens);
    match r.kind {
        ReqKind::Generate { max_new } => {
            w.put_u8(0);
            w.put_usize(max_new);
        }
        ReqKind::Score => w.put_u8(1),
    }
    Frame { kind: kind::SERVE_REQUEST, payload: w.into_bytes() }
}

/// Decode a [`kind::SERVE_REQUEST`] payload. Structural problems — a
/// bad kind tag, trailing bytes, a short buffer — are
/// [`WireError::Malformed`].
pub fn decode_request(payload: &[u8]) -> Result<ServeRequest, WireError> {
    let mut r = WireReader::new(payload);
    let id = r.get_u64()?;
    let variant = r.get_str()?;
    let tokens = r.get_i32s()?;
    let kind = match r.get_u8()? {
        0 => ReqKind::Generate { max_new: r.get_usize()? },
        1 => ReqKind::Score,
        _ => return Err(WireError::Malformed("bad serve request kind")),
    };
    if !r.is_done() {
        return Err(WireError::Malformed("trailing serve request bytes"));
    }
    Ok(ServeRequest { id, variant, tokens, kind })
}

/// Encode a reply into a [`kind::SERVE_REPLY`] frame.
pub fn encode_reply(reply: &ServeReply) -> Frame {
    let mut w = WireWriter::new();
    match reply {
        ServeReply::Tokens { id, tokens } => {
            w.put_u8(0);
            w.put_u64(*id);
            w.put_i32s(tokens);
        }
        ServeReply::Score { id, nll, count } => {
            w.put_u8(1);
            w.put_u64(*id);
            w.put_f64(*nll);
            w.put_f64(*count);
        }
        ServeReply::Busy { id } => {
            w.put_u8(2);
            w.put_u64(*id);
        }
        ServeReply::Error { id, message } => {
            w.put_u8(3);
            w.put_u64(*id);
            w.put_str(message);
        }
    }
    Frame { kind: kind::SERVE_REPLY, payload: w.into_bytes() }
}

/// Decode a [`kind::SERVE_REPLY`] payload.
pub fn decode_reply(payload: &[u8]) -> Result<ServeReply, WireError> {
    let mut r = WireReader::new(payload);
    let tag = r.get_u8()?;
    let reply = match tag {
        0 => {
            let id = r.get_u64()?;
            ServeReply::Tokens { id, tokens: r.get_i32s()? }
        }
        1 => {
            let id = r.get_u64()?;
            ServeReply::Score { id, nll: r.get_f64()?, count: r.get_f64()? }
        }
        2 => ServeReply::Busy { id: r.get_u64()? },
        3 => {
            let id = r.get_u64()?;
            ServeReply::Error { id, message: r.get_str()? }
        }
        _ => return Err(WireError::Malformed("bad serve reply tag")),
    };
    if !r.is_done() {
        return Err(WireError::Malformed("trailing serve reply bytes"));
    }
    Ok(reply)
}

/// Encode a cancel into a [`kind::SERVE_CANCEL`] frame.
pub fn encode_cancel(id: u64) -> Frame {
    let mut w = WireWriter::new();
    w.put_u64(id);
    Frame { kind: kind::SERVE_CANCEL, payload: w.into_bytes() }
}

/// Decode a [`kind::SERVE_CANCEL`] payload.
pub fn decode_cancel(payload: &[u8]) -> Result<u64, WireError> {
    let mut r = WireReader::new(payload);
    let id = r.get_u64()?;
    if !r.is_done() {
        return Err(WireError::Malformed("trailing serve cancel bytes"));
    }
    Ok(id)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        for kind in [ReqKind::Generate { max_new: 7 }, ReqKind::Score] {
            let req = ServeRequest {
                id: 42,
                variant: "qer-r8".into(),
                tokens: vec![1, 2, 3, 250],
                kind,
            };
            let f = encode_request(&req);
            assert_eq!(decode_request(&f.payload).unwrap(), req);
        }
    }

    #[test]
    fn reply_roundtrip() {
        let replies = [
            ServeReply::Tokens { id: 1, tokens: vec![9, 8, 7] },
            ServeReply::Score { id: 2, nll: 13.25, count: 7.0 },
            ServeReply::Busy { id: 3 },
            ServeReply::Error { id: 4, message: "unknown variant".into() },
        ];
        for r in &replies {
            let f = encode_reply(r);
            assert_eq!(&decode_reply(&f.payload).unwrap(), r);
        }
    }

    #[test]
    fn cancel_roundtrip() {
        let f = encode_cancel(77);
        assert_eq!(decode_cancel(&f.payload).unwrap(), 77);
    }

    /// Negative decode paths: every malformed payload is a
    /// `Malformed`-class error, never a panic.
    #[test]
    fn malformed_payloads_are_refused() {
        // short buffers at several cut points
        let good = encode_request(&ServeRequest {
            id: 1,
            variant: "v".into(),
            tokens: vec![1, 2],
            kind: ReqKind::Score,
        })
        .payload;
        for cut in 0..good.len() {
            assert!(
                matches!(decode_request(&good[..cut]), Err(WireError::Malformed(_))),
                "cut at {cut} must be refused"
            );
        }
        // bad request kind tag
        let mut bad = good.clone();
        *bad.last_mut().unwrap() = 9;
        assert!(matches!(decode_request(&bad), Err(WireError::Malformed(_))));
        // trailing bytes
        let mut long = good.clone();
        long.push(0);
        assert!(matches!(decode_request(&long), Err(WireError::Malformed(_))));
        // bad reply tag
        assert!(matches!(decode_reply(&[9u8; 9]), Err(WireError::Malformed(_))));
        // short cancel
        assert!(matches!(decode_cancel(&[1, 2, 3]), Err(WireError::Malformed(_))));
    }
}
