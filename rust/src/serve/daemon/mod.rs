//! `srr serve` — a continuous-batching inference daemon over the
//! factored serving layer, proven correct by deterministic replay.
//!
//! The daemon coalesces concurrent generate/score requests from many
//! clients into lock-step batches over [`FleetEngine`]'s variant
//! table: several rank/bit variants of one sweep served behind one
//! endpoint, sharing a single packed base per linear (the
//! `Arc<PackedMat>` sharing that [`LinearOp::matmul_grouped`] turns
//! into one base decode per batch). Admission control and
//! backpressure run through the shard plane's `BoundedQueue`; the
//! client protocol reuses its versioned, checksummed wire frames and
//! the HELLO handshake, so TCP clients and in-process test clients
//! (including fault-injected ones) share one code path.
//!
//! Module map, in dependency order:
//!
//! * [`clock`] — the virtual tick clock; no wall time in decisions.
//! * [`protocol`] — request/reply/cancel frames over
//!   [`crate::coordinator::wire`].
//! * [`scheduler`] — deterministic continuous-batching slot pool.
//! * [`engine`] — the lock-step mixed-variant forward + serial oracle.
//! * [`server`] — the IO shell: accept loop, reader threads, event
//!   loop, replies.
//! * [`client`] — dial / attach, send, receive.
//! * [`loadgen`] — seeded open-loop load with latency percentiles.
//!
//! The correctness story is the tentpole: every batched request is
//! bit-identical to running it alone ([`FleetEngine`]'s grouped-path
//! contract), checked end to end by the property harness and the
//! `serve_live` bench's oracle replay — not assumed.
//!
//! [`LinearOp::matmul_grouped`]: crate::serve::LinearOp::matmul_grouped

pub mod clock;
pub mod client;
pub mod engine;
pub mod loadgen;
pub mod protocol;
pub mod scheduler;
pub mod server;

pub use client::ServeClient;
pub use engine::{FleetEngine, StepOut};
pub use loadgen::{run_open_loop, LoadReport, LoadSpec};
pub use protocol::{ReqKind, ServeReply, ServeRequest};
pub use scheduler::{Admit, SchedConfig, Scheduler, SlotRequest};
pub use server::{Daemon, DaemonConfig, DaemonHandle, DaemonStats};
