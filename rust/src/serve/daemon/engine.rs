//! The batched inference engine behind the daemon: a fixed table of
//! served model variants plus one lock-step `step` that advances a
//! mixed batch of requests by one forward pass.
//!
//! **Bit-identity contract.** Every forward — whether the batch holds
//! one request or eight, and regardless of which variants its members
//! run under — goes through the same code path: a [`MixedFleet`] view
//! dispatching each linear through [`LinearOp::matmul_grouped`] into
//! [`forward_fleet_distinct`]. The grouped matmul preserves per-row
//! summation order no matter how many members share the stack, and the
//! trunk is row/sequence-local, so a request's logits are bit-identical
//! whoever it was batched with. [`FleetEngine::run_to_completion`] is
//! the serial oracle the test harness compares against: it runs the
//! *same* path with a group of one, so "batched output == serial
//! output" is checked end to end, not proved by assumption.
//!
//! One hazard keeps the contract honest: the batch-1 fused matvec
//! kernels reorder summation. The engine never reaches them because
//! the grouped path is unconditional and the daemon's admission floor
//! (`min_prompt ≥ 2`) keeps every stacked member at `t ≥ 2` rows.

use crate::model::forward::{forward_fleet_distinct, row_nll, FleetWeights};
use crate::runtime::manifest::ModelCfg;
use crate::serve::{FactoredModel, LinearOp, ServeError};
use crate::tensor::{matmul, Mat};

use super::protocol::ReqKind;
use super::scheduler::SlotRequest;

/// What one finished request produced.
#[derive(Clone, Debug, PartialEq)]
pub enum StepOut {
    /// a generate request's full decoded continuation
    Tokens(Vec<i32>),
    /// a score request's summed NLL and scored-position count
    Score {
        /// Σ next-token negative log-likelihood over the prompt
        nll: f64,
        /// number of scored positions (t − 1)
        count: f64,
    },
}

/// A fixed set of named model variants served off shared state. The
/// interesting deployment shape is several rank/bit variants of one
/// sweep carrying the *same* `Arc<PackedMat>` bases — the engine
/// doesn't require that, but [`LinearOp::matmul_grouped`] exploits it
/// (one base decode per group) whenever it holds.
pub struct FleetEngine {
    cfg: ModelCfg,
    variants: Vec<(String, FactoredModel)>,
}

/// A per-batch [`FleetWeights`] view: member `g` of the stack is
/// evaluated under `members[g]`'s weights. Members may repeat (two
/// requests on the same variant) and mix freely.
struct MixedFleet<'a> {
    members: Vec<&'a FactoredModel>,
}

impl FleetWeights for MixedFleet<'_> {
    fn group_size(&self) -> usize {
        self.members.len()
    }

    fn linear_stacked(&self, name: &str, x: &Mat) -> Result<Mat, ServeError> {
        if self.members[0].op(name).is_some() {
            // engine construction validated op alignment, but a
            // misaligned member still fails the step, not the daemon
            let ops: Vec<&LinearOp> = self
                .members
                .iter()
                .map(|m| m.op(name).ok_or_else(|| ServeError::UnknownTensor(name.to_string())))
                .collect::<Result<_, _>>()?;
            LinearOp::matmul_grouped(&ops, x)
        } else {
            let w = self.members[0]
                .skeleton
                .get_mat(name)
                .ok_or_else(|| ServeError::UnknownTensor(name.to_string()))?;
            Ok(matmul(x, &w))
        }
    }

    fn vec(&self, name: &str) -> &[f32] {
        self.members[0].skeleton.get_vec(name).expect("vec param")
    }

    fn mat(&self, name: &str) -> Mat {
        self.members[0].skeleton.get_mat(name).expect("mat param")
    }
}

impl FleetEngine {
    /// Build an engine over named variants, validating that every
    /// variant quantizes the same set of linears (so any mix of them
    /// can share one stacked forward).
    pub fn new(
        cfg: ModelCfg,
        variants: Vec<(String, FactoredModel)>,
    ) -> Result<Self, ServeError> {
        if variants.is_empty() {
            return Err(ServeError::EmptyGroup);
        }
        let first = &variants[0].1;
        for (_, m) in &variants[1..] {
            let aligned = m.ops.len() == first.ops.len()
                && m.ops.iter().zip(&first.ops).all(|((a, _), (b, _))| a == b);
            if !aligned {
                return Err(ServeError::ShapeMismatch {
                    what: "served variants quantize different linear sets",
                });
            }
        }
        Ok(FleetEngine { cfg, variants })
    }

    /// The model configuration every variant serves.
    pub fn cfg(&self) -> &ModelCfg {
        &self.cfg
    }

    /// The served variant names, in table order.
    pub fn variant_names(&self) -> Vec<&str> {
        self.variants.iter().map(|(n, _)| n.as_str()).collect()
    }

    /// Resolve a variant name to its table index.
    pub fn variant_index(&self, name: &str) -> Option<usize> {
        self.variants.iter().position(|(n, _)| n == name)
    }

    /// Advance every batch member by one lock-step forward. Members
    /// must share one current length `t ≥ 2` (the scheduler's batching
    /// rule guarantees this). Returns, per member, `Some(StepOut)` when
    /// the request finished this step and `None` when it still needs
    /// more decode steps (generate only; score always finishes).
    pub fn step(
        &self,
        batch: &mut [SlotRequest],
    ) -> Result<Vec<Option<StepOut>>, ServeError> {
        let g = batch.len();
        if g == 0 {
            return Err(ServeError::EmptyBatch);
        }
        let t = batch[0].cur_len();
        if batch.iter().any(|r| r.cur_len() != t) {
            return Err(ServeError::RaggedStack { rows: 0, group: g });
        }
        if t < 2 {
            return Err(ServeError::ShapeMismatch {
                what: "batch member shorter than 2 tokens",
            });
        }
        let mut members = Vec::with_capacity(g);
        let mut stacked = Vec::with_capacity(g * t);
        for r in batch.iter() {
            let (_, model) = self
                .variants
                .get(r.variant)
                .ok_or_else(|| ServeError::UnknownTensor(format!("variant #{}", r.variant)))?;
            members.push(model);
            stacked.extend_from_slice(&r.tokens);
            stacked.extend_from_slice(&r.produced);
        }
        let fleet = MixedFleet { members };
        let logits = forward_fleet_distinct(&fleet, &self.cfg, &stacked, 1, t, true)?;

        let mut out = Vec::with_capacity(g);
        for (gi, r) in batch.iter_mut().enumerate() {
            match r.kind {
                ReqKind::Generate { max_new } => {
                    let next = argmax(logits.row(gi * t + t - 1));
                    r.produced.push(next);
                    out.push(if r.produced.len() >= max_new {
                        Some(StepOut::Tokens(r.produced.clone()))
                    } else {
                        None
                    });
                }
                ReqKind::Score => {
                    let mut nll = 0.0;
                    for pos in 0..t - 1 {
                        nll += row_nll(logits.row(gi * t + pos), r.tokens[pos + 1] as usize, 1.0);
                    }
                    out.push(Some(StepOut::Score { nll, count: (t - 1) as f64 }));
                }
            }
        }
        Ok(out)
    }

    /// The serial oracle: run one request to completion alone, through
    /// the *same* grouped code path with a group of one. The
    /// equivalence harness compares every batched output against this.
    pub fn run_to_completion(
        &self,
        variant: usize,
        tokens: &[i32],
        kind: ReqKind,
    ) -> Result<StepOut, ServeError> {
        let mut batch = vec![SlotRequest {
            conn: 0,
            id: 0,
            variant,
            tokens: tokens.to_vec(),
            produced: Vec::new(),
            kind,
            seq: 0,
            admitted: 0,
        }];
        loop {
            let mut done = self.step(&mut batch)?;
            if let Some(out) = done.pop().expect("singleton result") {
                return Ok(out);
            }
        }
    }
}

/// Greedy decode: index of the strictly greatest logit; ties resolve to
/// the lowest index, so decoding is deterministic.
fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best as i32
}

/// Test-only fixtures shared by the daemon's unit, property, and
/// integration-style tests: a tiny model config plus shared-base rank
/// variants in the serving deployment shape.
#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::coordinator::QuantizerSpec;
    use crate::model::synth::synth_lm_params;
    use crate::model::Params;
    use crate::quant::{QuantCtx, Quantizer};
    use crate::serve::QuantBase;
    use crate::util::Rng;
    use std::sync::Arc;

    /// A 1-layer model small enough to forward in microseconds.
    pub(crate) fn tiny_cfg() -> ModelCfg {
        ModelCfg {
            name: "tiny-test".into(),
            vocab: 48,
            d_model: 32,
            n_heads: 2,
            n_layers: 1,
            d_ff: 64,
            seq_len: 16,
        }
    }

    /// Rank variants sharing one packed base per linear — the serving
    /// deployment shape, shrunk to test size.
    pub(crate) fn shared_base_variants(
        cfg: &ModelCfg,
        ranks: &[usize],
        seed: u64,
    ) -> Vec<(String, FactoredModel)> {
        let mut rng = Rng::new(seed);
        let params = synth_lm_params(cfg, seed, cfg.vocab);
        let spec = QuantizerSpec::Mxint { bits: 4, block: 32 };
        let names = Params::linear_names(cfg);
        let bases: Vec<(String, QuantBase)> = names
            .iter()
            .map(|n| {
                let w = params.get_mat(n).expect("linear");
                let ctx = QuantCtx { hessian: None, seed };
                let (_, packed) = spec.build().quantize_coded(&w, &ctx);
                (n.clone(), QuantBase::Packed(Arc::new(packed.expect("packable"))))
            })
            .collect();
        ranks
            .iter()
            .map(|&rank| {
                let mut skeleton = params.clone();
                let ops: Vec<(String, LinearOp)> = bases
                    .iter()
                    .map(|(n, base)| {
                        skeleton.unset(n);
                        let (m, k) = (base.rows(), base.cols());
                        let op = LinearOp::FactoredQlr {
                            base: base.clone(),
                            l: Mat::randn(m, rank, 0.05, &mut rng),
                            r: Mat::randn(rank, k, 0.05, &mut rng),
                        };
                        (n.clone(), op)
                    })
                    .collect();
                (format!("r{rank}"), FactoredModel { skeleton, ops })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::{shared_base_variants, tiny_cfg};
    use super::*;
    use crate::util::Rng;

    fn slot(variant: usize, tokens: Vec<i32>, kind: ReqKind) -> SlotRequest {
        SlotRequest {
            conn: 0,
            id: 0,
            variant,
            tokens,
            produced: Vec::new(),
            kind,
            seq: 0,
            admitted: 0,
        }
    }

    /// Mixed-variant batches produce bit-identical outputs to the
    /// serial oracle, for both generate and score.
    #[test]
    fn batched_equals_serial_bitwise() {
        let cfg = tiny_cfg();
        let engine = FleetEngine::new(cfg.clone(), shared_base_variants(&cfg, &[2, 4], 11))
            .expect("aligned variants");
        let mut rng = Rng::new(7);
        let prompts: Vec<Vec<i32>> = (0..4)
            .map(|_| (0..5).map(|_| rng.below(cfg.vocab) as i32).collect())
            .collect();
        let kinds = [
            ReqKind::Generate { max_new: 3 },
            ReqKind::Score,
            ReqKind::Generate { max_new: 3 },
            ReqKind::Score,
        ];
        // batched run: drive all four to completion in lock-step
        let mut batch: Vec<SlotRequest> = prompts
            .iter()
            .zip(&kinds)
            .enumerate()
            .map(|(i, (p, &k))| slot(i % 2, p.clone(), k))
            .collect();
        let mut batched: Vec<Option<StepOut>> = vec![None; batch.len()];
        while batch.iter().zip(&batched).any(|(_, d)| d.is_none()) {
            let live_idx: Vec<usize> =
                (0..batch.len()).filter(|&i| batched[i].is_none()).collect();
            let mut live: Vec<SlotRequest> =
                live_idx.iter().map(|&i| batch[i].clone()).collect();
            let done = engine.step(&mut live).expect("step");
            for ((&i, r), d) in live_idx.iter().zip(live).zip(done) {
                batch[i] = r;
                if d.is_some() {
                    batched[i] = d;
                }
            }
        }
        // serial oracle, one request at a time
        for (i, (p, &k)) in prompts.iter().zip(&kinds).enumerate() {
            let serial = engine.run_to_completion(i % 2, p, k).expect("serial");
            let got = batched[i].clone().expect("finished");
            match (&serial, &got) {
                (StepOut::Tokens(a), StepOut::Tokens(b)) => assert_eq!(a, b),
                (
                    StepOut::Score { nll: a, count: ca },
                    StepOut::Score { nll: b, count: cb },
                ) => {
                    assert_eq!(a.to_bits(), b.to_bits(), "score must be bit-identical");
                    assert_eq!(ca, cb);
                }
                _ => panic!("kind mismatch"),
            }
        }
    }

    #[test]
    fn engine_refuses_malformed_batches() {
        let cfg = tiny_cfg();
        let engine = FleetEngine::new(cfg.clone(), shared_base_variants(&cfg, &[2], 11))
            .expect("aligned variants");
        // empty batch
        assert!(matches!(engine.step(&mut []), Err(ServeError::EmptyBatch)));
        // ragged lengths
        let mut ragged = vec![
            slot(0, vec![1, 2, 3], ReqKind::Score),
            slot(0, vec![1, 2], ReqKind::Score),
        ];
        assert!(matches!(engine.step(&mut ragged), Err(ServeError::RaggedStack { .. })));
        // sub-minimum length (would fall into fused batch-1 kernels)
        let mut short = vec![slot(0, vec![1], ReqKind::Score)];
        assert!(matches!(engine.step(&mut short), Err(ServeError::ShapeMismatch { .. })));
        // unknown variant index
        let mut bad = vec![slot(9, vec![1, 2, 3], ReqKind::Score)];
        assert!(matches!(engine.step(&mut bad), Err(ServeError::UnknownTensor(_))));
        // empty variant table
        assert!(matches!(
            FleetEngine::new(cfg, Vec::new()),
            Err(ServeError::EmptyGroup)
        ));
    }
}
