//! The daemon's IO shell: connection admission, per-connection reader
//! threads, the reply path, and the scheduling event loop.
//!
//! The layering is strict. Everything nondeterministic — sockets,
//! threads, arrival timing — lives here and is reduced to an ordered
//! stream of [`Event`]s; everything decision-shaped (which requests
//! batch together, what each produces) lives in the deterministic
//! [`Scheduler`] + [`FleetEngine`] pair driven off a [`VirtualClock`].
//! The property harness replays scripted event streams through that
//! pair directly, so the logic this loop executes is the logic the
//! seeds exercise.
//!
//! Connections arrive two ways sharing one serving path:
//!
//! * **TCP** — [`Daemon::bind`] + the shard plane's
//!   [`ShardHost::accept_loop`]: every dial-in must pass the versioned
//!   HELLO handshake (daemon = host role), so a stale or hostile peer
//!   is refused before it can touch the request protocol.
//! * **In-process** — [`DaemonHandle::admit`] attaches any open
//!   [`Transport`] (e.g. a [`FaultTransport`] in the churn tests)
//!   directly, skipping only the TCP handshake.
//!
//! Misbehavior never stops service: a malformed frame ends *that*
//! connection (with a best-effort error reply), a disconnect or cancel
//! frees the scheduler slots it owned, and admission beyond
//! `max_slots` is shed with an explicit [`ServeReply::Busy`].
//!
//! [`FaultTransport`]: crate::coordinator::FaultTransport

use std::collections::HashMap;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::jobs::{BoundedQueue, PopResult};
use crate::coordinator::wire::{kind, read_frame_limited};
use crate::coordinator::{ShardHost, Transport};

use super::clock::VirtualClock;
use super::engine::{FleetEngine, StepOut};
use super::protocol::{
    decode_cancel, decode_request, encode_reply, ReqKind, ServeReply, ServeRequest,
    SERVE_MAX_REQUEST_LEN,
};
use super::scheduler::{Admit, SchedConfig, Scheduler, SlotRequest};

/// Daemon limits and pacing.
#[derive(Clone, Copy, Debug)]
pub struct DaemonConfig {
    /// max in-flight requests before admission sheds (backpressure)
    pub max_slots: usize,
    /// max requests per lock-step forward
    pub max_batch: usize,
    /// minimum prompt length (must stay ≥ 2: shorter members would
    /// reach the fused batch-1 kernels and break bit-identity)
    pub min_prompt: usize,
    /// max total sequence length (prompt + generated); 0 = the served
    /// model's `seq_len`
    pub max_seq: usize,
    /// max `max_new` a generate request may ask for
    pub max_new_cap: usize,
    /// how long the idle event loop blocks waiting for an event
    pub idle_wait: Duration,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        DaemonConfig {
            max_slots: 16,
            max_batch: 8,
            min_prompt: 2,
            max_seq: 0,
            max_new_cap: 64,
            idle_wait: Duration::from_millis(2),
        }
    }
}

/// Live daemon counters, shared with the handle for observability and
/// the churn tests' leak assertions.
#[derive(Debug, Default)]
pub struct DaemonStats {
    /// requests currently holding scheduler slots
    pub active_slots: AtomicUsize,
    /// replies delivered (tokens / score)
    pub served: AtomicU64,
    /// requests shed with a busy reply
    pub shed: AtomicU64,
    /// requests refused with an error reply (validation failures)
    pub refused: AtomicU64,
    /// connections dropped for protocol violations
    pub malformed: AtomicU64,
    /// connections that ended (EOF, error, or kill)
    pub disconnects: AtomicU64,
}

/// One nondeterministic input, ordered by arrival into the event
/// queue. The reader threads produce these; only the event loop
/// consumes them.
enum Event {
    /// a decoded request frame from connection `conn`
    Request { conn: u64, req: ServeRequest },
    /// a cancel frame for request `id` on connection `conn`
    Cancel { conn: u64, id: u64 },
    /// connection `conn` is finished; `Some` carries a protocol-
    /// violation description (clean EOF is `None`)
    Gone { conn: u64, violation: Option<String> },
}

/// The continuous-batching serving daemon: admission control, the
/// scheduler event loop, and reply delivery over any [`Transport`].
pub struct Daemon {
    engine: FleetEngine,
    cfg: DaemonConfig,
    host: Option<ShardHost>,
    conns_q: Arc<BoundedQueue<Box<dyn Transport>>>,
    stats: Arc<DaemonStats>,
    stop: Arc<AtomicBool>,
}

/// Control handle to a spawned [`Daemon`]: admit in-process
/// connections, read stats, stop, join.
pub struct DaemonHandle {
    stop: Arc<AtomicBool>,
    stats: Arc<DaemonStats>,
    conns_q: Arc<BoundedQueue<Box<dyn Transport>>>,
    thread: JoinHandle<()>,
}

impl DaemonHandle {
    /// Attach an already-open connection (in-process test client or
    /// fault-injected loopback). Returns `false` once the daemon is
    /// stopping.
    pub fn admit(&self, t: Box<dyn Transport>) -> bool {
        self.conns_q.push(t)
    }

    /// Live counters.
    pub fn stats(&self) -> &DaemonStats {
        &self.stats
    }

    /// Ask the daemon to stop after the current scheduling round.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::Release);
        self.conns_q.close();
    }

    /// Stop and wait for the event loop to exit.
    pub fn join(self) {
        self.stop();
        let _ = self.thread.join();
    }
}

impl Daemon {
    /// A daemon serving `engine`'s variants under `cfg`'s limits. Not
    /// yet listening: call [`Daemon::bind`] for TCP, then
    /// [`Daemon::spawn`].
    pub fn new(engine: FleetEngine, cfg: DaemonConfig) -> Daemon {
        assert!(cfg.min_prompt >= 2, "min_prompt < 2 breaks bit-identity");
        assert!(cfg.max_slots >= 1 && cfg.max_batch >= 1);
        Daemon {
            engine,
            cfg,
            host: None,
            conns_q: Arc::new(BoundedQueue::new(64)),
            stats: Arc::new(DaemonStats::default()),
            stop: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Listen on `addr` (e.g. `127.0.0.1:0`); returns the bound
    /// address clients dial. TCP clients must pass the HELLO
    /// handshake ([`crate::coordinator::transport::worker_connect`]).
    pub fn bind(&mut self, addr: &str) -> Result<SocketAddr> {
        let host = ShardHost::bind(addr)?;
        let bound = host.local_addr()?;
        self.host = Some(host);
        Ok(bound)
    }

    /// Start the event loop (and the TCP accept loop when bound) on
    /// background threads.
    pub fn spawn(self) -> DaemonHandle {
        let stop = self.stop.clone();
        let stats = self.stats.clone();
        let conns_q = self.conns_q.clone();
        let thread = std::thread::spawn(move || self.run_loop());
        DaemonHandle { stop, stats, conns_q, thread }
    }

    /// The event loop. Single-threaded over scheduler + engine +
    /// reply writing; reader threads and the accept loop only feed
    /// the queues.
    fn run_loop(mut self) {
        let events: Arc<BoundedQueue<Event>> =
            Arc::new(BoundedQueue::new((self.cfg.max_slots * 4).max(64)));
        // TCP accept loop on its own thread (owns the listener)
        let mut accept_thread = None;
        if let Some(host) = self.host.take() {
            let stop = self.stop.clone();
            let conns_q = self.conns_q.clone();
            accept_thread = Some(std::thread::spawn(move || {
                host.accept_loop(&stop, |t| {
                    let _ = conns_q.push(Box::new(t));
                });
            }));
        }

        let max_seq = if self.cfg.max_seq == 0 {
            self.engine.cfg().seq_len
        } else {
            self.cfg.max_seq
        };
        let mut clock = VirtualClock::new();
        let mut sched = Scheduler::new(SchedConfig {
            max_slots: self.cfg.max_slots,
            max_batch: self.cfg.max_batch,
        });
        let mut conns: HashMap<u64, Box<dyn Transport>> = HashMap::new();
        let mut next_conn: u64 = 0;

        while !self.stop.load(Ordering::Acquire) {
            // attach newly admitted connections
            while let PopResult::Item(mut t) = self.conns_q.try_pop() {
                let conn = next_conn;
                next_conn += 1;
                if let Some(reader) = t.take_reader() {
                    let ev = events.clone();
                    std::thread::spawn(move || reader_main(conn, reader, &ev));
                    conns.insert(conn, t);
                } else {
                    self.stats.disconnects.fetch_add(1, Ordering::Relaxed);
                }
            }

            // drain pending events without blocking
            let mut handled = 0usize;
            while let PopResult::Item(ev) = events.try_pop() {
                self.handle_event(ev, clock.now(), max_seq, &mut sched, &mut conns);
                handled += 1;
                if handled >= 256 {
                    break; // bounded per round so scheduling stays live
                }
            }

            self.stats.active_slots.store(sched.active(), Ordering::Relaxed);
            if sched.active() == 0 {
                // idle: block briefly for the next event
                match events.pop_timeout(self.cfg.idle_wait) {
                    PopResult::Item(ev) => {
                        self.handle_event(ev, clock.now(), max_seq, &mut sched, &mut conns)
                    }
                    PopResult::Empty | PopResult::Closed => {}
                }
                continue;
            }

            // one scheduling round
            clock.advance();
            let mut batch = sched.take_batch();
            match self.engine.step(&mut batch) {
                Ok(done) => {
                    for (req, out) in batch.into_iter().zip(done) {
                        match out {
                            Some(StepOut::Tokens(tokens)) => {
                                self.reply(
                                    &mut conns,
                                    &mut sched,
                                    req.conn,
                                    &ServeReply::Tokens { id: req.id, tokens },
                                );
                                self.stats.served.fetch_add(1, Ordering::Relaxed);
                            }
                            Some(StepOut::Score { nll, count }) => {
                                self.reply(
                                    &mut conns,
                                    &mut sched,
                                    req.conn,
                                    &ServeReply::Score { id: req.id, nll, count },
                                );
                                self.stats.served.fetch_add(1, Ordering::Relaxed);
                            }
                            None => sched.restore(req),
                        }
                    }
                }
                Err(e) => {
                    // admission validated everything the engine checks,
                    // so this is unreachable in practice; refuse the
                    // batch rather than crash the daemon if it ever
                    // happens
                    for req in batch {
                        self.reply(
                            &mut conns,
                            &mut sched,
                            req.conn,
                            &ServeReply::Error { id: req.id, message: e.to_string() },
                        );
                        self.stats.refused.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            self.stats.active_slots.store(sched.active(), Ordering::Relaxed);
        }

        // teardown: sever every connection so reader threads unblock
        for (_, mut t) in conns.drain() {
            t.kill();
        }
        self.conns_q.close();
        while let PopResult::Item(mut t) = self.conns_q.try_pop() {
            t.kill();
        }
        events.close();
        if let Some(h) = accept_thread {
            let _ = h.join();
        }
    }

    /// Apply one event to the scheduler state.
    fn handle_event(
        &self,
        ev: Event,
        now: super::clock::Tick,
        max_seq: usize,
        sched: &mut Scheduler,
        conns: &mut HashMap<u64, Box<dyn Transport>>,
    ) {
        match ev {
            Event::Request { conn, req } => {
                let id = req.id;
                match self.validate(&req, max_seq) {
                    Ok(slot0) => {
                        let slot = SlotRequest { conn, ..slot0 };
                        match sched.admit(slot, now) {
                            Admit::Accepted => {}
                            Admit::Busy => {
                                self.stats.shed.fetch_add(1, Ordering::Relaxed);
                                self.reply(conns, sched, conn, &ServeReply::Busy { id });
                            }
                        }
                    }
                    Err(message) => {
                        self.stats.refused.fetch_add(1, Ordering::Relaxed);
                        self.reply(conns, sched, conn, &ServeReply::Error { id, message });
                    }
                }
            }
            Event::Cancel { conn, id } => {
                sched.cancel(conn, id);
            }
            Event::Gone { conn, violation } => {
                if let Some(message) = violation {
                    self.stats.malformed.fetch_add(1, Ordering::Relaxed);
                    // best-effort: tell the peer why before severing
                    self.reply(conns, sched, conn, &ServeReply::Error { id: 0, message });
                }
                if let Some(mut t) = conns.remove(&conn) {
                    t.kill();
                    self.stats.disconnects.fetch_add(1, Ordering::Relaxed);
                }
                sched.drop_conn(conn);
            }
        }
    }

    /// Admission validation: everything that must hold for the engine
    /// to evaluate the request without panicking, checked while the
    /// request is still refusable.
    fn validate(&self, req: &ServeRequest, max_seq: usize) -> Result<SlotRequest, String> {
        let variant = self
            .engine
            .variant_index(&req.variant)
            .ok_or_else(|| format!("unknown variant {:?}", req.variant))?;
        if req.tokens.len() < self.cfg.min_prompt {
            return Err(format!(
                "prompt too short: {} < {}",
                req.tokens.len(),
                self.cfg.min_prompt
            ));
        }
        let vocab = self.engine.cfg().vocab;
        if let Some(&bad) = req.tokens.iter().find(|&&t| t < 0 || t as usize >= vocab) {
            return Err(format!("token id {bad} outside vocab {vocab}"));
        }
        let new = match req.kind {
            ReqKind::Generate { max_new } => {
                if max_new < 1 || max_new > self.cfg.max_new_cap {
                    return Err(format!(
                        "max_new {max_new} outside [1, {}]",
                        self.cfg.max_new_cap
                    ));
                }
                max_new
            }
            ReqKind::Score => 0,
        };
        if req.tokens.len() + new > max_seq {
            return Err(format!(
                "request length {} + {new} exceeds max seq {max_seq}",
                req.tokens.len()
            ));
        }
        Ok(SlotRequest {
            conn: 0,
            id: req.id,
            variant,
            tokens: req.tokens.clone(),
            produced: Vec::new(),
            kind: req.kind,
            seq: 0,
            admitted: 0,
        })
    }

    /// Write one reply frame to a connection; a failed write means the
    /// peer is gone, so its slots are freed and the transport killed.
    fn reply(
        &self,
        conns: &mut HashMap<u64, Box<dyn Transport>>,
        sched: &mut Scheduler,
        conn: u64,
        reply: &ServeReply,
    ) {
        let ok = match conns.get_mut(&conn).and_then(|t| t.writer()) {
            Some(w) => encode_reply(reply).write_to(w).and_then(|_| w.flush()).is_ok(),
            None => false,
        };
        if !ok {
            if let Some(mut t) = conns.remove(&conn) {
                t.kill();
                self.stats.disconnects.fetch_add(1, Ordering::Relaxed);
            }
            sched.drop_conn(conn);
        }
    }
}

/// Per-connection reader: turn the byte stream into events until EOF,
/// error, or protocol violation. Never panics on peer bytes — every
/// decode failure is a value that ends only this connection.
fn reader_main(
    conn: u64,
    mut reader: Box<dyn std::io::Read + Send>,
    events: &BoundedQueue<Event>,
) {
    loop {
        match read_frame_limited(&mut reader, SERVE_MAX_REQUEST_LEN) {
            Ok(Some(frame)) => {
                let ev = match frame.kind {
                    kind::SERVE_REQUEST => match decode_request(&frame.payload) {
                        Ok(req) => Event::Request { conn, req },
                        Err(e) => Event::Gone { conn, violation: Some(e.to_string()) },
                    },
                    kind::SERVE_CANCEL => match decode_cancel(&frame.payload) {
                        Ok(id) => Event::Cancel { conn, id },
                        Err(e) => Event::Gone { conn, violation: Some(e.to_string()) },
                    },
                    k => Event::Gone {
                        conn,
                        violation: Some(format!("unexpected frame kind {k}")),
                    },
                };
                let fatal = matches!(ev, Event::Gone { .. });
                if !events.push(ev) || fatal {
                    return;
                }
            }
            Ok(None) => {
                let _ = events.push(Event::Gone { conn, violation: None });
                return;
            }
            Err(e) => {
                let _ = events.push(Event::Gone { conn, violation: Some(e.to_string()) });
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::testutil::{shared_base_variants, tiny_cfg};
    use super::*;
    use crate::util::prop;

    /// One scripted event in a replayable schedule. The whole script is
    /// generated up front from the case seed, so a reported seed replays
    /// the exact interleaving of arrivals, cancels, disconnects, and
    /// scheduling rounds.
    enum Action {
        /// a validated request arrives on `conn`
        Arrive { conn: u64, id: u64, variant: usize, tokens: Vec<i32>, kind: ReqKind },
        /// the client cancels a previously issued request
        Cancel { conn: u64, id: u64 },
        /// `conn` disconnects, freeing every slot it owns
        Disconnect { conn: u64 },
        /// the event loop runs one scheduling round
        Round,
    }

    fn scripted_schedule(g: &mut prop::Gen, vocab: usize, n_variants: usize) -> Vec<Action> {
        let mut script = Vec::new();
        let mut issued: Vec<(u64, u64)> = Vec::new();
        let mut next_id = 1u64;
        let n = 8 + g.rng.below(8);
        for _ in 0..n {
            match g.rng.below(10) {
                0..=5 => {
                    let conn = g.rng.below(3) as u64;
                    let len = 2 + g.rng.below(4);
                    let tokens = (0..len).map(|_| g.rng.below(vocab) as i32).collect();
                    let kind = if g.rng.below(3) == 0 {
                        ReqKind::Score
                    } else {
                        ReqKind::Generate { max_new: 1 + g.rng.below(3) }
                    };
                    let id = next_id;
                    next_id += 1;
                    issued.push((conn, id));
                    script.push(Action::Arrive {
                        conn,
                        id,
                        variant: g.rng.below(n_variants),
                        tokens,
                        kind,
                    });
                }
                6 if !issued.is_empty() => {
                    let (conn, id) = issued[g.rng.below(issued.len())];
                    script.push(Action::Cancel { conn, id });
                }
                7 => script.push(Action::Disconnect { conn: g.rng.below(3) as u64 }),
                _ => script.push(Action::Round),
            }
        }
        script
    }

    /// One scheduling round, exactly as the event loop runs it:
    /// advance the virtual clock, take a lock-step batch, step the
    /// engine, restore the unfinished. Completions are checked off
    /// against the reference in-flight set.
    fn run_round(
        engine: &FleetEngine,
        max_batch: usize,
        sched: &mut Scheduler,
        clock: &mut VirtualClock,
        inflight: &mut HashMap<(u64, u64), (usize, Vec<i32>, ReqKind)>,
        completed: &mut Vec<(usize, Vec<i32>, ReqKind, StepOut)>,
    ) {
        clock.advance();
        let mut batch = sched.take_batch();
        assert!(batch.len() <= max_batch, "batch exceeds max_batch");
        if batch.is_empty() {
            return;
        }
        let t0 = batch[0].cur_len();
        assert!(batch.iter().all(|r| r.cur_len() == t0), "ragged batch");
        let outs = engine.step(&mut batch).expect("scheduler emits engine-valid batches");
        for (req, out) in batch.into_iter().zip(outs) {
            match out {
                Some(o) => {
                    let (variant, tokens, kind) = inflight
                        .remove(&(req.conn, req.id))
                        .expect("completed request was in flight");
                    completed.push((variant, tokens, kind, o));
                }
                None => sched.restore(req),
            }
        }
    }

    /// Satellite property: any seeded schedule of arrivals, cancels,
    /// and disconnects — mixed variants sharing one packed base, batch
    /// sizes {1, 2, 8} — produces, for every request that survives to
    /// completion, output **bit-identical** to serial one-at-a-time
    /// execution; and admission / cancel / disconnect bookkeeping
    /// matches a reference in-flight set (no slot leaks, no completions
    /// for freed requests). A failure prints its replay seed (see
    /// [`crate::util::prop`]).
    #[test]
    fn scheduled_outputs_match_serial_oracle() {
        let cfg = tiny_cfg();
        let engine = FleetEngine::new(cfg.clone(), shared_base_variants(&cfg, &[2, 4], 23))
            .expect("aligned variants");
        prop::check(0x5E12_BA7C, 12, |g| {
            let max_batch = g.choice(&[1usize, 2, 8]);
            let max_slots = g.choice(&[2usize, 4, 8]);
            let script = scripted_schedule(g, cfg.vocab, 2);
            let mut sched = Scheduler::new(SchedConfig { max_slots, max_batch });
            let mut clock = VirtualClock::new();
            let mut inflight: HashMap<(u64, u64), (usize, Vec<i32>, ReqKind)> = HashMap::new();
            let mut completed: Vec<(usize, Vec<i32>, ReqKind, StepOut)> = Vec::new();
            for a in &script {
                match a {
                    Action::Arrive { conn, id, variant, tokens, kind } => {
                        let slot = SlotRequest {
                            conn: *conn,
                            id: *id,
                            variant: *variant,
                            tokens: tokens.clone(),
                            produced: Vec::new(),
                            kind: *kind,
                            seq: 0,
                            admitted: 0,
                        };
                        let expect_busy = inflight.len() >= max_slots;
                        match sched.admit(slot, clock.now()) {
                            Admit::Accepted => {
                                assert!(!expect_busy, "admitted past capacity");
                                inflight.insert((*conn, *id), (*variant, tokens.clone(), *kind));
                            }
                            Admit::Busy => assert!(expect_busy, "shed below capacity"),
                        }
                    }
                    Action::Cancel { conn, id } => {
                        let freed = sched.cancel(*conn, *id);
                        assert_eq!(freed, inflight.remove(&(*conn, *id)).is_some());
                    }
                    Action::Disconnect { conn } => {
                        let owned = inflight.keys().filter(|(c, _)| c == conn).count();
                        assert_eq!(sched.drop_conn(*conn), owned);
                        inflight.retain(|(c, _), _| c != conn);
                    }
                    Action::Round => run_round(
                        &engine,
                        max_batch,
                        &mut sched,
                        &mut clock,
                        &mut inflight,
                        &mut completed,
                    ),
                }
                assert_eq!(sched.active(), inflight.len(), "slot leak");
            }
            // drain: everything still admitted must run to completion
            while sched.active() > 0 {
                run_round(
                    &engine,
                    max_batch,
                    &mut sched,
                    &mut clock,
                    &mut inflight,
                    &mut completed,
                );
            }
            assert!(inflight.is_empty(), "in-flight requests never completed");
            // every survivor matches serial execution bit for bit
            for (variant, tokens, kind, got) in &completed {
                let serial =
                    engine.run_to_completion(*variant, tokens, *kind).expect("serial oracle");
                match (&serial, got) {
                    (StepOut::Tokens(a), StepOut::Tokens(b)) => assert_eq!(a, b),
                    (
                        StepOut::Score { nll: a, count: ca },
                        StepOut::Score { nll: b, count: cb },
                    ) => {
                        assert_eq!(a.to_bits(), b.to_bits(), "score must be bit-identical");
                        assert_eq!(ca.to_bits(), cb.to_bits());
                    }
                    _ => panic!("kind mismatch vs oracle"),
                }
            }
        });
    }
}
