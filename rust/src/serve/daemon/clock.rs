//! The scheduler's virtual clock.
//!
//! Every batching decision the daemon makes is keyed to a **tick** — a
//! monotonically increasing logical counter — never to wall time. This
//! is the load-bearing design constraint of the whole serving layer:
//! the scheduler run on a scripted arrival schedule at seeded ticks is
//! a pure function of its event order, so any interleaving bug replays
//! exactly from a printed property-test seed. Wall clocks appear only
//! at the edges (socket pacing, latency *measurement* in the load
//! generator), never in decision logic.

/// A logical scheduler instant. Tick 0 is daemon start; one tick per
/// scheduling round.
pub type Tick = u64;

/// Monotonic tick source. The daemon's event loop advances it once per
/// scheduling round; the deterministic test harness advances it from a
/// scripted schedule.
#[derive(Debug, Default)]
pub struct VirtualClock {
    tick: Tick,
}

impl VirtualClock {
    /// A clock at tick 0.
    pub fn new() -> Self {
        VirtualClock { tick: 0 }
    }

    /// The current tick.
    pub fn now(&self) -> Tick {
        self.tick
    }

    /// Advance by one tick, returning the new value.
    pub fn advance(&mut self) -> Tick {
        self.tick += 1;
        self.tick
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ticks_are_monotonic() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now(), 0);
        assert_eq!(c.advance(), 1);
        assert_eq!(c.advance(), 2);
        assert_eq!(c.now(), 2);
    }
}
