//! The factored QLR serving layer: carry `Q + L·R` end-to-end instead of
//! densifying `W_hat`.
//!
//! The whole point of the Q + L·R parameterization (SRR, and its
//! ancestors LQER / QERA) is that the quantized base and the rank-r
//! correction stay *factored* at inference. This module is the serving
//! representation every consumer dispatches through:
//!
//! * [`LinearOp`] — one linear's weight: either a plain [`Mat`]
//!   (`Dense`) or the factored pair `FactoredQlr { base, l, r }`, whose
//!   matmul evaluates `Qdeq·x + L·(R·x)` by *streaming* dequantization
//!   over the packed code blocks — the dense `W_hat` is never
//!   materialized. The streamed base splits into column stripes across
//!   the worker pool, so even a batch-1 matvec parallelizes (the dense
//!   GEMM path parallelizes over batch rows and degenerates there).
//!   Within a stripe the decode runs the word-at-a-time block kernels
//!   (`quant::packed`): batches stream row-panel × group-aligned column
//!   tiles sized to L1 so every unpacked panel is reused by all samples
//!   while cache-hot, and batch-1 takes [`LinearOp::matvec`] — a
//!   borrowing path that folds `xv·scale` into the unpack
//!   ([`PackedMat::axpy_span`]) and accumulates the `(x·L)·R` correction
//!   into the same stripe tile, one pass over the codes per token. The
//!   pre-kernel scalar paths stay callable
//!   ([`LinearOp::matvec_scalar_ref`], [`packed_matmul_scalar_ref`]) as
//!   the bit-identity oracle and measured-against bench baseline.
//! * [`QuantBase`] — the quantized base: bit-packed codes
//!   ([`PackedMat`], 4–8× smaller than f32 at 2–4 bits) or a dense
//!   fallback for quantizers without a packed format (QuIP#-sim).
//! * [`FactoredModel`] — a whole model: non-linear parameters in a
//!   [`Params`] skeleton plus one [`LinearOp`] per quantizable linear.
//!   Implements [`ModelWeights`], so `model::forward_with` /
//!   `eval::perplexity_native` run the factored model rust-natively,
//!   without PJRT and without densifying.
//!
//! Producers: [`crate::qer::QerResult::into_factored`] (single layer),
//! [`crate::coordinator::run_ptq_factored`] /
//! [`crate::coordinator::SweepRunner::run_factored`] (whole models).
//! `exp::perf::serve_bench` records the dense-vs-factored footprint and
//! throughput into `BENCH_serve.json`.
//!
//! Both [`QuantBase`] payloads sit behind [`Arc`]: rank variants of the
//! same `(layer, quantizer, seed)` sweep cell carry the *same* packed
//! buffer (the sweep engine hands every such outcome one
//! `Arc<PackedMat>` from its `LayerCache`), so a grid of M rank variants
//! holds one base in memory instead of M — and the fleet evaluator
//! ([`crate::eval::fleet`]) recognizes the sharing by pointer identity
//! ([`QuantBase::same_buffer`]) to decode each base once for the whole
//! group via [`LinearOp::matmul_grouped`].

use std::sync::Arc;

use crate::model::{ModelWeights, Params};
use crate::quant::packed::PackedMat;
use crate::tensor::{matmul, Mat};
use crate::util::pool;

pub mod daemon;

/// A serving-path input that cannot be evaluated: malformed op groups
/// and unknown tensor names surface as values instead of panics, so the
/// always-on daemon ([`daemon`]) can refuse one bad request and keep
/// serving every other client.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// [`LinearOp::matmul_grouped`] was called with an empty op group.
    EmptyGroup,
    /// The stacked activation matrix has zero rows.
    EmptyBatch,
    /// Stacked rows are not divisible by the group size.
    RaggedStack {
        /// rows of the stacked activation matrix
        rows: usize,
        /// number of ops in the group
        group: usize,
    },
    /// Ops in one group (or the op and its input) disagree on shape.
    ShapeMismatch {
        /// human-readable description of the disagreement
        what: &'static str,
    },
    /// A request named a tensor the model does not carry.
    UnknownTensor(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::EmptyGroup => write!(f, "empty op group"),
            ServeError::EmptyBatch => write!(f, "zero-row activation batch"),
            ServeError::RaggedStack { rows, group } => {
                write!(f, "stacked rows {rows} not divisible by group {group}")
            }
            ServeError::ShapeMismatch { what } => write!(f, "shape mismatch: {what}"),
            ServeError::UnknownTensor(name) => write!(f, "unknown tensor {name:?}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// The quantized base of a factored linear. Cheap to clone: both
/// variants share their buffer through an [`Arc`].
#[derive(Clone, Debug)]
pub enum QuantBase {
    /// bit-packed codes + per-group scales (uniform / MXINT / GPTQ)
    Packed(Arc<PackedMat>),
    /// dense dequantized fallback (quantizers without a packed format)
    Dense(Arc<Mat>),
}

impl QuantBase {
    /// Input dimension of the base weight.
    pub fn rows(&self) -> usize {
        match self {
            QuantBase::Packed(p) => p.rows,
            QuantBase::Dense(m) => m.rows,
        }
    }

    /// Output dimension of the base weight.
    pub fn cols(&self) -> usize {
        match self {
            QuantBase::Packed(p) => p.cols,
            QuantBase::Dense(m) => m.cols,
        }
    }

    /// Address of the shared underlying buffer — the grouping key the
    /// fleet evaluator uses to detect bases it can decode once per
    /// lock-step group.
    pub fn buffer_ptr(&self) -> usize {
        match self {
            QuantBase::Packed(p) => Arc::as_ptr(p) as usize,
            QuantBase::Dense(m) => Arc::as_ptr(m) as usize,
        }
    }

    /// Whether two bases alias the same underlying buffer (not merely
    /// equal contents).
    pub fn same_buffer(&self, other: &QuantBase) -> bool {
        self.buffer_ptr() == other.buffer_ptr()
    }

    /// Payload bytes this base occupies in memory.
    pub fn bytes(&self) -> usize {
        match self {
            QuantBase::Packed(p) => p.bytes(),
            QuantBase::Dense(m) => m.data.len() * 4,
        }
    }

    /// The shared packed payload, if this base is bit-packed. The wire
    /// codec (`coordinator::wire`) uses this to ship a base's content
    /// once per shard connection and reference it thereafter.
    pub fn as_packed(&self) -> Option<&Arc<PackedMat>> {
        match self {
            QuantBase::Packed(p) => Some(p),
            QuantBase::Dense(_) => None,
        }
    }

    /// The shared dense payload for bases without a packed form.
    pub fn as_dense(&self) -> Option<&Arc<Mat>> {
        match self {
            QuantBase::Packed(_) => None,
            QuantBase::Dense(m) => Some(m),
        }
    }

    /// Dense dequantized form (bit-identical to the quantizer's output
    /// for packed bases — see `quant::packed`).
    pub fn densify(&self) -> Mat {
        match self {
            QuantBase::Packed(p) => p.dequantize(),
            QuantBase::Dense(m) => (**m).clone(),
        }
    }
}

/// One linear layer's weight as the serving path evaluates it.
#[derive(Clone, Debug)]
pub enum LinearOp {
    /// plain dense weight (unquantized parameter)
    Dense(Mat),
    /// factored `W_hat = Qdeq + L·R`, kept factored end-to-end
    FactoredQlr { base: QuantBase, l: Mat, r: Mat },
}

impl LinearOp {
    /// Input dimension (weights are stored W (in × out), applied y = x·W).
    pub fn in_dim(&self) -> usize {
        match self {
            LinearOp::Dense(w) => w.rows,
            LinearOp::FactoredQlr { base, .. } => base.rows(),
        }
    }

    /// Output dimension of the linear.
    pub fn out_dim(&self) -> usize {
        match self {
            LinearOp::Dense(w) => w.cols,
            LinearOp::FactoredQlr { base, .. } => base.cols(),
        }
    }

    /// Rank of the low-rank correction (0 for dense).
    pub fn rank(&self) -> usize {
        match self {
            LinearOp::Dense(_) => 0,
            LinearOp::FactoredQlr { l, .. } => l.cols,
        }
    }

    /// Payload bytes of this representation.
    pub fn bytes(&self) -> usize {
        match self {
            LinearOp::Dense(w) => w.data.len() * 4,
            LinearOp::FactoredQlr { base, l, r } => {
                base.bytes() + (l.data.len() + r.data.len()) * 4
            }
        }
    }

    /// Materialize the dense weight (compatibility path only — serving
    /// never calls this).
    pub fn densify(&self) -> Mat {
        match self {
            LinearOp::Dense(w) => w.clone(),
            LinearOp::FactoredQlr { base, l, r } => {
                let q = base.densify();
                if l.cols == 0 {
                    q
                } else {
                    q.add(&matmul(l, r))
                }
            }
        }
    }

    /// y = x · W for a batch x (rows = samples). The factored form
    /// evaluates `x·Qdeq + (x·L)·R`, streaming the base from packed
    /// codes; `W_hat` is never materialized. A single-row batch takes
    /// the fused [`LinearOp::matvec`] path (correction folded into the
    /// base pass); larger batches run the cache-blocked tile decode.
    pub fn matmul(&self, x: &Mat) -> Mat {
        if x.rows == 1 {
            if let LinearOp::FactoredQlr { .. } = self {
                let y = self.matvec(x.row(0));
                return Mat::from_vec(1, self.out_dim(), y);
            }
        }
        match self {
            LinearOp::Dense(w) => matmul(x, w),
            LinearOp::FactoredQlr { base, l, r } => {
                let mut y = match base {
                    QuantBase::Packed(p) => packed_matmul(p, x),
                    QuantBase::Dense(q) => matmul(x, q),
                };
                if l.cols > 0 {
                    y.add_assign(&matmul(&matmul(x, l), r));
                }
                y
            }
        }
    }

    /// Single-token serving: y = x · W for one activation row, borrowing
    /// `x` — the only allocation is the output row (plus a rank-length
    /// fold for factored ops). The factored path fuses the `(x·L)·R`
    /// correction into the same per-stripe accumulator the streamed base
    /// fills, so a token makes one pass over the codes and one over the
    /// adapter rows with no intermediate `Mat` and no
    /// `matmul`+`add_assign` round trip through memory.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.in_dim(), "matvec dim mismatch");
        match self {
            LinearOp::Dense(w) => dense_matvec(w, x),
            LinearOp::FactoredQlr { base, l, r } => {
                // fold x·L once; stripes add (x·L)·R into their own tile
                let xl = if l.cols > 0 { dense_matvec(l, x) } else { Vec::new() };
                match base {
                    QuantBase::Packed(p) => packed_matvec_fused(p, x, &xl, r),
                    QuantBase::Dense(q) => {
                        let mut y = dense_matvec(q, x);
                        for (k, &u) in xl.iter().enumerate() {
                            if u != 0.0 {
                                for (a, &v) in y.iter_mut().zip(r.row(k)) {
                                    *a += u * v;
                                }
                            }
                        }
                        y
                    }
                }
            }
        }
    }

    /// The pre-kernel single-token path: clone `x` into a 1-row [`Mat`],
    /// scalar-decode base matmul, then the unfused `matmul`+`add_assign`
    /// correction. Retained callable so `exp::perf::serve_bench` and the
    /// property suite *measure* the block-kernel speedup against the
    /// real PR-2 baseline instead of asserting it.
    pub fn matvec_scalar_ref(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.in_dim(), "matvec dim mismatch");
        let xm = Mat::from_vec(1, x.len(), x.to_vec());
        match self {
            LinearOp::Dense(w) => matmul(&xm, w).data,
            LinearOp::FactoredQlr { base, l, r } => {
                let mut y = match base {
                    QuantBase::Packed(p) => packed_matmul_scalar_ref(p, &xm),
                    QuantBase::Dense(q) => matmul(&xm, q),
                };
                if l.cols > 0 {
                    y.add_assign(&matmul(&matmul(&xm, l), r));
                }
                y.data
            }
        }
    }

    /// Lock-step matmul for a *group* of ops evaluated simultaneously.
    ///
    /// `x` vertically stacks one activation block per op (op `g` owns
    /// rows `[g·rows_per, (g+1)·rows_per)` with
    /// `rows_per = x.rows / ops.len()`). When every op is
    /// [`LinearOp::FactoredQlr`] over the *same* base buffer
    /// ([`QuantBase::same_buffer`]) — the sweep-engine layout for rank
    /// variants of one `(layer, quantizer, seed)` cell — the shared base
    /// streams through one [`QuantBase`] matmul over the whole stack, so
    /// each packed code row-span is decoded once for the group instead
    /// of once per op; only the cheap per-op `(x·L)·R` correction runs
    /// per member. Ops without a shared buffer fall back to the per-op
    /// [`LinearOp::matmul`] on their row block.
    ///
    /// Row-for-row bit-identical to calling [`LinearOp::matmul`] per op
    /// on its block whenever the stacked and per-op calls both take the
    /// batched (`rows > 1`) base path — the per-element summation order
    /// is unchanged by stacking.
    ///
    /// Malformed groups — empty, a zero-row stack, rows not divisible by
    /// the group size, ops disagreeing on dimensions — are
    /// [`ServeError`]s, not panics: the serving daemon reaches this from
    /// untrusted request batches and must refuse one bad group without
    /// taking the process down.
    pub fn matmul_grouped(ops: &[&LinearOp], x: &Mat) -> Result<Mat, ServeError> {
        let g = ops.len();
        if g == 0 {
            return Err(ServeError::EmptyGroup);
        }
        if x.rows == 0 {
            return Err(ServeError::EmptyBatch);
        }
        if x.rows % g != 0 {
            return Err(ServeError::RaggedStack { rows: x.rows, group: g });
        }
        if ops.iter().any(|op| op.in_dim() != x.cols) {
            return Err(ServeError::ShapeMismatch { what: "op in_dim vs activation cols" });
        }
        if ops.iter().any(|op| op.out_dim() != ops[0].out_dim()) {
            return Err(ServeError::ShapeMismatch { what: "group ops disagree on out_dim" });
        }
        let rows_per = x.rows / g;

        let shared: Option<&QuantBase> = match ops[0] {
            LinearOp::FactoredQlr { base, .. }
                if ops.iter().all(|op| match op {
                    LinearOp::FactoredQlr { base: b, .. } => base.same_buffer(b),
                    LinearOp::Dense(_) => false,
                }) =>
            {
                Some(base)
            }
            _ => None,
        };

        match shared {
            Some(base) => {
                // one streaming pass over the shared base serves every op
                let mut y = match base {
                    QuantBase::Packed(p) => packed_matmul(p, x),
                    QuantBase::Dense(q) => matmul(x, q),
                };
                for (gi, op) in ops.iter().enumerate() {
                    if let LinearOp::FactoredQlr { l, r, .. } = op {
                        if l.cols > 0 {
                            let xg = x.rows_slice(gi * rows_per, (gi + 1) * rows_per);
                            let corr = matmul(&matmul(&xg, l), r);
                            for i in 0..rows_per {
                                let yrow = y.row_mut(gi * rows_per + i);
                                for (a, &v) in yrow.iter_mut().zip(corr.row(i)) {
                                    *a += v;
                                }
                            }
                        }
                    }
                }
                Ok(y)
            }
            None => {
                let mut y = Mat::zeros(x.rows, ops[0].out_dim());
                for (gi, op) in ops.iter().enumerate() {
                    let yg = op.matmul(&x.rows_slice(gi * rows_per, (gi + 1) * rows_per));
                    for i in 0..rows_per {
                        y.row_mut(gi * rows_per + i).copy_from_slice(yg.row(i));
                    }
                }
                Ok(y)
            }
        }
    }
}

/// Minimum code count before striping the decode across the pool is
/// worth a scoped-thread spawn (~tens of µs per call). Small layers —
/// and fleet eval jobs that already run *inside* a pool worker — take
/// the single-stripe path; stripe count never changes results (each
/// output element lives in exactly one stripe, summed in row order).
const PAR_MIN_CODES: usize = 32 * 1024;

/// Rows per decoded panel in the cache-blocked batched path.
const PANEL_ROWS: usize = 8;

/// Target column-tile width (f32 lanes; group-aligned at use). A decoded
/// `PANEL_ROWS × TILE_COLS` panel is 16 KiB — it, the accumulator rows
/// it feeds, and the code bytes behind it stay L1-resident while a row
/// panel streams, so every unpacked lane is reused by the whole batch at
/// cache speed.
const TILE_COLS: usize = 512;

/// Group-aligned column stripes splitting `p`'s columns across the
/// worker pool (shared by the batched and fused batch-1 paths).
fn stripe_bounds(p: &PackedMat) -> Vec<(usize, usize)> {
    let (m, n) = (p.rows, p.cols);
    let glen = p.scheme.group_len();
    let gpr = p.groups_per_row();
    let stripes = if m * n >= PAR_MIN_CODES {
        pool::n_threads().min(gpr).max(1)
    } else {
        1
    };
    let groups_per_stripe = gpr.div_ceil(stripes);
    (0..stripes)
        .map(|s| {
            let j0 = (s * groups_per_stripe * glen).min(n);
            let j1 = ((s + 1) * groups_per_stripe * glen).min(n);
            (j0, j1)
        })
        .filter(|(j0, j1)| j0 < j1)
        .collect()
}

/// Decode the `rows [i0, i1) × cols [j0, j1)` block of `p` into `out`
/// (row-major, width `j1 - j0`) — the row-panel × column-tile unit the
/// cache-blocked batched path feeds on.
fn decode_block_into(p: &PackedMat, i0: usize, i1: usize, j0: usize, j1: usize, out: &mut [f32]) {
    let w = j1 - j0;
    debug_assert!(out.len() >= (i1 - i0) * w);
    for (ip, i) in (i0..i1).enumerate() {
        p.decode_span_into(i, j0, j1, &mut out[ip * w..(ip + 1) * w]);
    }
}

/// y = x · Qdeq with the base streamed from packed codes through the
/// block decode kernels. Work splits into group-aligned column stripes
/// over the worker pool: every stripe decodes a disjoint slice of the
/// code buffer, so there is no duplicated dequant work at any batch
/// size, and the result is deterministic (per-element summation order is
/// the row order — tiling never reorders the `i` accumulation, so the
/// output is bit-identical to `packed_matmul_scalar_ref`).
fn packed_matmul(p: &PackedMat, x: &Mat) -> Mat {
    assert_eq!(
        x.cols, p.rows,
        "packed matmul shape mismatch: {}x{} · {}x{}",
        x.rows, x.cols, p.rows, p.cols
    );
    let (b, m, n) = (x.rows, p.rows, p.cols);
    let glen = p.scheme.group_len();
    let bounds = stripe_bounds(p);

    let blocks: Vec<(usize, usize, Vec<f32>)> = pool::par_map(bounds.len(), |s| {
        let (j0, j1) = bounds[s];
        let width = j1 - j0;
        let mut acc = vec![0.0f32; b * width];
        if b == 1 {
            // batch-1 serving: fused decode+accumulate, single code pass
            for i in 0..m {
                let xv = x.at(0, i);
                if xv != 0.0 {
                    p.axpy_span(i, j0, j1, xv, &mut acc);
                }
            }
        } else {
            // cache-blocked: group-aligned column tiles × row panels;
            // each decoded panel is reused by every sample while hot
            let tile = (TILE_COLS / glen).max(1) * glen;
            let mut buf = vec![0.0f32; PANEL_ROWS * tile.min(width)];
            let mut jt = j0;
            while jt < j1 {
                let jt1 = (jt + tile).min(j1);
                let tw = jt1 - jt;
                let mut i0 = 0usize;
                while i0 < m {
                    let i1 = (i0 + PANEL_ROWS).min(m);
                    decode_block_into(p, i0, i1, jt, jt1, &mut buf[..(i1 - i0) * tw]);
                    for bi in 0..b {
                        let at = bi * width + (jt - j0);
                        let acc_t = &mut acc[at..at + tw];
                        for (ip, i) in (i0..i1).enumerate() {
                            let xv = x.at(bi, i);
                            if xv == 0.0 {
                                continue;
                            }
                            let trow = &buf[ip * tw..(ip + 1) * tw];
                            for (a, &v) in acc_t.iter_mut().zip(trow) {
                                *a += xv * v;
                            }
                        }
                    }
                    i0 = i1;
                }
                jt = jt1;
            }
        }
        (j0, j1, acc)
    });

    let mut y = Mat::zeros(b, n);
    for (j0, j1, acc) in blocks {
        let width = j1 - j0;
        for bi in 0..b {
            y.row_mut(bi)[j0..j1].copy_from_slice(&acc[bi * width..(bi + 1) * width]);
        }
    }
    y
}

/// The pre-kernel streaming matmul — per-code scalar decode
/// ([`PackedMat::axpy_span_scalar`] / [`PackedMat::decode_span_into_scalar`]),
/// unblocked batched loop, same striping. Retained callable as the bench
/// baseline and the bit-identity oracle for the block kernels
/// (`kernel_bit_identical` in `BENCH_serve.json`).
pub fn packed_matmul_scalar_ref(p: &PackedMat, x: &Mat) -> Mat {
    assert_eq!(
        x.cols, p.rows,
        "packed matmul shape mismatch: {}x{} · {}x{}",
        x.rows, x.cols, p.rows, p.cols
    );
    let (b, m, n) = (x.rows, p.rows, p.cols);
    let bounds = stripe_bounds(p);

    let blocks: Vec<(usize, usize, Vec<f32>)> = pool::par_map(bounds.len(), |s| {
        let (j0, j1) = bounds[s];
        let width = j1 - j0;
        let mut acc = vec![0.0f32; b * width];
        if b == 1 {
            for i in 0..m {
                let xv = x.at(0, i);
                if xv != 0.0 {
                    p.axpy_span_scalar(i, j0, j1, xv, &mut acc);
                }
            }
        } else {
            let mut buf = vec![0.0f32; width];
            for i in 0..m {
                p.decode_span_into_scalar(i, j0, j1, &mut buf);
                for bi in 0..b {
                    let xv = x.at(bi, i);
                    if xv == 0.0 {
                        continue;
                    }
                    for (a, &v) in acc[bi * width..(bi + 1) * width].iter_mut().zip(&buf) {
                        *a += xv * v;
                    }
                }
            }
        }
        (j0, j1, acc)
    });

    let mut y = Mat::zeros(b, n);
    for (j0, j1, acc) in blocks {
        let width = j1 - j0;
        for bi in 0..b {
            y.row_mut(bi)[j0..j1].copy_from_slice(&acc[bi * width..(bi + 1) * width]);
        }
    }
    y
}

/// Dense y = x · W for one activation row, borrowing both: row-major
/// axpy over W's rows, allocating only the output row.
fn dense_matvec(w: &Mat, x: &[f32]) -> Vec<f32> {
    debug_assert_eq!(x.len(), w.rows);
    let mut y = vec![0.0f32; w.cols];
    for (i, &xv) in x.iter().enumerate() {
        if xv != 0.0 {
            for (a, &v) in y.iter_mut().zip(w.row(i)) {
                *a += xv * v;
            }
        }
    }
    y
}

/// Fused batch-1 factored serving: per column stripe, one pass streams
/// the packed base through [`PackedMat::axpy_span`] (scale/lo folded
/// into the unpacked lanes) and then accumulates the low-rank correction
/// `(x·L)·R` into the *same* stripe tile while it is cache-hot — the
/// separate correction `matmul` + `add_assign` round trip through memory
/// is gone. `xl` is the precomputed `x·L` fold (empty for rank 0);
/// stripes are disjoint, so the merge is a plain copy.
fn packed_matvec_fused(p: &PackedMat, x: &[f32], xl: &[f32], r: &Mat) -> Vec<f32> {
    debug_assert_eq!(x.len(), p.rows);
    debug_assert!(xl.is_empty() || (r.rows == xl.len() && r.cols == p.cols));
    let n = p.cols;
    let bounds = stripe_bounds(p);

    let blocks: Vec<(usize, usize, Vec<f32>)> = pool::par_map(bounds.len(), |s| {
        let (j0, j1) = bounds[s];
        let mut acc = vec![0.0f32; j1 - j0];
        for (i, &xv) in x.iter().enumerate() {
            if xv != 0.0 {
                p.axpy_span(i, j0, j1, xv, &mut acc);
            }
        }
        for (k, &u) in xl.iter().enumerate() {
            if u != 0.0 {
                for (a, &v) in acc.iter_mut().zip(&r.row(k)[j0..j1]) {
                    *a += u * v;
                }
            }
        }
        (j0, j1, acc)
    });

    let mut y = vec![0.0f32; n];
    for (j0, j1, acc) in blocks {
        y[j0..j1].copy_from_slice(&acc);
    }
    y
}

/// A whole model in factored serving form: the non-linear parameters
/// (embedding, norms, head) live in a [`Params`] skeleton whose linear
/// slots are unset; every quantizable linear is a [`LinearOp`].
#[derive(Clone, Debug)]
pub struct FactoredModel {
    /// non-linear parameters (embedding, norms, head); the quantized
    /// linear slots are unset
    pub skeleton: Params,
    /// (name, op) in `Params::linear_names` order
    pub ops: Vec<(String, LinearOp)>,
}

impl FactoredModel {
    /// The serving op for the named linear, if it was quantized.
    pub fn op(&self, name: &str) -> Option<&LinearOp> {
        self.ops.iter().find(|(n, _)| n == name).map(|(_, op)| op)
    }

    /// y = x · W for the named linear, refusing unknown tensor names as
    /// [`ServeError::UnknownTensor`] instead of panicking — the daemon's
    /// request path, where the name ultimately comes off the wire. Also
    /// validates the activation width against the op's input dimension.
    pub fn linear_checked(&self, name: &str, x: &Mat) -> Result<Mat, ServeError> {
        if let Some(op) = self.op(name) {
            if op.in_dim() != x.cols {
                return Err(ServeError::ShapeMismatch { what: "op in_dim vs activation cols" });
            }
            return Ok(op.matmul(x));
        }
        match self.skeleton.get_mat(name) {
            Ok(w) if w.rows == x.cols => Ok(matmul(x, &w)),
            Ok(_) => Err(ServeError::ShapeMismatch { what: "param rows vs activation cols" }),
            Err(_) => Err(ServeError::UnknownTensor(name.to_string())),
        }
    }

    /// Densify every linear back into a full [`Params`] (compatibility
    /// with the PJRT artifact path and the legacy dense pipeline).
    pub fn densified_params(&self) -> Params {
        let mut out = self.skeleton.clone();
        for (name, op) in &self.ops {
            out.set_mat(name, &op.densify());
        }
        out
    }

    /// Serving bytes of the quantizable linears (packed codes + scales +
    /// adapter factors).
    pub fn linear_bytes(&self) -> usize {
        self.ops.iter().map(|(_, op)| op.bytes()).sum()
    }

    /// Bytes the same linears occupy densified to f32.
    pub fn dense_linear_bytes(&self) -> usize {
        self.ops.iter().map(|(_, op)| op.in_dim() * op.out_dim() * 4).sum()
    }
}

impl ModelWeights for FactoredModel {
    fn linear(&self, name: &str, x: &Mat) -> Mat {
        match self.op(name) {
            Some(op) => op.matmul(x),
            None => matmul(x, &self.skeleton.get_mat(name).expect("linear param")),
        }
    }

    fn vec(&self, name: &str) -> &[f32] {
        self.skeleton.get_vec(name).expect("vec param")
    }

    fn mat(&self, name: &str) -> Mat {
        self.skeleton.get_mat(name).expect("mat param")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::QuantizerSpec;
    use crate::quant::{QuantCtx, Quantizer};
    use crate::util::{prop, Rng};

    fn rel_err(got: &Mat, want: &Mat) -> f64 {
        got.sub(want).frob() / want.frob().max(1e-12)
    }

    /// Satellite requirement: `FactoredQlr` forward matches the densified
    /// `W_hat` forward within 1e-5 for all three packable quantizer
    /// families, across random shapes, bit-widths, batch sizes and ranks.
    #[test]
    fn prop_factored_forward_matches_densified() {
        prop::check(0xFAC70, 20, |g| {
            let m = 32 * g.dim(3); // 32..96, keeps MXINT blocks whole
            let n = 32 * g.dim(3);
            let bsz = g.dim(4);
            let rank = g.choice(&[0usize, 4, 16]);
            let spec = g.choice(&[
                QuantizerSpec::Mxint { bits: 3, block: 32 },
                QuantizerSpec::Uniform { bits: 4, group: 32, symmetric: true },
                QuantizerSpec::Uniform { bits: 3, group: 32, symmetric: false },
                QuantizerSpec::Gptq { bits: 3, group: 32 },
            ]);
            let w = Mat::randn(m, n, 1.0, &mut g.rng);
            let ctx = QuantCtx::default();
            let (qdeq, packed) = spec.build().quantize_coded(&w, &ctx);
            let packed = packed.expect("all three families pack");

            // exactness half of the contract: unpack == dense quantize
            assert_eq!(packed.dequantize(), qdeq, "{}: unpack diverges", spec.label());

            let l = Mat::randn(m, rank, 0.1, &mut g.rng);
            let r = Mat::randn(rank, n, 0.1, &mut g.rng);
            let what = if rank == 0 { qdeq.clone() } else { qdeq.add(&matmul(&l, &r)) };
            let op = LinearOp::FactoredQlr { base: QuantBase::Packed(Arc::new(packed)), l, r };
            assert!(op.densify().allclose(&what, 1e-6));

            let x = Mat::randn(bsz, m, 1.0, &mut g.rng);
            let dense_y = matmul(&x, &what);
            let fact_y = op.matmul(&x);
            let rel = rel_err(&fact_y, &dense_y);
            assert!(rel < 1e-5, "{}: rel err {rel}", spec.label());

            // single-row serving path (fused decode+accumulate) agrees
            // with the batched one up to summation-order rounding
            let yv = op.matvec(x.row(0));
            let y0 = Mat::from_vec(1, n, yv);
            let f0 = Mat::from_vec(1, n, fact_y.row(0).to_vec());
            assert!(rel_err(&y0, &f0) < 1e-5, "matvec vs batched row diverge");
        });
    }

    /// Satellite contract for the borrowing batch-1 path: at rank 0 the
    /// fused matvec computes the same sums in the same order as the
    /// retained scalar reference (bit-identical — this is the batch-1
    /// half of `kernel_bit_identical`); with a correction the fused path
    /// folds `(x·L)·R` into the base stripes, which reorders the f32
    /// adds, so agreement there is 1e-5.
    #[test]
    fn prop_matvec_matches_scalar_ref() {
        prop::check(0x3A7EC, 15, |g| {
            let m = 32 * g.dim(2);
            let n = 32 * g.dim(2);
            let spec = g.choice(&[
                QuantizerSpec::Mxint { bits: 3, block: 32 },
                QuantizerSpec::Uniform { bits: 4, group: 32, symmetric: false },
                QuantizerSpec::Gptq { bits: 3, group: 32 },
            ]);
            let w = Mat::randn(m, n, 1.0, &mut g.rng);
            let (_, packed) = spec.build().quantize_coded(&w, &QuantCtx::default());
            let base = QuantBase::Packed(Arc::new(packed.expect("packable family")));
            let x = Mat::randn(1, m, 1.0, &mut g.rng);

            let op0 = LinearOp::FactoredQlr {
                base: base.clone(),
                l: Mat::zeros(m, 0),
                r: Mat::zeros(0, n),
            };
            let fast = op0.matvec(x.row(0));
            let slow = op0.matvec_scalar_ref(x.row(0));
            for (k, (a, b)) in fast.iter().zip(&slow).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{}: rank-0 lane {k}", spec.label());
            }

            let op = LinearOp::FactoredQlr {
                base,
                l: Mat::randn(m, 8, 0.1, &mut g.rng),
                r: Mat::randn(8, n, 0.1, &mut g.rng),
            };
            let fast = Mat::from_vec(1, n, op.matvec(x.row(0)));
            let slow = Mat::from_vec(1, n, op.matvec_scalar_ref(x.row(0)));
            assert!(rel_err(&fast, &slow) < 1e-5, "{}: fused matvec diverges", spec.label());
        });
    }

    #[test]
    fn dense_matvec_matches_matmul_row() {
        let mut rng = Rng::new(31);
        let w = Mat::randn(48, 37, 1.0, &mut rng);
        let op = LinearOp::Dense(w.clone());
        let x = Mat::randn(1, 48, 1.0, &mut rng);
        let y = op.matvec(x.row(0));
        let want = matmul(&x, &w);
        assert_eq!(y.len(), 37);
        for (k, (a, b)) in y.iter().zip(want.row(0)).enumerate() {
            assert!((a - b).abs() < 1e-4, "lane {k}: {a} vs {b}");
        }
    }

    #[test]
    fn factored_is_smaller_than_dense() {
        let mut rng = Rng::new(11);
        let w = Mat::randn(128, 256, 1.0, &mut rng);
        let spec = QuantizerSpec::Mxint { bits: 3, block: 32 };
        let (qdeq, packed) = spec.build().quantize_coded(&w, &QuantCtx::default());
        let l = Mat::randn(128, 16, 0.1, &mut rng);
        let r = Mat::randn(16, 256, 0.1, &mut rng);
        let dense = LinearOp::Dense(qdeq.add(&matmul(&l, &r)));
        let fact =
            LinearOp::FactoredQlr { base: QuantBase::Packed(Arc::new(packed.unwrap())), l, r };
        assert_eq!(fact.in_dim(), 128);
        assert_eq!(fact.out_dim(), 256);
        assert_eq!(fact.rank(), 16);
        // 3.25 effective bits + rank-16 adapters still beat 32-bit dense
        assert!(fact.bytes() * 2 < dense.bytes(), "{} vs {}", fact.bytes(), dense.bytes());
    }

    #[test]
    fn dense_base_fallback_matches() {
        // quantizers without a packed format serve through a dense base
        let mut rng = Rng::new(12);
        let w = Mat::randn(64, 64, 1.0, &mut rng);
        let l = Mat::randn(64, 8, 0.1, &mut rng);
        let r = Mat::randn(8, 64, 0.1, &mut rng);
        let what = w.add(&matmul(&l, &r));
        let op = LinearOp::FactoredQlr { base: QuantBase::Dense(Arc::new(w.clone())), l, r };
        let x = Mat::randn(3, 64, 1.0, &mut rng);
        let rel = rel_err(&op.matmul(&x), &matmul(&x, &what));
        assert!(rel < 1e-5);
        assert_eq!(op.densify(), what);
        assert_eq!(QuantBase::Dense(Arc::new(w)).bytes(), 64 * 64 * 4);
    }

    #[test]
    fn rank_zero_op_is_base_only() {
        let mut rng = Rng::new(13);
        let w = Mat::randn(32, 64, 1.0, &mut rng);
        let spec = QuantizerSpec::Uniform { bits: 4, group: 32, symmetric: false };
        let (qdeq, packed) = spec.build().quantize_coded(&w, &QuantCtx::default());
        let op = LinearOp::FactoredQlr {
            base: QuantBase::Packed(Arc::new(packed.unwrap())),
            l: Mat::zeros(32, 0),
            r: Mat::zeros(0, 64),
        };
        assert_eq!(op.densify(), qdeq);
        let x = Mat::randn(2, 32, 1.0, &mut rng);
        assert!(op.matmul(&x).allclose(&matmul(&x, &qdeq), 1e-5));
    }

    /// Tentpole contract: the lock-step grouped matmul over ops sharing
    /// one base buffer is bit-identical to the per-op batched path, for
    /// every packable family and mixed ranks (including rank 0).
    #[test]
    fn prop_grouped_matmul_matches_per_op() {
        prop::check(0xF1EE7, 15, |g| {
            let m = 32 * g.dim(2); // 32..64
            let n = 32 * g.dim(2);
            let rows_per = 2 + g.dim(6); // >= 3 rows: both paths batched
            let spec = g.choice(&[
                QuantizerSpec::Mxint { bits: 3, block: 32 },
                QuantizerSpec::Uniform { bits: 4, group: 32, symmetric: true },
                QuantizerSpec::Gptq { bits: 3, group: 32 },
            ]);
            let w = Mat::randn(m, n, 1.0, &mut g.rng);
            let (_, packed) = spec.build().quantize_coded(&w, &QuantCtx::default());
            let base = QuantBase::Packed(Arc::new(packed.expect("packable family")));

            let ranks = [0usize, 4, 8];
            let ops: Vec<LinearOp> = ranks
                .iter()
                .map(|&rank| LinearOp::FactoredQlr {
                    base: base.clone(),
                    l: Mat::randn(m, rank, 0.1, &mut g.rng),
                    r: Mat::randn(rank, n, 0.1, &mut g.rng),
                })
                .collect();
            let refs: Vec<&LinearOp> = ops.iter().collect();
            assert!(refs.iter().all(|op| match op {
                LinearOp::FactoredQlr { base: b, .. } => base.same_buffer(b),
                _ => false,
            }));

            let x = Mat::randn(refs.len() * rows_per, m, 1.0, &mut g.rng);
            let y = LinearOp::matmul_grouped(&refs, &x).expect("well-formed group");
            assert_eq!((y.rows, y.cols), (x.rows, n));
            for (gi, op) in refs.iter().enumerate() {
                let xg = x.rows_slice(gi * rows_per, (gi + 1) * rows_per);
                let solo = op.matmul(&xg);
                for i in 0..rows_per {
                    assert_eq!(
                        y.row(gi * rows_per + i),
                        solo.row(i),
                        "member {gi} row {i} diverges"
                    );
                }
            }
        });
    }

    #[test]
    fn grouped_matmul_falls_back_without_shared_buffer() {
        // equal *contents*, distinct buffers: must take the per-op path
        // and still agree with per-op matmul
        let mut rng = Rng::new(21);
        let w = Mat::randn(64, 64, 1.0, &mut rng);
        let spec = QuantizerSpec::Mxint { bits: 3, block: 32 };
        let (_, p1) = spec.build().quantize_coded(&w, &QuantCtx::default());
        let (_, p2) = spec.build().quantize_coded(&w, &QuantCtx::default());
        let b1 = QuantBase::Packed(Arc::new(p1.unwrap()));
        let b2 = QuantBase::Packed(Arc::new(p2.unwrap()));
        assert!(!b1.same_buffer(&b2));
        assert!(b1.same_buffer(&b1.clone()), "Arc clone aliases the buffer");
        let l = Mat::randn(64, 4, 0.1, &mut rng);
        let r = Mat::randn(4, 64, 0.1, &mut rng);
        let ops = [
            LinearOp::FactoredQlr { base: b1, l: l.clone(), r: r.clone() },
            LinearOp::FactoredQlr { base: b2, l, r },
        ];
        let refs: Vec<&LinearOp> = ops.iter().collect();
        let x = Mat::randn(6, 64, 1.0, &mut rng);
        let y = LinearOp::matmul_grouped(&refs, &x).expect("well-formed group");
        for (gi, op) in refs.iter().enumerate() {
            let solo = op.matmul(&x.rows_slice(gi * 3, (gi + 1) * 3));
            for i in 0..3 {
                assert_eq!(y.row(gi * 3 + i), solo.row(i));
            }
        }
    }

    /// Bugfix regressions: the grouped matmul edge cases the daemon can
    /// reach from untrusted request batches are errors, never panics.
    #[test]
    fn grouped_matmul_refuses_malformed_groups() {
        let mut rng = Rng::new(41);
        let op = LinearOp::Dense(Mat::randn(8, 8, 1.0, &mut rng));
        let x = Mat::randn(6, 8, 1.0, &mut rng);

        // empty group
        assert_eq!(LinearOp::matmul_grouped(&[], &x), Err(ServeError::EmptyGroup));
        // zero-row batch
        let empty = Mat::zeros(0, 8);
        assert_eq!(
            LinearOp::matmul_grouped(&[&op], &empty),
            Err(ServeError::EmptyBatch)
        );
        // rows not divisible by the group
        let ragged = Mat::randn(5, 8, 1.0, &mut rng);
        assert_eq!(
            LinearOp::matmul_grouped(&[&op, &op], &ragged),
            Err(ServeError::RaggedStack { rows: 5, group: 2 })
        );
        // activation width vs op input dimension
        let narrow = Mat::randn(6, 4, 1.0, &mut rng);
        assert!(matches!(
            LinearOp::matmul_grouped(&[&op], &narrow),
            Err(ServeError::ShapeMismatch { .. })
        ));
        // ops disagreeing on output dimension
        let wide = LinearOp::Dense(Mat::randn(8, 16, 1.0, &mut rng));
        assert!(matches!(
            LinearOp::matmul_grouped(&[&op, &wide], &x),
            Err(ServeError::ShapeMismatch { .. })
        ));
        // a well-formed group still evaluates
        assert!(LinearOp::matmul_grouped(&[&op, &op], &x).is_ok());
    }

    /// Bugfix regression: an unknown tensor name off the wire is a
    /// [`ServeError::UnknownTensor`], not an `expect` panic.
    #[test]
    fn linear_checked_refuses_unknown_tensor() {
        use crate::model::synth::synth_lm_params;
        use crate::runtime::manifest::ModelCfg;
        let cfg = ModelCfg {
            name: "t".into(),
            vocab: 32,
            d_model: 16,
            n_heads: 2,
            n_layers: 1,
            d_ff: 32,
            seq_len: 8,
        };
        let params = synth_lm_params(&cfg, 1, cfg.vocab);
        let model = FactoredModel { skeleton: params, ops: vec![] };
        let x = Mat::zeros(2, 16);
        assert_eq!(
            model.linear_checked("l9.wq", &x),
            Err(ServeError::UnknownTensor("l9.wq".into()))
        );
        // a known linear still evaluates through the checked path
        assert!(model.linear_checked("l0.wq", &x).is_ok());
        // a known linear fed a wrong-width activation is a shape error
        let bad = Mat::zeros(2, 8);
        assert!(matches!(
            model.linear_checked("l0.wq", &bad),
            Err(ServeError::ShapeMismatch { .. })
        ));
    }
}
