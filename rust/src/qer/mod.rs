//! Quantization Error Reconstruction: the paper's algorithm (SRR) and
//! every baseline it compares against.
//!
//! All methods produce `W_hat = Qdeq + L·R` with rank(L·R) ≤ r:
//!
//! | method        | scaling S      | rank allocation                     |
//! |---------------|----------------|-------------------------------------|
//! | w-only        | —              | no correction                       |
//! | ZeroQuant-V2  | I              | k = 0 (all rank on residual)        |
//! | LQER          | diag rms       | k = 0                               |
//! | QERA-approx   | diag abs-mean  | k = 0                               |
//! | QERA-exact    | (E[xxᵀ])^{1/2} | k = 0                               |
//! | LQ-LoRA init  | any            | k = r via iterative Q/LR refinement |
//! | SVDQuant-like | any            | k = r one-shot (preserve only)      |
//! | ODLRI-like    | any            | fixed k = r/2 split                 |
//! | **SRR**       | any            | k = k\* from Eq. (5)                |
//!
//! SRR composes with any scaling/quantizer pair ("plug-and-play"): the
//! experiment grid therefore crosses {LQER, QERA-approx, QERA-exact} ×
//! {±SRR}, exactly like the paper's Table 1.

pub mod rank_select;
pub mod srr;
pub mod methods;
pub mod assumptions;

pub use assumptions::{eta_q, eta_q_from};
pub use methods::{
    correction_from_svd, reconstruct, reconstruct_prepared, Method, QerConfig, QerResult,
};
pub use rank_select::{rho_profile, select_k, PreparedSpectra, RankSelection};
pub use srr::{srr_decompose, srr_single_svd_prepared, srr_with_k_prepared, SrrOutput};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{MxintQuantizer, QuantCtx, Quantizer};
    use crate::scaling::Scaling;
    use crate::tensor::{matmul, Mat};
    use crate::util::Rng;

    /// End-to-end sanity on the module's headline claim: under the same
    /// rank budget, SRR's scaled reconstruction error is no worse than
    /// plain QER on a weight with strong low-rank structure.
    #[test]
    fn srr_beats_qer_on_anisotropic_weight() {
        let mut rng = Rng::new(200);
        // strongly anisotropic W: power-law spectrum
        let u = Mat::randn(96, 96, 1.0, &mut rng);
        let v = Mat::randn(96, 96, 1.0, &mut rng);
        let (qu, _) = crate::linalg::qr_thin(&u);
        let (qv, _) = crate::linalg::qr_thin(&v);
        let mut core = Mat::zeros(96, 96);
        for i in 0..96 {
            *core.at_mut(i, i) = 10.0 / (1.0 + i as f32).powf(1.2);
        }
        let w = matmul(&matmul(&qu, &core), &qv.transpose());

        let quantizer = MxintQuantizer::new(2, 32);
        let scaling = Scaling::Identity;
        let ctx = QuantCtx::default();
        let r = 32;

        // plain QER (k = 0)
        let q = quantizer.quantize(&w, &ctx);
        let resid = w.sub(&q);
        let svd = crate::linalg::jacobi_svd(&resid);
        let qer_err = {
            let rec = q.add(&svd.reconstruct(r));
            w.sub(&rec).frob()
        };

        // SRR
        let out = srr_decompose(&w, &quantizer, &scaling, &ctx, r, 4, &mut rng);
        let lr = matmul(&out.l, &out.r);
        let srr_err = w.sub(&out.qdeq.add(&lr)).frob();

        assert!(out.k_star > 0, "expected preservation on anisotropic W");
        assert!(
            srr_err < qer_err * 1.02,
            "srr {srr_err} should be <= qer {qer_err}"
        );
    }
}
