//! Rank-split selection (paper §4.2, Eq. 5):
//!
//!   k* = argmin_{0 ≤ k ≤ r}  ρ_k(SW) · ρ_{r−k}(SE)
//!
//! where E is a one-shot U[-1,1] random probe standing in for the
//! normalized quantization-error spectrum (Assumption 4.2). Both ρ
//! profiles come from randomized SVDs of the top-r spectra plus exact
//! Frobenius norms — no enumeration of E_k, no extra quantizer calls.
//!
//! The expensive part — the two randomized SVDs — is factored into
//! [`PreparedSpectra`] so a sweep over many `(method, quantizer, rank)`
//! configs computes it once per (layer, scaling, seed) and selects any
//! k ≤ prep rank from the cached spectra (`coordinator::sweep` owns that
//! amortization; `select_k` below is the one-shot convenience wrapper).

use crate::linalg::{randomized_svd, rho, Svd};
use crate::scaling::Scaling;
use crate::tensor::Mat;
use crate::util::Rng;

/// Salt decoupling the spectra RNG stream from the reconstruction stream,
/// so precomputing spectra does not shift the residual-stage draws.
pub(crate) const PREP_SALT: u64 = 0x5EED_0F_5A17_A55A;

/// Everything the selection computed, kept for the analysis benches
/// (Fig. 2 surrogate curves, Fig. 5 k* distributions, Table 12 stability).
#[derive(Clone, Debug, PartialEq)]
pub struct RankSelection {
    pub k_star: usize,
    /// surrogate objective value per k ∈ [0, r]
    pub objective: Vec<f64>,
    /// ρ_k(SW) for k ∈ [0, r]
    pub rho_sw: Vec<f64>,
    /// ρ_{r−k}(SE) for k ∈ [0, r] (indexed by k)
    pub rho_se: Vec<f64>,
    /// leading singular values of SW (length ≥ r)
    pub sw_spectrum: Vec<f32>,
}

/// ρ_p(A) for p = 0..=r given A's leading spectrum and ‖A‖_F².
pub fn rho_profile(sv: &[f32], frob2: f64, r: usize) -> Vec<f64> {
    (0..=r).map(|p| rho(sv, frob2, p)).collect()
}

/// The per-layer spectra every SRR-family reconstruction consumes: the
/// leading randomized SVDs of the scaled weight S·W (spectrum + preserve
/// factors) and of the scaled probe S·E, with exact Frobenius energies.
///
/// Computed once at `rank` = the largest rank the caller will ever select
/// or preserve at; any budget r ≤ `rank` is then served by prefix
/// truncation, which keeps a shared-work sweep bit-identical to the
/// per-config path (both truncate the same factorization).
#[derive(Clone, Debug)]
pub struct PreparedSpectra {
    /// randomized SVD of S·W to `rank` (descending spectrum)
    pub sw_svd: Svd,
    pub sw_frob2: f64,
    /// randomized SVD of the scaled probe S·E
    pub se_svd: Svd,
    pub se_frob2: f64,
    /// the rank the SVDs were computed at (selection budget ceiling)
    pub rank: usize,
    /// seed this was derived from (probe realization identity)
    pub seed: u64,
}

impl PreparedSpectra {
    /// Deterministic preparation from a seed: the RNG stream is private
    /// to the spectra (salted), so per-config and sweep paths that share
    /// a (layer, scaling, seed, rank) key produce identical spectra.
    pub fn compute(w: &Mat, scaling: &Scaling, rank: usize, n_iter: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ PREP_SALT);
        let mut s = Self::compute_with_rng(w, scaling, rank, n_iter, &mut rng);
        s.seed = seed;
        s
    }

    /// Preparation drawing from a caller-owned RNG, in the exact draw
    /// order the original `select_k` used (SW svd → probe → SE svd), so
    /// the legacy wrapper below reproduces its historical output.
    pub fn compute_with_rng(
        w: &Mat,
        scaling: &Scaling,
        rank: usize,
        n_iter: usize,
        rng: &mut Rng,
    ) -> Self {
        let sw = scaling.apply(w);
        let sw_frob2 = sw.frob2();
        let sw_svd = randomized_svd(&sw, rank, n_iter, rng);

        let probe = Mat::rand_uniform(w.rows, w.cols, -1.0, 1.0, rng);
        let se = scaling.apply(&probe);
        let se_frob2 = se.frob2();
        let se_svd = randomized_svd(&se, rank, n_iter, rng);

        PreparedSpectra { sw_svd, sw_frob2, se_svd, se_frob2, rank, seed: 0 }
    }

    /// Eq. (5) selection for any budget `r` ≤ `self.rank`.
    pub fn select(&self, r: usize) -> RankSelection {
        assert!(
            r <= self.rank,
            "select budget {r} exceeds prepared rank {}",
            self.rank
        );
        let rho_sw = rho_profile(&self.sw_svd.s, self.sw_frob2, r);
        let rho_se_by_p = rho_profile(&self.se_svd.s, self.se_frob2, r);

        let mut objective = Vec::with_capacity(r + 1);
        let mut best = (f64::INFINITY, 0usize);
        for k in 0..=r {
            let obj = rho_sw[k] * rho_se_by_p[r - k];
            objective.push(obj);
            if obj < best.0 {
                best = (obj, k);
            }
        }
        RankSelection {
            k_star: best.1,
            objective,
            rho_sw,
            rho_se: (0..=r).map(|k| rho_se_by_p[r - k]).collect(),
            sw_spectrum: self.sw_svd.s.clone(),
        }
    }
}

/// Compute k* for a weight W under scaling S with rank budget r.
///
/// One-shot wrapper over [`PreparedSpectra`]: prepares at `r` and selects
/// at `r`. `n_iter` is the randomized-SVD power-iteration count (paper:
/// 4). The probe E is drawn from `rng` — callers seed it per (layer,
/// seed) so Table 12's stability analysis can vary it.
///
/// **The criterion, in the paper's notation (§4.2, Eq. 5).** With the
/// unrecoverable-energy ratio
///
///   ρ_p(A) = 1 − Σ_{j≤p} σ_j²(A) / ‖A‖²_F
///
/// — the fraction of A's energy *outside* its best rank-p subspace —
/// the split of the budget r into k preserved directions of the scaled
/// weight S·W and r−k reconstruction directions of the scaled error
/// S·E is scored by the product surrogate
///
///   k* = argmin_{0 ≤ k ≤ r}  ρ_k(SW) · ρ_{r−k}(SE).
///
/// ρ_k(SW) is the weight energy still *exposed* to quantization after
/// preserving the top-k directions; ρ_{r−k}(SE) is the error energy a
/// rank-(r−k) correction cannot recover. Preserving more (larger k)
/// shrinks the first factor but starves the correction, growing the
/// second — the argmin balances exposed energy against unrecoverable
/// error. The [`RankSelection`] carries both ρ-profiles (each indexed
/// by k) and their product so analyses can replot the whole curve.
///
/// # Examples
///
/// A weight whose energy concentrates in a few directions should
/// preserve some of them (k* > 0), and the reported profiles reproduce
/// the objective exactly:
///
/// ```
/// use srr::qer::select_k;
/// use srr::scaling::Scaling;
/// use srr::tensor::{matmul, Mat};
/// use srr::util::Rng;
///
/// let mut rng = Rng::new(7);
/// // strongly structured weight: planted rank-4 component + small noise
/// let planted = matmul(&Mat::randn(64, 4, 1.0, &mut rng), &Mat::randn(4, 64, 1.0, &mut rng));
/// let mut w = Mat::randn(64, 64, 0.05, &mut rng);
/// for i in 0..64 {
///     for j in 0..64 {
///         *w.at_mut(i, j) += planted.at(i, j);
///     }
/// }
///
/// let sel = select_k(&w, &Scaling::Identity, 8, 4, &mut rng);
/// assert!(sel.k_star >= 1 && sel.k_star <= 8);
///
/// // objective[k] is exactly ρ_k(SW) · ρ_{r−k}(SE) ...
/// for k in 0..=8 {
///     assert!((sel.objective[k] - sel.rho_sw[k] * sel.rho_se[k]).abs() < 1e-12);
/// }
/// // ... and k* attains its minimum
/// let min = sel.objective.iter().cloned().fold(f64::INFINITY, f64::min);
/// assert_eq!(sel.objective[sel.k_star], min);
/// ```
pub fn select_k(
    w: &Mat,
    scaling: &Scaling,
    r: usize,
    n_iter: usize,
    rng: &mut Rng,
) -> RankSelection {
    PreparedSpectra::compute_with_rng(w, scaling, r, n_iter, rng).select(r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul;
    use crate::util::prop;

    fn power_law_weight(m: usize, n: usize, decay: f32, rng: &mut Rng) -> Mat {
        let (qu, _) = crate::linalg::qr_thin(&Mat::randn(m, m.min(n), 1.0, rng));
        let (qv, _) = crate::linalg::qr_thin(&Mat::randn(n, m.min(n), 1.0, rng));
        let mut core = Mat::zeros(m.min(n), m.min(n));
        for i in 0..m.min(n) {
            *core.at_mut(i, i) = 10.0 / (1.0 + i as f32).powf(decay);
        }
        matmul(&matmul(&qu, &core), &qv.transpose())
    }

    #[test]
    fn k_star_within_budget_and_profiles_monotone() {
        let mut rng = Rng::new(300);
        let w = power_law_weight(64, 80, 1.0, &mut rng);
        let sel = select_k(&w, &Scaling::Identity, 16, 4, &mut rng);
        assert!(sel.k_star <= 16);
        assert_eq!(sel.objective.len(), 17);
        for win in sel.rho_sw.windows(2) {
            assert!(win[1] <= win[0] + 1e-9, "rho_sw must be non-increasing");
        }
        // rho_se indexed by k is ρ_{r−k}(SE): non-decreasing in k
        for win in sel.rho_se.windows(2) {
            assert!(win[1] >= win[0] - 1e-9);
        }
    }

    #[test]
    fn concentrated_spectrum_selects_positive_k() {
        let mut rng = Rng::new(301);
        let w = power_law_weight(96, 96, 1.6, &mut rng); // very concentrated
        let sel = select_k(&w, &Scaling::Identity, 32, 4, &mut rng);
        assert!(sel.k_star > 0, "concentrated W should preserve, got k*=0");
    }

    #[test]
    fn flat_spectrum_objective_is_nearly_flat() {
        // For pure gaussian noise the probe and SW have the same spectral
        // shape, so the surrogate is ~symmetric in k and nearly constant:
        // the selection is genuinely ambivalent (any k costs about the
        // same, matching Eq. 3 — preservation and reconstruction are
        // equally (un)helpful on unstructured weights).
        let mut rng = Rng::new(302);
        let w = Mat::randn(96, 96, 1.0, &mut rng);
        let sel = select_k(&w, &Scaling::Identity, 32, 4, &mut rng);
        let max = sel.objective.iter().cloned().fold(f64::MIN, f64::max);
        let min = sel.objective.iter().cloned().fold(f64::MAX, f64::min);
        assert!((max - min) / max < 0.15, "objective spread too large: {min}..{max}");
    }

    #[test]
    fn slow_decay_selects_interior_k() {
        // Interior optima appear when the spectral decay rate (≈(2p−1)/k
        // for power-law exponent p) crosses the probe's per-rank energy
        // share (≈4/min_dim) inside the budget — i.e. slow decay. Steep
        // decay legitimately drives k* → r (the preserve-everything
        // regime the paper attributes to LQ-LoRA/SVDQuant).
        let mut rng = Rng::new(306);
        let w = power_law_weight(96, 96, 0.6, &mut rng);
        let sel = select_k(&w, &Scaling::Identity, 32, 4, &mut rng);
        assert!(
            sel.k_star > 0 && sel.k_star < 32,
            "expected interior split, got k*={}",
            sel.k_star
        );
    }

    #[test]
    fn steep_decay_selects_full_preservation() {
        let mut rng = Rng::new(307);
        let w = power_law_weight(96, 96, 1.8, &mut rng);
        let sel = select_k(&w, &Scaling::Identity, 16, 4, &mut rng);
        assert!(sel.k_star >= 12, "steep decay should preserve, got k*={}", sel.k_star);
    }

    #[test]
    fn stability_across_probe_seeds() {
        // Table 12: the probe realization barely moves k*
        let mut wrng = Rng::new(303);
        let w = power_law_weight(80, 96, 1.2, &mut wrng);
        let mut ks = vec![];
        for seed in 0..4u64 {
            let mut rng = Rng::new(1000 + seed);
            ks.push(select_k(&w, &Scaling::Identity, 32, 4, &mut rng).k_star as i64);
        }
        let spread = ks.iter().max().unwrap() - ks.iter().min().unwrap();
        assert!(spread <= 3, "k* spread {spread} too large: {ks:?}");
    }

    #[test]
    fn objective_is_product_of_profiles() {
        let mut rng = Rng::new(304);
        let w = power_law_weight(48, 64, 0.8, &mut rng);
        let sel = select_k(&w, &Scaling::Identity, 12, 4, &mut rng);
        for k in 0..=12 {
            let want = sel.rho_sw[k] * sel.rho_se[k];
            assert!((sel.objective[k] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn scaling_changes_selection_inputs() {
        // a diagonal scaling that crushes most rows concentrates SW
        let mut rng = Rng::new(305);
        let w = Mat::randn(64, 64, 1.0, &mut rng);
        let mut d = vec![0.05f32; 64];
        for v in d.iter_mut().take(4) {
            *v = 10.0;
        }
        let s = Scaling::diagonal(d);
        let sel_scaled = select_k(&w, &s, 16, 4, &mut rng);
        let sel_plain = select_k(&w, &Scaling::Identity, 16, 4, &mut rng);
        // scaled version sees a much more concentrated spectrum
        assert!(sel_scaled.rho_sw[4] < sel_plain.rho_sw[4]);
    }

    #[test]
    fn prepared_spectra_are_seed_deterministic_and_prefix_consistent() {
        let mut rng = Rng::new(308);
        let w = power_law_weight(64, 96, 1.1, &mut rng);
        let a = PreparedSpectra::compute(&w, &Scaling::Identity, 12, 4, 42);
        let b = PreparedSpectra::compute(&w, &Scaling::Identity, 12, 4, 42);
        assert_eq!(a.sw_svd.s, b.sw_svd.s);
        assert_eq!(a.se_svd.s, b.se_svd.s);
        assert_eq!(a.seed, 42);
        // selecting a smaller budget uses the spectrum prefix
        let sel8 = a.select(8);
        let sel12 = a.select(12);
        assert_eq!(sel8.objective.len(), 9);
        for k in 0..=8 {
            assert!((sel8.rho_sw[k] - sel12.rho_sw[k]).abs() < 1e-15);
        }
        // a different seed draws a different probe
        let c = PreparedSpectra::compute(&w, &Scaling::Identity, 12, 4, 43);
        assert_ne!(a.se_svd.s, c.se_svd.s);
    }

    #[test]
    fn prop_selection_invariants() {
        // Satellite: k* ≤ r, ρ_SW non-increasing, ρ_SE (by k) non-
        // decreasing, objective = elementwise product, ρ bounded in [0,1]
        // — across random shapes, budgets and spectral decays.
        prop::check(0xC5, 12, |g| {
            let m = 24 + g.rng.below(48);
            let n = 24 + g.rng.below(48);
            let r = 2 + g.rng.below(m.min(n) / 2);
            let decay = g.f32_in(0.2, 2.0);
            let w = power_law_weight(m, n, decay, &mut g.rng);
            let sel = select_k(&w, &Scaling::Identity, r, 2, &mut g.rng);
            assert!(sel.k_star <= r, "k*={} > r={r}", sel.k_star);
            assert_eq!(sel.objective.len(), r + 1);
            assert_eq!(sel.rho_sw.len(), r + 1);
            assert_eq!(sel.rho_se.len(), r + 1);
            for win in sel.rho_sw.windows(2) {
                assert!(win[1] <= win[0] + 1e-9, "rho_sw not non-increasing");
            }
            for win in sel.rho_se.windows(2) {
                assert!(win[1] >= win[0] - 1e-9, "rho_se not non-decreasing");
            }
            for k in 0..=r {
                assert!((0.0..=1.0 + 1e-9).contains(&sel.rho_sw[k]));
                assert!((0.0..=1.0 + 1e-9).contains(&sel.rho_se[k]));
                let want = sel.rho_sw[k] * sel.rho_se[k];
                assert!(
                    (sel.objective[k] - want).abs() < 1e-12,
                    "objective[{k}] not the profile product"
                );
            }
            // the selected k attains the minimum of the objective
            let min = sel.objective.iter().cloned().fold(f64::INFINITY, f64::min);
            assert!((sel.objective[sel.k_star] - min).abs() < 1e-15);
        });
    }
}
