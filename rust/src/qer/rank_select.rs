//! Rank-split selection (paper §4.2, Eq. 5):
//!
//!   k* = argmin_{0 ≤ k ≤ r}  ρ_k(SW) · ρ_{r−k}(SE)
//!
//! where E is a one-shot U[-1,1] random probe standing in for the
//! normalized quantization-error spectrum (Assumption 4.2). Both ρ
//! profiles come from randomized SVDs of the top-r spectra plus exact
//! Frobenius norms — no enumeration of E_k, no extra quantizer calls.

use crate::linalg::{randomized_svd, rho};
use crate::scaling::Scaling;
use crate::tensor::Mat;
use crate::util::Rng;

/// Everything the selection computed, kept for the analysis benches
/// (Fig. 2 surrogate curves, Fig. 5 k* distributions, Table 12 stability).
#[derive(Clone, Debug)]
pub struct RankSelection {
    pub k_star: usize,
    /// surrogate objective value per k ∈ [0, r]
    pub objective: Vec<f64>,
    /// ρ_k(SW) for k ∈ [0, r]
    pub rho_sw: Vec<f64>,
    /// ρ_{r−k}(SE) for k ∈ [0, r] (indexed by k)
    pub rho_se: Vec<f64>,
    /// leading singular values of SW (length ≥ r)
    pub sw_spectrum: Vec<f32>,
}

/// ρ_p(A) for p = 0..=r given A's leading spectrum and ‖A‖_F².
pub fn rho_profile(sv: &[f32], frob2: f64, r: usize) -> Vec<f64> {
    (0..=r).map(|p| rho(sv, frob2, p)).collect()
}

/// Compute k* for a weight W under scaling S with rank budget r.
///
/// `n_iter` is the randomized-SVD power-iteration count (paper: 4).
/// The probe E is drawn from `rng` — callers seed it per (layer, seed) so
/// Table 12's stability analysis can vary it.
pub fn select_k(
    w: &Mat,
    scaling: &Scaling,
    r: usize,
    n_iter: usize,
    rng: &mut Rng,
) -> RankSelection {
    let sw = scaling.apply(w);
    let sw_frob2 = sw.frob2();
    let sw_svd = randomized_svd(&sw, r, n_iter, rng);

    let probe = Mat::rand_uniform(w.rows, w.cols, -1.0, 1.0, rng);
    let se = scaling.apply(&probe);
    let se_frob2 = se.frob2();
    let se_svd = randomized_svd(&se, r, n_iter, rng);

    let rho_sw = rho_profile(&sw_svd.s, sw_frob2, r);
    let rho_se_by_p = rho_profile(&se_svd.s, se_frob2, r);

    let mut objective = Vec::with_capacity(r + 1);
    let mut best = (f64::INFINITY, 0usize);
    for k in 0..=r {
        let obj = rho_sw[k] * rho_se_by_p[r - k];
        objective.push(obj);
        if obj < best.0 {
            best = (obj, k);
        }
    }
    RankSelection {
        k_star: best.1,
        objective,
        rho_sw,
        rho_se: (0..=r).map(|k| rho_se_by_p[r - k]).collect(),
        sw_spectrum: sw_svd.s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul;

    fn power_law_weight(m: usize, n: usize, decay: f32, rng: &mut Rng) -> Mat {
        let (qu, _) = crate::linalg::qr_thin(&Mat::randn(m, m.min(n), 1.0, rng));
        let (qv, _) = crate::linalg::qr_thin(&Mat::randn(n, m.min(n), 1.0, rng));
        let mut core = Mat::zeros(m.min(n), m.min(n));
        for i in 0..m.min(n) {
            *core.at_mut(i, i) = 10.0 / (1.0 + i as f32).powf(decay);
        }
        matmul(&matmul(&qu, &core), &qv.transpose())
    }

    #[test]
    fn k_star_within_budget_and_profiles_monotone() {
        let mut rng = Rng::new(300);
        let w = power_law_weight(64, 80, 1.0, &mut rng);
        let sel = select_k(&w, &Scaling::Identity, 16, 4, &mut rng);
        assert!(sel.k_star <= 16);
        assert_eq!(sel.objective.len(), 17);
        for win in sel.rho_sw.windows(2) {
            assert!(win[1] <= win[0] + 1e-9, "rho_sw must be non-increasing");
        }
        // rho_se indexed by k is ρ_{r−k}(SE): non-decreasing in k
        for win in sel.rho_se.windows(2) {
            assert!(win[1] >= win[0] - 1e-9);
        }
    }

    #[test]
    fn concentrated_spectrum_selects_positive_k() {
        let mut rng = Rng::new(301);
        let w = power_law_weight(96, 96, 1.6, &mut rng); // very concentrated
        let sel = select_k(&w, &Scaling::Identity, 32, 4, &mut rng);
        assert!(sel.k_star > 0, "concentrated W should preserve, got k*=0");
    }

    #[test]
    fn flat_spectrum_objective_is_nearly_flat() {
        // For pure gaussian noise the probe and SW have the same spectral
        // shape, so the surrogate is ~symmetric in k and nearly constant:
        // the selection is genuinely ambivalent (any k costs about the
        // same, matching Eq. 3 — preservation and reconstruction are
        // equally (un)helpful on unstructured weights).
        let mut rng = Rng::new(302);
        let w = Mat::randn(96, 96, 1.0, &mut rng);
        let sel = select_k(&w, &Scaling::Identity, 32, 4, &mut rng);
        let max = sel.objective.iter().cloned().fold(f64::MIN, f64::max);
        let min = sel.objective.iter().cloned().fold(f64::MAX, f64::min);
        assert!((max - min) / max < 0.15, "objective spread too large: {min}..{max}");
    }

    #[test]
    fn slow_decay_selects_interior_k() {
        // Interior optima appear when the spectral decay rate (≈(2p−1)/k
        // for power-law exponent p) crosses the probe's per-rank energy
        // share (≈4/min_dim) inside the budget — i.e. slow decay. Steep
        // decay legitimately drives k* → r (the preserve-everything
        // regime the paper attributes to LQ-LoRA/SVDQuant).
        let mut rng = Rng::new(306);
        let w = power_law_weight(96, 96, 0.6, &mut rng);
        let sel = select_k(&w, &Scaling::Identity, 32, 4, &mut rng);
        assert!(
            sel.k_star > 0 && sel.k_star < 32,
            "expected interior split, got k*={}",
            sel.k_star
        );
    }

    #[test]
    fn steep_decay_selects_full_preservation() {
        let mut rng = Rng::new(307);
        let w = power_law_weight(96, 96, 1.8, &mut rng);
        let sel = select_k(&w, &Scaling::Identity, 16, 4, &mut rng);
        assert!(sel.k_star >= 12, "steep decay should preserve, got k*={}", sel.k_star);
    }

    #[test]
    fn stability_across_probe_seeds() {
        // Table 12: the probe realization barely moves k*
        let mut wrng = Rng::new(303);
        let w = power_law_weight(80, 96, 1.2, &mut wrng);
        let mut ks = vec![];
        for seed in 0..4u64 {
            let mut rng = Rng::new(1000 + seed);
            ks.push(select_k(&w, &Scaling::Identity, 32, 4, &mut rng).k_star as i64);
        }
        let spread = ks.iter().max().unwrap() - ks.iter().min().unwrap();
        assert!(spread <= 3, "k* spread {spread} too large: {ks:?}");
    }

    #[test]
    fn objective_is_product_of_profiles() {
        let mut rng = Rng::new(304);
        let w = power_law_weight(48, 64, 0.8, &mut rng);
        let sel = select_k(&w, &Scaling::Identity, 12, 4, &mut rng);
        for k in 0..=12 {
            let want = sel.rho_sw[k] * sel.rho_se[k];
            assert!((sel.objective[k] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn scaling_changes_selection_inputs() {
        // a diagonal scaling that crushes most rows concentrates SW
        let mut rng = Rng::new(305);
        let w = Mat::randn(64, 64, 1.0, &mut rng);
        let mut d = vec![0.05f32; 64];
        for v in d.iter_mut().take(4) {
            *v = 10.0;
        }
        let s = Scaling::diagonal(d);
        let sel_scaled = select_k(&w, &s, 16, 4, &mut rng);
        let sel_plain = select_k(&w, &Scaling::Identity, 16, 4, &mut rng);
        // scaled version sees a much more concentrated spectrum
        assert!(sel_scaled.rho_sw[4] < sel_plain.rho_sw[4]);
    }
}
