//! Empirical validation of the paper's modeling assumptions (Appendix E,
//! Tables 20–21).
//!
//! * Assumption 4.1 — quantization error has ~constant relative scale:
//!   η_Q(A) = ‖S·E_Q(A)‖_F / ‖S·A‖_F varies weakly across matrices.
//!   Metric: coefficient of variation across layers.
//! * Assumption 4.2 — the normalized quantization-error spectrum is
//!   k-insensitive and matched by a U[-1,1] random probe:
//!   ρ_{r−k}(S·E_k) ≈ ρ_{r−k}(S·E).
//!   Metric: mean relative error between the two profiles.

use crate::linalg::{randomized_svd, rho};
use crate::quant::{QuantCtx, Quantizer};
use crate::scaling::Scaling;
use crate::tensor::Mat;
use crate::util::stats::{coeff_of_variation, mean_relative_error};
use crate::util::Rng;

/// η_Q for one matrix under one scaling.
pub fn eta_q(w: &Mat, quantizer: &dyn Quantizer, scaling: &Scaling, ctx: &QuantCtx) -> f64 {
    eta_q_from(w, &quantizer.quantize(w, ctx), scaling)
}

/// η_Q given an already-dequantized `qdeq` (the k=0 quantization of
/// `w`): ‖S·(W − Qdeq)‖_F / ‖S·W‖_F. Split out from [`eta_q`] so callers
/// holding a cached quantization — the sweep engine's `LayerCache`, and
/// the budget allocator's per-(layer, bits) exposed-energy estimates
/// ([`crate::coordinator::budget`]) — don't quantize a second time.
pub fn eta_q_from(w: &Mat, qdeq: &Mat, scaling: &Scaling) -> f64 {
    let num = scaling.apply(&w.sub(qdeq)).frob();
    let den = scaling.apply(w).frob();
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
}

/// CV of η_Q across a set of weight matrices (Assumption 4.1 check).
pub fn eta_q_cv(
    weights: &[&Mat],
    quantizer: &dyn Quantizer,
    scaling: &Scaling,
    ctx: &QuantCtx,
) -> f64 {
    let etas: Vec<f64> = weights
        .iter()
        .map(|w| eta_q(w, quantizer, scaling, ctx))
        .collect();
    coeff_of_variation(&etas)
}

/// ρ profile of the *true* quantization error at split k versus the
/// random-probe proxy, over k ∈ {0, step, 2·step, …, r}. Returns
/// (actual ρ_{r−k}(SE_k) values, proxy ρ_{r−k}(SE) values, MRE).
pub fn proxy_alignment(
    w: &Mat,
    quantizer: &dyn Quantizer,
    scaling: &Scaling,
    ctx: &QuantCtx,
    rank: usize,
    step: usize,
    n_iter: usize,
    rng: &mut Rng,
) -> (Vec<f64>, Vec<f64>, f64) {
    // proxy spectrum (one shot)
    let probe = Mat::rand_uniform(w.rows, w.cols, -1.0, 1.0, rng);
    let se = scaling.apply(&probe);
    let se_svd = randomized_svd(&se, rank, n_iter, rng);
    let se_frob2 = se.frob2();

    let mut actual = Vec::new();
    let mut proxy = Vec::new();
    let mut k = 0;
    while k <= rank {
        // true E_k: preserve k, quantize, measure error spectrum
        let preserved = if k > 0 {
            let sw = scaling.apply(w);
            let svd = randomized_svd(&sw, k, n_iter, rng);
            scaling.unapply(&svd.reconstruct(k))
        } else {
            Mat::zeros(w.rows, w.cols)
        };
        let resid = w.sub(&preserved);
        let q = quantizer.quantize(&resid, ctx);
        let ek = resid.sub(&q);
        let sek = scaling.apply(&ek);
        let sek_svd = randomized_svd(&sek, rank, n_iter, rng);
        actual.push(rho(&sek_svd.s, sek.frob2(), rank - k));
        proxy.push(rho(&se_svd.s, se_frob2, rank - k));
        k += step.max(1);
    }
    let mre = mean_relative_error(&actual, &proxy);
    (actual, proxy, mre)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::MxintQuantizer;

    #[test]
    fn eta_q_scale_invariant_for_mxint() {
        // MXINT's power-of-two scales make η_Q nearly invariant to global
        // rescaling of the input — the heart of Assumption 4.1.
        let mut rng = Rng::new(500);
        let w = Mat::randn(64, 96, 1.0, &mut rng);
        let q = MxintQuantizer::new(3, 32);
        let ctx = QuantCtx::default();
        let e1 = eta_q(&w, &q, &Scaling::Identity, &ctx);
        let e2 = eta_q(&w.scale(8.0), &q, &Scaling::Identity, &ctx);
        assert!((e1 - e2).abs() / e1 < 0.05, "{e1} vs {e2}");
    }

    #[test]
    fn eta_q_cv_moderate_across_random_layers() {
        let mut rng = Rng::new(501);
        let ws: Vec<Mat> = (0..6).map(|_| Mat::randn(48, 64, 1.0, &mut rng)).collect();
        let refs: Vec<&Mat> = ws.iter().collect();
        let cv = eta_q_cv(&refs, &MxintQuantizer::new(3, 32), &Scaling::Identity, &QuantCtx::default());
        assert!(cv < 0.3, "cv={cv}");
    }

    #[test]
    fn proxy_tracks_actual_spectrum() {
        let mut rng = Rng::new(502);
        let w = Mat::randn(64, 96, 0.7, &mut rng);
        let (actual, proxy, mre) = proxy_alignment(
            &w,
            &MxintQuantizer::new(3, 32),
            &Scaling::Identity,
            &QuantCtx::default(),
            16,
            4,
            2,
            &mut rng,
        );
        assert_eq!(actual.len(), proxy.len());
        // the paper reports MRE ≈ 4% at 3 bits; allow generous slack here
        assert!(mre < 0.25, "mre={mre}, actual={actual:?}, proxy={proxy:?}");
    }

    #[test]
    fn higher_bits_tighten_the_proxy() {
        let mut rng = Rng::new(503);
        let w = Mat::randn(64, 96, 0.7, &mut rng);
        let ctx = QuantCtx::default();
        let (_, _, mre3) = proxy_alignment(
            &w, &MxintQuantizer::new(3, 32), &Scaling::Identity, &ctx, 16, 8, 2, &mut rng,
        );
        let (_, _, mre4) = proxy_alignment(
            &w, &MxintQuantizer::new(4, 32), &Scaling::Identity, &ctx, 16, 8, 2, &mut rng,
        );
        // 4-bit error is closer to unstructured noise (paper Table 20)
        assert!(mre4 <= mre3 * 1.5, "mre4={mre4} mre3={mre3}");
    }
}
